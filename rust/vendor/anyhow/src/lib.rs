//! Minimal, dependency-free stand-in for the `anyhow` crate.
//!
//! The build image is offline with no registry access, so this vendored
//! shim provides the subset of the real crate's API that the CIMR-V tree
//! uses: [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Errors are stored as a flat
//! message chain (outermost context first) — enough for the crate's
//! diagnostics, which only ever format errors for humans.
//!
//! The coherence trick for making `.context(..)` work on both
//! `Result<T, E: std::error::Error>` and `Result<T, anyhow::Error>` is the
//! same private-extension-trait pattern the real crate uses.

use std::fmt;

/// Result alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chain error value: `chain[0]` is the outermost message, later
/// entries are the causes added below it.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain inline, like the real crate.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

mod private {
    use super::Error;

    /// Sealed conversion trait: implemented for every std error AND for
    /// `Error` itself, so `Context` below needs only one blanket impl.
    pub trait IntoError {
        fn into_error(self) -> Error;
        fn add_context(self, context: String) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
        fn add_context(self, context: String) -> Error {
            Error::from(self).context(context)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
        fn add_context(self, context: String) -> Error {
            self.context(context)
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: private::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.add_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.add_context(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_message() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert!(format!("{e:#}").contains("no such file"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn context_on_std_result() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["outer", "inner 7"]);
        let o: Option<u32> = None;
        assert!(o.context("missing").is_err());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            ensure!(x != 3);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(3).unwrap_err().to_string().contains("condition failed"));
        assert!(f(5).is_err());
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = String::from_utf8(vec![0xFF])?;
            Ok(s)
        }
        assert!(g().is_err());
    }
}
