//! Telemetry overhead on the packed serving path: the unified telemetry
//! subsystem promises that *disabled* telemetry is free and *enabled*
//! telemetry is cheap. This bench drives the same batched fast-path
//! inference three ways —
//!
//! * **baseline**: `FastSim::infer_batch` called directly (no telemetry
//!   code on the path at all);
//! * **disabled**: `FastBackend::run_batch` with telemetry off (the
//!   global-off fast path: one relaxed load, then the baseline call);
//! * **enabled**: `FastBackend::run_batch` with telemetry on (registry
//!   get-or-create + histogram/counter updates per batch);
//!
//! — interleaved per rep with min-of-reps timing, and asserts the
//! disabled overhead is <= 1% and the enabled overhead is <= 5% of the
//! baseline. The enabled path now includes the scoped self-time
//! profiler's regions (backend + per-layer), so the same gates also
//! bound the profiler's cost. Results land in `BENCH_observability.json`
//! and are folded with every other `BENCH_*.json` into
//! `BENCH_summary.json` (stamped from `CIMRV_BENCH_STAMP`).
//!
//! `CIMRV_BENCH_QUICK=1` shrinks reps/iters for the CI smoke run; the
//! asserts still run.

mod common;

use std::hint::black_box;
use std::time::Instant;

use cimrv::backend::{FastBackend, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::fsim::FastSim;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::telemetry;
use cimrv::util::json::Json;

const BATCH: usize = 8;

fn main() {
    let quick = std::env::var("CIMRV_BENCH_QUICK").is_ok_and(|v| v != "0" && !v.is_empty());
    let (model, model_kind) = match KwsModel::load_default() {
        Ok(m) => (m, "trained"),
        Err(_) => {
            println!("(artifacts not found: benchmarking the synthetic model)");
            (KwsModel::synthetic(1), "synthetic")
        }
    };
    let prog = build_kws_program(&model, OptLevel::FULL).expect("codegen");
    // One batch thread: the comparison is about per-call overhead, so
    // keep the measured quantity free of thread-pool scheduling jitter.
    let sim = std::sync::Arc::new(
        FastSim::new(prog, DramConfig::default()).expect("fsim").with_batch_threads(1),
    );
    let mut be = FastBackend::shared(std::sync::Arc::clone(&sim));

    let audios: Vec<Vec<f32>> = (0..BATCH)
        .map(|i| dataset::synth_utterance(i % 12, 900 + i as u64, model.audio_len, 0.37))
        .collect();
    let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();

    let (reps, iters) = if quick { (4, 3) } else { (8, 6) };

    // Warm both paths (page in weights, settle the allocator).
    telemetry::set_enabled(false);
    black_box(sim.infer_batch(&refs));
    black_box(be.run_batch(&refs).expect("warmup"));

    // Interleave the three modes inside every rep so clock drift and
    // cache state hit all of them equally; min-of-reps drops the noise.
    let mut best = [f64::INFINITY; 3]; // baseline, disabled, enabled
    for _ in 0..reps {
        telemetry::set_enabled(false);
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(sim.infer_batch(&refs));
        }
        best[0] = best[0].min(t0.elapsed().as_secs_f64() / iters as f64);

        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(be.run_batch(&refs).expect("disabled run"));
        }
        best[1] = best[1].min(t0.elapsed().as_secs_f64() / iters as f64);

        telemetry::set_enabled(true);
        let t0 = Instant::now();
        for _ in 0..iters {
            black_box(be.run_batch(&refs).expect("enabled run"));
        }
        best[2] = best[2].min(t0.elapsed().as_secs_f64() / iters as f64);
    }
    telemetry::set_enabled(false);
    let [base, disabled, enabled] = best;

    // Overhead relative to the direct call, clamped at 0 (a faster-than-
    // baseline measurement is noise, not negative cost).
    let pct = |t: f64| (100.0 * (t / base - 1.0)).max(0.0);
    let (disabled_pct, enabled_pct) = (pct(disabled), pct(enabled));
    println!(
        "batch {BATCH} fast-path: baseline {:8.3} ms | run_batch off {:8.3} ms (+{:.2}%) | \
         run_batch on {:8.3} ms (+{:.2}%)",
        1e3 * base,
        1e3 * disabled,
        disabled_pct,
        1e3 * enabled,
        enabled_pct
    );

    // The enabled runs must actually have recorded — a silently disabled
    // path would pass the overhead gate without measuring anything.
    let batches = telemetry::global().counter("backend.fast.batches").get();
    assert!(
        batches >= (reps * iters) as u64,
        "enabled runs recorded {batches} batches, expected >= {}",
        reps * iters
    );
    // Same honesty check for the profiler: the ≤5% gate is only a
    // profiler bound if the enabled runs actually opened regions.
    let fold = telemetry::global_profiler().fold();
    assert!(
        fold.contains_key("backend_fast_run"),
        "enabled runs recorded no backend_fast_run region (profiler silently off?): {:?}",
        fold.keys().collect::<Vec<_>>()
    );

    let doc = Json::obj(vec![
        ("model", Json::str(model_kind)),
        ("quick", Json::Bool(quick)),
        ("batch", Json::num(BATCH as f64)),
        ("reps", Json::num(reps as f64)),
        ("iters_per_rep", Json::num(iters as f64)),
        ("baseline_ms_per_batch", Json::num(1e3 * base)),
        ("disabled_ms_per_batch", Json::num(1e3 * disabled)),
        ("enabled_ms_per_batch", Json::num(1e3 * enabled)),
        ("disabled_overhead_pct", Json::num(disabled_pct)),
        ("enabled_overhead_pct", Json::num(enabled_pct)),
        ("enabled_batches_recorded", Json::num(batches as f64)),
    ]);
    std::fs::write("BENCH_observability.json", format!("{doc}\n"))
        .expect("writing BENCH_observability.json");
    println!("wrote BENCH_observability.json");
    let stamp = std::env::var("CIMRV_BENCH_STAMP").unwrap_or_else(|_| "local".to_string());
    common::write_bench_summary(&stamp);

    assert!(
        disabled_pct <= 1.0,
        "disabled telemetry must cost <= 1% on the packed serving path (got {disabled_pct:.2}%)"
    );
    assert!(
        enabled_pct <= 5.0,
        "enabled telemetry must cost <= 5% on the packed serving path (got {enabled_pct:.2}%)"
    );
    println!("telemetry overhead: disabled <= 1%, enabled <= 5% \u{2713}");
}
