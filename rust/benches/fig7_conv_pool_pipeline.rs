//! Fig. 7 — convolution/max-pooling pipeline: pooling fused into the CIM
//! drain path (OR of the SA latch with the pool register) vs a separate
//! RISC-V pooling pass with the macro idle.
//! Paper: −40.00% (additional). Measured on top of layer+weight fusion,
//! matching the paper's cumulative ordering.

mod common;

use cimrv::baselines::OptLevel;

fn main() {
    let model = common::model();
    let audio = common::audio(&model, 3, 1);

    let unfused = common::run_once(
        &model,
        OptLevel { layer_fusion: true, weight_fusion: true, conv_pool_pipeline: false },
        &audio,
    );
    let fused = common::run_once(&model, OptLevel::FULL, &audio);

    println!("=== Fig. 7: conv/max-pool pipeline ===");
    println!("{:<28}{:>14}{:>16}", "config", "conv cycles", "accel cycles");
    println!(
        "{:<28}{:>14}{:>16}",
        "separate pooling pass", unfused.phases.conv, unfused.phases.accelerated()
    );
    println!(
        "{:<28}{:>14}{:>16}",
        "pipelined (pool-OR drain)", fused.phases.conv, fused.phases.accelerated()
    );
    let conv_red = 100.0 * (1.0 - fused.phases.conv as f64 / unfused.phases.conv as f64);
    let accel_red = 100.0
        * (1.0 - fused.phases.accelerated() as f64 / unfused.phases.accelerated() as f64);
    println!(
        "conv-phase reduction: {conv_red:.2}% | accelerated-phase: {accel_red:.2}% (paper: 40.00%)"
    );
    assert_eq!(unfused.logits, fused.logits, "pipeline must not change values");
}
