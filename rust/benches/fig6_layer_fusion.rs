//! Fig. 6 — CIM layer fusion: convolution-phase latency with inter-layer
//! feature maps kept in FM SRAM vs round-tripped through DRAM.
//! Paper: −33.16% of convolution execution. Our model's binary FMs are
//! much smaller relative to its weights, so the absolute share is lower;
//! the direction and mechanism (saved DRAM FM traffic) are the claim.

mod common;

use cimrv::baselines::OptLevel;

fn main() {
    let model = common::model();
    let audio = common::audio(&model, 3, 1);

    let base = common::run_once(&model, OptLevel::BASELINE, &audio);
    let fused = common::run_once(
        &model,
        OptLevel { layer_fusion: true, ..OptLevel::BASELINE },
        &audio,
    );

    println!("=== Fig. 6: CIM layer fusion ===");
    println!("{:<24}{:>16}{:>16}{:>18}", "config", "conv cycles", "accel cycles", "DRAM bytes");
    // Real byte counts from the activity accounting — not dram_pj divided
    // by an assumed pJ/byte, which silently skewed this column whenever
    // the energy table changed.
    println!(
        "{:<24}{:>16}{:>16}{:>18}",
        "no fusion (DRAM FM)",
        base.phases.conv,
        base.phases.accelerated(),
        base.energy.dram_bytes
    );
    println!(
        "{:<24}{:>16}{:>16}{:>18}",
        "layer fusion (on-chip)",
        fused.phases.conv,
        fused.phases.accelerated(),
        fused.energy.dram_bytes
    );
    let conv_red = 100.0 * (1.0 - fused.phases.conv as f64 / base.phases.conv as f64);
    let accel_red =
        100.0 * (1.0 - fused.phases.accelerated() as f64 / base.phases.accelerated() as f64);
    println!(
        "conv-phase reduction: {conv_red:.2}% | accelerated-phase: {accel_red:.2}% \
         (paper: 33.16% of conv execution)"
    );
    assert_eq!(base.logits, fused.logits, "fusion must not change values");
}
