//! Fig. 6 — CIM layer fusion: convolution-phase latency with inter-layer
//! feature maps kept in FM SRAM vs round-tripped through DRAM, and the
//! full fused resident schedule (co-resident sign planes + conv/max-pool
//! pipelining + weight fusion) on top.
//! Paper: −33.16% of convolution execution from FM fusion alone, 85.14%
//! total. Our model's binary FMs are much smaller relative to its
//! weights, so the absolute share is lower; the direction and mechanism
//! (saved DRAM FM + weight traffic) are the claim.

mod common;

use cimrv::baselines::OptLevel;

fn main() {
    let model = common::model();
    let audio = common::audio(&model, 3, 1);

    let base = common::run_once(&model, OptLevel::BASELINE, &audio);
    let fm_fused = common::run_once(
        &model,
        OptLevel { layer_fusion: true, ..OptLevel::BASELINE },
        &audio,
    );
    let full = common::run_once(&model, OptLevel::FULL, &audio);
    // The real fused path: weights stay resident across inferences, so
    // the steady-state DRAM traffic is the audio fetch alone.
    let fused = common::run_once(&model, OptLevel::FUSED, &audio);

    println!("=== Fig. 6: CIM layer fusion ===");
    println!("{:<24}{:>16}{:>16}{:>18}", "config", "conv cycles", "accel cycles", "DRAM bytes");
    // Real byte counts from the activity accounting — not dram_pj divided
    // by an assumed pJ/byte, which silently skewed this column whenever
    // the energy table changed.
    for (name, r) in [
        ("no fusion (DRAM FM)", &base),
        ("layer fusion (on-chip)", &fm_fused),
        ("full ladder", &full),
        ("fused resident", &fused),
    ] {
        println!(
            "{:<24}{:>16}{:>16}{:>18}",
            name,
            r.phases.conv,
            r.phases.accelerated(),
            r.energy.dram_bytes
        );
    }
    let conv_red = 100.0 * (1.0 - fm_fused.phases.conv as f64 / base.phases.conv as f64);
    let accel_red =
        100.0 * (1.0 - fused.phases.accelerated() as f64 / base.phases.accelerated() as f64);
    let dram_red = 100.0 * (1.0 - fused.energy.dram_bytes as f64 / full.energy.dram_bytes as f64);
    println!(
        "FM-fusion conv-phase reduction: {conv_red:.2}% (paper: 33.16% of conv execution)"
    );
    println!(
        "fused resident accelerated-phase reduction: {accel_red:.2}% (paper: 85.14% total) | \
         per-inference DRAM traffic vs full: -{dram_red:.2}% \
         ({} -> {} bytes, resident weights leave only the audio fetch)",
        full.energy.dram_bytes, fused.energy.dram_bytes
    );
    assert_eq!(base.logits, fm_fused.logits, "FM fusion must not change values");
    assert_eq!(base.logits, fused.logits, "the fused schedule must not change values");
    assert!(
        fused.energy.dram_bytes < full.energy.dram_bytes,
        "fused per-inference DRAM bytes must undercut the full ladder"
    );
}
