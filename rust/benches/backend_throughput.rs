//! Backend throughput: inferences/sec of the cycle-level SoC vs the fast
//! functional simulator on the same compiled program — the headline
//! number for the `backend` subsystem (target: >= 20x; in practice the
//! fast backend lands orders of magnitude higher because it skips the
//! ~10^6-step CPU loop entirely).
//!
//! Runs on the trained artifacts when present, else on the synthetic
//! model, so it works straight after `cargo build`.

use std::time::Instant;

use cimrv::backend::{self, BackendKind, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};

fn main() {
    let model = KwsModel::load_default().unwrap_or_else(|_| {
        println!("(artifacts not found: benchmarking the synthetic model)");
        KwsModel::synthetic(1)
    });
    let prog = build_kws_program(&model, OptLevel::FULL).expect("codegen");
    let audios: Vec<Vec<f32>> = (0..32)
        .map(|i| dataset::synth_utterance(i % 12, i as u64, model.audio_len, 0.37))
        .collect();

    // --- cycle-level baseline -------------------------------------------
    let mut cycle = backend::build(BackendKind::Cycle, prog.clone(), DramConfig::default())
        .expect("cycle backend");
    let n_cycle = 4;
    let t0 = Instant::now();
    let mut cycle_ref = None;
    for audio in audios.iter().take(n_cycle) {
        cycle_ref = Some(cycle.run(audio).expect("cycle inference"));
    }
    let cycle_s = t0.elapsed().as_secs_f64() / n_cycle as f64;
    println!(
        "cycle backend: {:8.2} ms/inference ({:8.1} inf/s)",
        1e3 * cycle_s,
        1.0 / cycle_s
    );

    // --- fast functional simulator --------------------------------------
    let t0 = Instant::now();
    let mut fast = backend::build(BackendKind::Fast, prog, DramConfig::default())
        .expect("fast backend");
    let setup_s = t0.elapsed().as_secs_f64();
    let n_fast = 256;
    let t0 = Instant::now();
    let mut fast_ref = None;
    for i in 0..n_fast {
        fast_ref = Some(fast.run(&audios[i % audios.len()]).expect("fast inference"));
    }
    let fast_s = t0.elapsed().as_secs_f64() / n_fast as f64;
    println!(
        "fast backend:  {:8.2} ms/inference ({:8.1} inf/s; one-time setup {:.2} ms)",
        1e3 * fast_s,
        1.0 / fast_s,
        1e3 * setup_s
    );
    println!("speedup: {:.1}x inferences/sec", cycle_s / fast_s);

    // Parity spot check on the last shared utterance.
    let idx = (n_fast - 1) % audios.len();
    let want = cycle.run(&audios[idx]).expect("cycle inference");
    let got = fast.run(&audios[idx]).expect("fast inference");
    assert_eq!(want.logits, got.logits, "backends disagree on logits");
    let (c, f) = (cycle_ref.unwrap(), fast_ref.unwrap());
    println!(
        "latency model: fast {} vs cycle {} chip cycles on their last runs",
        f.cycles, c.cycles
    );
    assert!(
        cycle_s / fast_s >= 20.0,
        "fast backend must be >= 20x the cycle backend ({:.1}x measured)",
        cycle_s / fast_s
    );
    println!("parity: logits bit-identical \u{2713}");
}
