//! Backend + kernel throughput: the headline numbers for the serving
//! stack, machine-readable in `BENCH_kernels.json`.
//!
//! Levels, each asserted:
//!
//! * cycle SoC vs the fast functional simulator (target: >= 20x — in
//!   practice orders of magnitude, the fast path skips the ~10^6-step CPU
//!   loop entirely);
//! * the packed XNOR-popcount fsim vs the PR 1 scalar kernels on the same
//!   decoded program (target: >= 5x inferences/sec);
//! * the lane-blocked SIMD + incremental-window engine (`model::kernel`,
//!   what `DecodedProgram::infer` now runs) vs the PR 2 packed path kept
//!   as `infer_packed_ref` (target: >= 3x single-inference on full runs
//!   when the `simd` feature is on and a SIMD tier is detected; recorded
//!   on every run, with the active `engine_kind()` tier in the JSON);
//! * **batched** fsim (`run_batch`, weight planes walked once per batch +
//!   chunked thread fan-out) vs single-utterance `run` (target: >= 2x
//!   inferences/sec at batch 8 on full runs with >= 4 cores; batch 2/4/8
//!   rows always recorded, `--batch N` adds a custom row);
//! * multi-macro sharded fsim (one thread per macro) vs the single-macro
//!   packed path on a wide synthetic model (target: >= 1.5x at N=4 when
//!   the host has >= 4 cores; N=2 and N=4 rows always recorded);
//! * kernel-level micro benches (preprocess, each conv layer, the GAP
//!   layer) — scalar vs packed, written to `BENCH_kernels.json` so the
//!   perf trajectory is tracked run over run;
//! * the fused resident schedule (`--opt fused`): measured cycle-engine
//!   runs at baseline / full / fused, with the accelerated-phase
//!   (weights+conv) reduction gated at >= 60% vs baseline (paper:
//!   85.14%) and fused DRAM traffic gated below full's — deterministic
//!   cycle counts, so these gates run even in quick (CI) mode.
//!
//! Runs on the trained artifacts when present, else on the synthetic
//! model, so it works straight after `cargo build`. Set
//! `CIMRV_BENCH_QUICK=1` for a short-iteration smoke run (CI) — the
//! batched rows and their parity checks run in quick mode too, so a
//! regression in the batched path fails fast.

use std::hint::black_box;
use std::time::Instant;

use cimrv::backend::{self, BackendKind, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::dataflow::shard::ShardPlan;
use cimrv::fsim::FastSim;
use cimrv::mem::dram::DramConfig;
use cimrv::model::kernel;
use cimrv::model::reference::{
    self, conv_layer, conv_layer_packed, final_layer_gap, final_layer_gap_packed, BitMap,
};
use cimrv::model::{dataset, KwsModel};

/// Seconds per iteration of `f` over `iters` runs.
fn time_per<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

struct KernelRow {
    name: String,
    scalar_us: f64,
    packed_us: f64,
    /// Lane-blocked SIMD + incremental-window engine; `None` for stages
    /// with no engine variant (preprocess is shared by both paths).
    engine_us: Option<f64>,
}

impl KernelRow {
    fn speedup(&self) -> f64 {
        self.scalar_us / self.packed_us
    }

    fn engine_speedup(&self) -> Option<f64> {
        self.engine_us.map(|e| self.packed_us / e)
    }
}

fn main() {
    let quick = std::env::var("CIMRV_BENCH_QUICK").is_ok_and(|v| v != "0" && !v.is_empty());
    // `cargo bench --bench backend_throughput -- --batch 16` appends a
    // custom batch size to the standard 2/4/8 batched rows.
    let argv: Vec<String> = std::env::args().collect();
    let extra_batch: Option<usize> = argv
        .iter()
        .position(|a| a == "--batch")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok());
    let (model, model_kind) = match KwsModel::load_default() {
        Ok(m) => (m, "trained"),
        Err(_) => {
            println!("(artifacts not found: benchmarking the synthetic model)");
            (KwsModel::synthetic(1), "synthetic")
        }
    };
    let prog = build_kws_program(&model, OptLevel::FULL).expect("codegen");
    let audios: Vec<Vec<f32>> = (0..32)
        .map(|i| dataset::synth_utterance(i % 12, i as u64, model.audio_len, 0.37))
        .collect();

    // --- cycle-level baseline -------------------------------------------
    let mut cycle = backend::build(BackendKind::Cycle, prog.clone(), DramConfig::default())
        .expect("cycle backend");
    let n_cycle = if quick { 2 } else { 4 };
    let cycle_s = {
        let mut i = 0;
        time_per(n_cycle, || {
            cycle.run(&audios[i % audios.len()]).expect("cycle inference");
            i += 1;
        })
    };
    println!(
        "cycle backend:       {:8.2} ms/inference ({:8.1} inf/s)",
        1e3 * cycle_s,
        1.0 / cycle_s
    );

    // --- fast functional simulator (packed XNOR-popcount kernels) -------
    let t0 = Instant::now();
    let mut fast = backend::build(BackendKind::Fast, prog.clone(), DramConfig::default())
        .expect("fast backend");
    let setup_s = t0.elapsed().as_secs_f64();
    let n_fast = if quick { 32 } else { 256 };
    let fast_s = {
        let mut i = 0;
        time_per(n_fast, || {
            fast.run(&audios[i % audios.len()]).expect("fast inference");
            i += 1;
        })
    };
    println!(
        "fast backend:        {:8.2} ms/inference ({:8.1} inf/s; one-time setup {:.2} ms)",
        1e3 * fast_s,
        1.0 / fast_s,
        1e3 * setup_s
    );

    // --- PR 1 scalar fsim path on the same decoded program ---------------
    let sim = FastSim::new(prog, DramConfig::default()).expect("fsim");
    let decoded = sim.decoded();
    let specs = decoded.to_layer_specs();
    let n_scalar = if quick { 8 } else { 32 };
    // black_box on every direct (non-vtable) call below: the results are
    // otherwise dead and the optimizer could elide the measured work.
    let scalar_s = {
        let mut i = 0;
        time_per(n_scalar, || {
            black_box(decoded.infer_scalar(black_box(&specs), &audios[i % audios.len()]));
            i += 1;
        })
    };
    println!(
        "fsim scalar kernels: {:8.2} ms/inference ({:8.1} inf/s — the PR 1 path)",
        1e3 * scalar_s,
        1.0 / scalar_s
    );

    // --- PR 2 packed path vs the lane engine -----------------------------
    // `infer_packed_ref` is the pre-engine packed path (per-position
    // window gather, one channel at a time); `infer` is the lane-blocked
    // SIMD + incremental-window engine the serving stack now runs.
    let n_ref = if quick { 16 } else { 64 };
    let packed_ref_s = {
        let mut i = 0;
        time_per(n_ref, || {
            black_box(decoded.infer_packed_ref(&audios[i % audios.len()]));
            i += 1;
        })
    };
    let n_eng = if quick { 32 } else { 256 };
    let engine_s = {
        let mut i = 0;
        time_per(n_eng, || {
            black_box(decoded.infer(&audios[i % audios.len()]));
            i += 1;
        })
    };
    let engine = kernel::engine_kind();
    println!(
        "fsim packed (PR 2):  {:8.2} ms/inference ({:8.1} inf/s)",
        1e3 * packed_ref_s,
        1.0 / packed_ref_s
    );
    println!(
        "fsim lane engine:    {:8.2} ms/inference ({:8.1} inf/s; tier {engine})",
        1e3 * engine_s,
        1.0 / engine_s
    );
    println!(
        "speedup: fast vs cycle {:.1}x | packed vs scalar kernels {:.2}x | \
         engine vs packed {:.2}x",
        cycle_s / fast_s,
        scalar_s / fast_s,
        packed_ref_s / engine_s
    );

    // Parity: all four paths agree bit-for-bit on a shared utterance.
    let probe = &audios[7];
    let want = cycle.run(probe).expect("cycle inference");
    let got = fast.run(probe).expect("fast inference");
    let (scalar_logits, _) = decoded.infer_scalar(&specs, probe);
    let (packed_ref_logits, _) = decoded.infer_packed_ref(probe);
    assert_eq!(want.logits, got.logits, "fast backend disagrees with cycle on logits");
    assert_eq!(scalar_logits, got.logits, "scalar kernels disagree with the lane engine");
    assert_eq!(
        packed_ref_logits, got.logits,
        "lane engine disagrees with the PR 2 packed reference path"
    );
    println!("parity: cycle / engine / packed / scalar logits bit-identical \u{2713}");

    // --- batched fsim (run_batch) ----------------------------------------
    // Weight planes walked once per batch + chunked thread fan-out vs the
    // single-utterance `run` loop. Parity is checked on every row even in
    // quick mode, so batched-path regressions fail fast in CI.
    let mut batch_sizes = vec![2usize, 4, 8];
    if let Some(b) = extra_batch {
        if b >= 1 && !batch_sizes.contains(&b) {
            batch_sizes.push(b);
        }
    }
    let mut batched_rows: Vec<(usize, f64)> = Vec::new();
    for &bs in &batch_sizes {
        let refs: Vec<&[f32]> = (0..bs).map(|i| audios[i % audios.len()].as_slice()).collect();
        let rs = fast.run_batch(&refs).expect("batched inference");
        assert_eq!(rs.len(), bs, "run_batch must answer every element");
        for (i, r) in rs.iter().enumerate() {
            let want = fast.run(refs[i]).expect("fast inference");
            assert_eq!(
                r.logits, want.logits,
                "batched element {i} of {bs} diverged from sequential run"
            );
        }
        let iters = ((if quick { 32 } else { 256 }) / bs).max(2);
        let per_inf = time_per(iters, || {
            black_box(fast.run_batch(black_box(&refs)).expect("batched inference"));
        }) / bs as f64;
        println!(
            "fast run_batch({bs:>2}): {:8.2} ms/inference ({:8.1} inf/s; {:.2}x vs batch 1)",
            1e3 * per_inf,
            1.0 / per_inf,
            fast_s / per_inf
        );
        batched_rows.push((bs, per_inf));
    }
    println!("parity: batched logits bit-identical to sequential \u{2713}");

    // --- kernel-level micro benches --------------------------------------
    // Walk the net once to capture each layer's real input feature map,
    // then time scalar vs packed per stage.
    let (k_iters_s, k_iters_p) = if quick { (3, 30) } else { (20, 200) };
    let mut rows: Vec<KernelRow> = Vec::new();
    let pre_audio = &audios[3];
    rows.push(KernelRow {
        name: "preprocess".into(),
        scalar_us: 1e6 * time_per(k_iters_s, || {
            black_box(decoded.preprocess_scalar(black_box(pre_audio)));
        }),
        packed_us: 1e6 * time_per(k_iters_p, || {
            black_box(decoded.preprocess(black_box(pre_audio)));
        }),
        // Preprocessing is shared: the engine starts at the first conv.
        engine_us: None,
    });
    let mut x: BitMap = decoded.preprocess(pre_audio);
    let n_layers = decoded.layers.len();
    for (i, ((packed, lane), spec)) in
        decoded.layers.iter().zip(&decoded.lanes).zip(&specs).enumerate()
    {
        let name = format!(
            "layer{i}_{}x{}{}",
            spec.c_in,
            spec.c_out,
            if spec.pooled { "_pool" } else { "" }
        );
        if i < n_layers - 1 {
            rows.push(KernelRow {
                name: format!("conv_{name}"),
                scalar_us: 1e6 * time_per(k_iters_s, || {
                    black_box(conv_layer(black_box(&x), spec));
                }),
                packed_us: 1e6 * time_per(k_iters_p, || {
                    black_box(conv_layer_packed(black_box(&x), packed));
                }),
                engine_us: Some(1e6 * time_per(k_iters_p, || {
                    black_box(kernel::conv_layer_lanes(black_box(&x), lane));
                })),
            });
            x = conv_layer_packed(&x, packed);
        } else {
            rows.push(KernelRow {
                name: format!("gap_{name}"),
                scalar_us: 1e6 * time_per(k_iters_s, || {
                    black_box(final_layer_gap(black_box(&x), spec));
                }),
                packed_us: 1e6 * time_per(k_iters_p, || {
                    black_box(final_layer_gap_packed(black_box(&x), packed));
                }),
                engine_us: Some(1e6 * time_per(k_iters_p, || {
                    black_box(kernel::final_layer_gap_lanes(black_box(&x), lane));
                })),
            });
        }
    }
    // Sanity on the captured pipeline: packed forward equals the oracle.
    assert_eq!(
        reference::infer_packed(&model, pre_audio),
        reference::infer(&model, pre_audio),
        "packed model-level inference diverged from the scalar oracle"
    );

    println!("\nkernel             scalar us    packed us   speedup    engine us  eng/packed");
    for r in &rows {
        let (eng, eng_sp) = match (r.engine_us, r.engine_speedup()) {
            (Some(e), Some(s)) => (format!("{e:>10.1}"), format!("{s:>9.2}x")),
            _ => (format!("{:>10}", "-"), format!("{:>10}", "-")),
        };
        println!(
            "{:<18} {:>9.1} {:>12.1} {:>8.2}x {eng} {eng_sp}",
            r.name,
            r.scalar_us,
            r.packed_us,
            r.speedup()
        );
    }

    // --- multi-macro sharded fsim ----------------------------------------
    // A wide synthetic model (256-channel layers) so an output-channel
    // split has real work per macro; one OS thread per macro.
    let wide = KwsModel::synthetic_wide(5);
    let wprog = build_kws_program(&wide, OptLevel::FULL).expect("codegen (wide)");
    let wsim = FastSim::new(wprog.clone(), DramConfig::default()).expect("fsim (wide)");
    let wa: Vec<Vec<f32>> = (0..8)
        .map(|i| dataset::synth_utterance(i % 12, 100 + i as u64, wide.audio_len, 0.37))
        .collect();
    let n_sh = if quick { 4 } else { 24 };
    let single_sh_s = {
        let mut i = 0;
        time_per(n_sh, || {
            black_box(wsim.infer(black_box(&wa[i % wa.len()])));
            i += 1;
        })
    };
    let base_logits = wsim.infer(&wa[0]).logits;
    println!(
        "\nsharded fsim (wide synthetic model, {:.2} ms single-macro):",
        1e3 * single_sh_s
    );
    let mut shard_rows: Vec<(usize, f64)> = Vec::new();
    for n in [2usize, 4] {
        let plan = ShardPlan::even(&wprog.plan, n).expect("shard plan");
        let ssim = FastSim::new(wprog.clone(), DramConfig::default())
            .expect("fsim (sharded)")
            .with_shard_plan(&plan, true)
            .expect("shard slicing");
        assert_eq!(
            ssim.infer(&wa[0]).logits,
            base_logits,
            "sharded logits diverged from single-macro at N={n}"
        );
        let s = {
            let mut i = 0;
            time_per(n_sh, || {
                black_box(ssim.infer(black_box(&wa[i % wa.len()])));
                i += 1;
            })
        };
        println!(
            "  --macros {n}: {:8.2} ms/inference ({:5.2}x vs single macro)",
            1e3 * s,
            single_sh_s / s
        );
        shard_rows.push((n, s));
    }

    // --- fused resident schedule (cycle engine, modeled cycles) ----------
    // The fusion tentpole's regression gate: baseline / full / fused
    // measured on the cycle engine. Cycle counts and DRAM byte counts are
    // deterministic, so the thresholds hold in quick (CI) mode too.
    let fused_probe = &audios[3];
    let fused_ladder = [
        ("baseline", OptLevel::BASELINE),
        ("full", OptLevel::FULL),
        ("fused", OptLevel::FUSED),
    ];
    let fused_rows: Vec<(&str, cimrv::sim::RunResult)> = fused_ladder
        .iter()
        .map(|&(name, opt)| {
            let p = build_kws_program(&model, opt).expect("codegen (fused ladder)");
            let mut be = backend::build(BackendKind::Cycle, p, DramConfig::default())
                .expect("cycle backend (fused ladder)");
            (name, be.run(fused_probe).expect("cycle inference (fused ladder)"))
        })
        .collect();
    println!("\nfused resident schedule (cycle engine):");
    println!(
        "  {:<10}{:>14}{:>14}{:>14}{:>14}",
        "config", "total cyc", "accel cyc", "conv cyc", "DRAM bytes"
    );
    for (name, r) in &fused_rows {
        println!(
            "  {:<10}{:>14}{:>14}{:>14}{:>14}",
            name,
            r.cycles,
            r.phases.accelerated(),
            r.phases.conv,
            r.energy.dram_bytes
        );
    }
    let (base_r, full_r, fused_r) = (&fused_rows[0].1, &fused_rows[1].1, &fused_rows[2].1);
    assert_eq!(
        fused_r.logits, base_r.logits,
        "fused schedule must be bit-identical to the baseline program"
    );
    let accel_red =
        1.0 - fused_r.phases.accelerated() as f64 / base_r.phases.accelerated() as f64;
    let e2e_red = 1.0 - fused_r.cycles as f64 / base_r.cycles as f64;
    println!(
        "  accelerated-phase reduction {:.2}% (gate >= 60%, paper 85.14%) | e2e {:.2}% | \
         DRAM {} -> {} bytes",
        100.0 * accel_red,
        100.0 * e2e_red,
        full_r.energy.dram_bytes,
        fused_r.energy.dram_bytes
    );
    assert!(
        accel_red >= 0.60,
        "fused schedule must cut >= 60% of baseline accelerated-phase cycles \
         ({:.2}% measured)",
        100.0 * accel_red
    );
    assert!(
        fused_r.cycles < full_r.cycles,
        "fused total cycles ({}) must beat the full ladder ({})",
        fused_r.cycles,
        full_r.cycles
    );
    assert!(
        fused_r.energy.dram_bytes < full_r.energy.dram_bytes,
        "fused per-inference DRAM traffic ({}) must beat full's ({}): resident weights \
         leave only the audio fetch",
        fused_r.energy.dram_bytes,
        full_r.energy.dram_bytes
    );
    println!(
        "assert: fused >= 60% accelerated reduction, < full cycles, < full DRAM bytes \u{2713}"
    );

    // --- BENCH_kernels.json ----------------------------------------------
    let mut json = String::from("{\n");
    json.push_str(&format!("  \"model\": \"{model_kind}\",\n"));
    json.push_str(&format!("  \"quick\": {quick},\n"));
    json.push_str(&format!("  \"engine\": \"{engine}\",\n"));
    json.push_str(&format!("  \"simd_feature\": {},\n", cfg!(feature = "simd")));
    json.push_str("  \"inference\": {\n");
    json.push_str(&format!("    \"cycle_ms\": {:.4},\n", 1e3 * cycle_s));
    json.push_str(&format!("    \"fsim_scalar_ms\": {:.4},\n", 1e3 * scalar_s));
    json.push_str(&format!("    \"fsim_packed_ms\": {:.4},\n", 1e3 * packed_ref_s));
    json.push_str(&format!("    \"fsim_engine_ms\": {:.4},\n", 1e3 * engine_s));
    json.push_str(&format!("    \"engine_vs_packed\": {:.2},\n", packed_ref_s / engine_s));
    json.push_str(&format!("    \"packed_vs_scalar\": {:.2},\n", scalar_s / fast_s));
    json.push_str(&format!("    \"fast_vs_cycle\": {:.1}\n", cycle_s / fast_s));
    json.push_str("  },\n");
    json.push_str("  \"batched\": {\n");
    json.push_str(&format!("    \"single_ms\": {:.4},\n", 1e3 * fast_s));
    json.push_str("    \"rows\": [\n");
    for (i, (bs, s)) in batched_rows.iter().enumerate() {
        json.push_str(&format!(
            "      {{\"batch\": {bs}, \"ms_per_inf\": {:.4}, \"speedup\": {:.2}}}{}\n",
            1e3 * s,
            fast_s / s,
            if i + 1 < batched_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("    ]\n  },\n");
    json.push_str("  \"kernels\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let engine_cols = match (r.engine_us, r.engine_speedup()) {
            (Some(e), Some(s)) => {
                format!("\"engine_us\": {e:.2}, \"engine_vs_packed\": {s:.2}")
            }
            _ => "\"engine_us\": null, \"engine_vs_packed\": null".into(),
        };
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"scalar_us\": {:.2}, \"packed_us\": {:.2}, \"speedup\": {:.2}, {engine_cols}}}{}\n",
            r.name,
            r.scalar_us,
            r.packed_us,
            r.speedup(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str("  \"sharded\": {\n");
    json.push_str(&format!("    \"single_macro_ms\": {:.4},\n", 1e3 * single_sh_s));
    json.push_str("    \"rows\": [\n");
    for (i, (n, s)) in shard_rows.iter().enumerate() {
        json.push_str(&format!(
            "      {{\"macros\": {n}, \"ms\": {:.4}, \"speedup\": {:.2}}}{}\n",
            1e3 * s,
            single_sh_s / s,
            if i + 1 < shard_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("    ]\n  },\n");
    json.push_str("  \"fused\": {\n");
    json.push_str(&format!("    \"accelerated_reduction_pct\": {:.2},\n", 100.0 * accel_red));
    json.push_str(&format!("    \"e2e_reduction_pct\": {:.2},\n", 100.0 * e2e_red));
    json.push_str("    \"gate\": \"accelerated_reduction_pct >= 60\",\n");
    json.push_str("    \"rows\": [\n");
    for (i, (name, r)) in fused_rows.iter().enumerate() {
        json.push_str(&format!(
            "      {{\"config\": \"{name}\", \"total_cycles\": {}, \
             \"accelerated_cycles\": {}, \"conv_cycles\": {}, \"dram_bytes\": {}}}{}\n",
            r.cycles,
            r.phases.accelerated(),
            r.phases.conv,
            r.energy.dram_bytes,
            if i + 1 < fused_rows.len() { "," } else { "" }
        ));
    }
    json.push_str("    ]\n  }\n}\n");
    std::fs::write("BENCH_kernels.json", &json).expect("writing BENCH_kernels.json");
    println!("\nwrote BENCH_kernels.json");

    assert!(
        cycle_s / fast_s >= 20.0,
        "fast backend must be >= 20x the cycle backend ({:.1}x measured)",
        cycle_s / fast_s
    );
    assert!(
        scalar_s / fast_s >= 5.0,
        "packed kernels must be >= 5x the PR 1 scalar fsim path ({:.2}x measured)",
        scalar_s / fast_s
    );
    // Lane engine: >= 3x the PR 2 packed path single-inference. Enforced
    // on full runs when the `simd` feature compiled in a SIMD tier and
    // the host actually detected one — the portable tier still records
    // its ratio (incremental windows alone usually clear 3x, but only the
    // SIMD configuration *promises* it). Quick smoke runs record only.
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let simd_on = cfg!(feature = "simd") && engine != "portable";
    if !quick && cores >= 4 && simd_on {
        assert!(
            packed_ref_s / engine_s >= 3.0,
            "lane engine must be >= 3x the PR 2 packed path \
             ({:.2}x measured, tier {engine})",
            packed_ref_s / engine_s
        );
        println!("assert: lane engine >= 3x packed path ({engine}) \u{2713}");
    } else {
        println!(
            "(engine {:.2}x vs packed recorded, tier {engine}; 3x threshold enforced \
             on full runs with the simd feature and a detected SIMD tier)",
            packed_ref_s / engine_s
        );
    }
    // Batched throughput: >= 2x single-utterance fsim at batch 8. Like
    // the sharded assert below, the threshold is enforced on full runs
    // with enough cores (a 2-core host's thread-fan-out ceiling is
    // exactly 2x — no margin); quick CI smoke runs and small hosts
    // still *record* the rows (and always parity-check them).
    let batch8 = batched_rows.iter().find(|(b, _)| *b == 8).map(|(_, s)| *s);
    if let Some(s8) = batch8 {
        if !quick && cores >= 4 {
            assert!(
                fast_s / s8 >= 2.0,
                "batched fsim at batch 8 must be >= 2x single-utterance fsim \
                 ({:.2}x measured on {cores} cores)",
                fast_s / s8
            );
            println!("assert: batched fsim >= 2x single at batch 8 \u{2713}");
        } else {
            println!(
                "(batched {:.2}x at batch 8 recorded; 2x threshold enforced on full \
                 runs with >= 4 cores)",
                fast_s / s8
            );
        }
    }
    // Sharded throughput: assert only on full runs with enough cores —
    // quick CI smoke runs and small hosts still *record* the rows above.
    let shard4 = shard_rows.iter().find(|(n, _)| *n == 4).map(|(_, s)| *s);
    if let Some(s4) = shard4 {
        if !quick && cores >= 4 {
            assert!(
                single_sh_s / s4 >= 1.5,
                "sharded fsim at N=4 must be >= 1.5x the single-macro packed path \
                 ({:.2}x measured on {cores} cores)",
                single_sh_s / s4
            );
            println!(
                "asserts: fast >= 20x cycle, packed >= 5x scalar, sharded N=4 >= 1.5x \u{2713}"
            );
        } else {
            println!(
                "asserts: fast >= 20x cycle, packed >= 5x scalar \u{2713} (sharded \
                 {:.2}x at N=4 recorded; threshold enforced on full runs with >= 4 cores)",
                single_sh_s / s4
            );
        }
    }
}
