//! Shared bench harness (criterion is not in the offline image): a tiny
//! timing loop plus the standard model/audio setup all benches share.

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::sim::{RunResult, Soc};

/// Time a closure `iters` times; returns (mean seconds, result of last).
pub fn time_it<T>(iters: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut out = None;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        out = Some(f());
    }
    (t0.elapsed().as_secs_f64() / iters as f64, out.unwrap())
}

pub fn model() -> KwsModel {
    KwsModel::load_default().expect("run `make artifacts` first")
}

pub fn audio(model: &KwsModel, label: usize, seed: u64) -> Vec<f32> {
    dataset::synth_utterance(label, seed, model.audio_len, 0.37)
}

/// One simulated inference at an opt level.
pub fn run_once(model: &KwsModel, opt: OptLevel, audio: &[f32]) -> RunResult {
    let prog = build_kws_program(model, opt).expect("codegen");
    let mut soc = Soc::new(prog, DramConfig::default()).expect("soc");
    soc.infer(audio).expect("inference")
}
