//! Shared bench harness (criterion is not in the offline image): a tiny
//! timing loop plus the standard model/audio setup all benches share.

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::sim::{RunResult, Soc};

/// Time a closure `iters` times; returns (mean seconds, result of last).
pub fn time_it<T>(iters: usize, mut f: impl FnMut() -> T) -> (f64, T) {
    let mut out = None;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        out = Some(f());
    }
    (t0.elapsed().as_secs_f64() / iters as f64, out.unwrap())
}

pub fn model() -> KwsModel {
    KwsModel::load_default().expect("run `make artifacts` first")
}

pub fn audio(model: &KwsModel, label: usize, seed: u64) -> Vec<f32> {
    dataset::synth_utterance(label, seed, model.audio_len, 0.37)
}

/// One simulated inference at an opt level.
pub fn run_once(model: &KwsModel, opt: OptLevel, audio: &[f32]) -> RunResult {
    let prog = build_kws_program(model, opt).expect("codegen");
    let mut soc = Soc::new(prog, DramConfig::default()).expect("soc");
    soc.infer(audio).expect("inference")
}

/// Fold every per-bench `BENCH_*.json` in the working directory into one
/// `BENCH_summary.json` keyed by bench name (`BENCH_kernels.json` ->
/// `kernels`), stamped with the caller-supplied run identifier.
///
/// The stamp is an *input* (CI passes its run id via `CIMRV_BENCH_STAMP`)
/// — this emitter reads no wall clock, so re-running a bench over
/// unchanged inputs reproduces the summary byte for byte.
pub fn write_bench_summary(stamp: &str) {
    use cimrv::util::json::Json;
    let mut benches = std::collections::BTreeMap::new();
    let mut names = Vec::new();
    let entries = match std::fs::read_dir(".") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("(bench summary skipped: reading cwd failed: {e})");
            return;
        }
    };
    for entry in entries.flatten() {
        let file = entry.file_name();
        let Some(file) = file.to_str() else { continue };
        let Some(name) = file.strip_prefix("BENCH_").and_then(|f| f.strip_suffix(".json"))
        else {
            continue;
        };
        if name == "summary" {
            continue; // never fold a previous summary into itself
        }
        let Ok(text) = std::fs::read_to_string(entry.path()) else { continue };
        match Json::parse(&text) {
            Ok(doc) => {
                names.push(name.to_string());
                benches.insert(name.to_string(), doc);
            }
            Err(e) => eprintln!("(bench summary: skipping malformed {file}: {e})"),
        }
    }
    let doc = Json::obj(vec![
        ("schema", Json::str("cimrv-bench-summary/v1")),
        ("stamp", Json::str(stamp)),
        ("benches", Json::Obj(benches)),
    ]);
    std::fs::write("BENCH_summary.json", format!("{doc}\n"))
        .expect("writing BENCH_summary.json");
    names.sort();
    println!("wrote BENCH_summary.json (stamp {stamp}; folded: {})", names.join(", "));
}
