//! Monte-Carlo robustness sweep throughput: the whole point of the
//! variation-aware functional simulator is running disturbance grids at
//! serving speed, so this bench drives the same grid through both
//! engines and asserts the fast path is >= 10x the cycle engine per
//! disturbed inference. On the trained artifact set it also re-checks
//! the §II-B mapping claim (symmetric holds accuracy where single-ended
//! collapses). Results — the full sweep report plus the engine timing
//! comparison — land in `BENCH_robustness.json`.
//!
//! `CIMRV_BENCH_QUICK=1` shrinks the grid to the CI smoke size; the 10x
//! assert still runs (the gap is orders of magnitude in practice).

use std::time::Instant;

use cimrv::backend::{CycleBackend, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::coordinator::report::render_sweep;
use cimrv::fsim::FastSim;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::robustness::{run_sweep, SweepConfig, VariationParams};
use cimrv::util::json::Json;

fn main() {
    let quick = std::env::var("CIMRV_BENCH_QUICK").is_ok_and(|v| v != "0" && !v.is_empty());
    let (model, model_kind) = match KwsModel::load_default() {
        Ok(m) => (m, "trained"),
        Err(_) => {
            println!("(artifacts not found: sweeping the synthetic model)");
            (KwsModel::synthetic(1), "synthetic")
        }
    };

    // Utterances + labels: the checked-in eval set when available (real
    // accuracy numbers), synthetic otherwise (timing still meaningful).
    let (audios, labels): (Vec<Vec<f32>>, Vec<usize>) = match cimrv::util::io::artifacts_dir()
        .and_then(|d| dataset::Dataset::load_eval(&d, model.audio_len, model.n_classes))
    {
        Ok(eval) if model_kind == "trained" => {
            let labels: Vec<usize> = eval.labels.iter().map(|&l| l as usize).collect();
            let audios = (0..eval.len()).map(|i| eval.utterance(i).to_vec()).collect();
            (audios, labels)
        }
        _ => {
            let labels: Vec<usize> = (0..8).map(|i| i % 12).collect();
            let audios = labels
                .iter()
                .enumerate()
                .map(|(i, &l)| dataset::synth_utterance(l, 500 + i as u64, model.audio_len, 0.37))
                .collect();
            (audios, labels)
        }
    };
    let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();

    let prog = build_kws_program(&model, OptLevel::FULL).expect("codegen");
    let sim = FastSim::new(prog.clone(), DramConfig::default())
        .expect("fsim")
        .with_batch_threads(1);

    let cfg = if quick { SweepConfig::quick() } else { SweepConfig::full() };
    let report = run_sweep(&sim, &refs, &labels, &cfg).expect("sweep");
    print!("{}", render_sweep(&report));

    // --- cycle engine on the same disturbance, for the speedup ----------
    // A few reseeded runs suffice: per-inference cost is data-independent.
    let probe = VariationParams {
        sigma: *cfg.sigmas.last().unwrap(),
        nl_alpha: cfg.nl_alphas[0],
        symmetric: false,
        mismatch: cfg.mismatch,
        seed: cfg.seeds[0],
    };
    let mut cyc = CycleBackend::new(prog, DramConfig::default())
        .expect("cycle backend")
        .with_variation(probe);
    let n_cycle = if quick { 2 } else { 4 };
    let t0 = Instant::now();
    for i in 0..n_cycle {
        cyc.run(refs[i % refs.len()]).expect("cycle disturbed inference");
    }
    let cycle_per_inf = t0.elapsed().as_secs_f64() / n_cycle as f64;
    let fast_per_inf = report.elapsed_s / report.inferences as f64;
    let speedup = cycle_per_inf / fast_per_inf;
    println!(
        "disturbed inference: cycle {:8.2} ms | fast {:8.3} ms | {:.0}x \
         (grid of {} would take {:.1}s on the cycle engine vs {:.2}s measured)",
        1e3 * cycle_per_inf,
        1e3 * fast_per_inf,
        speedup,
        report.inferences,
        cycle_per_inf * report.inferences as f64,
        report.elapsed_s
    );

    // --- BENCH_robustness.json ------------------------------------------
    let mut json = match report.to_json() {
        Json::Obj(map) => map,
        _ => unreachable!("sweep report serializes to an object"),
    };
    json.insert("model".into(), Json::str(model_kind));
    json.insert("quick".into(), Json::Bool(quick));
    json.insert(
        "bench".into(),
        Json::obj(vec![
            ("cycle_ms_per_inference", Json::num(1e3 * cycle_per_inf)),
            ("fast_ms_per_inference", Json::num(1e3 * fast_per_inf)),
            ("speedup", Json::num(speedup)),
        ]),
    );
    std::fs::write("BENCH_robustness.json", format!("{}\n", Json::Obj(json)))
        .expect("writing BENCH_robustness.json");
    println!("wrote BENCH_robustness.json");

    // The acceptance gates: the sweep demonstrably rides the fast path,
    // and (on the trained model) reproduces the paper's §II-B claim.
    assert!(
        speedup >= 10.0,
        "robustness sweep must be >= 10x the cycle engine per disturbed \
         inference ({speedup:.1}x measured)"
    );
    if model_kind == "trained" {
        report.check_mapping_claim().expect("§II-B mapping claim");
        println!(
            "asserts: sweep >= 10x cycle per disturbed inference, symmetric beats \
             single-ended at max sigma \u{2713}"
        );
    } else {
        println!("assert: sweep >= 10x cycle per disturbed inference \u{2713}");
    }
}
