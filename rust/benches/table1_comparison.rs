//! Table I — comparison with prior SRAM-CIM designs: published numbers +
//! the normalization footnote math (computed, not transcribed), plus this
//! reproduction's measured energy efficiency (peak-calibrated and
//! end-to-end) and synthetic-GSCD accuracy. Also the §II-B variation
//! ablation (symmetric vs single-ended weight mapping).

mod common;

use cimrv::baselines::{comparison, OptLevel};
use cimrv::cim::{Mode, VariationModel};
use cimrv::compiler::build_kws_program;
use cimrv::energy::tops::peak_tops;
use cimrv::energy::EnergyTable;
use cimrv::mem::dram::DramConfig;
use cimrv::model::reference;
use cimrv::robustness::VariationParams;
use cimrv::sim::Soc;

fn main() {
    // `-- --mismatch M` sweeps with a non-default residual differential
    // mismatch (the knob `cimrv sweep --mismatch` exposes; both surfaces
    // build the same `VariationParams`).
    let argv: Vec<String> = std::env::args().collect();
    let mismatch: f64 = argv
        .iter()
        .position(|a| a == "--mismatch")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(VariationModel::DEFAULT_MISMATCH);
    let model = common::model();
    let audio = common::audio(&model, 0, 7);
    let r = common::run_once(&model, OptLevel::FULL, &audio);

    // Accuracy over synthetic-GSCD eval vectors (host reference — bit
    // exact vs the ISS, demonstrated by the integration tests).
    let dir = cimrv::util::io::artifacts_dir().unwrap();
    let eval =
        cimrv::model::dataset::Dataset::load_eval(&dir, model.audio_len, model.n_classes).unwrap();
    let mut hits = 0;
    for i in 0..eval.len() {
        let l = reference::infer(&model, eval.utterance(i));
        if reference::argmax(&l) == eval.labels[i] as usize {
            hits += 1;
        }
    }
    let acc = 100.0 * hits as f64 / eval.len() as f64;

    println!("=== Table I: comparison with SRAM-based CIM designs ===");
    println!("{}", comparison::render_table1(Some(r.energy.tops_per_w()), Some(acc)));
    println!(
        "peak (architectural): {:.4} TOPS @50 MHz, {:.2} TOPS/W calibrated",
        peak_tops(Mode::X),
        {
            let t = EnergyTable::default();
            peak_tops(Mode::X) / (t.peak_cycle_pj() * 1e-12 * cimrv::clock::CLOCK_HZ)
        }
    );
    println!("macro utilization this run: {:.2}%", 100.0 * r.energy.macs as f64
        / (r.cycles as f64 * Mode::X.macs_per_fire() as f64));

    // --- §II-B ablation: symmetric vs single-ended mapping under cell
    // variation / bitline NL.
    println!("\n=== §II-B: symmetry weight mapping vs variation (mismatch {mismatch}) ===");
    println!("{:<10}{:>22}{:>22}", "sigma", "symmetric acc %", "single-ended acc %");
    let n = 24.min(eval.len());
    for sigma in [0.0, 0.05, 0.1, 0.2] {
        let mut accs = [0.0f64; 2];
        for (k, symmetric) in [(0, true), (1, false)] {
            let params =
                VariationParams { sigma, nl_alpha: 0.3, symmetric, mismatch, seed: 7 };
            let prog = build_kws_program(&model, OptLevel::FULL).unwrap();
            let mut soc = Soc::new(prog, DramConfig::default())
                .unwrap()
                .with_variation(params.model());
            let mut h = 0;
            for i in 0..n {
                let r = soc.infer(eval.utterance(i)).unwrap();
                if r.predicted == eval.labels[i] as usize {
                    h += 1;
                }
            }
            accs[k] = 100.0 * h as f64 / n as f64;
        }
        println!("{sigma:<10}{:>22.1}{:>22.1}", accs[0], accs[1]);
    }
}
