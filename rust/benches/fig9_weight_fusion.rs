//! Fig. 9 — weight fusion: the uDMA descriptor chain prefetches every
//! layer's weight stream from DRAM into the 512 Kb weight SRAM behind
//! compute, vs stalling on DRAM before each layer's cim_w burst.
//! Paper: −62.94% (additional, after layer fusion).

mod common;

use cimrv::baselines::OptLevel;

fn main() {
    let model = common::model();
    let audio = common::audio(&model, 3, 1);

    let serial = common::run_once(
        &model,
        OptLevel { layer_fusion: true, ..OptLevel::BASELINE },
        &audio,
    );
    let fused = common::run_once(
        &model,
        OptLevel { layer_fusion: true, weight_fusion: true, conv_pool_pipeline: false },
        &audio,
    );

    println!("=== Fig. 9: weight fusion ===");
    println!("{:<26}{:>16}{:>16}", "config", "weight cycles", "accel cycles");
    println!(
        "{:<26}{:>16}{:>16}",
        "serial DRAM loads", serial.phases.weights, serial.phases.accelerated()
    );
    println!(
        "{:<26}{:>16}{:>16}",
        "weight fusion (prefetch)", fused.phases.weights, fused.phases.accelerated()
    );
    let w_red = 100.0 * (1.0 - fused.phases.weights as f64 / serial.phases.weights as f64);
    let accel_red =
        100.0 * (1.0 - fused.phases.accelerated() as f64 / serial.phases.accelerated() as f64);
    println!(
        "weight-phase reduction: {w_red:.2}% | accelerated-phase: {accel_red:.2}% (paper: 62.94%)"
    );
    assert_eq!(serial.logits, fused.logits);
}
