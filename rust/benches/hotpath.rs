//! §Perf microbenchmarks: the simulator's own hot paths — macro fire
//! (bit-parallel popcount MAC), ISS instruction throughput, and compiled
//! program build time. Used for the before/after log in EXPERIMENTS.md.

mod common;

use cimrv::baselines::OptLevel;
use cimrv::cim::{weight_map, CimMacro, Mode};
use cimrv::compiler::build_kws_program;
use cimrv::util::rng::Rng;

fn main() {
    // --- macro fire throughput, full window vs layer-sized window -------
    let mut rng = Rng::new(1);
    let mut m = CimMacro::new();
    let img = weight_map::WeightImage::from_layer(Mode::X, 1024, 256, |_, _| 1, &vec![0; 256]);
    m.load_image(&img).unwrap();
    for _ in 0..32 {
        m.shift_in(rng.next_u32());
    }
    for (name, window) in [("window=32 (full array)", 32u8), ("window=6 (L0-sized)", 6)] {
        m.cfg.window_words = window;
        let iters = 20_000;
        let (secs, _) = common::time_it(iters, || {
            m.shift_in(rng.next_u32());
            m.fire();
            m.raw_sum(0)
        });
        println!(
            "macro fire {name}: {:.2} us/fire ({:.1} Mfires/s, {:.1} GMAC/s simulated)",
            1e6 * secs,
            1e-6 / secs,
            1e-9 * Mode::X.macs_per_fire() as f64 / secs
        );
    }

    // --- ISS throughput on the real workload ----------------------------
    let model = common::model();
    let audio = common::audio(&model, 3, 1);
    let (secs, r) = common::time_it(3, || common::run_once(&model, OptLevel::FULL, &audio));
    println!(
        "ISS end-to-end: {:.1} ms host per inference = {:.2} Minstr/s ({} instr, {} cycles)",
        1e3 * secs,
        1e-6 * r.instret as f64 / secs,
        r.instret,
        r.cycles
    );

    // --- codegen cost ----------------------------------------------------
    let (secs, prog) = common::time_it(10, || build_kws_program(&model, OptLevel::FULL).unwrap());
    println!(
        "codegen: {:.2} ms for {} instructions ({} KiB)",
        1e3 * secs,
        prog.imem.len(),
        prog.imem_bytes() / 1024
    );
}
