//! §III-A headline — end-to-end latency waterfall: baseline -> +layer
//! fusion -> +weight fusion -> +conv/pool pipeline (the paper's cumulative
//! ordering; 85.14% total reduction on its model/testbed).

mod common;

use cimrv::baselines::OptLevel;
use cimrv::coordinator::report::{render_ladder, LadderPoint};

fn main() {
    let model = common::model();
    let audio = common::audio(&model, 3, 1);
    let mut points = Vec::new();
    for (name, opt) in OptLevel::ladder() {
        let r = common::run_once(&model, opt, &audio);
        points.push(LadderPoint::from_run(name, opt, &r));
    }
    println!("=== §III-A: end-to-end latency waterfall ===");
    println!("{}", render_ladder(&points));
    let base = points[0].accelerated_cycles as f64;
    let full = points[3].accelerated_cycles as f64;
    println!(
        "total accelerated-phase reduction: {:.2}% (paper: 85.14%)",
        100.0 * (1.0 - full / base)
    );
    // Wall-clock of the simulator itself (host-side throughput).
    let (secs, _) = common::time_it(3, || common::run_once(&model, OptLevel::FULL, &audio));
    println!("simulator speed: {:.2} ms host-time per inference", 1e3 * secs);
    dram_sweep(&model, &audio);
}

/// DRAM-bandwidth sensitivity (DESIGN.md §8 calls the bridge bandwidth a
/// calibration choice — this sweep shows the waterfall's dependence on it).
fn dram_sweep(model: &cimrv::model::KwsModel, audio: &[f32]) {
    use cimrv::compiler::build_kws_program;
    use cimrv::mem::dram::DramConfig;
    use cimrv::sim::Soc;
    println!("\n=== ablation: DRAM bridge bandwidth sensitivity ===");
    println!("{:<18}{:>18}{:>18}{:>14}", "bytes/cycle", "baseline accel", "full accel", "reduction");
    for bpc in [1u64, 2, 4, 8] {
        let cfg = DramConfig { bytes_per_cycle: bpc, ..DramConfig::default() };
        let mut accel = [0u64; 2];
        for (k, opt) in [(0, OptLevel::BASELINE), (1, OptLevel::FULL)] {
            let prog = build_kws_program(model, opt).unwrap();
            let mut soc = Soc::new(prog, cfg.clone()).unwrap();
            accel[k] = soc.infer(audio).unwrap().phases.accelerated();
        }
        println!(
            "{:<18}{:>18}{:>18}{:>13.2}%",
            bpc,
            accel[0],
            accel[1],
            100.0 * (1.0 - accel[1] as f64 / accel[0] as f64)
        );
    }
}
