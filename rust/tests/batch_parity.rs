//! Batch-vs-sequential parity: `run_batch` must be bit-identical to N
//! sequential `run` calls — per element, in order — on both backends,
//! across optimization levels, shard counts 1..4, and ragged final
//! batches (batch sizes that don't divide the request count). No
//! artifacts required — runs on synthetic models.

use cimrv::backend::{self, BackendKind, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::{build_kws_program, build_kws_program_sharded};
use cimrv::dataflow::shard::ShardPlan;
use cimrv::fsim::FastSim;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::util::proptest::check;

fn utterances(m: &KwsModel, n: usize, base_seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| dataset::synth_utterance(i % 12, base_seed + i as u64, m.audio_len, 0.37))
        .collect()
}

/// Drive `audios` through `be` both ways — one `run` per utterance, then
/// `run_batch` in chunks of `chunk` (the last chunk ragged when `chunk`
/// doesn't divide the count) — and require bit-identical records.
fn assert_batch_matches_sequential(
    be: &mut dyn InferenceBackend,
    audios: &[Vec<f32>],
    chunk: usize,
    ctx: &str,
) {
    let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
    let want: Vec<_> = refs.iter().map(|a| be.run(a).unwrap()).collect();
    let mut got = Vec::new();
    for c in refs.chunks(chunk) {
        got.extend(be.run_batch(c).unwrap());
    }
    assert_eq!(got.len(), want.len(), "{ctx}: element count");
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.logits, w.logits, "{ctx}: element {i} logits");
        assert_eq!(g.predicted, w.predicted, "{ctx}: element {i} argmax");
        assert_eq!(g.cycles, w.cycles, "{ctx}: element {i} cycles");
        assert_eq!(g.shard_fires, w.shard_fires, "{ctx}: element {i} shard fires");
    }
}

#[test]
fn fast_backend_batches_bit_identical_across_opts_shards_and_ragged_tails() {
    let m = KwsModel::synthetic(31);
    let audios = utterances(&m, 7, 100);
    for (name, opt) in OptLevel::ladder() {
        for macros in 1..=4usize {
            let prog = build_kws_program_sharded(&m, opt, macros).unwrap();
            let mut be =
                backend::build(BackendKind::Fast, prog, DramConfig::default()).unwrap();
            // 7 requests in chunks of 1 / 3 / 8: singleton batches, a
            // ragged tail (3+3+1), and one oversized chunk (7 < 8).
            for chunk in [1usize, 3, 8] {
                assert_batch_matches_sequential(
                    be.as_mut(),
                    &audios,
                    chunk,
                    &format!("fast/{name}/macros {macros}/chunk {chunk}"),
                );
            }
        }
    }
}

#[test]
fn fast_batches_bit_identical_on_explicit_uneven_shard_plans() {
    // The functional simulator accepts channel-granular plans the cycle
    // engine can't; batched execution must honor them identically —
    // with and without the in-batch thread fan-out.
    let m = KwsModel::synthetic(5);
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let audios = utterances(&m, 5, 300);
    let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
    for n in 2..=4usize {
        let plan = ShardPlan::even(&prog.plan, n).unwrap();
        for threads in [1usize, 4] {
            let sim = FastSim::new(prog.clone(), DramConfig::default())
                .unwrap()
                .with_shard_plan(&plan, false)
                .unwrap()
                .with_batch_threads(threads);
            let want: Vec<_> = refs.iter().map(|a| sim.infer(a)).collect();
            let got = sim.infer_batch(&refs);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(g.logits, w.logits, "n {n} threads {threads} element {i}");
                assert_eq!(g.shard_fires, w.shard_fires);
            }
        }
    }
}

#[test]
fn cycle_backend_batches_bit_identical_including_sharded() {
    // The cycle engine loops internally (it is the timing oracle, not
    // the throughput path) — parity must still hold, sharded included.
    let m = KwsModel::synthetic(8);
    let audios = utterances(&m, 3, 200);
    for macros in [1usize, 2] {
        let prog = build_kws_program_sharded(&m, OptLevel::FULL, macros).unwrap();
        let mut be = backend::build(BackendKind::Cycle, prog, DramConfig::default()).unwrap();
        assert_batch_matches_sequential(
            be.as_mut(),
            &audios,
            2, // ragged: 2 + 1
            &format!("cycle/macros {macros}"),
        );
    }
}

#[test]
fn prop_fast_ragged_batches_match_sequential() {
    // Property sweep over random batch sizes and chunkings on one
    // decoded program: whatever the grouping, the elements are the same.
    let m = KwsModel::synthetic(77);
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let sim = FastSim::new(prog, DramConfig::default()).unwrap();
    check("ragged batch grouping", 12, |rng| {
        let n = rng.range(1, 10);
        let audios = utterances(&m, n, rng.range(0, 1000) as u64);
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        let want: Vec<_> = refs.iter().map(|a| sim.infer(a)).collect();
        let chunk = rng.range(1, n + 1);
        let mut got = Vec::new();
        for c in refs.chunks(chunk) {
            got.extend(sim.infer_batch(c));
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.logits, w.logits, "n {n} chunk {chunk} element {i}");
        }
    });
}

#[test]
fn disturbed_batches_bit_identical_to_sequential_disturbed_runs() {
    // Variation-aware serving composes with the batch seam: every batch
    // element is an independent Monte-Carlo trial (fresh per-macro noise
    // streams per inference), so grouping and thread fan-out can never
    // change a disturbed result.
    use cimrv::robustness::VariationParams;
    let m = KwsModel::synthetic(31);
    let audios = utterances(&m, 5, 400);
    let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
    let params =
        VariationParams { sigma: 0.4, nl_alpha: 0.3, symmetric: false, ..Default::default() };
    for macros in [1usize, 2] {
        for threads in [1usize, 3] {
            let prog = build_kws_program_sharded(&m, OptLevel::FULL, macros).unwrap();
            let sim = FastSim::new(prog, DramConfig::default())
                .unwrap()
                .with_variation(params)
                .with_batch_threads(threads);
            let want: Vec<_> = refs.iter().map(|a| sim.infer(a)).collect();
            for chunk in [1usize, 2, 8] {
                let mut got = Vec::new();
                for c in refs.chunks(chunk) {
                    got.extend(sim.infer_batch(c));
                }
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.logits, w.logits,
                        "macros {macros} threads {threads} chunk {chunk} element {i}"
                    );
                    assert_eq!(g.predicted, w.predicted);
                }
            }
            // Same request, same seed => same disturbance (replayable).
            assert_eq!(sim.infer(refs[0]).logits, want[0].logits);
        }
    }
}

#[test]
fn empty_batch_is_empty_on_both_backends() {
    let m = KwsModel::synthetic(2);
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    for kind in [BackendKind::Fast, BackendKind::Cycle] {
        let mut be = backend::build(kind, prog.clone(), DramConfig::default()).unwrap();
        assert!(be.run_batch(&[]).unwrap().is_empty(), "{kind}");
    }
}
