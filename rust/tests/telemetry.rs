//! Telemetry integration suite: registry behavior under real thread
//! contention, the Perfetto export schema, and the span-vs-ServiceStats
//! consistency contract — everything driven through the public API the
//! CLI uses (`cimrv serve --trace-out/--metrics-out`). No artifacts
//! required — runs on synthetic models.

use std::sync::Mutex;

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program_sharded;
use cimrv::coordinator::{Coordinator, InferenceRequest};
use cimrv::model::{dataset, KwsModel};
use cimrv::telemetry::{
    self, events, global_profiler, perfetto, region, EventLog, Histogram, IncidentEvent,
    IncidentKind, Registry, SloConfig, SloMonitor, TraceBuilder,
};
use cimrv::util::json::Json;

/// The enable flag is process-global; tests that flip it run serialized
/// (the library's internal tests use the same pattern via
/// `telemetry::with_telemetry`, which is `cfg(test)`-private to the lib).
fn with_telemetry<T>(f: impl FnOnce() -> T) -> T {
    static GUARD: Mutex<()> = Mutex::new(());
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    let was = telemetry::enabled();
    telemetry::set_enabled(true);
    let out = f();
    telemetry::set_enabled(was);
    out
}

#[test]
fn registry_totals_are_exact_under_thread_contention() {
    with_telemetry(|| {
        let reg = Registry::new();
        const THREADS: usize = 8;
        const PER_THREAD: u64 = 10_000;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let reg = &reg;
                s.spawn(move || {
                    let c = reg.counter("contended.count");
                    let h = reg.histogram("contended.us", Histogram::us_bounds());
                    let g = reg.gauge("contended.gauge");
                    for i in 0..PER_THREAD {
                        c.inc();
                        h.observe(i % 1000);
                        g.set(t as f64);
                    }
                });
            }
        });
        let total = THREADS as u64 * PER_THREAD;
        assert_eq!(reg.counter("contended.count").get(), total);
        let h = reg.histogram("contended.us", Histogram::us_bounds());
        assert_eq!(h.count(), total);
        // Sum is exact too: each thread contributes sum(0..1000) * 10.
        let per_thread_sum: u64 = (0..PER_THREAD).map(|i| i % 1000).sum();
        assert_eq!(h.sum(), THREADS as u64 * per_thread_sum);
        // The +Inf cumulative bucket accounts for every observation.
        assert_eq!(h.cumulative().last().unwrap().1, total);
        // The gauge holds one of the racing writes, not garbage.
        let g = reg.gauge("contended.gauge").get();
        assert!((0.0..THREADS as f64).contains(&g));
        // Both expositions stay parseable under the load.
        assert!(reg.render_prometheus().contains("contended_count"));
        assert!(Json::parse(&reg.to_json().to_string()).is_ok());
    });
}

/// Every event in an exported trace document — metadata, slices,
/// counter samples, and instants — must carry `ph`/`ts`/`pid`/`tid`,
/// or Perfetto refuses the load.
fn assert_trace_schema(doc: &Json) -> usize {
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    for e in events {
        for key in ["ph", "ts", "pid", "tid"] {
            assert!(e.get(key).is_ok(), "trace event missing {key:?}: {e}");
        }
        let ph = e.get("ph").unwrap().as_str().unwrap().to_string();
        assert!(
            ph == "X" || ph == "M" || ph == "C" || ph == "i",
            "unexpected phase {ph:?}"
        );
        match ph.as_str() {
            "X" => assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0),
            // Counter samples carry their value in args.
            "C" => assert!(e.path(&["args", "value"]).unwrap().as_f64().is_ok()),
            // Instants need a scope or Perfetto rejects them.
            "i" => assert_eq!(e.get("s").unwrap().as_str().unwrap(), "t"),
            _ => {}
        }
    }
    events.len()
}

#[test]
fn perfetto_export_from_a_real_serve_passes_the_schema_smoke() {
    with_telemetry(|| {
        let m = KwsModel::synthetic(5);
        let macros = 2;
        let mut coord = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            cimrv::backend::BackendKind::Fast,
            cimrv::coordinator::ServeOptions { macros, ..Default::default() },
        )
        .unwrap();
        let reqs: Vec<_> = (0..6)
            .map(|i| InferenceRequest {
                id: i,
                audio: dataset::synth_utterance(i as usize % 12, i, m.audio_len, 0.3),
                label: None,
                deadline: None,
            })
            .collect();
        let _ = coord.serve_batch(reqs).unwrap();
        coord.shutdown();

        // One synthetic incident so the instant track has something to
        // carry (clean serving emits none).
        events().record(IncidentKind::Shed, None, Some(99), "synthetic test shed".to_string());

        // Exactly the export `cmd_serve --trace-out` performs.
        let spans = coord.stats.spans.snapshot();
        let mut tb = TraceBuilder::new();
        perfetto::serving_tracks(&mut tb, &spans, 256);
        perfetto::counter_tracks(&mut tb, &spans);
        perfetto::incident_tracks(&mut tb, &events().snapshot());
        perfetto::profiler_tracks(&mut tb, &global_profiler().slices_snapshot());
        let (markers, cycles) = coord.stats.engine_sample().expect("engine sample");
        let program = build_kws_program_sharded(&m, OptLevel::FULL, macros).unwrap();
        perfetto::engine_tracks(&mut tb, &program, &markers, cycles);
        let doc = tb.build();

        let n = assert_trace_schema(&doc);
        assert!(n > 0, "trace must carry events");
        // Round-trips through the JSON parser (what CI's validator does).
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), n);
        // All timelines present: worker batching, counters, incidents,
        // profiler regions, and the per-macro engine.
        let text = doc.to_string();
        assert!(text.contains("worker 0"), "missing worker track");
        assert!(text.contains("macro 0"), "missing engine macro track");
        assert!(text.contains("macro 1"), "missing second macro track");
        assert!(text.contains("execute["), "missing batch execute slices");
        assert!(text.contains("queue depth"), "missing queue-depth counter track");
        assert!(text.contains("batch size w"), "missing batch-size counter track");
        assert!(text.contains("incidents"), "missing incident instant track");
        assert!(text.contains("backend_fast_run"), "missing profiler slices");
    });
}

#[test]
fn span_percentiles_match_service_stats_exactly() {
    with_telemetry(|| {
        let m = KwsModel::synthetic(7);
        let mut coord = Coordinator::start_with(
            &m,
            OptLevel::FULL,
            3,
            cimrv::backend::BackendKind::Fast,
        )
        .unwrap();
        let reqs: Vec<_> = (0..12)
            .map(|i| InferenceRequest {
                id: i,
                audio: dataset::synth_utterance(i as usize % 12, 70 + i, m.audio_len, 0.3),
                label: None,
                deadline: None,
            })
            .collect();
        let _ = coord.serve_batch(reqs).unwrap();
        coord.shutdown();

        assert_eq!(coord.stats.spans.len(), 12);
        // The contract: a span's end-to-end time IS the host-latency
        // sample, so the derived percentiles agree exactly — p50 and p99
        // alike, no tolerance.
        let from_spans = coord.stats.span_latency_percentiles().unwrap();
        let from_stats = coord.stats.host_latency_percentiles().unwrap();
        assert_eq!(from_spans, from_stats);
    });
}

/// Keep the optimizer from collapsing the timed work to nothing.
fn spin() -> u64 {
    let mut x = 0u64;
    for i in 0..5_000u64 {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    x
}

#[test]
fn profiler_nesting_attributes_self_time_exactly_under_contention() {
    with_telemetry(|| {
        let prof = global_profiler();
        prof.reset();
        const THREADS: usize = 4;
        const ITERS: usize = 32;
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..ITERS {
                        let _o = region("outer");
                        std::hint::black_box(spin());
                        let _i = region("inner");
                        std::hint::black_box(spin());
                    }
                });
            }
        });
        let fold = prof.fold();
        let outer = fold["outer"];
        let inner = fold["outer;inner"];
        let closes = (THREADS * ITERS) as u64;
        assert_eq!(outer.count, closes);
        assert_eq!(inner.count, closes);
        assert!(outer.total_ns > 0 && inner.total_ns > 0);
        // The nesting contract, exact by construction: a parent's self
        // time is its total minus the sum of its children's totals —
        // the same ns values, not re-measured, so no tolerance.
        assert_eq!(
            outer.total_ns,
            outer.self_ns + inner.total_ns,
            "outer self must be total minus the nested child's total"
        );
        // Folded-stack grammar: every line is `path<SP><integer µs>`
        // with a semicolon-joined path and no other spaces.
        let folded = prof.render_folded();
        assert!(folded.lines().count() >= 2, "{folded}");
        for line in folded.lines() {
            let (path, val) = line.rsplit_once(' ').expect("`path value` line");
            assert!(!path.is_empty() && !path.contains(' '), "{line:?}");
            val.parse::<u64>().expect("folded value is integer µs");
        }
        // The table aggregates by leaf name and carries both names.
        let table = prof.table();
        assert!(table.iter().any(|r| r.name == "outer"));
        assert!(table.iter().any(|r| r.name == "inner"));
        // Timeline slices carry depth and the full path.
        let slices = prof.slices_snapshot();
        assert!(slices.iter().any(|s| s.path == "outer;inner" && s.depth == 1));

        // Disabled: a region guard records nothing at all.
        telemetry::set_enabled(false);
        prof.reset();
        {
            let _r = region("ghost");
            std::hint::black_box(spin());
        }
        assert!(!prof.has_data(), "disabled region must not record");
        telemetry::set_enabled(true);
    });
}

#[test]
fn event_ring_overflow_keeps_newest_and_jsonl_roundtrips() {
    with_telemetry(|| {
        let log = EventLog::with_capacity(8);
        for i in 0..20usize {
            log.record(
                IncidentKind::Shed,
                Some(i % 3),
                Some(i as u64),
                format!("detail {i}"),
            );
        }
        // Bounded ring: newest 8 survive, 12 oldest counted as dropped.
        assert_eq!(log.len(), 8);
        assert_eq!(log.dropped(), 12);
        let snap = log.snapshot();
        assert_eq!(snap.first().unwrap().seq, 12, "oldest survivor");
        assert_eq!(snap.last().unwrap().seq, 19, "newest survivor");
        for w in snap.windows(2) {
            assert_eq!(w[1].seq, w[0].seq + 1, "seq stays monotone");
            assert!(w[1].ts_us >= w[0].ts_us, "timestamps stay ordered");
        }
        // JSONL round-trip: one parseable object per line, every field
        // surviving (including the optional ids).
        let jsonl = log.to_jsonl();
        assert_eq!(jsonl.lines().count(), 8);
        for (line, want) in jsonl.lines().zip(&snap) {
            let ev = IncidentEvent::from_json(&Json::parse(line).unwrap()).unwrap();
            assert_eq!(&ev, want);
            assert_eq!(ev.kind, IncidentKind::Shed);
            assert_eq!(ev.detail, format!("detail {}", ev.seq));
        }
        // Disabled: record is a no-op, the ring stays put.
        telemetry::set_enabled(false);
        log.record(IncidentKind::Shed, None, None, "ignored".to_string());
        assert_eq!(log.len(), 8);
        assert_eq!(log.dropped(), 12);
        telemetry::set_enabled(true);
    });
}

#[test]
fn slo_window_math_availability_p99_and_burn_rate() {
    let cfg = SloConfig::parse_spec("p99_ms=5,availability=0.9,window=100").unwrap();
    let mon = SloMonitor::new(cfg);
    // 95 served at 10..=950 µs, then 5 unserved outcomes.
    for i in 1..=95u64 {
        mon.record(i * 10, true);
    }
    for _ in 0..5 {
        mon.record(0, false);
    }
    let rep = mon.report();
    assert_eq!(rep.seen, 100);
    assert_eq!(rep.window_n, 100);
    assert_eq!(rep.availability, Some(0.95));
    // Nearest-rank p99 over 95 served samples: rank ceil(0.99*95)=95,
    // i.e. the max, 950 µs.
    assert_eq!(rep.p99_us, Some(950));
    // Burn rate: (1-0.95)/(1-0.9) = 0.5 — half the error budget.
    assert!((rep.burn_rate.unwrap() - 0.5).abs() < 1e-12);
    assert!(rep.availability_ok() && rep.p99_ok() && rep.compliant());

    // 20 more failures slide the window: 75 served / 25 failed.
    for _ in 0..20 {
        mon.record(0, false);
    }
    let rep = mon.report();
    assert_eq!(rep.window_n, 100, "window stays bounded");
    assert_eq!(rep.seen, 120, "seen keeps counting past the window");
    assert_eq!(rep.availability, Some(0.75));
    assert!((rep.burn_rate.unwrap() - 2.5).abs() < 1e-12, "2.5x over budget");
    assert!(!rep.availability_ok() && !rep.compliant());
    // The report renders and serializes without panicking.
    assert!(rep.render().contains("burn rate"));
    assert!(Json::parse(&rep.to_json().to_string()).is_ok());

    // The soak-gate checker agrees with the same targets.
    assert!(cfg.check_observed(0.95, Some(950)).is_ok());
    assert!(cfg.check_observed(0.95, Some(5_001)).is_err(), "p99 above 5 ms");
    assert!(cfg.check_observed(0.85, Some(950)).is_err(), "availability below 0.9");
}
