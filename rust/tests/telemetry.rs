//! Telemetry integration suite: registry behavior under real thread
//! contention, the Perfetto export schema, and the span-vs-ServiceStats
//! consistency contract — everything driven through the public API the
//! CLI uses (`cimrv serve --trace-out/--metrics-out`). No artifacts
//! required — runs on synthetic models.

use std::sync::Mutex;

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program_sharded;
use cimrv::coordinator::{Coordinator, InferenceRequest};
use cimrv::model::{dataset, KwsModel};
use cimrv::telemetry::{self, perfetto, Histogram, Registry, TraceBuilder};
use cimrv::util::json::Json;

/// The enable flag is process-global; tests that flip it run serialized
/// (the library's internal tests use the same pattern via
/// `telemetry::with_telemetry`, which is `cfg(test)`-private to the lib).
fn with_telemetry<T>(f: impl FnOnce() -> T) -> T {
    static GUARD: Mutex<()> = Mutex::new(());
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    let was = telemetry::enabled();
    telemetry::set_enabled(true);
    let out = f();
    telemetry::set_enabled(was);
    out
}

#[test]
fn registry_totals_are_exact_under_thread_contention() {
    with_telemetry(|| {
        let reg = Registry::new();
        const THREADS: usize = 8;
        const PER_THREAD: u64 = 10_000;
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let reg = &reg;
                s.spawn(move || {
                    let c = reg.counter("contended.count");
                    let h = reg.histogram("contended.us", Histogram::us_bounds());
                    let g = reg.gauge("contended.gauge");
                    for i in 0..PER_THREAD {
                        c.inc();
                        h.observe(i % 1000);
                        g.set(t as f64);
                    }
                });
            }
        });
        let total = THREADS as u64 * PER_THREAD;
        assert_eq!(reg.counter("contended.count").get(), total);
        let h = reg.histogram("contended.us", Histogram::us_bounds());
        assert_eq!(h.count(), total);
        // Sum is exact too: each thread contributes sum(0..1000) * 10.
        let per_thread_sum: u64 = (0..PER_THREAD).map(|i| i % 1000).sum();
        assert_eq!(h.sum(), THREADS as u64 * per_thread_sum);
        // The +Inf cumulative bucket accounts for every observation.
        assert_eq!(h.cumulative().last().unwrap().1, total);
        // The gauge holds one of the racing writes, not garbage.
        let g = reg.gauge("contended.gauge").get();
        assert!((0.0..THREADS as f64).contains(&g));
        // Both expositions stay parseable under the load.
        assert!(reg.render_prometheus().contains("contended_count"));
        assert!(Json::parse(&reg.to_json().to_string()).is_ok());
    });
}

/// Every event in an exported trace document — metadata and slices —
/// must carry `ph`/`ts`/`pid`/`tid`, or Perfetto refuses the load.
fn assert_trace_schema(doc: &Json) -> usize {
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    for e in events {
        for key in ["ph", "ts", "pid", "tid"] {
            assert!(e.get(key).is_ok(), "trace event missing {key:?}: {e}");
        }
        let ph = e.get("ph").unwrap().as_str().unwrap().to_string();
        assert!(ph == "X" || ph == "M", "unexpected phase {ph:?}");
        if ph == "X" {
            assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        }
    }
    events.len()
}

#[test]
fn perfetto_export_from_a_real_serve_passes_the_schema_smoke() {
    with_telemetry(|| {
        let m = KwsModel::synthetic(5);
        let macros = 2;
        let mut coord = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            cimrv::backend::BackendKind::Fast,
            cimrv::coordinator::ServeOptions { macros, ..Default::default() },
        )
        .unwrap();
        let reqs: Vec<_> = (0..6)
            .map(|i| InferenceRequest {
                id: i,
                audio: dataset::synth_utterance(i as usize % 12, i, m.audio_len, 0.3),
                label: None,
                deadline: None,
            })
            .collect();
        let _ = coord.serve_batch(reqs).unwrap();
        coord.shutdown();

        // Exactly the export `cmd_serve --trace-out` performs.
        let mut tb = TraceBuilder::new();
        perfetto::serving_tracks(&mut tb, &coord.stats.spans.snapshot(), 256);
        let (markers, cycles) = coord.stats.engine_sample().expect("engine sample");
        let program = build_kws_program_sharded(&m, OptLevel::FULL, macros).unwrap();
        perfetto::engine_tracks(&mut tb, &program, &markers, cycles);
        let doc = tb.build();

        let n = assert_trace_schema(&doc);
        assert!(n > 0, "trace must carry events");
        // Round-trips through the JSON parser (what CI's validator does).
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("traceEvents").unwrap().as_arr().unwrap().len(), n);
        // Both timelines present: worker batching and per-macro engine.
        let text = doc.to_string();
        assert!(text.contains("worker 0"), "missing worker track");
        assert!(text.contains("macro 0"), "missing engine macro track");
        assert!(text.contains("macro 1"), "missing second macro track");
        assert!(text.contains("execute["), "missing batch execute slices");
    });
}

#[test]
fn span_percentiles_match_service_stats_exactly() {
    with_telemetry(|| {
        let m = KwsModel::synthetic(7);
        let mut coord = Coordinator::start_with(
            &m,
            OptLevel::FULL,
            3,
            cimrv::backend::BackendKind::Fast,
        )
        .unwrap();
        let reqs: Vec<_> = (0..12)
            .map(|i| InferenceRequest {
                id: i,
                audio: dataset::synth_utterance(i as usize % 12, 70 + i, m.audio_len, 0.3),
                label: None,
                deadline: None,
            })
            .collect();
        let _ = coord.serve_batch(reqs).unwrap();
        coord.shutdown();

        assert_eq!(coord.stats.spans.len(), 12);
        // The contract: a span's end-to-end time IS the host-latency
        // sample, so the derived percentiles agree exactly — p50 and p99
        // alike, no tolerance.
        let from_spans = coord.stats.span_latency_percentiles().unwrap();
        let from_stats = coord.stats.host_latency_percentiles().unwrap();
        assert_eq!(from_spans, from_stats);
    });
}
