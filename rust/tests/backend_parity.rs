//! Backend parity suite: the fast functional simulator must be
//! indistinguishable from the cycle-level SoC on values (bit-identical
//! logits across models, seeds and optimization levels) and close on
//! timing (analytical latency within 5% of measured cycles; snap
//! calibration exact). No artifacts required — runs on synthetic models.

use cimrv::backend::{self, BackendKind, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::fsim::{Calibration, FastSim};
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::sim::Soc;

#[test]
fn fsim_logits_bit_identical_across_seeds_and_opt_levels() {
    for model_seed in [1u64, 42] {
        let m = KwsModel::synthetic(model_seed);
        for (name, opt) in OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
            let fast = FastSim::new(prog, DramConfig::default()).unwrap();
            for audio_seed in [3u64, 9] {
                let audio = dataset::synth_utterance(
                    audio_seed as usize % 12,
                    audio_seed,
                    m.audio_len,
                    0.37,
                );
                let want = soc.infer(&audio).unwrap();
                let got = fast.infer(&audio);
                assert_eq!(
                    got.logits, want.logits,
                    "model {model_seed} / {name} / audio {audio_seed}"
                );
                assert_eq!(got.predicted, want.predicted);
            }
        }
    }
}

#[test]
fn analytical_latency_within_5_percent_of_cycle_sim() {
    let m = KwsModel::synthetic(3);
    let audio = dataset::synth_utterance(5, 7, m.audio_len, 0.37);
    for (name, opt) in OptLevel::ladder() {
        let prog = build_kws_program(&m, opt).unwrap();
        let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
        let actual = soc.infer(&audio).unwrap();
        let fast = FastSim::new(prog, DramConfig::default()).unwrap();
        let est = fast.infer(&audio);

        let err = (est.cycles as f64 - actual.cycles as f64).abs() / actual.cycles as f64;
        assert!(
            err <= 0.05,
            "{name}: analytical {} vs measured {} cycles ({:.2}% error)",
            est.cycles,
            actual.cycles,
            100.0 * err
        );
        // Instruction count and energy track the same walk.
        let ierr =
            (est.instret as f64 - actual.instret as f64).abs() / actual.instret as f64;
        assert!(ierr <= 0.05, "{name}: instret error {:.2}%", 100.0 * ierr);
        let eerr = (est.energy.total_pj - actual.energy.total_pj).abs()
            / actual.energy.total_pj;
        assert!(eerr <= 0.05, "{name}: energy error {:.2}%", 100.0 * eerr);
        // Phase attribution stays in the same regime per phase.
        assert!(est.phases.boot > 0 && est.phases.preprocess > 0);
        assert_eq!(est.phases.total(), est.cycles);
    }
}

#[test]
fn calibrated_fast_backend_is_cycle_exact() {
    let m = KwsModel::synthetic(8);
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
    let audio = dataset::synth_utterance(1, 4, m.audio_len, 0.37);
    let measured = soc.infer(&audio).unwrap();

    let fast = FastSim::new(prog.clone(), DramConfig::default())
        .unwrap()
        .with_calibration(Calibration::from_run(&measured));
    // Latency is data-independent, so the calibration from one utterance
    // holds for a different one.
    let other = dataset::synth_utterance(9, 77, m.audio_len, 0.37);
    let want_other = soc.infer(&other).unwrap();
    let got = fast.infer(&other);
    assert_eq!(got.cycles, want_other.cycles, "calibrated cycles must be exact");
    assert_eq!(got.instret, want_other.instret);
    assert_eq!(got.logits, want_other.logits);
    assert!((got.energy.total_pj - want_other.energy.total_pj).abs() < 1e-6);

    // The backend-level wrapper carries the same calibration semantics.
    let mut be = backend::FastBackend::new(prog, DramConfig::default())
        .unwrap()
        .with_calibration(Calibration::from_run(&measured));
    let r = be.run(&other).unwrap();
    assert_eq!(r.cycles, want_other.cycles);
    assert_eq!(r.logits, want_other.logits);
}

#[test]
fn packed_kernels_bit_identical_to_scalar_oracle_and_cycle_soc() {
    // The tentpole contract: the XNOR-popcount engine, the PR 1 scalar
    // kernels, and the cycle-level SoC all agree bit-for-bit on the same
    // compiled image.
    for model_seed in [4u64, 23] {
        let m = KwsModel::synthetic(model_seed);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
        let fast = FastSim::new(prog, DramConfig::default()).unwrap();
        let specs = fast.decoded().to_layer_specs();
        for audio_seed in [0u64, 8, 31] {
            let audio =
                dataset::synth_utterance(audio_seed as usize % 12, audio_seed, m.audio_len, 0.37);
            let cycle = soc.infer(&audio).unwrap();
            let (packed_logits, packed_pred) = fast.decoded().infer(&audio);
            let (scalar_logits, scalar_pred) = fast.decoded().infer_scalar(&specs, &audio);
            assert_eq!(
                packed_logits, cycle.logits,
                "packed vs cycle: model {model_seed} audio {audio_seed}"
            );
            assert_eq!(
                packed_logits, scalar_logits,
                "packed vs scalar: model {model_seed} audio {audio_seed}"
            );
            assert_eq!(packed_pred, cycle.predicted);
            assert_eq!(packed_pred, scalar_pred);
        }
    }
}

#[test]
fn backend_trait_serves_both_engines() {
    let m = KwsModel::synthetic(12);
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let audio = dataset::synth_utterance(6, 2, m.audio_len, 0.37);
    let mut cycle = backend::build(BackendKind::Cycle, prog.clone(), DramConfig::default())
        .unwrap();
    let mut fast = backend::build(BackendKind::Fast, prog, DramConfig::default()).unwrap();
    assert_eq!(cycle.name(), "cycle");
    assert_eq!(fast.name(), "fast");
    assert_eq!(cycle.program().n_classes, fast.program().n_classes);
    let a = cycle.run(&audio).unwrap();
    let b = fast.run(&audio).unwrap();
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.predicted, b.predicted);
    assert!(a.cycles > 0 && b.cycles > 0);
}
