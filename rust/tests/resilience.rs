//! Fault-tolerance integration tests: chaos determinism, availability
//! under injected panics/transients, typed admission + deadline sheds,
//! and breaker-driven graceful degradation — the acceptance criteria of
//! the resilience subsystem, exercised through the public coordinator
//! surface exactly the way `cimrv serve --chaos` and `cimrv soak` do.

use std::time::{Duration, Instant};

use cimrv::backend::{self, BackendKind, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::coordinator::{
    Coordinator, InferenceRequest, ServeError, ServeOptions, SubmitError, BREAKER_THRESHOLD,
};
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::resilience::{ChaosBackend, FaultPlan};
use cimrv::telemetry::{self, events, IncidentKind};
use cimrv::util::rng::Rng;

/// The telemetry enable flag is process-global; the one test that flips
/// it (to capture the incident log) serializes through this guard, same
/// pattern as `tests/telemetry.rs`.
fn with_telemetry<T>(f: impl FnOnce() -> T) -> T {
    static GUARD: std::sync::Mutex<()> = std::sync::Mutex::new(());
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    let was = telemetry::enabled();
    telemetry::set_enabled(true);
    let out = f();
    telemetry::set_enabled(was);
    out
}

/// Load the trained artifacts, or skip the calling test (same contract
/// as `integration.rs`: the checked-in testdata set makes this run in
/// CI; a missing set must not fail the suite).
fn model() -> Option<KwsModel> {
    match KwsModel::load_default() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping: artifacts not found (run `make artifacts`): {e}");
            None
        }
    }
}

fn requests(m: &KwsModel, n: u64, deadline: Option<Instant>) -> Vec<InferenceRequest> {
    (0..n)
        .map(|i| InferenceRequest {
            id: i,
            audio: dataset::synth_utterance(i as usize % 12, 90 + i, m.audio_len, 0.3),
            label: Some((i % 12) as i32),
            deadline,
        })
        .collect()
}

/// Same plan + same seed ⇒ the same fault schedule and counters,
/// call for call; a different stream seed ⇒ a different schedule.
#[test]
fn chaos_schedule_is_deterministic_per_seed() {
    let Some(m) = model() else { return };
    let plan = FaultPlan {
        seed: 11,
        latency: 0.3,
        latency_ms: 0,
        transient: 0.3,
        corrupt: 0.2,
        ..Default::default()
    };
    let audio = dataset::synth_utterance(4, 9, m.audio_len, 0.3);
    let run = |seed: u64| {
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let inner = backend::build(BackendKind::Fast, prog, DramConfig::default()).unwrap();
        let mut chaos = ChaosBackend::with_seed(inner, plan, seed);
        let mut results = Vec::new();
        for _ in 0..24 {
            results.push(chaos.run_batch(&[&audio]).map(|rs| rs[0].logits.clone()).ok());
        }
        (chaos.fault_log().to_vec(), chaos.counts(), results)
    };
    let (log_a, counts_a, res_a) = run(77);
    let (log_b, counts_b, res_b) = run(77);
    assert_eq!(log_a, log_b, "same seed must replay the same fault schedule");
    assert_eq!(counts_a, counts_b);
    assert_eq!(res_a, res_b, "corrupted logits are part of the deterministic stream");
    assert_eq!(counts_a.calls, 24);
    assert!(counts_a.transient > 0, "schedule should exercise transients at p=0.3");
    let (log_c, _, _) = run(78);
    assert_ne!(log_a, log_c, "a different stream seed must give a different schedule");
}

/// Panics + transients at serving time: every request still gets an
/// answer (100% availability), the supervisor respawns the dead worker
/// within the run, and non-corrupting faults leave logits bit-identical
/// to a clean serve.
#[test]
fn serve_survives_panics_and_transients_with_full_availability() {
    let Some(m) = model() else { return };
    let n = 24;
    let clean = {
        let mut coord =
            Coordinator::start_with_options(&m, OptLevel::FULL, 2, BackendKind::Fast, ServeOptions::default())
                .unwrap();
        let resps = coord.serve_batch(requests(&m, n, None)).unwrap();
        coord.shutdown();
        resps
    };
    let opts = ServeOptions {
        chaos: Some(FaultPlan { seed: 5, panic: 0.25, transient: 0.25, ..Default::default() }),
        max_attempts: 40,
        ..Default::default()
    };
    let mut coord =
        Coordinator::start_with_options(&m, OptLevel::FULL, 2, BackendKind::Fast, opts).unwrap();
    let resps = coord.serve_batch(requests(&m, n, None)).unwrap();
    assert_eq!(resps.len() as u64, n, "availability must be 100% under retryable chaos");
    for (got, want) in resps.iter().zip(&clean) {
        assert_eq!(got.id, want.id);
        assert_eq!(got.logits, want.logits, "req {}: non-corrupting faults must not change logits", got.id);
    }
    use std::sync::atomic::Ordering::Relaxed;
    let s = &coord.stats;
    assert!(s.worker_panics.load(Relaxed) > 0, "p=0.25 over ~{n} calls should panic at least once");
    assert!(
        s.respawns.load(Relaxed) >= s.worker_panics.load(Relaxed).min(1),
        "every panicked worker must be respawned within the run"
    );
    assert!(s.retries.load(Relaxed) + s.requeues.load(Relaxed) > 0);
    coord.shutdown();
}

/// A full queue sheds new work *fast* with a typed error instead of
/// blocking the caller behind a stalled worker.
#[test]
fn full_queue_sheds_with_typed_overloaded_error() {
    let Some(m) = model() else { return };
    let opts = ServeOptions {
        queue_cap: 2,
        chaos: Some(FaultPlan { stall: 1.0, stall_ms: 400, ..Default::default() }),
        ..Default::default()
    };
    let coord =
        Coordinator::start_with_options(&m, OptLevel::FULL, 1, BackendKind::Fast, opts).unwrap();
    // Let the single worker wedge itself on the first request.
    let mut reqs = requests(&m, 8, None).into_iter();
    let _pending = coord.submit(reqs.next().unwrap()).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    // Fill the queue, then overflow it: the shed must be immediate.
    let mut rxs = Vec::new();
    let mut overloaded = 0;
    let t0 = Instant::now();
    for req in reqs {
        match coord.submit(req) {
            Ok(rx) => rxs.push(rx),
            Err(SubmitError::Overloaded { depth, cap }) => {
                assert_eq!(cap, 2);
                assert!(depth >= cap);
                overloaded += 1;
            }
            Err(SubmitError::Shutdown) => panic!("coordinator is not shutting down"),
        }
    }
    let elapsed = t0.elapsed();
    assert!(overloaded >= 5, "7 submits into a cap-2 queue: got {overloaded} sheds");
    assert!(
        elapsed < Duration::from_millis(200),
        "admission control must not block behind the stalled worker ({elapsed:?})"
    );
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(coord.stats.shed_overload.load(Relaxed), overloaded);
    drop(coord); // Drop impl shuts down; queued jobs drain with typed errors.
}

/// Requests whose deadline lapses while queued behind a stalled worker
/// come back as `ServeError::DeadlineExceeded`, not as hangs.
#[test]
fn expired_deadlines_shed_with_typed_error() {
    let Some(m) = model() else { return };
    let opts = ServeOptions {
        chaos: Some(FaultPlan { stall: 1.0, stall_ms: 120, ..Default::default() }),
        ..Default::default()
    };
    let mut coord =
        Coordinator::start_with_options(&m, OptLevel::FULL, 1, BackendKind::Fast, opts).unwrap();
    let deadline = Some(Instant::now() + Duration::from_millis(40));
    let rxs: Vec<_> = requests(&m, 4, deadline)
        .into_iter()
        .map(|r| coord.submit(r).expect("queue has room"))
        .collect();
    let mut expired = 0;
    for rx in rxs {
        match rx.recv().expect("every request gets a terminal answer") {
            Ok(_) => {}
            Err(ServeError::DeadlineExceeded { waited_us }) => {
                assert!(waited_us > 0);
                expired += 1;
            }
            Err(e) => panic!("unexpected error class: {e}"),
        }
    }
    // Every 120 ms stall pushes the 40 ms budget past its deadline for
    // whatever is still queued; at least the tail must shed.
    assert!(expired >= 1, "stalled worker must force deadline sheds");
    use std::sync::atomic::Ordering::Relaxed;
    assert_eq!(coord.stats.shed_deadline.load(Relaxed), expired);
    coord.shutdown();
}

/// Breaker-driven graceful degradation: a worker whose backend faults
/// `BREAKER_THRESHOLD` times in a row is torn down and respawned in
/// degraded mode (shard re-plan over the survivor macros); the job it
/// was holding is requeued, succeeds on the new incarnation, and its
/// logits still match the clean baseline exactly.
#[test]
fn breaker_trips_respawn_degraded_and_preserve_correctness() {
    let Some(m) = model() else { return };
    // Find a plan seed whose incarnation-0 stream opens with
    // BREAKER_THRESHOLD straight transients (trips the breaker on the
    // first job) while incarnation 1 recovers within a few calls. The
    // search runs on the plan itself, so the test stays deterministic
    // without depending on the RNG's internals.
    let threshold = BREAKER_THRESHOLD as usize;
    let plan = (0..200_000u64)
        .map(|seed| FaultPlan { seed, transient: 0.6, ..Default::default() })
        .find(|p| {
            let mut inc0 = Rng::new(p.worker_seed(0, 0));
            let trips = (0..threshold).all(|_| p.draw(&mut inc0).transient);
            let mut inc1 = Rng::new(p.worker_seed(0, 1));
            let recovers = (0..10).any(|_| !p.draw(&mut inc1).transient);
            trips && recovers
        })
        .expect("a tripping seed exists well inside the search budget");
    let clean = {
        let opts = ServeOptions { macros: 2, ..Default::default() };
        let mut coord =
            Coordinator::start_with_options(&m, OptLevel::FULL, 1, BackendKind::Fast, opts)
                .unwrap();
        let resps = coord.serve_batch(requests(&m, 2, None)).unwrap();
        coord.shutdown();
        resps
    };
    let opts = ServeOptions {
        macros: 2,
        chaos: Some(plan),
        max_attempts: 40,
        ..Default::default()
    };
    // Serve with telemetry on so the incident log captures the whole
    // degradation story alongside the counters.
    let (resps, stats, degraded, incidents) = with_telemetry(|| {
        events().reset();
        let mut coord =
            Coordinator::start_with_options(&m, OptLevel::FULL, 1, BackendKind::Fast, opts)
                .unwrap();
        let resps = coord.serve_batch(requests(&m, 2, None)).unwrap();
        coord.shutdown();
        let degraded = coord.degraded_workers();
        (resps, std::sync::Arc::clone(&coord.stats), degraded, events().snapshot())
    });
    use std::sync::atomic::Ordering::Relaxed;
    let s = &stats;
    assert!(s.breaker_trips.load(Relaxed) >= 1, "incarnation 0 must trip the breaker");
    assert!(s.respawns.load(Relaxed) >= 1, "the tripped worker must be respawned");
    assert_eq!(
        degraded, 1,
        "the respawned worker must run the degraded survivor shard plan"
    );
    // The structured incident log tells the same story, in order: chaos
    // injections, the breaker trip on worker 0, the degraded re-plan
    // (built during respawn), then the respawn announcement. The log is
    // process-global, so concurrently running tests may interleave
    // their own incidents — assert the trip -> re-plan -> respawn chain
    // exists in order rather than demanding exclusive positions.
    assert!(
        incidents.iter().any(|e| e.kind == IncidentKind::ChaosInjected),
        "injected faults must log"
    );
    let trip = incidents
        .iter()
        .position(|e| e.kind == IncidentKind::BreakerTrip)
        .expect("breaker trip in the event log");
    let trip_ev = &incidents[trip];
    assert_eq!(trip_ev.worker, Some(0), "single-worker serve: worker 0 trips");
    assert!(
        trip_ev.detail.contains("consecutive faults"),
        "trip detail carries the streak: {trip_ev:?}"
    );
    let replan = incidents[trip..]
        .iter()
        .position(|e| e.kind == IncidentKind::DegradedReplan)
        .map(|p| trip + p)
        .expect("degraded re-plan after the trip");
    assert!(
        incidents[replan..].iter().any(|e| e.kind == IncidentKind::WorkerRespawn),
        "respawn after the degraded re-plan"
    );
    for (got, want) in resps.iter().zip(&clean) {
        assert_eq!(
            got.logits, want.logits,
            "req {}: degraded re-plan must stay bit-exact",
            got.id
        );
        assert_eq!(got.predicted, want.predicted);
    }
}
