//! Variation parity: the variation-aware functional simulator
//! (`robustness::replay`) must produce logits **bit-identical** to the
//! cycle engine with the same `VariationModel` seed — across optimization
//! levels and shard counts — and reduce to today's undisturbed fast path
//! at sigma = 0. No artifacts required (synthetic models).
//!
//! This is the contract that makes Monte-Carlo robustness sweeps at
//! serving speed trustworthy: every disturbed trial the sweep engine runs
//! is exactly the inference the simulated silicon would have produced.

use cimrv::backend::{CycleBackend, FastBackend, InferenceBackend};
use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program_sharded;
use cimrv::fsim::FastSim;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::robustness::VariationParams;
use cimrv::sim::Soc;

fn configs() -> Vec<VariationParams> {
    vec![
        // Symmetric mapping: residual-mismatch noise only.
        VariationParams { sigma: 0.3, nl_alpha: 0.1, symmetric: true, ..Default::default() },
        // Single-ended: full noise + data-dependent compressive NL.
        VariationParams { sigma: 0.15, nl_alpha: 0.3, symmetric: false, ..Default::default() },
        // Non-default mismatch and seed must thread through both engines.
        VariationParams { sigma: 0.5, nl_alpha: 0.2, symmetric: true, mismatch: 0.4, seed: 99 },
    ]
}

#[test]
fn disturbed_fsim_bit_identical_to_cycle_across_opt_levels() {
    let m = KwsModel::synthetic(42);
    let audio = dataset::synth_utterance(3, 7, m.audio_len, 0.37);
    for (name, opt) in OptLevel::ladder() {
        for params in configs() {
            let prog = build_kws_program_sharded(&m, opt, 1).unwrap();
            let mut soc = Soc::new(prog.clone(), DramConfig::default())
                .unwrap()
                .with_variation(params.model());
            let want = soc.infer(&audio).unwrap();
            let sim = FastSim::new(prog, DramConfig::default()).unwrap();
            let got = sim.infer_disturbed(&audio, &params);
            assert_eq!(
                got.logits, want.logits,
                "{name}: disturbed fsim diverged from cycle engine ({params:?})"
            );
            assert_eq!(got.predicted, want.predicted);
        }
    }
}

#[test]
fn disturbed_fsim_bit_identical_to_cycle_across_shard_counts() {
    let m = KwsModel::synthetic(13);
    let audio = dataset::synth_utterance(5, 11, m.audio_len, 0.37);
    let params =
        VariationParams { sigma: 0.25, nl_alpha: 0.3, symmetric: false, ..Default::default() };
    for n in 1..=4usize {
        let prog = build_kws_program_sharded(&m, OptLevel::FULL, n).unwrap();
        let mut soc = Soc::new(prog.clone(), DramConfig::default())
            .unwrap()
            .with_variation(params.model());
        let want = soc.infer(&audio).unwrap();
        // FastSim auto-engages the program's shard plan; the replay must
        // advance one independent stream per macro, like the SoC's bank.
        let sim = FastSim::new(prog, DramConfig::default()).unwrap();
        let got = sim.infer_disturbed(&audio, &params);
        assert_eq!(got.logits, want.logits, "shards {n}: disturbed logits diverged");
        assert_eq!(got.shard_fires, want.shard_fires, "shards {n}: fire accounting diverged");
    }
}

#[test]
fn sigma_zero_is_bit_identical_to_undisturbed_fsim() {
    let m = KwsModel::synthetic(8);
    let audio = dataset::synth_utterance(1, 3, m.audio_len, 0.37);
    // sigma = 0 symmetric (NL cancels) and sigma = 0 single-ended with
    // nl = 0 are arithmetic identities: same bits as the clean fast path.
    let noops = [
        VariationParams { sigma: 0.0, nl_alpha: 0.7, symmetric: true, ..Default::default() },
        VariationParams { sigma: 0.0, nl_alpha: 0.0, symmetric: false, ..Default::default() },
    ];
    for (_, opt) in OptLevel::ladder() {
        for n in 1..=2usize {
            let prog = build_kws_program_sharded(&m, opt, n).unwrap();
            let sim = FastSim::new(prog, DramConfig::default()).unwrap();
            let clean = sim.infer(&audio);
            for params in noops.iter() {
                assert!(params.is_noop());
                let got = sim.infer_disturbed(&audio, params);
                assert_eq!(got.logits, clean.logits, "opt {opt} shards {n}");
                assert_eq!(got.predicted, clean.predicted);
            }
        }
    }
}

#[test]
fn backend_seam_serves_matching_disturbance_and_is_reproducible() {
    // Through the InferenceBackend contract (what the coordinator runs):
    // cycle and fast backends reseed per request, so matched seeds give
    // matched disturbed logits — and repeating a request reproduces them.
    let m = KwsModel::synthetic(21);
    let audios: Vec<Vec<f32>> = (0..3)
        .map(|i| dataset::synth_utterance(i % 12, 60 + i as u64, m.audio_len, 0.37))
        .collect();
    let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
    let params =
        VariationParams { sigma: 0.4, nl_alpha: 0.3, symmetric: false, ..Default::default() };

    let prog = build_kws_program_sharded(&m, OptLevel::FULL, 2).unwrap();
    let mut cyc = CycleBackend::new(prog.clone(), DramConfig::default())
        .unwrap()
        .with_variation(params);
    let want = cyc.run_batch(&refs).unwrap();
    let mut fast = FastBackend::new(prog, DramConfig::default()).unwrap().with_variation(params);
    let got = fast.run_batch(&refs).unwrap();
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.logits, w.logits, "request {i} diverged across engines");
    }
    // Reproducibility: the same batch again — including on the cycle
    // backend, which re-injects fresh streams per inference — yields the
    // same disturbance, element for element.
    let again = cyc.run_batch(&refs).unwrap();
    for (a, w) in again.iter().zip(&want) {
        assert_eq!(a.logits, w.logits);
    }
    let again = fast.run_batch(&refs).unwrap();
    for (a, w) in again.iter().zip(&want) {
        assert_eq!(a.logits, w.logits);
    }
}
