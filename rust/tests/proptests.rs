//! Property tests (in-tree harness — the image has no proptest crate):
//! ISA round-trips, macro-vs-reference MAC equivalence, allocator/plan
//! invariants, coordinator batching invariants.

use cimrv::cim::{weight_map, CimMacro, Mode};
use cimrv::isa::rv32::{AluOp, BranchKind, Instr, LoadKind, MulOp, StoreKind};
use cimrv::isa::{decode, encode, CimFunct, CimInstr, Reg};
use cimrv::util::proptest::check;
use cimrv::util::rng::Rng;

fn rand_reg(rng: &mut Rng) -> Reg {
    Reg(rng.range(0, 32) as u8)
}

fn rand_instr(rng: &mut Rng) -> Instr {
    match rng.range(0, 12) {
        0 => Instr::Lui { rd: rand_reg(rng), imm: rng.range(0, 1 << 20) as i32 },
        1 => Instr::Auipc { rd: rand_reg(rng), imm: rng.range(0, 1 << 20) as i32 },
        2 => Instr::Jal { rd: rand_reg(rng), offset: (rng.range(0, 1 << 20) as i32 - (1 << 19)) * 2 },
        3 => Instr::Jalr {
            rd: rand_reg(rng),
            rs1: rand_reg(rng),
            offset: rng.range(0, 4096) as i32 - 2048,
        },
        4 => {
            let kinds = [BranchKind::Beq, BranchKind::Bne, BranchKind::Blt, BranchKind::Bge, BranchKind::Bltu, BranchKind::Bgeu];
            Instr::Branch {
                kind: kinds[rng.range(0, kinds.len())],
                rs1: rand_reg(rng),
                rs2: rand_reg(rng),
                offset: (rng.range(0, 4096) as i32 - 2048) * 2,
            }
        }
        5 => {
            let kinds = [LoadKind::Lb, LoadKind::Lh, LoadKind::Lw, LoadKind::Lbu, LoadKind::Lhu];
            Instr::Load {
                kind: kinds[rng.range(0, kinds.len())],
                rd: rand_reg(rng),
                rs1: rand_reg(rng),
                offset: rng.range(0, 4096) as i32 - 2048,
            }
        }
        6 => {
            let kinds = [StoreKind::Sb, StoreKind::Sh, StoreKind::Sw];
            Instr::Store {
                kind: kinds[rng.range(0, kinds.len())],
                rs1: rand_reg(rng),
                rs2: rand_reg(rng),
                offset: rng.range(0, 4096) as i32 - 2048,
            }
        }
        7 => {
            let ops = [AluOp::Add, AluOp::Slt, AluOp::Sltu, AluOp::Xor, AluOp::Or, AluOp::And];
            Instr::OpImm {
                op: ops[rng.range(0, ops.len())],
                rd: rand_reg(rng),
                rs1: rand_reg(rng),
                imm: rng.range(0, 4096) as i32 - 2048,
            }
        }
        8 => {
            let ops = [AluOp::Sll, AluOp::Srl, AluOp::Sra];
            Instr::OpImm {
                op: ops[rng.range(0, ops.len())],
                rd: rand_reg(rng),
                rs1: rand_reg(rng),
                imm: rng.range(0, 32) as i32,
            }
        }
        9 => {
            let ops = [
                AluOp::Add, AluOp::Sub, AluOp::Sll, AluOp::Slt, AluOp::Sltu,
                AluOp::Xor, AluOp::Srl, AluOp::Sra, AluOp::Or, AluOp::And,
            ];
            Instr::Op {
                op: ops[rng.range(0, ops.len())],
                rd: rand_reg(rng),
                rs1: rand_reg(rng),
                rs2: rand_reg(rng),
            }
        }
        10 => {
            let ops = [MulOp::Mul, MulOp::Mulh, MulOp::Mulhsu, MulOp::Mulhu, MulOp::Div, MulOp::Divu, MulOp::Rem, MulOp::Remu];
            Instr::MulDiv {
                op: ops[rng.range(0, ops.len())],
                rd: rand_reg(rng),
                rs1: rand_reg(rng),
                rs2: rand_reg(rng),
            }
        }
        _ => {
            let functs = [CimFunct::Conv, CimFunct::Read, CimFunct::Write];
            let funct = functs[rng.range(0, 3)];
            let conv = funct == CimFunct::Conv;
            Instr::Cim(CimInstr {
                funct,
                rs1: Reg(10 + rng.range(0, 4) as u8),
                rs2: Reg(10 + rng.range(0, 4) as u8),
                imm_s: rng.range(0, 256) as u16,
                imm_d: rng.range(0, 128) as u16,
                wd: if conv { rng.range(0, 8) as u8 } else { 0 },
                sh: conv && rng.bool(0.5),
            })
        }
    }
}

#[test]
fn prop_isa_encode_decode_roundtrip() {
    check("isa roundtrip", 5000, |rng| {
        let i = rand_instr(rng);
        let w = encode(&i).unwrap();
        let back = decode(w).unwrap_or_else(|e| panic!("{i:?} -> {w:#010x}: {e}"));
        assert_eq!(back, i, "word {w:#010x}");
    });
}

#[test]
fn prop_decode_never_panics_on_random_words() {
    check("decode total", 20000, |rng| {
        let w = rng.next_u32();
        let _ = decode(w); // must return Ok or Err, never panic
    });
}

#[test]
fn prop_macro_mac_equals_naive_reference() {
    check("macro MAC", 60, |rng| {
        let mode = if rng.bool(0.5) { Mode::X } else { Mode::Y };
        let max_rows = mode.wordlines();
        let rows = 32 * rng.range(1, max_rows / 32 + 1);
        let cols = rng.range(1, mode.sense_amps() + 1);
        let ternary = rng.bool(0.3);
        let w: Vec<Vec<i8>> = (0..rows)
            .map(|_| {
                (0..cols)
                    .map(|_| if ternary && rng.bool(0.15) { 0 } else { rng.pm1() })
                    .collect()
            })
            .collect();
        let th: Vec<i32> = (0..cols).map(|_| rng.range(0, 15) as i32 - 7).collect();
        let x: Vec<u8> = (0..rows).map(|_| rng.bool(0.5) as u8).collect();

        let mut m = CimMacro::new();
        m.cfg.mode = mode;
        m.cfg.window_words = (rows / 32) as u8;
        if ternary {
            let img = weight_map::WeightImage::from_layer(mode, rows, cols, |r, c| w[r][c], &th);
            m.load_image(&img).unwrap();
        } else {
            // Binary (±1) layers go through the packed-plane load path —
            // the same planes the fsim kernels use — so `load_packed` is
            // exercised across random modes and shapes too.
            use cimrv::model::kws::LayerSpec;
            use cimrv::model::reference::PackedLayer;
            let spec = LayerSpec {
                c_in: rows,
                c_out: cols,
                kernel: 1,
                pooled: false,
                binarized: true,
                weights: (0..rows * cols).map(|i| w[i / cols][i % cols]).collect(),
                thresholds: th.clone(),
            };
            m.load_packed(&PackedLayer::from_spec(&spec), 0, 0).unwrap();
        }
        for j in 0..rows / 32 {
            let mut word = 0u32;
            for b in 0..32 {
                if x[j * 32 + b] == 1 {
                    word |= 1 << b;
                }
            }
            m.shift_in(word);
        }
        m.fire();
        for c in 0..cols {
            let want: i32 = (0..rows).filter(|&r| x[r] == 1).map(|r| w[r][c] as i32).sum();
            assert_eq!(m.raw_sum(c), want, "col {c} ({mode:?}, rows {rows})");
            let bit = (m.latch_word(c / 32) >> (c % 32)) & 1 == 1;
            assert_eq!(bit, want > th[c], "latch col {c}");
        }
    });
}

#[test]
fn prop_plan_invariants() {
    // For random Table-II-shaped models: streams fit weight-SRAM halves,
    // DRAM streams are disjoint, window fits the input buffer.
    use cimrv::dataflow::KwsPlan;
    use cimrv::model::kws::LayerSpec;
    use cimrv::model::KwsModel;
    check("plan invariants", 200, |rng| {
        let depth = rng.range(2, 8);
        let mut layers = Vec::new();
        let mut ci = 32 * rng.range(1, 5);
        let first_c = ci;
        for d in 0..depth {
            let last = d == depth - 1;
            let co = if last { 12 } else { 32 * rng.range(1, 9) };
            if 3 * ci > 1024 {
                return; // config invalid by construction; skip case
            }
            layers.push(LayerSpec {
                c_in: ci,
                c_out: co,
                kernel: 3,
                pooled: !last,
                binarized: !last,
                weights: vec![1; 3 * ci * co],
                thresholds: if last { vec![] } else { vec![0; co] },
            });
            ci = co;
        }
        let t = 1 << rng.range(5, 9); // 32..256 frames
        if t >> (depth - 1) < 2 {
            return;
        }
        let m = KwsModel {
            audio_len: 16000,
            t,
            c: first_c,
            n_classes: 12,
            fusion_split: depth - 1,
            layers,
            bn_gamma: vec![1.0; first_c],
            bn_beta: vec![0.0; first_c],
            bn_mean: vec![0.0; first_c],
            bn_var: vec![1.0; first_c],
            pre_thr: vec![0; first_c],
            pre_dir: vec![1; first_c],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        };
        let Ok(p) = KwsPlan::new(&m) else { return };
        let mut prev_end = 0u32;
        let mut t_cur = t;
        for lp in &p.layers {
            assert!(lp.window_words <= 32);
            assert!(lp.stream_bytes() <= 0x8000);
            assert!(lp.dram_offset >= prev_end);
            prev_end = lp.dram_offset + lp.stream_bytes();
            assert_eq!(lp.t_in, t_cur);
            t_cur = lp.t_out;
            assert_eq!(lp.t_out, if lp.pooled { lp.t_in / 2 } else { lp.t_in });
        }
    });
}

#[test]
fn prop_pooled_conv_commutes_with_reference() {
    // Host reference: fused pool == unfused conv then pairwise OR.
    use cimrv::model::kws::LayerSpec;
    use cimrv::model::reference::{conv_layer, BitMap};
    check("pool commutes", 150, |rng| {
        let t = 2 * rng.range(2, 20);
        let ci = 8 * rng.range(1, 9);
        let co = rng.range(1, 40);
        let mut rng2 = Rng::new(rng.next_u64());
        let layer = LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled: true,
            binarized: true,
            weights: (0..3 * ci * co).map(|_| rng2.pm1()).collect(),
            thresholds: (0..co).map(|_| rng2.range(0, 9) as i32 - 4).collect(),
        };
        let mut x = BitMap::zero(t, ci);
        for r in 0..t {
            for c in 0..ci {
                if rng2.bool(0.5) {
                    x.set(r, c);
                }
            }
        }
        let pooled = conv_layer(&x, &layer);
        let mut twin = layer.clone();
        twin.pooled = false;
        let unpooled = conv_layer(&x, &twin);
        for ot in 0..pooled.t {
            for c in 0..co {
                assert_eq!(
                    pooled.get(ot, c),
                    unpooled.get(2 * ot, c) || unpooled.get(2 * ot + 1, c)
                );
            }
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    use cimrv::util::json::Json;
    fn rand_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.range(0, 1 << 20) as f64) - (1 << 19) as f64),
            3 => Json::Str(format!("s{}-\"q\"\\n{}", rng.range(0, 100), rng.range(0, 10))),
            4 => Json::Arr((0..rng.range(0, 5)).map(|_| rand_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(0, 5))
                    .map(|i| (format!("k{i}"), rand_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", 500, |rng| {
        let j = rand_json(rng, 3);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("{s}: {e}"));
        assert_eq!(back, j, "{s}");
    });
}

#[test]
fn prop_random_models_iss_bit_exact_vs_reference() {
    // The strongest end-to-end property: for random small binary CNNs and
    // random audio, the compiled program on the cycle-level SoC produces
    // bit-identical logits to the host reference, at a random opt level.
    use cimrv::baselines::OptLevel;
    use cimrv::compiler::build_kws_program;
    use cimrv::mem::dram::DramConfig;
    use cimrv::model::kws::{fold_bn, LayerSpec};
    use cimrv::model::{dataset, reference, KwsModel};
    use cimrv::sim::Soc;
    check("random models bit-exact", 8, |rng| {
        let depth = rng.range(2, 5);
        let mut channels = Vec::new();
        let mut ci = 32 * rng.range(1, 3);
        let c0 = ci;
        for d in 0..depth {
            let co = if d == depth - 1 { rng.range(2, 13) } else { 32 * rng.range(1, 5) };
            channels.push((ci, co));
            ci = co;
        }
        let mut wrng = Rng::new(rng.next_u64());
        let n = channels.len();
        let layers: Vec<LayerSpec> = channels
            .iter()
            .enumerate()
            .map(|(i, &(ci, co))| {
                let last = i == n - 1;
                LayerSpec {
                    c_in: ci,
                    c_out: co,
                    kernel: 3,
                    pooled: !last,
                    binarized: !last,
                    weights: (0..3 * ci * co).map(|_| wrng.pm1()).collect(),
                    thresholds: if last {
                        vec![]
                    } else {
                        (0..co).map(|_| wrng.range(0, 11) as i32 - 5).collect()
                    },
                }
            })
            .collect();
        let gamma = vec![1.0; c0];
        let beta = vec![0.3; c0];
        let mean = vec![22_000.0; c0];
        let var = vec![5.0e8; c0];
        let (pre_thr, pre_dir) = fold_bn(&gamma, &beta, &mean, &var);
        let model = KwsModel {
            audio_len: 16000,
            t: 128,
            c: c0,
            n_classes: channels[n - 1].1,
            fusion_split: n - 1,
            layers,
            bn_gamma: gamma,
            bn_beta: beta,
            bn_mean: mean,
            bn_var: var,
            pre_thr,
            pre_dir,
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        };
        let opts = cimrv::baselines::OptLevel::ladder();
        let (_, opt): (&str, OptLevel) = opts[rng.range(0, 4)];
        let audio = dataset::synth_utterance(rng.range(0, 12), rng.next_u64(), 16000, 0.3);
        let want = reference::infer(&model, &audio);
        let prog = build_kws_program(&model, opt).unwrap();
        let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
        let got = soc.infer(&audio).unwrap();
        assert_eq!(got.logits, want, "depth {depth}, opt {opt}");
    });
}
