//! THE cross-check suite: every execution layer against the exported
//! golden logits.
//!
//! Two tiers:
//!
//! * **Artifact-backed golden logits** (always run on a fresh checkout):
//!   the checked-in `rust/testdata/artifacts` set carries logits computed
//!   by the *Python/JAX reference path* (`make_testdata.py`, independent
//!   implementation, float pipeline). The Rust host reference, the
//!   cycle-level ISS, the functional simulator, and the sharded engines
//!   must all reproduce them with `==` — the three-layer bit-exactness
//!   claim, minus the PJRT runtime.
//! * **PJRT HLO executables** (need a full `make artifacts` export with
//!   `model.hlo.txt`): the AOT-lowered JAX+Pallas model executed through
//!   PJRT. Gated on `GoldenModel::available` so the testdata set — which
//!   intentionally ships logits instead of HLO — does not fail them.

use cimrv::baselines::OptLevel;
use cimrv::compiler::{build_kws_program, build_kws_program_sharded};
use cimrv::dataflow::shard::ShardPlan;
use cimrv::fsim::FastSim;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, reference, KwsModel};
use cimrv::runtime::GoldenModel;
use cimrv::sim::Soc;
use cimrv::util::io::artifacts_dir;

/// Any artifact set (checked-in testdata or a full export); skip only on
/// a broken checkout.
fn artifacts() -> Option<std::path::PathBuf> {
    match artifacts_dir() {
        Ok(d) => Some(d),
        Err(e) => {
            eprintln!("skipping: artifacts not found (run `make artifacts`): {e}");
            None
        }
    }
}

/// The PJRT tiers additionally need the HLO text on disk.
fn pjrt_artifacts() -> Option<std::path::PathBuf> {
    let dir = artifacts()?;
    if GoldenModel::available(&dir) {
        Some(dir)
    } else {
        eprintln!(
            "skipping PJRT tier: {} has no HLO executable (the checked-in testdata set \
             ships golden logits instead; run `make artifacts` for the full export)",
            dir.display()
        );
        None
    }
}

#[test]
fn host_reference_matches_python_golden_logits() {
    // Rust integer pipeline vs the JAX float pipeline, bit for bit.
    let Some(dir) = artifacts() else { return };
    let m = KwsModel::load(&dir).unwrap();
    let tv = dataset::Dataset::load_testvec(&dir, m.audio_len, m.n_classes).unwrap();
    assert!(tv.len() >= 3, "golden testvec set too small");
    for i in 0..tv.len() {
        let got = reference::infer(&m, tv.utterance(i));
        assert_eq!(got.as_slice(), tv.golden_logits(i).unwrap(), "utterance {i}");
    }
}

#[test]
fn iss_matches_python_golden_logits() {
    // The full compiled RV32IM+CIM program on the cycle-level SoC.
    let Some(dir) = artifacts() else { return };
    let m = KwsModel::load(&dir).unwrap();
    let tv = dataset::Dataset::load_testvec(&dir, m.audio_len, m.n_classes).unwrap();
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
    for i in 0..tv.len().min(2) {
        let r = soc.infer(tv.utterance(i)).unwrap();
        assert_eq!(r.logits.as_slice(), tv.golden_logits(i).unwrap(), "utterance {i}");
    }
}

#[test]
fn fsim_and_sharded_engines_match_python_golden_logits() {
    // The functional simulator — unsharded, auto-sharded from a 2-macro
    // image, and 3-way uneven-split threaded — against the same goldens.
    let Some(dir) = artifacts() else { return };
    let m = KwsModel::load(&dir).unwrap();
    let tv = dataset::Dataset::load_testvec(&dir, m.audio_len, m.n_classes).unwrap();
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let fast = FastSim::new(prog.clone(), DramConfig::default()).unwrap();
    let sharded2 = FastSim::new(
        build_kws_program_sharded(&m, OptLevel::FULL, 2).unwrap(),
        DramConfig::default(),
    )
    .unwrap();
    let plan = ShardPlan::even(&prog.plan, 3).unwrap();
    let sharded3 = FastSim::new(prog, DramConfig::default())
        .unwrap()
        .with_shard_plan(&plan, true)
        .unwrap();
    for i in 0..tv.len() {
        let golden = tv.golden_logits(i).unwrap();
        assert_eq!(fast.infer(tv.utterance(i)).logits.as_slice(), golden, "fsim {i}");
        assert_eq!(sharded2.infer(tv.utterance(i)).logits.as_slice(), golden, "2-macro {i}");
        assert_eq!(sharded3.infer(tv.utterance(i)).logits.as_slice(), golden, "3-shard {i}");
    }
}

#[test]
fn golden_pjrt_matches_host_reference_on_testvecs() {
    let Some(dir) = pjrt_artifacts() else { return };
    let m = KwsModel::load(&dir).unwrap();
    let golden = GoldenModel::load(&dir).unwrap();
    let tv = dataset::Dataset::load_testvec(&dir, m.audio_len, m.n_classes).unwrap();
    for i in 0..tv.len().min(8) {
        let audio = tv.utterance(i);
        let g = golden.infer(audio).unwrap();
        // vs the exported JAX logits (same path, round-tripped through
        // HLO text + PJRT) ...
        assert_eq!(g.as_slice(), tv.golden_logits(i).unwrap(), "PJRT vs export {i}");
        // ... and vs the Rust host reference.
        assert_eq!(g, reference::infer(&m, audio), "PJRT vs host ref {i}");
    }
}

#[test]
fn full_stack_iss_vs_pjrt_bit_exact() {
    let Some(dir) = pjrt_artifacts() else { return };
    let m = KwsModel::load(&dir).unwrap();
    let golden = GoldenModel::load(&dir).unwrap();
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
    for (label, seed) in [(0usize, 1u64), (5, 2), (11, 3)] {
        let audio = dataset::synth_utterance(label, seed, m.audio_len, 0.37);
        let iss = soc.infer(&audio).unwrap();
        let pjrt = golden.infer(&audio).unwrap();
        assert_eq!(
            iss.logits, pjrt,
            "cycle-level ISS vs AOT JAX+Pallas mismatch (label {label})"
        );
    }
}
