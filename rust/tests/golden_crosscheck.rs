//! THE three-layer cross-check: the cycle-level Rust SoC (L3) running the
//! compiled RV32IM+CIM program must be bit-exact against the AOT-lowered
//! JAX+Pallas model (L2/L1) executed through PJRT — the same weights, the
//! same audio, logits compared with `==`.

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, reference, KwsModel};
use cimrv::runtime::GoldenModel;
use cimrv::sim::Soc;
use cimrv::util::io::artifacts_dir;

/// The cross-checks need the AOT artifacts; skip (don't fail) on a fresh
/// checkout where `make artifacts` has not run.
fn artifacts() -> Option<std::path::PathBuf> {
    match artifacts_dir() {
        Ok(d) => Some(d),
        Err(e) => {
            eprintln!("skipping: artifacts not found (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn golden_pjrt_matches_host_reference_on_testvecs() {
    let Some(dir) = artifacts() else { return };
    let m = KwsModel::load(&dir).unwrap();
    let golden = GoldenModel::load(&dir).unwrap();
    let tv = dataset::Dataset::load_testvec(&dir, m.audio_len, m.n_classes).unwrap();
    for i in 0..tv.len().min(8) {
        let audio = tv.utterance(i);
        let g = golden.infer(audio).unwrap();
        // vs the exported JAX logits (same path, round-tripped through
        // HLO text + PJRT) ...
        assert_eq!(g.as_slice(), tv.golden_logits(i).unwrap(), "PJRT vs export {i}");
        // ... and vs the Rust host reference.
        assert_eq!(g, reference::infer(&m, audio), "PJRT vs host ref {i}");
    }
}

#[test]
fn full_stack_iss_vs_pjrt_bit_exact() {
    let Some(dir) = artifacts() else { return };
    let m = KwsModel::load(&dir).unwrap();
    let golden = GoldenModel::load(&dir).unwrap();
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
    for (label, seed) in [(0usize, 1u64), (5, 2), (11, 3)] {
        let audio = dataset::synth_utterance(label, seed, m.audio_len, 0.37);
        let iss = soc.infer(&audio).unwrap();
        let pjrt = golden.infer(&audio).unwrap();
        assert_eq!(
            iss.logits, pjrt,
            "cycle-level ISS vs AOT JAX+Pallas mismatch (label {label})"
        );
    }
}
