//! Fused-schedule parity suite: the resident fused program (`--opt
//! fused` — co-resident sign planes, conv/max-pool drain pipelining,
//! one-time weight setup) must be **bit-identical** to the unfused
//! ladder — on both engines, at every shard count, on pooled and
//! unpooled layers, under variation replay (sigma > 0), and through the
//! input-channel-axis fallback used when a fused group cannot co-reside.
//! No artifacts required — runs on synthetic models.

use cimrv::baselines::OptLevel;
use cimrv::compiler::{
    build_kws_program, build_kws_program_input_sharded, build_kws_program_sharded,
};
use cimrv::fsim::{latency, FastSim};
use cimrv::mem::dram::DramConfig;
use cimrv::model::kws::LayerSpec;
use cimrv::model::{dataset, reference, KwsModel};
use cimrv::robustness::VariationParams;
use cimrv::sim::Soc;

/// A model with an unpooled mid layer (96 -> 64, no max-pool), so the
/// fused drain path covers both the pooling-overlap schedule and the
/// plain store-through drain in one program.
fn mixed_model(seed: u64) -> KwsModel {
    use cimrv::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
        c_in: ci,
        c_out: co,
        kernel: 3,
        pooled,
        binarized,
        weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
        thresholds: if binarized {
            (0..co).map(|_| rng.range(0, 9) as i32 - 4).collect()
        } else {
            vec![]
        },
    };
    let layers = vec![
        mk(64, 96, true, true),
        mk(96, 64, false, true), // unpooled binarized layer
        mk(64, 32, true, true),
        mk(32, 12, false, false),
    ];
    let (pre_thr, pre_dir) =
        cimrv::model::kws::fold_bn(&[1.0; 64], &[0.5; 64], &[20000.0; 64], &[4.0e8; 64]);
    KwsModel {
        audio_len: 16000,
        t: 128,
        c: 64,
        n_classes: 12,
        fusion_split: 2,
        layers,
        bn_gamma: vec![1.0; 64],
        bn_beta: vec![0.5; 64],
        bn_mean: vec![20000.0; 64],
        bn_var: vec![4.0e8; 64],
        pre_thr,
        pre_dir,
        trained: false,
        artifacts_dir: std::path::PathBuf::new(),
    }
}

#[test]
fn fused_cycle_engine_bit_identical_across_shard_counts_and_reuse() {
    // The fused chip vs the host reference, for a pooled-only model and a
    // pooled/unpooled mix, at 1..=4 macros — and a *second* inference on
    // the same SoC, which is the whole point of residency: the weights
    // stay programmed, only the audio changes.
    for (tag, model, shards) in [
        ("synthetic", KwsModel::synthetic(11), vec![1usize, 2, 4]),
        ("mixed", mixed_model(3), vec![1usize, 3]),
    ] {
        let a0 = dataset::synth_utterance(2, 6, model.audio_len, 0.37);
        let a1 = dataset::synth_utterance(9, 41, model.audio_len, 0.37);
        let want0 = reference::infer(&model, &a0);
        let want1 = reference::infer(&model, &a1);
        for n in shards {
            let prog = build_kws_program_sharded(&model, OptLevel::FUSED, n).unwrap();
            assert!(prog.entry > 0, "{tag} n={n}: fused programs carry a setup section");
            let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
            let r0 = soc.infer(&a0).unwrap();
            let r1 = soc.infer(&a1).unwrap();
            assert_eq!(r0.logits, want0, "{tag} n={n}: first fused inference");
            assert_eq!(r1.logits, want1, "{tag} n={n}: reused resident weights");
            assert_eq!(r0.shard_fires.len(), n, "{tag} n={n}");
        }
    }
}

#[test]
fn fused_streamed_fallback_matches_full_on_both_engines() {
    // synthetic_wide's windows cannot all co-reside in one macro's
    // wordlines, so the fusion planner keeps only a prefix resident and
    // streams the rest per inference — values must be untouched either
    // way, on the cycle engine and the functional simulator.
    let model = KwsModel::synthetic_wide(17);
    let audio = dataset::synth_utterance(4, 13, model.audio_len, 0.37);
    let full = build_kws_program(&model, OptLevel::FULL).unwrap();
    let fused = build_kws_program(&model, OptLevel::FUSED).unwrap();
    assert!(fused.entry > 0);
    let want = Soc::new(full.clone(), DramConfig::default()).unwrap().infer(&audio).unwrap();
    let got = Soc::new(fused.clone(), DramConfig::default()).unwrap().infer(&audio).unwrap();
    assert_eq!(got.logits, want.logits, "partially-resident fused schedule changed values");
    let f_full = FastSim::new(full, DramConfig::default()).unwrap().infer(&audio);
    let f_fused = FastSim::new(fused, DramConfig::default()).unwrap().infer(&audio);
    assert_eq!(f_fused.logits, want.logits, "fsim fused diverged from cycle engine");
    assert_eq!(f_full.logits, want.logits, "fsim full diverged from cycle engine");
}

#[test]
fn fused_fsim_matches_cycle_engine_at_every_ladder_rung() {
    // Cross-engine parity over the whole 5-rung ladder (the fused rung
    // included), 2-macro program: the functional simulator must serve
    // exactly the bits the fused silicon produces.
    let model = mixed_model(5);
    let audio = dataset::synth_utterance(7, 3, model.audio_len, 0.37);
    for (name, opt) in OptLevel::ladder() {
        let prog = build_kws_program_sharded(&model, opt, 2).unwrap();
        let want = Soc::new(prog.clone(), DramConfig::default()).unwrap().infer(&audio).unwrap();
        let got = FastSim::new(prog, DramConfig::default()).unwrap().infer(&audio);
        assert_eq!(got.logits, want.logits, "{name}");
        assert_eq!(got.shard_fires, want.shard_fires, "{name}");
    }
}

#[test]
fn fused_variation_replay_parity_sigma_nonzero() {
    // Variation replay on the fused program: the disturbed fast path must
    // reproduce the disturbed fused chip bit for bit — and because the
    // fused schedule preserves the fire walk (same layers, same rows,
    // same order), the disturbed logits must equal the FULL ladder's too.
    let model = KwsModel::synthetic(42);
    let audio = dataset::synth_utterance(3, 7, model.audio_len, 0.37);
    let configs = [
        VariationParams { sigma: 0.3, nl_alpha: 0.1, symmetric: true, ..Default::default() },
        VariationParams { sigma: 0.5, nl_alpha: 0.2, symmetric: true, mismatch: 0.4, seed: 99 },
    ];
    for n in [1usize, 2] {
        let fused = build_kws_program_sharded(&model, OptLevel::FUSED, n).unwrap();
        let full = build_kws_program_sharded(&model, OptLevel::FULL, n).unwrap();
        for params in &configs {
            assert!(params.sigma > 0.0);
            let want = Soc::new(fused.clone(), DramConfig::default())
                .unwrap()
                .with_variation(params.model())
                .infer(&audio)
                .unwrap();
            let got = FastSim::new(fused.clone(), DramConfig::default())
                .unwrap()
                .infer_disturbed(&audio, params);
            assert_eq!(got.logits, want.logits, "n={n} {params:?}: disturbed fsim diverged");
            let full_r = Soc::new(full.clone(), DramConfig::default())
                .unwrap()
                .with_variation(params.model())
                .infer(&audio)
                .unwrap();
            assert_eq!(
                want.logits, full_r.logits,
                "n={n} {params:?}: fused fire walk drew a different noise stream"
            );
        }
    }
}

#[test]
fn fused_latency_estimate_beats_full() {
    // The analytical walker's fused schedule: strictly fewer cycles and
    // strictly less DRAM traffic per steady-state inference than the full
    // unfused ladder (weights resident, audio fetch only).
    for model in [KwsModel::synthetic(11), mixed_model(3)] {
        let full = build_kws_program(&model, OptLevel::FULL).unwrap();
        let fused = build_kws_program(&model, OptLevel::FUSED).unwrap();
        let e_full = latency::estimate(&full, &DramConfig::default());
        let e_fused = latency::estimate(&fused, &DramConfig::default());
        assert!(
            e_fused.cycles < e_full.cycles,
            "fused {} !< full {} cycles",
            e_fused.cycles,
            e_full.cycles
        );
        assert!(
            e_fused.counts.dram_bytes < e_full.counts.dram_bytes,
            "fused {} !< full {} DRAM bytes",
            e_fused.counts.dram_bytes,
            e_full.counts.dram_bytes
        );
    }
}

#[test]
fn input_axis_fallback_bit_identical_on_both_engines() {
    // The input-channel-axis shard split (the fallback when a fused
    // group's window exceeds one macro's wordlines): raw partial sums
    // merged by the core must reproduce the unsharded bits exactly, on
    // the cycle engine and through the fsim's auto-routed merge path.
    let model = KwsModel::synthetic(5);
    let audio = dataset::synth_utterance(6, 17, model.audio_len, 0.37);
    let want = reference::infer(&model, &audio);
    for n in 1..=3usize {
        let prog = build_kws_program_input_sharded(&model, OptLevel::FULL, n).unwrap();
        let r = Soc::new(prog.clone(), DramConfig::default()).unwrap().infer(&audio).unwrap();
        assert_eq!(r.logits, want, "cycle input-axis n={n}");
        let f = FastSim::new(prog, DramConfig::default()).unwrap().infer(&audio);
        assert_eq!(f.logits, want, "fsim input-axis n={n}");
        assert_eq!(f.predicted, r.predicted, "n={n}");
    }
    // Wide model (several latch words per row) through the fsim merge.
    let wide = KwsModel::synthetic_wide(17);
    let waudio = dataset::synth_utterance(1, 29, wide.audio_len, 0.37);
    let wwant = reference::infer(&wide, &waudio);
    let prog = build_kws_program_input_sharded(&wide, OptLevel::FULL, 2).unwrap();
    let f = FastSim::new(prog, DramConfig::default()).unwrap().infer(&waudio);
    assert_eq!(f.logits, wwant, "fsim input-axis wide");
    assert_eq!(f.shard_fires.len(), 2);
    assert!(f.shard_fires.iter().all(|&x| x > 0), "both input slices fire");
}

#[test]
fn fused_rejected_where_unsupported() {
    // The input-axis cycle builder cannot host tensor-level residency for
    // sliced windows; asking for it is a loud error, not silent fallback.
    let model = KwsModel::synthetic(5);
    assert!(build_kws_program_input_sharded(&model, OptLevel::FUSED, 2).is_err());
}
