//! Packed-vs-scalar kernel parity: the XNOR-popcount engine
//! (`PackedLayer` + `conv_*_packed`) must be bit-identical to the scalar
//! i8 oracle on randomized layers — odd and even `t`, `c_in` that is not
//! a word multiple, pooled and unpooled, kernels 1/3/5 (edge-padding rows
//! included: every position of small maps is checked, so the zero-padded
//! windows at t=0 and t=t-1 are always exercised). No artifacts needed.

use cimrv::model::kernel::{
    conv_layer_lanes, conv_layer_lanes_batch, conv_sums_lanes, engine_kind,
    final_layer_gap_lanes, final_layer_gap_lanes_batch, LaneLayer,
};
use cimrv::model::kws::LayerSpec;
use cimrv::model::reference::{
    conv_layer, conv_layer_packed, conv_layer_packed_batch, conv_sums, conv_sums_packed,
    conv_sums_packed_batch, final_layer_gap, final_layer_gap_packed, final_layer_gap_packed_batch,
    BitMap, PackedLayer,
};
use cimrv::util::proptest::check;
use cimrv::util::rng::Rng;

fn random_layer(rng: &mut Rng, binarized: bool) -> LayerSpec {
    let kernel = [1, 3, 5][rng.range(0, 3)];
    // Deliberately spans word-unaligned widths (not multiples of 32).
    let c_in = rng.range(1, 100);
    let c_out = rng.range(1, 40);
    LayerSpec {
        c_in,
        c_out,
        kernel,
        pooled: binarized && rng.bool(0.5),
        binarized,
        weights: (0..kernel * c_in * c_out).map(|_| rng.pm1()).collect(),
        thresholds: if binarized {
            (0..c_out).map(|_| rng.range(0, 9) as i32 - 4).collect()
        } else {
            vec![]
        },
    }
}

fn random_bits(rng: &mut Rng, t: usize, c: usize) -> BitMap {
    let mut x = BitMap::zero(t, c);
    let density = rng.f64();
    for r in 0..t {
        for ch in 0..c {
            if rng.bool(density) {
                x.set(r, ch);
            }
        }
    }
    x
}

#[test]
fn prop_packed_conv_sums_match_scalar() {
    check("packed conv sums", 120, |rng| {
        let layer = random_layer(rng, true);
        let t = rng.range(1, 16); // odd and even, incl t=1 (all-padding windows)
        let x = random_bits(rng, t, layer.c_in);
        let packed = PackedLayer::from_spec(&layer);
        for pos in 0..t {
            assert_eq!(
                conv_sums_packed(&x, &packed, pos),
                conv_sums(&x, &layer, pos),
                "k {} c_in {} c_out {} t {t} pos {pos}",
                layer.kernel,
                layer.c_in,
                layer.c_out
            );
        }
    });
}

#[test]
fn prop_packed_conv_layer_matches_scalar() {
    check("packed conv layer", 120, |rng| {
        let layer = random_layer(rng, true);
        // Odd t exercises the dropped pooling tail.
        let t = rng.range(2, 24);
        let x = random_bits(rng, t, layer.c_in);
        let packed = PackedLayer::from_spec(&layer);
        assert_eq!(
            conv_layer_packed(&x, &packed),
            conv_layer(&x, &layer),
            "k {} c_in {} c_out {} pooled {} t {t}",
            layer.kernel,
            layer.c_in,
            layer.c_out,
            layer.pooled
        );
    });
}

#[test]
fn prop_packed_gap_matches_scalar() {
    check("packed GAP", 100, |rng| {
        let layer = random_layer(rng, false);
        let t = rng.range(1, 20);
        let x = random_bits(rng, t, layer.c_in);
        let packed = PackedLayer::from_spec(&layer);
        // f32 equality is exact here: both sides divide the same integer
        // sums by the same t.
        assert_eq!(
            final_layer_gap_packed(&x, &packed),
            final_layer_gap(&x, &layer),
            "k {} c_in {} c_out {} t {t}",
            layer.kernel,
            layer.c_in,
            layer.c_out
        );
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    check("pack/unpack roundtrip", 150, |rng| {
        let layer = random_layer(rng, rng.bool(0.5));
        let packed = PackedLayer::from_spec(&layer);
        // u64 window words: half the u32 stream-word trip count.
        assert_eq!(packed.plane_words, layer.rows().div_ceil(64));
        assert_eq!(packed.stream_words(), layer.rows().div_ceil(32));
        // Plane padding bits above rows() stay clear (kernel invariant).
        let tail = layer.rows() % 64;
        if tail != 0 {
            for co in 0..layer.c_out {
                assert_eq!(packed.plane(co)[packed.plane_words - 1] >> tail, 0, "co {co}");
            }
        }
        let back = packed.to_spec();
        assert_eq!(back.weights, layer.weights);
        assert_eq!(back.thresholds, layer.thresholds);
    });
}

#[test]
fn prop_stream_words_match_legacy_u32_packing() {
    // The DRAM sign-stream layout is unchanged by the u64 widening: the
    // u32 view of every plane must equal packing the weights 32 at a
    // time (what the compiler emits and the macro's weight port holds).
    check("u64 planes vs u32 stream", 120, |rng| {
        let layer = random_layer(rng, rng.bool(0.5));
        let packed = PackedLayer::from_spec(&layer);
        let rows = layer.rows();
        for co in 0..layer.c_out {
            for wj in 0..packed.stream_words() {
                let mut want = 0u32;
                for b in 0..32 {
                    let r = wj * 32 + b;
                    if r < rows && layer.weight(r, co) > 0 {
                        want |= 1 << b;
                    }
                }
                assert_eq!(packed.stream_word(co, wj), want, "co {co} wj {wj}");
            }
        }
    });
}

#[test]
fn prop_batched_conv_layer_matches_per_utterance() {
    check("batched conv layer", 60, |rng| {
        let layer = random_layer(rng, true);
        let t = rng.range(2, 16);
        let n = rng.range(1, 7);
        let xs: Vec<BitMap> = (0..n).map(|_| random_bits(rng, t, layer.c_in)).collect();
        let packed = PackedLayer::from_spec(&layer);
        let batch = conv_layer_packed_batch(&xs, &packed);
        assert_eq!(batch.len(), n);
        for (u, x) in xs.iter().enumerate() {
            assert_eq!(
                batch[u],
                conv_layer_packed(x, &packed),
                "k {} c_in {} c_out {} pooled {} t {t} u {u}/{n}",
                layer.kernel,
                layer.c_in,
                layer.c_out,
                layer.pooled
            );
        }
    });
}

#[test]
fn prop_batched_sums_and_gap_match_per_utterance() {
    check("batched sums + GAP", 60, |rng| {
        let conv = random_layer(rng, true);
        let last = random_layer(rng, false);
        let t = rng.range(1, 12);
        let n = rng.range(1, 6);
        let packed_conv = PackedLayer::from_spec(&conv);
        let xs: Vec<BitMap> = (0..n).map(|_| random_bits(rng, t, conv.c_in)).collect();
        for pos in 0..t {
            let batch = conv_sums_packed_batch(&xs, &packed_conv, pos);
            for (u, x) in xs.iter().enumerate() {
                assert_eq!(batch[u], conv_sums_packed(x, &packed_conv, pos), "pos {pos} u {u}");
            }
        }
        let packed_last = PackedLayer::from_spec(&last);
        let ys: Vec<BitMap> = (0..n).map(|_| random_bits(rng, t, last.c_in)).collect();
        let batch = final_layer_gap_packed_batch(&ys, &packed_last);
        for (u, y) in ys.iter().enumerate() {
            assert_eq!(batch[u], final_layer_gap_packed(y, &packed_last), "u {u}");
        }
    });
}

// --- lane-engine (SIMD + incremental windows) vs the scalar oracle ------
// These run under both cargo feature configurations: the CI matrix builds
// with and without `--features simd`, so the same assertions cover the
// portable tier and whichever SIMD tier the host dispatches to.

#[test]
fn prop_lane_conv_sums_match_scalar() {
    // Raw per-position sums: the lane engine's blocked accumulators vs
    // the i8 oracle, across ragged widths (c_in % 64 != 0 dominates the
    // 1..100 draw) and every padded edge position.
    check("lane conv sums", 120, |rng| {
        let layer = random_layer(rng, true);
        let t = rng.range(1, 16);
        let x = random_bits(rng, t, layer.c_in);
        let lanes = LaneLayer::from_packed(&PackedLayer::from_spec(&layer));
        for pos in 0..t {
            assert_eq!(
                conv_sums_lanes(&x, &lanes, pos),
                conv_sums(&x, &layer, pos),
                "engine {} k {} c_in {} c_out {} t {t} pos {pos}",
                engine_kind(),
                layer.kernel,
                layer.c_in,
                layer.c_out
            );
        }
    });
}

#[test]
fn prop_lane_conv_layer_matches_scalar() {
    check("lane conv layer", 120, |rng| {
        let layer = random_layer(rng, true);
        // Odd t exercises the dropped pooling tail.
        let t = rng.range(2, 24);
        let x = random_bits(rng, t, layer.c_in);
        let lanes = LaneLayer::from_packed(&PackedLayer::from_spec(&layer));
        assert_eq!(
            conv_layer_lanes(&x, &lanes),
            conv_layer(&x, &layer),
            "engine {} k {} c_in {} c_out {} pooled {} t {t}",
            engine_kind(),
            layer.kernel,
            layer.c_in,
            layer.c_out,
            layer.pooled
        );
    });
}

#[test]
fn prop_lane_gap_matches_scalar() {
    check("lane GAP", 100, |rng| {
        let layer = random_layer(rng, false);
        let t = rng.range(1, 20);
        let x = random_bits(rng, t, layer.c_in);
        let lanes = LaneLayer::from_packed(&PackedLayer::from_spec(&layer));
        assert_eq!(
            final_layer_gap_lanes(&x, &lanes),
            final_layer_gap(&x, &layer),
            "engine {} k {} c_in {} c_out {} t {t}",
            engine_kind(),
            layer.kernel,
            layer.c_in,
            layer.c_out
        );
    });
}

#[test]
fn prop_lane_batches_match_per_utterance() {
    // Ragged batches: every utterance shares (t, c_in) geometry but not
    // content; batch sizes 1..7 hit partial final thread chunks upstream.
    check("lane batched conv + GAP", 60, |rng| {
        let conv = random_layer(rng, true);
        let last = random_layer(rng, false);
        let t = rng.range(2, 16);
        let n = rng.range(1, 7);
        let lanes_conv = LaneLayer::from_packed(&PackedLayer::from_spec(&conv));
        let xs: Vec<BitMap> = (0..n).map(|_| random_bits(rng, t, conv.c_in)).collect();
        let batch = conv_layer_lanes_batch(&xs, &lanes_conv);
        assert_eq!(batch.len(), n);
        for (u, x) in xs.iter().enumerate() {
            assert_eq!(
                batch[u],
                conv_layer_lanes(x, &lanes_conv),
                "engine {} k {} pooled {} t {t} u {u}/{n}",
                engine_kind(),
                conv.kernel,
                conv.pooled
            );
        }
        let lanes_last = LaneLayer::from_packed(&PackedLayer::from_spec(&last));
        let ys: Vec<BitMap> = (0..n).map(|_| random_bits(rng, t, last.c_in)).collect();
        let gap = final_layer_gap_lanes_batch(&ys, &lanes_last);
        for (u, y) in ys.iter().enumerate() {
            assert_eq!(gap[u], final_layer_gap_lanes(y, &lanes_last), "u {u}");
        }
    });
}

#[test]
fn prop_lane_sharded_channel_slices_match_scalar() {
    // The sharded fsim builds LaneLayers from `slice_channels` slices —
    // slice widths not divisible by LANES leave dead lanes in the final
    // block, which must not leak into the sums.
    check("lane sharded slices", 80, |rng| {
        let layer = random_layer(rng, true);
        let t = rng.range(2, 12);
        let x = random_bits(rng, t, layer.c_in);
        let packed = PackedLayer::from_spec(&layer);
        let cut = rng.range(1, layer.c_out.max(2)); // 1..c_out-1 (or 1 when c_out == 1)
        let cut = cut.min(layer.c_out);
        let want = conv_layer(&x, &layer);
        for (c0, c1) in [(0, cut), (cut, layer.c_out)] {
            if c0 == c1 {
                continue;
            }
            let shard = LaneLayer::from_packed(&packed.slice_channels(c0, c1));
            let got = conv_layer_lanes(&x, &shard);
            // The shard's channel ch is the full layer's channel c0 + ch.
            assert_eq!(got.t, want.t);
            for r in 0..got.t {
                for ch in c0..c1 {
                    assert_eq!(
                        got.get(r, ch - c0),
                        want.get(r, ch),
                        "engine {} k {} c_out {} slice {c0}..{c1} r {r} ch {ch}",
                        engine_kind(),
                        layer.kernel,
                        layer.c_out
                    );
                }
            }
        }
    });
}

#[test]
fn packed_chain_matches_scalar_on_a_model_shaped_stack() {
    // A Table-II-shaped two-conv + GAP stack, scalar vs packed end to end,
    // with a word-unaligned middle width.
    let mut rng = Rng::new(0xBEEF);
    let mut mk = |c_in: usize, c_out: usize, pooled: bool, binarized: bool| LayerSpec {
        c_in,
        c_out,
        kernel: 3,
        pooled,
        binarized,
        weights: (0..3 * c_in * c_out).map(|_| rng.pm1()).collect(),
        thresholds: if binarized {
            (0..c_out).map(|_| rng.range(0, 9) as i32 - 4).collect()
        } else {
            vec![]
        },
    };
    let layers = [mk(64, 48, true, true), mk(48, 33, true, true), mk(33, 12, false, false)];
    let mut rng2 = Rng::new(0xF00D);
    let x0 = random_bits(&mut rng2, 21, 64); // odd t through two pools
    let mut scalar = x0.clone();
    let mut packed = x0;
    for l in &layers[..2] {
        scalar = conv_layer(&scalar, l);
        packed = conv_layer_packed(&packed, &PackedLayer::from_spec(l));
        assert_eq!(packed, scalar);
    }
    assert_eq!(
        final_layer_gap_packed(&packed, &PackedLayer::from_spec(&layers[2])),
        final_layer_gap(&scalar, &layers[2])
    );
}
