//! Integration tests over the trained artifacts: model loading, the ISS
//! vs host-reference bit-exactness on the trained model, the optimization
//! ladder, accuracy, and the coordinator.
//!
//! An artifact set is always present on a fresh checkout: the tiny
//! pre-trained set under `rust/testdata/artifacts` (generated once by
//! `python/compile/make_testdata.py`, checked in) is found automatically
//! by `util::io::artifacts_dir`, so this suite runs — rather than skips —
//! in CI. A full `make artifacts` export takes precedence when present.

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, reference, KwsModel};
use cimrv::sim::Soc;
use cimrv::util::io::artifacts_dir;

/// Load the trained artifacts, or skip the calling test: the suite must
/// pass on a fresh checkout where `make artifacts` has not run (the
/// artifact-free parity coverage lives in `backend_parity.rs`).
fn model() -> Option<KwsModel> {
    match KwsModel::load_default() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping: artifacts not found (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_matches_table2_topology() {
    let Some(m) = model() else { return };
    assert_eq!(m.layers.len(), 7, "Table II: 7 convolutions");
    assert_eq!(m.n_classes, 12, "GSCD 12 classes");
    assert_eq!(m.fusion_split, 5, "weight fusion after 5 conv+pool blocks");
    assert!(m.layers[..6].iter().all(|l| l.binarized && l.pooled));
    let last = m.layers.last().unwrap();
    assert!(!last.binarized && !last.pooled);
    assert_eq!(last.c_out, 12);
    // Weight-SRAM premise of Fig. 9.
    assert!(m.resident_bits() <= 512 * 1024);
    assert!(m.streamed_bits() > 0);
}

#[test]
fn iss_bit_exact_vs_host_reference_trained_model() {
    let Some(m) = model() else { return };
    let audio = dataset::synth_utterance(5, 11, m.audio_len, 0.37);
    let want = reference::infer(&m, &audio);
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
    let r = soc.infer(&audio).unwrap();
    assert_eq!(r.logits, want);
}

#[test]
fn ladder_monotone_on_trained_model() {
    let Some(m) = model() else { return };
    let audio = dataset::synth_utterance(2, 3, m.audio_len, 0.37);
    let mut prev_accel = u64::MAX;
    let mut logits: Option<Vec<f32>> = None;
    for (name, opt) in OptLevel::ladder() {
        let prog = build_kws_program(&m, opt).unwrap();
        let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
        let r = soc.infer(&audio).unwrap();
        assert!(
            r.phases.accelerated() < prev_accel,
            "{name}: accelerated cycles must strictly drop"
        );
        prev_accel = r.phases.accelerated();
        // Optimizations must never change values.
        if let Some(l) = &logits {
            assert_eq!(&r.logits, l, "{name} changed logits");
        }
        logits = Some(r.logits);
    }
}

#[test]
fn host_reference_matches_exported_golden_logits() {
    // The aot.py test vectors carry logits computed by the JAX reference
    // path; our Rust host reference must reproduce them bit-for-bit.
    let Some(m) = model() else { return };
    let dir = artifacts_dir().unwrap();
    let tv = dataset::Dataset::load_testvec(&dir, m.audio_len, m.n_classes).unwrap();
    // >= 3: the checked-in testdata set carries 3 golden utterances; a
    // full `make artifacts` export carries more.
    assert!(tv.len() >= 3);
    for i in 0..tv.len() {
        let got = reference::infer(&m, tv.utterance(i));
        let want = tv.golden_logits(i).unwrap();
        assert_eq!(got.as_slice(), want, "utterance {i}");
    }
}

#[test]
fn eval_accuracy_in_paper_regime() {
    // Host-reference accuracy on the exported eval set should be in the
    // paper's 94%-class regime (trained to ~96% on the synthetic corpus;
    // the assertion guards against silent weight/preprocessing skew, not
    // the exact number).
    let Some(m) = model() else { return };
    let dir = artifacts_dir().unwrap();
    let eval = dataset::Dataset::load_eval(&dir, m.audio_len, m.n_classes).unwrap();
    let mut hits = 0;
    for i in 0..eval.len() {
        let logits = reference::infer(&m, eval.utterance(i));
        if reference::argmax(&logits) == eval.labels[i] as usize {
            hits += 1;
        }
    }
    let acc = hits as f64 / eval.len() as f64;
    assert!(acc > 0.85, "accuracy collapsed: {acc}");
}

#[test]
fn iss_accuracy_matches_host_on_subset() {
    let Some(m) = model() else { return };
    let dir = artifacts_dir().unwrap();
    let eval = dataset::Dataset::load_eval(&dir, m.audio_len, m.n_classes).unwrap();
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
    for i in 0..4.min(eval.len()) {
        let r = soc.infer(eval.utterance(i)).unwrap();
        let host = reference::infer(&m, eval.utterance(i));
        assert_eq!(r.logits, host, "utterance {i}");
    }
}

#[test]
fn coordinator_end_to_end_on_trained_model() {
    use cimrv::coordinator::{Coordinator, InferenceRequest};
    let Some(m) = model() else { return };
    let mut coord = Coordinator::start(&m, OptLevel::FULL, 2).unwrap();
    let reqs: Vec<_> = (0..4)
        .map(|i| InferenceRequest {
            id: i as u64,
            audio: dataset::synth_utterance(i % 12, 50 + i as u64, m.audio_len, 0.37),
            label: Some((i % 12) as i32),
            deadline: None,
        })
        .collect();
    let resps = coord.serve_batch(reqs).unwrap();
    assert_eq!(resps.len(), 4);
    assert!(resps.iter().all(|r| r.chip_cycles > 0));
    coord.shutdown();
}

#[test]
fn sharded_inference_bit_exact_on_trained_model() {
    // The tentpole on the real weights: a 2-macro sharded program (cycle
    // engine) and a 3-way sharded fast backend both reproduce the
    // trained model's logits bit for bit.
    use cimrv::compiler::build_kws_program_sharded;
    use cimrv::dataflow::shard::ShardPlan;
    use cimrv::fsim::FastSim;
    let Some(m) = model() else { return };
    let audio = dataset::synth_utterance(7, 21, m.audio_len, 0.37);
    let want = reference::infer(&m, &audio);

    let prog = build_kws_program_sharded(&m, OptLevel::FULL, 2).unwrap();
    let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
    let r = soc.infer(&audio).unwrap();
    assert_eq!(r.logits, want, "2-macro cycle engine");
    assert_eq!(r.shard_fires.len(), 2);

    let plan = ShardPlan::even(&prog.plan, 3).unwrap();
    let fast = FastSim::new(build_kws_program(&m, OptLevel::FULL).unwrap(), DramConfig::default())
        .unwrap()
        .with_shard_plan(&plan, true)
        .unwrap();
    assert_eq!(fast.infer(&audio).logits, want, "3-way threaded fast backend");
}

#[test]
fn energy_efficiency_in_calibrated_range() {
    // A full-opt run's measured end-to-end TOPS/W sits far below the
    // 3707.84 peak: the macro fires on ~0.5% of cycles (preprocessing and
    // weight loading dominate the KWS inference), which is exactly why
    // the paper quotes the peak number. The assertion pins the envelope:
    // strictly positive, strictly below peak.
    let Some(m) = model() else { return };
    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
    let audio = dataset::synth_utterance(1, 2, m.audio_len, 0.37);
    let r = soc.infer(&audio).unwrap();
    let ee = r.energy.tops_per_w();
    assert!(ee > 0.5 && ee < 3707.84, "measured EE {ee}");
}

#[test]
fn variation_injection_degrades_gracefully() {
    // Symmetric mapping at moderate sigma should usually preserve the
    // prediction; single-ended with strong NL should visibly disturb raw
    // sums (the §II-B robustness argument). We assert on logits change,
    // not accuracy (one utterance).
    use cimrv::cim::VariationModel;
    let Some(m) = model() else { return };
    let audio = dataset::synth_utterance(4, 8, m.audio_len, 0.37);
    let clean = reference::infer(&m, &audio);

    let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
    let mut sym = Soc::new(prog.clone(), DramConfig::default())
        .unwrap()
        .with_variation(VariationModel::new(0.02, 0.1, true, 7));
    let r_sym = sym.infer(&audio).unwrap();

    let mut single = Soc::new(prog, DramConfig::default())
        .unwrap()
        .with_variation(VariationModel::new(0.02, 0.5, false, 7));
    let r_single = single.infer(&audio).unwrap();

    // Symmetric: logits stay close to clean (allow small drift).
    let drift_sym: f32 =
        r_sym.logits.iter().zip(&clean).map(|(a, b)| (a - b).abs()).sum();
    let drift_single: f32 =
        r_single.logits.iter().zip(&clean).map(|(a, b)| (a - b).abs()).sum();
    assert!(
        drift_sym < drift_single,
        "symmetric mapping must be more robust: {drift_sym} vs {drift_single}"
    );
}
