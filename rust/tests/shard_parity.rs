//! Multi-macro sharding parity suite: splitting a layer's output channels
//! across N macros must never change a single bit of the logits —
//! whatever the split (even, uneven, word-aligned), the engine (cycle SoC
//! with a macro bank vs functional simulator), the execution mode
//! (sequential vs one-thread-per-macro), or the optimization level.
//! No artifacts required — runs on synthetic models.

use cimrv::baselines::OptLevel;
use cimrv::compiler::{build_kws_program, build_kws_program_sharded};
use cimrv::dataflow::shard::ShardPlan;
use cimrv::fsim::{latency, FastSim};
use cimrv::mem::dram::DramConfig;
use cimrv::model::kws::LayerSpec;
use cimrv::model::reference::PackedLayer;
use cimrv::model::{dataset, reference, KwsModel};
use cimrv::sim::Soc;

/// A model with an unpooled mid layer and non-word-multiple shard loads
/// (96 = 3 latch words), so the suite covers pooled/unpooled layers and
/// splits whose per-macro word counts differ.
fn mixed_model(seed: u64) -> KwsModel {
    use cimrv::util::rng::Rng;
    let mut rng = Rng::new(seed);
    let mut mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
        c_in: ci,
        c_out: co,
        kernel: 3,
        pooled,
        binarized,
        weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
        thresholds: if binarized {
            (0..co).map(|_| rng.range(0, 9) as i32 - 4).collect()
        } else {
            vec![]
        },
    };
    let layers = vec![
        mk(64, 96, true, true),
        mk(96, 64, false, true), // unpooled binarized layer
        mk(64, 32, true, true),
        mk(32, 12, false, false),
    ];
    let (pre_thr, pre_dir) =
        cimrv::model::kws::fold_bn(&[1.0; 64], &[0.5; 64], &[20000.0; 64], &[4.0e8; 64]);
    KwsModel {
        audio_len: 16000,
        t: 128,
        c: 64,
        n_classes: 12,
        fusion_split: 2,
        layers,
        bn_gamma: vec![1.0; 64],
        bn_beta: vec![0.5; 64],
        bn_mean: vec![20000.0; 64],
        bn_var: vec![4.0e8; 64],
        pre_thr,
        pre_dir,
        trained: false,
        artifacts_dir: std::path::PathBuf::new(),
    }
}

#[test]
fn fsim_sharded_bit_identical_n_1_to_4_even_and_uneven() {
    // Channel-granular splits: 96/64/32/12-wide layers over N in 1..=4
    // hit both exact divisions and uneven remainders (e.g. 96 % 4 == 0
    // but 64 % 3 != 0 and 12 % 4 == 0 with idle macros elsewhere).
    for model in [mixed_model(3), KwsModel::synthetic(11)] {
        let prog = build_kws_program(&model, OptLevel::FULL).unwrap();
        let single = FastSim::new(prog.clone(), DramConfig::default()).unwrap();
        for n in 1..=4usize {
            let plan = ShardPlan::even(&prog.plan, n).unwrap();
            let seq = FastSim::new(prog.clone(), DramConfig::default())
                .unwrap()
                .with_shard_plan(&plan, false)
                .unwrap();
            let par = FastSim::new(prog.clone(), DramConfig::default())
                .unwrap()
                .with_shard_plan(&plan, true)
                .unwrap();
            for seed in [1u64, 9] {
                let audio =
                    dataset::synth_utterance(seed as usize % 12, seed, model.audio_len, 0.37);
                let want = single.infer(&audio);
                let s = seq.infer(&audio);
                let p = par.infer(&audio);
                assert_eq!(s.logits, want.logits, "sequential n={n} seed={seed}");
                assert_eq!(p.logits, want.logits, "parallel n={n} seed={seed}");
                assert_eq!(s.predicted, want.predicted);
                assert_eq!(p.predicted, want.predicted);
            }
        }
    }
}

#[test]
fn packed_shard_slices_match_scalar_oracle_per_shard() {
    // Every shard's packed sub-layer, unpacked back to scalar form, must
    // run the scalar kernels to exactly the full layer's channel range —
    // packed-vs-scalar parity per shard, including uneven boundaries.
    let model = mixed_model(7);
    let audio = dataset::synth_utterance(4, 2, model.audio_len, 0.37);
    let mut x = reference::preprocess(&model, &audio);
    for layer in &model.layers[..model.layers.len() - 1] {
        let packed = PackedLayer::from_spec(layer);
        for n in [2usize, 3] {
            let base = layer.c_out / n;
            let rem = layer.c_out % n;
            let mut at = 0usize;
            for m in 0..n {
                let len = base + usize::from(m < rem);
                let shard = packed.slice_channels(at, at + len);
                // Packed shard output vs scalar shard output (unpacked).
                let shard_scalar = shard.to_spec();
                let got = reference::conv_layer_packed(&x, &shard);
                let want = reference::conv_layer(&x, &shard_scalar);
                assert_eq!(got, want, "layer c_out={} shard {m}/{n}", layer.c_out);
                at += len;
            }
        }
        x = reference::conv_layer(&x, layer);
    }
}

#[test]
fn cycle_engine_sharded_logits_bit_identical_across_n() {
    // The multi-macro SoC: same audio, N in 1..=4, every logit identical
    // to the host reference and the single-macro chip, with per-shard
    // fire statistics exposed.
    let model = mixed_model(5);
    let audio = dataset::synth_utterance(2, 6, model.audio_len, 0.37);
    let want = reference::infer(&model, &audio);
    for n in 1..=4usize {
        let prog = build_kws_program_sharded(&model, OptLevel::FULL, n).unwrap();
        let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
        let r = soc.infer(&audio).unwrap();
        assert_eq!(r.logits, want, "cycle n={n}");
        assert_eq!(r.shard_fires.len(), n);
        let stats = soc.macro_stats();
        assert_eq!(stats.len(), n);
        // Owners fire once per row position of each layer they own;
        // macros left idle by the word-aligned split fire nothing.
        for (m, s) in stats.iter().enumerate() {
            let expect: u64 = prog
                .plan
                .layers
                .iter()
                .map(|lp| {
                    let owned = !prog.shards.layers[lp.index].is_empty(m);
                    if owned { lp.t_in as u64 } else { 0 }
                })
                .sum();
            assert_eq!(s.fires, expect, "macro {m} of {n}");
        }
    }
}

#[test]
fn cycle_sharding_commutes_with_every_opt_level() {
    // Sharding is orthogonal to the paper's three optimizations: at every
    // ladder rung the 2-macro program produces the single-macro logits
    // (unfused pooling passes and FM spills included).
    let model = mixed_model(9);
    let audio = dataset::synth_utterance(7, 3, model.audio_len, 0.37);
    for (name, opt) in OptLevel::ladder() {
        let single = build_kws_program(&model, opt).unwrap();
        let sharded = build_kws_program_sharded(&model, opt, 2).unwrap();
        let a = Soc::new(single, DramConfig::default()).unwrap().infer(&audio).unwrap();
        let b = Soc::new(sharded, DramConfig::default()).unwrap().infer(&audio).unwrap();
        assert_eq!(a.logits, b.logits, "{name}");
    }
}

#[test]
fn fsim_auto_shards_from_program_metadata_and_matches_cycle() {
    // A sharded image drives both engines: the SoC's macro bank and the
    // functional simulator's shard groups must agree bit for bit.
    let model = mixed_model(1);
    for n in [2usize, 3] {
        let prog = build_kws_program_sharded(&model, OptLevel::FULL, n).unwrap();
        let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
        let fast = FastSim::new(prog, DramConfig::default()).unwrap();
        for seed in [0u64, 5] {
            let audio = dataset::synth_utterance(seed as usize % 12, seed, model.audio_len, 0.37);
            let want = soc.infer(&audio).unwrap();
            let got = fast.infer(&audio);
            assert_eq!(got.logits, want.logits, "n={n} seed={seed}");
            // Per-shard fire accounting agrees between the engines.
            assert_eq!(got.shard_fires, want.shard_fires, "n={n} seed={seed}");
        }
    }
}

#[test]
fn word_aligned_and_even_plans_agree_on_values() {
    // Two different split geometries of the same program: identical bits.
    let model = mixed_model(13);
    let prog = build_kws_program(&model, OptLevel::FULL).unwrap();
    let audio = dataset::synth_utterance(8, 8, model.audio_len, 0.37);
    let base = FastSim::new(prog.clone(), DramConfig::default()).unwrap().infer(&audio);
    for n in [2usize, 4] {
        for plan in [
            ShardPlan::even(&prog.plan, n).unwrap(),
            ShardPlan::word_aligned(&prog.plan, n).unwrap(),
        ] {
            let sim = FastSim::new(prog.clone(), DramConfig::default())
                .unwrap()
                .with_shard_plan(&plan, false)
                .unwrap();
            assert_eq!(sim.infer(&audio).logits, base.logits, "n={n}");
        }
    }
}

#[test]
fn sharded_analytical_latency_tracks_the_cycle_sim() {
    // The latency walker mirrors the sharded emission instruction for
    // instruction; the bound is looser than the single-macro 5% contract
    // only to absorb DMA launch quantization across more phases.
    let model = mixed_model(2);
    let audio = dataset::synth_utterance(1, 1, model.audio_len, 0.37);
    for n in [2usize, 4] {
        let prog = build_kws_program_sharded(&model, OptLevel::FULL, n).unwrap();
        let mut soc = Soc::new(prog.clone(), DramConfig::default()).unwrap();
        let actual = soc.infer(&audio).unwrap();
        let est = latency::estimate(&prog, &DramConfig::default());
        let err = (est.cycles as f64 - actual.cycles as f64).abs() / actual.cycles as f64;
        assert!(
            err <= 0.10,
            "n={n}: analytical {} vs measured {} cycles ({:.2}% error)",
            est.cycles,
            actual.cycles,
            100.0 * err
        );
        // The overlapped multi-macro schedule only ever helps.
        let overlapped = latency::estimate_overlapped(&prog, &DramConfig::default());
        assert!(overlapped.cycles <= est.cycles, "n={n}");
    }
}
