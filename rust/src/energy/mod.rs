//! Energy/power/throughput accounting and the Table I normalization
//! formulas.
//!
//! * [`table`]     — the calibrated per-event energy table (pJ).
//! * [`power`]     — run statistics -> energy -> average power -> TOPS/W.
//! * [`tops`]      — throughput (peak and achieved).
//! * [`normalize`] — Table I's footnote math (normalized ops + normalized
//!   energy efficiency across process/voltage/precision).

pub mod normalize;
pub mod power;
pub mod table;
pub mod tops;

pub use power::{ActivityCounts, EnergyReport};
pub use table::EnergyTable;
