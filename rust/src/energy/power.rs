//! Run statistics -> energy breakdown -> average power -> TOPS/W.

use crate::cim::macro_::CimStats;
use crate::cpu::ExecStats;
use crate::mem::bus::Bus;

use super::table::EnergyTable;
use super::tops::{achieved_tops, CLOCK_HZ};

/// Device-activity event counts — the inputs of the energy model in one
/// bus-independent struct, so analytical backends (`fsim`) and the
/// cycle-level run can share one accounting formula.
#[derive(Debug, Clone, Copy, Default)]
pub struct ActivityCounts {
    pub instret: u64,
    pub muldiv: u64,
    /// CIM macro full-array fires / input-buffer shifts.
    pub fires: u64,
    pub shifts: u64,
    /// Weight-port words written (`cim_w`) / read (`cim_r`).
    pub weight_writes: u64,
    pub weight_reads: u64,
    /// FM / weight SRAM word accesses.
    pub fm_reads: u64,
    pub fm_writes: u64,
    pub wt_reads: u64,
    pub wt_writes: u64,
    /// DMEM word accesses (reads + writes).
    pub dmem_accesses: u64,
    /// DRAM bytes moved (device side) and uDMA bytes moved (on-chip side).
    pub dram_bytes: u64,
    pub udma_bytes: u64,
    pub cycles: u64,
    pub macs: u64,
}

/// Energy breakdown of one simulated run (picojoules).
#[derive(Debug, Clone, Default)]
pub struct EnergyReport {
    pub core_pj: f64,
    pub macro_pj: f64,
    pub fm_sram_pj: f64,
    pub wt_sram_pj: f64,
    pub dmem_pj: f64,
    pub dram_pj: f64,
    pub udma_pj: f64,
    pub total_pj: f64,
    /// Cycles and MACs the energy was spent over.
    pub cycles: u64,
    pub macs: u64,
    /// DRAM bytes actually moved (carried from [`ActivityCounts`], not
    /// derivable from `dram_pj` without assuming the table's per-byte
    /// cost — reporting code must use this, never divide the energy
    /// back).
    pub dram_bytes: u64,
}

impl EnergyReport {
    /// Account a completed cycle-level run.
    pub fn from_run(table: &EnergyTable, cpu: &ExecStats, bus: &Bus) -> Self {
        // Aggregate over the macro bank: every macro's fires/shifts cost
        // energy, whether the program uses one macro or a sharded set.
        let cim: CimStats = bus.cim_stats_total();
        Self::from_counts(
            table,
            &ActivityCounts {
                instret: cpu.instret,
                muldiv: cpu.muldiv,
                fires: cim.fires,
                shifts: cim.shifts,
                weight_writes: cim.weight_writes,
                weight_reads: cim.weight_reads,
                fm_reads: bus.fm.reads,
                fm_writes: bus.fm.writes,
                wt_reads: bus.wt.reads,
                wt_writes: bus.wt.writes,
                dmem_accesses: bus.dmem.reads + bus.dmem.writes,
                dram_bytes: bus.dram.bytes_transferred,
                udma_bytes: bus.udma.bytes,
                cycles: cpu.cycles,
                macs: cim.macs,
            },
        )
    }

    /// Account from bare activity counts (analytical backends).
    pub fn from_counts(table: &EnergyTable, c: &ActivityCounts) -> Self {
        let core_pj = table.core_instr * c.instret as f64 + table.core_muldiv * c.muldiv as f64;
        let macro_pj = table.macro_fire * c.fires as f64
            + table.input_shift * c.shifts as f64
            + table.weight_write * c.weight_writes as f64
            + table.weight_read * c.weight_reads as f64;
        let fm_sram_pj = table.fm_read * c.fm_reads as f64 + table.fm_write * c.fm_writes as f64;
        let wt_sram_pj = table.wt_read * c.wt_reads as f64 + table.wt_write * c.wt_writes as f64;
        let dmem_pj = table.dmem_access * c.dmem_accesses as f64;
        let dram_pj = table.dram_byte * c.dram_bytes as f64;
        let udma_pj = table.udma_word * (c.udma_bytes / 4) as f64;
        let static_pj = table.static_cycle * c.cycles as f64;
        let total_pj =
            core_pj + macro_pj + fm_sram_pj + wt_sram_pj + dmem_pj + dram_pj + udma_pj + static_pj;
        EnergyReport {
            core_pj,
            macro_pj,
            fm_sram_pj,
            wt_sram_pj,
            dmem_pj,
            dram_pj,
            udma_pj,
            total_pj,
            cycles: c.cycles,
            macs: c.macs,
            dram_bytes: c.dram_bytes,
        }
    }

    /// Average power over the run (watts) at the 50 MHz clock.
    pub fn avg_power_w(&self) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.total_pj * 1e-12 / (self.cycles as f64 / CLOCK_HZ)
    }

    /// Measured energy efficiency (TOPS/W) of the run.
    pub fn tops_per_w(&self) -> f64 {
        let p = self.avg_power_w();
        if p == 0.0 {
            return 0.0;
        }
        achieved_tops(self.macs, self.cycles) / p
    }

    /// Energy per inference in microjoules (edge-device budget number).
    pub fn total_uj(&self) -> f64 {
        self.total_pj * 1e-6
    }

    /// Render a human-readable breakdown.
    pub fn breakdown(&self) -> String {
        let pct = |x: f64| if self.total_pj > 0.0 { 100.0 * x / self.total_pj } else { 0.0 };
        format!(
            "energy {:.2} uJ: core {:.1}% | macro {:.1}% | FM {:.1}% | WT {:.1}% | dmem {:.1}% | DRAM {:.1}% | uDMA {:.1}%",
            self.total_uj(),
            pct(self.core_pj),
            pct(self.macro_pj),
            pct(self.fm_sram_pj),
            pct(self.wt_sram_pj),
            pct(self.dmem_pj),
            pct(self.dram_pj),
            pct(self.udma_pj),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::dram::DramConfig;

    #[test]
    fn peak_synthetic_run_hits_calibration() {
        // Construct stats as if a cim_conv fired every cycle for 1000
        // cycles: the measured TOPS/W must equal the calibrated 3707.84.
        let table = EnergyTable::default();
        let mut bus = Bus::new(DramConfig::default());
        let cycles = 1000u64;
        bus.cims[0].stats.fires = cycles;
        bus.cims[0].stats.shifts = cycles;
        bus.cims[0].stats.macs = cycles * crate::cim::Mode::X.macs_per_fire();
        bus.fm.reads = cycles;
        bus.fm.writes = cycles;
        let cpu = ExecStats { instret: cycles, cycles, ..Default::default() };
        let r = EnergyReport::from_run(&table, &cpu, &bus);
        assert!((r.tops_per_w() - 3707.84).abs() < 1.0, "{}", r.tops_per_w());
        assert!((r.avg_power_w() - 7.07e-3).abs() < 1e-4);
    }

    #[test]
    fn dram_dominates_unfused_traffic() {
        // 64 KB over DRAM costs more energy than 1000 macro fires — the
        // architectural argument for fusion, in one assert.
        let table = EnergyTable::default();
        assert!(table.dram_byte * 65536.0 > table.macro_fire * 1000.0);
    }

    #[test]
    fn breakdown_percentages_sum() {
        let table = EnergyTable::default();
        let mut bus = Bus::new(DramConfig::default());
        bus.cims[0].stats.fires = 10;
        bus.dram.bytes_transferred = 100;
        let cpu = ExecStats { instret: 100, cycles: 100, ..Default::default() };
        let r = EnergyReport::from_run(&table, &cpu, &bus);
        let parts = r.core_pj + r.macro_pj + r.fm_sram_pj + r.wt_sram_pj + r.dmem_pj + r.dram_pj + r.udma_pj;
        assert!((parts - r.total_pj).abs() < 1e-9);
        // Byte counts ride through untouched: the report must never need
        // dram_pj / dram_byte to recover them.
        assert_eq!(r.dram_bytes, 100);
        assert_eq!(r.dram_pj, table.dram_byte * 100.0);
    }
}
