//! Per-event energy table (picojoules), TSMC 28 nm @ 0.9 V, 50 MHz.
//!
//! Calibration (DESIGN.md §6): the paper reports 26.21 TOPS at
//! 3707.84 TOPS/W, i.e. 7.0701 mW total at peak — 141.40 pJ per cycle when
//! a `cim_conv` fires every cycle. A peak-compute cycle spends:
//!
//! ```text
//!   core issue + decode        10.0 pJ   (ibex-class 2-stage @28nm)
//!   FM SRAM read (32 b)         5.0 pJ
//!   input-buffer shift          2.0 pJ
//!   macro full-array MAC      118.4 pJ   <- calibrated residual
//!   FM SRAM write (32 b)        6.0 pJ
//!   total                     141.4 pJ  -> 3707.84 TOPS/W exactly
//! ```
//!
//! The macro figure is consistent with the integrated macro's standalone
//! headline ([7]: 20943 TOPS/W ternary @0.9 V — lower per-op energy than
//! our residual, the difference being SA/latch and routing overhead inside
//! the CIMR-V wrapper). DRAM energy uses a DDR4-class 400 pJ/byte
//! (interface + device) — it only matters for the baseline (no-fusion)
//! rows, which is rather the point of the paper.

/// Energy per event, picojoules.
#[derive(Debug, Clone)]
pub struct EnergyTable {
    /// RISC-V core, per retired instruction (issue/decode/regfile).
    pub core_instr: f64,
    /// Extra for mul/div (iterative datapath activity).
    pub core_muldiv: f64,
    /// CIM macro full-array MAC fire (X or Y mode, includes SA + latch).
    pub macro_fire: f64,
    /// Input-buffer 32-bit shift.
    pub input_shift: f64,
    /// Weight-port word write (`cim_w`) including write drivers.
    pub weight_write: f64,
    /// Weight-port word read (`cim_r`).
    pub weight_read: f64,
    /// FM SRAM word read / write.
    pub fm_read: f64,
    pub fm_write: f64,
    /// Weight SRAM word read / write.
    pub wt_read: f64,
    pub wt_write: f64,
    /// DMEM word access (either direction).
    pub dmem_access: f64,
    /// DRAM, per byte moved (device + interface, DDR4-class).
    pub dram_byte: f64,
    /// uDMA engine, per word moved (on-chip side).
    pub udma_word: f64,
    /// Static/leakage + clock tree, per cycle.
    pub static_cycle: f64,
}

impl Default for EnergyTable {
    fn default() -> Self {
        EnergyTable {
            core_instr: 10.0,
            core_muldiv: 8.0,
            macro_fire: 118.4,
            input_shift: 2.0,
            weight_write: 6.0,
            weight_read: 6.0,
            fm_read: 5.0,
            fm_write: 6.0,
            wt_read: 7.0,
            wt_write: 8.0,
            dmem_access: 5.0,
            dram_byte: 400.0,
            udma_word: 4.0,
            static_cycle: 0.0,
        }
    }
}

impl EnergyTable {
    /// Energy of one peak-throughput cycle (cim_conv firing): the quantity
    /// the table is calibrated on.
    pub fn peak_cycle_pj(&self) -> f64 {
        self.core_instr + self.fm_read + self.input_shift + self.macro_fire + self.fm_write
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_hits_table1_energy_efficiency() {
        let t = EnergyTable::default();
        let clk = crate::clock::CLOCK_HZ;
        let peak_w = t.peak_cycle_pj() * 1e-12 * clk;
        let tops = 1024.0 * 256.0 * 2.0 * clk / 1e12;
        let tops_per_w = tops / peak_w;
        assert!(
            (tops_per_w - 3707.84).abs() < 1.0,
            "calibration drifted: {tops_per_w:.2} TOPS/W"
        );
    }
}
