//! Throughput accounting: peak (architectural) and achieved (measured).

use crate::cim::Mode;

/// Core clock of the paper's implementation (re-exported from the
/// single source of truth, [`crate::clock`]).
pub use crate::clock::CLOCK_HZ;

/// Ops per MAC (multiply + accumulate).
pub const OPS_PER_MAC: f64 = 2.0;

/// Peak TOPS in a mode at the paper's 50 MHz clock (Table I headline:
/// X-mode -> 26.21 TOPS).
pub fn peak_tops(mode: Mode) -> f64 {
    mode.macs_per_fire() as f64 * OPS_PER_MAC * CLOCK_HZ / 1e12
}

/// Achieved TOPS of a measured run: MACs actually performed over the
/// cycles it took, at the 50 MHz clock.
pub fn achieved_tops(total_macs: u64, cycles: u64) -> f64 {
    if cycles == 0 {
        return 0.0;
    }
    let secs = cycles as f64 / CLOCK_HZ;
    total_macs as f64 * OPS_PER_MAC / secs / 1e12
}

/// Macro utilization: fraction of cycles with a fire.
pub fn macro_utilization(fires: u64, cycles: u64) -> f64 {
    if cycles == 0 {
        0.0
    } else {
        fires as f64 / cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn x_mode_peak_matches_table1() {
        assert!((peak_tops(Mode::X) - 26.2144).abs() < 1e-6);
        assert!((peak_tops(Mode::Y) - 26.2144).abs() < 1e-6); // same cell count
    }

    #[test]
    fn achieved_is_peak_when_firing_every_cycle() {
        let macs = Mode::X.macs_per_fire() * 1000;
        let t = achieved_tops(macs, 1000);
        assert!((t - peak_tops(Mode::X)).abs() < 1e-9);
    }

    #[test]
    fn utilization_bounds() {
        assert_eq!(macro_utilization(0, 100), 0.0);
        assert_eq!(macro_utilization(50, 100), 0.5);
        assert_eq!(macro_utilization(0, 0), 0.0);
    }
}
