//! The paper's 50 MHz system clock — the single source of truth.
//!
//! Every consumer (TOPS accounting, average-power, Perfetto cycle→µs
//! conversion, the CLI throughput summary, `seconds_at_50mhz`) derives
//! from [`CLOCK_HZ`]; nothing else in the tree may carry its own `50e6`
//! or `50.0` literal, so traces, TOPS and peak-power numbers can never
//! disagree about what a cycle is worth.

/// Core clock of the paper's implementation (TSMC 28 nm @ 0.9 V).
pub const CLOCK_HZ: f64 = 50e6;

/// The same clock in MHz — the cycles → microseconds divisor.
pub const CLOCK_MHZ: f64 = CLOCK_HZ / 1e6;

/// Wall-clock seconds a cycle count corresponds to at the system clock.
#[inline]
pub fn cycles_to_seconds(cycles: u64) -> f64 {
    cycles as f64 / CLOCK_HZ
}

/// Wall-clock microseconds a cycle count corresponds to (trace axes).
#[inline]
pub fn cycles_to_us(cycles: u64) -> f64 {
    cycles as f64 / CLOCK_MHZ
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_forms_agree() {
        assert_eq!(CLOCK_HZ, 50e6);
        assert_eq!(CLOCK_MHZ, 50.0);
        assert_eq!(cycles_to_seconds(50_000_000), 1.0);
        assert_eq!(cycles_to_us(50), 1.0);
        // µs and s forms describe the same clock.
        assert_eq!(cycles_to_us(12_345), cycles_to_seconds(12_345) * 1e6);
    }
}
