//! `cimrv` — the CIMR-V launcher.
//!
//! Subcommands:
//!   run        one inference (+ golden cross-check); --backend cycle|fast,
//!              --batch B for a batched run through run_batch
//!   ablation   the Fig. 6/7/9 + §III-A optimization ladder
//!              (--variation SPEC injects §II-B disturbance into the runs)
//!   table1     Table I comparison (+ measured TOPS/W and accuracy;
//!              --variation SPEC adds disturbed accuracy)
//!   accuracy   synthetic-GSCD accuracy on the ISS vs the host reference
//!   serve      threaded coordinator demo; --backend cycle|fast, --batch B
//!              turns the workers into micro-batching schedulers,
//!              --linger-us N overrides the adaptive straggler window,
//!              --variation SPEC serves disturbed inferences,
//!              --chaos SPEC injects deterministic faults, --queue-cap N
//!              bounds admission, --deadline-ms D stamps per-request
//!              deadlines, --max-attempts K caps retries
//!   sweep      Monte-Carlo robustness sweep over (sigma x nl x mapping x
//!              seed) through the variation-aware fast path; emits
//!              BENCH_robustness.json with bootstrap CIs (--quick,
//!              --check, grid flags, --seeds K)
//!   soak       chaos soak across the standard fault grid (panics,
//!              transients, stalls, deadlines, overload); emits
//!              BENCH_resilience.json (--quick, --check)
//!   disasm     decode a hex instruction word
//!
//! The --chaos SPEC is comma-separated key=value (all faults seeded +
//! reproducible): latency=P,latency_ms=N,stall=P,stall_ms=N,transient=P,
//! panic=P,corrupt=P,corrupt_sigma=S,seed=N
//!
//! Observability (run/serve/sweep/soak/trace): --trace-out FILE writes
//! a Perfetto/chrome://tracing trace (instruction JSONL on `trace`),
//! --metrics-out FILE dumps the telemetry registry (Prometheus text for
//! .prom/.txt, JSON otherwise), --profile-out FILE writes the scoped
//! self-time profile as collapsed/folded stacks (flamegraph input),
//! --events-out FILE writes the structured incident log as JSONL; any
//! of these flags turns telemetry on. --slo p99_ms=..,availability=..
//! [,window=N] arms the rolling SLO monitor on serve (report block +
//! slo.* gauges) and gates `soak --check` cells on the same targets.
//!
//! The shared --variation SPEC is comma-separated key=value:
//!   sigma=0.1,nl=0.3,mapping=single,mismatch=0.05,seed=7
//!
//! Run from the repo root after `make artifacts && cargo build --release`.

use anyhow::{bail, Context, Result};

use cimrv::backend::{self, BackendKind, InferenceBackend};
use cimrv::baselines::{comparison, OptLevel};
use cimrv::compiler::{build_kws_program, build_kws_program_sharded};
use cimrv::coordinator::report::{
    ladder_json, render_batch_histogram, render_ladder, render_latency_percentiles,
    render_resilience, render_shard_utilization, render_span_breakdown, render_sweep,
    LadderPoint,
};
use cimrv::coordinator::{
    Coordinator, InferenceRequest, ServeError, ServeOptions, DEFAULT_MAX_ATTEMPTS,
    DEFAULT_QUEUE_CAP,
};
use cimrv::resilience::{run_soak, FaultPlan, SoakConfig};
use cimrv::fsim::FastSim;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, reference, KwsModel};
use cimrv::robustness::{self, run_sweep, SweepConfig};
use cimrv::runtime::GoldenModel;
use cimrv::sim::Soc;
use cimrv::telemetry::{self, events, global_profiler, perfetto, SloConfig, TraceBuilder};
use cimrv::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse(&["no-golden", "json", "verbose", "calibrate", "quick", "check"])?;
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("table1") => cmd_table1(&args),
        Some("accuracy") => cmd_accuracy(&args),
        Some("serve") => cmd_serve(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("soak") => cmd_soak(&args),
        Some("disasm") => cmd_disasm(&args),
        Some("trace") => cmd_trace(&args),
        _ => {
            eprintln!(
                "usage: cimrv <run|ablation|table1|accuracy|serve|sweep|soak|trace|disasm> \
                 [--opt LEVEL] [--backend cycle|fast] [--macros N] [--batch B] [--calibrate] \
                 [--linger-us U] [--variation SPEC] [--n N] [--workers W] [--label L] \
                 [--seed S] [--skip K] [--no-golden] [--json] \
                 [--trace-out FILE] [--metrics-out FILE] [--profile-out FILE] \
                 [--events-out FILE]\n\
                 serve resilience: [--chaos SPEC] [--queue-cap N] [--deadline-ms D] \
                 [--max-attempts K] [--slo p99_ms=..,availability=..[,window=N]]\n\
                 sweep: [--quick] [--check] [--sigmas 0,0.1,..] [--nl 0.3] \
                 [--mappings both|symmetric|single] [--seeds K] [--mismatch M] \
                 [--threads T] [--out FILE]\n\
                 soak: [--quick] [--check] [--n N] [--workers W] [--out FILE] \
                 [--slo SPEC] (default BENCH_resilience.json)\n\
                 observability: --trace-out writes a Perfetto/chrome://tracing JSON \
                 (run/serve; JSONL on trace), --metrics-out dumps the metrics \
                 registry (.prom/.txt = Prometheus text, else JSON), --profile-out \
                 writes folded stacks (flamegraph input), --events-out writes the \
                 incident log as JSONL, --slo arms the SLO monitor (serve) or \
                 gates --check (soak)"
            );
            Ok(())
        }
    }
}

fn load_model() -> Result<KwsModel> {
    KwsModel::load_default().context("loading artifacts (run `make artifacts` first)")
}

/// Parsed observability output flags (`--trace-out`, `--metrics-out`,
/// `--profile-out`, `--events-out`).
#[derive(Default)]
struct ObsOutputs {
    trace_out: Option<String>,
    metrics_out: Option<String>,
    profile_out: Option<String>,
    events_out: Option<String>,
}

impl ObsOutputs {
    fn any(&self) -> bool {
        self.trace_out.is_some()
            || self.metrics_out.is_some()
            || self.profile_out.is_some()
            || self.events_out.is_some()
    }
}

/// Shared observability-output handling: asking for any output
/// implicitly turns telemetry on (with a fresh registry, profiler, and
/// event ring, so every dump covers exactly this invocation).
fn telemetry_outputs(args: &Args) -> ObsOutputs {
    let get = |k: &str| args.opt(k).map(str::to_string);
    let outs = ObsOutputs {
        trace_out: get("trace-out"),
        metrics_out: get("metrics-out"),
        profile_out: get("profile-out"),
        events_out: get("events-out"),
    };
    if outs.any() {
        telemetry::set_enabled(true);
        telemetry::global().reset();
        global_profiler().reset();
        events().reset();
    }
    outs
}

/// Dump the global registry: Prometheus text exposition for `.prom` /
/// `.txt` paths, the JSON form otherwise.
fn write_metrics(path: &str) -> Result<()> {
    let text = if path.ends_with(".prom") || path.ends_with(".txt") {
        telemetry::global().render_prometheus()
    } else {
        format!("{}\n", telemetry::global().to_json())
    };
    std::fs::write(path, text).with_context(|| format!("writing {path}"))?;
    println!("wrote {path}");
    Ok(())
}

fn write_trace(path: &str, tb: TraceBuilder) -> Result<()> {
    let n = tb.len();
    std::fs::write(path, format!("{}\n", tb.build()))
        .with_context(|| format!("writing {path}"))?;
    println!("wrote {path} ({n} events — open in ui.perfetto.dev or chrome://tracing)");
    Ok(())
}

/// `--profile-out`: collapsed/folded stacks (one `a;b;c <µs>` line per
/// call path — direct flamegraph.pl / speedscope input), plus the
/// per-region self/total table on stdout.
fn write_profile(path: &str) -> Result<()> {
    let prof = global_profiler();
    std::fs::write(path, prof.render_folded()).with_context(|| format!("writing {path}"))?;
    let dropped = prof.dropped_slices();
    if dropped > 0 {
        eprintln!("note: profiler slice ring overflowed ({dropped} slices dropped from the trace; folded totals are unaffected)");
    }
    println!("wrote {path} (folded stacks — flamegraph.pl or speedscope)");
    print!("{}", prof.render_table());
    Ok(())
}

/// `--events-out`: the structured incident log, one JSON object per line.
fn write_events(path: &str) -> Result<()> {
    let log = events();
    std::fs::write(path, log.to_jsonl()).with_context(|| format!("writing {path}"))?;
    let dropped = log.dropped();
    let suffix = if dropped > 0 {
        format!(", {dropped} older event(s) dropped by the ring")
    } else {
        String::new()
    };
    println!("wrote {path} ({} incident event(s){suffix})", log.len());
    Ok(())
}

/// The non-trace observability dumps every subcommand shares (the trace
/// itself carries command-specific tracks, so each command builds its
/// own `TraceBuilder`).
fn write_obs_outputs(outs: &ObsOutputs) -> Result<()> {
    if let Some(path) = &outs.profile_out {
        write_profile(path)?;
    }
    if let Some(path) = &outs.events_out {
        write_events(path)?;
    }
    if let Some(path) = &outs.metrics_out {
        write_metrics(path)?;
    }
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let model = load_model()?;
    let outs = telemetry_outputs(args);
    let opt = OptLevel::parse(&args.opt_or("opt", "full"))?;
    let kind = BackendKind::parse(&args.opt_or("backend", "cycle"))?;
    let macros = args.opt_usize("macros", 1)?.max(1);
    let label = args.opt_usize("label", 3)?;
    let seed = args.opt_usize("seed", 1)? as u64;
    let audio = dataset::synth_utterance(label, seed, model.audio_len, 0.37);

    let program = build_kws_program_sharded(&model, opt, macros)?;
    println!(
        "program: {} instructions ({} KiB IMEM), opt {}, backend {kind}, {macros} macro(s)",
        program.imem.len(),
        program.imem_bytes() / 1024,
        opt
    );
    if macros > 1 {
        // Shard-aware latency model: the serial interleave the single-
        // issue core pays vs the overlapped multi-macro schedule.
        let serial = cimrv::fsim::latency::estimate(&program, &DramConfig::default());
        let overlapped =
            cimrv::fsim::latency::estimate_overlapped(&program, &DramConfig::default());
        println!(
            "sharded latency model: serial interleave {} cycles, overlapped schedule {} \
             cycles ({:.1}% headroom)",
            serial.cycles,
            overlapped.cycles,
            100.0 * (1.0 - overlapped.cycles as f64 / serial.cycles as f64)
        );
    }
    let mut be = backend::build(kind, program, DramConfig::default())?;
    let batch = args.opt_usize("batch", 1)?.max(1);
    if batch > 1 {
        // Serve `batch` utterances (varying seeds, same label) through
        // one run_batch call: the fast backend walks every layer's
        // weight planes once for the whole batch.
        let audios: Vec<Vec<f32>> = (0..batch)
            .map(|i| dataset::synth_utterance(label, seed + i as u64, model.audio_len, 0.37))
            .collect();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        let t0 = std::time::Instant::now();
        let rs = be.run_batch(&refs)?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "batched run: {batch} utterances in {:.2} ms host time ({:.2} ms/inference)",
            1e3 * wall,
            1e3 * wall / batch as f64
        );
        for (i, (r, a)) in rs.iter().zip(&audios).enumerate() {
            let host = reference::infer(&model, a);
            if r.logits != host {
                bail!("batched element {i} disagrees with host reference");
            }
            println!("  [{i}] predicted {} (true {label})", r.predicted);
        }
        println!("host reference: all {batch} batched elements bit-exact \u{2713}");
        if let (Some(path), Some(r)) = (&outs.trace_out, rs.first()) {
            let mut tb = TraceBuilder::new();
            perfetto::engine_tracks(&mut tb, be.program(), &r.markers, r.cycles);
            perfetto::profiler_tracks(&mut tb, &global_profiler().slices_snapshot());
            write_trace(path, tb)?;
        }
        write_obs_outputs(&outs)?;
        return Ok(());
    }
    let r = be.run(&audio)?;
    if let Some(path) = &outs.trace_out {
        let mut tb = TraceBuilder::new();
        perfetto::engine_tracks(&mut tb, be.program(), &r.markers, r.cycles);
        perfetto::profiler_tracks(&mut tb, &global_profiler().slices_snapshot());
        write_trace(path, tb)?;
    }
    write_obs_outputs(&outs)?;
    println!("predicted class {} (true {label}), logits {:?}", r.predicted, r.logits);
    println!("{}", r.phases.render());
    println!("{}", r.energy.breakdown());
    println!(
        "chip latency: {} cycles = {:.3} ms @50 MHz | measured {:.2} TOPS/W",
        r.cycles,
        1e3 * r.seconds_at_50mhz,
        r.energy.tops_per_w()
    );
    if macros > 1 {
        println!("per-shard fires: {:?}", r.shard_fires);
    }

    let host = reference::infer(&model, &audio);
    if r.logits != host {
        bail!("ISS disagrees with host reference: {:?} vs {host:?}", r.logits);
    }
    println!("host reference: bit-exact \u{2713}");
    if !args.flag("no-golden") {
        let dir = cimrv::util::io::artifacts_dir()?;
        if GoldenModel::available(&dir) {
            let golden = GoldenModel::load(&dir)?;
            let g = golden.infer(&audio)?;
            if r.logits != g {
                bail!("ISS disagrees with PJRT golden model: {:?} vs {g:?}", r.logits);
            }
            println!("PJRT golden model (AOT JAX+Pallas): bit-exact \u{2713}");
        } else {
            println!(
                "PJRT golden model not present in this artifact set (checked-in testdata \
                 carries golden logits instead) — skipping the HLO cross-check"
            );
        }
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> Result<()> {
    let model = load_model()?;
    let seed = args.opt_usize("seed", 1)? as u64;
    let variation = robustness::variation_from_args(args)?;
    let audio = dataset::synth_utterance(3, seed, model.audio_len, 0.37);
    let mut points = Vec::new();
    let mut disturbed_logits: Vec<Vec<f32>> = Vec::new();
    for (name, opt) in OptLevel::ladder() {
        let program = build_kws_program(&model, opt)?;
        let mut soc = Soc::new(program, DramConfig::default())?;
        if let Some(v) = &variation {
            soc.set_variation(Some(v.model()));
        }
        let r = soc.infer(&audio)?;
        if variation.is_some() {
            disturbed_logits.push(r.logits.clone());
        }
        points.push(LadderPoint::from_run(name, opt, &r));
    }
    if let Some(v) = &variation {
        // The optimizations change timing, never the fire sequence — so
        // the injected disturbance is identical across the whole ladder.
        // Diagnostic goes to stderr: `--json` stdout stays pure JSON.
        let all_same = disturbed_logits.windows(2).all(|w| w[0] == w[1]);
        eprintln!(
            "variation injected ({}): disturbed logits {} across the ladder",
            v.spec(),
            if all_same { "bit-identical" } else { "DIVERGED (fire sequences differ!)" }
        );
        if !all_same {
            bail!("opt levels disagreed under variation — fire sequences are not equivalent");
        }
    }
    if args.flag("json") {
        println!("{}", ladder_json(&points));
    } else {
        println!("{}", render_ladder(&points));
        let base = points[0].accelerated_cycles as f64;
        let top = points.last().expect("ladder is non-empty");
        println!(
            "total accelerated-phase reduction ({}): {:.2}% (paper: 85.14% on its \
             model/testbed)",
            top.name,
            100.0 * (1.0 - top.accelerated_cycles as f64 / base)
        );
    }
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let model = load_model()?;
    // Measure TOPS/W on a full-opt inference.
    let program = build_kws_program(&model, OptLevel::FULL)?;
    let mut soc = Soc::new(program, DramConfig::default())?;
    let audio = dataset::synth_utterance(0, 7, model.audio_len, 0.37);
    let r = soc.infer(&audio)?;
    // Quick accuracy over a few eval utterances (host reference, fast).
    let n = args.opt_usize("n", 64)?;
    let mut hits = 0usize;
    for i in 0..n {
        let label = i % 12;
        let a = dataset::synth_utterance(label, 1000 + i as u64, model.audio_len, 0.37);
        if reference::argmax(&reference::infer(&model, &a)) == label {
            hits += 1;
        }
    }
    let acc = 100.0 * hits as f64 / n as f64;
    println!("{}", comparison::render_table1(Some(r.energy.tops_per_w()), Some(acc)));
    if let Some(v) = robustness::variation_from_args(args)? {
        // Disturbed accuracy on the same utterances through the
        // variation-aware fast path (bit-identical to a cycle run with
        // the same seed — tests/variation_parity.rs).
        let prog = build_kws_program(&model, OptLevel::FULL)?;
        let sim = FastSim::new(prog, DramConfig::default())?;
        let mut hits = 0usize;
        for i in 0..n {
            let label = i % 12;
            let a = dataset::synth_utterance(label, 1000 + i as u64, model.audio_len, 0.37);
            if sim.infer_disturbed(&a, &v).predicted == label {
                hits += 1;
            }
        }
        println!(
            "accuracy under variation ({}): {:.2}% ({hits}/{n})",
            v.spec(),
            100.0 * hits as f64 / n as f64
        );
    }
    Ok(())
}

fn cmd_accuracy(args: &Args) -> Result<()> {
    let model = load_model()?;
    let dir = cimrv::util::io::artifacts_dir()?;
    let eval = dataset::Dataset::load_eval(&dir, model.audio_len, model.n_classes)?;
    let n = args.opt_usize("n", eval.len())?.min(eval.len());
    let on_iss = args.opt_usize("iss", 8)?.min(n); // ISS is slower; subset
    let program = build_kws_program(&model, OptLevel::FULL)?;
    let mut soc = Soc::new(program, DramConfig::default())?;
    let mut host_hits = 0;
    let mut iss_hits = 0;
    let mut iss_matches = 0;
    for i in 0..n {
        let audio = eval.utterance(i);
        let want = eval.labels[i] as usize;
        let host = reference::infer(&model, audio);
        if reference::argmax(&host) == want {
            host_hits += 1;
        }
        if i < on_iss {
            let r = soc.infer(audio)?;
            if r.predicted == want {
                iss_hits += 1;
            }
            if r.logits == host {
                iss_matches += 1;
            }
        }
    }
    println!(
        "host reference accuracy: {:.2}% ({host_hits}/{n})",
        100.0 * host_hits as f64 / n as f64
    );
    if on_iss > 0 {
        println!(
            "ISS accuracy: {:.2}% ({iss_hits}/{on_iss}); bit-exact vs host on {iss_matches}/{on_iss}",
            100.0 * iss_hits as f64 / on_iss as f64
        );
    }
    println!("(paper reports 94.02% on the real GSCD; ours is the synthetic corpus — DESIGN.md §2)");
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let model = load_model()?;
    let outs = telemetry_outputs(args);
    let workers = args.opt_usize("workers", 4)?;
    let n = args.opt_usize("n", 24)?;
    let opt = OptLevel::parse(&args.opt_or("opt", "full"))?;
    let kind = BackendKind::parse(&args.opt_or("backend", "cycle"))?;
    let linger_us = match args.opt("linger-us") {
        Some(_) => Some(args.opt_u64("linger-us", 0)?),
        None => None,
    };
    let deadline_ms = match args.opt("deadline-ms") {
        Some(_) => Some(args.opt_u64("deadline-ms", 0)?),
        None => None,
    };
    let opts = ServeOptions {
        calibrate: args.flag("calibrate"),
        macros: args.opt_usize("macros", 1)?.max(1),
        batch: args.opt_usize("batch", 1)?,
        linger_us,
        variation: robustness::variation_from_args(args)?,
        queue_cap: args.opt_usize("queue-cap", DEFAULT_QUEUE_CAP)?,
        chaos: args.opt("chaos").map(FaultPlan::parse_spec).transpose()?,
        max_attempts: args.opt_u64("max-attempts", u64::from(DEFAULT_MAX_ATTEMPTS))? as u32,
        slo: args.opt("slo").map(SloConfig::parse_spec).transpose()?,
    };
    if opts.calibrate && kind == BackendKind::Cycle {
        eprintln!("note: --calibrate is a fast-backend option (cycle is already exact)");
    }
    let mut coord = Coordinator::start_with_options(&model, opt, workers, kind, opts)?;
    if opts.calibrate && kind == BackendKind::Fast {
        println!("calibrated from one cycle-level run: served latency/energy are exact");
    }
    if let Some(v) = &opts.variation {
        println!(
            "serving DISTURBED inferences ({}): fresh per-macro noise streams per request",
            v.spec()
        );
    }
    if let Some(plan) = &opts.chaos {
        println!(
            "serving under CHAOS ({}): faults are deterministic per (worker, incarnation)",
            plan.spec()
        );
    }
    match opts.linger_us {
        Some(us) if opts.batch > 1 => println!("micro-batch linger: fixed {us} µs"),
        None if opts.batch > 1 => {
            println!("micro-batch linger: adaptive (sized from observed inter-arrival rate)")
        }
        _ => {}
    }
    let t0 = std::time::Instant::now();
    let reqs: Vec<_> = (0..n)
        .map(|i| InferenceRequest {
            id: i as u64,
            audio: dataset::synth_utterance(i % 12, 400 + i as u64, model.audio_len, 0.37),
            label: Some((i % 12) as i32),
            deadline: deadline_ms.map(|ms| t0 + std::time::Duration::from_millis(ms)),
        })
        .collect();
    // Under chaos or deadlines a typed per-request failure is expected
    // service behaviour, not a demo-aborting error: collect outcomes and
    // report the degradation instead of bailing on the first one.
    let fault_tolerant = opts.chaos.is_some() || deadline_ms.is_some();
    let resps = if fault_tolerant {
        let rxs: Vec<_> = reqs.into_iter().map(|r| coord.submit(r)).collect();
        let mut oks = Vec::new();
        let (mut shed, mut expired, mut failed) = (0usize, 0usize, 0usize);
        for rx in rxs {
            match rx {
                Err(_) => shed += 1,
                Ok(rx) => match rx.recv() {
                    Ok(Ok(resp)) => oks.push(resp),
                    Ok(Err(ServeError::DeadlineExceeded { .. })) => expired += 1,
                    Ok(Err(_)) | Err(_) => failed += 1,
                },
            }
        }
        if shed + expired + failed > 0 {
            println!(
                "degraded service: {shed} shed at admission, {expired} missed deadline, \
                 {failed} failed"
            );
        }
        oks
    } else {
        coord.serve_batch(reqs)?
    };
    let wall = t0.elapsed().as_secs_f64();
    let served = resps.len();
    let chip: u64 = resps.iter().map(|r| r.chip_cycles).sum();
    println!(
        "served {served}/{n} requests on {workers} {kind}-backend workers in {wall:.2}s host \
         time ({:.1} req/s host, {:.1} req/s chip-time)",
        served as f64 / wall,
        served as f64 / cimrv::clock::cycles_to_seconds(chip).max(f64::MIN_POSITIVE)
    );
    if fault_tolerant {
        use std::sync::atomic::Ordering::Relaxed;
        let s = &coord.stats;
        println!(
            "resilience: retries {} requeues {} worker panics {} respawns {} breaker trips {}",
            s.retries.load(Relaxed),
            s.requeues.load(Relaxed),
            s.worker_panics.load(Relaxed),
            s.respawns.load(Relaxed),
            s.breaker_trips.load(Relaxed)
        );
    }
    if let Some(acc) = coord.accuracy() {
        println!("accuracy: {:.2}%", 100.0 * acc);
    }
    print!("{}", render_latency_percentiles(&coord.stats));
    if opts.batch > 1 {
        print!("{}", render_batch_histogram(&coord.stats));
    }
    if opts.macros > 1 {
        print!("{}", render_shard_utilization(&coord.stats));
    }
    if telemetry::enabled() {
        print!("{}", render_span_breakdown(&coord.stats));
    }
    if let Some(slo) = coord.stats.slo_report() {
        print!("{}", slo.render());
    }
    if let Some(path) = &outs.trace_out {
        let spans = coord.stats.spans.snapshot();
        let mut tb = TraceBuilder::new();
        perfetto::serving_tracks(&mut tb, &spans, 256);
        // Queue-depth and per-worker batch-size counter tracks from the
        // same spans, plus the incident log as instant events.
        perfetto::counter_tracks(&mut tb, &spans);
        perfetto::incident_tracks(&mut tb, &events().snapshot());
        perfetto::profiler_tracks(&mut tb, &global_profiler().slices_snapshot());
        // The engine timeline from one representative run, on the same
        // trace's time axis (its own process track).
        if let Some((markers, cycles)) = coord.stats.engine_sample() {
            let program = build_kws_program_sharded(&model, opt, opts.macros)?;
            perfetto::engine_tracks(&mut tb, &program, &markers, cycles);
        }
        write_trace(path, tb)?;
    }
    write_obs_outputs(&outs)?;
    coord.shutdown();
    Ok(())
}

fn parse_f64_list(s: &str) -> Result<Vec<f64>> {
    s.split(',')
        .map(str::trim)
        .filter(|v| !v.is_empty())
        .map(|v| v.parse::<f64>().map_err(|_| anyhow::anyhow!("bad number {v:?} in list")))
        .collect()
}

/// Monte-Carlo robustness sweep (`cimrv sweep`): the (sigma × nl ×
/// mapping × seed) grid through the variation-aware fast path over the
/// checked-in artifact eval set; text report + BENCH_robustness.json.
/// `--quick` = the CI smoke grid, `--check` = fail unless symmetric
/// mapping beats single-ended at the largest swept sigma (§II-B).
fn cmd_sweep(args: &Args) -> Result<()> {
    let model = load_model()?;
    let outs = telemetry_outputs(args);
    let dir = cimrv::util::io::artifacts_dir()?;
    let eval = dataset::Dataset::load_eval(&dir, model.audio_len, model.n_classes)?;
    let n = args.opt_usize("n", eval.len())?.min(eval.len());
    anyhow::ensure!(n > 0, "eval set is empty");

    let mut cfg = if args.flag("quick") { SweepConfig::quick() } else { SweepConfig::full() };
    if let Some(s) = args.opt("sigmas") {
        cfg.sigmas = parse_f64_list(s)?;
    }
    if let Some(s) = args.opt("nl") {
        cfg.nl_alphas = parse_f64_list(s)?;
    }
    if let Some(m) = args.opt("mappings") {
        cfg.mappings = match m {
            "both" => vec![true, false],
            "symmetric" | "sym" => vec![true],
            "single" | "single-ended" | "se" => vec![false],
            _ => bail!("--mappings expects both|symmetric|single, got {m:?}"),
        };
    }
    // `--seeds` is the documented spelling; `--mc-seeds` stays as an alias.
    if let Some(k) = args.opt("seeds").or_else(|| args.opt("mc-seeds")) {
        let k: u64 = k.parse().map_err(|_| anyhow::anyhow!("--seeds expects a count"))?;
        anyhow::ensure!(k > 0, "--seeds must be >= 1");
        cfg.seeds = (0..k).map(|s| 1000 + s).collect();
    }
    cfg.mismatch = args.opt_f64("mismatch", cfg.mismatch)?;
    cfg.threads = args.opt_usize("threads", cfg.threads)?;

    let opt = OptLevel::parse(&args.opt_or("opt", "full"))?;
    let macros = args.opt_usize("macros", 1)?.max(1);
    let program = build_kws_program_sharded(&model, opt, macros)?;
    // The point fleet is the parallelism; keep each trial on its thread.
    let sim = FastSim::new(program, DramConfig::default())?.with_batch_threads(1);

    let utterances: Vec<&[f32]> = (0..n).map(|i| eval.utterance(i)).collect();
    let labels: Vec<usize> = (0..n).map(|i| eval.labels[i] as usize).collect();
    let t0 = std::time::Instant::now();
    let report = run_sweep(&sim, &utterances, &labels, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    let n_points = cfg.sigmas.len() * cfg.nl_alphas.len() * cfg.mappings.len() * cfg.seeds.len();
    eprintln!(
        "sweep wall-clock: {wall:.2}s ({:.1} grid points/s over {n_points} points)",
        n_points as f64 / wall.max(1e-9)
    );

    let out = args.opt_or("out", "BENCH_robustness.json");
    std::fs::write(&out, format!("{}\n", report.to_json()))
        .with_context(|| format!("writing {out}"))?;
    if args.flag("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", render_sweep(&report));
    }
    println!("wrote {out}");
    if args.flag("check") {
        report.check_mapping_claim()?;
        println!("check: symmetric mapping beats single-ended at max sigma \u{2713}");
    }
    write_obs_outputs(&outs)?;
    Ok(())
}

/// Chaos soak (`cimrv soak`): drive the serving stack through the
/// standard fault grid — clean baseline, transient errors, worker
/// panics, latency spikes under deadlines, stalls that force deadline
/// sheds, and a tiny queue that forces admission sheds — and emit
/// BENCH_resilience.json. `--quick` = the CI smoke grid, `--check` =
/// fail unless the availability contract holds (no hung requests,
/// 100% availability wherever the cell promises it, and the expected
/// respawn/shed evidence per cell).
fn cmd_soak(args: &Args) -> Result<()> {
    let model = load_model()?;
    let outs = telemetry_outputs(args);
    let slo = args.opt("slo").map(SloConfig::parse_spec).transpose()?;
    let mut cfg = if args.flag("quick") { SoakConfig::quick() } else { SoakConfig::standard() };
    cfg.n = args.opt_usize("n", cfg.n)?;
    anyhow::ensure!(cfg.n > 0, "--n must be >= 1");
    cfg.workers = args.opt_usize("workers", cfg.workers)?;
    cfg.batch = args.opt_usize("batch", cfg.batch)?;
    cfg.macros = args.opt_usize("macros", cfg.macros)?.max(1);
    cfg.seed = args.opt_u64("seed", cfg.seed)?;

    let t0 = std::time::Instant::now();
    let report = run_soak(&model, &cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    eprintln!("soak wall-clock: {wall:.2}s ({} cells)", report.cells.len());

    let out = args.opt_or("out", "BENCH_resilience.json");
    std::fs::write(&out, format!("{}\n", report.to_json()))
        .with_context(|| format!("writing {out}"))?;
    if args.flag("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", render_resilience(&report));
    }
    println!("wrote {out}");
    if args.flag("check") {
        report.check()?;
        println!("check: availability contract holds under chaos \u{2713}");
        if let Some(slo) = &slo {
            report.check_slo(slo)?;
            println!("check: SLO targets ({}) hold on full-availability cells \u{2713}", slo.spec());
        }
    } else if let Some(slo) = &slo {
        eprintln!("note: --slo gates soak only with --check ({})", slo.spec());
    }
    write_obs_outputs(&outs)?;
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let model = load_model()?;
    let opt = OptLevel::parse(&args.opt_or("opt", "full"))?;
    let n = args.opt_usize("n", 40)?;
    let skip = args.opt_usize("skip", 0)? as u64;
    let program = build_kws_program(&model, opt)?;
    // Stage a deterministic utterance so the trace reflects a real run.
    let mut prog = program;
    let audio = dataset::synth_utterance(3, 1, model.audio_len, 0.37);
    let q = cimrv::model::reference::quantize_audio(&audio);
    let mut bytes = Vec::with_capacity(q.len() * 2);
    for v in &q {
        bytes.extend_from_slice(&(*v as i16).to_le_bytes());
    }
    prog.dram.push((cimrv::dataflow::plan::DRAM_AUDIO, bytes));
    let entries = cimrv::sim::trace::trace_program(&prog, skip, n)?;
    if let Some(path) = args.opt("trace-out") {
        std::fs::write(path, cimrv::sim::trace::render_jsonl(&entries))
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {path} ({} instructions, JSON lines)", entries.len());
    } else {
        for e in &entries {
            println!("{}", e.render());
        }
    }
    Ok(())
}

fn cmd_disasm(args: &Args) -> Result<()> {
    for p in &args.positional {
        let w = u32::from_str_radix(p.trim_start_matches("0x"), 16)
            .with_context(|| format!("parsing {p}"))?;
        match cimrv::isa::decode(w) {
            Ok(i) => println!("{p}: {}", cimrv::isa::disasm(&i)),
            Err(e) => println!("{p}: <illegal: {e}>"),
        }
    }
    Ok(())
}
