//! Lock-cheap metrics registry: counters, gauges, fixed-bucket
//! histograms behind atomics.
//!
//! The design point is the *disabled* cost: every metric operation
//! starts with one relaxed load of a process-global flag and returns
//! immediately when telemetry is off, so the kernel hot loops (fsim
//! popcount batches, the coordinator drain loop) pay ~one predicted
//! branch. When enabled, updates are single `Relaxed` atomic RMWs on
//! per-metric cache lines — no locks on the record path. The only lock
//! in the subsystem guards metric *registration* (get-or-create by
//! name), which callers do once and cache the `Arc` handle.
//!
//! Exposition is pull-style: [`Registry::render_prometheus`] emits the
//! text format, [`Registry::to_json`] the same data through
//! [`util::json`](crate::util::json) for `--metrics-out` dumps and the
//! bench artifacts.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;
use crate::util::lock_or_recover;

/// Process-global enable flag (the "global-off fast path").
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is telemetry recording enabled?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn telemetry recording on or off process-wide. Handles stay
/// valid either way; disabled metrics simply stop moving.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if !enabled() {
            return;
        }
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.value.store(0, Ordering::Relaxed);
    }
}

/// A last-write-wins gauge (f64 stored as bits).
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    #[inline]
    pub fn set(&self, v: f64) {
        if !enabled() {
            return;
        }
        self.bits.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    fn reset(&self) {
        self.bits.store(0, Ordering::Relaxed);
    }
}

/// A fixed-bucket histogram over `u64` samples (we only histogram
/// microsecond durations and small integer sizes, so integer samples
/// keep the sum atomic and exact).
///
/// `bounds` are inclusive upper bounds of the finite buckets; one
/// implicit +Inf bucket catches the rest, Prometheus-style.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<u64>,
    counts: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    fn new(mut bounds: Vec<u64>) -> Self {
        bounds.sort_unstable();
        bounds.dedup();
        let counts = (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect();
        Histogram { bounds, counts, sum: AtomicU64::new(0), count: AtomicU64::new(0) }
    }

    /// Default bucket ladder for microsecond durations: 1µs .. ~16s,
    /// powers of four.
    pub fn us_bounds() -> Vec<u64> {
        (0..13).map(|i| 4u64.pow(i)).collect()
    }

    /// Fine-grained microsecond ladder: 1µs .. ~1s, powers of two —
    /// for sub-100µs populations (kernel regions, fast-path executes)
    /// where the powers-of-four ladder collapses everything into two
    /// or three buckets.
    pub fn fine_us_bounds() -> Vec<u64> {
        (0..=20).map(|i| 1u64 << i).collect()
    }

    #[inline]
    pub fn observe(&self, v: u64) {
        if !enabled() {
            return;
        }
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Mean sample, or `None` when nothing was observed (no divide by
    /// zero, no NaN in reports).
    pub fn mean(&self) -> Option<f64> {
        let n = self.count();
        if n == 0 {
            None
        } else {
            Some(self.sum() as f64 / n as f64)
        }
    }

    /// Cumulative bucket counts as `(upper_bound, count)`, the +Inf
    /// bucket last with `None` as its bound.
    pub fn cumulative(&self) -> Vec<(Option<u64>, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, c) in self.counts.iter().enumerate() {
            acc += c.load(Ordering::Relaxed);
            out.push((self.bounds.get(i).copied(), acc));
        }
        out
    }

    fn reset(&self) {
        for c in &self.counts {
            c.store(0, Ordering::Relaxed);
        }
        self.sum.store(0, Ordering::Relaxed);
        self.count.store(0, Ordering::Relaxed);
    }
}

/// One registered metric.
#[derive(Debug, Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A named collection of metrics. Registration (get-or-create) takes
/// the registry lock; recording through the returned handles does not.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
    help: Mutex<BTreeMap<String, String>>,
}

/// Sanitize a metric name into the Prometheus grammar
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`): invalid characters become `_` and a
/// leading digit gets a `_` prefix. The registry's dotted namespaces
/// keep their historical mapping (`serve.requests` → `serve_requests`).
fn sanitize_metric_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 1);
    for (i, c) in name.chars().enumerate() {
        let valid = c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit());
        if valid {
            out.push(c);
        } else if i == 0 && c.is_ascii_digit() {
            out.push('_');
            out.push(c);
        } else {
            out.push('_');
        }
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Escape a label value for the text exposition (`\` → `\\`, `"` →
/// `\"`, newline → `\n`). Today only `le` flows through here, but any
/// future labelled metric must use it too.
fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Escape `# HELP` text (`\` → `\\`, newline → `\n`).
fn escape_help(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Help strings for the well-known metric namespaces; [`Registry::
/// describe`] overrides, anything else falls back to a generic line.
fn builtin_help(name: &str) -> Option<&'static str> {
    Some(match name {
        "serve.requests" => "Requests admitted by the coordinator",
        "serve.batches" => "Micro-batches executed by the workers",
        "serve.retries" => "Batch attempts retried after transient faults",
        "serve.shed.overload" => "Requests shed at admission (queue full)",
        "serve.shed.deadline" => "Requests shed on an expired deadline",
        "serve.host_latency_us" => "End-to-end host latency per served request (us)",
        "serve.execute_us" => "Backend run_batch wall time per batch (us)",
        "serve.queue_depth" => "Request queue depth at last admission",
        "serve.linger_window_us" => "Micro-batch linger window in effect (us)",
        "backend.fast.batches" => "Batches executed by the fast backend",
        "backend.fast.inferences" => "Inferences executed by the fast backend",
        "backend.fast.execute_us" => "Fast-backend run_batch wall time (us)",
        "backend.cycle.batches" => "Batches executed by the cycle backend",
        "backend.cycle.inferences" => "Inferences executed by the cycle backend",
        "backend.cycle.execute_us" => "Cycle-backend run_batch wall time (us)",
        "sweep.point_us" => "Robustness-sweep grid point wall time (us)",
        "sweep.points_per_s" => "Robustness-sweep throughput (grid points/s)",
        "slo.availability" => "Rolling-window served fraction vs the SLO target",
        "slo.p99_us" => "Rolling-window p99 host latency (us)",
        "slo.burn_rate" => "Error-budget burn rate (1.0 = on budget)",
        _ => return None,
    })
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get or create the counter `name`. Panics if `name` is already
    /// registered as a different metric kind (a wiring bug, not input).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = lock_or_recover(&self.metrics);
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => Arc::clone(c),
            other => panic!("metric {name:?} already registered as {other:?}"),
        }
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = lock_or_recover(&self.metrics);
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            other => panic!("metric {name:?} already registered as {other:?}"),
        }
    }

    /// Get or create the histogram `name` with the given finite bucket
    /// bounds (ignored when the histogram already exists).
    pub fn histogram(&self, name: &str, bounds: Vec<u64>) -> Arc<Histogram> {
        let mut m = lock_or_recover(&self.metrics);
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(bounds))))
        {
            Metric::Histogram(h) => Arc::clone(h),
            other => panic!("metric {name:?} already registered as {other:?}"),
        }
    }

    /// Zero every registered metric (benches/tests; handles stay live).
    pub fn reset(&self) {
        for metric in lock_or_recover(&self.metrics).values() {
            match metric {
                Metric::Counter(c) => c.reset(),
                Metric::Gauge(g) => g.reset(),
                Metric::Histogram(h) => h.reset(),
            }
        }
    }

    /// Attach a `# HELP` string to a metric name (raw dotted name, not
    /// the sanitized form). Well-known namespaces have built-in help;
    /// this overrides or extends it for custom metrics.
    pub fn describe(&self, name: &str, help: &str) {
        lock_or_recover(&self.help).insert(name.to_string(), help.to_string());
    }

    fn help_for(&self, name: &str) -> String {
        if let Some(h) = lock_or_recover(&self.help).get(name) {
            return h.clone();
        }
        builtin_help(name).map(str::to_string).unwrap_or_else(|| format!("cimrv metric {name}"))
    }

    /// Prometheus text exposition: `# HELP` + `# TYPE` per metric,
    /// names sanitized into the exposition grammar (the registry
    /// namespaces with dots, e.g. `serve.requests` → `serve_requests`),
    /// label values escaped.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (name, metric) in lock_or_recover(&self.metrics).iter() {
            let n = sanitize_metric_name(name);
            let help = escape_help(&self.help_for(name));
            out.push_str(&format!("# HELP {n} {help}\n"));
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!("# TYPE {n} histogram\n"));
                    for (bound, cum) in h.cumulative() {
                        let le = match bound {
                            Some(b) => b.to_string(),
                            None => "+Inf".to_string(),
                        };
                        let le = escape_label_value(&le);
                        out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cum}\n"));
                    }
                    out.push_str(&format!("{n}_sum {}\n{n}_count {}\n", h.sum(), h.count()));
                }
            }
        }
        out
    }

    /// JSON snapshot for `--metrics-out` and bench artifacts.
    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (name, metric) in lock_or_recover(&self.metrics).iter() {
            let v = match metric {
                Metric::Counter(c) => Json::obj(vec![
                    ("type", Json::str("counter")),
                    ("value", Json::num(c.get() as f64)),
                ]),
                Metric::Gauge(g) => Json::obj(vec![
                    ("type", Json::str("gauge")),
                    ("value", Json::num(g.get())),
                ]),
                Metric::Histogram(h) => {
                    let buckets = h
                        .cumulative()
                        .into_iter()
                        .map(|(bound, cum)| {
                            Json::obj(vec![
                                (
                                    "le",
                                    match bound {
                                        Some(b) => Json::num(b as f64),
                                        None => Json::str("+Inf"),
                                    },
                                ),
                                ("count", Json::num(cum as f64)),
                            ])
                        })
                        .collect();
                    Json::obj(vec![
                        ("type", Json::str("histogram")),
                        ("sum", Json::num(h.sum() as f64)),
                        ("count", Json::num(h.count() as f64)),
                        ("mean", h.mean().map(Json::num).unwrap_or(Json::Null)),
                        ("buckets", Json::Arr(buckets)),
                    ])
                }
            };
            obj.insert(name.clone(), v);
        }
        Json::Obj(obj)
    }
}

/// The process-wide registry every subsystem records into.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::with_telemetry;

    #[test]
    fn disabled_metrics_do_not_move() {
        // Inside the guard so no parallel test re-enables mid-check.
        with_telemetry(|| {
            set_enabled(false);
            let r = Registry::new();
            let c = r.counter("t.count");
            let g = r.gauge("t.gauge");
            let h = r.histogram("t.hist", vec![10, 100]);
            c.inc();
            g.set(3.5);
            h.observe(7);
            assert_eq!(c.get(), 0);
            assert_eq!(g.get(), 0.0);
            assert_eq!(h.count(), 0);
            assert!(h.mean().is_none());
        });
    }

    #[test]
    fn records_and_renders_when_enabled() {
        with_telemetry(|| {
            let r = Registry::new();
            let c = r.counter("t.count");
            let g = r.gauge("t.gauge");
            let h = r.histogram("t.hist", vec![10, 100]);
            c.add(3);
            g.set(2.5);
            for v in [1, 10, 11, 1000] {
                h.observe(v);
            }
            assert_eq!(c.get(), 3);
            assert_eq!(g.get(), 2.5);
            assert_eq!(h.count(), 4);
            assert_eq!(h.sum(), 1022);
            // Buckets are cumulative: le=10 catches 1 and 10, le=100
            // adds 11, +Inf adds 1000.
            assert_eq!(h.cumulative(), vec![(Some(10), 2), (Some(100), 3), (None, 4)]);

            let prom = r.render_prometheus();
            assert!(prom.contains("# TYPE t_count counter"));
            assert!(prom.contains("t_hist_bucket{le=\"+Inf\"} 4"));
            assert!(prom.contains("t_hist_sum 1022"));

            let j = r.to_json();
            assert_eq!(j.path(&["t.count", "value"]).unwrap().as_usize().unwrap(), 3);
            assert_eq!(j.path(&["t.hist", "count"]).unwrap().as_usize().unwrap(), 4);
            // The snapshot round-trips through the parser.
            assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        });
    }

    #[test]
    fn get_or_create_returns_the_same_metric() {
        with_telemetry(|| {
            let r = Registry::new();
            r.counter("same").add(1);
            r.counter("same").add(2);
            assert_eq!(r.counter("same").get(), 3);
            r.reset();
            assert_eq!(r.counter("same").get(), 0);
        });
    }

    #[test]
    fn empty_histogram_renders_without_panicking() {
        let r = Registry::new();
        let _ = r.histogram("h.empty", Histogram::us_bounds());
        let prom = r.render_prometheus();
        assert!(prom.contains("h_empty_count 0"));
        let j = r.to_json();
        assert_eq!(j.path(&["h.empty", "mean"]).unwrap(), &Json::Null);
    }

    #[test]
    fn prometheus_exposition_has_help_and_sanitized_names() {
        with_telemetry(|| {
            let r = Registry::new();
            r.counter("serve.requests").add(2);
            r.counter("9weird name/metric").inc();
            r.describe("9weird name/metric", "custom help\nwith a newline");
            let prom = r.render_prometheus();
            // Built-in help for the well-known namespace.
            assert!(prom.contains("# HELP serve_requests Requests admitted by the coordinator"));
            assert!(prom.contains("# TYPE serve_requests counter"));
            // Invalid characters sanitized, leading digit prefixed,
            // help newline escaped.
            assert!(prom.contains("# HELP _9weird_name_metric custom help\\nwith a newline"));
            assert!(prom.contains("_9weird_name_metric 1"));
            // Unknown names still get a HELP line.
            r.gauge("totally.new").set(1.0);
            assert!(r.render_prometheus().contains("# HELP totally_new cimrv metric totally.new"));
        });
    }

    #[test]
    fn sanitize_and_escape_helpers() {
        assert_eq!(sanitize_metric_name("serve.requests"), "serve_requests");
        assert_eq!(sanitize_metric_name("a-b.c"), "a_b_c");
        assert_eq!(sanitize_metric_name("7up"), "_7up");
        assert_eq!(sanitize_metric_name(""), "_");
        assert_eq!(sanitize_metric_name("ok:name_1"), "ok:name_1");
        assert_eq!(escape_label_value("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(escape_help("a\\b\nc"), "a\\\\b\\nc");
    }

    #[test]
    fn fine_bounds_resolve_sub_100us_populations() {
        with_telemetry(|| {
            let bounds = Histogram::fine_us_bounds();
            assert_eq!(bounds.first(), Some(&1));
            assert_eq!(bounds.last(), Some(&(1 << 20)));
            let r = Registry::new();
            let h = r.histogram("fine.us", Histogram::fine_us_bounds());
            for v in [3, 5, 40, 90] {
                h.observe(v);
            }
            // Powers of two separate 40 from 90 (bounds 64/128); the
            // us_bounds powers-of-four ladder would merge them at 64.
            let cum = h.cumulative();
            let at = |b: u64| cum.iter().find(|(bb, _)| *bb == Some(b)).unwrap().1;
            assert_eq!(at(4), 1);
            assert_eq!(at(64), 3);
            assert_eq!(at(128), 4);
        });
    }
}
