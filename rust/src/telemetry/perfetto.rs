//! Chrome trace-event (Perfetto) exporter.
//!
//! Emits the JSON object format (`{"traceEvents": [...]}`) that
//! Perfetto's UI and chrome://tracing both load. Two producers feed it:
//!
//! * **Serving tracks** from the coordinator's [`RequestSpan`]s — one
//!   thread track per worker (assemble / execute / respond slices, with
//!   per-macro sub-slices apportioned from `shard_fires`), plus one
//!   track per request showing its queued → execute → respond life.
//! * **Engine tracks** from a `RunResult`'s MMIO phase markers — the
//!   same `(id, cycle)` stream `PhaseBreakdown` attributes, rendered as
//!   a phase track plus one track per CIM macro showing which layer
//!   spans it loads weights for and fires in. Cycles convert to wall
//!   microseconds at the paper's 50 MHz clock, so chip and host tracks
//!   share a time axis.
//!
//! Every event — including the `"M"` metadata naming events — carries
//! `ph`/`ts`/`pid`/`tid`, which the schema smoke test relies on.

use crate::compiler::Program;
use crate::util::json::Json;

use super::spans::RequestSpan;
#[cfg(test)]
use super::spans::SpanOutcome;

/// The paper's system clock: cycles → µs divisor (re-exported from the
/// single source of truth, [`crate::clock`]).
pub use crate::clock::CLOCK_MHZ;

/// Trace process ids (one per logical timeline).
pub const PID_SERVE: u64 = 1;
pub const PID_REQUESTS: u64 = 2;
pub const PID_ENGINE: u64 = 3;
pub const PID_PROFILER: u64 = 4;

/// Thread id on the serve process reserved for incident instants (well
/// above any realistic worker id, below none that exist).
pub const TID_INCIDENTS: u64 = 95;

/// Builds a Chrome trace-event JSON document.
#[derive(Debug, Default)]
pub struct TraceBuilder {
    events: Vec<Json>,
}

impl TraceBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    fn meta(&mut self, what: &str, pid: u64, tid: u64, name: &str) {
        self.events.push(Json::obj(vec![
            ("name", Json::str(what)),
            ("ph", Json::str("M")),
            ("ts", Json::num(0.0)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]));
    }

    /// Name a process track.
    pub fn process_name(&mut self, pid: u64, name: &str) {
        self.meta("process_name", pid, 0, name);
    }

    /// Name a thread track within a process.
    pub fn thread_name(&mut self, pid: u64, tid: u64, name: &str) {
        self.meta("thread_name", pid, tid, name);
    }

    /// Add a complete (`ph:"X"`) slice. Timestamps/durations in µs.
    pub fn complete(
        &mut self,
        pid: u64,
        tid: u64,
        name: &str,
        cat: &str,
        ts_us: f64,
        dur_us: f64,
        args: Vec<(&str, Json)>,
    ) {
        self.events.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str(cat)),
            ("ph", Json::str("X")),
            ("ts", Json::num(ts_us)),
            ("dur", Json::num(dur_us.max(0.0))),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(args)),
        ]));
    }

    /// Add a counter (`ph:"C"`) sample. Perfetto renders consecutive
    /// samples sharing one `(pid, name)` pair as a stepped area chart.
    pub fn counter(&mut self, pid: u64, name: &str, ts_us: f64, value: f64) {
        self.events.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("ph", Json::str("C")),
            ("ts", Json::num(ts_us)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("value", Json::num(value))])),
        ]));
    }

    /// Add an instant (`ph:"i"`, thread-scoped) event.
    pub fn instant(
        &mut self,
        pid: u64,
        tid: u64,
        name: &str,
        cat: &str,
        ts_us: f64,
        args: Vec<(&str, Json)>,
    ) {
        self.events.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str(cat)),
            ("ph", Json::str("i")),
            ("s", Json::str("t")),
            ("ts", Json::num(ts_us)),
            ("pid", Json::num(pid as f64)),
            ("tid", Json::num(tid as f64)),
            ("args", Json::obj(args)),
        ]));
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The finished trace document.
    pub fn build(self) -> Json {
        Json::obj(vec![
            ("traceEvents", Json::Arr(self.events)),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }
}

/// Human label for a phase-marker id (the `PhaseBreakdown` scheme:
/// 1 = boot, 2 = preprocess, 10..=29 weights per layer, 30..=39 conv
/// per layer, 40..=49 fused pool-drain overlap start per layer —
/// matched before the conv arm, since the two ranges share the
/// `from_markers` conv bucket — anything else tail work).
fn marker_label(id: u32) -> String {
    match id {
        1 => "boot".to_string(),
        2 => "preprocess".to_string(),
        10..=29 => format!("weights L{}", id - 10),
        40..=49 => format!("pool drain L{}", id - 40),
        30..=49 => format!("conv L{}", id - 30),
        other => format!("marker {other}"),
    }
}

/// Render an engine run's phase/fire schedule: a phase track plus one
/// track per macro. `markers` is the `(id, end_cycle)` stream a
/// `RunResult` carries; each marker closes the span since its
/// predecessor, exactly like `PhaseBreakdown::from_markers`.
pub fn engine_tracks(
    tb: &mut TraceBuilder,
    program: &Program,
    markers: &[(u32, u64)],
    total_cycles: u64,
) {
    let us = |cycles: u64| cycles as f64 / CLOCK_MHZ;
    let n_macros = program.shards.n_macros;
    tb.process_name(PID_ENGINE, "cim engine (cycles @ 50 MHz)");
    tb.thread_name(PID_ENGINE, 0, "phases");
    for m in 0..n_macros {
        tb.thread_name(PID_ENGINE, 1 + m as u64, &format!("macro {m}"));
    }

    // Fused programs mark the first pooled row drain of layer `l`'s conv
    // phase with id `40 + l`: it *opens* an overlap window (drains ride
    // along with the remaining fires) that the layer's conv-done marker
    // (`30 + l`) closes. The open markers don't split the phase track —
    // their cycles are conv work, same as `PhaseBreakdown::from_markers`
    // folds them — they become concurrent per-macro pool-drain slices.
    let mut drain_open: [Option<u64>; 10] = [None; 10];
    let mut prev = 0u64;
    for &(id, at) in markers {
        if let 40..=49 = id {
            drain_open[(id - 40) as usize % 10] = Some(at);
            continue;
        }
        let (ts, dur) = (us(prev), us(at.saturating_sub(prev)));
        tb.complete(
            PID_ENGINE,
            0,
            &marker_label(id),
            "phase",
            ts,
            dur,
            vec![("cycles", Json::num(at.saturating_sub(prev) as f64))],
        );
        // Per-macro sub-tracks: weight loads and fire windows land on
        // the macros that own channels of the marker's layer.
        let layer = match id {
            10..=29 => Some((id - 10) as usize, "load"),
            30..=49 => Some((id - 30) as usize, "fire"),
            _ => None,
        };
        if let Some((l, kind)) = layer {
            if let Some(ls) = program.shards.layers.iter().find(|ls| ls.index == l) {
                let fires =
                    program.plan.layers.get(l).map(|lp| lp.t_in).unwrap_or(0);
                let drain_from = if kind == "fire" && l < 10 {
                    drain_open[l].take()
                } else {
                    None
                };
                for (m, c0, c1) in ls.non_empty() {
                    let mut args = vec![
                        ("channels", Json::num((c1 - c0) as f64)),
                        ("range", Json::str(format!("c{c0}..c{c1}"))),
                    ];
                    if kind == "fire" {
                        args.push(("fires", Json::num(fires as f64)));
                    }
                    tb.complete(
                        PID_ENGINE,
                        1 + m as u64,
                        &format!("L{l} {kind}"),
                        kind,
                        ts,
                        dur,
                        args,
                    );
                    // The fused conv/max-pool pipeline: pooled drains run
                    // concurrently with the tail of the fire window.
                    if let Some(t1) = drain_from {
                        tb.complete(
                            PID_ENGINE,
                            1 + m as u64,
                            &format!("L{l} pool drain"),
                            "pool",
                            us(t1),
                            us(at.saturating_sub(t1)),
                            vec![("overlapped_with", Json::str(format!("L{l} fire")))],
                        );
                    }
                }
            }
        }
        prev = at;
    }
    if total_cycles > prev {
        tb.complete(
            PID_ENGINE,
            0,
            "tail",
            "phase",
            us(prev),
            us(total_cycles - prev),
            vec![("cycles", Json::num((total_cycles - prev) as f64))],
        );
    }
}

/// Render the coordinator's batching timeline: one thread track per
/// worker (assemble/execute/respond per batch, with per-macro execute
/// sub-slices apportioned from `shard_fires`) and one track per request
/// (capped at `max_request_tracks` to bound trace size).
pub fn serving_tracks(tb: &mut TraceBuilder, spans: &[RequestSpan], max_request_tracks: usize) {
    if spans.is_empty() {
        return;
    }
    tb.process_name(PID_SERVE, "cimrv-serve workers");
    tb.process_name(PID_REQUESTS, "requests");
    // Spans shed at admission carry `worker == usize::MAX` (no worker
    // ever saw them): they get a request-track slice below but must not
    // fabricate a worker thread or join a batch.
    let mut workers: Vec<usize> =
        spans.iter().map(|s| s.worker).filter(|&w| w != usize::MAX).collect();
    workers.sort_unstable();
    workers.dedup();
    for &w in &workers {
        tb.thread_name(PID_SERVE, w as u64, &format!("worker {w}"));
    }

    // One batch = every span sharing (worker, exec_start). Spans arrive
    // sorted by req_id; batches keep first-seen order.
    let mut batches: Vec<(usize, u64, Vec<&RequestSpan>)> = Vec::new();
    for s in spans {
        if s.worker == usize::MAX {
            continue;
        }
        match batches.iter_mut().find(|(w, x, _)| *w == s.worker && *x == s.exec_start_us) {
            Some((_, _, members)) => members.push(s),
            None => batches.push((s.worker, s.exec_start_us, vec![s])),
        }
    }

    for (w, _, members) in &batches {
        let lead = members[0];
        let n = members.len();
        let tid = *w as u64;
        let batch_args = |extra: Vec<(&'static str, Json)>| {
            let mut v = vec![("batch_size", Json::num(n as f64))];
            v.extend(extra);
            v
        };
        tb.complete(
            PID_SERVE,
            tid,
            &format!("assemble[{n}]"),
            "assemble",
            lead.assembly_start_us as f64,
            lead.assembled_us.saturating_sub(lead.assembly_start_us) as f64,
            batch_args(vec![]),
        );
        let exec_dur = lead.execute_us();
        tb.complete(
            PID_SERVE,
            tid,
            &format!("execute[{n}]"),
            "execute",
            lead.exec_start_us as f64,
            exec_dur as f64,
            batch_args(vec![("req_ids", Json::Arr(
                members.iter().map(|s| Json::num(s.req_id as f64)).collect(),
            ))]),
        );
        // Apportion the execute slice across macros by fire share —
        // host time isn't measured per macro, but the fire counts say
        // where the chip's work went.
        let fires = &lead.shard_fires;
        let total_fires: u64 = fires.iter().sum();
        if total_fires > 0 && fires.len() > 1 {
            let mut at = lead.exec_start_us as f64;
            for (m, &f) in fires.iter().enumerate() {
                if f == 0 {
                    continue;
                }
                let dur = exec_dur as f64 * f as f64 / total_fires as f64;
                tb.complete(
                    PID_SERVE,
                    tid,
                    &format!("shard {m}"),
                    "shard",
                    at,
                    dur,
                    vec![("fires", Json::num(f as f64))],
                );
                at += dur;
            }
        }
        let respond_end = members.iter().map(|s| s.respond_us).max().unwrap_or(lead.exec_end_us);
        tb.complete(
            PID_SERVE,
            tid,
            &format!("respond[{n}]"),
            "respond",
            lead.exec_end_us as f64,
            respond_end.saturating_sub(lead.exec_end_us) as f64,
            batch_args(vec![]),
        );
    }

    // Per-request lifecycle tracks.
    for s in spans.iter().take(max_request_tracks) {
        let tid = s.req_id;
        tb.thread_name(PID_REQUESTS, tid, &format!("req {}", s.req_id));
        if s.worker == usize::MAX {
            // Rejected at admission: one instantaneous "shed" slice is
            // the whole lifecycle.
            tb.complete(
                PID_REQUESTS,
                tid,
                "shed",
                "shed",
                s.enqueue_us as f64,
                0.0,
                vec![("outcome", Json::str(s.outcome.as_str()))],
            );
            continue;
        }
        tb.complete(
            PID_REQUESTS,
            tid,
            "queued",
            "queue",
            s.enqueue_us as f64,
            s.queue_us() as f64,
            vec![("worker", Json::num(s.worker as f64))],
        );
        tb.complete(
            PID_REQUESTS,
            tid,
            "execute",
            "execute",
            s.exec_start_us as f64,
            s.execute_us() as f64,
            vec![("batch_size", Json::num(s.batch_size as f64))],
        );
        tb.complete(
            PID_REQUESTS,
            tid,
            "respond",
            "respond",
            s.exec_end_us as f64,
            s.respond_us.saturating_sub(s.exec_end_us) as f64,
            vec![("outcome", Json::str(s.outcome.as_str()))],
        );
    }
}

/// Derive counter tracks from the request spans: a queue-depth series
/// (each admitted request raises depth at enqueue and lowers it when a
/// worker starts assembling its batch) and a per-worker batch-size
/// series sampled when each batch starts executing.
pub fn counter_tracks(tb: &mut TraceBuilder, spans: &[RequestSpan]) {
    if spans.is_empty() {
        return;
    }
    // Queue depth: +1 at enqueue, -1 at assembly start, for every span a
    // worker eventually picked up. Admission-shed spans (worker ==
    // usize::MAX) never occupied the queue.
    let mut deltas: Vec<(u64, i64)> = Vec::new();
    for s in spans {
        if s.worker == usize::MAX {
            continue;
        }
        deltas.push((s.enqueue_us, 1));
        deltas.push((s.assembly_start_us, -1));
    }
    deltas.sort_unstable();
    let mut depth = 0i64;
    let mut i = 0;
    while i < deltas.len() {
        let ts = deltas[i].0;
        while i < deltas.len() && deltas[i].0 == ts {
            depth += deltas[i].1;
            i += 1;
        }
        tb.counter(PID_SERVE, "queue depth", ts as f64, depth as f64);
    }
    // Batch sizes: one sample per batch (first member seen), one counter
    // series per worker so the step charts don't interleave.
    let mut seen: Vec<(usize, u64)> = Vec::new();
    for s in spans {
        if s.worker == usize::MAX || seen.contains(&(s.worker, s.exec_start_us)) {
            continue;
        }
        seen.push((s.worker, s.exec_start_us));
        let n = spans
            .iter()
            .filter(|t| t.worker == s.worker && t.exec_start_us == s.exec_start_us)
            .count();
        tb.counter(
            PID_SERVE,
            &format!("batch size w{}", s.worker),
            s.exec_start_us as f64,
            n as f64,
        );
    }
}

/// Render profiler slices into their own process: one thread track per
/// profiled OS thread, nesting reconstructed from the recorded depth.
pub fn profiler_tracks(tb: &mut TraceBuilder, slices: &[super::profiler::ProfSlice]) {
    if slices.is_empty() {
        return;
    }
    tb.process_name(PID_PROFILER, "profiler (self-time regions)");
    let mut tids: Vec<usize> = slices.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for &t in &tids {
        tb.thread_name(PID_PROFILER, t as u64, &format!("profiled thread {t}"));
    }
    for s in slices {
        tb.complete(
            PID_PROFILER,
            s.tid as u64,
            &s.name,
            "profile",
            s.start_us as f64,
            s.dur_us as f64,
            vec![
                ("path", Json::str(s.path.as_str())),
                ("depth", Json::num(s.depth as f64)),
            ],
        );
    }
}

/// Render incident-log events as instants on a dedicated serve-process
/// thread, so breaker trips / sheds / respawns line up against the
/// worker and request tracks they explain.
pub fn incident_tracks(tb: &mut TraceBuilder, events: &[super::events::IncidentEvent]) {
    if events.is_empty() {
        return;
    }
    tb.thread_name(PID_SERVE, TID_INCIDENTS, "incidents");
    for e in events {
        let mut args = vec![
            ("seq", Json::num(e.seq as f64)),
            ("detail", Json::str(e.detail.as_str())),
        ];
        if let Some(w) = e.worker {
            args.push(("worker", Json::num(w as f64)));
        }
        if let Some(r) = e.req_id {
            args.push(("req_id", Json::num(r as f64)));
        }
        tb.instant(
            PID_SERVE,
            TID_INCIDENTS,
            e.kind.as_str(),
            "incident",
            e.ts_us as f64,
            args,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program_sharded;
    use crate::model::KwsModel;

    fn assert_event_schema(doc: &Json) -> usize {
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        for e in events {
            for key in ["ph", "ts", "pid", "tid"] {
                assert!(e.get(key).is_ok(), "event missing {key}: {e}");
            }
        }
        events.len()
    }

    #[test]
    fn engine_tracks_cover_phases_and_macros() {
        let m = KwsModel::synthetic(3);
        let prog = build_kws_program_sharded(&m, OptLevel::FULL, 2).unwrap();
        // boot @100, preprocess @400, L0 weights @600, L0 conv @900.
        let markers = vec![(1, 100), (2, 400), (10, 600), (30, 900)];
        let mut tb = TraceBuilder::new();
        engine_tracks(&mut tb, &prog, &markers, 1000);
        let doc = tb.build();
        let n = assert_event_schema(&doc);
        assert!(n > 0);
        let text = doc.to_string();
        assert!(text.contains("conv L0"));
        assert!(text.contains("macro 0"));
        assert!(text.contains("macro 1"));
        assert!(text.contains("\"tail\""));
        // 100 cycles of boot = 2µs at 50 MHz.
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let boot = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok().as_deref() == Some("boot"))
            .unwrap();
        assert_eq!(boot.get("dur").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn fused_pool_drain_markers_render_concurrent_slices() {
        let m = KwsModel::synthetic(3);
        let prog = build_kws_program_sharded(&m, OptLevel::FULL, 2).unwrap();
        // boot @100, preprocess @400, L0 weights @600, first pooled drain
        // @700 (opens the overlap window), L0 conv done @900.
        let markers = vec![(1, 100), (2, 400), (10, 600), (40, 700), (30, 900)];
        let mut tb = TraceBuilder::new();
        engine_tracks(&mut tb, &prog, &markers, 1000);
        let doc = tb.build();
        assert_event_schema(&doc);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let named = |want: &str| -> Vec<&Json> {
            events
                .iter()
                .filter(|e| {
                    e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok().as_deref()
                        == Some(want)
                })
                .collect()
        };
        // The open marker never splits the phase track: conv L0 runs
        // 600..900 = 6µs starting at 12µs, exactly as from_markers folds
        // the drain cycles into the conv bucket.
        let conv = named("conv L0");
        assert_eq!(conv.len(), 1);
        assert_eq!(conv[0].get("ts").unwrap().as_f64().unwrap(), 12.0);
        assert_eq!(conv[0].get("dur").unwrap().as_f64().unwrap(), 6.0);
        // Both owning macros show the drain window 700..900 concurrent
        // with their fire slice.
        let drains = named("L0 pool drain");
        assert_eq!(drains.len(), 2, "one pool-drain slice per owning macro");
        for d in drains {
            assert_eq!(d.get("ts").unwrap().as_f64().unwrap(), 14.0);
            assert_eq!(d.get("dur").unwrap().as_f64().unwrap(), 4.0);
        }
        assert_eq!(named("L0 fire").len(), 2);
    }

    #[test]
    fn serving_tracks_group_batches_per_worker() {
        let span = |req_id: u64, worker: usize, exec_start_us: u64| RequestSpan {
            req_id,
            worker,
            batch_size: 2,
            enqueue_us: 5 + req_id,
            assembly_start_us: 10,
            assembled_us: 20,
            exec_start_us,
            exec_end_us: exec_start_us + 100,
            respond_us: exec_start_us + 110,
            shard_fires: vec![30, 10],
            outcome: SpanOutcome::Ok,
        };
        let spans = vec![span(0, 0, 30), span(1, 0, 30), span(2, 1, 40)];
        let mut tb = TraceBuilder::new();
        serving_tracks(&mut tb, &spans, 256);
        let doc = tb.build();
        assert_event_schema(&doc);
        let text = doc.to_string();
        // Worker 0's batch of two, worker 1's singleton.
        assert!(text.contains("execute[2]"));
        assert!(text.contains("execute[1]"));
        assert!(text.contains("worker 1"));
        // Shard sub-slices apportioned 75/25 from fires [30, 10].
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let shard0 = events
            .iter()
            .filter(|e| {
                e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok().as_deref()
                    == Some("shard 0")
            })
            .count();
        assert_eq!(shard0, 2);
        assert!(text.contains("req 2"));
        // Respond slices carry the lifecycle outcome.
        assert!(text.contains("\"outcome\""));
    }

    #[test]
    fn shed_spans_stay_off_worker_tracks() {
        let served = RequestSpan {
            req_id: 0,
            worker: 0,
            batch_size: 1,
            enqueue_us: 5,
            assembly_start_us: 10,
            assembled_us: 20,
            exec_start_us: 30,
            exec_end_us: 130,
            respond_us: 140,
            shard_fires: vec![10],
            outcome: SpanOutcome::Ok,
        };
        let shed = RequestSpan {
            req_id: 1,
            worker: usize::MAX,
            batch_size: 0,
            enqueue_us: 50,
            assembly_start_us: 50,
            assembled_us: 50,
            exec_start_us: 50,
            exec_end_us: 50,
            respond_us: 50,
            shard_fires: vec![],
            outcome: SpanOutcome::Shed,
        };
        let mut tb = TraceBuilder::new();
        serving_tracks(&mut tb, &[served, shed], 256);
        let doc = tb.build();
        assert_event_schema(&doc);
        let text = doc.to_string();
        // The shed request appears on its own track...
        assert!(text.contains("\"shed\""), "{text}");
        assert!(text.contains("req 1"), "{text}");
        // ...but no phantom worker thread or batch was fabricated.
        assert!(!text.contains(&format!("worker {}", usize::MAX)), "{text}");
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let executes = events
            .iter()
            .filter(|e| {
                e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok().as_deref()
                    == Some("execute[1]")
            })
            .count();
        assert_eq!(executes, 1, "only the served span forms a batch");
    }

    #[test]
    fn empty_inputs_build_empty_but_valid_docs() {
        let mut tb = TraceBuilder::new();
        serving_tracks(&mut tb, &[], 256);
        counter_tracks(&mut tb, &[]);
        profiler_tracks(&mut tb, &[]);
        incident_tracks(&mut tb, &[]);
        assert!(tb.is_empty());
        let doc = tb.build();
        assert_eq!(doc.get("traceEvents").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn counter_tracks_integrate_queue_depth_and_batch_sizes() {
        let span = |req_id: u64, worker: usize, enq: u64, asm: u64, exec: u64| RequestSpan {
            req_id,
            worker,
            batch_size: 2,
            enqueue_us: enq,
            assembly_start_us: asm,
            assembled_us: asm + 5,
            exec_start_us: exec,
            exec_end_us: exec + 100,
            respond_us: exec + 110,
            shard_fires: vec![10],
            outcome: SpanOutcome::Ok,
        };
        // Two requests queue up (depth 2), both drained into one batch
        // at 20µs; a third is shed at admission and must not count.
        let mut shed = span(2, usize::MAX, 12, 12, 12);
        shed.outcome = SpanOutcome::Shed;
        let spans = vec![span(0, 0, 5, 20, 30), span(1, 0, 10, 20, 30), shed];
        let mut tb = TraceBuilder::new();
        counter_tracks(&mut tb, &spans);
        let doc = tb.build();
        assert_event_schema(&doc);
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let samples: Vec<(f64, f64)> = events
            .iter()
            .filter(|e| {
                e.get("ph").and_then(|p| p.as_str().map(str::to_string)).ok().as_deref()
                    == Some("C")
                    && e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok().as_deref()
                        == Some("queue depth")
            })
            .map(|e| {
                (
                    e.get("ts").unwrap().as_f64().unwrap(),
                    e.path(&["args", "value"]).unwrap().as_f64().unwrap(),
                )
            })
            .collect();
        assert_eq!(samples, vec![(5.0, 1.0), (10.0, 2.0), (20.0, 0.0)]);
        // One batch-size sample for worker 0's batch of two.
        let batch = events
            .iter()
            .find(|e| {
                e.get("name").and_then(|n| n.as_str().map(str::to_string)).ok().as_deref()
                    == Some("batch size w0")
            })
            .unwrap();
        assert_eq!(batch.path(&["args", "value"]).unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(batch.get("ts").unwrap().as_f64().unwrap(), 30.0);
    }

    #[test]
    fn profiler_and_incident_tracks_carry_valid_phases() {
        use super::super::events::{IncidentEvent, IncidentKind};
        use super::super::profiler::ProfSlice;
        let slices = vec![
            ProfSlice {
                tid: 0,
                name: "infer".to_string(),
                path: "infer".to_string(),
                start_us: 10,
                dur_us: 100,
                depth: 0,
            },
            ProfSlice {
                tid: 0,
                name: "conv_l0".to_string(),
                path: "infer;conv_l0".to_string(),
                start_us: 12,
                dur_us: 40,
                depth: 1,
            },
        ];
        let ev = IncidentEvent {
            seq: 0,
            ts_us: 55,
            kind: IncidentKind::BreakerTrip,
            worker: Some(1),
            req_id: None,
            detail: "5 consecutive failures".to_string(),
        };
        let mut tb = TraceBuilder::new();
        profiler_tracks(&mut tb, &slices);
        incident_tracks(&mut tb, &[ev]);
        let doc = tb.build();
        assert_event_schema(&doc);
        let text = doc.to_string();
        assert!(text.contains("profiled thread 0"));
        assert!(text.contains("\"conv_l0\""));
        assert!(text.contains("infer;conv_l0"));
        assert!(text.contains("breaker_trip"));
        assert!(text.contains("\"incidents\""));
        // Instants carry the scope field chrome://tracing requires.
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let inst = events
            .iter()
            .find(|e| {
                e.get("ph").and_then(|p| p.as_str().map(str::to_string)).ok().as_deref()
                    == Some("i")
            })
            .unwrap();
        assert_eq!(inst.get("s").unwrap().as_str().unwrap(), "t");
        assert_eq!(inst.get("tid").unwrap().as_f64().unwrap(), TID_INCIDENTS as f64);
    }
}
