//! Scoped self-time profiler: nestable regions behind the global
//! telemetry switch.
//!
//! A [`region`] guard marks one stretch of work. Regions nest — opening
//! a region inside another attributes the child's wall time to the
//! child, and the parent's *self* time becomes its total minus its
//! children's totals (exactly, by construction: a parent accumulates
//! each closing child's duration and subtracts the sum when it closes
//! itself). Aggregation is per thread — each thread owns its frame
//! stack (a plain `RefCell`, no lock on open) and folds closed regions
//! into a `path → {total, self, count}` map shared with the global
//! [`Profiler`] — so the record path takes no cross-thread lock until
//! a region *closes*, and even then only an uncontended per-thread
//! mutex plus one bounded push into the slice buffer.
//!
//! Disabled cost is the telemetry contract: [`region`] is one relaxed
//! load of the global flag and an inert guard. The hot loops use
//! [`layer_name`] for per-layer region labels so the disabled path
//! never formats a string.
//!
//! Three exports, all from the same recorded data:
//!
//! * **Folded stacks** ([`Profiler::render_folded`]) — one line per
//!   distinct call path, `a;b;c <self_µs>`, directly consumable by
//!   `flamegraph.pl` / speedscope (`--profile-out FILE`).
//! * **Self/total table** ([`Profiler::render_table`]) — per region
//!   name, printed after `run`/`serve` when telemetry is on.
//! * **Perfetto slices** ([`Profiler::slices_snapshot`]) — bounded
//!   buffer of timestamped slices the trace exporter renders as its
//!   own process ([`perfetto::profiler_tracks`](super::perfetto)).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::lock_or_recover;

/// Upper bound on buffered Perfetto slices; past it, closes still
/// aggregate (folded stacks and the table stay exact) but no new
/// timeline slices are kept ([`Profiler::dropped_slices`] counts them).
pub const SLICE_CAP: usize = 16_384;

/// Aggregate for one distinct call path (`a;b;c`).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct PathStat {
    /// Total wall time spent with this path open, ns.
    pub total_ns: u64,
    /// Wall time minus time attributed to child regions, ns.
    pub self_ns: u64,
    /// Number of times this exact path closed.
    pub count: u64,
}

/// One region's self/total aggregate by leaf name (the table view).
#[derive(Debug, Clone, PartialEq)]
pub struct RegionRow {
    pub name: String,
    pub count: u64,
    pub total_us: u64,
    pub self_us: u64,
}

/// One closed region instance, timestamped for the Perfetto timeline.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfSlice {
    /// Profiler-assigned thread index (registration order).
    pub tid: usize,
    /// Leaf region name.
    pub name: String,
    /// Full `;`-joined path.
    pub path: String,
    /// µs since the profiler epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Nesting depth at open (0 = top level).
    pub depth: usize,
}

#[derive(Debug)]
struct ThreadAgg {
    tid: usize,
    agg: Mutex<BTreeMap<String, PathStat>>,
}

/// Process-wide sink the per-thread recorders register with.
#[derive(Debug)]
pub struct Profiler {
    epoch: Instant,
    threads: Mutex<Vec<Arc<ThreadAgg>>>,
    slices: Mutex<Vec<ProfSlice>>,
    dropped: AtomicU64,
    next_tid: AtomicUsize,
}

impl Profiler {
    fn new() -> Self {
        Profiler {
            epoch: Instant::now(),
            threads: Mutex::new(Vec::new()),
            slices: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
            next_tid: AtomicUsize::new(0),
        }
    }

    fn register(&self) -> Arc<ThreadAgg> {
        let t = Arc::new(ThreadAgg {
            tid: self.next_tid.fetch_add(1, Ordering::Relaxed),
            agg: Mutex::new(BTreeMap::new()),
        });
        lock_or_recover(&self.threads).push(Arc::clone(&t));
        t
    }

    /// Clear all recorded data (per-thread aggregates stay registered,
    /// so live threads keep recording into their cleared maps).
    pub fn reset(&self) {
        for t in lock_or_recover(&self.threads).iter() {
            lock_or_recover(&t.agg).clear();
        }
        lock_or_recover(&self.slices).clear();
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// Merge every thread's aggregates into one `path → stat` map.
    pub fn fold(&self) -> BTreeMap<String, PathStat> {
        let mut out: BTreeMap<String, PathStat> = BTreeMap::new();
        for t in lock_or_recover(&self.threads).iter() {
            for (path, s) in lock_or_recover(&t.agg).iter() {
                let e = out.entry(path.clone()).or_default();
                e.total_ns += s.total_ns;
                e.self_ns += s.self_ns;
                e.count += s.count;
            }
        }
        out
    }

    /// Has anything been recorded since the last reset?
    pub fn has_data(&self) -> bool {
        lock_or_recover(&self.threads)
            .iter()
            .any(|t| !lock_or_recover(&t.agg).is_empty())
    }

    /// Folded-stack text (`path self_µs`, one line per path) —
    /// flamegraph.pl / speedscope input. Region names never contain
    /// spaces, so the final space-separated field is always the value.
    pub fn render_folded(&self) -> String {
        let mut out = String::new();
        for (path, s) in self.fold() {
            out.push_str(&format!("{path} {}\n", s.self_ns / 1_000));
        }
        out
    }

    /// Per-region rows aggregated by leaf name, heaviest self time
    /// first. Totals for a name sum over every path it closes under.
    pub fn table(&self) -> Vec<RegionRow> {
        let mut by_name: BTreeMap<String, PathStat> = BTreeMap::new();
        for (path, s) in self.fold() {
            let leaf = path.rsplit(';').next().unwrap_or(&path).to_string();
            let e = by_name.entry(leaf).or_default();
            e.total_ns += s.total_ns;
            e.self_ns += s.self_ns;
            e.count += s.count;
        }
        let mut rows: Vec<RegionRow> = by_name
            .into_iter()
            .map(|(name, s)| RegionRow {
                name,
                count: s.count,
                total_us: s.total_ns / 1_000,
                self_us: s.self_ns / 1_000,
            })
            .collect();
        rows.sort_by(|a, b| b.self_us.cmp(&a.self_us).then(a.name.cmp(&b.name)));
        rows
    }

    /// The self/total table as printed after `run`/`serve`.
    pub fn render_table(&self) -> String {
        let rows = self.table();
        if rows.is_empty() {
            return String::new();
        }
        let mut out = String::from(
            "profile (self time, aggregated across threads):\n\
             region                     count    total µs     self µs\n",
        );
        for r in &rows {
            out.push_str(&format!(
                "  {:<24} {:>6} {:>11} {:>11}\n",
                r.name, r.count, r.total_us, r.self_us
            ));
        }
        let dropped = self.dropped_slices();
        if dropped > 0 {
            out.push_str(&format!("  ({dropped} timeline slices dropped past the {SLICE_CAP}-slice cap; aggregates stay exact)\n"));
        }
        out
    }

    /// Copy of the buffered timeline slices, in close order.
    pub fn slices_snapshot(&self) -> Vec<ProfSlice> {
        lock_or_recover(&self.slices).clone()
    }

    /// Slices discarded because the buffer hit [`SLICE_CAP`].
    pub fn dropped_slices(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// The process-wide profiler every [`region`] records into.
pub fn global_profiler() -> &'static Profiler {
    static GLOBAL: OnceLock<Profiler> = OnceLock::new();
    GLOBAL.get_or_init(Profiler::new)
}

struct Frame {
    name: String,
    start: Instant,
    child_ns: u64,
}

struct ThreadState {
    agg: Arc<ThreadAgg>,
    stack: Vec<Frame>,
}

thread_local! {
    static STATE: RefCell<Option<ThreadState>> = const { RefCell::new(None) };
}

/// RAII guard for one open region; closing (drop) does the accounting.
#[must_use = "a region measures the scope of its guard; bind it with `let _r = ...`"]
pub struct Region {
    active: bool,
}

/// Open a nestable profiling region. One relaxed load and an inert
/// guard when telemetry is disabled. `name` must not contain spaces or
/// semicolons (they would corrupt the folded-stack grammar); offenders
/// are recorded with the bad characters replaced by `_`.
pub fn region(name: &str) -> Region {
    if !crate::telemetry::enabled() {
        return Region { active: false };
    }
    let clean: String = name
        .chars()
        .map(|c| if c == ' ' || c == ';' { '_' } else { c })
        .collect();
    STATE.with(|s| {
        let mut s = s.borrow_mut();
        let st = s.get_or_insert_with(|| ThreadState {
            agg: global_profiler().register(),
            stack: Vec::new(),
        });
        st.stack.push(Frame { name: clean, start: Instant::now(), child_ns: 0 });
    });
    Region { active: true }
}

/// Static per-layer region labels (`conv_l0`, `conv_l1`, ...): keeps
/// the disabled hot path free of `format!` allocations.
pub fn layer_name(i: usize) -> &'static str {
    const NAMES: [&str; 16] = [
        "conv_l0", "conv_l1", "conv_l2", "conv_l3", "conv_l4", "conv_l5", "conv_l6", "conv_l7",
        "conv_l8", "conv_l9", "conv_l10", "conv_l11", "conv_l12", "conv_l13", "conv_l14",
        "conv_l15",
    ];
    NAMES.get(i).copied().unwrap_or("conv_ln")
}

impl Drop for Region {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        // try_with: a guard dropped during thread teardown (after TLS
        // destruction) silently loses its sample instead of aborting.
        let _ = STATE.try_with(|s| {
            let mut s = s.borrow_mut();
            let Some(st) = s.as_mut() else { return };
            let Some(f) = st.stack.pop() else { return };
            let dur_ns = f.start.elapsed().as_nanos() as u64;
            let self_ns = dur_ns.saturating_sub(f.child_ns);
            let depth = st.stack.len();
            let mut path = String::new();
            for fr in &st.stack {
                path.push_str(&fr.name);
                path.push(';');
            }
            path.push_str(&f.name);
            if let Some(parent) = st.stack.last_mut() {
                parent.child_ns += dur_ns;
            }
            {
                let mut agg = lock_or_recover(&st.agg.agg);
                let e = agg.entry(path.clone()).or_default();
                e.total_ns += dur_ns;
                e.self_ns += self_ns;
                e.count += 1;
            }
            let p = global_profiler();
            let mut slices = lock_or_recover(&p.slices);
            if slices.len() < SLICE_CAP {
                let start_us = f.start.saturating_duration_since(p.epoch).as_micros() as u64;
                slices.push(ProfSlice {
                    tid: st.agg.tid,
                    name: f.name,
                    path,
                    start_us,
                    dur_us: dur_ns / 1_000,
                    depth,
                });
            } else {
                drop(slices);
                p.dropped.fetch_add(1, Ordering::Relaxed);
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::with_telemetry;

    fn spin_us(us: u64) {
        let t0 = Instant::now();
        while t0.elapsed().as_micros() < us as u128 {
            std::hint::spin_loop();
        }
    }

    #[test]
    fn disabled_regions_record_nothing() {
        with_telemetry(|| {
            crate::telemetry::set_enabled(false);
            global_profiler().reset();
            {
                let _a = region("off_outer");
                let _b = region("off_inner");
            }
            assert!(!global_profiler().fold().contains_key("off_outer"));
        });
    }

    #[test]
    fn nesting_self_time_is_exact() {
        with_telemetry(|| {
            global_profiler().reset();
            {
                let _a = region("nest_a");
                spin_us(200);
                {
                    let _b = region("nest_b");
                    spin_us(200);
                }
                spin_us(100);
            }
            let fold = global_profiler().fold();
            let a = fold.get("nest_a").copied().expect("outer path recorded");
            let b = fold.get("nest_a;nest_b").copied().expect("nested path recorded");
            assert_eq!(a.count, 1);
            assert_eq!(b.count, 1);
            // The invariant is exact by construction: a's child_ns is
            // b's measured duration, so self + child == total.
            assert_eq!(a.self_ns + b.total_ns, a.total_ns);
            assert_eq!(b.self_ns, b.total_ns, "leaf self == total");
            assert!(a.total_ns >= b.total_ns);
            // Table view: one row per leaf name, self-descending.
            let rows = global_profiler().table();
            assert!(rows.iter().any(|r| r.name == "nest_a"));
            assert!(rows.iter().any(|r| r.name == "nest_b"));
            assert!(!global_profiler().render_table().is_empty());
        });
    }

    #[test]
    fn folded_lines_and_slices_share_the_grammar() {
        with_telemetry(|| {
            global_profiler().reset();
            {
                let _a = region("fold outer"); // space sanitized to _
                let _b = region("fold_leaf");
            }
            let folded = global_profiler().render_folded();
            let line = folded
                .lines()
                .find(|l| l.starts_with("fold_outer;fold_leaf "))
                .expect("nested folded line present");
            // `stack self_us`: exactly one space, integer value.
            let (stack, val) = line.rsplit_once(' ').unwrap();
            assert_eq!(stack, "fold_outer;fold_leaf");
            val.parse::<u64>().expect("folded value is an integer");
            let slices = global_profiler().slices_snapshot();
            let s = slices.iter().find(|s| s.name == "fold_leaf").unwrap();
            assert_eq!(s.depth, 1);
            assert_eq!(s.path, "fold_outer;fold_leaf");
            assert_eq!(global_profiler().dropped_slices(), 0);
        });
    }

    #[test]
    fn per_thread_aggregation_is_exact_under_contention() {
        with_telemetry(|| {
            global_profiler().reset();
            const THREADS: usize = 8;
            const PER_THREAD: usize = 200;
            std::thread::scope(|scope| {
                for _ in 0..THREADS {
                    scope.spawn(|| {
                        for _ in 0..PER_THREAD {
                            let _o = region("cont_outer");
                            let _i = region("cont_inner");
                        }
                    });
                }
            });
            let fold = global_profiler().fold();
            let total = (THREADS * PER_THREAD) as u64;
            assert_eq!(fold["cont_outer"].count, total);
            assert_eq!(fold["cont_outer;cont_inner"].count, total);
            // Per-path invariant survives the merge: self + children == total.
            let o = fold["cont_outer"];
            let i = fold["cont_outer;cont_inner"];
            assert_eq!(o.self_ns + i.total_ns, o.total_ns);
        });
    }

    #[test]
    fn layer_names_are_static_and_bounded() {
        assert_eq!(layer_name(0), "conv_l0");
        assert_eq!(layer_name(15), "conv_l15");
        assert_eq!(layer_name(99), "conv_ln");
    }
}
