//! Unified telemetry: metrics registry, request-lifecycle spans,
//! Perfetto trace export, continuous profiling, the incident event
//! log, and SLO monitoring.
//!
//! Every tier shares one on/off switch ([`set_enabled`]):
//!
//! * [`registry`] — process-global counters/gauges/histograms behind
//!   atomics, with Prometheus text and JSON exposition (`--metrics-out`).
//! * [`spans`] — per-request lifecycle spans recorded by the
//!   coordinator's worker loop and aggregated into `ServiceStats`.
//! * [`perfetto`] — a Chrome trace-event exporter rendering the serving
//!   timeline and the engines' phase/fire schedules into one
//!   `trace.json` (`--trace-out`), loadable in ui.perfetto.dev or
//!   chrome://tracing.
//! * [`profiler`] — scoped self-time regions through the fsim/kernel
//!   hot paths and the worker loop, folded-stack + table + Perfetto
//!   slice export (`--profile-out`).
//! * [`events`] — a bounded ring of typed resilience incidents
//!   (respawns, breaker trips, sheds, chaos injections, ...), JSONL +
//!   Perfetto instant export (`--events-out`).
//! * [`slo`] — rolling-window availability / p99 / error-budget burn
//!   tracking against `--slo` targets, rendered in the serve report and
//!   gating `soak --check`.
//!
//! Everything is off by default: the record paths cost one relaxed
//! atomic load until a CLI flag (or a test/bench) turns telemetry on —
//! `benches/telemetry_overhead.rs` holds that claim to ≤1% disabled /
//! ≤5% enabled on the packed serving path, profiler regions included.

pub mod events;
pub mod perfetto;
pub mod profiler;
pub mod registry;
pub mod slo;
pub mod spans;

pub use events::{events, incident, EventLog, IncidentEvent, IncidentKind};
pub use perfetto::TraceBuilder;
pub use profiler::{global_profiler, region, Profiler, Region};
pub use registry::{enabled, global, set_enabled, Counter, Gauge, Histogram, Registry};
pub use slo::{SloConfig, SloMonitor, SloReport};
pub use spans::{RequestSpan, SpanLog, SpanOutcome};

/// Serialize unit tests that flip the process-global enable flag, so
/// parallel test threads don't observe each other's state.
#[cfg(test)]
pub(crate) fn with_telemetry<T>(f: impl FnOnce() -> T) -> T {
    use std::sync::Mutex;
    static GUARD: Mutex<()> = Mutex::new(());
    let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
    let was = enabled();
    set_enabled(true);
    let out = f();
    set_enabled(was);
    out
}
