//! Structured incident event log: a bounded ring of typed, timestamped
//! resilience events.
//!
//! Metrics say *how much*, spans say *how long* — this log says *what
//! happened*: worker respawns, breaker trips/resets, degraded re-plans,
//! admission/deadline sheds, chaos injections, calibration snaps, and
//! terminal request failures, each carrying the worker and request ids
//! needed to correlate with the span timeline. The log is a bounded
//! ring ([`EVENT_RING_CAP`] by default): incidents are rare by design,
//! but a pathological storm must not grow memory without bound — old
//! events are dropped and counted instead.
//!
//! Recording goes through the same global enable flag as every other
//! telemetry tier; [`incident`] takes the detail as a closure so a
//! disabled process never formats the string. Export is JSONL
//! ([`EventLog::to_jsonl`], `--events-out FILE`) — one self-contained
//! object per line, schema-stable keys (`seq`, `ts_us`, `kind`,
//! `worker`, `req_id`, `detail`) — plus Perfetto instant events via
//! [`perfetto::incident_tracks`](super::perfetto).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::util::json::Json;
use crate::util::lock_or_recover;

/// Default ring capacity: bounded memory under incident storms.
pub const EVENT_RING_CAP: usize = 4096;

/// What happened. The wire label ([`IncidentKind::as_str`]) is the
/// JSONL/Perfetto schema; add variants, never repurpose labels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IncidentKind {
    /// Supervisor restarted a dead worker (panic or breaker teardown).
    WorkerRespawn,
    /// A worker's circuit breaker hit its consecutive-fault threshold.
    BreakerTrip,
    /// A previously tripping worker served cleanly again.
    BreakerReset,
    /// A respawned worker was rebuilt on the survivor shard re-plan.
    DegradedReplan,
    /// Admission control refused a request (queue full).
    Shed,
    /// A request's deadline expired (at dequeue or post-exec).
    DeadlineMiss,
    /// The chaos backend fired one or more scheduled faults.
    ChaosInjected,
    /// Served latency/energy snapped to a measured cycle-level run.
    CalibrationSnap,
    /// A request failed back to the caller with a typed error.
    RequestFailed,
}

impl IncidentKind {
    /// Stable snake_case wire label.
    pub fn as_str(&self) -> &'static str {
        match self {
            IncidentKind::WorkerRespawn => "worker_respawn",
            IncidentKind::BreakerTrip => "breaker_trip",
            IncidentKind::BreakerReset => "breaker_reset",
            IncidentKind::DegradedReplan => "degraded_replan",
            IncidentKind::Shed => "shed",
            IncidentKind::DeadlineMiss => "deadline_miss",
            IncidentKind::ChaosInjected => "chaos_injected",
            IncidentKind::CalibrationSnap => "calibration_snap",
            IncidentKind::RequestFailed => "request_failed",
        }
    }

    /// Inverse of [`as_str`](Self::as_str) (JSONL round-trips).
    pub fn parse(s: &str) -> Option<IncidentKind> {
        Some(match s {
            "worker_respawn" => IncidentKind::WorkerRespawn,
            "breaker_trip" => IncidentKind::BreakerTrip,
            "breaker_reset" => IncidentKind::BreakerReset,
            "degraded_replan" => IncidentKind::DegradedReplan,
            "shed" => IncidentKind::Shed,
            "deadline_miss" => IncidentKind::DeadlineMiss,
            "chaos_injected" => IncidentKind::ChaosInjected,
            "calibration_snap" => IncidentKind::CalibrationSnap,
            "request_failed" => IncidentKind::RequestFailed,
            _ => return None,
        })
    }
}

/// One recorded incident.
#[derive(Debug, Clone, PartialEq)]
pub struct IncidentEvent {
    /// Monotone sequence number within the log (survives ring drops, so
    /// gaps at the front are visible).
    pub seq: u64,
    /// µs since the log's epoch (process start of the global log).
    pub ts_us: u64,
    pub kind: IncidentKind,
    /// Worker index, when the incident belongs to one.
    pub worker: Option<usize>,
    /// Request id, when the incident belongs to one.
    pub req_id: Option<u64>,
    /// Free-form context (chaos spec, shed depth/cap, attempts, ...).
    pub detail: String,
}

impl IncidentEvent {
    /// Schema-stable JSON object (all six keys always present; absent
    /// ids are `null`).
    pub fn to_json(&self) -> Json {
        let opt_num = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("seq", Json::num(self.seq as f64)),
            ("ts_us", Json::num(self.ts_us as f64)),
            ("kind", Json::str(self.kind.as_str())),
            ("worker", opt_num(self.worker.map(|w| w as f64))),
            ("req_id", opt_num(self.req_id.map(|r| r as f64))),
            ("detail", Json::str(self.detail.clone())),
        ])
    }

    /// Parse one JSONL object back (round-trip tests, external tools).
    pub fn from_json(j: &Json) -> Result<IncidentEvent> {
        let opt_u64 = |j: &Json| -> Result<Option<u64>> {
            match j {
                Json::Null => Ok(None),
                other => Ok(Some(other.as_f64()? as u64)),
            }
        };
        let kind_s = j.get("kind")?.as_str()?.to_string();
        Ok(IncidentEvent {
            seq: j.get("seq")?.as_f64()? as u64,
            ts_us: j.get("ts_us")?.as_f64()? as u64,
            kind: IncidentKind::parse(&kind_s)
                .ok_or_else(|| anyhow!("unknown incident kind {kind_s:?}"))?,
            worker: opt_u64(j.get("worker")?)?.map(|w| w as usize),
            req_id: opt_u64(j.get("req_id")?)?,
            detail: j.get("detail")?.as_str()?.to_string(),
        })
    }
}

#[derive(Debug, Default)]
struct Ring {
    buf: VecDeque<IncidentEvent>,
    dropped: u64,
}

/// Bounded incident sink. The process-global instance ([`events`]) is
/// what the coordinator and the chaos backend record into; tests build
/// private ones.
#[derive(Debug)]
pub struct EventLog {
    epoch: Instant,
    cap: usize,
    seq: AtomicU64,
    ring: Mutex<Ring>,
}

impl Default for EventLog {
    fn default() -> Self {
        Self::with_capacity(EVENT_RING_CAP)
    }
}

impl EventLog {
    pub fn with_capacity(cap: usize) -> Self {
        EventLog {
            epoch: Instant::now(),
            cap: cap.max(1),
            seq: AtomicU64::new(0),
            ring: Mutex::new(Ring::default()),
        }
    }

    /// µs since this log's epoch.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record an incident (no-op while telemetry is disabled). Oldest
    /// events fall off when the ring is full; `seq` stays monotone.
    pub fn record(
        &self,
        kind: IncidentKind,
        worker: Option<usize>,
        req_id: Option<u64>,
        detail: String,
    ) {
        if !crate::telemetry::enabled() {
            return;
        }
        let ev = IncidentEvent {
            seq: self.seq.fetch_add(1, Ordering::Relaxed),
            ts_us: self.now_us(),
            kind,
            worker,
            req_id,
            detail,
        };
        let mut ring = lock_or_recover(&self.ring);
        ring.buf.push_back(ev);
        while ring.buf.len() > self.cap {
            ring.buf.pop_front();
            ring.dropped += 1;
        }
    }

    /// Number of events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        lock_or_recover(&self.ring).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events dropped off the front of the ring so far.
    pub fn dropped(&self) -> u64 {
        lock_or_recover(&self.ring).dropped
    }

    /// Copy of the held events, oldest first.
    pub fn snapshot(&self) -> Vec<IncidentEvent> {
        lock_or_recover(&self.ring).buf.iter().cloned().collect()
    }

    /// Clear the ring and restart sequence numbering (per-invocation
    /// dumps, mirrors `Registry::reset`).
    pub fn reset(&self) {
        let mut ring = lock_or_recover(&self.ring);
        ring.buf.clear();
        ring.dropped = 0;
        self.seq.store(0, Ordering::Relaxed);
    }

    /// JSONL export: one schema-stable object per line, oldest first.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in lock_or_recover(&self.ring).buf.iter() {
            out.push_str(&ev.to_json().to_string());
            out.push('\n');
        }
        out
    }
}

/// The process-wide incident log.
pub fn events() -> &'static EventLog {
    static GLOBAL: OnceLock<EventLog> = OnceLock::new();
    GLOBAL.get_or_init(EventLog::default)
}

/// Record into the global log. The detail closure only runs when
/// telemetry is enabled, so disabled call sites never format.
pub fn incident(
    kind: IncidentKind,
    worker: Option<usize>,
    req_id: Option<u64>,
    detail: impl FnOnce() -> String,
) {
    if !crate::telemetry::enabled() {
        return;
    }
    events().record(kind, worker, req_id, detail());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::with_telemetry;

    #[test]
    fn disabled_log_records_nothing() {
        with_telemetry(|| {
            crate::telemetry::set_enabled(false);
            let log = EventLog::with_capacity(8);
            log.record(IncidentKind::Shed, Some(0), Some(1), "x".into());
            assert!(log.is_empty());
        });
    }

    #[test]
    fn ring_overflow_drops_oldest_and_keeps_seq_monotone() {
        with_telemetry(|| {
            let log = EventLog::with_capacity(4);
            for i in 0..10u64 {
                log.record(IncidentKind::ChaosInjected, None, Some(i), format!("call {i}"));
            }
            assert_eq!(log.len(), 4);
            assert_eq!(log.dropped(), 6);
            let snap = log.snapshot();
            // The survivors are the newest four, in order, with their
            // original sequence numbers (the front gap is visible).
            assert_eq!(snap.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![6, 7, 8, 9]);
            assert_eq!(snap[0].req_id, Some(6));
            log.reset();
            assert!(log.is_empty());
            assert_eq!(log.dropped(), 0);
            log.record(IncidentKind::Shed, None, None, "post-reset".into());
            assert_eq!(log.snapshot()[0].seq, 0);
        });
    }

    #[test]
    fn jsonl_round_trips_through_util_json() {
        with_telemetry(|| {
            let log = EventLog::with_capacity(16);
            log.record(IncidentKind::BreakerTrip, Some(2), None, "5 consecutive faults".into());
            log.record(
                IncidentKind::DeadlineMiss,
                Some(0),
                Some(42),
                "waited 1234 us \"quoted\"".into(),
            );
            let jsonl = log.to_jsonl();
            let lines: Vec<&str> = jsonl.lines().collect();
            assert_eq!(lines.len(), 2);
            let orig = log.snapshot();
            for (line, want) in lines.iter().zip(&orig) {
                let j = Json::parse(line).expect("each line is a standalone JSON object");
                for key in ["seq", "ts_us", "kind", "worker", "req_id", "detail"] {
                    assert!(j.get(key).is_ok(), "line missing {key}: {line}");
                }
                let back = IncidentEvent::from_json(&j).unwrap();
                assert_eq!(&back, want);
            }
            // null ids round-trip as None.
            let j = Json::parse(lines[0]).unwrap();
            assert_eq!(j.get("req_id").unwrap(), &Json::Null);
        });
    }

    #[test]
    fn kind_labels_round_trip() {
        for k in [
            IncidentKind::WorkerRespawn,
            IncidentKind::BreakerTrip,
            IncidentKind::BreakerReset,
            IncidentKind::DegradedReplan,
            IncidentKind::Shed,
            IncidentKind::DeadlineMiss,
            IncidentKind::ChaosInjected,
            IncidentKind::CalibrationSnap,
            IncidentKind::RequestFailed,
        ] {
            assert_eq!(IncidentKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(IncidentKind::parse("nope"), None);
    }

    #[test]
    fn incident_helper_gates_the_detail_closure() {
        with_telemetry(|| {
            crate::telemetry::set_enabled(false);
            let mut ran = false;
            incident(IncidentKind::Shed, None, None, || {
                ran = true;
                String::new()
            });
            assert!(!ran, "detail must not be formatted while disabled");
        });
    }
}
