//! SLO monitoring: rolling-window availability and p99-vs-target
//! tracking with error-budget burn rate.
//!
//! `--slo p99_ms=...,availability=...[,window=N]` on `serve`/`soak`
//! arms a [`SloMonitor`] over the most recent `window` terminal request
//! outcomes (served / shed / deadline-missed / failed). From that
//! window it derives:
//!
//! * **availability** — served fraction of the window;
//! * **p99** — nearest-rank 99th percentile of the *served* latencies
//!   (the same rank rule as the serve report, so the two agree on
//!   identical sample sets);
//! * **burn rate** — observed error rate divided by the error budget
//!   (`1 − availability_target`): 1.0 means errors arrive exactly as
//!   fast as the budget allows, >1 means the budget is burning down.
//!
//! The monitor lives in `ServiceStats` (installed by the coordinator
//! when `ServeOptions.slo` is set), records from the same terminal
//! sites that close request spans, renders in the serve report, and
//! mirrors its numbers into the metrics registry (`slo.availability`,
//! `slo.p99_us`, `slo.burn_rate`). `soak --check --slo ...` gates each
//! full-availability cell on the same [`SloConfig`] targets.

use std::collections::VecDeque;
use std::sync::Mutex;

use anyhow::{bail, ensure, Result};

use crate::util::json::Json;
use crate::util::lock_or_recover;

/// Default rolling-window size (terminal request outcomes).
pub const DEFAULT_SLO_WINDOW: usize = 512;

/// Parsed `--slo` targets. `Copy` so it rides inside `ServeOptions`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// p99 latency target in milliseconds (served requests).
    pub p99_ms: Option<f64>,
    /// Availability target as a fraction in (0, 1].
    pub availability: Option<f64>,
    /// Rolling-window size in requests.
    pub window: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig { p99_ms: None, availability: None, window: DEFAULT_SLO_WINDOW }
    }
}

impl SloConfig {
    /// Parse `p99_ms=5,availability=0.999,window=256` (any subset; at
    /// least one target required).
    pub fn parse_spec(spec: &str) -> Result<SloConfig> {
        let mut cfg = SloConfig::default();
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let Some((k, v)) = part.split_once('=') else {
                bail!("--slo expects key=value pairs, got {part:?}");
            };
            match k.trim() {
                "p99_ms" => {
                    let ms: f64 =
                        v.trim().parse().map_err(|_| anyhow::anyhow!("bad p99_ms {v:?}"))?;
                    ensure!(ms > 0.0 && ms.is_finite(), "p99_ms must be a positive number");
                    cfg.p99_ms = Some(ms);
                }
                "availability" => {
                    let a: f64 = v
                        .trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad availability {v:?}"))?;
                    ensure!((0.0..=1.0).contains(&a) && a > 0.0, "availability must be in (0, 1]");
                    cfg.availability = Some(a);
                }
                "window" => {
                    let w: usize =
                        v.trim().parse().map_err(|_| anyhow::anyhow!("bad window {v:?}"))?;
                    ensure!(w >= 1, "window must be >= 1");
                    cfg.window = w;
                }
                other => bail!(
                    "unknown --slo key {other:?} (expected p99_ms, availability, window)"
                ),
            }
        }
        ensure!(
            cfg.p99_ms.is_some() || cfg.availability.is_some(),
            "--slo needs at least one target (p99_ms=... or availability=...)"
        );
        Ok(cfg)
    }

    /// Canonical spec string (reports, JSON).
    pub fn spec(&self) -> String {
        let mut parts = Vec::new();
        if let Some(ms) = self.p99_ms {
            parts.push(format!("p99_ms={ms}"));
        }
        if let Some(a) = self.availability {
            parts.push(format!("availability={a}"));
        }
        parts.push(format!("window={}", self.window));
        parts.join(",")
    }

    /// p99 target in µs, when set.
    pub fn p99_target_us(&self) -> Option<u64> {
        self.p99_ms.map(|ms| (ms * 1_000.0).round() as u64)
    }

    /// Gate one observed (availability, p99) pair against the targets —
    /// the `soak --check` SLO gate.
    pub fn check_observed(&self, availability: f64, p99_us: Option<u64>) -> Result<()> {
        if let Some(target) = self.availability {
            ensure!(
                availability + 1e-12 >= target,
                "availability {:.4} below SLO target {:.4}",
                availability,
                target
            );
        }
        if let (Some(target_us), Some(p99)) = (self.p99_target_us(), p99_us) {
            ensure!(p99 <= target_us, "p99 {p99} us above SLO target {target_us} us");
        }
        Ok(())
    }
}

/// Nearest-rank percentile over an unsorted sample set: the smallest
/// value with at least `ceil(p * n)` samples ≤ it. Exact at boundaries:
/// `p=0.99` over `1..=100` is 99, `p=1.0` is the max. Matches the serve
/// report's rank rule (`coordinator::report::percentiles_us`).
pub fn percentile_us(samples: &[u64], p: f64) -> Option<u64> {
    if samples.is_empty() {
        return None;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_unstable();
    let p = if p.is_nan() { 0.0 } else { p.clamp(0.0, 1.0) };
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    Some(sorted[rank - 1])
}

#[derive(Debug, Default)]
struct SloState {
    /// (latency_us, served) per terminal outcome, newest at the back.
    window: VecDeque<(u64, bool)>,
    seen: u64,
    served_total: u64,
}

/// Rolling SLO tracker; thread-safe, recorded from the worker loop.
#[derive(Debug)]
pub struct SloMonitor {
    cfg: SloConfig,
    state: Mutex<SloState>,
}

/// One evaluated snapshot of the monitor.
#[derive(Debug, Clone, PartialEq)]
pub struct SloReport {
    pub cfg: SloConfig,
    /// Terminal outcomes observed overall / in the current window.
    pub seen: u64,
    pub window_n: usize,
    /// Served fraction of the window (`None` until anything lands).
    pub availability: Option<f64>,
    /// Nearest-rank p99 of served latencies in the window, µs.
    pub p99_us: Option<u64>,
    /// Error-budget burn rate (needs an availability target < 1).
    pub burn_rate: Option<f64>,
}

impl SloMonitor {
    pub fn new(cfg: SloConfig) -> Self {
        SloMonitor { cfg, state: Mutex::new(SloState::default()) }
    }

    pub fn config(&self) -> SloConfig {
        self.cfg
    }

    /// Record one terminal outcome: `served` with its end-to-end host
    /// latency, or an unserved lifecycle (shed/deadline/failed).
    pub fn record(&self, latency_us: u64, served: bool) {
        let mut s = lock_or_recover(&self.state);
        s.seen += 1;
        if served {
            s.served_total += 1;
        }
        s.window.push_back((latency_us, served));
        while s.window.len() > self.cfg.window {
            s.window.pop_front();
        }
    }

    /// Evaluate the rolling window and mirror the numbers into the
    /// metrics registry gauges.
    pub fn report(&self) -> SloReport {
        let s = lock_or_recover(&self.state);
        let n = s.window.len();
        let served: Vec<u64> =
            s.window.iter().filter(|(_, ok)| *ok).map(|(us, _)| *us).collect();
        let availability = if n == 0 { None } else { Some(served.len() as f64 / n as f64) };
        let p99_us = percentile_us(&served, 0.99);
        let burn_rate = match (availability, self.cfg.availability) {
            (Some(a), Some(target)) if target < 1.0 => Some((1.0 - a) / (1.0 - target)),
            _ => None,
        };
        drop(s);
        if crate::telemetry::enabled() {
            let reg = crate::telemetry::global();
            if let Some(a) = availability {
                reg.gauge("slo.availability").set(a);
            }
            if let Some(p) = p99_us {
                reg.gauge("slo.p99_us").set(p as f64);
            }
            if let Some(b) = burn_rate {
                reg.gauge("slo.burn_rate").set(b);
            }
        }
        SloReport {
            cfg: self.cfg,
            seen: self.seen(),
            window_n: n,
            availability,
            p99_us,
            burn_rate,
        }
    }

    fn seen(&self) -> u64 {
        lock_or_recover(&self.state).seen
    }
}

impl SloReport {
    /// Does the window meet the availability target (vacuously true
    /// when no target is set or nothing landed yet)?
    pub fn availability_ok(&self) -> bool {
        match (self.availability, self.cfg.availability) {
            (Some(a), Some(target)) => a + 1e-12 >= target,
            _ => true,
        }
    }

    /// Does the window meet the p99 target?
    pub fn p99_ok(&self) -> bool {
        match (self.p99_us, self.cfg.p99_target_us()) {
            (Some(p), Some(target)) => p <= target,
            _ => true,
        }
    }

    pub fn compliant(&self) -> bool {
        self.availability_ok() && self.p99_ok()
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::num).unwrap_or(Json::Null);
        Json::obj(vec![
            ("spec", Json::str(self.cfg.spec())),
            ("seen", Json::num(self.seen as f64)),
            ("window_n", Json::num(self.window_n as f64)),
            ("availability", opt(self.availability)),
            ("p99_us", opt(self.p99_us.map(|p| p as f64))),
            ("burn_rate", opt(self.burn_rate)),
            ("compliant", Json::Bool(self.compliant())),
        ])
    }

    /// The serve-report block.
    pub fn render(&self) -> String {
        let mut out = format!("slo ({}):\n", self.cfg.spec());
        match self.availability {
            Some(a) => {
                let target = self
                    .cfg
                    .availability
                    .map(|t| format!(" (target {:.4}, {})", t, ok_str(self.availability_ok())))
                    .unwrap_or_default();
                out.push_str(&format!(
                    "  availability: {:.4} over last {} request(s){target}\n",
                    a, self.window_n
                ));
            }
            None => out.push_str("  availability: no requests observed yet\n"),
        }
        match self.p99_us {
            Some(p) => {
                let target = self
                    .cfg
                    .p99_target_us()
                    .map(|t| format!(" (target {} µs, {})", t, ok_str(self.p99_ok())))
                    .unwrap_or_default();
                out.push_str(&format!("  p99: {p} µs{target}\n"));
            }
            None => out.push_str("  p99: no served requests in window\n"),
        }
        if let Some(b) = self.burn_rate {
            out.push_str(&format!(
                "  error-budget burn rate: {b:.2}x ({})\n",
                if b <= 1.0 { "within budget" } else { "burning down" }
            ));
        }
        out
    }
}

fn ok_str(ok: bool) -> &'static str {
    if ok {
        "met"
    } else {
        "MISSED"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_and_round_trips() {
        let cfg = SloConfig::parse_spec("p99_ms=5,availability=0.999,window=128").unwrap();
        assert_eq!(cfg.p99_ms, Some(5.0));
        assert_eq!(cfg.availability, Some(0.999));
        assert_eq!(cfg.window, 128);
        assert_eq!(cfg.p99_target_us(), Some(5_000));
        let again = SloConfig::parse_spec(&cfg.spec()).unwrap();
        assert_eq!(again, cfg);
        // Subsets parse; an empty target set does not.
        assert!(SloConfig::parse_spec("availability=0.99").is_ok());
        assert!(SloConfig::parse_spec("window=64").is_err());
        assert!(SloConfig::parse_spec("p99_ms=0").is_err());
        assert!(SloConfig::parse_spec("availability=1.5").is_err());
        assert!(SloConfig::parse_spec("bogus=1").is_err());
    }

    #[test]
    fn percentile_is_exact_at_boundaries() {
        let v: Vec<u64> = (1..=100).collect();
        // Nearest rank: ceil(0.99 * 100) = 99 → the 99th smallest.
        assert_eq!(percentile_us(&v, 0.99), Some(99));
        assert_eq!(percentile_us(&v, 1.0), Some(100));
        assert_eq!(percentile_us(&v, 0.0), Some(1));
        assert_eq!(percentile_us(&v, 0.5), Some(50));
        // One more sample tips the rank: ceil(0.99 * 101) = 100.
        let v101: Vec<u64> = (1..=101).collect();
        assert_eq!(percentile_us(&v101, 0.99), Some(100));
        assert_eq!(percentile_us(&[], 0.99), None);
        assert_eq!(percentile_us(&[7], f64::NAN), Some(7));
    }

    #[test]
    fn rolling_window_math_and_burn_rate() {
        let cfg = SloConfig::parse_spec("p99_ms=1,availability=0.9,window=10").unwrap();
        let m = SloMonitor::new(cfg);
        // 8 served at 500 µs + 2 failures: availability 0.8 in-window.
        for _ in 0..8 {
            m.record(500, true);
        }
        for _ in 0..2 {
            m.record(0, false);
        }
        let r = m.report();
        assert_eq!(r.window_n, 10);
        assert_eq!(r.availability, Some(0.8));
        assert_eq!(r.p99_us, Some(500));
        // Error rate 0.2 against a 0.1 budget: burning at 2x.
        let burn = r.burn_rate.unwrap();
        assert!((burn - 2.0).abs() < 1e-9, "burn {burn}");
        assert!(!r.availability_ok());
        assert!(r.p99_ok(), "500 µs meets the 1 ms target");
        assert!(!r.compliant());
        // 10 clean fast requests roll the failures out of the window.
        for _ in 0..10 {
            m.record(400, true);
        }
        let r = m.report();
        assert_eq!(r.availability, Some(1.0));
        assert_eq!(r.burn_rate, Some(0.0));
        assert!(r.compliant());
        assert_eq!(r.seen, 20);
        // JSON snapshot round-trips through the parser.
        let j = r.to_json();
        assert!(Json::parse(&j.to_string()).is_ok());
        assert!(r.render().contains("slo ("));
    }

    #[test]
    fn p99_violation_fails_compliance() {
        let cfg = SloConfig::parse_spec("p99_ms=1,window=100").unwrap();
        let m = SloMonitor::new(cfg);
        for _ in 0..99 {
            m.record(100, true);
        }
        m.record(5_000, true); // rank 100 of 100 at p99? ceil(.99*100)=99 → 100 µs
        let r = m.report();
        assert_eq!(r.p99_us, Some(100));
        assert!(r.compliant());
        // A second slow sample moves rank 99 onto the slow tail.
        m.record(6_000, true);
        let r = m.report();
        assert_eq!(r.p99_us, Some(5_000));
        assert!(!r.p99_ok());
        assert!(!r.compliant());
    }

    #[test]
    fn check_observed_gates_targets() {
        let cfg = SloConfig::parse_spec("p99_ms=2,availability=0.99").unwrap();
        assert!(cfg.check_observed(1.0, Some(1_500)).is_ok());
        assert!(cfg.check_observed(0.98, Some(1_500)).is_err());
        assert!(cfg.check_observed(1.0, Some(2_500)).is_err());
        assert!(cfg.check_observed(0.995, None).is_ok());
    }
}
