//! Request-lifecycle spans for the serving path.
//!
//! One [`RequestSpan`] per served request, timestamped in microseconds
//! from the owning [`SpanLog`]'s epoch (the coordinator's start). The
//! lifecycle mirrors the worker loop exactly:
//!
//! ```text
//! enqueue ──▶ assembly_start ──▶ assembled ──▶ exec_start ──▶ exec_end ──▶ respond
//!  (queued)   (worker drains)   (linger closed)   (backend run_batch)     (reply sent)
//! ```
//!
//! Recording is gated on [`telemetry::enabled`](crate::telemetry::enabled)
//! inside [`SpanLog::record`], so an untelemetered serve pays one
//! relaxed load per request. The Perfetto exporter renders these spans
//! into worker/request tracks; `ServiceStats` aggregates them into
//! span-derived latency percentiles that agree exactly with its own
//! host-latency samples (`respond_us - enqueue_us` is *defined* as the
//! measured host latency, not a second clock read).

use std::sync::Mutex;
use std::time::Instant;

use crate::util::lock_or_recover;

/// How a request's lifecycle ended — the fault story's per-request verdict.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Served cleanly on the first attempt.
    #[default]
    Ok,
    /// Served, but only after one or more retries/requeues.
    Retried,
    /// Rejected at admission (queue full) — never executed.
    Shed,
    /// Deadline expired before a result could be returned.
    Deadline,
    /// Exhausted its retry budget; failed back to the caller typed.
    Failed,
}

impl SpanOutcome {
    /// Stable lowercase label (Perfetto args, reports).
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanOutcome::Ok => "ok",
            SpanOutcome::Retried => "retried",
            SpanOutcome::Shed => "shed",
            SpanOutcome::Deadline => "deadline",
            SpanOutcome::Failed => "failed",
        }
    }

    /// Did the caller get a real `InferenceResponse`? Served spans are
    /// the ones whose `total_us` is a host-latency sample; shed/failed
    /// lifecycles are part of the trace but not the latency population.
    pub fn served(&self) -> bool {
        matches!(self, SpanOutcome::Ok | SpanOutcome::Retried)
    }
}

/// One request's lifecycle, in µs offsets from the [`SpanLog`] epoch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestSpan {
    /// Submission order (the coordinator's request counter).
    pub req_id: u64,
    /// Worker that served the batch this request rode in.
    pub worker: usize,
    /// Size of that batch.
    pub batch_size: usize,
    /// Request entered the queue.
    pub enqueue_us: u64,
    /// The worker began draining the batch (first job received).
    pub assembly_start_us: u64,
    /// Batch fully assembled — the linger window closed.
    pub assembled_us: u64,
    /// Backend `run_batch` began.
    pub exec_start_us: u64,
    /// Backend `run_batch` returned.
    pub exec_end_us: u64,
    /// Reply handed back: `enqueue_us` + the measured host latency.
    pub respond_us: u64,
    /// Per-macro fire counts from this request's `RunResult` (empty for
    /// lifecycles that never executed: shed / deadline-dropped).
    pub shard_fires: Vec<u64>,
    /// How the lifecycle ended (`ok|retried|shed|deadline|failed`).
    pub outcome: SpanOutcome,
}

impl RequestSpan {
    /// Queue + linger time: enqueue until the batch was assembled.
    pub fn queue_us(&self) -> u64 {
        self.assembled_us.saturating_sub(self.enqueue_us)
    }

    /// Backend execution time (shared by the whole batch).
    pub fn execute_us(&self) -> u64 {
        self.exec_end_us.saturating_sub(self.exec_start_us)
    }

    /// End-to-end host latency.
    pub fn total_us(&self) -> u64 {
        self.respond_us.saturating_sub(self.enqueue_us)
    }
}

/// Span sink owned by `ServiceStats`: an epoch plus the recorded spans.
#[derive(Debug)]
pub struct SpanLog {
    epoch: Instant,
    spans: Mutex<Vec<RequestSpan>>,
}

impl Default for SpanLog {
    fn default() -> Self {
        SpanLog { epoch: Instant::now(), spans: Mutex::new(Vec::new()) }
    }
}

impl SpanLog {
    /// Microseconds from the epoch to `t` (0 for pre-epoch instants,
    /// which cannot arise in the serving path — jobs enqueue after the
    /// coordinator starts).
    pub fn us_since_epoch(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Microseconds from the epoch to now.
    pub fn now_us(&self) -> u64 {
        self.us_since_epoch(Instant::now())
    }

    /// Record a span (no-op while telemetry is disabled).
    pub fn record(&self, span: RequestSpan) {
        if !crate::telemetry::enabled() {
            return;
        }
        lock_or_recover(&self.spans).push(span);
    }

    pub fn len(&self) -> usize {
        lock_or_recover(&self.spans).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Copy of the recorded spans, in request-id order.
    pub fn snapshot(&self) -> Vec<RequestSpan> {
        let mut v = lock_or_recover(&self.spans).clone();
        v.sort_by_key(|s| s.req_id);
        v
    }

    /// End-to-end latency samples (µs), one per *served* span. Shed,
    /// deadline-dropped, and failed lifecycles are excluded so these
    /// samples stay exactly the host-latency population (`ServiceStats`
    /// asserts span-derived percentiles == host percentiles).
    pub fn total_us_samples(&self) -> Vec<u64> {
        lock_or_recover(&self.spans)
            .iter()
            .filter(|s| s.outcome.served())
            .map(|s| s.total_us())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::with_telemetry;

    fn span(req_id: u64) -> RequestSpan {
        RequestSpan {
            req_id,
            worker: 0,
            batch_size: 2,
            enqueue_us: 10,
            assembly_start_us: 15,
            assembled_us: 30,
            exec_start_us: 31,
            exec_end_us: 131,
            respond_us: 140,
            shard_fires: vec![5, 5],
            outcome: SpanOutcome::Ok,
        }
    }

    #[test]
    fn derived_durations() {
        let s = span(0);
        assert_eq!(s.queue_us(), 20);
        assert_eq!(s.execute_us(), 100);
        assert_eq!(s.total_us(), 130);
    }

    #[test]
    fn record_is_gated_and_snapshot_sorts() {
        let log = SpanLog::default();
        with_telemetry(|| {
            // The guard serializes access to the global flag, so the
            // disabled-path check runs inside it too.
            crate::telemetry::set_enabled(false);
            log.record(span(0));
            assert!(log.is_empty());
            crate::telemetry::set_enabled(true);
            log.record(span(2));
            log.record(span(1));
            let snap = log.snapshot();
            assert_eq!(snap.len(), 2);
            assert_eq!(snap[0].req_id, 1);
            assert_eq!(log.total_us_samples(), vec![130, 130]);
        });
    }

    #[test]
    fn latency_samples_exclude_unserved_outcomes() {
        let log = SpanLog::default();
        with_telemetry(|| {
            log.record(span(0));
            log.record(RequestSpan { outcome: SpanOutcome::Retried, ..span(1) });
            log.record(RequestSpan { outcome: SpanOutcome::Shed, ..span(2) });
            log.record(RequestSpan { outcome: SpanOutcome::Deadline, ..span(3) });
            log.record(RequestSpan { outcome: SpanOutcome::Failed, ..span(4) });
            // All five lifecycles are in the trace...
            assert_eq!(log.snapshot().len(), 5);
            // ...but only the served ones are latency samples.
            assert_eq!(log.total_us_samples(), vec![130, 130]);
        });
        for (o, s) in [
            (SpanOutcome::Ok, "ok"),
            (SpanOutcome::Retried, "retried"),
            (SpanOutcome::Shed, "shed"),
            (SpanOutcome::Deadline, "deadline"),
            (SpanOutcome::Failed, "failed"),
        ] {
            assert_eq!(o.as_str(), s);
        }
        assert!(SpanOutcome::Retried.served());
        assert!(!SpanOutcome::Deadline.served());
    }

    #[test]
    fn epoch_offsets_are_monotone() {
        let log = SpanLog::default();
        let a = log.now_us();
        let b = log.now_us();
        assert!(b >= a);
        // Pre-epoch instants clamp to 0 rather than panicking.
        if let Some(past) = Instant::now().checked_sub(std::time::Duration::from_secs(60)) {
            assert_eq!(log.us_since_epoch(past), 0);
        }
    }
}
