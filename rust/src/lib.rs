//! # CIMR-V — an end-to-end SRAM-based CIM accelerator with RISC-V
//!
//! Cycle-level, bit-exact reproduction of *CIMR-V: An End-to-End SRAM-based
//! CIM Accelerator with RISC-V for AI Edge Device* (cs.AR 2025) as a
//! three-layer Rust + JAX + Pallas stack (see `DESIGN.md`).
//!
//! The silicon is unavailable (TSMC 28 nm testchip), so every subsystem is
//! built here as a simulation substrate:
//!
//! * [`isa`] — the full RV32IM ISA plus the paper's CIM-type extension
//!   (`cim_conv` / `cim_r` / `cim_w`, Fig. 4): encode, decode, disassemble.
//! * [`cpu`] — a 2-stage (ibex-class) in-order core: prefetch buffer +
//!   decode/execute, CSRs, LSU; single-cycle CIM instructions.
//! * [`cim`] — the 512 Kb 10T-SRAM CIM macro: X-mode (1024×512, 256 SA) and
//!   Y-mode (512×1024, 512 SA), shift input buffer, programmable SA
//!   references, symmetry weight mapping, NL/cell-variation injection.
//! * [`mem`] — on-chip SRAMs (instruction / 256 Kb feature-map / 512 Kb
//!   weight), a DDR4-like DRAM timing model, and the uDMA engine.
//! * [`dataflow`] — the paper's three latency optimizations: CIM layer
//!   fusion (Fig. 6), conv/max-pool pipelining (Fig. 7), weight fusion
//!   (Fig. 8/9), over the row-wise convolution dataflow (Fig. 5).
//! * [`compiler`] — the "full stack flow" (Fig. 10): model IR → SRAM
//!   allocation → schedule → encoded RV32IM+CIM program.
//! * [`energy`] — per-op energy/latency accounting, TOPS / TOPS/W, and the
//!   normalization formulas of Table I.
//! * [`sim`] — the SoC: wires core, macro, memories, DMA together and runs
//!   programs cycle by cycle with full stats.
//! * [`fsim`] — the fast functional simulator: executes the same compiled
//!   program at the tensor/op level (bit-identical logits) with an
//!   analytical latency/energy model — the serving-speed engine.
//! * [`backend`] — the pluggable `InferenceBackend` seam over both
//!   engines (`--backend {cycle,fast}` on the CLI).
//! * [`robustness`] — variation-aware fast simulation (the cycle engine's
//!   per-fire cell-variation/NL disturbance replayed bit-exactly at
//!   tensor level) + the Monte-Carlo robustness sweep engine
//!   (`cimrv sweep`, `serve --variation`, `BENCH_robustness.json`).
//! * [`runtime`] — PJRT golden model: loads `artifacts/*.hlo.txt` (AOT-
//!   lowered JAX/Pallas) and executes it for bit-exact cross-checking.
//! * [`coordinator`] — the edge-inference request loop (threaded leader /
//!   worker): batches requests, runs simulator + golden model, reports.
//! * [`baselines`] — analytical models of the Table I comparators and the
//!   no-fusion ablations.
//! * [`telemetry`] — unified observability: lock-cheap metrics registry
//!   (Prometheus/JSON exposition), request-lifecycle spans through the
//!   serving path, and a Chrome trace-event (Perfetto) exporter covering
//!   both engines (`--metrics-out` / `--trace-out`).
//!
//! The image is offline with a minimal vendored crate set, so [`util`]
//! carries small in-tree replacements (JSON, RNG, CLI, property-testing,
//! micro-bench harness) instead of serde/clap/proptest/criterion.

pub mod backend;
pub mod baselines;
pub mod cim;
pub mod clock;
pub mod compiler;
pub mod coordinator;
pub mod cpu;
pub mod dataflow;
pub mod energy;
pub mod fsim;
pub mod isa;
pub mod mem;
pub mod model;
pub mod resilience;
pub mod robustness;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod util;

/// Crate-wide result type (anyhow is in the vendored set).
pub type Result<T> = anyhow::Result<T>;
