//! Model container: loads `artifacts/kws_manifest.json` + weight payloads
//! produced by `python/compile/aot.py` (the deployment half of the paper's
//! "full stack flow").

use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::util::io::read_f32;
use crate::util::json::Json;

/// One convolution layer of Table II.
#[derive(Debug, Clone)]
pub struct LayerSpec {
    pub c_in: usize,
    pub c_out: usize,
    pub kernel: usize,
    /// Max-pool 2:1 after this layer?
    pub pooled: bool,
    /// Binarized output (SA compare) or raw sums (final layer)?
    pub binarized: bool,
    /// Weights, tap-major/channel-minor rows: `w[(j*c_in+ci)*c_out + co]`
    /// in {-1, +1} — row index matches the macro wordline (im2col order).
    pub weights: Vec<i8>,
    /// Per-output-channel SA thresholds (empty for the raw final layer).
    pub thresholds: Vec<i32>,
}

impl LayerSpec {
    pub fn rows(&self) -> usize {
        self.kernel * self.c_in
    }

    pub fn weight_bits(&self) -> usize {
        self.rows() * self.c_out
    }

    pub fn weight(&self, row: usize, co: usize) -> i8 {
        self.weights[row * self.c_out + co]
    }
}

/// The full model + preprocessing parameters.
#[derive(Debug, Clone)]
pub struct KwsModel {
    pub audio_len: usize,
    pub t: usize,
    pub c: usize,
    pub n_classes: usize,
    pub fusion_split: usize,
    pub layers: Vec<LayerSpec>,
    /// Preprocessing BN (float, RISC-V high-precision path).
    pub bn_gamma: Vec<f32>,
    pub bn_beta: Vec<f32>,
    pub bn_mean: Vec<f32>,
    pub bn_var: Vec<f32>,
    /// BN folded to integer feature thresholds: (floor(tau), direction).
    pub pre_thr: Vec<i64>,
    pub pre_dir: Vec<i8>,
    /// Whether the weights came from a trained checkpoint.
    pub trained: bool,
    pub artifacts_dir: PathBuf,
}

impl KwsModel {
    /// Load from an artifacts directory (see `util::io::artifacts_dir`).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("kws_manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let m = Json::parse(&text).context("parsing kws_manifest.json")?;

        let cfg = m.get("config")?;
        let t = cfg.get("t")?.as_usize()?;
        let c = cfg.get("c")?.as_usize()?;
        let kernel = cfg.get("kernel")?.as_usize()?;
        let n_classes = cfg.get("n_classes")?.as_usize()?;
        let audio_len = cfg.get("audio_len")?.as_usize()?;
        let fusion_split = cfg.get("fusion_split")?.as_usize()?;
        let channels = cfg.get("channels")?.as_arr()?;

        let read_param = |name: &str| -> Result<Vec<f32>> {
            read_f32(&dir.join("weights").join(format!("{name}.bin")))
        };

        // Weight payload format: f32 ±1 values (the `make artifacts`
        // export) or packed sign bits (the compact checked-in testdata
        // set: bit idx of word idx/32 set ⇔ flat weight idx is +1, flat
        // order = [k][ci][co] row-major, LSB-first).
        let sign_bits = m
            .get("format")
            .and_then(|f| f.get("weights"))
            .and_then(|w| w.as_str())
            .map(|s| s == "sign_bits")
            .unwrap_or(false);

        let n_layers = channels.len();
        let mut layers = Vec::with_capacity(n_layers);
        for (i, ch) in channels.iter().enumerate() {
            let pair = ch.as_arr()?;
            let c_in = pair[0].as_usize()?;
            let c_out = pair[1].as_usize()?;
            let n_w = kernel * c_in * c_out;
            let weights: Vec<i8> = if sign_bits {
                let words =
                    crate::util::io::read_u32(&dir.join("weights").join(format!("conv{i}.bin")))?;
                ensure!(
                    words.len() == n_w.div_ceil(32),
                    "conv{i}: got {} packed words, want {}",
                    words.len(),
                    n_w.div_ceil(32)
                );
                (0..n_w)
                    .map(|idx| if (words[idx / 32] >> (idx % 32)) & 1 == 1 { 1i8 } else { -1 })
                    .collect()
            } else {
                let w = read_param(&format!("conv{i}"))?;
                ensure!(w.len() == n_w, "conv{i}: got {} weights, want {n_w}", w.len());
                // f32 {-1,+1} -> i8, laid out [k][ci][co] == row-major rows.
                w.iter()
                    .map(|&v| {
                        ensure!(v == 1.0 || v == -1.0, "non-binary weight {v}");
                        Ok(if v > 0.0 { 1i8 } else { -1 })
                    })
                    .collect::<Result<_>>()?
            };
            let binarized = i < n_layers - 1;
            let thresholds = if binarized {
                let th = read_param(&format!("th{i}"))?;
                ensure!(th.len() == c_out, "th{i} length");
                th.iter()
                    .map(|&v| {
                        ensure!(v == v.round(), "non-integer threshold {v}");
                        Ok(v as i32)
                    })
                    .collect::<Result<_>>()?
            } else {
                Vec::new()
            };
            layers.push(LayerSpec {
                c_in,
                c_out,
                kernel,
                pooled: binarized, // pools follow layers 0..=5 (Table II)
                binarized,
                weights,
                thresholds,
            });
        }

        let bn_gamma = read_param("bn_gamma")?;
        let bn_beta = read_param("bn_beta")?;
        let bn_mean = read_param("bn_mean")?;
        let bn_var = read_param("bn_var")?;
        ensure!(bn_gamma.len() == c, "bn size");

        let (pre_thr, pre_dir) = fold_bn(&bn_gamma, &bn_beta, &bn_mean, &bn_var);

        Ok(KwsModel {
            audio_len,
            t,
            c,
            n_classes,
            fusion_split,
            layers,
            bn_gamma,
            bn_beta,
            bn_mean,
            bn_var,
            pre_thr,
            pre_dir,
            trained: m.get("trained").and_then(|j| j.as_bool()).unwrap_or(false),
            artifacts_dir: dir.to_path_buf(),
        })
    }

    /// Load from the default artifacts location.
    pub fn load_default() -> Result<Self> {
        Self::load(&crate::util::io::artifacts_dir()?)
    }

    /// Total weight bits resident before the weight-fusion boundary.
    pub fn resident_bits(&self) -> usize {
        self.layers[..self.fusion_split].iter().map(|l| l.weight_bits()).sum()
    }

    /// Weight bits streamed from DRAM during compute (weight fusion).
    pub fn streamed_bits(&self) -> usize {
        self.layers[self.fusion_split..].iter().map(|l| l.weight_bits()).sum()
    }

    /// Time length at the input of layer `i` (pools halve it).
    pub fn t_at_layer(&self, i: usize) -> usize {
        let pools = self.layers[..i].iter().filter(|l| l.pooled).count();
        self.t >> pools
    }

    /// Deterministic synthetic model (no artifacts needed): three conv
    /// layers shaped like a shrunken Table II — two binarized+pooled, one
    /// raw classifier — with pseudo-random ±1 weights. Used by benches
    /// and tests that must run before `make artifacts`.
    pub fn synthetic(seed: u64) -> KwsModel {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
            thresholds: if binarized {
                (0..co).map(|_| rng.range(0, 9) as i32 - 4).collect()
            } else {
                vec![]
            },
        };
        let layers =
            vec![mk(64, 64, true, true), mk(64, 32, true, true), mk(32, 12, false, false)];
        let gamma = vec![1.0f32; 64];
        let beta = vec![0.5f32; 64];
        let mean = vec![20000.0f32; 64];
        let var = vec![4.0e8f32; 64];
        let (pre_thr, pre_dir) = fold_bn(&gamma, &beta, &mean, &var);
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 2,
            layers,
            bn_gamma: gamma,
            bn_beta: beta,
            bn_mean: mean,
            bn_var: var,
            pre_thr,
            pre_dir,
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    /// A heavier synthetic model for sharding/throughput work: output
    /// channels up to 256 wide (several latch words per row), so a
    /// multi-macro split has real work to divide. Same artifact-free
    /// contract as [`Self::synthetic`].
    pub fn synthetic_wide(seed: u64) -> KwsModel {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed ^ 0x57AD);
        let mut mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
            thresholds: if binarized {
                (0..co).map(|_| rng.range(0, 9) as i32 - 4).collect()
            } else {
                vec![]
            },
        };
        let layers = vec![
            mk(64, 256, true, true),
            mk(256, 256, true, true),
            mk(256, 192, true, true),
            mk(192, 12, false, false),
        ];
        let gamma = vec![1.0f32; 64];
        let beta = vec![0.5f32; 64];
        let mean = vec![20000.0f32; 64];
        let var = vec![4.0e8f32; 64];
        let (pre_thr, pre_dir) = fold_bn(&gamma, &beta, &mean, &var);
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 2,
            layers,
            bn_gamma: gamma,
            bn_beta: beta,
            bn_mean: mean,
            bn_var: var,
            pre_thr,
            pre_dir,
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }
}

/// Fold BN + binarize into integer feature compares (mirrors
/// `python/compile/kernels/ref.py::bn_fold_thresholds`; f64 on both sides
/// so floor() ties break identically).
pub fn fold_bn(gamma: &[f32], beta: &[f32], mean: &[f32], var: &[f32]) -> (Vec<i64>, Vec<i8>) {
    let eps = 1e-5f64;
    let mut thr = Vec::with_capacity(gamma.len());
    let mut dir = Vec::with_capacity(gamma.len());
    for i in 0..gamma.len() {
        let g = gamma[i] as f64;
        let b = beta[i] as f64;
        let m = mean[i] as f64;
        let s = ((var[i] as f64) + eps).sqrt();
        let tau = m - b * s / if g == 0.0 { 1.0 } else { g };
        thr.push(tau.floor() as i64);
        dir.push(if g > 0.0 {
            1
        } else if g < 0.0 {
            -1
        } else {
            0
        });
    }
    (thr, dir)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fold_bn_directions() {
        // gamma>0: f > tau; gamma<0: f < tau; gamma=0: constant.
        let (thr, dir) = fold_bn(&[1.0, -1.0, 0.0], &[0.0, 0.0, 1.0], &[5.5, 5.5, 0.0], &[1.0, 1.0, 1.0]);
        assert_eq!(dir, vec![1, -1, 0]);
        assert_eq!(thr[0], 5);
        assert_eq!(thr[1], 5);
    }

    #[test]
    fn fold_bn_matches_float_bn_on_integers() {
        // Exhaustive check on a grid of integer features.
        let gamma = [0.7f32, -2.3, 1.1];
        let beta = [0.4f32, -0.2, 3.0];
        let mean = [100.0f32, 50.0, 7.0];
        let var = [400.0f32, 25.0, 1.0];
        let (thr, dir) = fold_bn(&gamma, &beta, &mean, &var);
        for ch in 0..3 {
            for f in -20..200i64 {
                let float_bit = {
                    let std = ((var[ch] as f64) + 1e-5).sqrt();
                    gamma[ch] as f64 * (f as f64 - mean[ch] as f64) / std + beta[ch] as f64 > 0.0
                };
                let int_bit = match dir[ch] {
                    1 => f > thr[ch],
                    -1 => f < thr[ch] + 1,
                    _ => beta[ch] > 0.0,
                };
                assert_eq!(int_bit, float_bit, "ch {ch} f {f}");
            }
        }
    }

    // Manifest-dependent tests live in rust/tests/integration.rs (they
    // need `make artifacts` to have run).
}
