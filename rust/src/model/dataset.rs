//! Synthetic-GSCD test vectors and eval sets exported by `make artifacts`
//! (see `python/compile/data.py` for the corpus definition and DESIGN.md
//! §2 for why a synthetic corpus substitutes the real GSCD).

use std::path::Path;

use anyhow::{ensure, Result};

use crate::util::io::{read_audio_any, read_f32, read_i32};

/// A set of utterances with golden labels (and optionally golden logits).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub audio_len: usize,
    /// Flattened (n, audio_len) waveforms.
    pub audio: Vec<f32>,
    pub labels: Vec<i32>,
    /// Golden logits from the JAX reference path (test vectors only).
    pub logits: Option<Vec<f32>>,
    pub n_classes: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn utterance(&self, i: usize) -> &[f32] {
        &self.audio[i * self.audio_len..(i + 1) * self.audio_len]
    }

    pub fn golden_logits(&self, i: usize) -> Option<&[f32]> {
        self.logits
            .as_ref()
            .map(|l| &l[i * self.n_classes..(i + 1) * self.n_classes])
    }

    /// Load the small test-vector set (audio + golden logits + labels).
    /// Audio may be stored as f32 (`make artifacts`) or compact i16
    /// quantized samples (the checked-in testdata set) — see
    /// `util::io::read_audio_any`.
    pub fn load_testvec(dir: &Path, audio_len: usize, n_classes: usize) -> Result<Self> {
        let audio = read_audio_any(&dir.join("testvec"), "audio")?;
        let labels = read_i32(&dir.join("testvec/labels.bin"))?;
        let logits = read_f32(&dir.join("testvec/logits.bin"))?;
        ensure!(audio.len() == labels.len() * audio_len, "testvec audio size");
        ensure!(logits.len() == labels.len() * n_classes, "testvec logits size");
        Ok(Dataset { audio_len, audio, labels, logits: Some(logits), n_classes })
    }

    /// Load the larger eval set (audio + labels, no golden logits).
    pub fn load_eval(dir: &Path, audio_len: usize, n_classes: usize) -> Result<Self> {
        let audio = read_audio_any(&dir.join("testvec"), "eval_audio")?;
        let labels = read_i32(&dir.join("testvec/eval_labels.bin"))?;
        ensure!(audio.len() == labels.len() * audio_len, "eval audio size");
        Ok(Dataset { audio_len, audio, labels, logits: None, n_classes })
    }
}

/// Generate a synthetic utterance on the Rust side (workload generator for
/// benches that must not depend on artifacts). This does NOT reproduce the
/// Python corpus bit-for-bit (different RNG); it reproduces its *shape*:
/// class-dependent burst envelopes on a sinusoid carrier plus noise.
pub fn synth_utterance(label: usize, seed: u64, audio_len: usize, noise: f64) -> Vec<f32> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed ^ 0xC13B_0000);
    let t = 128;
    let frame = audio_len / t;
    // Deterministic per-class envelope (mirrors data.class_envelope's idea).
    let mut env = vec![0.0f64; t];
    let mut crng = Rng::new(0xC13B + label as u64);
    let n_bursts = 3 + label % 4;
    for _ in 0..n_bursts {
        let start = crng.range(0, t - 8);
        let width = crng.range(6, 24);
        let level = 0.5 + 0.5 * crng.f64();
        for e in env.iter_mut().skip(start).take(width) {
            *e = (*e + level).min(1.5);
        }
    }
    let scale = 0.7 + 0.6 * rng.f64();
    let freq = 0.15 + 0.02 * (label % 5) as f64;
    let phase = rng.f64() * std::f64::consts::TAU;
    (0..audio_len)
        .map(|i| {
            let carrier = (std::f64::consts::TAU * freq * i as f64 + phase).sin();
            (carrier * env[i / frame] * scale + noise * rng.normal()) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synth_deterministic_and_class_dependent() {
        let a = synth_utterance(3, 7, 16000, 0.1);
        let b = synth_utterance(3, 7, 16000, 0.1);
        let c = synth_utterance(4, 7, 16000, 0.1);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 16000);
    }

    #[test]
    fn synth_amplitude_bounded() {
        let a = synth_utterance(0, 1, 16000, 0.0);
        assert!(a.iter().all(|x| x.abs() <= 1.5 * 1.3 + 0.01));
    }
}
