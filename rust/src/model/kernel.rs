//! The lane-blocked XNOR-popcount kernel engine — the fast path's fast
//! path.
//!
//! [`super::reference`]'s packed kernels walk one output channel's u64
//! window words at a time and re-gather every im2col window from scratch
//! (`gather_window`: k `OR`-shifts per position). This module rewrites
//! the hot loops around two ideas:
//!
//! * **Incremental windows** — stride-1 windows share k−1 of k columns.
//!   Window `t+1` is window `t` shifted down by `c_in` bits with the one
//!   incoming row OR-patched at bit offset `(k−1)·c_in`
//!   ([`build_windows_into`]): one shift + one row-OR per position
//!   instead of a k-row re-gather, and every window is materialized once
//!   per (map, layer) no matter how many channels read it.
//! * **Lane blocking** — output channels are transposed into blocks of
//!   [`LANES`] interleaved planes (word `j` of all 8 channels adjacent in
//!   memory, [`LaneLayer`]), so the inner loop ANDs one window word
//!   against 8 plane words and accumulates 8 popcounts. That loop is
//!   branch-free, unit-stride and independent across lanes — exactly the
//!   shape LLVM auto-vectorizes to u64x4 `vpand` + popcount sequences.
//!
//! With the `simd` cargo feature the same inner loop is additionally
//! compiled under `#[target_feature(enable = "popcnt")]` and
//! `#[target_feature(enable = "avx2,popcnt")]` on x86-64 and dispatched
//! by runtime CPU detection ([`engine_kind`] reports which tier is
//! live): `count_ones()` lowers to the hardware `popcnt`/`vpshufb`
//! nibble-LUT forms instead of the portable SWAR sequence. The default
//! build is unaffected — the scalar-walk kernels in
//! [`super::reference`] remain the differential oracle either way
//! (`tests/packed_parity.rs` fuzzes both configurations), and sums are
//! bit-identical across all tiers: AND/popcount arithmetic has no
//! floating point, so vectorization cannot change a single bit.

use super::reference::{gather_window, or_shifted_wide, BitMap, PackedLayer};
use crate::telemetry::region;

/// Output channels per lane block: one u64x4 AVX2 register pair's worth,
/// and a full unroll for the portable SWAR path.
pub const LANES: usize = 8;

/// A [`PackedLayer`] transposed for lane-parallel popcounting: channels
/// grouped in blocks of [`LANES`], plane words interleaved lane-minor —
/// `words[(b * plane_words + j) * LANES + l]` is window word `j` of
/// output channel `b*LANES + l`. Channels past `c_out` in the last block
/// are zero planes (their sums are computed and discarded; zero planes
/// cannot set bits or corrupt neighbours).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneLayer {
    pub c_in: usize,
    pub c_out: usize,
    pub kernel: usize,
    pub pooled: bool,
    pub binarized: bool,
    /// Words per plane, same as the source layer: `ceil(kernel*c_in/64)`.
    pub plane_words: usize,
    /// Lane blocks: `ceil(c_out / LANES)`.
    pub blocks: usize,
    /// `blocks * plane_words * LANES` interleaved plane words.
    pub words: Vec<u64>,
    pub thresholds: Vec<i32>,
}

impl LaneLayer {
    /// Transpose a packed layer into lane-blocked form (done once at
    /// decode/shard time; the planes themselves are bit-identical).
    pub fn from_packed(p: &PackedLayer) -> Self {
        let pw = p.plane_words;
        let blocks = p.c_out.div_ceil(LANES);
        let mut words = vec![0u64; blocks * pw * LANES];
        for co in 0..p.c_out {
            let (b, l) = (co / LANES, co % LANES);
            for (j, &w) in p.plane(co).iter().enumerate() {
                words[(b * pw + j) * LANES + l] = w;
            }
        }
        LaneLayer {
            c_in: p.c_in,
            c_out: p.c_out,
            kernel: p.kernel,
            pooled: p.pooled,
            binarized: p.binarized,
            plane_words: pw,
            blocks,
            words,
            thresholds: p.thresholds.clone(),
        }
    }

    /// Block `b`'s interleaved words (`plane_words * LANES` of them).
    #[inline]
    pub fn block(&self, b: usize) -> &[u64] {
        let n = self.plane_words * LANES;
        &self.words[b * n..(b + 1) * n]
    }

    /// Live lanes of block `b` (< [`LANES`] only in the last block).
    #[inline]
    fn live(&self, b: usize) -> usize {
        LANES.min(self.c_out - b * LANES)
    }
}

/// Shift `src`'s bit vector down by `sh_bits` into `dst` (bit `p+sh_bits`
/// of `src` becomes bit `p` of `dst`; high bits fill with zero). Both
/// slices are `plane_words` long. This is the incremental-window step:
/// shifting a window by `c_in` retires the oldest row and leaves the top
/// `c_in` bits clear for the incoming one.
#[inline]
fn shift_down_into(dst: &mut [u64], src: &[u64], sh_bits: usize) {
    debug_assert_eq!(dst.len(), src.len());
    let n = src.len();
    let wsh = sh_bits / 64;
    let sh = (sh_bits % 64) as u32;
    for (i, d) in dst.iter_mut().enumerate() {
        let j = i + wsh;
        let lo = if j < n { src[j] >> sh } else { 0 };
        // `sh == 0` would make the carry shift `<< 64` (UB), and there is
        // no carry to take in that case.
        let hi = if sh > 0 && j + 1 < n { src[j + 1] << (64 - sh) } else { 0 };
        *d = lo | hi;
    }
}

/// Materialize every im2col window of `x` for a kernel-`k` layer into
/// `windows` (`x.t * pw` u64 words, window `t` at `windows[t*pw..][..pw]`)
/// with per-window activation popcounts in `acts`. Window 0 is gathered
/// from scratch; each subsequent one is its predecessor shifted down by
/// `c_in` bits with the single incoming row (`t + k-1 - pad`, when in
/// range) OR-patched at bit offset `(k-1)*c_in` — the shift leaves those
/// top bits zero, and rows beyond the map contribute the zero padding the
/// scalar kernels model by skipping. Bit-identical to calling
/// `gather_window` at every position (property-tested).
pub(crate) fn build_windows_into(
    x: &BitMap,
    kernel: usize,
    pw: usize,
    windows: &mut [u64],
    acts: &mut [i32],
) {
    if x.t == 0 {
        return;
    }
    debug_assert_eq!(windows.len(), x.t * pw);
    debug_assert_eq!(acts.len(), x.t);
    let pad = (kernel - 1) / 2;
    gather_window(x, kernel, 0, &mut windows[..pw]);
    acts[0] = windows[..pw].iter().map(|v| v.count_ones()).sum::<u32>() as i32;
    for t in 1..x.t {
        let (done, rest) = windows.split_at_mut(t * pw);
        let prev = &done[(t - 1) * pw..];
        let cur = &mut rest[..pw];
        shift_down_into(cur, prev, x.c);
        let incoming = t + kernel - 1 - pad;
        if incoming < x.t {
            or_shifted_wide(cur, (kernel - 1) * x.c, x.row_words(incoming));
        }
        acts[t] = cur.iter().map(|v| v.count_ones()).sum::<u32>() as i32;
    }
}

/// [`build_windows_into`] over a whole batch: utterance `u`'s windows at
/// `windows[u * t_in * pw..]`, acts likewise. All maps must share
/// geometry (same assert as the reference batch kernels).
fn build_windows_batch(xs: &[BitMap], kernel: usize, pw: usize) -> (Vec<u64>, Vec<i32>) {
    let t_in = xs[0].t;
    let mut windows = vec![0u64; xs.len() * t_in * pw];
    let mut acts = vec![0i32; xs.len() * t_in];
    for (u, x) in xs.iter().enumerate() {
        assert_eq!((x.t, x.c), (t_in, xs[0].c), "batch maps must share geometry");
        build_windows_into(
            x,
            kernel,
            pw,
            &mut windows[u * t_in * pw..(u + 1) * t_in * pw],
            &mut acts[u * t_in..(u + 1) * t_in],
        );
    }
    (windows, acts)
}

/// The engine's one arithmetic primitive: for every window in `windows`
/// (`pw` words each, activation popcounts in `acts`), the XNOR-popcount
/// sums of one lane block — `sums[w*LANES + l] = 2*pop(win_w & plane_l)
/// - acts[w]`. Generic body, `#[inline(always)]` so the `target_feature`
/// wrappers below specialize it with their ISA extensions enabled.
#[inline(always)]
fn block_sums_impl(block: &[u64], pw: usize, windows: &[u64], acts: &[i32], sums: &mut [i32]) {
    debug_assert_eq!(block.len(), pw * LANES);
    debug_assert_eq!(windows.len(), acts.len() * pw);
    debug_assert_eq!(sums.len(), acts.len() * LANES);
    for (w, (win, &act)) in windows.chunks_exact(pw).zip(acts).enumerate() {
        let mut acc = [0u32; LANES];
        for (j, &xv) in win.iter().enumerate() {
            let row = &block[j * LANES..j * LANES + LANES];
            for (a, &pv) in acc.iter_mut().zip(row) {
                *a += (xv & pv).count_ones();
            }
        }
        let out = &mut sums[w * LANES..w * LANES + LANES];
        for (o, &a) in out.iter_mut().zip(&acc) {
            *o = (2 * a) as i32 - act;
        }
    }
}

fn block_sums_portable(block: &[u64], pw: usize, windows: &[u64], acts: &[i32], sums: &mut [i32]) {
    block_sums_impl(block, pw, windows, acts, sums)
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod x86 {
    use super::block_sums_impl;

    /// # Safety
    /// The caller must have verified `avx2` and `popcnt` support via
    /// runtime detection (the dispatcher does).
    #[target_feature(enable = "avx2,popcnt")]
    pub unsafe fn block_sums_avx2(
        block: &[u64],
        pw: usize,
        windows: &[u64],
        acts: &[i32],
        sums: &mut [i32],
    ) {
        block_sums_impl(block, pw, windows, acts, sums)
    }

    /// # Safety
    /// The caller must have verified `popcnt` support via runtime
    /// detection (the dispatcher does).
    #[target_feature(enable = "popcnt")]
    pub unsafe fn block_sums_popcnt(
        block: &[u64],
        pw: usize,
        windows: &[u64],
        acts: &[i32],
        sums: &mut [i32],
    ) {
        block_sums_impl(block, pw, windows, acts, sums)
    }
}

/// Which popcount tier the dispatcher resolves to on this host:
/// `"avx2"` / `"popcnt"` (with the `simd` feature on a capable x86-64)
/// or `"portable"` (default build, or no usable extension). Reported in
/// `BENCH_kernels.json` so bench rows are interpretable.
pub fn engine_kind() -> &'static str {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("popcnt")
        {
            return "avx2";
        }
        if std::arch::is_x86_feature_detected!("popcnt") {
            return "popcnt";
        }
    }
    "portable"
}

/// Dispatch [`block_sums_impl`] at the best tier the host supports. The
/// detection macro reads a cached atomic, so per-call cost is noise next
/// to a block's `pw * LANES * windows` popcounts.
#[inline]
fn block_sums(block: &[u64], pw: usize, windows: &[u64], acts: &[i32], sums: &mut [i32]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("popcnt")
        {
            // SAFETY: avx2+popcnt presence just verified.
            return unsafe { x86::block_sums_avx2(block, pw, windows, acts, sums) };
        }
        if std::arch::is_x86_feature_detected!("popcnt") {
            // SAFETY: popcnt presence just verified.
            return unsafe { x86::block_sums_popcnt(block, pw, windows, acts, sums) };
        }
    }
    block_sums_portable(block, pw, windows, acts, sums)
}

/// Lane-engine twin of `reference::conv_sums_packed`: sums at one
/// position (diagnostics/fuzzing; the layer kernels below never call it —
/// they amortize the incremental window build across all positions).
pub fn conv_sums_lanes(x: &BitMap, layer: &LaneLayer, t: usize) -> Vec<i32> {
    assert_eq!(x.c, layer.c_in, "feature map width must match the layer");
    let pw = layer.plane_words;
    let mut windows = vec![0u64; x.t * pw];
    let mut acts = vec![0i32; x.t];
    build_windows_into(x, layer.kernel, pw, &mut windows, &mut acts);
    let mut sums = vec![0i32; LANES];
    let mut out = vec![0i32; layer.c_out];
    for b in 0..layer.blocks {
        block_sums(
            layer.block(b),
            pw,
            &windows[t * pw..(t + 1) * pw],
            &acts[t..t + 1],
            &mut sums,
        );
        let live = layer.live(b);
        out[b * LANES..b * LANES + live].copy_from_slice(&sums[..live]);
    }
    out
}

/// Lane/incremental twin of `reference::conv_layer_packed_batch`:
/// bit-identical output maps, windows built once per map by
/// shift-and-patch, channels popcounted [`LANES`] at a time with each
/// block's planes walked once per batch (weight-stationary, blocks
/// outermost).
pub fn conv_layer_lanes_batch(xs: &[BitMap], layer: &LaneLayer) -> Vec<BitMap> {
    assert!(layer.binarized);
    if xs.is_empty() {
        return Vec::new();
    }
    assert_eq!(xs[0].c, layer.c_in, "feature map width must match the layer");
    let (t_in, pw) = (xs[0].t, layer.plane_words);
    let t_out = if layer.pooled { t_in / 2 } else { t_in };
    let (windows, acts) = {
        let _r = region("window_build");
        build_windows_batch(xs, layer.kernel, pw)
    };
    let mut outs: Vec<BitMap> = xs.iter().map(|_| BitMap::zero(t_out, layer.c_out)).collect();
    let mut sums = vec![0i32; t_in * LANES];
    // One coarse region per kernel call (never per block: the guard
    // would dominate the 8-lane popcount loop it measures).
    let _r = region("block_sums");
    for b in 0..layer.blocks {
        let block = layer.block(b);
        let live = layer.live(b);
        let thr = &layer.thresholds[b * LANES..b * LANES + live];
        for (u, out) in outs.iter_mut().enumerate() {
            block_sums(
                block,
                pw,
                &windows[u * t_in * pw..(u + 1) * t_in * pw],
                &acts[u * t_in..(u + 1) * t_in],
                &mut sums,
            );
            for t in 0..t_in {
                let ot = if layer.pooled { t / 2 } else { t };
                if ot >= t_out {
                    break; // odd tail dropped by pooling
                }
                for (l, &th) in thr.iter().enumerate() {
                    if sums[t * LANES + l] > th {
                        out.set(ot, b * LANES + l); // pooled max == OR of the pair
                    }
                }
            }
        }
    }
    outs
}

/// Lane/incremental twin of `reference::final_layer_gap_packed_batch`:
/// raw sums accumulated per lane across positions, GAP division last
/// (identical integer sums ⇒ identical f32 logits).
pub fn final_layer_gap_lanes_batch(xs: &[BitMap], layer: &LaneLayer) -> Vec<Vec<f32>> {
    assert!(!layer.binarized);
    if xs.is_empty() {
        return Vec::new();
    }
    assert_eq!(xs[0].c, layer.c_in, "feature map width must match the layer");
    let (t_in, pw) = (xs[0].t, layer.plane_words);
    let (windows, acts) = {
        let _r = region("window_build");
        build_windows_batch(xs, layer.kernel, pw)
    };
    let mut logits = vec![vec![0.0f32; layer.c_out]; xs.len()];
    let mut sums = vec![0i32; t_in * LANES];
    let _r = region("block_sums");
    for b in 0..layer.blocks {
        let block = layer.block(b);
        let live = layer.live(b);
        for (u, l) in logits.iter_mut().enumerate() {
            block_sums(
                block,
                pw,
                &windows[u * t_in * pw..(u + 1) * t_in * pw],
                &acts[u * t_in..(u + 1) * t_in],
                &mut sums,
            );
            let mut acc = [0i64; LANES];
            for chunk in sums.chunks_exact(LANES) {
                for (a, &s) in acc.iter_mut().zip(chunk) {
                    *a += s as i64;
                }
            }
            for (lane, &a) in acc[..live].iter().enumerate() {
                l[b * LANES + lane] = a as f32 / t_in as f32;
            }
        }
    }
    logits
}

/// Single-map conv through the lane engine (a batch of one: the batched
/// kernel's window build and block walk are already position-amortized,
/// so there is no cheaper dedicated form).
pub fn conv_layer_lanes(x: &BitMap, layer: &LaneLayer) -> BitMap {
    conv_layer_lanes_batch(std::slice::from_ref(x), layer).pop().unwrap()
}

/// Single-map GAP through the lane engine.
pub fn final_layer_gap_lanes(x: &BitMap, layer: &LaneLayer) -> Vec<f32> {
    final_layer_gap_lanes_batch(std::slice::from_ref(x), layer).pop().unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kws::LayerSpec;
    use crate::model::reference::{
        conv_layer_packed, conv_sums_packed, final_layer_gap_packed,
    };

    fn tiny_layer(c_in: usize, c_out: usize, kernel: usize, pooled: bool, binarized: bool) -> LayerSpec {
        let rows = kernel * c_in;
        let weights = (0..rows * c_out)
            .map(|i| {
                let (r, co) = (i / c_out, i % c_out);
                if (r * 3 + co * 7) % 5 < 2 { 1i8 } else { -1 }
            })
            .collect();
        LayerSpec {
            c_in,
            c_out,
            kernel,
            pooled,
            binarized,
            weights,
            thresholds: if binarized {
                (0..c_out).map(|co| (co % 7) as i32 - 3).collect()
            } else {
                vec![]
            },
        }
    }

    fn patterned_bits(t: usize, c: usize, salt: usize) -> BitMap {
        let mut x = BitMap::zero(t, c);
        for r in 0..t {
            for ch in 0..c {
                if (r * 11 + ch * 5 + salt * 3) % 7 < 3 {
                    x.set(r, ch);
                }
            }
        }
        x
    }

    #[test]
    fn lane_transpose_roundtrips_planes() {
        let spec = tiny_layer(70, 19, 3, false, true); // ragged both ways
        let packed = PackedLayer::from_spec(&spec);
        let lanes = LaneLayer::from_packed(&packed);
        assert_eq!(lanes.blocks, 19usize.div_ceil(LANES));
        for co in 0..packed.c_out {
            let (b, l) = (co / LANES, co % LANES);
            let block = lanes.block(b);
            for (j, &w) in packed.plane(co).iter().enumerate() {
                assert_eq!(block[j * LANES + l], w, "co {co} word {j}");
            }
        }
        // Dead lanes in the last block are zero planes.
        for l in 19 % LANES..LANES {
            let block = lanes.block(lanes.blocks - 1);
            for j in 0..lanes.plane_words {
                assert_eq!(block[j * LANES + l], 0);
            }
        }
    }

    #[test]
    fn incremental_windows_match_gather_every_position() {
        for kernel in [1usize, 3, 5] {
            for c in [1usize, 31, 32, 64, 70] {
                let x = patterned_bits(9, c, kernel);
                let pw = (kernel * c).div_ceil(64);
                let mut windows = vec![0u64; x.t * pw];
                let mut acts = vec![0i32; x.t];
                build_windows_into(&x, kernel, pw, &mut windows, &mut acts);
                let mut want = vec![0u64; pw];
                for t in 0..x.t {
                    gather_window(&x, kernel, t, &mut want);
                    assert_eq!(&windows[t * pw..(t + 1) * pw], &want[..], "k {kernel} c {c} t {t}");
                    let act: u32 = want.iter().map(|v| v.count_ones()).sum();
                    assert_eq!(acts[t], act as i32, "k {kernel} c {c} t {t}");
                }
            }
        }
    }

    #[test]
    fn shift_down_handles_word_multiples_and_overhang() {
        // 128-bit vector, shift by exactly 64 (sh == 0 path).
        let src = [0xDEAD_BEEF_0123_4567u64, 0x8899_AABB_CCDD_EEFF];
        let mut dst = [0u64; 2];
        shift_down_into(&mut dst, &src, 64);
        assert_eq!(dst, [0x8899_AABB_CCDD_EEFF, 0]);
        // Shift past the end zeroes everything.
        shift_down_into(&mut dst, &src, 128);
        assert_eq!(dst, [0, 0]);
        // Unaligned shift carries bits across the word boundary.
        shift_down_into(&mut dst, &src, 4);
        assert_eq!(dst[0], (src[0] >> 4) | (src[1] << 60));
        assert_eq!(dst[1], src[1] >> 4);
    }

    #[test]
    fn lane_kernels_match_packed_reference() {
        for (c_in, c_out, kernel, pooled) in
            [(8, 8, 3, false), (70, 19, 3, true), (33, 5, 5, false), (17, 24, 1, true)]
        {
            let spec = tiny_layer(c_in, c_out, kernel, pooled, true);
            let packed = PackedLayer::from_spec(&spec);
            let lanes = LaneLayer::from_packed(&packed);
            let x = patterned_bits(11, c_in, c_out); // odd t: pooling tail
            assert_eq!(
                conv_layer_lanes(&x, &lanes),
                conv_layer_packed(&x, &packed),
                "conv {c_in}x{c_out} k{kernel} pooled {pooled}"
            );
            for t in 0..x.t {
                assert_eq!(
                    conv_sums_lanes(&x, &lanes, t),
                    conv_sums_packed(&x, &packed, t),
                    "sums t {t}"
                );
            }
        }
        let spec = tiny_layer(19, 12, 3, false, false);
        let packed = PackedLayer::from_spec(&spec);
        let lanes = LaneLayer::from_packed(&packed);
        let x = patterned_bits(7, 19, 1);
        assert_eq!(final_layer_gap_lanes(&x, &lanes), final_layer_gap_packed(&x, &packed));
    }

    #[test]
    fn batched_lane_kernels_match_single_and_empty() {
        let conv = tiny_layer(70, 23, 3, true, true);
        let last = tiny_layer(23, 12, 3, false, false);
        let pc = PackedLayer::from_spec(&conv);
        let pl = PackedLayer::from_spec(&last);
        let lc = LaneLayer::from_packed(&pc);
        let ll = LaneLayer::from_packed(&pl);
        let xs: Vec<BitMap> = (0..5).map(|u| patterned_bits(9, 70, u)).collect();
        let mids = conv_layer_lanes_batch(&xs, &lc);
        for (u, x) in xs.iter().enumerate() {
            assert_eq!(mids[u], conv_layer_lanes(x, &lc), "u {u}");
            assert_eq!(mids[u], conv_layer_packed(x, &pc), "u {u} vs packed");
        }
        let logits = final_layer_gap_lanes_batch(&mids, &ll);
        for (u, mid) in mids.iter().enumerate() {
            assert_eq!(logits[u], final_layer_gap_packed(mid, &pl), "u {u}");
        }
        assert!(conv_layer_lanes_batch(&[], &lc).is_empty());
        assert!(final_layer_gap_lanes_batch(&[], &ll).is_empty());
    }

    #[test]
    fn engine_kind_is_a_known_tier() {
        assert!(["avx2", "popcnt", "portable"].contains(&engine_kind()));
        if !cfg!(feature = "simd") {
            assert_eq!(engine_kind(), "portable");
        }
    }
}
