//! Host reference implementation of quantized KWS inference — the Rust
//! mirror of `python/compile/kernels/ref.py`, bit-exact against both the
//! AOT-lowered JAX model (checked in `rust/tests/golden_crosscheck.rs`)
//! and the cycle-level ISS run (checked in `rust/tests/integration.rs`).
//!
//! Everything after the ADC is integer arithmetic; the only floats are the
//! final GAP division (exact: integer sums, power-of-two divisor regime is
//! not needed — f32 division of an integer-valued sum by a small integer
//! matches jnp.mean's float math for our magnitudes... see note on `gap`).

use super::kws::KwsModel;

/// A binary (t, c) feature map, bit-packed per row: `words_per_row =
/// ceil(c/32)`, bit (r, ch) at word `r*wpr + ch/32`, bit `ch%32`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMap {
    pub t: usize,
    pub c: usize,
    pub words: Vec<u32>,
}

impl BitMap {
    pub fn zero(t: usize, c: usize) -> Self {
        BitMap { t, c, words: vec![0; t * c.div_ceil(32)] }
    }

    pub fn wpr(&self) -> usize {
        self.c.div_ceil(32)
    }

    #[inline]
    pub fn get(&self, r: usize, ch: usize) -> bool {
        (self.words[r * self.wpr() + ch / 32] >> (ch % 32)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, r: usize, ch: usize) {
        let w = self.wpr();
        self.words[r * w + ch / 32] |= 1 << (ch % 32);
    }

    /// Count of set bits (tests/diagnostics).
    pub fn popcount(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }
}

/// ADC quantization: float waveform -> integer samples (11 bit + sign),
/// mirror of `ref.quantize_audio`.
pub fn quantize_audio(audio: &[f32]) -> Vec<i32> {
    audio
        .iter()
        .map(|&x| (x.clamp(-1.0, 1.0) * 2048.0).round_ties_even() as i32)
        .collect()
}

/// Integer preprocessing: pre-emphasis + frame features + folded-BN
/// compare -> binary (t, c) feature map. Mirror of `ref.ref_preprocess`
/// with BN folded to integer thresholds (`kws::fold_bn`).
pub fn preprocess(model: &KwsModel, audio: &[f32]) -> BitMap {
    let q = quantize_audio(audio);
    let frame = model.audio_len / model.t;
    let mut bits = BitMap::zero(model.t, model.c);
    for t in 0..model.t {
        for ch in 0..model.c {
            let idx = t * frame + ch;
            let x = q[idx] as i64;
            let prev = if idx == 0 { 0 } else { q[idx - 1] as i64 };
            let y = 32 * x - 31 * prev;
            let f = y.abs();
            let on = match model.pre_dir[ch] {
                1 => f > model.pre_thr[ch],
                -1 => f < model.pre_thr[ch] + 1,
                _ => model.bn_beta[ch] > 0.0,
            };
            if on {
                bits.set(t, ch);
            }
        }
    }
    bits
}

/// Binary conv1d row sums at position `t` for all output channels:
/// integer MAC over the tap-major/channel-minor im2col window with
/// symmetric zero padding (pad = (k-1)/2), identical to
/// `ref.ref_conv1d_binary`.
pub fn conv_sums(x: &BitMap, w: &super::kws::LayerSpec, t: usize) -> Vec<i32> {
    let k = w.kernel;
    let pad = (k - 1) / 2;
    let mut sums = vec![0i32; w.c_out];
    for j in 0..k {
        let tt = t as isize + j as isize - pad as isize;
        if tt < 0 || tt >= x.t as isize {
            continue; // zero padding contributes nothing
        }
        let row = tt as usize;
        for ci in 0..w.c_in {
            if x.get(row, ci) {
                let r = j * w.c_in + ci;
                for (co, s) in sums.iter_mut().enumerate() {
                    *s += w.weight(r, co) as i32;
                }
            }
        }
    }
    sums
}

/// One binarized conv layer (+ optional 2:1 max pool fused).
pub fn conv_layer(x: &BitMap, layer: &super::kws::LayerSpec) -> BitMap {
    assert!(layer.binarized);
    let t_out = if layer.pooled { x.t / 2 } else { x.t };
    let mut out = BitMap::zero(t_out, layer.c_out);
    for t in 0..x.t {
        let sums = conv_sums(x, layer, t);
        let ot = if layer.pooled { t / 2 } else { t };
        if ot >= t_out {
            break; // odd tail dropped by pooling
        }
        for co in 0..layer.c_out {
            if sums[co] > layer.thresholds[co] {
                out.set(ot, co); // pooled max == OR of the pair
            }
        }
    }
    out
}

/// The raw final layer + global average pooling -> logits. The division
/// is f32 like jnp.mean; sums and t are small integers so it is exact.
pub fn final_layer_gap(x: &BitMap, layer: &super::kws::LayerSpec) -> Vec<f32> {
    assert!(!layer.binarized);
    let mut acc = vec![0i64; layer.c_out];
    for t in 0..x.t {
        for (co, s) in conv_sums(x, layer, t).iter().enumerate() {
            acc[co] += *s as i64;
        }
    }
    acc.iter().map(|&s| s as f32 / x.t as f32).collect()
}

/// Full inference: audio -> logits. Bit-exact vs the JAX golden model.
pub fn infer(model: &KwsModel, audio: &[f32]) -> Vec<f32> {
    let mut x = preprocess(model, audio);
    for layer in &model.layers[..model.layers.len() - 1] {
        x = conv_layer(&x, layer);
    }
    final_layer_gap(&x, model.layers.last().unwrap())
}

/// Argmax helper (accuracy eval).
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kws::LayerSpec;

    fn tiny_layer(c_in: usize, c_out: usize, pooled: bool, binarized: bool) -> LayerSpec {
        // deterministic weights: +1 iff (row + co) even
        let k = 3;
        let rows = k * c_in;
        let weights = (0..rows * c_out)
            .map(|i| {
                let (r, co) = (i / c_out, i % c_out);
                if (r + co) % 2 == 0 { 1i8 } else { -1 }
            })
            .collect();
        LayerSpec {
            c_in,
            c_out,
            kernel: k,
            pooled,
            binarized,
            weights,
            thresholds: if binarized { vec![0; c_out] } else { vec![] },
        }
    }

    #[test]
    fn bitmap_roundtrip() {
        let mut b = BitMap::zero(4, 70);
        b.set(0, 0);
        b.set(3, 69);
        b.set(2, 32);
        assert!(b.get(0, 0) && b.get(3, 69) && b.get(2, 32));
        assert!(!b.get(1, 0) && !b.get(3, 68));
        assert_eq!(b.popcount(), 3);
    }

    #[test]
    fn conv_padding_zero_at_edges() {
        let layer = tiny_layer(4, 2, false, true);
        let mut x = BitMap::zero(3, 4);
        // only row 0 has bits -> position 2's window (rows 1,2,3) sums 0.
        x.set(0, 0);
        x.set(0, 3);
        let s0 = conv_sums(&x, &layer, 0);
        let s2 = conv_sums(&x, &layer, 2);
        assert_eq!(s2, vec![0, 0]);
        // Row 0 enters position 0's window at tap j=1 (center).
        // r = 1*4+0 = 4: w(4, 0) = +1; r = 1*4+3 = 7: w(7,0) = -1 -> 0.
        assert_eq!(s0[0], 0);
        // co=1: w(4,1) = -1, w(7,1) = +1 -> 0.
        assert_eq!(s0[1], 0);
    }

    #[test]
    fn conv_sums_match_naive() {
        // Naive O(t*k*ci*co) vs conv_sums on random-ish bits.
        let layer = tiny_layer(8, 4, false, true);
        let mut x = BitMap::zero(10, 8);
        for t in 0..10 {
            for c in 0..8 {
                if (t * 7 + c * 3) % 5 < 2 {
                    x.set(t, c);
                }
            }
        }
        for t in 0..10 {
            let got = conv_sums(&x, &layer, t);
            let mut want = vec![0i32; 4];
            for j in 0..3 {
                let tt = t as isize + j as isize - 1;
                if tt < 0 || tt >= 10 {
                    continue;
                }
                for ci in 0..8 {
                    if x.get(tt as usize, ci) {
                        for (co, wv) in want.iter_mut().enumerate() {
                            *wv += layer.weight(j * 8 + ci, co) as i32;
                        }
                    }
                }
            }
            assert_eq!(got, want, "position {t}");
        }
    }

    #[test]
    fn pooled_layer_is_or_of_pairs() {
        let layer = tiny_layer(4, 4, true, true);
        let mut x = BitMap::zero(6, 4);
        x.set(1, 1);
        x.set(4, 2);
        let pooled = conv_layer(&x, &layer);
        // Unpooled computed by a non-pooled twin must OR pairwise.
        let mut twin = layer.clone();
        twin.pooled = false;
        let unpooled = conv_layer(&x, &twin);
        assert_eq!(pooled.t, 3);
        for t in 0..3 {
            for co in 0..4 {
                assert_eq!(
                    pooled.get(t, co),
                    unpooled.get(2 * t, co) || unpooled.get(2 * t + 1, co)
                );
            }
        }
    }

    #[test]
    fn quantize_is_clamped_and_integral() {
        let q = quantize_audio(&[-2.0, -1.0, 0.0, 0.4999, 1.0, 2.0]);
        assert_eq!(q[0], -2048);
        assert_eq!(q[1], -2048);
        assert_eq!(q[2], 0);
        assert_eq!(q[4], 2048);
        assert_eq!(q[5], 2048);
    }
}
