//! Host reference implementation of quantized KWS inference — the Rust
//! mirror of `python/compile/kernels/ref.py`, bit-exact against both the
//! AOT-lowered JAX model (checked in `rust/tests/golden_crosscheck.rs`)
//! and the cycle-level ISS run (checked in `rust/tests/integration.rs`).
//!
//! Everything after the ADC is integer arithmetic; the only floats are the
//! final GAP division (exact: integer sums, power-of-two divisor regime is
//! not needed — f32 division of an integer-valued sum by a small integer
//! matches jnp.mean's float math for our magnitudes... see note on `gap`).

use super::kws::{KwsModel, LayerSpec};

/// A binary (t, c) feature map, bit-packed per row: `words_per_row =
/// ceil(c/32)`, bit (r, ch) at word `r*wpr + ch/32`, bit `ch%32`. Bits at
/// or above `c` in a row's last word are always zero — the packed kernels
/// rely on that to treat whole rows as word vectors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BitMap {
    pub t: usize,
    pub c: usize,
    /// Words per row, cached so `get`/`set` skip the division.
    wpr: usize,
    pub words: Vec<u32>,
}

impl BitMap {
    pub fn zero(t: usize, c: usize) -> Self {
        let wpr = c.div_ceil(32);
        BitMap { t, c, wpr, words: vec![0; t * wpr] }
    }

    #[inline]
    pub fn wpr(&self) -> usize {
        self.wpr
    }

    #[inline]
    pub fn get(&self, r: usize, ch: usize) -> bool {
        (self.words[r * self.wpr + ch / 32] >> (ch % 32)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, r: usize, ch: usize) {
        self.words[r * self.wpr + ch / 32] |= 1 << (ch % 32);
    }

    /// Row `r` as its packed word slice (word-level iteration for the
    /// packed kernels; padding bits above `c` are zero).
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u32] {
        &self.words[r * self.wpr..(r + 1) * self.wpr]
    }

    /// Count of set bits (tests/diagnostics).
    pub fn popcount(&self) -> u64 {
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// OR `src` (a packed row, padding bits above its meaningful length
    /// zero) into row `r` starting at channel bit `bit_off`. The shard
    /// merge primitive: a shard's output channels land at their global
    /// channel positions, word-aligned or not. The caller guarantees
    /// `bit_off + meaningful src bits <= c`.
    #[inline]
    pub fn or_row_at(&mut self, r: usize, bit_off: usize, src: &[u32]) {
        let row = &mut self.words[r * self.wpr..(r + 1) * self.wpr];
        or_shifted(row, bit_off, src);
    }
}

/// A conv layer in the macro's native form: one sign bit-plane per output
/// channel, bit `r` set ⇔ weight `(r, co)` is +1. The planes are stored
/// column-major (`co`-major, word-minor) in **u64 window words** —
/// `ceil(kernel*c_in/64)` per plane — so the XNOR-popcount inner loop
/// runs half the trips of the u32 form. The compiled image's DRAM sign
/// stream (`KwsPlan::build_dram_weights`) stays u32 column-major: each
/// u64 here is two consecutive stream words (little-endian halves), and
/// [`Self::stream_word`] recovers the stream/weight-port granularity for
/// macro loads (`cim::weight_map`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedLayer {
    pub c_in: usize,
    pub c_out: usize,
    pub kernel: usize,
    pub pooled: bool,
    pub binarized: bool,
    /// Words per plane: `ceil(kernel*c_in/64)`.
    pub plane_words: usize,
    /// Sign planes, `c_out * plane_words` u64 words; bits above `rows()`
    /// in a plane's last word are zero.
    pub planes: Vec<u64>,
    /// Per-output-channel SA thresholds (empty for the raw final layer).
    pub thresholds: Vec<i32>,
}

impl PackedLayer {
    /// Pack a scalar layer's ±1 weights into sign bit-planes.
    pub fn from_spec(spec: &LayerSpec) -> Self {
        let rows = spec.rows();
        let pw = rows.div_ceil(64);
        let mut planes = vec![0u64; spec.c_out * pw];
        for co in 0..spec.c_out {
            let plane = &mut planes[co * pw..(co + 1) * pw];
            for r in 0..rows {
                if spec.weight(r, co) > 0 {
                    plane[r / 64] |= 1 << (r % 64);
                }
            }
        }
        PackedLayer {
            c_in: spec.c_in,
            c_out: spec.c_out,
            kernel: spec.kernel,
            pooled: spec.pooled,
            binarized: spec.binarized,
            plane_words: pw,
            planes,
            thresholds: spec.thresholds.clone(),
        }
    }

    /// Unpack to the tap-major/channel-minor scalar form (the oracle
    /// representation; also the PR 1 serving representation).
    pub fn to_spec(&self) -> LayerSpec {
        let rows = self.rows();
        let mut weights = vec![-1i8; rows * self.c_out];
        for co in 0..self.c_out {
            let plane = self.plane(co);
            for (r, w) in weights.iter_mut().skip(co).step_by(self.c_out).enumerate() {
                if (plane[r / 64] >> (r % 64)) & 1 == 1 {
                    *w = 1;
                }
            }
        }
        LayerSpec {
            c_in: self.c_in,
            c_out: self.c_out,
            kernel: self.kernel,
            pooled: self.pooled,
            binarized: self.binarized,
            weights,
            thresholds: self.thresholds.clone(),
        }
    }

    /// The sub-layer holding output channels `[c0, c1)` — the shard a
    /// single macro owns under a `dataflow::shard::ShardPlan`. The planes
    /// are column-major, so a channel range is a contiguous word range;
    /// sums and thresholds of the retained channels are untouched, which
    /// is what makes sharded inference bit-identical.
    pub fn slice_channels(&self, c0: usize, c1: usize) -> PackedLayer {
        assert!(c0 <= c1 && c1 <= self.c_out, "channel slice out of range");
        PackedLayer {
            c_in: self.c_in,
            c_out: c1 - c0,
            kernel: self.kernel,
            pooled: self.pooled,
            binarized: self.binarized,
            plane_words: self.plane_words,
            planes: self.planes[c0 * self.plane_words..c1 * self.plane_words].to_vec(),
            thresholds: if self.thresholds.is_empty() {
                Vec::new()
            } else {
                self.thresholds[c0..c1].to_vec()
            },
        }
    }

    /// Wordlines this layer occupies (`kernel * c_in`).
    #[inline]
    pub fn rows(&self) -> usize {
        self.kernel * self.c_in
    }

    /// Output channel `co`'s sign plane.
    #[inline]
    pub fn plane(&self, co: usize) -> &[u64] {
        &self.planes[co * self.plane_words..(co + 1) * self.plane_words]
    }

    /// Words per plane at the DRAM sign-stream / weight-port granularity
    /// (`ceil(kernel*c_in/32)`, the layout the compiled image carries).
    #[inline]
    pub fn stream_words(&self) -> usize {
        self.rows().div_ceil(32)
    }

    /// Stream word `wj` of channel `co`: the u32 the DRAM sign stream and
    /// the macro's weight port hold at that offset (each u64 plane word
    /// is two consecutive stream words, little-endian halves).
    #[inline]
    pub fn stream_word(&self, co: usize, wj: usize) -> u32 {
        (self.planes[co * self.plane_words + wj / 2] >> (32 * (wj % 2))) as u32
    }
}

/// OR `src`'s bits into `dst` starting at bit `bit_off`. Bits of `src`
/// beyond its meaningful length must be zero (BitMap's row-padding
/// guarantee), so only real feature bits land in `dst`.
#[inline]
fn or_shifted(dst: &mut [u32], bit_off: usize, src: &[u32]) {
    let word = bit_off / 32;
    let sh = (bit_off % 32) as u32;
    if sh == 0 {
        for (d, &s) in dst[word..].iter_mut().zip(src) {
            *d |= s;
        }
        return;
    }
    for (i, &s) in src.iter().enumerate() {
        dst[word + i] |= s << sh;
        let hi = s >> (32 - sh);
        if hi != 0 {
            dst[word + i + 1] |= hi;
        }
    }
}

/// OR a u32 word vector (a `BitMap` row) into a u64 window buffer
/// starting at bit `bit_off`. The widened twin of [`or_shifted`]: source
/// bits beyond the row's meaningful length are zero (BitMap's padding
/// guarantee), so only real feature bits land in the window.
#[inline]
pub(crate) fn or_shifted_wide(dst: &mut [u64], bit_off: usize, src: &[u32]) {
    for (i, &s) in src.iter().enumerate() {
        if s == 0 {
            continue;
        }
        let off = bit_off + i * 32;
        let w = off / 64;
        let sh = (off % 64) as u32;
        dst[w] |= (s as u64) << sh;
        if sh > 32 {
            let hi = (s as u64) >> (64 - sh);
            if hi != 0 {
                dst[w + 1] |= hi;
            }
        }
    }
}

/// Gather the im2col window at position `t` into packed u64 words: input
/// row `t + j - pad` occupies bits `[j*c_in, (j+1)*c_in)`, matching the
/// wordline order `r = j*c_in + ci` of the scalar kernels and the macro.
/// Padding rows (outside the map) contribute zeros.
pub(crate) fn gather_window(x: &BitMap, kernel: usize, t: usize, out: &mut [u64]) {
    let pad = (kernel - 1) / 2;
    out.fill(0);
    for j in 0..kernel {
        let tt = t as isize + j as isize - pad as isize;
        if tt < 0 || tt >= x.t as isize {
            continue;
        }
        or_shifted_wide(out, j * x.c, x.row_words(tt as usize));
    }
}

/// `conv_sums` in the macro's arithmetic: with binary ±1 weights every
/// cell is active, so the MAC collapses to
/// `sum[co] = 2*popcount(x & sign[co]) - popcount(x)`
/// over the packed window words — one AND+popcount per 64 taps instead of
/// one scalar add per set input bit per channel.
///
/// The buffer-reusing form (`window`: `plane_words` u64 scratch, `sums`:
/// `c_out` outputs) — the position-at-a-time hot loop of both
/// [`conv_layer_packed`] and the variation-aware replay
/// (`robustness::replay`), which must walk sums fire by fire in the cycle
/// engine's order rather than channel-major.
pub fn conv_sums_packed_into(
    x: &BitMap,
    w: &PackedLayer,
    t: usize,
    window: &mut [u64],
    sums: &mut [i32],
) {
    debug_assert_eq!(x.c, w.c_in, "feature map width must match the layer");
    gather_window(x, w.kernel, t, window);
    let act: u32 = window.iter().map(|v| v.count_ones()).sum();
    for (co, s) in sums.iter_mut().enumerate() {
        let plane = w.plane(co);
        let mut pos = 0u32;
        for (xv, pv) in window.iter().zip(plane) {
            pos += (xv & pv).count_ones();
        }
        *s = (2 * pos) as i32 - act as i32;
    }
}

/// Packed twin of [`conv_sums`]: bit-identical sums, popcount arithmetic.
pub fn conv_sums_packed(x: &BitMap, w: &PackedLayer, t: usize) -> Vec<i32> {
    let mut window = vec![0u64; w.plane_words];
    let mut sums = vec![0i32; w.c_out];
    conv_sums_packed_into(x, w, t, &mut window, &mut sums);
    sums
}

/// Packed twin of [`conv_layer`] (+ optional fused 2:1 max pool).
pub fn conv_layer_packed(x: &BitMap, layer: &PackedLayer) -> BitMap {
    assert!(layer.binarized);
    let t_out = if layer.pooled { x.t / 2 } else { x.t };
    let mut out = BitMap::zero(t_out, layer.c_out);
    let mut window = vec![0u64; layer.plane_words];
    let mut sums = vec![0i32; layer.c_out];
    for t in 0..x.t {
        let ot = if layer.pooled { t / 2 } else { t };
        if ot >= t_out {
            break; // odd tail dropped by pooling
        }
        conv_sums_packed_into(x, layer, t, &mut window, &mut sums);
        for (co, &s) in sums.iter().enumerate() {
            if s > layer.thresholds[co] {
                out.set(ot, co); // pooled max == OR of the pair
            }
        }
    }
    out
}

/// Packed twin of [`final_layer_gap`]: raw sums + GAP, f32 division last.
pub fn final_layer_gap_packed(x: &BitMap, layer: &PackedLayer) -> Vec<f32> {
    assert!(!layer.binarized);
    let mut acc = vec![0i64; layer.c_out];
    let mut window = vec![0u64; layer.plane_words];
    let mut sums = vec![0i32; layer.c_out];
    for t in 0..x.t {
        conv_sums_packed_into(x, layer, t, &mut window, &mut sums);
        for (a, &s) in acc.iter_mut().zip(sums.iter()) {
            *a += s as i64;
        }
    }
    acc.iter().map(|&s| s as f32 / x.t as f32).collect()
}

/// Every utterance's im2col windows for one layer, materialized once:
/// window of utterance `u` at position `t` lives at
/// `windows[(u*t_in + t)*pw..][..pw]`, with its activation popcount in
/// `acts[u*t_in + t]`. This is what lets the batched kernels below walk
/// each weight plane **once per batch**: the `co` loop is outermost, so a
/// plane's words stay in registers across every (utterance, position)
/// pair instead of being re-fetched `t_in` times per utterance.
fn gather_windows_batch(xs: &[BitMap], layer: &PackedLayer) -> (Vec<u64>, Vec<i32>) {
    let (t_in, pw) = (xs[0].t, layer.plane_words);
    let mut windows = vec![0u64; xs.len() * t_in * pw];
    let mut acts = vec![0i32; xs.len() * t_in];
    for (u, x) in xs.iter().enumerate() {
        assert_eq!((x.t, x.c), (t_in, layer.c_in), "batch maps must share geometry");
        for t in 0..t_in {
            let w = &mut windows[(u * t_in + t) * pw..][..pw];
            gather_window(x, layer.kernel, t, w);
            acts[u * t_in + t] = w.iter().map(|v| v.count_ones()).sum::<u32>() as i32;
        }
    }
    (windows, acts)
}

/// Batched twin of [`conv_sums_packed`]: sums at position `t` for every
/// utterance, each weight plane read once for the whole batch.
pub fn conv_sums_packed_batch(xs: &[BitMap], w: &PackedLayer, t: usize) -> Vec<Vec<i32>> {
    let pw = w.plane_words;
    let mut windows = vec![0u64; xs.len() * pw];
    let mut acts = vec![0i32; xs.len()];
    for ((x, win), act) in xs.iter().zip(windows.chunks_mut(pw)).zip(acts.iter_mut()) {
        gather_window(x, w.kernel, t, win);
        *act = win.iter().map(|v| v.count_ones()).sum::<u32>() as i32;
    }
    let mut sums = vec![vec![0i32; w.c_out]; xs.len()];
    for co in 0..w.c_out {
        let plane = w.plane(co);
        for (u, s) in sums.iter_mut().enumerate() {
            let win = &windows[u * pw..(u + 1) * pw];
            let mut pos = 0u32;
            for (xv, pv) in win.iter().zip(plane) {
                pos += (xv & pv).count_ones();
            }
            s[co] = (2 * pos) as i32 - acts[u];
        }
    }
    sums
}

/// Batched twin of [`conv_layer_packed`]: one output map per input map,
/// bit-identical to calling the single-utterance kernel per map. The
/// weight walk is batch-amortized — planes outermost, utterances and
/// positions inner — which is the whole point of serving batch-first on
/// a weight-stationary macro.
pub fn conv_layer_packed_batch(xs: &[BitMap], layer: &PackedLayer) -> Vec<BitMap> {
    assert!(layer.binarized);
    if xs.is_empty() {
        return Vec::new();
    }
    let (t_in, pw) = (xs[0].t, layer.plane_words);
    let t_out = if layer.pooled { t_in / 2 } else { t_in };
    let (windows, acts) = gather_windows_batch(xs, layer);
    let mut outs: Vec<BitMap> = xs.iter().map(|_| BitMap::zero(t_out, layer.c_out)).collect();
    for (co, &thr) in layer.thresholds.iter().enumerate() {
        let plane = layer.plane(co);
        for (u, out) in outs.iter_mut().enumerate() {
            for t in 0..t_in {
                let ot = if layer.pooled { t / 2 } else { t };
                if ot >= t_out {
                    break; // odd tail dropped by pooling
                }
                let win = &windows[(u * t_in + t) * pw..][..pw];
                let mut pos = 0u32;
                for (xv, pv) in win.iter().zip(plane) {
                    pos += (xv & pv).count_ones();
                }
                if (2 * pos) as i32 - acts[u * t_in + t] > thr {
                    out.set(ot, co); // pooled max == OR of the pair
                }
            }
        }
    }
    outs
}

/// Batched twin of [`final_layer_gap_packed`]: one logits vector per
/// input map, planes walked once per batch.
pub fn final_layer_gap_packed_batch(xs: &[BitMap], layer: &PackedLayer) -> Vec<Vec<f32>> {
    assert!(!layer.binarized);
    if xs.is_empty() {
        return Vec::new();
    }
    let (t_in, pw) = (xs[0].t, layer.plane_words);
    let (windows, acts) = gather_windows_batch(xs, layer);
    let mut logits = vec![vec![0.0f32; layer.c_out]; xs.len()];
    for co in 0..layer.c_out {
        let plane = layer.plane(co);
        for (u, l) in logits.iter_mut().enumerate() {
            let mut acc = 0i64;
            for t in 0..t_in {
                let win = &windows[(u * t_in + t) * pw..][..pw];
                let mut pos = 0u32;
                for (xv, pv) in win.iter().zip(plane) {
                    pos += (xv & pv).count_ones();
                }
                acc += ((2 * pos) as i32 - acts[u * t_in + t]) as i64;
            }
            l[co] = acc as f32 / t_in as f32;
        }
    }
    logits
}

/// OR a shard's output feature map into the full-width map at channel
/// offset `c_off` (rows must agree). The functional simulator's shard
/// concatenation: each macro's channel range lands at its global bit
/// position, aligned or not.
pub fn merge_shard(dst: &mut BitMap, c_off: usize, shard: &BitMap) {
    assert_eq!(dst.t, shard.t, "shard rows must match");
    assert!(c_off + shard.c <= dst.c, "shard channels overflow the merged map");
    for r in 0..shard.t {
        dst.or_row_at(r, c_off, shard.row_words(r));
    }
}

/// Full inference through the packed engine (packs the model's layers
/// once per call; hot paths pack at load time instead — see
/// `fsim::DecodedProgram`). Bit-identical to [`infer`].
pub fn infer_packed(model: &KwsModel, audio: &[f32]) -> Vec<f32> {
    let mut x = preprocess(model, audio);
    for layer in &model.layers[..model.layers.len() - 1] {
        x = conv_layer_packed(&x, &PackedLayer::from_spec(layer));
    }
    final_layer_gap_packed(&x, &PackedLayer::from_spec(model.layers.last().unwrap()))
}

/// ADC quantization: float waveform -> integer samples (11 bit + sign),
/// mirror of `ref.quantize_audio`.
pub fn quantize_audio(audio: &[f32]) -> Vec<i32> {
    audio
        .iter()
        .map(|&x| (x.clamp(-1.0, 1.0) * 2048.0).round_ties_even() as i32)
        .collect()
}

/// Integer preprocessing: pre-emphasis + frame features + folded-BN
/// compare -> binary (t, c) feature map. Mirror of `ref.ref_preprocess`
/// with BN folded to integer thresholds (`kws::fold_bn`).
pub fn preprocess(model: &KwsModel, audio: &[f32]) -> BitMap {
    let q = quantize_audio(audio);
    let frame = model.audio_len / model.t;
    let mut bits = BitMap::zero(model.t, model.c);
    for t in 0..model.t {
        for ch in 0..model.c {
            let idx = t * frame + ch;
            let x = q[idx] as i64;
            let prev = if idx == 0 { 0 } else { q[idx - 1] as i64 };
            let y = 32 * x - 31 * prev;
            let f = y.abs();
            let on = match model.pre_dir[ch] {
                1 => f > model.pre_thr[ch],
                -1 => f < model.pre_thr[ch] + 1,
                _ => model.bn_beta[ch] > 0.0,
            };
            if on {
                bits.set(t, ch);
            }
        }
    }
    bits
}

/// Binary conv1d row sums at position `t` for all output channels:
/// integer MAC over the tap-major/channel-minor im2col window with
/// symmetric zero padding (pad = (k-1)/2), identical to
/// `ref.ref_conv1d_binary`.
pub fn conv_sums(x: &BitMap, w: &super::kws::LayerSpec, t: usize) -> Vec<i32> {
    let k = w.kernel;
    let pad = (k - 1) / 2;
    let mut sums = vec![0i32; w.c_out];
    for j in 0..k {
        let tt = t as isize + j as isize - pad as isize;
        if tt < 0 || tt >= x.t as isize {
            continue; // zero padding contributes nothing
        }
        let row = tt as usize;
        for ci in 0..w.c_in {
            if x.get(row, ci) {
                let r = j * w.c_in + ci;
                for (co, s) in sums.iter_mut().enumerate() {
                    *s += w.weight(r, co) as i32;
                }
            }
        }
    }
    sums
}

/// One binarized conv layer (+ optional 2:1 max pool fused).
pub fn conv_layer(x: &BitMap, layer: &super::kws::LayerSpec) -> BitMap {
    assert!(layer.binarized);
    let t_out = if layer.pooled { x.t / 2 } else { x.t };
    let mut out = BitMap::zero(t_out, layer.c_out);
    for t in 0..x.t {
        let sums = conv_sums(x, layer, t);
        let ot = if layer.pooled { t / 2 } else { t };
        if ot >= t_out {
            break; // odd tail dropped by pooling
        }
        for co in 0..layer.c_out {
            if sums[co] > layer.thresholds[co] {
                out.set(ot, co); // pooled max == OR of the pair
            }
        }
    }
    out
}

/// The raw final layer + global average pooling -> logits. The division
/// is f32 like jnp.mean; sums and t are small integers so it is exact.
pub fn final_layer_gap(x: &BitMap, layer: &super::kws::LayerSpec) -> Vec<f32> {
    assert!(!layer.binarized);
    let mut acc = vec![0i64; layer.c_out];
    for t in 0..x.t {
        for (co, s) in conv_sums(x, layer, t).iter().enumerate() {
            acc[co] += *s as i64;
        }
    }
    acc.iter().map(|&s| s as f32 / x.t as f32).collect()
}

/// Full inference: audio -> logits. Bit-exact vs the JAX golden model.
pub fn infer(model: &KwsModel, audio: &[f32]) -> Vec<f32> {
    let mut x = preprocess(model, audio);
    for layer in &model.layers[..model.layers.len() - 1] {
        x = conv_layer(&x, layer);
    }
    final_layer_gap(&x, model.layers.last().unwrap())
}

/// Argmax helper (accuracy eval).
pub fn argmax(v: &[f32]) -> usize {
    v.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kws::LayerSpec;

    fn tiny_layer(c_in: usize, c_out: usize, pooled: bool, binarized: bool) -> LayerSpec {
        // deterministic weights: +1 iff (row + co) even
        let k = 3;
        let rows = k * c_in;
        let weights = (0..rows * c_out)
            .map(|i| {
                let (r, co) = (i / c_out, i % c_out);
                if (r + co) % 2 == 0 { 1i8 } else { -1 }
            })
            .collect();
        LayerSpec {
            c_in,
            c_out,
            kernel: k,
            pooled,
            binarized,
            weights,
            thresholds: if binarized { vec![0; c_out] } else { vec![] },
        }
    }

    #[test]
    fn bitmap_roundtrip() {
        let mut b = BitMap::zero(4, 70);
        b.set(0, 0);
        b.set(3, 69);
        b.set(2, 32);
        assert!(b.get(0, 0) && b.get(3, 69) && b.get(2, 32));
        assert!(!b.get(1, 0) && !b.get(3, 68));
        assert_eq!(b.popcount(), 3);
    }

    #[test]
    fn conv_padding_zero_at_edges() {
        let layer = tiny_layer(4, 2, false, true);
        let mut x = BitMap::zero(3, 4);
        // only row 0 has bits -> position 2's window (rows 1,2,3) sums 0.
        x.set(0, 0);
        x.set(0, 3);
        let s0 = conv_sums(&x, &layer, 0);
        let s2 = conv_sums(&x, &layer, 2);
        assert_eq!(s2, vec![0, 0]);
        // Row 0 enters position 0's window at tap j=1 (center).
        // r = 1*4+0 = 4: w(4, 0) = +1; r = 1*4+3 = 7: w(7,0) = -1 -> 0.
        assert_eq!(s0[0], 0);
        // co=1: w(4,1) = -1, w(7,1) = +1 -> 0.
        assert_eq!(s0[1], 0);
    }

    #[test]
    fn conv_sums_match_naive() {
        // Naive O(t*k*ci*co) vs conv_sums on random-ish bits.
        let layer = tiny_layer(8, 4, false, true);
        let mut x = BitMap::zero(10, 8);
        for t in 0..10 {
            for c in 0..8 {
                if (t * 7 + c * 3) % 5 < 2 {
                    x.set(t, c);
                }
            }
        }
        for t in 0..10 {
            let got = conv_sums(&x, &layer, t);
            let mut want = vec![0i32; 4];
            for j in 0..3 {
                let tt = t as isize + j as isize - 1;
                if tt < 0 || tt >= 10 {
                    continue;
                }
                for ci in 0..8 {
                    if x.get(tt as usize, ci) {
                        for (co, wv) in want.iter_mut().enumerate() {
                            *wv += layer.weight(j * 8 + ci, co) as i32;
                        }
                    }
                }
            }
            assert_eq!(got, want, "position {t}");
        }
    }

    #[test]
    fn pooled_layer_is_or_of_pairs() {
        let layer = tiny_layer(4, 4, true, true);
        let mut x = BitMap::zero(6, 4);
        x.set(1, 1);
        x.set(4, 2);
        let pooled = conv_layer(&x, &layer);
        // Unpooled computed by a non-pooled twin must OR pairwise.
        let mut twin = layer.clone();
        twin.pooled = false;
        let unpooled = conv_layer(&x, &twin);
        assert_eq!(pooled.t, 3);
        for t in 0..3 {
            for co in 0..4 {
                assert_eq!(
                    pooled.get(t, co),
                    unpooled.get(2 * t, co) || unpooled.get(2 * t + 1, co)
                );
            }
        }
    }

    #[test]
    fn bitmap_row_words_and_padding() {
        let mut b = BitMap::zero(3, 40);
        b.set(1, 0);
        b.set(1, 39);
        assert_eq!(b.wpr(), 2);
        assert_eq!(b.row_words(0), &[0, 0]);
        assert_eq!(b.row_words(1), &[1, 1 << 7]);
        // Padding bits above c stay zero (packed-kernel invariant).
        assert_eq!(b.row_words(1)[1] >> 8, 0);
    }

    #[test]
    fn packed_roundtrips_through_spec() {
        let layer = tiny_layer(5, 3, true, true);
        let packed = PackedLayer::from_spec(&layer);
        assert_eq!(packed.plane_words, (3 * 5usize).div_ceil(64));
        let back = packed.to_spec();
        assert_eq!(back.weights, layer.weights);
        assert_eq!(back.thresholds, layer.thresholds);
        assert_eq!((back.c_in, back.c_out, back.kernel), (5, 3, 3));
        assert!(back.pooled && back.binarized);
    }

    #[test]
    fn packed_sums_match_scalar_including_edges() {
        // 70 channels: rows = 210 bits -> 7 window words, non-aligned rows.
        let layer = tiny_layer(70, 5, false, true);
        let packed = PackedLayer::from_spec(&layer);
        let mut x = BitMap::zero(9, 70);
        for t in 0..9 {
            for c in 0..70 {
                if (t * 11 + c * 5) % 7 < 3 {
                    x.set(t, c);
                }
            }
        }
        for t in 0..9 {
            assert_eq!(conv_sums_packed(&x, &packed, t), conv_sums(&x, &layer, t), "t {t}");
        }
    }

    #[test]
    fn packed_layer_and_gap_match_scalar() {
        let conv = tiny_layer(40, 33, true, true);
        let last = tiny_layer(33, 12, false, false);
        let mut x = BitMap::zero(11, 40); // odd t: pooling drops the tail
        for t in 0..11 {
            for c in 0..40 {
                if (t * 13 + c * 3) % 5 < 2 {
                    x.set(t, c);
                }
            }
        }
        let mid_scalar = conv_layer(&x, &conv);
        let mid_packed = conv_layer_packed(&x, &PackedLayer::from_spec(&conv));
        assert_eq!(mid_packed, mid_scalar);
        assert_eq!(
            final_layer_gap_packed(&mid_packed, &PackedLayer::from_spec(&last)),
            final_layer_gap(&mid_scalar, &last)
        );
    }

    #[test]
    fn stream_words_recover_u32_layout() {
        // 70-channel layer: rows = 210 -> 7 stream words, 4 u64 planes;
        // the u32 view must be exactly the legacy column-major packing.
        let layer = tiny_layer(70, 3, false, true);
        let packed = PackedLayer::from_spec(&layer);
        assert_eq!(packed.stream_words(), layer.rows().div_ceil(32));
        for co in 0..layer.c_out {
            for wj in 0..packed.stream_words() {
                let mut want = 0u32;
                for b in 0..32 {
                    let r = wj * 32 + b;
                    if r < layer.rows() && layer.weight(r, co) > 0 {
                        want |= 1 << b;
                    }
                }
                assert_eq!(packed.stream_word(co, wj), want, "co {co} wj {wj}");
            }
        }
    }

    #[test]
    fn batched_kernels_match_single_utterance_twins() {
        let conv = tiny_layer(70, 23, true, true); // word-unaligned widths
        let last = tiny_layer(23, 12, false, false);
        let packed_conv = PackedLayer::from_spec(&conv);
        let packed_last = PackedLayer::from_spec(&last);
        // A ragged little batch of distinct maps (odd t drops a pool tail).
        let xs: Vec<BitMap> = (0..5)
            .map(|u| {
                let mut x = BitMap::zero(9, 70);
                for t in 0..9 {
                    for c in 0..70 {
                        if (t * 11 + c * 5 + u * 3) % 7 < 3 {
                            x.set(t, c);
                        }
                    }
                }
                x
            })
            .collect();
        for t in 0..9 {
            let batch = conv_sums_packed_batch(&xs, &packed_conv, t);
            for (u, x) in xs.iter().enumerate() {
                assert_eq!(batch[u], conv_sums_packed(x, &packed_conv, t), "u {u} t {t}");
            }
        }
        let mids = conv_layer_packed_batch(&xs, &packed_conv);
        for (u, x) in xs.iter().enumerate() {
            assert_eq!(mids[u], conv_layer_packed(x, &packed_conv), "u {u}");
        }
        let logits = final_layer_gap_packed_batch(&mids, &packed_last);
        for (u, mid) in mids.iter().enumerate() {
            assert_eq!(logits[u], final_layer_gap_packed(mid, &packed_last), "u {u}");
        }
        // Empty batches are empty, not a panic.
        assert!(conv_layer_packed_batch(&[], &packed_conv).is_empty());
        assert!(final_layer_gap_packed_batch(&[], &packed_last).is_empty());
    }

    #[test]
    fn infer_packed_matches_infer() {
        let model = crate::model::KwsModel::synthetic(17);
        for seed in 0..3u64 {
            let audio =
                crate::model::dataset::synth_utterance(seed as usize % 12, seed, model.audio_len, 0.3);
            assert_eq!(infer_packed(&model, &audio), infer(&model, &audio), "seed {seed}");
        }
    }

    #[test]
    fn slice_channels_preserves_sums_and_thresholds() {
        let layer = tiny_layer(70, 23, true, true); // non-word-aligned both ways
        let packed = PackedLayer::from_spec(&layer);
        let mut x = BitMap::zero(7, 70);
        for t in 0..7 {
            for c in 0..70 {
                if (t * 5 + c * 3) % 4 < 2 {
                    x.set(t, c);
                }
            }
        }
        for (c0, c1) in [(0, 23), (0, 7), (7, 23), (10, 11), (23, 23)] {
            let shard = packed.slice_channels(c0, c1);
            assert_eq!(shard.c_out, c1 - c0);
            assert_eq!(shard.thresholds, layer.thresholds[c0..c1].to_vec());
            for t in 0..7 {
                let full = conv_sums_packed(&x, &packed, t);
                let part = conv_sums_packed(&x, &shard, t);
                assert_eq!(part.as_slice(), &full[c0..c1], "t {t} range {c0}..{c1}");
            }
        }
    }

    #[test]
    fn merge_shard_reassembles_full_map_unaligned() {
        // Split a map into 3 uneven channel ranges, merge, compare.
        let mut full = BitMap::zero(5, 70);
        for t in 0..5 {
            for c in 0..70 {
                if (t * 13 + c * 7) % 3 == 0 {
                    full.set(t, c);
                }
            }
        }
        let ranges = [(0usize, 18usize), (18, 53), (53, 70)];
        let mut merged = BitMap::zero(5, 70);
        for &(a, b) in &ranges {
            let mut part = BitMap::zero(5, b - a);
            for t in 0..5 {
                for c in a..b {
                    if full.get(t, c) {
                        part.set(t, c - a);
                    }
                }
            }
            merge_shard(&mut merged, a, &part);
        }
        assert_eq!(merged, full);
    }

    #[test]
    fn quantize_is_clamped_and_integral() {
        let q = quantize_audio(&[-2.0, -1.0, 0.0, 0.4999, 1.0, 2.0]);
        assert_eq!(q[0], -2048);
        assert_eq!(q[1], -2048);
        assert_eq!(q[2], 0);
        assert_eq!(q[4], 2048);
        assert_eq!(q[5], 2048);
    }
}
