//! The keyword-spotting model (paper Table II) on the Rust side:
//! manifest + weight loading, the bit-exact host reference implementation,
//! and the synthetic-GSCD test vectors exported by `make artifacts`.

pub mod dataset;
pub mod kernel;
pub mod kws;
pub mod reference;

pub use kws::{KwsModel, LayerSpec};
