//! Binary artifact I/O: the f32/i32 little-endian payloads written by
//! `python/compile/aot.py` (weights, test vectors, eval sets).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Read a little-endian f32 payload.
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a little-endian i32 payload.
pub fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 payload.
pub fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

/// Locate the artifacts directory: `$CIMRV_ARTIFACTS`, else `./artifacts`,
/// else `../artifacts` (so tests/examples work from any workspace cwd).
pub fn artifacts_dir() -> Result<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("CIMRV_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.is_dir() {
            return Ok(p);
        }
        bail!("CIMRV_ARTIFACTS={} is not a directory", p.display());
    }
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join("kws_manifest.json").is_file() {
            return Ok(p);
        }
    }
    bail!("artifacts/ not found — run `make artifacts` first")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let dir = std::env::temp_dir().join("cimrv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let data = vec![0.0f32, -1.5, 3.25, f32::MAX];
        write_f32(&p, &data).unwrap();
        assert_eq!(read_f32(&p).unwrap(), data);
    }

    #[test]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join("cimrv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ragged.bin");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(read_f32(&p).is_err());
        assert!(read_i32(&p).is_err());
    }
}
