//! Binary artifact I/O: the f32/i32 little-endian payloads written by
//! `python/compile/aot.py` (weights, test vectors, eval sets).

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Read a little-endian f32 payload.
pub fn read_f32(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a little-endian i32 payload.
pub fn read_i32(path: &Path) -> Result<Vec<i32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a little-endian u32 payload (packed sign-bit weight planes of the
/// checked-in testdata artifact format).
pub fn read_u32(path: &Path) -> Result<Vec<u32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read audio stored as little-endian i16 quantized samples (`k` such
/// that the waveform value is `k / 2048`). The expansion is exact in f32
/// (|k| <= 2048, power-of-two divisor), so artifacts shipped in this
/// compact form reproduce the f32 pipeline bit for bit.
pub fn read_i16_audio(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 2 != 0 {
        bail!("{}: length {} not a multiple of 2", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(2)
        .map(|c| i16::from_le_bytes([c[0], c[1]]) as f32 / 2048.0)
        .collect())
}

/// Read audio from `<base>.bin` (f32, the `make artifacts` export) or
/// fall back to `<base>_i16.bin` (the compact checked-in testdata form).
pub fn read_audio_any(dir: &Path, base: &str) -> Result<Vec<f32>> {
    let f32_path = dir.join(format!("{base}.bin"));
    if f32_path.is_file() {
        return read_f32(&f32_path);
    }
    read_i16_audio(&dir.join(format!("{base}_i16.bin")))
}

/// Write a little-endian f32 payload.
pub fn write_f32(path: &Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

/// Locate the artifacts directory: `$CIMRV_ARTIFACTS`, else `./artifacts`
/// / `../artifacts` (a `make artifacts` export), else the checked-in tiny
/// pre-trained set under `rust/testdata/artifacts` — so tests, benches
/// and the CLI work on a fresh checkout from any workspace cwd.
pub fn artifacts_dir() -> Result<std::path::PathBuf> {
    if let Ok(dir) = std::env::var("CIMRV_ARTIFACTS") {
        let p = std::path::PathBuf::from(dir);
        if p.is_dir() {
            return Ok(p);
        }
        bail!("CIMRV_ARTIFACTS={} is not a directory", p.display());
    }
    for cand in [
        "artifacts",
        "../artifacts",
        "../../artifacts",
        // Checked-in testdata set (cwd = rust/ under cargo, or repo root).
        "testdata/artifacts",
        "rust/testdata/artifacts",
        "../rust/testdata/artifacts",
    ] {
        let p = std::path::PathBuf::from(cand);
        if p.join("kws_manifest.json").is_file() {
            return Ok(p);
        }
    }
    bail!("artifacts/ not found — run `make artifacts` first")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let dir = std::env::temp_dir().join("cimrv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.bin");
        let data = vec![0.0f32, -1.5, 3.25, f32::MAX];
        write_f32(&p, &data).unwrap();
        assert_eq!(read_f32(&p).unwrap(), data);
    }

    #[test]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join("cimrv_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("ragged.bin");
        std::fs::write(&p, [0u8; 7]).unwrap();
        assert!(read_f32(&p).is_err());
        assert!(read_i32(&p).is_err());
        assert!(read_u32(&p).is_err());
        assert!(read_i16_audio(&p).is_err());
    }

    #[test]
    fn i16_audio_expands_exactly_and_any_prefers_f32() {
        let dir = std::env::temp_dir().join("cimrv_io_test_audio");
        std::fs::create_dir_all(&dir).unwrap();
        // i16 form: k / 2048 exactly.
        let ks: [i16; 5] = [-2048, -1, 0, 1, 2048];
        let mut bytes = Vec::new();
        for k in ks {
            bytes.extend_from_slice(&k.to_le_bytes());
        }
        std::fs::write(dir.join("clip_i16.bin"), &bytes).unwrap();
        let a = read_audio_any(&dir, "clip").unwrap();
        assert_eq!(a, vec![-1.0, -1.0 / 2048.0, 0.0, 1.0 / 2048.0, 1.0]);
        // Quantizing the expansion recovers k bit-for-bit.
        let q = crate::model::reference::quantize_audio(&a);
        assert_eq!(q, ks.iter().map(|&k| k as i32).collect::<Vec<_>>());
        // An f32 file with the same base wins over the i16 fallback.
        write_f32(&dir.join("clip.bin"), &[0.5]).unwrap();
        assert_eq!(read_audio_any(&dir, "clip").unwrap(), vec![0.5]);
    }
}
