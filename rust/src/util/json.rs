//! Minimal JSON parser/emitter (the image has no serde_json).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs outside the
//! BMP; numbers parse as f64 (integers round-trip exactly up to 2^53,
//! far beyond anything in our manifests). Only used for build artifacts
//! (`kws_manifest.json`) and report emission — never on a hot path.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(anyhow!("expected object, got {self:?}")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(anyhow!("expected array")),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(anyhow!("expected string")),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(anyhow!("expected number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(anyhow!("expected bool")),
        }
    }

    /// `obj["key"]` with a useful error.
    pub fn get(&self, key: &str) -> Result<&Json> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Deep lookup: `json.path(&["a", "b", "c"])`.
    pub fn path(&self, keys: &[&str]) -> Result<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Ok(cur)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u{hex} escape"))?,
                            );
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                _ => {
                    // Re-sync to char boundary for multibyte UTF-8.
                    let start = self.i - 1;
                    while self.i < self.b.len() && self.b[self.i] & 0xC0 == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|_| {
            anyhow!("bad number {s:?} at byte {start}")
        })?))
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }
}

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => escape(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Convenience constructors for report emission.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e1 ").unwrap(), Json::Num(-125.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
        assert!(!j.get("c").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let j = Json::parse(r#""café — ☕""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café — ☕");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,null,true,"s"],"n":-3,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn integer_fidelity() {
        let j = Json::parse("1024").unwrap();
        assert_eq!(j.as_usize().unwrap(), 1024);
        assert!(Json::parse("1.5").unwrap().as_usize().is_err());
    }
}
