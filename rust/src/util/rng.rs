//! Deterministic PRNG (xoshiro256**) — the image has no `rand` crate.
//!
//! Used for workload generation, cell-variation injection and the in-tree
//! property-test harness. Seeded explicitly everywhere so every experiment
//! is reproducible from its config.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (the recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; unbiased enough for
    /// simulation workloads).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random ±1 weight.
    #[inline]
    pub fn pm1(&mut self) -> i8 {
        if self.next_u64() & 1 == 0 { -1 } else { 1 }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn golden_sequence_pinned() {
        // Pinned against an independent xoshiro256** + SplitMix64
        // implementation. Cell-variation replay parity depends on the
        // exact draw sequence, so any change to seeding or state update
        // must fail loudly here, not as a silent parity break.
        let mut r = Rng::new(42);
        let want: [u64; 6] = [
            0x15780b2e0c2ec716,
            0x6104d9866d113a7e,
            0xae17533239e499a1,
            0xecb8ad4703b360a1,
            0xfde6dc7fe2ec5e64,
            0xc50da53101795238,
        ];
        for (i, w) in want.iter().enumerate() {
            assert_eq!(r.next_u64(), *w, "draw {i}");
        }
        // f64() is a pure integer transform of next_u64: exact values.
        let mut r = Rng::new(7);
        let want_f = [
            0.7005764821796896,
            0.2787512294737843,
            0.8396274618764198,
            0.9810977250149351,
        ];
        for (i, w) in want_f.iter().enumerate() {
            assert_eq!(r.f64(), *w, "f64 draw {i}");
        }
    }

    #[test]
    fn normal_consumes_exactly_two_uniform_draws() {
        // Box–Muller takes (u1, u2) = two next_u64 draws per sample —
        // the sequencing contract the variation replay's burn() relies
        // on. A fresh generator skipped 2k draws must continue in
        // lockstep with one that produced k normals.
        for k in [1usize, 3, 10] {
            let mut a = Rng::new(1234);
            for _ in 0..k {
                let _ = a.normal();
            }
            let mut b = Rng::new(1234);
            for _ in 0..2 * k {
                let _ = b.next_u64();
            }
            for i in 0..5 {
                assert_eq!(a.next_u64(), b.next_u64(), "k {k} draw {i}");
            }
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let (mut s, mut s2) = (0.0, 0.0);
        let n = 20_000;
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
