//! Small in-tree replacements for crates missing from the offline image
//! (serde_json, clap, rand, proptest) plus binary-artifact I/O helpers.

pub mod cli;
pub mod io;
pub mod json;
pub mod proptest;
pub mod rng;
