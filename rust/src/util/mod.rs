//! Small in-tree replacements for crates missing from the offline image
//! (serde_json, clap, rand, proptest) plus binary-artifact I/O helpers.

use std::sync::{Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

pub mod cli;
pub mod io;
pub mod json;
pub mod proptest;
pub mod rng;

/// Lock a mutex, recovering from poisoning instead of propagating the
/// panic. The serving path guards plain data (stat vectors, queues)
/// behind its mutexes — no invariant spans a critical section — so a
/// worker that panicked while holding one leaves the data intact and
/// the right response is to keep serving, not to wedge `serve_batch`,
/// `shutdown`, and every stats reporter behind a `PoisonError`.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`lock_or_recover`]'s read twin for `RwLock` (the sharded-parallel
/// inference path shares its evolving feature map through one): a shard
/// thread that panicked mid-layer poisons the lock, but the map itself is
/// only ever replaced wholesale by the merge leader, so readers can
/// always recover — the *error* surfacing belongs to the dead-shard
/// accounting, not to every subsequent lock site.
pub fn read_or_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// [`read_or_recover`]'s write twin.
pub fn write_or_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|poisoned| poisoned.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_or_recover_survives_poisoning() {
        let m = Mutex::new(vec![1u64]);
        // Poison it: panic while holding the guard.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = m.lock().unwrap();
            panic!("poison");
        }));
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // Recovery: the data is still there and still writable.
        lock_or_recover(&m).push(2);
        assert_eq!(*lock_or_recover(&m), vec![1, 2]);
    }

    #[test]
    fn rwlock_recovery_survives_poisoning_both_ways() {
        let l = RwLock::new(7u64);
        // Poison: panic while holding the write guard.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = l.write().unwrap();
            panic!("poison");
        }));
        assert!(l.read().is_err(), "rwlock must actually be poisoned");
        assert_eq!(*read_or_recover(&l), 7);
        *write_or_recover(&l) = 8;
        assert_eq!(*read_or_recover(&l), 8);
    }
}
