//! Tiny CLI argument parser (the image has no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args —
//! enough for the `cimrv` launcher and the examples.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line: subcommand, options, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit arg list (no program name).
    /// `flag_names` lists options that take no value.
    pub fn parse_from(args: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{rest} expects a value"))?;
                    out.options.insert(rest.to_string(), v.clone());
                }
            } else if a.starts_with('-') && a.len() > 1 {
                bail!("short options are not supported: {a}");
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    /// Parse the process command line.
    pub fn parse(flag_names: &[&str]) -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse_from(&argv, flag_names)
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got {v:?}")),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = Args::parse_from(&s(&["run", "--steps", "10", "--mode=fused", "prog.bin"]), &[])
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.opt("steps"), Some("10"));
        assert_eq!(a.opt("mode"), Some("fused"));
        assert_eq!(a.positional, vec!["prog.bin"]);
    }

    #[test]
    fn flags() {
        let a = Args::parse_from(&s(&["bench", "--verbose", "--n", "3"]), &["verbose"]).unwrap();
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse_from(&s(&["run", "--steps"]), &[]).is_err());
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse_from(&s(&["x", "--f", "2.5"]), &[]).unwrap();
        assert_eq!(a.opt_f64("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.opt_f64("g", 1.5).unwrap(), 1.5);
        assert!(a.opt_usize("f", 0).is_err());
    }

    #[test]
    fn u64_accessor() {
        let a = Args::parse_from(&s(&["x", "--linger-us", "2500"]), &[]).unwrap();
        assert_eq!(a.opt_u64("linger-us", 0).unwrap(), 2500);
        assert_eq!(a.opt_u64("absent", 7).unwrap(), 7);
        let b = Args::parse_from(&s(&["x", "--linger-us", "nope"]), &[]).unwrap();
        assert!(b.opt_u64("linger-us", 0).is_err());
    }
}
