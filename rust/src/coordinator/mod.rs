//! L3 coordinator: the edge-inference request loop.
//!
//! CIMR-V is an edge accelerator, so the coordinator is a leader/worker
//! request pipeline rather than a datacenter router: a leader thread
//! batches incoming utterances, worker threads each own a SoC instance
//! (the cycle-accurate chip) and optionally the PJRT golden model, and
//! every response carries latency/energy accounting and a cross-check
//! verdict. (The offline image has no tokio; std threads + channels play
//! its role — see DESIGN.md §2.)
//!
//! Since the fault-tolerance rework the serving path is supervised:
//! admission is bounded (typed [`crate::resilience::SubmitError`]
//! sheds), requests carry optional deadlines, workers run under
//! `catch_unwind` with retry/backoff, and a supervisor respawns dead or
//! breaker-tripped workers (the latter degraded onto a reduced shard
//! plan). See `crate::resilience` for the building blocks.

pub mod report;
pub mod server;

pub use server::{
    Coordinator, InferenceRequest, InferenceResponse, LingerEstimator, ServeOptions, ServiceStats,
    BREAKER_THRESHOLD, DEFAULT_MAX_ATTEMPTS, DEFAULT_QUEUE_CAP,
};

// The serving-path error surface lives in `resilience`; re-exported here
// because `submit`/`serve_batch` signatures carry these types.
pub use crate::resilience::{ServeError, SubmitError};
