//! L3 coordinator: the edge-inference request loop.
//!
//! CIMR-V is an edge accelerator, so the coordinator is a leader/worker
//! request pipeline rather than a datacenter router: a leader thread
//! batches incoming utterances, worker threads each own a SoC instance
//! (the cycle-accurate chip) and optionally the PJRT golden model, and
//! every response carries latency/energy accounting and a cross-check
//! verdict. (The offline image has no tokio; std threads + channels play
//! its role — see DESIGN.md §2.)

pub mod report;
pub mod server;

pub use server::{
    Coordinator, InferenceRequest, InferenceResponse, LingerEstimator, ServeOptions, ServiceStats,
};
