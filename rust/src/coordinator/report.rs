//! Experiment reporting: JSON + human-readable summaries shared by the
//! CLI subcommands and the benches.

use std::sync::atomic::Ordering;

use crate::baselines::OptLevel;
use crate::coordinator::ServiceStats;
use crate::sim::RunResult;
use crate::util::json::Json;

/// Nearest-rank percentiles over `samples` (µs, unsorted; a copy is
/// sorted internally). Returns one value per requested `p`, or `None`
/// for an empty sample set — callers render "n/a" instead of panicking.
/// Out-of-range or non-finite `p` clamps into `[0, 1]` (NaN maps to 0),
/// and a single-sample set answers every percentile with that sample.
pub fn percentiles_us(samples: &[u64], ps: &[f64]) -> Option<Vec<u64>> {
    if samples.is_empty() {
        return None;
    }
    let mut v = samples.to_vec();
    v.sort_unstable();
    Some(
        ps.iter()
            .map(|&p| {
                let p = if p.is_nan() { 0.0 } else { p.clamp(0.0, 1.0) };
                let rank = ((p * v.len() as f64).ceil() as usize).clamp(1, v.len());
                v[rank - 1]
            })
            .collect(),
    )
}

/// One ladder rung's measurement.
#[derive(Debug, Clone)]
pub struct LadderPoint {
    pub name: &'static str,
    pub opt: OptLevel,
    pub total_cycles: u64,
    pub accelerated_cycles: u64,
    pub preprocess_cycles: u64,
}

impl LadderPoint {
    pub fn from_run(name: &'static str, opt: OptLevel, r: &RunResult) -> Self {
        LadderPoint {
            name,
            opt,
            total_cycles: r.cycles,
            accelerated_cycles: r.phases.accelerated(),
            preprocess_cycles: r.phases.preprocess,
        }
    }
}

/// Render the Fig. 6/7/9 + §III-A waterfall: per-step and cumulative
/// reductions over the accelerated (weights+conv) phases and end-to-end.
pub fn render_ladder(points: &[LadderPoint]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<28}{:>14}{:>14}{:>12}{:>12}{:>12}{:>12}\n",
        "config", "accel cycles", "e2e cycles", "step red.", "cum red.", "e2e step", "e2e cum."
    ));
    let base = &points[0];
    let mut prev = base;
    for p in points {
        let step = 1.0 - p.accelerated_cycles as f64 / prev.accelerated_cycles as f64;
        let cum = 1.0 - p.accelerated_cycles as f64 / base.accelerated_cycles as f64;
        let estep = 1.0 - p.total_cycles as f64 / prev.total_cycles as f64;
        let ecum = 1.0 - p.total_cycles as f64 / base.total_cycles as f64;
        s.push_str(&format!(
            "{:<28}{:>14}{:>14}{:>11.2}%{:>11.2}%{:>11.2}%{:>11.2}%\n",
            p.name,
            p.accelerated_cycles,
            p.total_cycles,
            100.0 * step,
            100.0 * cum,
            100.0 * estep,
            100.0 * ecum,
        ));
        prev = p;
    }
    s
}

/// Render per-shard macro utilization accumulated by a serving run
/// (`--macros N`): each macro's fire count and its share of the bank's
/// total work. Idle shards (empty channel ranges) show 0.0%.
pub fn render_shard_utilization(stats: &ServiceStats) -> String {
    let fires: Vec<u64> = stats.shard_fires.iter().map(|a| a.load(Ordering::Relaxed)).collect();
    let total: u64 = fires.iter().sum();
    let mut s = String::from("per-shard macro utilization:\n");
    for (m, f) in fires.iter().enumerate() {
        let pct = if total > 0 { 100.0 * *f as f64 / total as f64 } else { 0.0 };
        s.push_str(&format!("  macro {m}: {f:>10} fires ({pct:5.1}% of bank work)\n"));
    }
    s
}

/// Render the host-latency percentiles a serving run accumulated
/// (p50/p95/p99 from submit to response, queue wait + linger included).
/// Empty stats render a placeholder instead of panicking.
pub fn render_latency_percentiles(stats: &ServiceStats) -> String {
    match stats.host_latency_percentiles() {
        Some([p50, p95, p99]) => format!(
            "host latency: p50 {:.2} ms | p95 {:.2} ms | p99 {:.2} ms\n",
            1e3 * p50,
            1e3 * p95,
            1e3 * p99
        ),
        None => "host latency: no requests served yet\n".to_string(),
    }
}

/// Render the request-lifecycle breakdown aggregated from the recorded
/// telemetry spans: mean queue+linger / execute / end-to-end time per
/// request. Renders "n/a" when no spans were recorded (telemetry off or
/// nothing served yet).
pub fn render_span_breakdown(stats: &ServiceStats) -> String {
    let spans = stats.spans.snapshot();
    if spans.is_empty() {
        return "request spans: n/a (telemetry off or nothing served)\n".to_string();
    }
    let n = spans.len() as f64;
    let mean_ms = |us: u64| us as f64 / n / 1e3;
    let queue: u64 = spans.iter().map(|s| s.queue_us()).sum();
    let exec: u64 = spans.iter().map(|s| s.execute_us()).sum();
    let total: u64 = spans.iter().map(|s| s.total_us()).sum();
    format!(
        "request spans: {} recorded | mean queue+linger {:.2} ms | mean execute {:.2} ms | \
         mean total {:.2} ms\n",
        spans.len(),
        mean_ms(queue),
        mean_ms(exec),
        mean_ms(total),
    )
}

/// Render the micro-batch size histogram: how many worker batches formed
/// at each size up to the `--batch` cap, plus the request-weighted mean
/// (how big the average request's batch was).
pub fn render_batch_histogram(stats: &ServiceStats) -> String {
    let counts: Vec<u64> = stats.batch_sizes.iter().map(|a| a.load(Ordering::Relaxed)).collect();
    let batches: u64 = counts.iter().sum();
    let requests: u64 = counts.iter().enumerate().map(|(b, n)| (b as u64 + 1) * n).sum();
    let mut s = format!("micro-batches: {batches} formed over {requests} requests\n");
    for (b, n) in counts.iter().enumerate() {
        if *n > 0 {
            let pct = 100.0 * (*n as f64) / batches.max(1) as f64;
            s.push_str(&format!("  size {:>3}: {n:>8} batches ({pct:5.1}%)\n", b + 1));
        }
    }
    if requests > 0 {
        // sum(size^2 * count) / requests = the batch size the average
        // request experienced.
        let weighted: u64 =
            counts.iter().enumerate().map(|(b, n)| (b as u64 + 1).pow(2) * n).sum();
        s.push_str(&format!(
            "  request-weighted mean batch size: {:.2}\n",
            weighted as f64 / requests as f64
        ));
    }
    s
}

/// Render a Monte-Carlo robustness sweep (`cimrv sweep`): one row per
/// (sigma, nl, mapping) cell with seed-averaged accuracy, flip rate and
/// logit drift, plus the throughput/provenance footer. The JSON twin is
/// [`crate::robustness::SweepReport::to_json`] (`BENCH_robustness.json`).
pub fn render_sweep(report: &crate::robustness::SweepReport) -> String {
    let mut s = format!(
        "=== robustness sweep: {} utterances, clean accuracy {:.1}% ===\n",
        report.n_utterances,
        100.0 * report.clean_accuracy
    );
    s.push_str(&format!(
        "{:<8}{:<8}{:<14}{:>10}{:>18}{:>11}{:>14}{:>14}\n",
        "sigma", "nl", "mapping", "acc %", "95% CI", "flips %", "mean |dL|", "max |dL|"
    ));
    for c in report.cell_summaries() {
        // cell_summaries() carries the seed-averaged accuracy and its
        // bootstrap CI (the same numbers the mapping-claim gate and the
        // JSON use); the re-filter below only averages the remaining
        // per-point stats.
        let pts: Vec<_> = report
            .points
            .iter()
            .filter(|p| {
                p.params.sigma == c.sigma
                    && p.params.nl_alpha == c.nl_alpha
                    && p.params.symmetric == c.symmetric
            })
            .collect();
        let n = pts.len().max(1) as f64;
        let flips = pts.iter().map(|p| p.flip_rate).sum::<f64>() / n;
        let mean_d = pts.iter().map(|p| p.mean_abs_logit_delta).sum::<f64>() / n;
        let max_d = pts.iter().map(|p| p.max_abs_logit_delta).fold(0.0, f64::max);
        s.push_str(&format!(
            "{:<8}{:<8}{:<14}{:>10.1}{:>18}{:>11.1}{:>14.3}{:>14.3}\n",
            c.sigma,
            c.nl_alpha,
            if c.symmetric { "symmetric" } else { "single-ended" },
            100.0 * c.mean_accuracy,
            format!("[{:.1}, {:.1}]", 100.0 * c.ci95_lo, 100.0 * c.ci95_hi),
            100.0 * flips,
            mean_d,
            max_d
        ));
    }
    if let Some((sigma, sym, single)) = report.mapping_gap_at_max_sigma() {
        s.push_str(&format!(
            "mapping gap at sigma {sigma}: symmetric {:.1}% vs single-ended {:.1}%\n",
            100.0 * sym,
            100.0 * single
        ));
    }
    s.push_str(&format!(
        "{} disturbed inferences in {:.2}s ({:.0} inf/s host; chip {:.3} ms/inference \
         @50 MHz; mismatch {}, {} threads)\n",
        report.inferences,
        report.elapsed_s,
        report.inf_per_s,
        1e3 * crate::clock::cycles_to_seconds(report.chip_cycles_per_inference),
        report.mismatch,
        report.threads
    ));
    s
}

/// Render a chaos soak (`cimrv soak`): one row per cell with
/// availability, shed/retry/respawn counts and p99-under-fault. The JSON
/// twin is [`crate::resilience::SoakReport::to_json`]
/// (`BENCH_resilience.json`).
pub fn render_resilience(report: &crate::resilience::SoakReport) -> String {
    report.render()
}

/// Ladder as JSON (machine-readable experiment record).
pub fn ladder_json(points: &[LadderPoint]) -> Json {
    Json::Arr(
        points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("name", Json::str(p.name)),
                    ("opt", Json::str(p.opt.to_string())),
                    ("accelerated_cycles", Json::num(p.accelerated_cycles as f64)),
                    ("total_cycles", Json::num(p.total_cycles as f64)),
                    ("preprocess_cycles", Json::num(p.preprocess_cycles as f64)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(name: &'static str, accel: u64, total: u64) -> LadderPoint {
        LadderPoint {
            name,
            opt: OptLevel::FULL,
            total_cycles: total,
            accelerated_cycles: accel,
            preprocess_cycles: total - accel,
        }
    }

    #[test]
    fn percentiles_survive_empty_and_single_sample_inputs() {
        // Empty: None, not a panic or a nonsense zero.
        assert!(percentiles_us(&[], &[0.5, 0.99]).is_none());
        // Single sample answers every percentile with that sample.
        assert_eq!(percentiles_us(&[42], &[0.0, 0.5, 0.99, 1.0]).unwrap(), vec![42; 4]);
        // Nearest-rank over a known set.
        let v = [1000u64, 2000, 3000, 40_000];
        assert_eq!(percentiles_us(&v, &[0.50, 0.95, 0.99]).unwrap(), vec![2000, 40_000, 40_000]);
        // Unsorted input sorts internally.
        let u = [40_000u64, 1000, 3000, 2000];
        assert_eq!(percentiles_us(&u, &[0.50]).unwrap(), vec![2000]);
        // Out-of-range and non-finite p clamp instead of indexing wild.
        assert_eq!(
            percentiles_us(&v, &[-1.0, 2.0, f64::NAN, f64::INFINITY]).unwrap(),
            vec![1000, 40_000, 1000, 40_000]
        );
    }

    #[test]
    fn span_breakdown_renders_na_without_spans() {
        let stats = ServiceStats::default();
        assert!(render_span_breakdown(&stats).contains("n/a"));
    }

    #[test]
    fn shard_utilization_renders_shares() {
        let stats = ServiceStats::for_shards(2);
        stats.shard_fires[0].fetch_add(300, Ordering::Relaxed);
        stats.shard_fires[1].fetch_add(100, Ordering::Relaxed);
        let s = render_shard_utilization(&stats);
        assert!(s.contains("macro 0"));
        assert!(s.contains("75.0%"));
        assert!(s.contains("25.0%"));
        // Zero-work stats render without dividing by zero.
        let empty = ServiceStats::for_shards(1);
        assert!(render_shard_utilization(&empty).contains("0.0%"));
    }

    #[test]
    fn latency_percentiles_and_batch_histogram_render() {
        let stats = ServiceStats::sized(1, 4);
        assert!(render_latency_percentiles(&stats).contains("no requests"));
        for us in [1000u64, 2000, 3000, 40_000] {
            stats.record_host_latency(us as f64 / 1e6);
        }
        let s = render_latency_percentiles(&stats);
        assert!(s.contains("p50 2.00 ms"), "{s}");
        assert!(s.contains("p99 40.00 ms"), "{s}");
        stats.record_batch(1);
        stats.record_batch(4);
        stats.record_batch(4);
        let h = render_batch_histogram(&stats);
        assert!(h.contains("3 formed over 9 requests"), "{h}");
        assert!(h.contains("size   1:"), "{h}");
        assert!(h.contains("size   4:"), "{h}");
        // (1*1 + 16*2) / 9
        assert!(h.contains("mean batch size: 3.67"), "{h}");
        // Empty histogram renders without dividing by zero.
        assert!(render_batch_histogram(&ServiceStats::default()).contains("0 formed"));
    }

    #[test]
    fn sweep_report_renders_cells_and_gap() {
        use crate::robustness::{SweepPoint, SweepReport, VariationParams};
        let mk = |sigma: f64, symmetric: bool, seed: u64, acc: f64| SweepPoint {
            params: VariationParams { sigma, nl_alpha: 0.3, symmetric, mismatch: 0.05, seed },
            accuracy: acc,
            flip_rate: 1.0 - acc,
            mean_abs_logit_delta: 0.1,
            max_abs_logit_delta: 0.5,
        };
        let report = SweepReport {
            points: vec![
                mk(0.0, true, 1, 1.0),
                mk(0.6, true, 1, 0.9),
                mk(0.6, true, 2, 1.0),
                mk(0.6, false, 1, 0.1),
                mk(0.6, false, 2, 0.2),
            ],
            clean_accuracy: 1.0,
            n_utterances: 8,
            inferences: 40,
            elapsed_s: 0.5,
            inf_per_s: 80.0,
            chip_cycles_per_inference: 100_000,
            mismatch: 0.05,
            threads: 2,
        };
        let s = render_sweep(&report);
        assert!(s.contains("symmetric"), "{s}");
        assert!(s.contains("single-ended"), "{s}");
        assert!(s.contains("mapping gap at sigma 0.6"), "{s}");
        // Seed-averaged cells drive the §II-B claim check.
        let (sigma, sym, single) = report.mapping_gap_at_max_sigma().unwrap();
        assert_eq!(sigma, 0.6);
        assert!((sym - 0.95).abs() < 1e-12);
        assert!((single - 0.15).abs() < 1e-12);
        report.check_mapping_claim().unwrap();
    }

    #[test]
    fn ladder_renders_percentages() {
        let pts =
            vec![pt("baseline", 100_000, 200_000), pt("+lf", 80_000, 180_000), pt("full", 20_000, 120_000)];
        let s = render_ladder(&pts);
        assert!(s.contains("baseline"));
        assert!(s.contains("80.00%")); // cumulative accel reduction of full
        let j = ladder_json(&pts);
        assert_eq!(j.as_arr().unwrap().len(), 3);
    }
}
