//! Leader/worker inference service over a pluggable execution backend
//! (cycle-level SoC or the fast functional simulator).
//!
//! Since the batch-first refactor the coordinator is a **micro-batching
//! scheduler**: each worker drains the shared request queue into a
//! coalesced batch (up to [`ServeOptions::batch`] requests, waiting for
//! stragglers after the first one arrives — a window sized adaptively
//! from the observed inter-arrival rate by [`LingerEstimator`], or
//! pinned by the [`ServeOptions::linger_us`] override) and serves it
//! through one `run_batch` call — the fast backend walks every layer's
//! weight planes once per batch, which is where the throughput comes
//! from. `--batch 1` degenerates to the old request-at-a-time loop.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::{BackendKind, CycleBackend, FastBackend, InferenceBackend};
use crate::baselines::OptLevel;
use crate::compiler::build_kws_program_sharded;
use crate::fsim::{Calibration, FastSim};
use crate::mem::dram::DramConfig;
use crate::model::KwsModel;
use crate::robustness::VariationParams;
use crate::sim::{RunResult, Soc};
use crate::telemetry::{self, Histogram, RequestSpan, SpanLog};

/// One utterance to classify.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub audio: Vec<f32>,
    /// Golden label, if known (accuracy accounting).
    pub label: Option<i32>,
}

/// The service's answer.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub predicted: usize,
    pub logits: Vec<f32>,
    /// Simulated chip latency (cycles @ 50 MHz).
    pub chip_cycles: u64,
    pub chip_seconds: f64,
    /// Host wall-clock spent simulating.
    pub host_seconds: f64,
    /// Energy per inference (uJ).
    pub energy_uj: f64,
    pub correct: Option<bool>,
    /// Which execution engine served this request.
    pub backend: &'static str,
}

impl InferenceResponse {
    fn from_run(id: u64, r: &RunResult, label: Option<i32>, host: f64, backend: &'static str) -> Self {
        InferenceResponse {
            id,
            predicted: r.predicted,
            logits: r.logits.clone(),
            chip_cycles: r.cycles,
            chip_seconds: r.seconds_at_50mhz,
            host_seconds: host,
            energy_uj: r.energy.total_uj(),
            correct: label.map(|l| l as usize == r.predicted),
            backend,
        }
    }
}

/// Aggregate service statistics.
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub served: AtomicU64,
    pub correct: AtomicU64,
    pub labeled: AtomicU64,
    pub chip_cycles: AtomicU64,
    /// Per-shard macro fire counts accumulated across every served
    /// request (one entry per macro; empty only for a default-constructed
    /// stats block). Idle shards stay at zero — the utilization signal
    /// rendered by `report::render_shard_utilization`.
    pub shard_fires: Vec<AtomicU64>,
    /// Micro-batch size histogram: bucket `b` counts worker batches of
    /// exactly `b + 1` requests (the last bucket saturates). Sized to the
    /// deployment's `--batch`; rendered by `report::render_batch_histogram`.
    pub batch_sizes: Vec<AtomicU64>,
    /// Per-request host latency samples (µs, submit -> response ready:
    /// queue wait + linger + simulation). Source of the p50/p95/p99 in
    /// the serve report.
    host_us: Mutex<Vec<u64>>,
    /// Request-lifecycle spans (recorded only while telemetry is
    /// enabled; the Perfetto `--trace-out` source).
    pub spans: SpanLog,
    /// First served run's `(markers, cycles)` — the engine timeline the
    /// trace exporter renders (latency is data-independent, so one
    /// sample describes every request). Captured only under telemetry.
    engine: Mutex<Option<(Vec<(u32, u64)>, u64)>>,
}

impl ServiceStats {
    /// Stats block sized for an `n`-macro deployment.
    pub fn for_shards(n: usize) -> Self {
        Self::sized(n, 1)
    }

    /// Stats block sized for an `n_shards`-macro deployment serving
    /// micro-batches of up to `batch_max` requests.
    pub fn sized(n_shards: usize, batch_max: usize) -> Self {
        ServiceStats {
            shard_fires: (0..n_shards.max(1)).map(|_| AtomicU64::new(0)).collect(),
            batch_sizes: (0..batch_max.max(1)).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    /// Count one worker batch of `size` requests.
    pub fn record_batch(&self, size: usize) {
        if size == 0 || self.batch_sizes.is_empty() {
            return;
        }
        let bucket = size.min(self.batch_sizes.len()) - 1;
        self.batch_sizes[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request's host latency (seconds, submit -> response).
    pub fn record_host_latency(&self, seconds: f64) {
        self.host_us.lock().unwrap().push((seconds * 1e6) as u64);
    }

    /// `[p50, p95, p99]` host latency in seconds over every request
    /// served so far (`None` before the first response). Nearest-rank
    /// percentiles over the exact sample set — the coordinator serves
    /// bounded demo/bench runs, so keeping every sample is fine.
    pub fn host_latency_percentiles(&self) -> Option<[f64; 3]> {
        let v = self.host_us.lock().unwrap().clone();
        Self::percentiles_s(&v)
    }

    /// The same `[p50, p95, p99]` derived from the recorded request
    /// spans instead of the host-latency samples. `None` until spans
    /// exist (telemetry off, or nothing served). The two agree exactly:
    /// a span's `respond_us - enqueue_us` *is* the host-latency sample.
    pub fn span_latency_percentiles(&self) -> Option<[f64; 3]> {
        Self::percentiles_s(&self.spans.total_us_samples())
    }

    fn percentiles_s(us: &[u64]) -> Option<[f64; 3]> {
        let p = super::report::percentiles_us(us, &[0.50, 0.95, 0.99])?;
        Some([p[0] as f64 / 1e6, p[1] as f64 / 1e6, p[2] as f64 / 1e6])
    }

    /// Keep the first served run's marker stream + cycle count for the
    /// trace exporter.
    pub fn record_engine_sample(&self, r: &RunResult) {
        let mut e = self.engine.lock().unwrap();
        if e.is_none() {
            *e = Some((r.markers.clone(), r.cycles));
        }
    }

    /// The captured engine timeline, if any run was sampled.
    pub fn engine_sample(&self) -> Option<(Vec<(u32, u64)>, u64)> {
        self.engine.lock().unwrap().clone()
    }
}

/// Serving options beyond the backend choice.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Run one cycle-level inference at coordinator start and snap the
    /// fast backend's latency/energy to the measured numbers (compiled
    /// KWS programs have data-independent latency, so one run calibrates
    /// every request). Ignored by the cycle backend, which is exact.
    pub calibrate: bool,
    /// Shard every layer's output channels across this many simulated CIM
    /// macros (`--macros N`; 1 = the classic single-macro chip). Both
    /// backends honor it: the cycle SoC drives a macro bank, the fast
    /// simulator executes per-shard packed groups.
    pub macros: usize,
    /// Micro-batch cap (`--batch N`): each worker coalesces up to this
    /// many queued requests into one `run_batch` call. 1 = classic
    /// request-at-a-time serving. Must be >= 1 (0 is rejected at start).
    pub batch: usize,
    /// Fixed straggler window override (`--linger-us N`): how long a
    /// worker lingers for follow-up requests after the first one of a
    /// batch arrives (µs). `None` (the default) sizes the window
    /// adaptively from the observed request inter-arrival rate instead —
    /// see [`LingerEstimator`]. Irrelevant when `batch == 1`.
    pub linger_us: Option<u64>,
    /// Serve *disturbed* inferences (`serve --variation sigma=...`):
    /// both backends replay fresh identically seeded per-macro noise
    /// streams per request (fault-injection scenarios; see
    /// `robustness::replay` for the semantics).
    pub variation: Option<VariationParams>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { calibrate: false, macros: 1, batch: 1, linger_us: None, variation: None }
    }
}

/// Sizes the micro-batch straggler window. With a fixed override it is
/// the classic `--linger-us` constant; otherwise it tracks an EWMA of
/// the gaps between consecutive requests' submit instants (as seen by
/// this worker — a subsample under multi-worker fleets, which only
/// biases the window *up*, toward more coalescing) and opens a window of
/// twice the mean gap, clamped to
/// `[ADAPTIVE_LINGER_MIN_US, ADAPTIVE_LINGER_MAX_US]`: a fast stream
/// coalesces full batches, a trickle gives up quickly instead of taxing
/// every request with a worst-case wait.
#[derive(Debug, Clone)]
pub struct LingerEstimator {
    fixed: Option<Duration>,
    ewma_us: Option<f64>,
}

/// Adaptive window floor: enough to catch a same-burst follow-up.
pub const ADAPTIVE_LINGER_MIN_US: u64 = 50;
/// Adaptive window ceiling: never worse than 5 ms of added latency.
pub const ADAPTIVE_LINGER_MAX_US: u64 = 5_000;
/// Window before the first gap has been observed (the old fixed default).
pub const ADAPTIVE_LINGER_DEFAULT_US: u64 = 500;
/// EWMA smoothing factor for the inter-arrival estimate.
const LINGER_EWMA_ALPHA: f64 = 0.3;

impl LingerEstimator {
    pub fn new(fixed_us: Option<u64>) -> Self {
        LingerEstimator { fixed: fixed_us.map(Duration::from_micros), ewma_us: None }
    }

    /// Feed one observed inter-arrival gap (µs between consecutive
    /// requests' submit instants).
    pub fn observe_gap_us(&mut self, gap_us: f64) {
        let gap = gap_us.max(0.0);
        self.ewma_us = Some(match self.ewma_us {
            Some(e) => (1.0 - LINGER_EWMA_ALPHA) * e + LINGER_EWMA_ALPHA * gap,
            None => gap,
        });
    }

    /// The straggler window to use for the next batch.
    pub fn window(&self) -> Duration {
        if let Some(d) = self.fixed {
            return d;
        }
        let us = match self.ewma_us {
            Some(e) => (2.0 * e) as u64,
            None => ADAPTIVE_LINGER_DEFAULT_US,
        };
        Duration::from_micros(us.clamp(ADAPTIVE_LINGER_MIN_US, ADAPTIVE_LINGER_MAX_US))
    }
}

/// One queued unit of work: the request, its enqueue instant (host
/// latency is measured from here), and where the answer goes.
struct Job {
    req: InferenceRequest,
    enqueued: Instant,
    reply: mpsc::Sender<Result<InferenceResponse>>,
}

/// The leader: owns worker threads, each with its own SoC (the chip is
/// single-tenant; a fleet of workers models a fleet of edge devices).
pub struct Coordinator {
    /// `None` once shut down: `submit` then returns an error instead of
    /// panicking on the closed channel.
    tx: Option<mpsc::Sender<Job>>,
    pub stats: Arc<ServiceStats>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spin up `n_workers` cycle-level workers for `model` at `opt`
    /// (the original single-engine entry point).
    pub fn start(model: &KwsModel, opt: OptLevel, n_workers: usize) -> Result<Self> {
        Self::start_with(model, opt, n_workers, BackendKind::Cycle)
    }

    /// Spin up `n_workers` workers, each owning one `kind` backend for
    /// the compiled program (`--backend {cycle,fast}` on the CLI).
    pub fn start_with(
        model: &KwsModel,
        opt: OptLevel,
        n_workers: usize,
        kind: BackendKind,
    ) -> Result<Self> {
        Self::start_with_options(model, opt, n_workers, kind, ServeOptions::default())
    }

    /// `start_with` plus [`ServeOptions`] (`--calibrate`, `--macros`,
    /// `--batch` on the CLI). Rejects degenerate deployments up front:
    /// zero workers or a zero micro-batch cap could never serve a
    /// request, so they are errors here rather than a silent hang.
    pub fn start_with_options(
        model: &KwsModel,
        opt: OptLevel,
        n_workers: usize,
        kind: BackendKind,
        opts: ServeOptions,
    ) -> Result<Self> {
        if n_workers == 0 {
            bail!("coordinator needs at least one worker (got --workers 0)");
        }
        if opts.batch == 0 {
            bail!("micro-batch cap must be >= 1 (got --batch 0; use 1 to disable batching)");
        }
        let program = build_kws_program_sharded(model, opt, opts.macros.max(1))?;
        // Build every worker's backend up front so construction errors
        // surface here with their real cause (not as a silent worker
        // exit). The functional simulator is stateless across requests
        // (`FastSim::infer` is `&self`): decode the image and run the
        // analytical walk once, then share the one instance across every
        // worker behind an `Arc`. The cycle SoC is stateful, so each
        // cycle worker gets its own instance.
        let fast_shared: Option<Arc<FastSim>> = match kind {
            BackendKind::Fast => {
                let mut sim = FastSim::new(program.clone(), DramConfig::default())?;
                if n_workers > 1 {
                    // The worker fleet is already the parallelism: keep
                    // each worker's batch on its own thread. A single
                    // worker gets the in-batch thread fan-out instead.
                    sim = sim.with_batch_threads(1);
                }
                if let Some(v) = opts.variation {
                    sim = sim.with_variation(v);
                }
                if opts.calibrate {
                    // One cycle-accurate run (any utterance: latency is
                    // data-independent — variation disturbs values, never
                    // timing, so the calibration SoC stays clean) snaps
                    // served latency/energy from analytical to exact.
                    let mut soc = Soc::new(program.clone(), DramConfig::default())?;
                    let silence = vec![0.0f32; model.audio_len];
                    let measured = soc.infer(&silence)?;
                    sim = sim.with_calibration(Calibration::from_run(&measured));
                }
                Some(Arc::new(sim))
            }
            BackendKind::Cycle => None,
        };
        let mut backends: Vec<Box<dyn InferenceBackend>> = Vec::new();
        for _ in 0..n_workers {
            let be: Box<dyn InferenceBackend> = match &fast_shared {
                Some(sim) => Box::new(FastBackend::shared(Arc::clone(sim))),
                None => {
                    let cb = CycleBackend::new(program.clone(), DramConfig::default())?;
                    Box::new(match opts.variation {
                        Some(v) => cb.with_variation(v),
                        None => cb,
                    })
                }
            };
            backends.push(be);
        }
        let stats = Arc::new(ServiceStats::sized(opts.macros.max(1), opts.batch));
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let linger_fixed = opts.linger_us;
        let batch_cap = opts.batch;
        let mut workers = Vec::new();
        for (wi, mut be) in backends.into_iter().enumerate() {
            let rx = Arc::clone(&rx);
            let stats = Arc::clone(&stats);
            workers.push(thread::spawn(move || {
                let bname = be.name();
                // Registry handles resolved once per worker; recording
                // through them is lock-free (and a no-op when telemetry
                // is disabled).
                let telem = telemetry::global();
                let m_requests = telem.counter("serve.requests");
                let m_batches = telem.counter("serve.batches");
                let m_host = telem.histogram("serve.host_latency_us", Histogram::us_bounds());
                let m_exec = telem.histogram("serve.execute_us", Histogram::us_bounds());
                let g_linger = telem.gauge("serve.linger_window_us");
                let mut linger = LingerEstimator::new(linger_fixed);
                let mut last_submit: Option<Instant> = None;
                loop {
                    // Drain the queue into one coalesced micro-batch:
                    // block for the first request, then keep the channel
                    // (and the drain lock) until the cap is hit, the
                    // linger window closes, or the queue goes quiet.
                    let mut jobs: Vec<Job> = Vec::with_capacity(batch_cap);
                    let assembly_start;
                    {
                        let rx = rx.lock().unwrap();
                        match rx.recv() {
                            Ok(job) => jobs.push(job),
                            Err(_) => break, // coordinator shut down
                        }
                        // The assembly window opens when the first job
                        // lands on this worker.
                        assembly_start = Instant::now();
                        let deadline = assembly_start + linger.window();
                        while jobs.len() < batch_cap {
                            match rx.try_recv() {
                                Ok(job) => jobs.push(job),
                                Err(TryRecvError::Disconnected) => break,
                                Err(TryRecvError::Empty) => {
                                    let now = Instant::now();
                                    if now >= deadline {
                                        break;
                                    }
                                    match rx.recv_timeout(deadline - now) {
                                        Ok(job) => jobs.push(job),
                                        Err(_) => break,
                                    }
                                }
                            }
                        }
                    }
                    // Feed the adaptive linger policy with the arrival
                    // process (submit instants, not drain instants, so
                    // the estimate is independent of worker scheduling).
                    for job in &jobs {
                        if let Some(prev) = last_submit {
                            let gap = job.enqueued.saturating_duration_since(prev);
                            linger.observe_gap_us(gap.as_secs_f64() * 1e6);
                        }
                        last_submit = Some(job.enqueued);
                    }
                    let assembled = Instant::now();
                    g_linger.set(linger.window().as_secs_f64() * 1e6);
                    let audios: Vec<&[f32]> =
                        jobs.iter().map(|j| j.req.audio.as_slice()).collect();
                    stats.record_batch(jobs.len());
                    m_batches.inc();
                    let exec_start = Instant::now();
                    let result = be.run_batch(&audios);
                    let exec_end = Instant::now();
                    m_exec.observe(exec_end.duration_since(exec_start).as_micros() as u64);
                    match result {
                        Ok(runs) if runs.len() == jobs.len() => {
                            if telemetry::enabled() {
                                if let Some(r) = runs.first() {
                                    stats.record_engine_sample(r);
                                }
                            }
                            for (job, r) in jobs.iter().zip(&runs) {
                                let host = job.enqueued.elapsed().as_secs_f64();
                                let resp = InferenceResponse::from_run(
                                    job.req.id,
                                    r,
                                    job.req.label,
                                    host,
                                    bname,
                                );
                                stats.served.fetch_add(1, Ordering::Relaxed);
                                stats.chip_cycles.fetch_add(r.cycles, Ordering::Relaxed);
                                stats.record_host_latency(host);
                                m_requests.inc();
                                m_host.observe((host * 1e6) as u64);
                                if telemetry::enabled() {
                                    let enqueue_us = stats.spans.us_since_epoch(job.enqueued);
                                    stats.spans.record(RequestSpan {
                                        req_id: job.req.id,
                                        worker: wi,
                                        batch_size: jobs.len(),
                                        enqueue_us,
                                        assembly_start_us: stats
                                            .spans
                                            .us_since_epoch(assembly_start),
                                        assembled_us: stats.spans.us_since_epoch(assembled),
                                        exec_start_us: stats.spans.us_since_epoch(exec_start),
                                        exec_end_us: stats.spans.us_since_epoch(exec_end),
                                        // Defined as enqueue + the host
                                        // sample so span totals agree
                                        // exactly with the percentiles.
                                        respond_us: enqueue_us + (host * 1e6) as u64,
                                        shard_fires: r.shard_fires.clone(),
                                    });
                                }
                                for (shard, fires) in
                                    stats.shard_fires.iter().zip(&r.shard_fires)
                                {
                                    shard.fetch_add(*fires, Ordering::Relaxed);
                                }
                                if let Some(c) = resp.correct {
                                    stats.labeled.fetch_add(1, Ordering::Relaxed);
                                    if c {
                                        stats.correct.fetch_add(1, Ordering::Relaxed);
                                    }
                                }
                                let _ = job.reply.send(Ok(resp));
                            }
                        }
                        Ok(runs) => {
                            for job in &jobs {
                                let _ = job.reply.send(Err(anyhow!(
                                    "backend returned {} results for a batch of {}",
                                    runs.len(),
                                    jobs.len()
                                )));
                            }
                        }
                        Err(e) => {
                            for job in &jobs {
                                let _ = job.reply.send(Err(anyhow!(
                                    "batched inference failed: {e}"
                                )));
                            }
                        }
                    }
                }
            }));
        }
        Ok(Coordinator { tx: Some(tx), stats, workers })
    }

    /// Submit one request; returns a receiver for the response, or an
    /// error if the coordinator has shut down (no panic).
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse>>> {
        let id = req.id;
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| anyhow!("coordinator is shut down (request {id} rejected)"))?;
        let (rtx, rrx) = mpsc::channel();
        tx.send(Job { req, enqueued: Instant::now(), reply: rtx })
            .map_err(|_| anyhow!("coordinator workers are gone (request {id} rejected)"))?;
        Ok(rrx)
    }

    /// Serve a whole batch, preserving order. An empty batch returns
    /// `Ok(vec![])` immediately without touching the worker queue (so it
    /// succeeds even after shutdown — there is nothing to serve).
    pub fn serve_batch(&self, reqs: Vec<InferenceRequest>) -> Result<Vec<InferenceResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let rxs: Vec<_> = reqs
            .into_iter()
            .map(|r| self.submit(r))
            .collect::<Result<Vec<_>>>()?;
        rxs.into_iter()
            .map(|rx| rx.recv().context("worker dropped")?)
            .collect()
    }

    /// Measured accuracy over labeled requests so far.
    pub fn accuracy(&self) -> Option<f64> {
        let l = self.stats.labeled.load(Ordering::Relaxed);
        (l > 0).then(|| self.stats.correct.load(Ordering::Relaxed) as f64 / l as f64)
    }

    /// Shut down: drop the queue and join workers. Subsequent `submit`
    /// calls return an error.
    pub fn shutdown(&mut self) {
        self.tx = None;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kws::LayerSpec;

    fn fake_model() -> KwsModel {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
            thresholds: if binarized { vec![0; co] } else { vec![] },
        };
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 1,
            layers: vec![mk(64, 32, true, true), mk(32, 12, false, false)],
            bn_gamma: vec![1.0; 64],
            bn_beta: vec![0.0; 64],
            bn_mean: vec![20000.0; 64],
            bn_var: vec![4e8; 64],
            pre_thr: vec![20000; 64],
            pre_dir: vec![1; 64],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    #[test]
    fn serves_batches_in_order_across_workers() {
        let m = fake_model();
        let mut coord = Coordinator::start(&m, OptLevel::FULL, 3).unwrap();
        let reqs: Vec<_> = (0..9)
            .map(|i| InferenceRequest {
                id: i,
                audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                label: None,
            })
            .collect();
        let resps = coord.serve_batch(reqs).unwrap();
        assert_eq!(resps.len(), 9);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.chip_cycles > 0);
            assert!(r.energy_uj > 0.0);
        }
        assert_eq!(coord.stats.served.load(Ordering::Relaxed), 9);
        coord.shutdown();
    }

    #[test]
    fn responses_deterministic_across_workers() {
        // The same utterance must classify identically on every worker.
        let m = fake_model();
        let mut coord = Coordinator::start(&m, OptLevel::FULL, 4).unwrap();
        let audio = crate::model::dataset::synth_utterance(5, 1, 16000, 0.3);
        let reqs: Vec<_> = (0..8)
            .map(|i| InferenceRequest { id: i, audio: audio.clone(), label: None })
            .collect();
        let resps = coord.serve_batch(reqs).unwrap();
        for r in &resps[1..] {
            assert_eq!(r.logits, resps[0].logits);
            assert_eq!(r.chip_cycles, resps[0].chip_cycles);
        }
        coord.shutdown();
    }

    #[test]
    fn fast_backend_serves_identical_logits() {
        // The same requests through cycle and fast coordinators must
        // yield bit-identical logits (the backend parity contract).
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(
                        i as usize % 12,
                        i,
                        16000,
                        0.3,
                    ),
                    label: None,
                })
                .collect()
        };
        let mut cyc = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Cycle).unwrap();
        let a = cyc.serve_batch(reqs(4)).unwrap();
        cyc.shutdown();
        let mut fast = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let b = fast.serve_batch(reqs(4)).unwrap();
        fast.shutdown();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
            assert_eq!(x.predicted, y.predicted);
        }
        assert!(a.iter().all(|r| r.backend == "cycle"));
        assert!(b.iter().all(|r| r.backend == "fast"));
    }

    #[test]
    fn submit_after_shutdown_errors_instead_of_panicking() {
        let m = fake_model();
        let mut coord =
            Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let req = |id| InferenceRequest {
            id,
            audio: crate::model::dataset::synth_utterance(1, 2, 16000, 0.3),
            label: None,
        };
        let rx = coord.submit(req(0)).unwrap();
        assert!(rx.recv().unwrap().is_ok());
        coord.shutdown();
        let err = coord.submit(req(1)).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        assert!(coord.serve_batch(vec![req(2)]).is_err());
    }

    #[test]
    fn calibrated_fast_serving_is_cycle_exact() {
        // --calibrate at coordinator start: served latency/energy snaps
        // to the cycle simulator's numbers while logits stay identical.
        let m = fake_model();
        let audio = crate::model::dataset::synth_utterance(4, 11, 16000, 0.3);
        let req = || {
            vec![InferenceRequest { id: 0, audio: audio.clone(), label: None }]
        };
        let mut cyc = Coordinator::start_with(&m, OptLevel::FULL, 1, BackendKind::Cycle).unwrap();
        let want = cyc.serve_batch(req()).unwrap();
        cyc.shutdown();
        let mut fast = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            3,
            BackendKind::Fast,
            ServeOptions { calibrate: true, ..Default::default() },
        )
        .unwrap();
        let got = fast.serve_batch(req()).unwrap();
        fast.shutdown();
        assert_eq!(got[0].logits, want[0].logits);
        assert_eq!(got[0].chip_cycles, want[0].chip_cycles, "snap calibration must be exact");
        assert!((got[0].energy_uj - want[0].energy_uj).abs() < 1e-9);
        assert_eq!(got[0].backend, "fast");
    }

    #[test]
    fn empty_batch_returns_ok_without_round_trip() {
        let m = fake_model();
        let mut coord = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        assert!(coord.serve_batch(vec![]).unwrap().is_empty());
        assert_eq!(coord.stats.served.load(Ordering::Relaxed), 0, "no worker round trip");
        coord.shutdown();
        // Even after shutdown: nothing to serve, so still Ok.
        assert!(coord.serve_batch(vec![]).unwrap().is_empty());
    }

    #[test]
    fn sharded_serving_identical_logits_and_per_shard_utilization() {
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                })
                .collect()
        };
        let mut single =
            Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let want = single.serve_batch(reqs(4)).unwrap();
        single.shutdown();

        let mut sharded = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { macros: 2, ..Default::default() },
        )
        .unwrap();
        let got = sharded.serve_batch(reqs(4)).unwrap();
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
        }
        // Per-shard utilization accumulated across every request; the
        // fake model's 32- and 12-wide layers fit one latch word, so the
        // word-aligned split leaves macro 1 idle — visible in the stats.
        assert_eq!(sharded.stats.shard_fires.len(), 2);
        let f0 = sharded.stats.shard_fires[0].load(Ordering::Relaxed);
        let f1 = sharded.stats.shard_fires[1].load(Ordering::Relaxed);
        assert!(f0 > 0);
        assert!(f0 > f1, "macro 0 owns every layer's leading channels: {f0} vs {f1}");
        sharded.shutdown();
    }

    #[test]
    fn rejects_zero_workers_and_zero_batch() {
        let m = fake_model();
        let err = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            0,
            BackendKind::Fast,
            ServeOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one worker"), "{err}");
        let err = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { batch: 0, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("--batch 0"), "{err}");
    }

    #[test]
    fn micro_batched_serving_identical_logits_and_stats() {
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: Some((i % 12) as i32),
                })
                .collect()
        };
        let mut plain = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let want = plain.serve_batch(reqs(9)).unwrap();
        plain.shutdown();

        // One worker + a generous linger forces real coalescing: 9
        // requests cannot be served as 9 singleton batches.
        let mut micro = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            1,
            BackendKind::Fast,
            ServeOptions { batch: 4, linger_us: Some(50_000), ..Default::default() },
        )
        .unwrap();
        let got = micro.serve_batch(reqs(9)).unwrap();
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
            assert_eq!(x.predicted, y.predicted);
        }
        assert_eq!(micro.stats.served.load(Ordering::Relaxed), 9);
        // Histogram: sized to the cap, everything accounted, and at
        // least one multi-request batch actually formed.
        assert_eq!(micro.stats.batch_sizes.len(), 4);
        let hist: Vec<u64> =
            micro.stats.batch_sizes.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let total_reqs: u64 =
            hist.iter().enumerate().map(|(b, n)| (b as u64 + 1) * n).sum();
        assert_eq!(total_reqs, 9, "histogram accounts for every request: {hist:?}");
        assert!(hist[1..].iter().sum::<u64>() > 0, "no multi-request batch formed: {hist:?}");
        // Latency percentiles exist and are ordered.
        let [p50, p95, p99] = micro.stats.host_latency_percentiles().unwrap();
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        micro.shutdown();
        assert!(micro.accuracy().is_some());
    }

    #[test]
    fn linger_estimator_adapts_and_clamps() {
        // Fixed override wins unconditionally.
        let fixed = LingerEstimator::new(Some(1234));
        assert_eq!(fixed.window(), Duration::from_micros(1234));
        // Before any observation: the default window.
        let mut e = LingerEstimator::new(None);
        assert_eq!(e.window(), Duration::from_micros(ADAPTIVE_LINGER_DEFAULT_US));
        // A steady 200 µs stream converges to a ~400 µs window (2x gap).
        for _ in 0..50 {
            e.observe_gap_us(200.0);
        }
        let w = e.window().as_micros() as u64;
        assert!((395..=405).contains(&w), "window {w} µs for a 200 µs stream");
        // A trickle clamps at the ceiling instead of growing unbounded...
        for _ in 0..50 {
            e.observe_gap_us(1_000_000.0);
        }
        assert_eq!(e.window(), Duration::from_micros(ADAPTIVE_LINGER_MAX_US));
        // ...and a flood clamps at the floor.
        for _ in 0..200 {
            e.observe_gap_us(0.0);
        }
        assert_eq!(e.window(), Duration::from_micros(ADAPTIVE_LINGER_MIN_US));
        // The fixed override ignores observations entirely.
        let mut f = LingerEstimator::new(Some(777));
        f.observe_gap_us(0.0);
        assert_eq!(f.window(), Duration::from_micros(777));
    }

    #[test]
    fn adaptive_linger_serving_matches_fixed_linger_bits() {
        // The linger policy decides how batches coalesce, never what they
        // compute: default (adaptive) serving must produce the same
        // logits as a fixed-linger deployment, and still form real
        // multi-request batches under a burst.
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                })
                .collect()
        };
        let mut fixed = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            1,
            BackendKind::Fast,
            ServeOptions { batch: 4, linger_us: Some(50_000), ..Default::default() },
        )
        .unwrap();
        let want = fixed.serve_batch(reqs(8)).unwrap();
        fixed.shutdown();

        let mut adaptive = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            1,
            BackendKind::Fast,
            ServeOptions { batch: 4, ..Default::default() }, // linger_us: None
        )
        .unwrap();
        let got = adaptive.serve_batch(reqs(8)).unwrap();
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
            assert_eq!(x.predicted, y.predicted);
        }
        assert_eq!(adaptive.stats.served.load(Ordering::Relaxed), 8);
        let hist: Vec<u64> =
            adaptive.stats.batch_sizes.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        assert!(
            hist[1..].iter().sum::<u64>() > 0,
            "adaptive linger formed no multi-request batch under a burst: {hist:?}"
        );
        adaptive.shutdown();
    }

    #[test]
    fn variation_serving_is_disturbed_and_backend_agnostic() {
        // serve --variation: both engines replay fresh identically
        // seeded per-request noise streams, so a disturbed request
        // classifies identically on the fast and cycle backends — and
        // differently from clean serving.
        let m = fake_model();
        let variation = Some(VariationParams {
            sigma: 0.5,
            nl_alpha: 0.3,
            symmetric: false,
            ..Default::default()
        });
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                })
                .collect()
        };
        let mut clean = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let base = clean.serve_batch(reqs(3)).unwrap();
        clean.shutdown();

        let mut fast = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { variation, ..Default::default() },
        )
        .unwrap();
        let f = fast.serve_batch(reqs(3)).unwrap();
        fast.shutdown();
        assert!(
            f.iter().zip(&base).any(|(a, b)| a.logits != b.logits),
            "sigma 0.5 single-ended serving must disturb logits"
        );

        let mut cyc = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Cycle,
            ServeOptions { variation, ..Default::default() },
        )
        .unwrap();
        let c = cyc.serve_batch(reqs(3)).unwrap();
        cyc.shutdown();
        for (x, y) in f.iter().zip(&c) {
            assert_eq!(x.logits, y.logits, "disturbed request {} diverged across engines", x.id);
        }
    }

    #[test]
    fn batch_histogram_saturates_last_bucket() {
        let s = ServiceStats::sized(1, 2);
        s.record_batch(1);
        s.record_batch(2);
        s.record_batch(7); // beyond the cap -> last bucket
        assert_eq!(s.batch_sizes[0].load(Ordering::Relaxed), 1);
        assert_eq!(s.batch_sizes[1].load(Ordering::Relaxed), 2);
        // Degenerate blocks don't panic.
        ServiceStats::default().record_batch(3);
        assert!(ServiceStats::default().host_latency_percentiles().is_none());
    }

    #[test]
    fn spans_record_when_telemetry_enabled_and_match_host_samples() {
        crate::telemetry::with_telemetry(|| {
            let m = fake_model();
            let mut coord =
                Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
            let reqs: Vec<_> = (0..5)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                })
                .collect();
            let _ = coord.serve_batch(reqs).unwrap();
            coord.shutdown();
            let spans = coord.stats.spans.snapshot();
            assert_eq!(spans.len(), 5);
            for s in &spans {
                assert!(s.assembled_us >= s.assembly_start_us, "{s:?}");
                assert!(s.exec_end_us >= s.exec_start_us, "{s:?}");
                assert!(s.respond_us >= s.enqueue_us, "{s:?}");
                assert!(!s.shard_fires.is_empty());
                assert!(s.batch_size >= 1);
            }
            // Span-derived percentiles agree *exactly* with the host
            // samples (same numbers, not re-measured).
            assert_eq!(
                coord.stats.span_latency_percentiles().unwrap(),
                coord.stats.host_latency_percentiles().unwrap()
            );
            // The engine timeline was sampled for the trace exporter.
            let (markers, cycles) = coord.stats.engine_sample().unwrap();
            assert!(!markers.is_empty());
            assert!(cycles > 0);

            // Telemetry off (still inside the guard, so no parallel
            // test can re-enable it): serving records no spans.
            crate::telemetry::set_enabled(false);
            let mut coord =
                Coordinator::start_with(&m, OptLevel::FULL, 1, BackendKind::Fast).unwrap();
            let req = InferenceRequest {
                id: 0,
                audio: crate::model::dataset::synth_utterance(0, 1, 16000, 0.3),
                label: None,
            };
            let _ = coord.serve_batch(vec![req]).unwrap();
            coord.shutdown();
            assert!(coord.stats.spans.is_empty());
            assert!(coord.stats.engine_sample().is_none());
            assert!(coord.stats.span_latency_percentiles().is_none());
        });
    }

    #[test]
    fn accuracy_accounting() {
        let m = fake_model();
        let mut coord = Coordinator::start(&m, OptLevel::FULL, 2).unwrap();
        let reqs: Vec<_> = (0..4)
            .map(|i| InferenceRequest {
                id: i,
                audio: crate::model::dataset::synth_utterance(0, i, 16000, 0.3),
                label: Some(0),
            })
            .collect();
        let _ = coord.serve_batch(reqs).unwrap();
        assert_eq!(coord.stats.labeled.load(Ordering::Relaxed), 4);
        assert!(coord.accuracy().is_some());
        coord.shutdown();
    }
}
