//! Leader/worker inference service over a pluggable execution backend
//! (cycle-level SoC or the fast functional simulator).
//!
//! Since the batch-first refactor the coordinator is a **micro-batching
//! scheduler**: each worker drains the shared request queue into a
//! coalesced batch (up to [`ServeOptions::batch`] requests, waiting for
//! stragglers after the first one arrives — a window sized adaptively
//! from the observed inter-arrival rate by [`LingerEstimator`], or
//! pinned by the [`ServeOptions::linger_us`] override) and serves it
//! through one `run_batch` call — the fast backend walks every layer's
//! weight planes once per batch, which is where the throughput comes
//! from. `--batch 1` degenerates to the old request-at-a-time loop.
//!
//! The fault-tolerance rework layered the resilience subsystem on top:
//!
//! * **Admission control** — the queue is a bounded
//!   [`BoundedQueue`]; a full queue sheds with
//!   [`SubmitError::Overloaded`] instead of growing without limit, and
//!   requests may carry a [`InferenceRequest::deadline`] that is checked
//!   at dequeue *and* after execution so expired work is dropped, not
//!   computed.
//! * **Supervision** — each worker runs batches under `catch_unwind`;
//!   a panic requeues the in-flight jobs at the head of the queue and a
//!   supervisor thread respawns the dead worker against the shared
//!   `Arc<FastSim>`. Transient backend errors retry with capped
//!   exponential backoff + deterministic jitter before failing typed.
//! * **Graceful degradation** — a per-worker [`CircuitBreaker`] trips
//!   after [`BREAKER_THRESHOLD`] consecutive faults; the tripped worker
//!   is respawned *degraded*, re-planned over one fewer macro via
//!   [`ShardPlan::even`], shedding shard capacity instead of
//!   availability.
//! * **Chaos** — [`ServeOptions::chaos`] wraps every worker's backend in
//!   a seeded [`ChaosBackend`] so each of these paths is reproducible in
//!   tests and soaks (`cimrv soak`).
//!
//! Every accepted request resolves to either an `InferenceResponse` or a
//! typed [`ServeError`] — never a hang, never a dropped reply channel.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::backend::{BackendKind, CycleBackend, FastBackend, InferenceBackend};
use crate::baselines::OptLevel;
use crate::compiler::{build_kws_program_sharded, Program};
use crate::dataflow::shard::ShardPlan;
use crate::fsim::{Calibration, FastSim};
use crate::mem::dram::DramConfig;
use crate::model::KwsModel;
use crate::resilience::{
    BoundedQueue, ChaosBackend, CircuitBreaker, FaultPlan, PushError, ServeError, SubmitError,
};
use crate::robustness::VariationParams;
use crate::sim::{RunResult, Soc};
use crate::telemetry::{
    self, incident, Histogram, IncidentKind, RequestSpan, SloConfig, SloMonitor, SloReport,
    SpanLog, SpanOutcome,
};
use crate::util::lock_or_recover;
use crate::util::rng::Rng;

/// Consecutive faults (transient errors or panics) that trip a worker's
/// circuit breaker and force a degraded respawn.
pub const BREAKER_THRESHOLD: u32 = 5;
/// Default bounded-queue capacity (`--queue-cap`).
pub const DEFAULT_QUEUE_CAP: usize = 1024;
/// Default per-request attempt budget (first try + retries/requeues).
pub const DEFAULT_MAX_ATTEMPTS: u32 = 6;
/// First retry backoff; doubles per attempt up to [`RETRY_MAX_US`].
const RETRY_BASE_US: u64 = 200;
/// Backoff ceiling.
const RETRY_MAX_US: u64 = 20_000;
/// Supervisor poll cadence for dead-worker detection.
const SUPERVISOR_TICK: Duration = Duration::from_millis(1);
/// Respawn delay after a plain worker panic.
const PANIC_RESPAWN_COOLDOWN: Duration = Duration::from_millis(5);
/// Respawn delay after a breaker trip (the fault streak suggests the
/// worker's environment needs a beat before the degraded retry).
const BREAKER_COOLDOWN: Duration = Duration::from_millis(25);

/// One utterance to classify.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    pub audio: Vec<f32>,
    /// Golden label, if known (accuracy accounting).
    pub label: Option<i32>,
    /// Absolute response deadline. Checked when a worker dequeues the
    /// request and again after execution: expired work is answered with
    /// [`ServeError::DeadlineExceeded`] instead of being computed (or
    /// returned stale). `None` = no deadline.
    pub deadline: Option<Instant>,
}

/// The service's answer.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub predicted: usize,
    pub logits: Vec<f32>,
    /// Simulated chip latency (cycles @ 50 MHz).
    pub chip_cycles: u64,
    pub chip_seconds: f64,
    /// Host wall-clock spent simulating.
    pub host_seconds: f64,
    /// Energy per inference (uJ).
    pub energy_uj: f64,
    pub correct: Option<bool>,
    /// Which execution engine served this request.
    pub backend: &'static str,
}

impl InferenceResponse {
    fn from_run(id: u64, r: &RunResult, label: Option<i32>, host: f64, backend: &'static str) -> Self {
        InferenceResponse {
            id,
            predicted: r.predicted,
            logits: r.logits.clone(),
            chip_cycles: r.cycles,
            chip_seconds: r.seconds_at_50mhz,
            host_seconds: host,
            energy_uj: r.energy.total_uj(),
            correct: label.map(|l| l as usize == r.predicted),
            backend,
        }
    }
}

/// Aggregate service statistics.
#[derive(Debug, Default)]
pub struct ServiceStats {
    pub served: AtomicU64,
    pub correct: AtomicU64,
    pub labeled: AtomicU64,
    pub chip_cycles: AtomicU64,
    /// Requests refused at admission because the queue was full.
    pub shed_overload: AtomicU64,
    /// Requests answered `DeadlineExceeded` (at dequeue or post-exec).
    pub shed_deadline: AtomicU64,
    /// Batch retry attempts after transient backend errors.
    pub retries: AtomicU64,
    /// Jobs pushed back to the queue head by a crashed/tripped worker.
    pub requeues: AtomicU64,
    /// Requests that exhausted their attempt budget (typed failure).
    pub failed: AtomicU64,
    /// Worker batches that ended in a panic (caught, never fatal).
    pub worker_panics: AtomicU64,
    /// Workers respawned by the supervisor.
    pub respawns: AtomicU64,
    /// Circuit-breaker trips (each forces a degraded respawn).
    pub breaker_trips: AtomicU64,
    /// Per-shard macro fire counts accumulated across every served
    /// request (one entry per macro; empty only for a default-constructed
    /// stats block). Idle shards stay at zero — the utilization signal
    /// rendered by `report::render_shard_utilization`.
    pub shard_fires: Vec<AtomicU64>,
    /// Micro-batch size histogram: bucket `b` counts worker batches of
    /// exactly `b + 1` requests (the last bucket saturates). Sized to the
    /// deployment's `--batch`; rendered by `report::render_batch_histogram`.
    pub batch_sizes: Vec<AtomicU64>,
    /// Per-request host latency samples (µs, submit -> response ready:
    /// queue wait + linger + simulation). Source of the p50/p95/p99 in
    /// the serve report.
    host_us: Mutex<Vec<u64>>,
    /// Request-lifecycle spans (recorded only while telemetry is
    /// enabled; the Perfetto `--trace-out` source).
    pub spans: SpanLog,
    /// First served run's `(markers, cycles)` — the engine timeline the
    /// trace exporter renders (latency is data-independent, so one
    /// sample describes every request). Captured only under telemetry.
    engine: Mutex<Option<(Vec<(u32, u64)>, u64)>>,
    /// Rolling SLO monitor, installed once from [`ServeOptions::slo`]
    /// (`--slo p99_ms=...,availability=...`). Absent = no monitoring.
    slo: OnceLock<SloMonitor>,
}

impl ServiceStats {
    /// Stats block sized for an `n`-macro deployment.
    pub fn for_shards(n: usize) -> Self {
        Self::sized(n, 1)
    }

    /// Stats block sized for an `n_shards`-macro deployment serving
    /// micro-batches of up to `batch_max` requests.
    pub fn sized(n_shards: usize, batch_max: usize) -> Self {
        ServiceStats {
            shard_fires: (0..n_shards.max(1)).map(|_| AtomicU64::new(0)).collect(),
            batch_sizes: (0..batch_max.max(1)).map(|_| AtomicU64::new(0)).collect(),
            ..Default::default()
        }
    }

    /// Count one worker batch of `size` requests.
    pub fn record_batch(&self, size: usize) {
        if size == 0 || self.batch_sizes.is_empty() {
            return;
        }
        let bucket = size.min(self.batch_sizes.len()) - 1;
        self.batch_sizes[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one request's host latency (seconds, submit -> response).
    pub fn record_host_latency(&self, seconds: f64) {
        lock_or_recover(&self.host_us).push((seconds * 1e6) as u64);
    }

    /// `[p50, p95, p99]` host latency in seconds over every request
    /// served so far (`None` before the first response). Nearest-rank
    /// percentiles over the exact sample set — the coordinator serves
    /// bounded demo/bench runs, so keeping every sample is fine.
    pub fn host_latency_percentiles(&self) -> Option<[f64; 3]> {
        let v = lock_or_recover(&self.host_us).clone();
        Self::percentiles_s(&v)
    }

    /// The same `[p50, p95, p99]` derived from the recorded request
    /// spans instead of the host-latency samples. `None` until spans
    /// exist (telemetry off, or nothing served). The two agree exactly:
    /// a *served* span's `respond_us - enqueue_us` *is* the host-latency
    /// sample, and `SpanLog::total_us_samples` excludes shed/failed
    /// lifecycles from the population.
    pub fn span_latency_percentiles(&self) -> Option<[f64; 3]> {
        Self::percentiles_s(&self.spans.total_us_samples())
    }

    fn percentiles_s(us: &[u64]) -> Option<[f64; 3]> {
        let p = super::report::percentiles_us(us, &[0.50, 0.95, 0.99])?;
        Some([p[0] as f64 / 1e6, p[1] as f64 / 1e6, p[2] as f64 / 1e6])
    }

    /// Keep the first served run's marker stream + cycle count for the
    /// trace exporter.
    pub fn record_engine_sample(&self, r: &RunResult) {
        let mut e = lock_or_recover(&self.engine);
        if e.is_none() {
            *e = Some((r.markers.clone(), r.cycles));
        }
    }

    /// The captured engine timeline, if any run was sampled.
    pub fn engine_sample(&self) -> Option<(Vec<(u32, u64)>, u64)> {
        lock_or_recover(&self.engine).clone()
    }

    /// Install the rolling SLO monitor (no-op if one is already
    /// installed — the config is fixed for the deployment's lifetime).
    pub fn install_slo(&self, cfg: SloConfig) {
        let _ = self.slo.set(SloMonitor::new(cfg));
    }

    /// Feed one terminal request outcome into the SLO window (latency in
    /// µs; `served` = the request got an answer, not a shed/failure).
    /// No-op without an installed monitor.
    pub fn slo_record(&self, latency_us: u64, served: bool) {
        if let Some(m) = self.slo.get() {
            m.record(latency_us, served);
        }
    }

    /// Current SLO report, if monitoring is configured.
    pub fn slo_report(&self) -> Option<SloReport> {
        self.slo.get().map(SloMonitor::report)
    }
}

/// Serving options beyond the backend choice.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Run one cycle-level inference at coordinator start and snap the
    /// fast backend's latency/energy to the measured numbers (compiled
    /// KWS programs have data-independent latency, so one run calibrates
    /// every request). Ignored by the cycle backend, which is exact.
    pub calibrate: bool,
    /// Shard every layer's output channels across this many simulated CIM
    /// macros (`--macros N`; 1 = the classic single-macro chip). Both
    /// backends honor it: the cycle SoC drives a macro bank, the fast
    /// simulator executes per-shard packed groups.
    pub macros: usize,
    /// Micro-batch cap (`--batch N`): each worker coalesces up to this
    /// many queued requests into one `run_batch` call. 1 = classic
    /// request-at-a-time serving. Must be >= 1 (0 is rejected at start).
    pub batch: usize,
    /// Fixed straggler window override (`--linger-us N`): how long a
    /// worker lingers for follow-up requests after the first one of a
    /// batch arrives (µs). `None` (the default) sizes the window
    /// adaptively from the observed request inter-arrival rate instead —
    /// see [`LingerEstimator`]. Irrelevant when `batch == 1`.
    pub linger_us: Option<u64>,
    /// Serve *disturbed* inferences (`serve --variation sigma=...`):
    /// both backends replay fresh identically seeded per-macro noise
    /// streams per request (fault-injection scenarios; see
    /// `robustness::replay` for the semantics).
    pub variation: Option<VariationParams>,
    /// Bounded request-queue capacity (`--queue-cap N`): submits beyond
    /// this depth shed with [`SubmitError::Overloaded`]. Must be >= 1.
    pub queue_cap: usize,
    /// Deterministic fault injection (`--chaos spec`): every worker's
    /// backend is wrapped in a [`ChaosBackend`] seeded per (worker,
    /// incarnation) from the plan.
    pub chaos: Option<FaultPlan>,
    /// Per-request attempt budget: first execution plus retries (after
    /// transient errors) and requeues (after worker panics / breaker
    /// trips). Exhausting it fails the request with a typed
    /// [`ServeError`]. Must be >= 1.
    pub max_attempts: u32,
    /// SLO targets (`--slo p99_ms=...,availability=...`): installs a
    /// rolling-window monitor on [`ServiceStats`] fed by every terminal
    /// request outcome. `None` = no monitoring.
    pub slo: Option<SloConfig>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            calibrate: false,
            macros: 1,
            batch: 1,
            linger_us: None,
            variation: None,
            queue_cap: DEFAULT_QUEUE_CAP,
            chaos: None,
            max_attempts: DEFAULT_MAX_ATTEMPTS,
            slo: None,
        }
    }
}

/// Sizes the micro-batch straggler window. With a fixed override it is
/// the classic `--linger-us` constant; otherwise it tracks an EWMA of
/// the gaps between consecutive requests' submit instants (as seen by
/// this worker — a subsample under multi-worker fleets, which only
/// biases the window *up*, toward more coalescing) and opens a window of
/// twice the mean gap, clamped to
/// `[ADAPTIVE_LINGER_MIN_US, ADAPTIVE_LINGER_MAX_US]`: a fast stream
/// coalesces full batches, a trickle gives up quickly instead of taxing
/// every request with a worst-case wait.
#[derive(Debug, Clone)]
pub struct LingerEstimator {
    fixed: Option<Duration>,
    ewma_us: Option<f64>,
}

/// Adaptive window floor: enough to catch a same-burst follow-up.
pub const ADAPTIVE_LINGER_MIN_US: u64 = 50;
/// Adaptive window ceiling: never worse than 5 ms of added latency.
pub const ADAPTIVE_LINGER_MAX_US: u64 = 5_000;
/// Window before the first gap has been observed (the old fixed default).
pub const ADAPTIVE_LINGER_DEFAULT_US: u64 = 500;
/// EWMA smoothing factor for the inter-arrival estimate.
const LINGER_EWMA_ALPHA: f64 = 0.3;

impl LingerEstimator {
    pub fn new(fixed_us: Option<u64>) -> Self {
        LingerEstimator { fixed: fixed_us.map(Duration::from_micros), ewma_us: None }
    }

    /// Feed one observed inter-arrival gap (µs between consecutive
    /// requests' submit instants).
    pub fn observe_gap_us(&mut self, gap_us: f64) {
        let gap = gap_us.max(0.0);
        self.ewma_us = Some(match self.ewma_us {
            Some(e) => (1.0 - LINGER_EWMA_ALPHA) * e + LINGER_EWMA_ALPHA * gap,
            None => gap,
        });
    }

    /// The straggler window to use for the next batch.
    pub fn window(&self) -> Duration {
        if let Some(d) = self.fixed {
            return d;
        }
        let us = match self.ewma_us {
            Some(e) => (2.0 * e) as u64,
            None => ADAPTIVE_LINGER_DEFAULT_US,
        };
        Duration::from_micros(us.clamp(ADAPTIVE_LINGER_MIN_US, ADAPTIVE_LINGER_MAX_US))
    }
}

/// One queued unit of work: the request, its enqueue instant (host
/// latency is measured from here, including across requeues), how many
/// execution attempts it has consumed, and where the answer goes.
struct Job {
    req: InferenceRequest,
    enqueued: Instant,
    attempts: u32,
    reply: mpsc::Sender<Result<InferenceResponse, ServeError>>,
}

/// Why a worker thread returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum WorkerExit {
    /// Queue closed and drained — normal shutdown.
    Shutdown,
    /// A batch panicked (jobs requeued); supervisor should respawn.
    Panicked,
    /// The circuit breaker tripped; respawn *degraded*.
    BreakerOpen,
}

/// Everything a worker thread needs besides its backend.
#[derive(Clone)]
struct WorkerContext {
    queue: Arc<BoundedQueue<Job>>,
    stats: Arc<ServiceStats>,
    batch_cap: usize,
    linger_fixed: Option<u64>,
    max_attempts: u32,
}

/// Builds (and rebuilds) worker backends: the initial fleet at start,
/// respawns after panics, and degraded respawns after breaker trips.
struct BackendFactory {
    program: Program,
    /// The one shared fast simulator (fast deployments); `None` = cycle.
    fast_shared: Option<Arc<FastSim>>,
    variation: Option<VariationParams>,
    chaos: Option<FaultPlan>,
    macros: usize,
    multi_worker: bool,
}

impl BackendFactory {
    fn build(
        &self,
        worker: usize,
        incarnation: u64,
        degraded: bool,
    ) -> Result<Box<dyn InferenceBackend>> {
        let inner: Box<dyn InferenceBackend> = if let Some(sim) = &self.fast_shared {
            if degraded && self.macros > 1 {
                // Graceful degradation: re-plan this worker's execution
                // over one fewer macro (logits are bit-identical for any
                // split — the shard parity contract — so only throughput
                // degrades). Snap calibration is deliberately dropped:
                // the survivor plan has different timing, so the
                // analytical estimate applies until recalibration.
                let survivors = ShardPlan::even(&self.program.plan, self.macros - 1)?;
                incident(IncidentKind::DegradedReplan, Some(worker), None, || {
                    format!("re-planned over {} of {} macros", self.macros - 1, self.macros)
                });
                let mut fresh = FastSim::new(self.program.clone(), DramConfig::default())?
                    .with_shard_plan(&survivors, false)?;
                if self.multi_worker {
                    fresh = fresh.with_batch_threads(1);
                }
                if let Some(v) = self.variation {
                    fresh = fresh.with_variation(v);
                }
                Box::new(FastBackend::shared(Arc::new(fresh)))
            } else {
                Box::new(FastBackend::shared(Arc::clone(sim)))
            }
        } else {
            // The cycle engine is the timing oracle, not the throughput
            // path: degraded respawns rebuild it at full capacity.
            let cb = CycleBackend::new(self.program.clone(), DramConfig::default())?;
            Box::new(match self.variation {
                Some(v) => cb.with_variation(v),
                None => cb,
            })
        };
        Ok(match self.chaos {
            Some(plan) if !plan.is_noop() => Box::new(ChaosBackend::with_seed(
                inner,
                plan,
                plan.worker_seed(worker, incarnation),
            )),
            _ => inner,
        })
    }
}

/// One worker's seat in the fleet, owned by the supervisor.
struct WorkerSlot {
    handle: Option<thread::JoinHandle<WorkerExit>>,
    incarnation: u64,
    needs_respawn: bool,
    not_before: Option<Instant>,
    degraded: bool,
}

/// The leader: owns the bounded queue, the worker fleet, and the
/// supervisor that keeps the fleet alive.
pub struct Coordinator {
    queue: Arc<BoundedQueue<Job>>,
    pub stats: Arc<ServiceStats>,
    shutdown: Arc<AtomicBool>,
    slots: Arc<Mutex<Vec<WorkerSlot>>>,
    supervisor: Option<thread::JoinHandle<()>>,
}

/// Record a terminal non-served lifecycle (shed/deadline/failed) so the
/// trace still shows what happened to the request.
fn record_terminal_span(
    stats: &ServiceStats,
    worker: usize,
    batch_size: usize,
    job: &Job,
    outcome: SpanOutcome,
    assembly_start: Instant,
    assembled: Instant,
    exec_start: Instant,
    exec_end: Instant,
) {
    if !telemetry::enabled() {
        return;
    }
    let enqueue_us = stats.spans.us_since_epoch(job.enqueued);
    let host_us = job.enqueued.elapsed().as_micros() as u64;
    stats.spans.record(RequestSpan {
        req_id: job.req.id,
        worker,
        batch_size,
        enqueue_us,
        assembly_start_us: stats.spans.us_since_epoch(assembly_start),
        assembled_us: stats.spans.us_since_epoch(assembled),
        exec_start_us: stats.spans.us_since_epoch(exec_start),
        exec_end_us: stats.spans.us_since_epoch(exec_end),
        respond_us: enqueue_us + host_us,
        shard_fires: Vec::new(),
        outcome,
    });
}

/// The worker loop: assemble a micro-batch, execute it under
/// `catch_unwind` with retry + breaker accounting, respond per job.
fn run_worker(
    wi: usize,
    incarnation: u64,
    mut be: Box<dyn InferenceBackend>,
    ctx: WorkerContext,
) -> WorkerExit {
    let bname = be.name();
    // Registry handles resolved once per worker; recording through them
    // is lock-free (and a no-op when telemetry is disabled).
    let telem = telemetry::global();
    let m_requests = telem.counter("serve.requests");
    let m_batches = telem.counter("serve.batches");
    let m_retries = telem.counter("serve.retries");
    let m_shed_deadline = telem.counter("serve.shed.deadline");
    let m_host = telem.histogram("serve.host_latency_us", Histogram::us_bounds());
    let m_exec = telem.histogram("serve.execute_us", Histogram::us_bounds());
    let g_linger = telem.gauge("serve.linger_window_us");
    let g_depth = telem.gauge("serve.queue_depth");
    let mut linger = LingerEstimator::new(ctx.linger_fixed);
    let mut last_submit: Option<Instant> = None;
    let mut breaker = CircuitBreaker::new(BREAKER_THRESHOLD);
    // Deterministic backoff jitter, decorrelated across incarnations.
    let mut backoff_rng = Rng::new(0xB0FF ^ ((wi as u64) << 32) ^ incarnation);
    loop {
        // Drain the queue into one coalesced micro-batch: block for the
        // first request, then keep popping until the cap is hit, the
        // linger window closes, or the queue goes quiet.
        let Some(first) = ctx.queue.pop_wait() else {
            return WorkerExit::Shutdown; // closed and drained
        };
        let mut jobs: Vec<Job> = Vec::with_capacity(ctx.batch_cap);
        jobs.push(first);
        // The assembly window opens when the first job lands here.
        let assembly_start = Instant::now();
        let assemble_region = telemetry::region("worker_assemble");
        let window_closes = assembly_start + linger.window();
        while jobs.len() < ctx.batch_cap {
            let now = Instant::now();
            if now >= window_closes {
                break;
            }
            match ctx.queue.pop_timeout(window_closes - now) {
                Some(job) => jobs.push(job),
                None => break,
            }
        }
        g_depth.set(ctx.queue.len() as f64);
        // Feed the adaptive linger policy with the arrival process
        // (submit instants, not drain instants, so the estimate is
        // independent of worker scheduling).
        for job in &jobs {
            if let Some(prev) = last_submit {
                let gap = job.enqueued.saturating_duration_since(prev);
                linger.observe_gap_us(gap.as_secs_f64() * 1e6);
            }
            last_submit = Some(job.enqueued);
        }
        let assembled = Instant::now();
        drop(assemble_region);
        g_linger.set(linger.window().as_secs_f64() * 1e6);
        // Dequeue-time deadline check: expired work is dropped here, not
        // computed — the whole point of carrying a deadline.
        let mut live: Vec<Job> = Vec::with_capacity(jobs.len());
        for job in jobs {
            match job.req.deadline {
                Some(dl) if assembled >= dl => {
                    ctx.stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
                    m_shed_deadline.inc();
                    record_terminal_span(
                        &ctx.stats,
                        wi,
                        0,
                        &job,
                        SpanOutcome::Deadline,
                        assembly_start,
                        assembled,
                        assembled,
                        assembled,
                    );
                    let waited_us = job.enqueued.elapsed().as_micros() as u64;
                    incident(IncidentKind::DeadlineMiss, Some(wi), Some(job.req.id), || {
                        format!("expired in queue after {waited_us}µs")
                    });
                    ctx.stats.slo_record(waited_us, false);
                    let _ = job.reply.send(Err(ServeError::DeadlineExceeded { waited_us }));
                }
                _ => live.push(job),
            }
        }
        let mut jobs = live;
        if jobs.is_empty() {
            continue;
        }
        ctx.stats.record_batch(jobs.len());
        m_batches.inc();
        // Execute with retry: transient errors back off and try again
        // (dropping jobs whose attempt budget is exhausted); a panic
        // requeues the batch and kills this worker; enough consecutive
        // faults trip the breaker either way.
        let mut batch_attempts: u32 = 0;
        let finished = loop {
            let exec_start = Instant::now();
            let result = {
                let audios: Vec<&[f32]> = jobs.iter().map(|j| j.req.audio.as_slice()).collect();
                catch_unwind(AssertUnwindSafe(|| {
                    let _r = telemetry::region("worker_execute");
                    be.run_batch(&audios)
                }))
            };
            let exec_end = Instant::now();
            m_exec.observe(exec_end.duration_since(exec_start).as_micros() as u64);
            match result {
                Err(_panic) => {
                    ctx.stats.worker_panics.fetch_add(1, Ordering::Relaxed);
                    let tripped = breaker.record_fault();
                    let spent = batch_attempts + 1;
                    for mut job in jobs {
                        job.attempts += spent;
                        if job.attempts >= ctx.max_attempts {
                            ctx.stats.failed.fetch_add(1, Ordering::Relaxed);
                            record_terminal_span(
                                &ctx.stats,
                                wi,
                                1,
                                &job,
                                SpanOutcome::Failed,
                                assembly_start,
                                assembled,
                                exec_start,
                                exec_end,
                            );
                            let attempts = job.attempts;
                            incident(IncidentKind::RequestFailed, Some(wi), Some(job.req.id), || {
                                format!("worker panic with attempt budget exhausted ({attempts})")
                            });
                            ctx.stats
                                .slo_record(job.enqueued.elapsed().as_micros() as u64, false);
                            let _ = job.reply.send(Err(ServeError::WorkerPanic { attempts }));
                        } else {
                            ctx.stats.requeues.fetch_add(1, Ordering::Relaxed);
                            if let Err(PushError::Closed(job) | PushError::Full(job)) =
                                ctx.queue.push_front(job)
                            {
                                let _ = job.reply.send(Err(ServeError::Shutdown));
                            }
                        }
                    }
                    return if tripped { WorkerExit::BreakerOpen } else { WorkerExit::Panicked };
                }
                Ok(Ok(runs)) if runs.len() == jobs.len() => {
                    breaker.record_success();
                    if batch_attempts > 0 {
                        incident(IncidentKind::BreakerReset, Some(wi), None, || {
                            format!("fault streak cleared after {batch_attempts} retry attempt(s)")
                        });
                    }
                    break Some((runs, exec_start, exec_end));
                }
                Ok(Ok(runs)) => {
                    // Contract violation, not a transient: fail typed.
                    let got = runs.len();
                    let want = jobs.len();
                    for job in jobs {
                        ctx.stats.failed.fetch_add(1, Ordering::Relaxed);
                        record_terminal_span(
                            &ctx.stats,
                            wi,
                            want,
                            &job,
                            SpanOutcome::Failed,
                            assembly_start,
                            assembled,
                            exec_start,
                            exec_end,
                        );
                        incident(IncidentKind::RequestFailed, Some(wi), Some(job.req.id), || {
                            format!("backend returned {got} results for a batch of {want}")
                        });
                        ctx.stats.slo_record(job.enqueued.elapsed().as_micros() as u64, false);
                        let _ = job.reply.send(Err(ServeError::Backend {
                            attempts: job.attempts + batch_attempts + 1,
                            message: format!(
                                "backend returned {got} results for a batch of {want}"
                            ),
                        }));
                    }
                    break None;
                }
                Ok(Err(e)) => {
                    batch_attempts += 1;
                    let tripped = breaker.record_fault();
                    if tripped {
                        // Hand the batch back and exit for a degraded
                        // respawn; jobs keep their attempt accounting.
                        for mut job in jobs {
                            job.attempts += batch_attempts;
                            if job.attempts >= ctx.max_attempts {
                                ctx.stats.failed.fetch_add(1, Ordering::Relaxed);
                                record_terminal_span(
                                    &ctx.stats,
                                    wi,
                                    1,
                                    &job,
                                    SpanOutcome::Failed,
                                    assembly_start,
                                    assembled,
                                    exec_start,
                                    exec_end,
                                );
                                let attempts = job.attempts;
                                incident(
                                    IncidentKind::RequestFailed,
                                    Some(wi),
                                    Some(job.req.id),
                                    || format!("breaker open, attempt budget exhausted ({attempts}): {e:#}"),
                                );
                                ctx.stats
                                    .slo_record(job.enqueued.elapsed().as_micros() as u64, false);
                                let _ = job.reply.send(Err(ServeError::Backend {
                                    attempts,
                                    message: format!("{e:#}"),
                                }));
                            } else {
                                ctx.stats.requeues.fetch_add(1, Ordering::Relaxed);
                                if let Err(PushError::Closed(job) | PushError::Full(job)) =
                                    ctx.queue.push_front(job)
                                {
                                    let _ = job.reply.send(Err(ServeError::Shutdown));
                                }
                            }
                        }
                        return WorkerExit::BreakerOpen;
                    }
                    // Fail jobs whose budget is spent; retry the rest.
                    let mut keep = Vec::with_capacity(jobs.len());
                    for job in jobs {
                        if job.attempts + batch_attempts >= ctx.max_attempts {
                            ctx.stats.failed.fetch_add(1, Ordering::Relaxed);
                            record_terminal_span(
                                &ctx.stats,
                                wi,
                                1,
                                &job,
                                SpanOutcome::Failed,
                                assembly_start,
                                assembled,
                                exec_start,
                                exec_end,
                            );
                            incident(IncidentKind::RequestFailed, Some(wi), Some(job.req.id), || {
                                format!(
                                    "attempt budget exhausted ({}): {e:#}",
                                    job.attempts + batch_attempts
                                )
                            });
                            ctx.stats.slo_record(job.enqueued.elapsed().as_micros() as u64, false);
                            let _ = job.reply.send(Err(ServeError::Backend {
                                attempts: job.attempts + batch_attempts,
                                message: format!("{e:#}"),
                            }));
                        } else {
                            keep.push(job);
                        }
                    }
                    jobs = keep;
                    if jobs.is_empty() {
                        break None;
                    }
                    ctx.stats.retries.fetch_add(1, Ordering::Relaxed);
                    m_retries.inc();
                    // Capped exponential backoff with deterministic
                    // jitter (up to +50%) before the next attempt.
                    let exp = batch_attempts.saturating_sub(1).min(6);
                    let base = (RETRY_BASE_US << exp).min(RETRY_MAX_US);
                    let jitter = backoff_rng.below(base / 2 + 1);
                    thread::sleep(Duration::from_micros(base + jitter));
                }
            }
        };
        let Some((runs, exec_start, exec_end)) = finished else {
            continue;
        };
        if telemetry::enabled() {
            if let Some(r) = runs.first() {
                ctx.stats.record_engine_sample(r);
            }
        }
        let batch_size = jobs.len();
        let _respond = telemetry::region("worker_respond");
        for (job, r) in jobs.iter().zip(&runs) {
            // Post-exec deadline check: the result exists but arrived
            // too late to matter — answer typed, don't pretend.
            if let Some(dl) = job.req.deadline {
                if exec_end >= dl {
                    ctx.stats.shed_deadline.fetch_add(1, Ordering::Relaxed);
                    m_shed_deadline.inc();
                    record_terminal_span(
                        &ctx.stats,
                        wi,
                        batch_size,
                        job,
                        SpanOutcome::Deadline,
                        assembly_start,
                        assembled,
                        exec_start,
                        exec_end,
                    );
                    let waited_us = job.enqueued.elapsed().as_micros() as u64;
                    incident(IncidentKind::DeadlineMiss, Some(wi), Some(job.req.id), || {
                        format!("computed but expired after {waited_us}µs")
                    });
                    ctx.stats.slo_record(waited_us, false);
                    let _ = job.reply.send(Err(ServeError::DeadlineExceeded { waited_us }));
                    continue;
                }
            }
            let host = job.enqueued.elapsed().as_secs_f64();
            let resp = InferenceResponse::from_run(job.req.id, r, job.req.label, host, bname);
            ctx.stats.served.fetch_add(1, Ordering::Relaxed);
            ctx.stats.chip_cycles.fetch_add(r.cycles, Ordering::Relaxed);
            ctx.stats.record_host_latency(host);
            ctx.stats.slo_record((host * 1e6) as u64, true);
            m_requests.inc();
            m_host.observe((host * 1e6) as u64);
            if telemetry::enabled() {
                let enqueue_us = ctx.stats.spans.us_since_epoch(job.enqueued);
                ctx.stats.spans.record(RequestSpan {
                    req_id: job.req.id,
                    worker: wi,
                    batch_size,
                    enqueue_us,
                    assembly_start_us: ctx.stats.spans.us_since_epoch(assembly_start),
                    assembled_us: ctx.stats.spans.us_since_epoch(assembled),
                    exec_start_us: ctx.stats.spans.us_since_epoch(exec_start),
                    exec_end_us: ctx.stats.spans.us_since_epoch(exec_end),
                    // Defined as enqueue + the host sample so span totals
                    // agree exactly with the percentiles.
                    respond_us: enqueue_us + (host * 1e6) as u64,
                    shard_fires: r.shard_fires.clone(),
                    outcome: if job.attempts + batch_attempts > 0 {
                        SpanOutcome::Retried
                    } else {
                        SpanOutcome::Ok
                    },
                });
            }
            for (shard, fires) in ctx.stats.shard_fires.iter().zip(&r.shard_fires) {
                shard.fetch_add(*fires, Ordering::Relaxed);
            }
            if let Some(c) = resp.correct {
                ctx.stats.labeled.fetch_add(1, Ordering::Relaxed);
                if c {
                    ctx.stats.correct.fetch_add(1, Ordering::Relaxed);
                }
            }
            let _ = job.reply.send(Ok(resp));
        }
    }
}

/// The supervisor loop: joins finished workers, classifies their exit,
/// and respawns them (degraded after a breaker trip) until shutdown.
fn supervise(
    slots: Arc<Mutex<Vec<WorkerSlot>>>,
    factory: Arc<BackendFactory>,
    ctx: WorkerContext,
    shutdown: Arc<AtomicBool>,
) {
    while !shutdown.load(Ordering::SeqCst) {
        {
            let mut slots = lock_or_recover(&slots);
            for (wi, slot) in slots.iter_mut().enumerate() {
                if slot.handle.as_ref().is_some_and(|h| h.is_finished()) {
                    let exit = slot
                        .handle
                        .take()
                        .and_then(|h| h.join().ok())
                        // A worker thread dying outside catch_unwind is a
                        // bug, but the supervisor treats it as a panic
                        // and respawns anyway.
                        .unwrap_or(WorkerExit::Panicked);
                    match exit {
                        WorkerExit::Shutdown => {}
                        WorkerExit::Panicked => {
                            slot.needs_respawn = true;
                            slot.not_before = Some(Instant::now() + PANIC_RESPAWN_COOLDOWN);
                        }
                        WorkerExit::BreakerOpen => {
                            ctx.stats.breaker_trips.fetch_add(1, Ordering::Relaxed);
                            incident(IncidentKind::BreakerTrip, Some(wi), None, || {
                                format!("{BREAKER_THRESHOLD} consecutive faults; degraded respawn scheduled")
                            });
                            slot.needs_respawn = true;
                            slot.degraded = true;
                            slot.not_before = Some(Instant::now() + BREAKER_COOLDOWN);
                        }
                    }
                }
                let cooled = slot.not_before.map_or(true, |t| Instant::now() >= t);
                if slot.needs_respawn && cooled && !shutdown.load(Ordering::SeqCst) {
                    slot.incarnation += 1;
                    match factory.build(wi, slot.incarnation, slot.degraded) {
                        Ok(be) => {
                            let wctx = ctx.clone();
                            let incarnation = slot.incarnation;
                            slot.handle = Some(thread::spawn(move || {
                                run_worker(wi, incarnation, be, wctx)
                            }));
                            slot.needs_respawn = false;
                            slot.not_before = None;
                            ctx.stats.respawns.fetch_add(1, Ordering::Relaxed);
                            let (incarnation, degraded) = (slot.incarnation, slot.degraded);
                            incident(IncidentKind::WorkerRespawn, Some(wi), None, || {
                                format!(
                                    "incarnation {incarnation}{}",
                                    if degraded { " (degraded)" } else { "" }
                                )
                            });
                        }
                        // Construction failed (transient resource issue):
                        // leave needs_respawn set and retry next tick.
                        Err(_) => {}
                    }
                }
            }
        }
        thread::sleep(SUPERVISOR_TICK);
    }
}

impl Coordinator {
    /// Spin up `n_workers` cycle-level workers for `model` at `opt`
    /// (the original single-engine entry point).
    pub fn start(model: &KwsModel, opt: OptLevel, n_workers: usize) -> Result<Self> {
        Self::start_with(model, opt, n_workers, BackendKind::Cycle)
    }

    /// Spin up `n_workers` workers, each owning one `kind` backend for
    /// the compiled program (`--backend {cycle,fast}` on the CLI).
    pub fn start_with(
        model: &KwsModel,
        opt: OptLevel,
        n_workers: usize,
        kind: BackendKind,
    ) -> Result<Self> {
        Self::start_with_options(model, opt, n_workers, kind, ServeOptions::default())
    }

    /// `start_with` plus [`ServeOptions`] (`--calibrate`, `--macros`,
    /// `--batch`, `--queue-cap`, `--chaos` on the CLI). Rejects
    /// degenerate deployments up front: zero workers, a zero micro-batch
    /// cap, a zero queue, or a zero attempt budget could never serve a
    /// request, so they are errors here rather than a silent hang.
    pub fn start_with_options(
        model: &KwsModel,
        opt: OptLevel,
        n_workers: usize,
        kind: BackendKind,
        opts: ServeOptions,
    ) -> Result<Self> {
        if n_workers == 0 {
            bail!("coordinator needs at least one worker (got --workers 0)");
        }
        if opts.batch == 0 {
            bail!("micro-batch cap must be >= 1 (got --batch 0; use 1 to disable batching)");
        }
        if opts.queue_cap == 0 {
            bail!("queue capacity must be >= 1 (got --queue-cap 0)");
        }
        if opts.max_attempts == 0 {
            bail!("attempt budget must be >= 1 (got --max-attempts 0)");
        }
        let program = build_kws_program_sharded(model, opt, opts.macros.max(1))?;
        // Build the shared fast simulator up front so construction errors
        // surface here with their real cause (not as a silent worker
        // exit). The functional simulator is stateless across requests
        // (`FastSim::infer` is `&self`): decode the image and run the
        // analytical walk once, then share the one instance across every
        // worker behind an `Arc`. The cycle SoC is stateful, so each
        // cycle worker gets its own instance from the factory.
        let fast_shared: Option<Arc<FastSim>> = match kind {
            BackendKind::Fast => {
                let mut sim = FastSim::new(program.clone(), DramConfig::default())?;
                if n_workers > 1 {
                    // The worker fleet is already the parallelism: keep
                    // each worker's batch on its own thread. A single
                    // worker gets the in-batch thread fan-out instead.
                    sim = sim.with_batch_threads(1);
                }
                if let Some(v) = opts.variation {
                    sim = sim.with_variation(v);
                }
                if opts.calibrate {
                    // One cycle-accurate run (any utterance: latency is
                    // data-independent — variation disturbs values, never
                    // timing, so the calibration SoC stays clean) snaps
                    // served latency/energy from analytical to exact.
                    let mut soc = Soc::new(program.clone(), DramConfig::default())?;
                    let silence = vec![0.0f32; model.audio_len];
                    let measured = soc.infer(&silence)?;
                    incident(IncidentKind::CalibrationSnap, None, None, || {
                        format!("fast-backend timing snapped to {} measured cycles", measured.cycles)
                    });
                    sim = sim.with_calibration(Calibration::from_run(&measured));
                }
                Some(Arc::new(sim))
            }
            BackendKind::Cycle => None,
        };
        let factory = Arc::new(BackendFactory {
            program,
            fast_shared,
            variation: opts.variation,
            chaos: opts.chaos,
            macros: opts.macros.max(1),
            multi_worker: n_workers > 1,
        });
        // Build every worker's initial backend before spawning anything
        // so a bad configuration fails the whole start.
        let mut backends = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            backends.push(factory.build(wi, 0, false)?);
        }
        let stats = Arc::new(ServiceStats::sized(opts.macros.max(1), opts.batch));
        if let Some(cfg) = opts.slo {
            stats.install_slo(cfg);
        }
        let queue = Arc::new(BoundedQueue::new(opts.queue_cap));
        let shutdown = Arc::new(AtomicBool::new(false));
        let ctx = WorkerContext {
            queue: Arc::clone(&queue),
            stats: Arc::clone(&stats),
            batch_cap: opts.batch,
            linger_fixed: opts.linger_us,
            max_attempts: opts.max_attempts,
        };
        let slots: Vec<WorkerSlot> = backends
            .into_iter()
            .enumerate()
            .map(|(wi, be)| {
                let ctx = ctx.clone();
                WorkerSlot {
                    handle: Some(thread::spawn(move || run_worker(wi, 0, be, ctx))),
                    incarnation: 0,
                    needs_respawn: false,
                    not_before: None,
                    degraded: false,
                }
            })
            .collect();
        let slots = Arc::new(Mutex::new(slots));
        let supervisor = {
            let slots = Arc::clone(&slots);
            let shutdown = Arc::clone(&shutdown);
            let ctx = ctx.clone();
            Some(thread::spawn(move || supervise(slots, factory, ctx, shutdown)))
        };
        Ok(Coordinator { queue, stats, shutdown, slots, supervisor })
    }

    /// Submit one request; returns a receiver for the (typed) response.
    /// Admission can refuse: [`SubmitError::Overloaded`] when the
    /// bounded queue is full (the request is shed immediately, never
    /// queued), [`SubmitError::Shutdown`] after shutdown.
    pub fn submit(
        &self,
        req: InferenceRequest,
    ) -> Result<mpsc::Receiver<Result<InferenceResponse, ServeError>>, SubmitError> {
        if self.shutdown.load(Ordering::SeqCst) {
            return Err(SubmitError::Shutdown);
        }
        let (rtx, rrx) = mpsc::channel();
        let now = Instant::now();
        let job = Job { req, enqueued: now, attempts: 0, reply: rtx };
        match self.queue.push(job) {
            Ok(()) => {
                if telemetry::enabled() {
                    telemetry::global().gauge("serve.queue_depth").set(self.queue.len() as f64);
                }
                Ok(rrx)
            }
            Err(PushError::Full(job)) => {
                self.stats.shed_overload.fetch_add(1, Ordering::Relaxed);
                self.stats.slo_record(0, false);
                if telemetry::enabled() {
                    telemetry::global().counter("serve.shed.overload").inc();
                    let depth = self.queue.len();
                    incident(IncidentKind::Shed, None, Some(job.req.id), || {
                        format!("queue full at depth {depth}")
                    });
                    let t = self.stats.spans.us_since_epoch(now);
                    self.stats.spans.record(RequestSpan {
                        req_id: job.req.id,
                        // Shed before any worker saw it.
                        worker: usize::MAX,
                        batch_size: 0,
                        enqueue_us: t,
                        assembly_start_us: t,
                        assembled_us: t,
                        exec_start_us: t,
                        exec_end_us: t,
                        respond_us: t,
                        shard_fires: Vec::new(),
                        outcome: SpanOutcome::Shed,
                    });
                }
                Err(SubmitError::Overloaded {
                    depth: self.queue.len(),
                    cap: self.queue.capacity(),
                })
            }
            Err(PushError::Closed(_)) => Err(SubmitError::Shutdown),
        }
    }

    /// Serve a whole batch, preserving order. An empty batch returns
    /// `Ok(vec![])` immediately without touching the worker queue (so it
    /// succeeds even after shutdown — there is nothing to serve).
    pub fn serve_batch(&self, reqs: Vec<InferenceRequest>) -> Result<Vec<InferenceResponse>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let rxs = reqs
            .into_iter()
            .map(|r| self.submit(r))
            .collect::<Result<Vec<_>, SubmitError>>()?;
        rxs.into_iter()
            .map(|rx| -> Result<InferenceResponse> {
                Ok(rx.recv().context("worker dropped")??)
            })
            .collect()
    }

    /// Measured accuracy over labeled requests so far.
    pub fn accuracy(&self) -> Option<f64> {
        let l = self.stats.labeled.load(Ordering::Relaxed);
        (l > 0).then(|| self.stats.correct.load(Ordering::Relaxed) as f64 / l as f64)
    }

    /// Current bounded-queue depth (admitted, not yet dequeued).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// How many workers are currently running degraded (reduced shard
    /// capacity after a breaker trip).
    pub fn degraded_workers(&self) -> usize {
        lock_or_recover(&self.slots).iter().filter(|s| s.degraded).count()
    }

    /// Shut down: stop admissions, fail everything still queued with an
    /// explicit [`ServeError::Shutdown`] (no caller is left holding a
    /// dead channel), then join the supervisor and workers. Admitted
    /// work a worker already holds still completes. Subsequent `submit`
    /// calls return [`SubmitError::Shutdown`].
    pub fn shutdown(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.queue.close();
        // Typed drain: every job still queued gets an explicit shutdown
        // answer instead of a bare RecvError. (Jobs a worker pops in the
        // close/drain race are served normally — also fine.)
        for job in self.queue.drain() {
            let _ = job.reply.send(Err(ServeError::Shutdown));
        }
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        let handles: Vec<_> = {
            let mut slots = lock_or_recover(&self.slots);
            slots.iter_mut().filter_map(|s| s.handle.take()).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        // Belt and braces: a worker that died right at the end may have
        // requeued jobs after the first drain.
        for job in self.queue.drain() {
            let _ = job.reply.send(Err(ServeError::Shutdown));
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kws::LayerSpec;

    fn fake_model() -> KwsModel {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(99);
        let mut mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
            thresholds: if binarized { vec![0; co] } else { vec![] },
        };
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 1,
            layers: vec![mk(64, 32, true, true), mk(32, 12, false, false)],
            bn_gamma: vec![1.0; 64],
            bn_beta: vec![0.0; 64],
            bn_mean: vec![20000.0; 64],
            bn_var: vec![4e8; 64],
            pre_thr: vec![20000; 64],
            pre_dir: vec![1; 64],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    #[test]
    fn serves_batches_in_order_across_workers() {
        let m = fake_model();
        let mut coord = Coordinator::start(&m, OptLevel::FULL, 3).unwrap();
        let reqs: Vec<_> = (0..9)
            .map(|i| InferenceRequest {
                id: i,
                audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                label: None,
                deadline: None,
            })
            .collect();
        let resps = coord.serve_batch(reqs).unwrap();
        assert_eq!(resps.len(), 9);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.chip_cycles > 0);
            assert!(r.energy_uj > 0.0);
        }
        assert_eq!(coord.stats.served.load(Ordering::Relaxed), 9);
        coord.shutdown();
    }

    #[test]
    fn responses_deterministic_across_workers() {
        // The same utterance must classify identically on every worker.
        let m = fake_model();
        let mut coord = Coordinator::start(&m, OptLevel::FULL, 4).unwrap();
        let audio = crate::model::dataset::synth_utterance(5, 1, 16000, 0.3);
        let reqs: Vec<_> = (0..8)
            .map(|i| InferenceRequest { id: i, audio: audio.clone(), label: None, deadline: None })
            .collect();
        let resps = coord.serve_batch(reqs).unwrap();
        for r in &resps[1..] {
            assert_eq!(r.logits, resps[0].logits);
            assert_eq!(r.chip_cycles, resps[0].chip_cycles);
        }
        coord.shutdown();
    }

    #[test]
    fn fast_backend_serves_identical_logits() {
        // The same requests through cycle and fast coordinators must
        // yield bit-identical logits (the backend parity contract).
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(
                        i as usize % 12,
                        i,
                        16000,
                        0.3,
                    ),
                    label: None,
                    deadline: None,
                })
                .collect()
        };
        let mut cyc = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Cycle).unwrap();
        let a = cyc.serve_batch(reqs(4)).unwrap();
        cyc.shutdown();
        let mut fast = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let b = fast.serve_batch(reqs(4)).unwrap();
        fast.shutdown();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
            assert_eq!(x.predicted, y.predicted);
        }
        assert!(a.iter().all(|r| r.backend == "cycle"));
        assert!(b.iter().all(|r| r.backend == "fast"));
    }

    #[test]
    fn submit_after_shutdown_errors_instead_of_panicking() {
        let m = fake_model();
        let mut coord =
            Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let req = |id| InferenceRequest {
            id,
            audio: crate::model::dataset::synth_utterance(1, 2, 16000, 0.3),
            label: None,
            deadline: None,
        };
        let rx = coord.submit(req(0)).unwrap();
        assert!(rx.recv().unwrap().is_ok());
        coord.shutdown();
        let err = coord.submit(req(1)).unwrap_err();
        assert_eq!(err, SubmitError::Shutdown);
        assert!(err.to_string().contains("shut down"), "{err}");
        assert!(coord.serve_batch(vec![req(2)]).is_err());
    }

    #[test]
    fn calibrated_fast_serving_is_cycle_exact() {
        // --calibrate at coordinator start: served latency/energy snaps
        // to the cycle simulator's numbers while logits stay identical.
        let m = fake_model();
        let audio = crate::model::dataset::synth_utterance(4, 11, 16000, 0.3);
        let req = || {
            vec![InferenceRequest { id: 0, audio: audio.clone(), label: None, deadline: None }]
        };
        let mut cyc = Coordinator::start_with(&m, OptLevel::FULL, 1, BackendKind::Cycle).unwrap();
        let want = cyc.serve_batch(req()).unwrap();
        cyc.shutdown();
        let mut fast = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            3,
            BackendKind::Fast,
            ServeOptions { calibrate: true, ..Default::default() },
        )
        .unwrap();
        let got = fast.serve_batch(req()).unwrap();
        fast.shutdown();
        assert_eq!(got[0].logits, want[0].logits);
        assert_eq!(got[0].chip_cycles, want[0].chip_cycles, "snap calibration must be exact");
        assert!((got[0].energy_uj - want[0].energy_uj).abs() < 1e-9);
        assert_eq!(got[0].backend, "fast");
    }

    #[test]
    fn empty_batch_returns_ok_without_round_trip() {
        let m = fake_model();
        let mut coord = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        assert!(coord.serve_batch(vec![]).unwrap().is_empty());
        assert_eq!(coord.stats.served.load(Ordering::Relaxed), 0, "no worker round trip");
        coord.shutdown();
        // Even after shutdown: nothing to serve, so still Ok.
        assert!(coord.serve_batch(vec![]).unwrap().is_empty());
    }

    #[test]
    fn sharded_serving_identical_logits_and_per_shard_utilization() {
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                    deadline: None,
                })
                .collect()
        };
        let mut single =
            Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let want = single.serve_batch(reqs(4)).unwrap();
        single.shutdown();

        let mut sharded = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { macros: 2, ..Default::default() },
        )
        .unwrap();
        let got = sharded.serve_batch(reqs(4)).unwrap();
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
        }
        // Per-shard utilization accumulated across every request; the
        // fake model's 32- and 12-wide layers fit one latch word, so the
        // word-aligned split leaves macro 1 idle — visible in the stats.
        assert_eq!(sharded.stats.shard_fires.len(), 2);
        let f0 = sharded.stats.shard_fires[0].load(Ordering::Relaxed);
        let f1 = sharded.stats.shard_fires[1].load(Ordering::Relaxed);
        assert!(f0 > 0);
        assert!(f0 > f1, "macro 0 owns every layer's leading channels: {f0} vs {f1}");
        sharded.shutdown();
    }

    #[test]
    fn rejects_zero_workers_and_zero_batch() {
        let m = fake_model();
        let err = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            0,
            BackendKind::Fast,
            ServeOptions::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("at least one worker"), "{err}");
        let err = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { batch: 0, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("--batch 0"), "{err}");
        let err = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { queue_cap: 0, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("--queue-cap 0"), "{err}");
        let err = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { max_attempts: 0, ..Default::default() },
        )
        .unwrap_err();
        assert!(err.to_string().contains("--max-attempts 0"), "{err}");
    }

    #[test]
    fn micro_batched_serving_identical_logits_and_stats() {
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: Some((i % 12) as i32),
                    deadline: None,
                })
                .collect()
        };
        let mut plain = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let want = plain.serve_batch(reqs(9)).unwrap();
        plain.shutdown();

        // One worker + a generous linger forces real coalescing: 9
        // requests cannot be served as 9 singleton batches.
        let mut micro = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            1,
            BackendKind::Fast,
            ServeOptions { batch: 4, linger_us: Some(50_000), ..Default::default() },
        )
        .unwrap();
        let got = micro.serve_batch(reqs(9)).unwrap();
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
            assert_eq!(x.predicted, y.predicted);
        }
        assert_eq!(micro.stats.served.load(Ordering::Relaxed), 9);
        // Histogram: sized to the cap, everything accounted, and at
        // least one multi-request batch actually formed.
        assert_eq!(micro.stats.batch_sizes.len(), 4);
        let hist: Vec<u64> =
            micro.stats.batch_sizes.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        let total_reqs: u64 =
            hist.iter().enumerate().map(|(b, n)| (b as u64 + 1) * n).sum();
        assert_eq!(total_reqs, 9, "histogram accounts for every request: {hist:?}");
        assert!(hist[1..].iter().sum::<u64>() > 0, "no multi-request batch formed: {hist:?}");
        // Latency percentiles exist and are ordered.
        let [p50, p95, p99] = micro.stats.host_latency_percentiles().unwrap();
        assert!(p50 > 0.0 && p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        micro.shutdown();
        assert!(micro.accuracy().is_some());
    }

    #[test]
    fn linger_estimator_adapts_and_clamps() {
        // Fixed override wins unconditionally.
        let fixed = LingerEstimator::new(Some(1234));
        assert_eq!(fixed.window(), Duration::from_micros(1234));
        // Before any observation: the default window.
        let mut e = LingerEstimator::new(None);
        assert_eq!(e.window(), Duration::from_micros(ADAPTIVE_LINGER_DEFAULT_US));
        // A steady 200 µs stream converges to a ~400 µs window (2x gap).
        for _ in 0..50 {
            e.observe_gap_us(200.0);
        }
        let w = e.window().as_micros() as u64;
        assert!((395..=405).contains(&w), "window {w} µs for a 200 µs stream");
        // A trickle clamps at the ceiling instead of growing unbounded...
        for _ in 0..50 {
            e.observe_gap_us(1_000_000.0);
        }
        assert_eq!(e.window(), Duration::from_micros(ADAPTIVE_LINGER_MAX_US));
        // ...and a flood clamps at the floor.
        for _ in 0..200 {
            e.observe_gap_us(0.0);
        }
        assert_eq!(e.window(), Duration::from_micros(ADAPTIVE_LINGER_MIN_US));
        // The fixed override ignores observations entirely.
        let mut f = LingerEstimator::new(Some(777));
        f.observe_gap_us(0.0);
        assert_eq!(f.window(), Duration::from_micros(777));
    }

    #[test]
    fn adaptive_linger_serving_matches_fixed_linger_bits() {
        // The linger policy decides how batches coalesce, never what they
        // compute: default (adaptive) serving must produce the same
        // logits as a fixed-linger deployment, and still form real
        // multi-request batches under a burst.
        let m = fake_model();
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                    deadline: None,
                })
                .collect()
        };
        let mut fixed = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            1,
            BackendKind::Fast,
            ServeOptions { batch: 4, linger_us: Some(50_000), ..Default::default() },
        )
        .unwrap();
        let want = fixed.serve_batch(reqs(8)).unwrap();
        fixed.shutdown();

        let mut adaptive = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            1,
            BackendKind::Fast,
            ServeOptions { batch: 4, ..Default::default() }, // linger_us: None
        )
        .unwrap();
        let got = adaptive.serve_batch(reqs(8)).unwrap();
        for (x, y) in want.iter().zip(&got) {
            assert_eq!(x.logits, y.logits, "request {}", x.id);
            assert_eq!(x.predicted, y.predicted);
        }
        assert_eq!(adaptive.stats.served.load(Ordering::Relaxed), 8);
        let hist: Vec<u64> =
            adaptive.stats.batch_sizes.iter().map(|a| a.load(Ordering::Relaxed)).collect();
        assert!(
            hist[1..].iter().sum::<u64>() > 0,
            "adaptive linger formed no multi-request batch under a burst: {hist:?}"
        );
        adaptive.shutdown();
    }

    #[test]
    fn variation_serving_is_disturbed_and_backend_agnostic() {
        // serve --variation: both engines replay fresh identically
        // seeded per-request noise streams, so a disturbed request
        // classifies identically on the fast and cycle backends — and
        // differently from clean serving.
        let m = fake_model();
        let variation = Some(VariationParams {
            sigma: 0.5,
            nl_alpha: 0.3,
            symmetric: false,
            ..Default::default()
        });
        let reqs = |n: u64| -> Vec<InferenceRequest> {
            (0..n)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                    deadline: None,
                })
                .collect()
        };
        let mut clean = Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
        let base = clean.serve_batch(reqs(3)).unwrap();
        clean.shutdown();

        let mut fast = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Fast,
            ServeOptions { variation, ..Default::default() },
        )
        .unwrap();
        let f = fast.serve_batch(reqs(3)).unwrap();
        fast.shutdown();
        assert!(
            f.iter().zip(&base).any(|(a, b)| a.logits != b.logits),
            "sigma 0.5 single-ended serving must disturb logits"
        );

        let mut cyc = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            2,
            BackendKind::Cycle,
            ServeOptions { variation, ..Default::default() },
        )
        .unwrap();
        let c = cyc.serve_batch(reqs(3)).unwrap();
        cyc.shutdown();
        for (x, y) in f.iter().zip(&c) {
            assert_eq!(x.logits, y.logits, "disturbed request {} diverged across engines", x.id);
        }
    }

    #[test]
    fn batch_histogram_saturates_last_bucket() {
        let s = ServiceStats::sized(1, 2);
        s.record_batch(1);
        s.record_batch(2);
        s.record_batch(7); // beyond the cap -> last bucket
        assert_eq!(s.batch_sizes[0].load(Ordering::Relaxed), 1);
        assert_eq!(s.batch_sizes[1].load(Ordering::Relaxed), 2);
        // Degenerate blocks don't panic.
        ServiceStats::default().record_batch(3);
        assert!(ServiceStats::default().host_latency_percentiles().is_none());
    }

    #[test]
    fn spans_record_when_telemetry_enabled_and_match_host_samples() {
        crate::telemetry::with_telemetry(|| {
            let m = fake_model();
            let mut coord =
                Coordinator::start_with(&m, OptLevel::FULL, 2, BackendKind::Fast).unwrap();
            let reqs: Vec<_> = (0..5)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                    deadline: None,
                })
                .collect();
            let _ = coord.serve_batch(reqs).unwrap();
            coord.shutdown();
            let spans = coord.stats.spans.snapshot();
            assert_eq!(spans.len(), 5);
            for s in &spans {
                assert!(s.assembled_us >= s.assembly_start_us, "{s:?}");
                assert!(s.exec_end_us >= s.exec_start_us, "{s:?}");
                assert!(s.respond_us >= s.enqueue_us, "{s:?}");
                assert!(!s.shard_fires.is_empty());
                assert!(s.batch_size >= 1);
                assert_eq!(s.outcome, SpanOutcome::Ok, "clean serving: {s:?}");
            }
            // Span-derived percentiles agree *exactly* with the host
            // samples (same numbers, not re-measured).
            assert_eq!(
                coord.stats.span_latency_percentiles().unwrap(),
                coord.stats.host_latency_percentiles().unwrap()
            );
            // The engine timeline was sampled for the trace exporter.
            let (markers, cycles) = coord.stats.engine_sample().unwrap();
            assert!(!markers.is_empty());
            assert!(cycles > 0);

            // Telemetry off (still inside the guard, so no parallel
            // test can re-enable it): serving records no spans.
            crate::telemetry::set_enabled(false);
            let mut coord =
                Coordinator::start_with(&m, OptLevel::FULL, 1, BackendKind::Fast).unwrap();
            let req = InferenceRequest {
                id: 0,
                audio: crate::model::dataset::synth_utterance(0, 1, 16000, 0.3),
                label: None,
                deadline: None,
            };
            let _ = coord.serve_batch(vec![req]).unwrap();
            coord.shutdown();
            assert!(coord.stats.spans.is_empty());
            assert!(coord.stats.engine_sample().is_none());
            assert!(coord.stats.span_latency_percentiles().is_none());
        });
    }

    #[test]
    fn accuracy_accounting() {
        let m = fake_model();
        let mut coord = Coordinator::start(&m, OptLevel::FULL, 2).unwrap();
        let reqs: Vec<_> = (0..4)
            .map(|i| InferenceRequest {
                id: i,
                audio: crate::model::dataset::synth_utterance(0, i, 16000, 0.3),
                label: Some(0),
                deadline: None,
            })
            .collect();
        let _ = coord.serve_batch(reqs).unwrap();
        assert_eq!(coord.stats.labeled.load(Ordering::Relaxed), 4);
        assert!(coord.accuracy().is_some());
        coord.shutdown();
    }

    #[test]
    fn shutdown_drains_pending_requests_with_typed_error() {
        // Regression (satellite): shutdown used to drop queued requests'
        // reply channels, leaving callers with a bare RecvError. Now the
        // drain answers each with ServeError::Shutdown. A stalled worker
        // (100% stall chaos, long stall) pins the queue so requests are
        // still pending when shutdown runs.
        let m = fake_model();
        let chaos = FaultPlan { stall: 1.0, stall_ms: 300, ..Default::default() };
        let mut coord = Coordinator::start_with_options(
            &m,
            OptLevel::FULL,
            1,
            BackendKind::Fast,
            ServeOptions { chaos: Some(chaos), linger_us: Some(0), ..Default::default() },
        )
        .unwrap();
        let req = |id| InferenceRequest {
            id,
            audio: crate::model::dataset::synth_utterance(1, 2, 16000, 0.3),
            label: None,
            deadline: None,
        };
        // First request occupies the (stalled) worker; the rest queue up.
        let rx0 = coord.submit(req(0)).unwrap();
        thread::sleep(Duration::from_millis(30));
        let pending: Vec<_> = (1..4).map(|i| coord.submit(req(i)).unwrap()).collect();
        coord.shutdown();
        // The in-flight request finishes (admitted work completes)...
        assert!(rx0.recv().unwrap().is_ok(), "in-flight request must still be served");
        // ...and every queued request gets a typed Shutdown, not a hang
        // or a dead channel.
        for rx in pending {
            match rx.recv().expect("reply channel must not be dropped") {
                Err(ServeError::Shutdown) => {}
                other => panic!("expected ServeError::Shutdown, got {other:?}"),
            }
        }
    }

    #[test]
    fn slo_monitor_tracks_served_requests_when_configured() {
        crate::telemetry::with_telemetry(|| {
            let m = fake_model();
            // Generous targets: clean serving must be compliant.
            let slo = SloConfig::parse_spec("p99_ms=60000,availability=0.5").unwrap();
            let mut coord = Coordinator::start_with_options(
                &m,
                OptLevel::FULL,
                2,
                BackendKind::Fast,
                ServeOptions { slo: Some(slo), ..Default::default() },
            )
            .unwrap();
            let reqs: Vec<_> = (0..6)
                .map(|i| InferenceRequest {
                    id: i,
                    audio: crate::model::dataset::synth_utterance(i as usize % 12, i, 16000, 0.3),
                    label: None,
                    deadline: None,
                })
                .collect();
            let _ = coord.serve_batch(reqs).unwrap();
            coord.shutdown();
            let rep = coord.stats.slo_report().expect("--slo installs the monitor");
            assert_eq!(rep.seen, 6);
            assert_eq!(rep.window_n, 6);
            assert_eq!(rep.availability, Some(1.0), "clean serving: every outcome served");
            assert!(rep.p99_us.is_some(), "served latencies feed the p99 window");
            assert!(rep.burn_rate.is_some(), "availability target < 1 defines a budget");
            assert!(rep.compliant(), "{}", rep.render());
            // The report mirrors into the registry gauges.
            let reg = crate::telemetry::global();
            assert_eq!(reg.gauge("slo.availability").get(), 1.0);
            assert!(reg.gauge("slo.p99_us").get() >= 1.0, "p99 gauge mirrors µs");

            // No --slo: no monitor, no report.
            let mut plain =
                Coordinator::start_with(&m, OptLevel::FULL, 1, BackendKind::Fast).unwrap();
            let req = InferenceRequest {
                id: 0,
                audio: crate::model::dataset::synth_utterance(0, 1, 16000, 0.3),
                label: None,
                deadline: None,
            };
            let _ = plain.serve_batch(vec![req]).unwrap();
            plain.shutdown();
            assert!(plain.stats.slo_report().is_none());
        });
    }
}
