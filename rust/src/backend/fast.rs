//! The functional simulator behind the backend contract.

use std::sync::Arc;

use anyhow::Result;

use crate::compiler::Program;
use crate::fsim::{Calibration, FastSim};
use crate::mem::dram::DramConfig;
use crate::sim::RunResult;
use crate::telemetry::{self, Histogram};

use super::InferenceBackend;

/// Tensor-level engine: bit-identical logits, analytical latency/energy
/// (optionally snap-calibrated from one cycle-level run).
///
/// Holds the simulator behind an `Arc`: `FastSim::infer` is `&self` and
/// stateless, so a whole worker fleet shares one decoded program + one
/// analytical walk instead of cloning them per thread
/// (`Coordinator::start_with_options` does exactly that).
pub struct FastBackend {
    sim: Arc<FastSim>,
}

impl FastBackend {
    pub fn new(program: Program, dram_cfg: DramConfig) -> Result<Self> {
        Ok(FastBackend { sim: Arc::new(FastSim::new(program, dram_cfg)?) })
    }

    /// Share an already-built simulator across workers: the decode and
    /// the analytical walk exist once per program, not once per thread.
    pub fn shared(sim: Arc<FastSim>) -> Self {
        FastBackend { sim }
    }

    /// Replace the analytical latency/energy numbers with exact ones
    /// measured on the cycle simulator (valid for all inputs: the
    /// compiled program's latency is data-independent). Rebuilds the
    /// shared handle, so calibrate *before* fanning out to workers.
    pub fn with_calibration(self, c: Calibration) -> Self {
        let sim = (*self.sim).clone().with_calibration(c);
        FastBackend { sim: Arc::new(sim) }
    }

    /// Serve disturbed inferences (`serve --variation`): every request
    /// replays the macro bank's variation fire sequence with fresh
    /// per-macro streams (`FastSim::with_variation`). Rebuilds the shared
    /// handle, so configure *before* fanning out to workers.
    pub fn with_variation(self, v: crate::robustness::VariationParams) -> Self {
        let sim = (*self.sim).clone().with_variation(v);
        FastBackend { sim: Arc::new(sim) }
    }

    pub fn sim(&self) -> &FastSim {
        self.sim.as_ref()
    }
}

impl InferenceBackend for FastBackend {
    fn name(&self) -> &'static str {
        "fast"
    }

    /// Real batch execution: `FastSim::infer_batch` walks each layer's
    /// weight planes once for the whole batch (and fans large batches
    /// out across threads) — this is the throughput path the
    /// micro-batching coordinator and the benches drive.
    fn run_batch(&mut self, batch: &[&[f32]]) -> Result<Vec<RunResult>> {
        // Global-off fast path: one relaxed load, then exactly the
        // untelemetered call (the `telemetry_overhead` bench holds this
        // to ≤1% vs calling `infer_batch` directly).
        if !telemetry::enabled() {
            return Ok(self.sim.infer_batch(batch));
        }
        let telem = telemetry::global();
        let t0 = std::time::Instant::now();
        let runs = {
            let _r = telemetry::region("backend_fast_run");
            self.sim.infer_batch(batch)
        };
        telem
            .histogram("backend.fast.execute_us", Histogram::fine_us_bounds())
            .observe(t0.elapsed().as_micros() as u64);
        telem.counter("backend.fast.batches").inc();
        telem.counter("backend.fast.inferences").add(runs.len() as u64);
        Ok(runs)
    }

    fn program(&self) -> &Program {
        self.sim.program()
    }
}
