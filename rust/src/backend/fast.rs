//! The functional simulator behind the backend contract.

use anyhow::Result;

use crate::compiler::Program;
use crate::fsim::{Calibration, FastSim};
use crate::mem::dram::DramConfig;
use crate::sim::RunResult;

use super::InferenceBackend;

/// Tensor-level engine: bit-identical logits, analytical latency/energy
/// (optionally snap-calibrated from one cycle-level run).
pub struct FastBackend {
    sim: FastSim,
}

impl FastBackend {
    pub fn new(program: Program, dram_cfg: DramConfig) -> Result<Self> {
        Ok(FastBackend { sim: FastSim::new(program, dram_cfg)? })
    }

    /// Wrap an already-built simulator (the decode + analytical walk are
    /// immutable, so one `FastSim` can be cloned across workers instead
    /// of re-deriving it per thread).
    pub fn from_sim(sim: FastSim) -> Self {
        FastBackend { sim }
    }

    /// Replace the analytical latency/energy numbers with exact ones
    /// measured on the cycle simulator (valid for all inputs: the
    /// compiled program's latency is data-independent).
    pub fn with_calibration(mut self, c: Calibration) -> Self {
        self.sim = self.sim.with_calibration(c);
        self
    }

    pub fn sim(&self) -> &FastSim {
        &self.sim
    }
}

impl InferenceBackend for FastBackend {
    fn name(&self) -> &'static str {
        "fast"
    }

    fn run(&mut self, audio: &[f32]) -> Result<RunResult> {
        Ok(self.sim.infer(audio))
    }

    fn program(&self) -> &Program {
        self.sim.program()
    }
}
