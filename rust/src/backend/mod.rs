//! Pluggable inference backends: one request contract, interchangeable
//! execution engines.
//!
//! Everything that serves inferences — the coordinator's workers, the CLI
//! `run`/`serve` subcommands, the throughput benches — goes through
//! [`InferenceBackend`], so engines can be swapped per deployment:
//!
//! * [`CycleBackend`] — the cycle-level [`crate::sim::Soc`]: exact
//!   timing/energy, ~ms of host time per inference. The ground truth.
//! * [`FastBackend`]  — the functional simulator [`crate::fsim::FastSim`]:
//!   bit-identical logits, analytical (or snap-calibrated) timing, orders
//!   of magnitude more inferences/sec (`benches/backend_throughput.rs`).
//!
//! ## The batch seam
//!
//! The contract is **batch-first**: [`InferenceBackend::run_batch`] takes
//! a whole slice of utterances and is the required method;
//! [`InferenceBackend::run`] is the provided 1-element convenience. CIMR-V
//! amortizes data movement by keeping weights resident while activations
//! stream past, and batching is the serving-side realization of the same
//! idea — so the fast backend pushes real batch execution down every
//! layer (each `PackedLayer`'s weight planes are walked once per batch,
//! utterances innermost, optionally fanned out over threads in chunks),
//! while the cycle backend simply loops: it is the timing oracle, not the
//! throughput path, and the simulated chip serves utterances back to
//! back either way. Per-element results are bit-identical between
//! `run_batch` and N sequential `run` calls on both engines
//! (`rust/tests/batch_parity.rs`), and chip-side cycles/energy are
//! per-inference numbers unchanged by batching.
//!
//! ## The shard seam
//!
//! Multi-macro sharding threads through this boundary *in the program
//! image*, not the trait: `compiler::build_kws_program_sharded(model,
//! opt, n_macros)` stamps a [`crate::dataflow::shard::ShardPlan`] into
//! `Program::shards`, and each backend honors it natively — the SoC sizes
//! its macro bank and executes the interleaved fire sequences the sharded
//! codegen emits; `FastSim` pre-slices per-macro `PackedLayer` groups and
//! concatenates channel ranges (optionally on one thread per macro).
//! Batches compose with shards: each macro's channel slice carries the
//! whole batch before the per-utterance merge. Every `RunResult` carries
//! `shard_fires` (per-macro utilization), which the coordinator
//! aggregates into `ServiceStats::shard_fires`. Sharded and unsharded
//! logits are bit-identical by construction — enforced by
//! `rust/tests/shard_parity.rs`.
//!
//! Remaining scaling work on this seam: remote workers (same trait, same
//! batched contract).

pub mod cycle;
pub mod fast;

pub use cycle::CycleBackend;
pub use fast::FastBackend;

use std::fmt;

use anyhow::{bail, ensure, Result};

use crate::compiler::Program;
use crate::mem::dram::DramConfig;
use crate::sim::RunResult;

/// A loaded inference engine for one compiled program.
pub trait InferenceBackend: Send {
    /// Stable engine name (reports, response attribution).
    fn name(&self) -> &'static str;

    /// Run a batch of utterances end-to-end: audio slices in, one
    /// logits + latency/energy record per utterance out, order
    /// preserved (`result.len() == batch.len()`; an empty batch is
    /// `Ok(vec![])`). Implementations must produce logits bit-identical
    /// to the cycle-level SoC for the same program, element for element,
    /// regardless of how the batch is grouped.
    fn run_batch(&mut self, batch: &[&[f32]]) -> Result<Vec<RunResult>>;

    /// One utterance: the 1-element convenience over [`Self::run_batch`].
    fn run(&mut self, audio: &[f32]) -> Result<RunResult> {
        let mut out = self.run_batch(&[audio])?;
        ensure!(out.len() == 1, "run_batch returned {} results for 1 input", out.len());
        Ok(out.pop().unwrap())
    }

    /// The program image this backend serves.
    fn program(&self) -> &Program;
}

/// Which engine to construct (`--backend` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Cycle,
    Fast,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "cycle" | "iss" | "soc" => BackendKind::Cycle,
            "fast" | "fsim" | "functional" => BackendKind::Fast,
            _ => bail!("unknown backend {s:?} (cycle|fast)"),
        })
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BackendKind::Cycle => "cycle",
            BackendKind::Fast => "fast",
        })
    }
}

/// Construct a backend of `kind` for a compiled program.
pub fn build(
    kind: BackendKind,
    program: Program,
    dram_cfg: DramConfig,
) -> Result<Box<dyn InferenceBackend>> {
    let backend: Box<dyn InferenceBackend> = match kind {
        BackendKind::Cycle => Box::new(CycleBackend::new(program, dram_cfg)?),
        BackendKind::Fast => Box::new(FastBackend::new(program, dram_cfg)?),
    };
    Ok(backend)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_and_display() {
        assert_eq!(BackendKind::parse("cycle").unwrap(), BackendKind::Cycle);
        assert_eq!(BackendKind::parse("fast").unwrap(), BackendKind::Fast);
        assert_eq!(BackendKind::parse("fsim").unwrap(), BackendKind::Fast);
        assert!(BackendKind::parse("quantum").is_err());
        assert_eq!(BackendKind::Cycle.to_string(), "cycle");
        assert_eq!(BackendKind::Fast.to_string(), "fast");
    }
}
