//! The cycle-level SoC behind the backend contract.

use anyhow::Result;

use crate::compiler::Program;
use crate::mem::dram::DramConfig;
use crate::sim::{RunResult, Soc};

use super::InferenceBackend;

/// Adapter: one [`Soc`] instance serving requests serially (the chip is
/// single-tenant; parallelism comes from running one backend per worker).
pub struct CycleBackend {
    soc: Soc,
}

impl CycleBackend {
    pub fn new(program: Program, dram_cfg: DramConfig) -> Result<Self> {
        Ok(CycleBackend { soc: Soc::new(program, dram_cfg)? })
    }

    /// Direct access for callers that need SoC-only features (variation
    /// injection, tracing).
    pub fn soc_mut(&mut self) -> &mut Soc {
        &mut self.soc
    }
}

impl InferenceBackend for CycleBackend {
    fn name(&self) -> &'static str {
        "cycle"
    }

    /// The chip is single-tenant and exact: a batch is served as a plain
    /// internal loop (no host-side amortization to model — the cycle
    /// engine is the timing oracle, not the throughput path), which also
    /// makes batched-vs-sequential parity trivially structural here.
    fn run_batch(&mut self, batch: &[&[f32]]) -> Result<Vec<RunResult>> {
        batch.iter().map(|audio| self.soc.infer(audio)).collect()
    }

    fn program(&self) -> &Program {
        self.soc.program()
    }
}
