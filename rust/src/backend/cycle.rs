//! The cycle-level SoC behind the backend contract.

use anyhow::Result;

use crate::compiler::Program;
use crate::mem::dram::DramConfig;
use crate::robustness::VariationParams;
use crate::sim::{RunResult, Soc};
use crate::telemetry::{self, Histogram};

use super::InferenceBackend;

/// Adapter: one [`Soc`] instance serving requests serially (the chip is
/// single-tenant; parallelism comes from running one backend per worker).
pub struct CycleBackend {
    soc: Soc,
    /// Per-request variation injection: fresh identically seeded models
    /// re-injected into the macro bank before every inference, matching
    /// the fast backend's one-fresh-stream-per-inference semantics so a
    /// disturbed request classifies identically on either engine.
    variation: Option<VariationParams>,
}

impl CycleBackend {
    pub fn new(program: Program, dram_cfg: DramConfig) -> Result<Self> {
        Ok(CycleBackend { soc: Soc::new(program, dram_cfg)?, variation: None })
    }

    /// Serve disturbed inferences (`serve --variation` on the cycle
    /// engine): see the field note for the reseeding contract.
    pub fn with_variation(mut self, v: VariationParams) -> Self {
        self.variation = Some(v);
        self
    }

    /// Direct access for callers that need SoC-only features (variation
    /// injection, tracing).
    pub fn soc_mut(&mut self) -> &mut Soc {
        &mut self.soc
    }
}

impl InferenceBackend for CycleBackend {
    fn name(&self) -> &'static str {
        "cycle"
    }

    /// The chip is single-tenant and exact: a batch is served as a plain
    /// internal loop (no host-side amortization to model — the cycle
    /// engine is the timing oracle, not the throughput path), which also
    /// makes batched-vs-sequential parity trivially structural here.
    fn run_batch(&mut self, batch: &[&[f32]]) -> Result<Vec<RunResult>> {
        let variation = self.variation;
        // Same global-off fast path as the fast backend: disabled
        // telemetry costs one relaxed load before the serial loop.
        let t0 = telemetry::enabled().then(std::time::Instant::now);
        let _r = t0.map(|_| telemetry::region("backend_cycle_run"));
        let runs: Result<Vec<RunResult>> = batch
            .iter()
            .map(|audio| {
                if let Some(v) = variation {
                    self.soc.set_variation(Some(v.model()));
                }
                self.soc.infer(audio)
            })
            .collect();
        drop(_r);
        if let (Some(t0), Ok(runs)) = (t0, &runs) {
            let telem = telemetry::global();
            telem
                .histogram("backend.cycle.execute_us", Histogram::fine_us_bounds())
                .observe(t0.elapsed().as_micros() as u64);
            telem.counter("backend.cycle.batches").inc();
            telem.counter("backend.cycle.inferences").add(runs.len() as u64);
        }
        runs
    }

    fn program(&self) -> &Program {
        self.soc.program()
    }
}
