//! Top-level simulator: load a program image, stage an utterance, run to
//! halt, extract results + statistics.

use anyhow::{bail, Context, Result};

use crate::compiler::Program;
use crate::cpu::{Cpu, StepOutcome};
use crate::energy::{EnergyReport, EnergyTable};
use crate::mem::bus::Bus;
use crate::mem::dram::DramConfig;
use crate::mem::layout;
use crate::model::reference::argmax;

use super::stats::PhaseBreakdown;

/// Default step budget: generously above any KWS inference (~10^6).
const MAX_STEPS: u64 = 200_000_000;

/// One completed run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// GAP logits (result sums / final_t), comparable to the golden model.
    pub logits: Vec<f32>,
    pub predicted: usize,
    pub cycles: u64,
    pub instret: u64,
    pub phases: PhaseBreakdown,
    pub energy: EnergyReport,
    /// Wall-clock seconds at the paper's 50 MHz clock.
    pub seconds_at_50mhz: f64,
    pub console: String,
    /// MAC fires per macro this run (one entry per macro; a single entry
    /// for unsharded programs). Feeds the per-shard utilization counters
    /// in `coordinator::ServiceStats`.
    pub shard_fires: Vec<u64>,
    /// The raw MMIO phase-marker stream `(id, end_cycle)` this run's
    /// `phases` were attributed from — kept on the result so the
    /// telemetry Perfetto exporter can render the engine timeline
    /// without re-running.
    pub markers: Vec<(u32, u64)>,
}

/// The SoC instance (reusable across inferences: weights stay staged).
pub struct Soc {
    pub bus: Bus,
    program: Program,
    /// Predecoded instruction image (§Perf: decode once, not per step).
    decoded: Vec<crate::isa::Instr>,
    energy_table: EnergyTable,
    /// Whether to reset access counters before each run.
    reset_stats_per_run: bool,
}

impl Soc {
    /// Build a SoC with a program image loaded (IMEM + DRAM weights +
    /// DMEM tables). Audio is staged per-run.
    pub fn new(program: Program, dram_cfg: DramConfig) -> Result<Self> {
        let mut bus = Bus::new_with_macros(dram_cfg, program.shards.n_macros.max(1));
        for (i, w) in program.imem.iter().enumerate() {
            bus.imem.poke_u32((i * 4) as u32, *w)?;
        }
        for (off, bytes) in &program.dram {
            bus.dram.load(*off, bytes)?;
        }
        for (off, words) in &program.dmem {
            for (i, w) in words.iter().enumerate() {
                bus.dmem.poke_u32(off + (i * 4) as u32, *w)?;
            }
        }
        let decoded = program
            .imem
            .iter()
            .map(|&w| crate::isa::decode(w))
            .collect::<Result<Vec<_>>>()?;
        let mut soc =
            Soc { bus, program, decoded, energy_table: EnergyTable::default(), reset_stats_per_run: true };
        if soc.program.entry > 0 {
            // Fused image: execute the one-time setup section (PC 0) now —
            // mask init, weight DMA, resident sign bursts. Every `run`
            // starts at `entry` with the macros already loaded.
            soc.execute(0)?;
            match soc.bus.exit_code {
                Some(0) => {}
                Some(c) => bail!("fused setup exited with code {c}"),
                None => bail!("fused setup halted without HOST_EXIT"),
            }
            soc.bus.phases.clear();
            soc.bus.exit_code = None;
            soc.bus.console.clear();
        }
        Ok(soc)
    }

    pub fn with_energy_table(mut self, t: EnergyTable) -> Self {
        self.energy_table = t;
        self
    }

    /// Inject a variation model into the macro(s) (robustness experiments).
    pub fn with_variation(mut self, v: crate::cim::VariationModel) -> Self {
        self.set_variation(Some(v));
        self
    }

    /// (Re)inject or clear the macros' variation models in place. Every
    /// macro of the bank receives its own clone, i.e. an identically
    /// seeded but independently advancing noise stream — the convention
    /// the variation-aware functional simulator replays
    /// (`robustness::replay`). `Soc::run` never resets the streams, so a
    /// caller that wants per-inference reproducibility re-injects before
    /// each run (what `backend::CycleBackend::with_variation` does).
    pub fn set_variation(&mut self, v: Option<crate::cim::VariationModel>) {
        for m in &mut self.bus.cims {
            m.variation = v.clone();
        }
    }

    /// Per-macro fire/shift/load statistics of the last run.
    pub fn macro_stats(&self) -> Vec<crate::cim::CimStats> {
        self.bus.cims.iter().map(|m| m.stats).collect()
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    /// Stage one utterance (float waveform -> i16 ADC image in DRAM).
    /// The staged image is exactly the plan's audio region: shorter
    /// waveforms are zero-padded so a reused SoC never reads the previous
    /// request's samples (history-independent, bit-identical to the
    /// functional backend which treats missing samples as zero), and
    /// longer ones are truncated so they cannot overwrite the weight
    /// streams that live right above the region (the program only ever
    /// reads `audio_len` samples).
    pub fn stage_audio(&mut self, audio: &[f32]) -> Result<()> {
        let q = crate::model::reference::quantize_audio(audio);
        let mut bytes = Vec::with_capacity(q.len() * 2);
        for v in &q {
            bytes.extend_from_slice(&(*v as i16).to_le_bytes());
        }
        bytes.resize(self.program.plan.audio_bytes as usize, 0);
        self.bus.dram.load(crate::dataflow::plan::DRAM_AUDIO, &bytes)?;
        Ok(())
    }

    /// Run one inference to halt.
    pub fn run(&mut self) -> Result<RunResult> {
        if self.reset_stats_per_run {
            self.bus.fm.reset_counters();
            self.bus.wt.reset_counters();
            self.bus.dmem.reset_counters();
            self.bus.imem.reset_counters();
            self.bus.dram.reset_counters();
            for m in &mut self.bus.cims {
                m.reset_stats();
            }
            self.bus.udma.transfers = 0;
            self.bus.udma.bytes = 0;
            self.bus.udma.busy_cycles = 0;
            self.bus.phases.clear();
            self.bus.exit_code = None;
            self.bus.console.clear();
        }
        let cpu = self.execute((self.program.entry * 4) as u32)?;

        match self.bus.exit_code {
            Some(0) => {}
            Some(c) => bail!("program exited with code {c}"),
            None => bail!("program halted without HOST_EXIT"),
        }

        // Extract GAP sums from DMEM and divide by final T (f32, matching
        // jnp.mean over integer-valued sums).
        anyhow::ensure!(self.bus.result_addr != 0, "program did not publish a result address");
        let base = self.bus.result_addr - layout::DMEM_BASE;
        let n = self.program.n_classes;
        let mut logits = Vec::with_capacity(n);
        for c in 0..n {
            let raw = self.bus.dmem.peek_u32(base + (c * 4) as u32)? as i32;
            logits.push(raw as f32 / self.program.final_t as f32);
        }

        let phases = PhaseBreakdown::from_markers(&self.bus.phases, cpu.stats.cycles);
        let energy = EnergyReport::from_run(&self.energy_table, &cpu.stats, &self.bus);
        Ok(RunResult {
            predicted: argmax(&logits),
            logits,
            cycles: cpu.stats.cycles,
            instret: cpu.stats.instret,
            phases,
            energy,
            seconds_at_50mhz: crate::clock::cycles_to_seconds(cpu.stats.cycles),
            console: self.bus.console.clone(),
            shard_fires: self.bus.cims.iter().map(|m| m.stats.fires).collect(),
            markers: self.bus.phases.clone(),
        })
    }

    /// Convenience: stage + run.
    pub fn infer(&mut self, audio: &[f32]) -> Result<RunResult> {
        self.stage_audio(audio)?;
        self.run()
    }

    /// Execute from `start_pc` to halt (the shared core loop of the
    /// one-time fused setup pass and every per-inference run).
    fn execute(&mut self, start_pc: u32) -> Result<Cpu> {
        let mut cpu = Cpu::new(start_pc);
        let mut now: u64 = 0;
        let mut steps: u64 = 0;
        loop {
            self.bus.tick(now)?;
            match cpu
                .step_predecoded(&mut self.bus, &self.decoded)
                .with_context(|| format!("cycle {now}"))?
            {
                StepOutcome::Retired { cycles } => now += cycles,
                StepOutcome::Halted => break,
            }
            steps += 1;
            if steps > MAX_STEPS {
                bail!("program did not halt within {MAX_STEPS} steps");
            }
        }
        // Drain any in-flight uDMA bookkeeping.
        self.bus.tick(u64::MAX)?;
        self.bus.now = now;
        Ok(cpu)
    }
}

/// Build a ready SoC for the default artifacts model.
pub fn build_default_soc(opt: crate::baselines::OptLevel) -> Result<Soc> {
    let model = crate::model::KwsModel::load_default()?;
    let program = crate::compiler::build_kws_program(&model, opt)?;
    Soc::new(program, DramConfig::default())
}

// Integration-level tests live in rust/tests/ (they need artifacts); the
// unit tests here use the synthetic fake model from codegen's tests via a
// minimal end-to-end run.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program;
    use crate::model::kws::LayerSpec;
    use crate::model::reference;
    use crate::model::KwsModel;

    fn fake_model(seed: u64) -> KwsModel {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(seed);
        let mut mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
            thresholds: if binarized {
                (0..co).map(|_| rng.range(0, 9) as i32 - 4).collect()
            } else {
                vec![]
            },
        };
        let layers =
            vec![mk(64, 64, true, true), mk(64, 32, true, true), mk(32, 12, false, false)];
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 2,
            layers,
            bn_gamma: vec![1.0; 64],
            bn_beta: vec![0.5; 64],
            bn_mean: vec![20000.0; 64],
            bn_var: vec![4.0e8; 64],
            pre_thr: crate::model::kws::fold_bn(
                &[1.0; 64],
                &[0.5; 64],
                &[20000.0; 64],
                &[4.0e8; 64],
            )
            .0,
            pre_dir: vec![1; 64],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    fn test_audio(seed: u64) -> Vec<f32> {
        crate::model::dataset::synth_utterance(3, seed, 16000, 0.3)
    }

    #[test]
    fn iss_matches_host_reference_all_opt_levels() {
        // THE core system test: the cycle-level ISS program must produce
        // bit-identical logits to the host reference implementation, for
        // every optimization level (optimizations change timing, never
        // values).
        let m = fake_model(42);
        let audio = test_audio(7);
        let want = reference::infer(&m, &audio);
        for (name, opt) in OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
            let r = soc.infer(&audio).unwrap();
            assert_eq!(r.logits, want, "logits mismatch at {name}");
        }
    }

    #[test]
    fn optimizations_strictly_reduce_cycles() {
        let m = fake_model(1);
        let audio = test_audio(2);
        let mut prev = u64::MAX;
        for (name, opt) in OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
            let r = soc.infer(&audio).unwrap();
            assert!(r.cycles < prev, "{name}: {} !< {prev}", r.cycles);
            prev = r.cycles;
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let m = fake_model(5);
        let audio = test_audio(9);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
        let a = soc.infer(&audio).unwrap();
        let b = soc.infer(&audio).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn phase_markers_cover_run() {
        let m = fake_model(3);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
        let r = soc.infer(&test_audio(1)).unwrap();
        assert!(r.phases.boot > 0);
        assert!(r.phases.preprocess > 0);
        assert!(r.phases.weights > 0);
        assert!(r.phases.conv > 0);
        let total = r.phases.boot + r.phases.preprocess + r.phases.weights + r.phases.conv + r.phases.tail;
        assert_eq!(total, r.cycles);
    }

    #[test]
    fn fused_soc_is_reusable_and_matches_reference() {
        let m = fake_model(42);
        let audio = test_audio(7);
        let want = reference::infer(&m, &audio);
        let prog = build_kws_program(&m, OptLevel::FUSED).unwrap();
        assert!(prog.entry > 0);
        let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
        let a = soc.infer(&audio).unwrap();
        assert_eq!(a.logits, want, "first fused inference");
        // Steady state: the resident planes survive across runs.
        let b = soc.infer(&audio).unwrap();
        assert_eq!(b.logits, want, "second fused inference (resident reuse)");
        assert_eq!(a.cycles, b.cycles);
        // The overlapped pooled-drain region is announced per pooled layer.
        assert!(a.markers.iter().any(|&(id, _)| (40..50).contains(&id)));
    }

    #[test]
    fn input_sharded_soc_matches_reference() {
        let m = fake_model(11);
        let audio = test_audio(3);
        let want = reference::infer(&m, &audio);
        for n in 1..=4usize {
            let prog =
                crate::compiler::build_kws_program_input_sharded(&m, OptLevel::FULL, n).unwrap();
            let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
            let r = soc.infer(&audio).unwrap();
            assert_eq!(r.logits, want, "input-axis n={n}");
        }
    }

    #[test]
    fn energy_report_nonzero() {
        let m = fake_model(4);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let mut soc = Soc::new(prog, DramConfig::default()).unwrap();
        let r = soc.infer(&test_audio(4)).unwrap();
        assert!(r.energy.total_pj > 0.0);
        assert!(r.energy.macro_pj > 0.0);
        assert!(r.energy.tops_per_w() > 0.0);
    }
}
