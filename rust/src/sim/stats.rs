//! Phase attribution from the program's MMIO phase markers.


/// Cycle counts per program phase (paper Fig. 10's three modes plus boot).
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseBreakdown {
    /// One-time boot: audio staging + mask-plane init (+ L0 prefetch).
    pub boot: u64,
    /// RISC-V preprocessing (high-pass, features, BN compare).
    pub preprocess: u64,
    /// Weight phases: uDMA waits + cim_w bursts across all layers.
    pub weights: u64,
    /// Convolution phases (incl. unfused pooling passes and FM spills).
    pub conv: u64,
    /// Everything after the last marker (result publication).
    pub tail: u64,
}

impl PhaseBreakdown {
    /// Attribute cycles from (marker id, cycle) pairs.
    pub fn from_markers(markers: &[(u32, u64)], total: u64) -> Self {
        let mut b = PhaseBreakdown::default();
        let mut prev = 0u64;
        for &(id, at) in markers {
            let span = at.saturating_sub(prev);
            match id {
                1 => b.boot += span,
                2 => b.preprocess += span,
                10..=29 => b.weights += span,
                30..=49 => b.conv += span,
                _ => b.tail += span,
            }
            prev = at;
        }
        b.tail += total.saturating_sub(prev);
        b
    }

    /// The "accelerated" share the paper's three optimizations attack
    /// (weights + conv; preprocessing/boot run on the RISC-V either way).
    pub fn accelerated(&self) -> u64 {
        self.weights + self.conv
    }

    pub fn total(&self) -> u64 {
        self.boot + self.preprocess + self.weights + self.conv + self.tail
    }

    pub fn render(&self) -> String {
        let pct = |x: u64| 100.0 * x as f64 / self.total().max(1) as f64;
        format!(
            "cycles {}: boot {} ({:.1}%) | preprocess {} ({:.1}%) | weights {} ({:.1}%) | conv {} ({:.1}%) | tail {}",
            self.total(),
            self.boot,
            pct(self.boot),
            self.preprocess,
            pct(self.preprocess),
            self.weights,
            pct(self.weights),
            self.conv,
            pct(self.conv),
            self.tail,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attribution_spans() {
        // boot done @100, preprocess @400, weights L0 @600, conv L0 @900,
        // weights L1 @1000, conv L1 @1100; total 1150.
        let markers =
            vec![(1, 100), (2, 400), (10, 600), (30, 900), (11, 1000), (31, 1100)];
        let b = PhaseBreakdown::from_markers(&markers, 1150);
        assert_eq!(b.boot, 100);
        assert_eq!(b.preprocess, 300);
        assert_eq!(b.weights, 200 + 100);
        assert_eq!(b.conv, 300 + 100);
        assert_eq!(b.tail, 50);
        assert_eq!(b.total(), 1150);
        assert_eq!(b.accelerated(), 700);
    }

    #[test]
    fn empty_markers_all_tail() {
        let b = PhaseBreakdown::from_markers(&[], 500);
        assert_eq!(b.tail, 500);
        assert_eq!(b.total(), 500);
    }
}
