//! Execution tracing: disassembled instruction streams with cycle stamps
//! and register effects — the debugging view a real devboard bring-up
//! would give you through JTAG.

use anyhow::Result;

use crate::compiler::Program;
use crate::cpu::{Cpu, StepOutcome};
use crate::isa::{decode, disasm};
use crate::mem::bus::Bus;
use crate::mem::dram::DramConfig;
use crate::util::json::Json;

/// One traced instruction.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    pub cycle: u64,
    pub pc: u32,
    pub text: String,
    /// Register file delta (abi name, new value), if any.
    pub wrote: Option<(String, u32)>,
}

impl TraceEntry {
    pub fn render(&self) -> String {
        match &self.wrote {
            Some((r, v)) => format!("[{:>8}] {:#010x}  {:<36} {r} <- {v:#010x}", self.cycle, self.pc, self.text),
            None => format!("[{:>8}] {:#010x}  {}", self.cycle, self.pc, self.text),
        }
    }

    /// Machine-readable form of one entry (one object per instruction).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("cycle", Json::num(self.cycle as f64)),
            ("pc", Json::num(self.pc as f64)),
            ("text", Json::str(self.text.as_str())),
        ];
        if let Some((reg, val)) = &self.wrote {
            fields.push(("wrote_reg", Json::str(reg.as_str())));
            fields.push(("wrote_val", Json::num(*val as f64)));
        }
        Json::obj(fields)
    }
}

/// Render a trace as JSON Lines: one compact object per instruction, so
/// the stream greps/streams cleanly (`cimrv trace --trace-out file.jsonl`).
pub fn render_jsonl(entries: &[TraceEntry]) -> String {
    let mut out = String::new();
    for e in entries {
        out.push_str(&e.to_json().to_string());
        out.push('\n');
    }
    out
}

/// Run a program from reset, collecting up to `max` trace entries
/// (optionally skipping the first `skip` retired instructions). The run
/// continues to halt so the trace is taken from a *valid* execution.
pub fn trace_program(program: &Program, skip: u64, max: usize) -> Result<Vec<TraceEntry>> {
    let mut bus = Bus::new_with_macros(DramConfig::default(), program.shards.n_macros.max(1));
    for (i, w) in program.imem.iter().enumerate() {
        bus.imem.poke_u32((i * 4) as u32, *w)?;
    }
    for (off, bytes) in &program.dram {
        bus.dram.load(*off, bytes)?;
    }
    for (off, words) in &program.dmem {
        for (i, w) in words.iter().enumerate() {
            bus.dmem.poke_u32(off + (i * 4) as u32, *w)?;
        }
    }
    let mut cpu = Cpu::new(0);
    let mut now = 0u64;
    let mut out = Vec::new();
    let mut retired = 0u64;
    loop {
        bus.tick(now)?;
        let pc = cpu.pc;
        let before = cpu.regs.snapshot();
        let word = bus.fetch(pc).unwrap_or(0);
        match cpu.step(&mut bus)? {
            StepOutcome::Retired { cycles } => {
                if retired >= skip && out.len() < max {
                    let text = decode(word).map(|i| disasm(&i)).unwrap_or_else(|_| "<raw>".into());
                    let after = cpu.regs.snapshot();
                    let wrote = (0..32)
                        .find(|&i| after[i] != before[i])
                        .map(|i| (crate::isa::Reg(i as u8).abi().to_string(), after[i]));
                    out.push(TraceEntry { cycle: now, pc, text, wrote });
                }
                now += cycles;
                retired += 1;
            }
            StepOutcome::Halted => break,
        }
        if retired > 50_000_000 {
            anyhow::bail!("trace runaway");
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program;
    use crate::model::kws::LayerSpec;
    use crate::model::KwsModel;

    fn tiny_model() -> KwsModel {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(3);
        let mk = |rng: &mut Rng, ci: usize, co: usize, last: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled: !last,
            binarized: !last,
            weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
            thresholds: if last { vec![] } else { vec![0; co] },
        };
        let layers = vec![mk(&mut rng, 32, 32, false), mk(&mut rng, 32, 12, true)];
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 32,
            n_classes: 12,
            fusion_split: 1,
            layers,
            bn_gamma: vec![1.0; 32],
            bn_beta: vec![0.0; 32],
            bn_mean: vec![20000.0; 32],
            bn_var: vec![4e8; 32],
            pre_thr: vec![20000; 32],
            pre_dir: vec![1; 32],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    #[test]
    fn trace_captures_boot_instructions() {
        let prog = build_kws_program(&tiny_model(), OptLevel::FULL).unwrap();
        let t = trace_program(&prog, 0, 12).unwrap();
        assert_eq!(t.len(), 12);
        // Boot starts by loading the MMIO base.
        assert!(t[0].text.starts_with("lui"), "{}", t[0].text);
        assert_eq!(t[0].pc, 0);
        assert!(t[0].wrote.is_some());
        // Cycles are monotone.
        assert!(t.windows(2).all(|w| w[0].cycle < w[1].cycle));
    }

    #[test]
    fn trace_skip_window() {
        let prog = build_kws_program(&tiny_model(), OptLevel::FULL).unwrap();
        let a = trace_program(&prog, 0, 30).unwrap();
        let b = trace_program(&prog, 10, 5).unwrap();
        assert_eq!(b[0].pc, a[10].pc);
        assert_eq!(b.len(), 5);
    }

    #[test]
    fn trace_renders() {
        let prog = build_kws_program(&tiny_model(), OptLevel::FULL).unwrap();
        let t = trace_program(&prog, 0, 3).unwrap();
        for e in &t {
            let s = e.render();
            assert!(s.contains("0x"));
        }
    }

    #[test]
    fn trace_jsonl_round_trips() {
        let prog = build_kws_program(&tiny_model(), OptLevel::FULL).unwrap();
        let t = trace_program(&prog, 0, 4).unwrap();
        let jsonl = render_jsonl(&t);
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 4);
        for (line, e) in lines.iter().zip(&t) {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.get("cycle").unwrap().as_f64().unwrap() as u64, e.cycle);
            assert_eq!(j.get("pc").unwrap().as_f64().unwrap() as u32, e.pc);
            assert_eq!(j.get("text").unwrap().as_str().unwrap(), e.text);
            match &e.wrote {
                Some((reg, val)) => {
                    assert_eq!(j.get("wrote_reg").unwrap().as_str().unwrap(), reg);
                    assert_eq!(j.get("wrote_val").unwrap().as_f64().unwrap() as u32, *val);
                }
                None => assert!(j.get("wrote_reg").is_err()),
            }
        }
    }
}
