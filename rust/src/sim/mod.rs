//! The SoC: wires the 2-stage core, CIM macro, SRAMs, uDMA and DRAM
//! together (paper Fig. 2) and runs compiled programs cycle by cycle.

pub mod soc;
pub mod trace;
pub mod stats;

pub use soc::{RunResult, Soc};
pub use stats::PhaseBreakdown;
