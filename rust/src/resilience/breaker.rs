//! Per-worker circuit breaker: repeated faults trip the worker out of
//! full-capacity service so it can be respawned degraded.
//!
//! The breaker counts *consecutive* faults (transient errors or panics);
//! any successful batch resets the streak. When the streak reaches the
//! threshold the breaker "trips": the worker exits, its in-flight jobs
//! are requeued, and the supervisor respawns it after a cooldown with a
//! reduced shard plan — shedding that worker's shard capacity instead of
//! its availability.

/// Consecutive-fault circuit breaker (one per worker incarnation).
#[derive(Debug, Clone)]
pub struct CircuitBreaker {
    threshold: u32,
    consecutive: u32,
    trips: u64,
}

impl CircuitBreaker {
    pub fn new(threshold: u32) -> Self {
        assert!(threshold > 0, "breaker threshold must be positive");
        CircuitBreaker { threshold, consecutive: 0, trips: 0 }
    }

    /// A batch completed cleanly: the fault streak ends.
    pub fn record_success(&mut self) {
        self.consecutive = 0;
    }

    /// A batch faulted. Returns `true` when this fault trips the
    /// breaker (streak reached the threshold); the streak resets so a
    /// respawned incarnation starts clean.
    pub fn record_fault(&mut self) -> bool {
        self.consecutive += 1;
        if self.consecutive >= self.threshold {
            self.consecutive = 0;
            self.trips += 1;
            true
        } else {
            false
        }
    }

    pub fn consecutive(&self) -> u32 {
        self.consecutive
    }

    pub fn trips(&self) -> u64 {
        self.trips
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trips_on_consecutive_faults_only() {
        let mut b = CircuitBreaker::new(3);
        assert!(!b.record_fault());
        assert!(!b.record_fault());
        b.record_success(); // streak broken
        assert!(!b.record_fault());
        assert!(!b.record_fault());
        assert!(b.record_fault(), "third consecutive fault trips");
        assert_eq!(b.trips(), 1);
        assert_eq!(b.consecutive(), 0, "streak resets after trip");
    }

    #[test]
    fn threshold_one_trips_immediately() {
        let mut b = CircuitBreaker::new(1);
        assert!(b.record_fault());
        assert!(b.record_fault());
        assert_eq!(b.trips(), 2);
    }
}
