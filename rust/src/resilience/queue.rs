//! Bounded MPMC request queue with explicit overload rejection.
//!
//! The coordinator used an unbounded `mpsc` channel: a burst of submits
//! grew the queue without limit and every request eventually ran, long
//! after its caller stopped caring. [`BoundedQueue`] is the admission-
//! control replacement — `push` fails fast with [`PushError::Full`]
//! instead of queueing (the caller turns that into
//! `SubmitError::Overloaded`), workers block on `pop_wait`/`pop_timeout`
//! like they did on the channel, and `push_front` lets the supervisor
//! path requeue in-flight jobs from a crashed worker at the head of the
//! line (capacity-exempt: those jobs were already admitted once).
//!
//! All locking is poison-proof: a worker that panics while holding the
//! queue mutex must not wedge submits or shutdown.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Why a push was refused; the value is handed back to the caller.
#[derive(Debug)]
pub enum PushError<T> {
    /// Queue is at capacity — shed the request.
    Full(T),
    /// Queue is closed (shutdown) — no more work is accepted.
    Closed(T),
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// A bounded FIFO shared by submitters and worker threads.
pub struct BoundedQueue<T> {
    inner: Mutex<QueueState<T>>,
    cond: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "queue capacity must be positive");
        BoundedQueue {
            inner: Mutex::new(QueueState { items: VecDeque::new(), closed: false }),
            cond: Condvar::new(),
            cap,
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueState<T>> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Admit one item at the tail, or refuse without blocking.
    pub fn push(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.lock();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        drop(st);
        self.cond.notify_one();
        Ok(())
    }

    /// Requeue an already-admitted item at the head (crash recovery:
    /// the job must run before newer arrivals). Capacity-exempt — the
    /// item held a slot when it was first admitted, and failing it here
    /// would turn a worker crash into a lost response.
    pub fn push_front(&self, item: T) -> Result<(), PushError<T>> {
        let mut st = self.lock();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        st.items.push_front(item);
        drop(st);
        self.cond.notify_one();
        Ok(())
    }

    /// Block until an item is available or the queue is closed *and*
    /// drained. Pending items are still handed out after close so a
    /// graceful shutdown can finish admitted work.
    pub fn pop_wait(&self) -> Option<T> {
        let mut st = self.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.cond.wait(st).unwrap_or_else(|poisoned| poisoned.into_inner());
        }
    }

    /// Like `pop_wait` but gives up after `timeout` (micro-batch linger
    /// assembly). `None` means either timeout or closed-and-empty.
    pub fn pop_timeout(&self, timeout: Duration) -> Option<T> {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = self.lock();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = std::time::Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero()) else {
                return None;
            };
            let (guard, _timed_out) = self
                .cond
                .wait_timeout(st, left)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            st = guard;
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        self.lock().items.pop_front()
    }

    /// Stop admitting work and wake every blocked worker. Items already
    /// queued stay poppable (or can be swept with [`BoundedQueue::drain`]).
    pub fn close(&self) {
        self.lock().closed = true;
        self.cond.notify_all();
    }

    /// Remove and return everything still queued (shutdown path: each
    /// drained job gets an explicit typed failure instead of a dropped
    /// channel).
    pub fn drain(&self) -> Vec<T> {
        self.lock().items.drain(..).collect()
    }

    pub fn len(&self) -> usize {
        self.lock().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_fifo_and_capacity() {
        let q = BoundedQueue::new(2);
        assert!(q.push(1).is_ok());
        assert!(q.push(2).is_ok());
        match q.push(3) {
            Err(PushError::Full(v)) => assert_eq!(v, 3),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.push(3).is_ok(), "slot freed after pop");
        assert_eq!(q.try_pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn push_front_is_capacity_exempt_and_pops_first() {
        let q = BoundedQueue::new(1);
        assert!(q.push(10).is_ok());
        assert!(q.push_front(9).is_ok(), "requeue must not be shed");
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(9));
        assert_eq!(q.try_pop(), Some(10));
    }

    #[test]
    fn close_rejects_pushes_but_drains_pending() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.is_closed());
        match q.push(3) {
            Err(PushError::Closed(v)) => assert_eq!(v, 3),
            other => panic!("expected Closed, got {other:?}"),
        }
        assert!(matches!(q.push_front(0), Err(PushError::Closed(0))));
        // Admitted work is still poppable after close...
        assert_eq!(q.pop_wait(), Some(1));
        // ...and drain sweeps the rest.
        assert_eq!(q.drain(), vec![2]);
        assert_eq!(q.pop_wait(), None, "closed + empty = worker exit");
    }

    #[test]
    fn pop_timeout_returns_none_on_empty() {
        let q: BoundedQueue<u32> = BoundedQueue::new(1);
        let t0 = std::time::Instant::now();
        assert_eq!(q.pop_timeout(Duration::from_millis(10)), None);
        assert!(t0.elapsed() >= Duration::from_millis(5), "actually waited");
    }

    #[test]
    fn pop_wait_blocks_until_push_across_threads() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(Duration::from_millis(20));
        q.push(7u32).unwrap();
        assert_eq!(h.join().unwrap(), Some(7));
    }

    #[test]
    fn close_wakes_blocked_workers() {
        let q: Arc<BoundedQueue<u32>> = Arc::new(BoundedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_wait());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }
}
