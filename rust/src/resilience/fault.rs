//! Deterministic fault plans for chaos injection.
//!
//! A [`FaultPlan`] is the `--chaos SPEC` payload: per-`run_batch`-call
//! probabilities for each fault class plus the magnitudes (spike/stall
//! durations, corruption sigma) and one seed. The schedule is drawn by
//! [`FaultPlan::draw`] with a **fixed number of RNG consumptions per
//! call** (one uniform per fault class, always, in a fixed order), so the
//! same plan produces the same fault sequence regardless of which faults
//! actually fire — the property the chaos determinism tests pin.
//!
//! The spec grammar mirrors `VariationParams::parse_spec` (comma-
//! separated `key=value`):
//!
//! ```text
//! seed=42,transient=0.2,panic=0.1,stall=0.05,stall_ms=30,
//! latency=0.1,latency_ms=5,corrupt=0.05,corrupt_sigma=0.4
//! ```

use anyhow::{anyhow, bail, ensure, Result};

use crate::util::rng::Rng;

/// Which fault classes fire on one `run_batch` call, in injection order.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FiredFaults {
    /// Added latency spike (sleep, then execute normally).
    pub latency: bool,
    /// Long worker stall (sleep; models a wedged device/driver).
    pub stall: bool,
    /// Transient `Err` return (retryable).
    pub transient: bool,
    /// Full worker panic (thread dies; supervisor must respawn).
    pub panic: bool,
    /// Logit corruption through the `VariationModel` machinery.
    pub corrupt: bool,
}

impl FiredFaults {
    pub fn any(&self) -> bool {
        self.latency || self.stall || self.transient || self.panic || self.corrupt
    }

    /// Compact bitmask (latency=1, stall=2, transient=4, panic=8,
    /// corrupt=16) — the chaos backend's fault log entry.
    pub fn bits(&self) -> u8 {
        (self.latency as u8)
            | (self.stall as u8) << 1
            | (self.transient as u8) << 2
            | (self.panic as u8) << 3
            | (self.corrupt as u8) << 4
    }
}

/// A reproducible fault-injection plan (`--chaos SPEC`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    /// Base RNG seed; each worker incarnation derives its own stream
    /// via [`FaultPlan::worker_seed`].
    pub seed: u64,
    /// P(added latency spike) per `run_batch` call.
    pub latency: f64,
    /// Latency spike duration (ms).
    pub latency_ms: u64,
    /// P(long stall) per call.
    pub stall: f64,
    /// Stall duration (ms).
    pub stall_ms: u64,
    /// P(transient `Err`) per call — retryable with backoff.
    pub transient: f64,
    /// P(worker panic) per call — the thread dies mid-batch.
    pub panic: f64,
    /// P(logit corruption) per call.
    pub corrupt: f64,
    /// Conductance sigma for the corruption's `VariationModel`.
    pub corrupt_sigma: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 42,
            latency: 0.0,
            latency_ms: 5,
            stall: 0.0,
            stall_ms: 30,
            transient: 0.0,
            panic: 0.0,
            corrupt: 0.0,
            corrupt_sigma: 0.4,
        }
    }
}

impl FaultPlan {
    /// Parse the CLI spec: comma-separated `key=value` pairs (see module
    /// docs for the grammar). Unknown keys and out-of-range
    /// probabilities are errors, like the variation spec parser.
    pub fn parse_spec(spec: &str) -> Result<Self> {
        let mut p = FaultPlan::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("chaos spec entry {part:?} is not key=value"))?;
            let (k, v) = (k.trim(), v.trim());
            let f = || -> Result<f64> {
                v.parse().map_err(|_| anyhow!("chaos {k}={v:?}: expected a number"))
            };
            let u = || -> Result<u64> {
                v.parse().map_err(|_| anyhow!("chaos {k}={v:?}: expected an integer"))
            };
            match k {
                "seed" => p.seed = u()?,
                "latency" => p.latency = f()?,
                "latency_ms" | "latency-ms" => p.latency_ms = u()?,
                "stall" => p.stall = f()?,
                "stall_ms" | "stall-ms" => p.stall_ms = u()?,
                "transient" => p.transient = f()?,
                "panic" => p.panic = f()?,
                "corrupt" => p.corrupt = f()?,
                "corrupt_sigma" | "corrupt-sigma" => p.corrupt_sigma = f()?,
                _ => bail!(
                    "unknown chaos key {k:?} (seed|latency|latency_ms|stall|stall_ms|\
                     transient|panic|corrupt|corrupt_sigma)"
                ),
            }
        }
        for (name, prob) in [
            ("latency", p.latency),
            ("stall", p.stall),
            ("transient", p.transient),
            ("panic", p.panic),
            ("corrupt", p.corrupt),
        ] {
            ensure!(
                (0.0..=1.0).contains(&prob),
                "chaos {name} probability must be in [0, 1] (got {prob})"
            );
        }
        ensure!(p.corrupt_sigma >= 0.0, "chaos corrupt_sigma must be >= 0");
        Ok(p)
    }

    /// Render back to the canonical spec string (reports, JSON).
    pub fn spec(&self) -> String {
        format!(
            "seed={},latency={},latency_ms={},stall={},stall_ms={},transient={},panic={},\
             corrupt={},corrupt_sigma={}",
            self.seed,
            self.latency,
            self.latency_ms,
            self.stall,
            self.stall_ms,
            self.transient,
            self.panic,
            self.corrupt,
            self.corrupt_sigma
        )
    }

    /// True when no fault can ever fire (every probability is zero).
    pub fn is_noop(&self) -> bool {
        self.latency == 0.0
            && self.stall == 0.0
            && self.transient == 0.0
            && self.panic == 0.0
            && self.corrupt == 0.0
    }

    /// The RNG seed for one worker incarnation's fault stream: distinct
    /// per (worker, incarnation) so a respawned worker does not replay
    /// its predecessor's schedule, yet fully determined by the plan.
    pub fn worker_seed(&self, worker: usize, incarnation: u64) -> u64 {
        self.seed
            ^ (worker as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ (incarnation + 1).wrapping_mul(0xBF58_476D_1CE4_E5B9)
    }

    /// Draw one call's fault set. Always consumes exactly five uniforms
    /// (one per class, fixed order: latency, stall, transient, panic,
    /// corrupt) so the schedule depends only on the seed, never on which
    /// earlier faults happened to fire.
    pub fn draw(&self, rng: &mut Rng) -> FiredFaults {
        let latency = rng.f64() < self.latency;
        let stall = rng.f64() < self.stall;
        let transient = rng.f64() < self.transient;
        let panic = rng.f64() < self.panic;
        let corrupt = rng.f64() < self.corrupt;
        FiredFaults { latency, stall, transient, panic, corrupt }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_and_defaults() {
        let p = FaultPlan::parse_spec("seed=7,transient=0.25,panic=0.1,stall_ms=50").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.transient, 0.25);
        assert_eq!(p.panic, 0.1);
        assert_eq!(p.stall_ms, 50);
        assert_eq!(p.latency, 0.0);
        let q = FaultPlan::parse_spec(&p.spec()).unwrap();
        assert_eq!(p, q);
        // Empty spec = the noop default plan.
        assert!(FaultPlan::parse_spec("").unwrap().is_noop());
        assert!(!p.is_noop());
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(FaultPlan::parse_spec("transient").is_err());
        assert!(FaultPlan::parse_spec("bogus=1").is_err());
        assert!(FaultPlan::parse_spec("panic=1.5").is_err());
        assert!(FaultPlan::parse_spec("panic=-0.1").is_err());
        assert!(FaultPlan::parse_spec("corrupt_sigma=-1").is_err());
        assert!(FaultPlan::parse_spec("seed=x").is_err());
    }

    #[test]
    fn draw_is_deterministic_and_consumes_fixed_draws() {
        let plan = FaultPlan { transient: 0.5, panic: 0.2, ..Default::default() };
        let seq = |seed: u64| -> Vec<u8> {
            let mut rng = Rng::new(seed);
            (0..64).map(|_| plan.draw(&mut rng).bits()).collect()
        };
        assert_eq!(seq(1), seq(1), "same seed, same schedule");
        assert_ne!(seq(1), seq(2), "different seed, different schedule");
        // A plan with different probabilities but the same seed consumes
        // the same number of draws: the post-schedule RNG state matches.
        let plan_b = FaultPlan { latency: 0.9, corrupt: 0.9, ..Default::default() };
        let mut ra = Rng::new(9);
        let mut rb = Rng::new(9);
        for _ in 0..16 {
            plan.draw(&mut ra);
            plan_b.draw(&mut rb);
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "fixed draw count per call");
    }

    #[test]
    fn worker_seeds_are_distinct_and_stable() {
        let p = FaultPlan::default();
        assert_eq!(p.worker_seed(0, 0), p.worker_seed(0, 0));
        assert_ne!(p.worker_seed(0, 0), p.worker_seed(1, 0));
        assert_ne!(p.worker_seed(0, 0), p.worker_seed(0, 1));
    }

    #[test]
    fn fired_bits_encode_all_classes() {
        let all = FiredFaults { latency: true, stall: true, transient: true, panic: true, corrupt: true };
        assert_eq!(all.bits(), 0b1_1111);
        assert!(all.any());
        assert!(!FiredFaults::default().any());
        assert_eq!(FiredFaults::default().bits(), 0);
    }
}
