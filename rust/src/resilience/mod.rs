//! Fault tolerance for the serving tier: chaos injection, admission
//! control, worker supervision, and graceful degradation.
//!
//! CIM edge deployments fail in layers — device-level conductance drift,
//! wedged driver calls, crashed worker threads, plain overload — and the
//! coordinator must degrade throughput, never availability. This module
//! supplies the pieces the reworked [`crate::coordinator::Coordinator`]
//! composes:
//!
//! * [`fault::FaultPlan`] / [`chaos::ChaosBackend`] — deterministic,
//!   seeded fault injection behind the standard [`crate::backend::
//!   InferenceBackend`] seam (`--chaos` on the CLI), so every failure
//!   mode is reproducible in tests and soaks.
//! * [`queue::BoundedQueue`] — bounded admission with explicit
//!   [`SubmitError::Overloaded`] load-shedding and head-of-line requeue
//!   for crash recovery.
//! * [`breaker::CircuitBreaker`] — consecutive-fault trip wire behind
//!   shard-shedding degraded respawns.
//! * [`soak`] — the `cimrv soak` chaos-soak harness emitting
//!   `BENCH_resilience.json`.
//!
//! The typed error surface lives here: [`SubmitError`] for admission
//! (submit-side) failures and [`ServeError`] for per-request serving
//! failures. Both implement `std::error::Error`, so `?` lifts them into
//! `anyhow::Error` at the CLI boundary while tests can still match on
//! the concrete variants.

pub mod breaker;
pub mod chaos;
pub mod fault;
pub mod queue;
pub mod soak;

pub use breaker::CircuitBreaker;
pub use chaos::{ChaosBackend, FaultCounts};
pub use fault::{FaultPlan, FiredFaults};
pub use queue::{BoundedQueue, PushError};
pub use soak::{run_soak, SoakCell, SoakConfig, SoakReport};

use std::fmt;

/// Why a request was refused at the door (admission control).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is full — shed now, retry later.
    Overloaded { depth: usize, cap: usize },
    /// The coordinator has shut down.
    Shutdown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Overloaded { depth, cap } => {
                write!(f, "overloaded: request queue full ({depth}/{cap}); request shed")
            }
            // Wording kept compatible with callers matching on "shut down".
            SubmitError::Shutdown => write!(f, "coordinator is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Why an *accepted* request failed; every admitted request resolves to
/// either an `InferenceResponse` or one of these — never a hang or a
/// dropped channel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request's deadline expired before (or while) executing.
    DeadlineExceeded { waited_us: u64 },
    /// The backend kept failing after all retry attempts.
    Backend { attempts: u32, message: String },
    /// The worker thread panicked and the retry budget ran out.
    WorkerPanic { attempts: u32 },
    /// The coordinator shut down with this request still queued.
    Shutdown,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded { waited_us } => {
                write!(f, "deadline exceeded after {waited_us} us; request dropped unexecuted")
            }
            ServeError::Backend { attempts, message } => {
                write!(f, "backend failed after {attempts} attempt(s): {message}")
            }
            ServeError::WorkerPanic { attempts } => {
                write!(f, "worker panicked; request abandoned after {attempts} attempt(s)")
            }
            ServeError::Shutdown => write!(f, "coordinator shut down with request still pending"),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn errors_display_and_convert_to_anyhow() {
        let s = SubmitError::Overloaded { depth: 8, cap: 8 };
        assert!(s.to_string().contains("overloaded"));
        assert!(SubmitError::Shutdown.to_string().contains("shut down"));
        let e: anyhow::Error = ServeError::WorkerPanic { attempts: 3 }.into();
        assert!(e.to_string().contains("panicked"));
        let e: anyhow::Error = SubmitError::Shutdown.into();
        assert!(e.to_string().contains("shut down"));
    }
}
