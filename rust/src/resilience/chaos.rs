//! `ChaosBackend` — deterministic fault injection behind the standard
//! backend seam.
//!
//! Wraps any [`InferenceBackend`] and, before/after each `run_batch`,
//! consults a [`FaultPlan`] schedule drawn from a seeded RNG: latency
//! spikes and stalls sleep, transients return `Err`, panics kill the
//! worker thread mid-batch, and corruption perturbs the produced logits
//! through the same `VariationModel` math the robustness subsystem uses.
//! Because the wrapper sits *behind* the coordinator, every resilience
//! mechanism (retry, supervision, breakers, deadlines) is exercised by
//! exactly the code paths production faults would take.
//!
//! Injected panics carry a `"chaos: ..."` string payload; a
//! once-installed panic hook suppresses their default stderr backtrace
//! noise (cargo's capture is per-test-thread, and these fire on spawned
//! worker threads) while forwarding all other panics untouched.

use std::sync::Once;

use anyhow::{anyhow, Result};

use crate::backend::InferenceBackend;
use crate::cim::variation::VariationModel;
use crate::compiler::Program;
use crate::sim::RunResult;
use crate::util::rng::Rng;

use super::fault::{FaultPlan, FiredFaults};

/// Fixed-point scale for routing fractional logits through the integer
/// `VariationModel::disturb` path (logits are result-sums / final_t, so
/// they carry sub-integer precision worth preserving).
const LOGIT_FIX: f64 = 256.0;

/// Per-fault-class injection counters (determinism tests + soak report).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub calls: u64,
    pub latency: u64,
    pub stall: u64,
    pub transient: u64,
    pub panic: u64,
    pub corrupt: u64,
}

static QUIET_CHAOS_PANICS: Once = Once::new();

/// Payload prefix identifying an injected panic.
pub const CHAOS_PANIC_PREFIX: &str = "chaos:";

/// Is this panic payload one of ours? (Payloads from `panic!` with a
/// format string are `String`; literal-only panics are `&str`.)
pub fn is_chaos_payload(payload: &(dyn std::any::Any + Send)) -> bool {
    if let Some(s) = payload.downcast_ref::<&str>() {
        return s.starts_with(CHAOS_PANIC_PREFIX);
    }
    if let Some(s) = payload.downcast_ref::<String>() {
        return s.starts_with(CHAOS_PANIC_PREFIX);
    }
    false
}

fn install_quiet_panic_hook() {
    QUIET_CHAOS_PANICS.call_once(|| {
        let previous = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if !is_chaos_payload(info.payload()) {
                previous(info);
            }
        }));
    });
}

/// Fault-injecting wrapper around a real backend.
pub struct ChaosBackend {
    inner: Box<dyn InferenceBackend>,
    plan: FaultPlan,
    rng: Rng,
    counts: FaultCounts,
    /// One `FiredFaults::bits()` entry per `run_batch` call, in order —
    /// the fault *schedule*, pinned by the determinism tests.
    fault_log: Vec<u8>,
}

impl ChaosBackend {
    /// Wrap `inner` with the plan's base seed (single-backend use).
    pub fn new(inner: Box<dyn InferenceBackend>, plan: FaultPlan) -> Self {
        Self::with_seed(inner, plan, plan.seed)
    }

    /// Wrap `inner` with an explicit stream seed (the coordinator passes
    /// `plan.worker_seed(worker, incarnation)` so each worker
    /// incarnation gets its own deterministic schedule).
    pub fn with_seed(inner: Box<dyn InferenceBackend>, plan: FaultPlan, seed: u64) -> Self {
        install_quiet_panic_hook();
        ChaosBackend { inner, plan, rng: Rng::new(seed), counts: FaultCounts::default(), fault_log: Vec::new() }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn counts(&self) -> FaultCounts {
        self.counts
    }

    pub fn fault_log(&self) -> &[u8] {
        &self.fault_log
    }

    /// Disturb one result's logits through the variation machinery and
    /// recompute its argmax. Deterministic: the corruption seed comes
    /// off the schedule RNG, so it is part of the fault stream.
    fn corrupt_result(&mut self, r: &mut RunResult) {
        let mut vm = VariationModel::new(self.plan.corrupt_sigma, 0.0, false, self.rng.next_u64());
        for logit in &mut r.logits {
            let fixed = (*logit as f64 * LOGIT_FIX).round() as i32;
            let disturbed = vm.disturb(fixed, LOGIT_FIX as u32);
            *logit = disturbed as f32 / LOGIT_FIX as f32;
        }
        r.predicted = crate::model::reference::argmax(&r.logits);
    }

    fn draw(&mut self) -> FiredFaults {
        let fired = self.plan.draw(&mut self.rng);
        self.counts.calls += 1;
        self.counts.latency += fired.latency as u64;
        self.counts.stall += fired.stall as u64;
        self.counts.transient += fired.transient as u64;
        self.counts.panic += fired.panic as u64;
        self.counts.corrupt += fired.corrupt as u64;
        self.fault_log.push(fired.bits());
        if fired.any() {
            // The incident log sees only *fired* draws, not every call:
            // quiet calls are the common case and would drown the ring.
            let call = self.counts.calls;
            crate::telemetry::incident(
                crate::telemetry::IncidentKind::ChaosInjected,
                None,
                None,
                || format!("call {call}: {} (bits {:#04b})", fired_names(&fired), fired.bits()),
            );
        }
        fired
    }
}

/// Comma-joined names of the fault classes that fired (event-log detail).
fn fired_names(f: &FiredFaults) -> String {
    let mut names = Vec::new();
    if f.latency {
        names.push("latency");
    }
    if f.stall {
        names.push("stall");
    }
    if f.transient {
        names.push("transient");
    }
    if f.panic {
        names.push("panic");
    }
    if f.corrupt {
        names.push("corrupt");
    }
    names.join("+")
}

impl InferenceBackend for ChaosBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn run_batch(&mut self, batch: &[&[f32]]) -> Result<Vec<RunResult>> {
        let fired = self.draw();
        let call = self.counts.calls;
        if fired.latency {
            std::thread::sleep(std::time::Duration::from_millis(self.plan.latency_ms));
        }
        if fired.stall {
            std::thread::sleep(std::time::Duration::from_millis(self.plan.stall_ms));
        }
        if fired.panic {
            // String payload, prefix-matched by the quiet hook and by
            // the worker's catch_unwind classification.
            panic!("{CHAOS_PANIC_PREFIX} injected worker panic (call {call})");
        }
        if fired.transient {
            return Err(anyhow!("{CHAOS_PANIC_PREFIX} injected transient fault (call {call})"));
        }
        let mut out = self.inner.run_batch(batch)?;
        if fired.corrupt {
            for r in &mut out {
                self.corrupt_result(r);
            }
        }
        Ok(out)
    }

    fn program(&self) -> &Program {
        self.inner.program()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_panic_payloads_are_recognized() {
        install_quiet_panic_hook();
        let err = std::panic::catch_unwind(|| {
            panic!("{CHAOS_PANIC_PREFIX} injected worker panic (call 3)");
        })
        .unwrap_err();
        assert!(is_chaos_payload(&*err), "formatted String payload matches prefix");
        let other = std::panic::catch_unwind(|| panic!("{}", "unrelated")).unwrap_err();
        assert!(!is_chaos_payload(&*other));
    }
}
