//! Chaos soak harness (`cimrv soak`): drive the coordinator through a
//! grid of fault plans and prove the availability story end to end.
//!
//! Each [`SoakCell`] is one serving scenario — a fault plan, an optional
//! per-request deadline, a queue capacity, and an open-loop arrival rate
//! — soaked for [`SoakConfig::n`] requests against a fresh fast-backend
//! coordinator. Every submitted request is tracked to a *typed* end:
//! served, shed at admission, deadline-expired, failed, or shut down.
//! A request with no answer inside the collection timeout counts as
//! **hung**, and [`SoakReport::check`] treats any hang as a failure —
//! the availability contract is "every accepted request gets a typed
//! response", and the soak is the executable proof.
//!
//! [`SoakReport::to_json`] is the `BENCH_resilience.json` payload
//! (availability, shed rate, retry/respawn counts, p99-under-fault per
//! cell); `soak --quick --check` is the CI smoke gate.

use std::time::{Duration, Instant};

use anyhow::{ensure, Result};

use crate::backend::BackendKind;
use crate::baselines::OptLevel;
use crate::coordinator::{Coordinator, InferenceRequest, ServeOptions};
use crate::model::{dataset, KwsModel};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::fault::FaultPlan;
use super::{ServeError, SubmitError};

/// One soak scenario: a named fault plan plus serving-shape knobs.
#[derive(Debug, Clone)]
pub struct SoakCell {
    pub name: String,
    /// Fault plan for every worker backend (`None` = clean serving).
    pub chaos: Option<FaultPlan>,
    /// Per-request deadline, if the scenario serves deadline traffic.
    pub deadline_ms: Option<u64>,
    /// Bounded-queue capacity for this cell.
    pub queue_cap: usize,
    /// Open-loop arrival rate (requests/s); `0.0` = submit back-to-back
    /// (the overload pattern).
    pub rate: f64,
    /// `check()`: every accepted request must be *served* (not just
    /// answered) — the cell's faults are all retryable/absorbable.
    pub expect_full_availability: bool,
    /// `check()`: the supervisor must have respawned a worker.
    pub expect_respawn: bool,
    /// `check()`: admission control must have shed at least once.
    pub expect_overload_shed: bool,
    /// `check()`: at least one request must have expired its deadline.
    pub expect_deadline_shed: bool,
}

impl SoakCell {
    fn new(name: &str, chaos: Option<FaultPlan>) -> Self {
        SoakCell {
            name: name.to_string(),
            chaos,
            deadline_ms: None,
            queue_cap: 1024,
            rate: 2000.0,
            expect_full_availability: true,
            expect_respawn: false,
            expect_overload_shed: false,
            expect_deadline_shed: false,
        }
    }
}

/// The soak grid + execution knobs.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    pub cells: Vec<SoakCell>,
    /// Requests per cell.
    pub n: usize,
    pub workers: usize,
    /// Micro-batch cap for every cell's coordinator.
    pub batch: usize,
    pub macros: usize,
    /// Per-request attempt budget. The standard cells' fault rates are
    /// chosen so exhausting 12 attempts is a ~1e-6 event — availability
    /// checks stay deterministic in practice.
    pub max_attempts: u32,
    /// Base seed for the arrival process (fault schedules seed from each
    /// cell's own `FaultPlan::seed`).
    pub seed: u64,
    /// Per-request collection timeout; anything slower counts as hung.
    pub answer_timeout: Duration,
}

impl SoakConfig {
    /// The standard grid: clean baseline, retryable transients, worker
    /// panics (supervised respawn), latency spikes under a generous
    /// deadline, stalls under a tight deadline (typed sheds by design),
    /// and a tiny queue hammered back-to-back (admission sheds).
    pub fn standard() -> Self {
        let cells = vec![
            SoakCell::new("baseline", None),
            SoakCell::new(
                "transient",
                Some(FaultPlan { transient: 0.2, ..Default::default() }),
            ),
            SoakCell {
                expect_respawn: true,
                ..SoakCell::new(
                    "panic",
                    Some(FaultPlan { panic: 0.3, ..Default::default() }),
                )
            },
            SoakCell {
                deadline_ms: Some(250),
                ..SoakCell::new(
                    "latency_deadline",
                    Some(FaultPlan { latency: 0.5, latency_ms: 5, ..Default::default() }),
                )
            },
            SoakCell {
                deadline_ms: Some(15),
                expect_full_availability: false,
                expect_deadline_shed: true,
                ..SoakCell::new(
                    "stall_shed",
                    Some(FaultPlan { stall: 0.5, stall_ms: 30, ..Default::default() }),
                )
            },
            SoakCell {
                queue_cap: 4,
                rate: 0.0,
                expect_full_availability: false,
                expect_overload_shed: true,
                ..SoakCell::new(
                    "overload",
                    Some(FaultPlan { stall: 1.0, stall_ms: 10, ..Default::default() }),
                )
            },
        ];
        SoakConfig {
            cells,
            n: 96,
            workers: 2,
            batch: 4,
            macros: 1,
            max_attempts: 12,
            seed: 7,
            answer_timeout: Duration::from_secs(30),
        }
    }

    /// The CI smoke grid: the same cells, fewer requests per cell.
    pub fn quick() -> Self {
        SoakConfig { n: 40, ..Self::standard() }
    }
}

/// One cell's measured outcome.
#[derive(Debug, Clone)]
pub struct CellResult {
    pub name: String,
    /// Canonical `--chaos` spec string ("none" for the clean cell).
    pub spec: String,
    pub submitted: u64,
    /// Requests past admission (submitted minus overload sheds).
    pub accepted: u64,
    /// Submits refused with `SubmitError::Overloaded`.
    pub shed_overload: u64,
    /// Accepted requests served with a real response.
    pub ok: u64,
    /// Accepted requests answered `ServeError::DeadlineExceeded`.
    pub deadline_expired: u64,
    /// Accepted requests answered `Backend`/`WorkerPanic` (budget spent).
    pub failed: u64,
    /// Accepted requests answered `ServeError::Shutdown`.
    pub shutdown: u64,
    /// Accepted requests with NO answer inside the timeout — the one
    /// number that must always be zero.
    pub hung: u64,
    /// Batch retries after transient faults (coordinator counter).
    pub retries: u64,
    /// Jobs requeued by crashed/tripped workers.
    pub requeues: u64,
    pub worker_panics: u64,
    pub respawns: u64,
    pub breaker_trips: u64,
    /// `[p50, p95, p99]` host latency under fault, seconds (served
    /// requests only); `None` when nothing was served.
    pub latency_s: Option<[f64; 3]>,
    pub elapsed_s: f64,
}

impl CellResult {
    /// Served fraction of accepted requests (1.0 for an empty cell).
    pub fn availability(&self) -> f64 {
        if self.accepted == 0 {
            return 1.0;
        }
        self.ok as f64 / self.accepted as f64
    }

    /// Typed-answer fraction of accepted requests — hung requests are
    /// the only thing that lowers this.
    pub fn answered(&self) -> f64 {
        if self.accepted == 0 {
            return 1.0;
        }
        (self.accepted - self.hung) as f64 / self.accepted as f64
    }

    /// Fraction of submitted requests shed at admission.
    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed_overload as f64 / self.submitted as f64
    }
}

/// The whole soak's results.
#[derive(Debug, Clone)]
pub struct SoakReport {
    pub cells: Vec<(SoakCell, CellResult)>,
    pub elapsed_s: f64,
}

impl SoakReport {
    /// Assert the availability contract across every cell (the
    /// `soak --check` gate): no hangs anywhere, full availability where
    /// the cell's faults are retryable, at least one respawn/shed where
    /// the scenario is built to force one.
    pub fn check(&self) -> Result<()> {
        for (spec, r) in &self.cells {
            ensure!(
                r.hung == 0,
                "cell {}: {} request(s) got no typed answer (hung)",
                r.name,
                r.hung
            );
            if spec.expect_full_availability {
                ensure!(
                    r.ok == r.accepted,
                    "cell {}: availability {:.4} < 1.0 ({} of {} accepted served; \
                     {} deadline, {} failed, {} shutdown)",
                    r.name,
                    r.availability(),
                    r.ok,
                    r.accepted,
                    r.deadline_expired,
                    r.failed,
                    r.shutdown
                );
            }
            if spec.expect_respawn {
                ensure!(
                    r.respawns >= 1,
                    "cell {}: expected a supervised respawn, saw none ({} panics)",
                    r.name,
                    r.worker_panics
                );
            }
            if spec.expect_overload_shed {
                ensure!(
                    r.shed_overload >= 1,
                    "cell {}: expected admission sheds, saw none (queue cap {})",
                    r.name,
                    spec.queue_cap
                );
            }
            if spec.expect_deadline_shed {
                ensure!(
                    r.deadline_expired >= 1,
                    "cell {}: expected deadline expiries, saw none",
                    r.name
                );
            }
        }
        Ok(())
    }

    /// Gate every full-availability cell on explicit SLO targets
    /// (`soak --check --slo ...`). Cells that shed by design (tight
    /// deadlines, tiny queues) are exempt: their availability is a
    /// scenario property, not a service-level promise.
    pub fn check_slo(&self, slo: &crate::telemetry::SloConfig) -> Result<()> {
        for (spec, r) in &self.cells {
            if !spec.expect_full_availability {
                continue;
            }
            let p99_us = r.latency_s.map(|p| (p[2] * 1e6).round() as u64);
            slo.check_observed(r.availability(), p99_us)
                .map_err(|e| e.context(format!("cell {}: SLO violated ({})", r.name, slo.spec())))?;
        }
        Ok(())
    }

    /// `BENCH_resilience.json` payload.
    pub fn to_json(&self) -> Json {
        let cells = self
            .cells
            .iter()
            .map(|(spec, r)| {
                let mut fields = vec![
                    ("name", Json::str(&r.name)),
                    ("chaos", Json::str(&r.spec)),
                    (
                        "deadline_ms",
                        spec.deadline_ms.map_or(Json::Null, |d| Json::num(d as f64)),
                    ),
                    ("queue_cap", Json::num(spec.queue_cap as f64)),
                    ("submitted", Json::num(r.submitted as f64)),
                    ("accepted", Json::num(r.accepted as f64)),
                    ("availability", Json::num(r.availability())),
                    ("answered", Json::num(r.answered())),
                    ("shed_rate", Json::num(r.shed_rate())),
                    ("ok", Json::num(r.ok as f64)),
                    ("shed_overload", Json::num(r.shed_overload as f64)),
                    ("deadline_expired", Json::num(r.deadline_expired as f64)),
                    ("failed", Json::num(r.failed as f64)),
                    ("shutdown", Json::num(r.shutdown as f64)),
                    ("hung", Json::num(r.hung as f64)),
                    ("retries", Json::num(r.retries as f64)),
                    ("requeues", Json::num(r.requeues as f64)),
                    ("worker_panics", Json::num(r.worker_panics as f64)),
                    ("respawns", Json::num(r.respawns as f64)),
                    ("breaker_trips", Json::num(r.breaker_trips as f64)),
                    ("elapsed_s", Json::num(r.elapsed_s)),
                ];
                if let Some([p50, p95, p99]) = r.latency_s {
                    fields.push(("p50_ms", Json::num(1e3 * p50)));
                    fields.push(("p95_ms", Json::num(1e3 * p95)));
                    fields.push(("p99_ms", Json::num(1e3 * p99)));
                }
                Json::obj(fields)
            })
            .collect();
        Json::obj(vec![
            ("cells", Json::Arr(cells)),
            ("elapsed_s", Json::num(self.elapsed_s)),
        ])
    }

    /// Human-readable soak table.
    pub fn render(&self) -> String {
        let mut s = String::from("=== chaos soak ===\n");
        s.push_str(&format!(
            "{:<18}{:>7}{:>7}{:>8}{:>8}{:>8}{:>8}{:>8}{:>9}{:>10}\n",
            "cell", "subm", "acc", "avail%", "shed", "ddl", "retry", "panic", "respawn", "p99 ms"
        ));
        for (_, r) in &self.cells {
            let p99 = r
                .latency_s
                .map(|p| format!("{:.2}", 1e3 * p[2]))
                .unwrap_or_else(|| "n/a".to_string());
            s.push_str(&format!(
                "{:<18}{:>7}{:>7}{:>8.1}{:>8}{:>8}{:>8}{:>8}{:>9}{:>10}\n",
                r.name,
                r.submitted,
                r.accepted,
                100.0 * r.availability(),
                r.shed_overload,
                r.deadline_expired,
                r.retries,
                r.worker_panics,
                r.respawns,
                p99
            ));
        }
        s.push_str(&format!("soak wall time: {:.2}s\n", self.elapsed_s));
        s
    }
}

/// Run the soak: one coordinator per cell, `cfg.n` open-loop submits
/// with seeded exponential inter-arrival gaps, every receiver collected
/// to a typed end (or counted hung).
pub fn run_soak(model: &KwsModel, cfg: &SoakConfig) -> Result<SoakReport> {
    ensure!(!cfg.cells.is_empty(), "soak needs at least one cell");
    ensure!(cfg.n > 0, "soak needs at least one request per cell");
    // One utterance set shared by every cell (the faults are the
    // variable under test, not the audio).
    let audios: Vec<Vec<f32>> = (0..cfg.n)
        .map(|i| dataset::synth_utterance(i % 12, cfg.seed ^ i as u64, model.audio_len, 0.3))
        .collect();
    let t0 = Instant::now();
    let mut cells = Vec::with_capacity(cfg.cells.len());
    for (ci, cell) in cfg.cells.iter().enumerate() {
        let opts = ServeOptions {
            macros: cfg.macros,
            batch: cfg.batch,
            // Small fixed linger: real coalescing without taxing the
            // deadline cells' budgets.
            linger_us: Some(200),
            queue_cap: cell.queue_cap,
            chaos: cell.chaos,
            max_attempts: cfg.max_attempts,
            ..Default::default()
        };
        let mut coord =
            Coordinator::start_with_options(model, OptLevel::FULL, cfg.workers, BackendKind::Fast, opts)?;
        let mut arrivals = Rng::new(cfg.seed.wrapping_add(0x50AC).wrapping_mul(ci as u64 + 1));
        let tc = Instant::now();
        let mut r = CellResult {
            name: cell.name.clone(),
            spec: cell.chaos.map_or_else(|| "none".to_string(), |p| p.spec()),
            submitted: 0,
            accepted: 0,
            shed_overload: 0,
            ok: 0,
            deadline_expired: 0,
            failed: 0,
            shutdown: 0,
            hung: 0,
            retries: 0,
            requeues: 0,
            worker_panics: 0,
            respawns: 0,
            breaker_trips: 0,
            latency_s: None,
            elapsed_s: 0.0,
        };
        let mut rxs = Vec::with_capacity(cfg.n);
        for (i, audio) in audios.iter().enumerate() {
            if cell.rate > 0.0 {
                // Exponential inter-arrival gaps -> a Poisson process.
                let u = arrivals.f64();
                let gap_s = -(1.0 - u).ln() / cell.rate;
                std::thread::sleep(Duration::from_secs_f64(gap_s.min(0.05)));
            }
            let req = InferenceRequest {
                id: i as u64,
                audio: audio.clone(),
                label: Some((i % 12) as i32),
                deadline: cell.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms)),
            };
            r.submitted += 1;
            match coord.submit(req) {
                Ok(rx) => {
                    r.accepted += 1;
                    rxs.push(rx);
                }
                Err(SubmitError::Overloaded { .. }) => r.shed_overload += 1,
                Err(SubmitError::Shutdown) => r.shutdown += 1,
            }
        }
        for rx in rxs {
            match rx.recv_timeout(cfg.answer_timeout) {
                Ok(Ok(_resp)) => r.ok += 1,
                Ok(Err(ServeError::DeadlineExceeded { .. })) => r.deadline_expired += 1,
                Ok(Err(ServeError::Shutdown)) => r.shutdown += 1,
                Ok(Err(_)) => r.failed += 1,
                // Timeout or a dropped channel: the availability
                // contract is broken either way.
                Err(_) => r.hung += 1,
            }
        }
        use std::sync::atomic::Ordering;
        r.retries = coord.stats.retries.load(Ordering::Relaxed);
        r.requeues = coord.stats.requeues.load(Ordering::Relaxed);
        r.worker_panics = coord.stats.worker_panics.load(Ordering::Relaxed);
        r.respawns = coord.stats.respawns.load(Ordering::Relaxed);
        r.breaker_trips = coord.stats.breaker_trips.load(Ordering::Relaxed);
        r.latency_s = coord.stats.host_latency_percentiles();
        r.elapsed_s = tc.elapsed().as_secs_f64();
        coord.shutdown();
        cells.push((cell.clone(), r));
    }
    Ok(SoakReport { cells, elapsed_s: t0.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(name: &str) -> CellResult {
        CellResult {
            name: name.to_string(),
            spec: "none".to_string(),
            submitted: 10,
            accepted: 10,
            shed_overload: 0,
            ok: 10,
            deadline_expired: 0,
            failed: 0,
            shutdown: 0,
            hung: 0,
            retries: 0,
            requeues: 0,
            worker_panics: 0,
            respawns: 0,
            breaker_trips: 0,
            latency_s: Some([0.001, 0.002, 0.003]),
            elapsed_s: 0.1,
        }
    }

    #[test]
    fn standard_grid_covers_every_fault_story() {
        let cfg = SoakConfig::standard();
        let names: Vec<&str> = cfg.cells.iter().map(|c| c.name.as_str()).collect();
        for want in
            ["baseline", "transient", "panic", "latency_deadline", "stall_shed", "overload"]
        {
            assert!(names.contains(&want), "missing cell {want}");
        }
        assert!(cfg.cells.iter().any(|c| c.expect_respawn));
        assert!(cfg.cells.iter().any(|c| c.expect_overload_shed));
        assert!(cfg.cells.iter().any(|c| c.expect_deadline_shed));
        // quick() shrinks the load, never the scenario coverage.
        assert_eq!(SoakConfig::quick().cells.len(), cfg.cells.len());
        assert!(SoakConfig::quick().n < cfg.n);
    }

    #[test]
    fn report_check_enforces_the_availability_contract() {
        let spec = SoakCell::new("clean", None);
        let ok = SoakReport { cells: vec![(spec.clone(), result("clean"))], elapsed_s: 0.1 };
        ok.check().unwrap();
        // A hung request fails the check no matter the cell.
        let mut hung = result("clean");
        hung.hung = 1;
        let bad = SoakReport { cells: vec![(spec.clone(), hung)], elapsed_s: 0.1 };
        assert!(bad.check().unwrap_err().to_string().contains("hung"));
        // Lost availability fails where the cell demands 100%.
        let mut lossy = result("clean");
        lossy.ok = 9;
        lossy.failed = 1;
        let bad = SoakReport { cells: vec![(spec.clone(), lossy.clone())], elapsed_s: 0.1 };
        assert!(bad.check().unwrap_err().to_string().contains("availability"));
        // ...but is fine where the scenario sheds by design.
        let tolerant = SoakCell { expect_full_availability: false, ..spec };
        let mut shed = lossy;
        shed.failed = 0;
        shed.deadline_expired = 1;
        SoakReport { cells: vec![(tolerant, shed)], elapsed_s: 0.1 }.check().unwrap();
    }

    #[test]
    fn slo_gate_applies_to_full_availability_cells_only() {
        use crate::telemetry::SloConfig;
        let strict = SloConfig::parse_spec("p99_ms=2.5,availability=0.999").unwrap();
        let clean = SoakCell::new("clean", None);
        // result(): 10/10 served, p99 = 3 ms -> availability passes,
        // p99 fails the 2.5 ms target.
        let report =
            SoakReport { cells: vec![(clean.clone(), result("clean"))], elapsed_s: 0.1 };
        let err = report.check_slo(&strict).unwrap_err();
        assert!(format!("{err:#}").contains("p99"), "{err:#}");
        // A looser p99 target passes.
        let loose = SloConfig::parse_spec("p99_ms=5,availability=0.999").unwrap();
        report.check_slo(&loose).unwrap();
        // Lost availability trips the availability target...
        let mut lossy = result("lossy");
        lossy.ok = 9;
        lossy.failed = 1;
        let bad = SoakReport { cells: vec![(clean.clone(), lossy.clone())], elapsed_s: 0.1 };
        let err = bad.check_slo(&loose).unwrap_err();
        assert!(format!("{err:#}").contains("availability"), "{err:#}");
        // ...but shed-by-design cells are exempt from the gate.
        let tolerant = SoakCell { expect_full_availability: false, ..clean };
        SoakReport { cells: vec![(tolerant, lossy)], elapsed_s: 0.1 }.check_slo(&loose).unwrap();
    }

    #[test]
    fn report_ratios_and_json_roundtrip() {
        let mut r = result("overload");
        r.submitted = 12;
        r.accepted = 8;
        r.shed_overload = 4;
        r.ok = 8;
        assert!((r.availability() - 1.0).abs() < 1e-12);
        assert!((r.shed_rate() - 4.0 / 12.0).abs() < 1e-12);
        assert!((r.answered() - 1.0).abs() < 1e-12);
        let spec = SoakCell { queue_cap: 4, ..SoakCell::new("overload", None) };
        let report = SoakReport { cells: vec![(spec, r)], elapsed_s: 0.2 };
        let parsed = Json::parse(&report.to_json().to_string()).unwrap();
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.get("shed_overload").unwrap().as_f64().unwrap(), 4.0);
        assert_eq!(c.get("availability").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(c.get("queue_cap").unwrap().as_f64().unwrap(), 4.0);
        let text = report.render();
        assert!(text.contains("overload"), "{text}");
        assert!(text.contains("100.0"), "{text}");
    }
}
