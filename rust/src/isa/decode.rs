//! RV32IM + CIM instruction decoder (the core's decode stage).

use anyhow::{bail, Result};

use super::cim::{CimInstr, CIM_OPCODE};
use super::rv32::*;

#[inline]
fn rd(w: u32) -> Reg {
    Reg(((w >> 7) & 0x1F) as u8)
}

#[inline]
fn rs1(w: u32) -> Reg {
    Reg(((w >> 15) & 0x1F) as u8)
}

#[inline]
fn rs2(w: u32) -> Reg {
    Reg(((w >> 20) & 0x1F) as u8)
}

#[inline]
fn f3(w: u32) -> u32 {
    (w >> 12) & 0x7
}

#[inline]
fn f7(w: u32) -> u32 {
    w >> 25
}

#[inline]
fn imm_i(w: u32) -> i32 {
    (w as i32) >> 20
}

#[inline]
fn imm_s(w: u32) -> i32 {
    (((w & 0xFE00_0000) as i32) >> 20) | (((w >> 7) & 0x1F) as i32)
}

#[inline]
fn imm_b(w: u32) -> i32 {
    (((w & 0x8000_0000) as i32) >> 19)
        | (((w >> 7) & 1) as i32) << 11
        | (((w >> 25) & 0x3F) as i32) << 5
        | (((w >> 8) & 0xF) as i32) << 1
}

#[inline]
fn imm_u(w: u32) -> i32 {
    (w >> 12) as i32
}

#[inline]
fn imm_j(w: u32) -> i32 {
    (((w & 0x8000_0000) as i32) >> 11)
        | ((w & 0x000F_F000) as i32)
        | (((w >> 20) & 1) as i32) << 11
        | (((w >> 21) & 0x3FF) as i32) << 1
}

/// Decode one 32-bit instruction word. Unknown encodings are an error
/// (the core raises an illegal-instruction trap).
pub fn decode(w: u32) -> Result<Instr> {
    let op = w & 0x7F;
    Ok(match op {
        0x37 => Instr::Lui { rd: rd(w), imm: imm_u(w) },
        0x17 => Instr::Auipc { rd: rd(w), imm: imm_u(w) },
        0x6F => Instr::Jal { rd: rd(w), offset: imm_j(w) },
        0x67 => {
            if f3(w) != 0 {
                bail!("illegal jalr funct3 {}", f3(w));
            }
            Instr::Jalr { rd: rd(w), rs1: rs1(w), offset: imm_i(w) }
        }
        0x63 => {
            let kind = match f3(w) {
                0 => BranchKind::Beq,
                1 => BranchKind::Bne,
                4 => BranchKind::Blt,
                5 => BranchKind::Bge,
                6 => BranchKind::Bltu,
                7 => BranchKind::Bgeu,
                x => bail!("illegal branch funct3 {x}"),
            };
            Instr::Branch { kind, rs1: rs1(w), rs2: rs2(w), offset: imm_b(w) }
        }
        0x03 => {
            let kind = match f3(w) {
                0 => LoadKind::Lb,
                1 => LoadKind::Lh,
                2 => LoadKind::Lw,
                4 => LoadKind::Lbu,
                5 => LoadKind::Lhu,
                x => bail!("illegal load funct3 {x}"),
            };
            Instr::Load { kind, rd: rd(w), rs1: rs1(w), offset: imm_i(w) }
        }
        0x23 => {
            let kind = match f3(w) {
                0 => StoreKind::Sb,
                1 => StoreKind::Sh,
                2 => StoreKind::Sw,
                x => bail!("illegal store funct3 {x}"),
            };
            Instr::Store { kind, rs1: rs1(w), rs2: rs2(w), offset: imm_s(w) }
        }
        0x13 => {
            let op = match f3(w) {
                0b000 => AluOp::Add,
                0b001 => {
                    if f7(w) != 0 {
                        bail!("illegal slli funct7");
                    }
                    AluOp::Sll
                }
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b101 => match f7(w) {
                    0x00 => AluOp::Srl,
                    0x20 => AluOp::Sra,
                    x => bail!("illegal shift funct7 {x:#x}"),
                },
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                _ => unreachable!(),
            };
            let imm = if matches!(op, AluOp::Sll | AluOp::Srl | AluOp::Sra) {
                ((w >> 20) & 0x1F) as i32
            } else {
                imm_i(w)
            };
            Instr::OpImm { op, rd: rd(w), rs1: rs1(w), imm }
        }
        0x33 => {
            if f7(w) == 0x01 {
                let op = match f3(w) {
                    0 => MulOp::Mul,
                    1 => MulOp::Mulh,
                    2 => MulOp::Mulhsu,
                    3 => MulOp::Mulhu,
                    4 => MulOp::Div,
                    5 => MulOp::Divu,
                    6 => MulOp::Rem,
                    7 => MulOp::Remu,
                    _ => unreachable!(),
                };
                Instr::MulDiv { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) }
            } else {
                let op = match (f3(w), f7(w)) {
                    (0b000, 0x00) => AluOp::Add,
                    (0b000, 0x20) => AluOp::Sub,
                    (0b001, 0x00) => AluOp::Sll,
                    (0b010, 0x00) => AluOp::Slt,
                    (0b011, 0x00) => AluOp::Sltu,
                    (0b100, 0x00) => AluOp::Xor,
                    (0b101, 0x00) => AluOp::Srl,
                    (0b101, 0x20) => AluOp::Sra,
                    (0b110, 0x00) => AluOp::Or,
                    (0b111, 0x00) => AluOp::And,
                    (a, b) => bail!("illegal OP funct3/funct7 {a}/{b:#x}"),
                };
                Instr::Op { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) }
            }
        }
        0x0F => Instr::Fence,
        0x73 => match f3(w) {
            0 => match w >> 20 {
                0 => Instr::Ecall,
                1 => Instr::Ebreak,
                x => bail!("illegal SYSTEM imm {x:#x}"),
            },
            1 => Instr::Csr { op: CsrOp::Rw, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            2 => Instr::Csr { op: CsrOp::Rs, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            3 => Instr::Csr { op: CsrOp::Rc, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            5 => Instr::Csr { op: CsrOp::Rwi, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            6 => Instr::Csr { op: CsrOp::Rsi, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            7 => Instr::Csr { op: CsrOp::Rci, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            x => bail!("illegal SYSTEM funct3 {x}"),
        },
        CIM_OPCODE => Instr::Cim(
            CimInstr::decode(w).ok_or_else(|| anyhow::anyhow!("illegal CIM funct2"))?,
        ),
        x => bail!("unknown opcode {x:#09b}"),
    })
}

#[cfg(test)]
mod tests {
    use super::super::encode::encode;
    use super::*;

    #[test]
    fn decode_known_words() {
        // addi a0, zero, 42
        assert_eq!(
            decode(0x02A0_0513).unwrap(),
            Instr::OpImm { op: AluOp::Add, rd: Reg::A0, rs1: Reg::ZERO, imm: 42 }
        );
        // lw t0, 8(sp)
        assert_eq!(
            decode(0x0081_2283).unwrap(),
            Instr::Load { kind: LoadKind::Lw, rd: Reg::T0, rs1: Reg::SP, offset: 8 }
        );
        // ecall / ebreak
        assert_eq!(decode(0x0000_0073).unwrap(), Instr::Ecall);
        assert_eq!(decode(0x0010_0073).unwrap(), Instr::Ebreak);
    }

    #[test]
    fn negative_immediates() {
        // addi sp, sp, -16
        let i = decode(0xFF01_0113).unwrap();
        assert_eq!(
            i,
            Instr::OpImm { op: AluOp::Add, rd: Reg::SP, rs1: Reg::SP, imm: -16 }
        );
        // Round-trip.
        assert_eq!(decode(encode(&i).unwrap()).unwrap(), i);
    }

    #[test]
    fn branch_offset_sign() {
        let i = Instr::Branch {
            kind: BranchKind::Bne,
            rs1: Reg::T0,
            rs2: Reg::ZERO,
            offset: -8,
        };
        assert_eq!(decode(encode(&i).unwrap()).unwrap(), i);
    }

    #[test]
    fn jal_wide_offsets() {
        for off in [-1048576, -4096, -2, 0, 2, 4094, 1048574] {
            let i = Instr::Jal { rd: Reg::RA, offset: off };
            assert_eq!(decode(encode(&i).unwrap()).unwrap(), i, "offset {off}");
        }
    }

    #[test]
    fn rejects_unknown() {
        assert!(decode(0xFFFF_FFFF).is_err());
        assert!(decode(0x0000_0000).is_err());
    }
}
