//! RV32IM + CIM instruction encoder (the assembler's backend).

use anyhow::{bail, Result};

use super::rv32::*;

fn r(rd: u32, f3: u32, rs1: u32, rs2: u32, f7: u32, op: u32) -> u32 {
    (f7 << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) | (rd << 7) | op
}

fn i(rd: u32, f3: u32, rs1: u32, imm: i32, op: u32) -> Result<u32> {
    if !(-2048..=2047).contains(&imm) {
        bail!("I-type immediate {imm} out of range");
    }
    Ok((((imm as u32) & 0xFFF) << 20) | (rs1 << 15) | (f3 << 12) | (rd << 7) | op)
}

fn s(f3: u32, rs1: u32, rs2: u32, imm: i32, op: u32) -> Result<u32> {
    if !(-2048..=2047).contains(&imm) {
        bail!("S-type immediate {imm} out of range");
    }
    let u = imm as u32;
    Ok((((u >> 5) & 0x7F) << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) | ((u & 0x1F) << 7) | op)
}

fn b(f3: u32, rs1: u32, rs2: u32, off: i32, op: u32) -> Result<u32> {
    if off % 2 != 0 || !(-4096..=4094).contains(&off) {
        bail!("branch offset {off} invalid");
    }
    let u = off as u32;
    Ok((((u >> 12) & 1) << 31)
        | (((u >> 5) & 0x3F) << 25)
        | (rs2 << 20)
        | (rs1 << 15)
        | (f3 << 12)
        | (((u >> 1) & 0xF) << 8)
        | (((u >> 11) & 1) << 7)
        | op)
}

fn u_type(rd: u32, imm: i32, op: u32) -> u32 {
    ((imm as u32) << 12) | (rd << 7) | op
}

fn j(rd: u32, off: i32, op: u32) -> Result<u32> {
    if off % 2 != 0 || !(-(1 << 20)..(1 << 20)).contains(&off) {
        bail!("jal offset {off} invalid");
    }
    let u = off as u32;
    Ok((((u >> 20) & 1) << 31)
        | (((u >> 1) & 0x3FF) << 21)
        | (((u >> 11) & 1) << 20)
        | (((u >> 12) & 0xFF) << 12)
        | (rd << 7)
        | op)
}

/// Encode a decoded instruction back to its 32-bit word.
pub fn encode(instr: &Instr) -> Result<u32> {
    use Instr::*;
    Ok(match *instr {
        Lui { rd, imm } => u_type(rd.0 as u32, imm, 0x37),
        Auipc { rd, imm } => u_type(rd.0 as u32, imm, 0x17),
        Jal { rd, offset } => j(rd.0 as u32, offset, 0x6F)?,
        Jalr { rd, rs1, offset } => i(rd.0 as u32, 0, rs1.0 as u32, offset, 0x67)?,
        Branch { kind, rs1, rs2, offset } => {
            let f3 = match kind {
                BranchKind::Beq => 0,
                BranchKind::Bne => 1,
                BranchKind::Blt => 4,
                BranchKind::Bge => 5,
                BranchKind::Bltu => 6,
                BranchKind::Bgeu => 7,
            };
            b(f3, rs1.0 as u32, rs2.0 as u32, offset, 0x63)?
        }
        Load { kind, rd, rs1, offset } => {
            let f3 = match kind {
                LoadKind::Lb => 0,
                LoadKind::Lh => 1,
                LoadKind::Lw => 2,
                LoadKind::Lbu => 4,
                LoadKind::Lhu => 5,
            };
            i(rd.0 as u32, f3, rs1.0 as u32, offset, 0x03)?
        }
        Store { kind, rs1, rs2, offset } => {
            let f3 = match kind {
                StoreKind::Sb => 0,
                StoreKind::Sh => 1,
                StoreKind::Sw => 2,
            };
            s(f3, rs1.0 as u32, rs2.0 as u32, offset, 0x23)?
        }
        OpImm { op, rd, rs1, imm } => {
            let (f3, shift_f7) = match op {
                AluOp::Add => (0b000, None),
                AluOp::Sll => (0b001, Some(0)),
                AluOp::Slt => (0b010, None),
                AluOp::Sltu => (0b011, None),
                AluOp::Xor => (0b100, None),
                AluOp::Srl => (0b101, Some(0)),
                AluOp::Sra => (0b101, Some(0x20)),
                AluOp::Or => (0b110, None),
                AluOp::And => (0b111, None),
                AluOp::Sub => bail!("subi does not exist (use addi with -imm)"),
            };
            match shift_f7 {
                None => i(rd.0 as u32, f3, rs1.0 as u32, imm, 0x13)?,
                Some(f7) => {
                    if !(0..32).contains(&imm) {
                        bail!("shift amount {imm} out of range");
                    }
                    r(rd.0 as u32, f3, rs1.0 as u32, imm as u32, f7, 0x13)
                }
            }
        }
        Op { op, rd, rs1, rs2 } => {
            let (f3, f7) = match op {
                AluOp::Add => (0b000, 0x00),
                AluOp::Sub => (0b000, 0x20),
                AluOp::Sll => (0b001, 0x00),
                AluOp::Slt => (0b010, 0x00),
                AluOp::Sltu => (0b011, 0x00),
                AluOp::Xor => (0b100, 0x00),
                AluOp::Srl => (0b101, 0x00),
                AluOp::Sra => (0b101, 0x20),
                AluOp::Or => (0b110, 0x00),
                AluOp::And => (0b111, 0x00),
            };
            r(rd.0 as u32, f3, rs1.0 as u32, rs2.0 as u32, f7, 0x33)
        }
        MulDiv { op, rd, rs1, rs2 } => {
            let f3 = match op {
                MulOp::Mul => 0,
                MulOp::Mulh => 1,
                MulOp::Mulhsu => 2,
                MulOp::Mulhu => 3,
                MulOp::Div => 4,
                MulOp::Divu => 5,
                MulOp::Rem => 6,
                MulOp::Remu => 7,
            };
            r(rd.0 as u32, f3, rs1.0 as u32, rs2.0 as u32, 0x01, 0x33)
        }
        Fence => 0x0000_000F,
        Ecall => 0x0000_0073,
        Ebreak => 0x0010_0073,
        Csr { op, rd, rs1, csr } => {
            let f3 = match op {
                CsrOp::Rw => 1,
                CsrOp::Rs => 2,
                CsrOp::Rc => 3,
                CsrOp::Rwi => 5,
                CsrOp::Rsi => 6,
                CsrOp::Rci => 7,
            };
            ((csr as u32) << 20) | ((rs1.0 as u32) << 15) | (f3 << 12) | ((rd.0 as u32) << 7) | 0x73
        }
        Cim(c) => {
            c.validate()?;
            c.encode()
        }
    })
}
