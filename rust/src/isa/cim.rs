//! The CIM-type instruction extension (paper Fig. 4).
//!
//! Opcode `1111110` (0x7E). Three instructions, all executed atomically in
//! a single cycle by the modified core:
//!
//! * `cim_conv` — shift 32 bits of feature-map SRAM into the macro's input
//!   buffer, fire the full-array MAC, and store a 32-bit word of the
//!   binarized output latch back to feature-map SRAM.
//! * `cim_r`    — read 32 weight bits out of the macro into SRAM.
//! * `cim_w`    — write 32 weight bits from SRAM into the macro.
//!
//! ## Encoding (documented deviation from Fig. 4)
//!
//! Fig. 4's field diagram is internally inconsistent in the published PDF:
//! the bit ranges for rs1/rs2/funct2 overlap, "funct2" carries the values
//! 0x01/0x10/0x11 (only readable as *binary* 2-bit values), and nothing
//! says how the 256-bit CIM output reaches SRAM 32 bits at a time. We keep
//! the published field order and semantics and pin down a self-consistent
//! layout that makes the hidden sequencing explicit:
//!
//! ```text
//!  31     25 24      17 16 15 14 13 12 11 10    8   7   6      0
//! +---------+----------+-----+-----+-----+--------+----+--------+
//! | imm_d   | imm_s    | rs2'| rs1'| f2  |   wd   | sh | opcode |
//! | [6:0]   | [7:0]    |     |     |     |        |    | 1111110|
//! +---------+----------+-----+-----+-----+--------+----+--------+
//! ```
//!
//! * `rs1'`/`rs2'` are 2-bit selectors over x10..x13 (a0..a3): the
//!   compiler pins CIM base addresses to the a-register window, which is
//!   what lets two bases, two offsets, a word select and a function field
//!   coexist in 32 bits.
//! * `imm_s`/`imm_d` are unsigned *word* offsets (the CIM port moves
//!   32-bit words): 8 bits source, 7 bits destination.
//! * `wd` (3 bits) selects the 32-lane slice of the 256-bit output latch
//!   to store — the paper's "store CIM_out[31:0]" issued 8 times per row
//!   with an implicit word counter; we carry the counter in the encoding.
//! * `sh` (1 bit) gates the input-buffer shift, so output-word drains that
//!   outnumber input-word fills (c_out > c_in layers) don't corrupt the
//!   window being assembled for the next row.
//!
//! ### `cim_conv` micro-order (single cycle)
//!   1. if `sh`: shift FM-SRAM word at `rs1 + 4*imm_s` into CIM_in
//!      (1024-bit shift register, 32 bits per shift, LSW-first)
//!   2. if `wd == 0`: fire the full-array MAC and latch all SA outputs
//!   3. store latch word `wd` to FM-SRAM at `rs2 + 4*imm_d`
//!
//! Firing on `wd == 0` (after the shift) lets the compiler interleave the
//! next row's fills with the previous row's drains — the paper's row-wise
//! pipeline — while keeping "one instruction, one cycle, one macro event".
//!
//! ### `cim_w` / `cim_r`
//! `cim_w`: SRAM word at `rs1 + 4*imm_s` -> macro weight word at
//! `rs2_val + imm_d` (rs2 carries a *weight-array word index* base).
//! `cim_r` is the exact inverse (macro word at `rs1_val + imm_s` -> SRAM
//! at `rs2 + 4*imm_d`). `wd`/`sh` must be zero for both.

use std::fmt;

use super::rv32::Reg;

/// CIM extension major opcode (bits 6:0).
pub const CIM_OPCODE: u32 = 0b111_1110;

/// funct2 values (bits 12:11).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CimFunct {
    /// 0b01: shift-in + full-array MAC + store output word.
    Conv,
    /// 0b10: macro -> SRAM weight readback.
    Read,
    /// 0b11: SRAM -> macro weight write.
    Write,
}

impl CimFunct {
    pub fn bits(self) -> u32 {
        match self {
            CimFunct::Conv => 0b01,
            CimFunct::Read => 0b10,
            CimFunct::Write => 0b11,
        }
    }

    pub fn from_bits(b: u32) -> Option<Self> {
        match b {
            0b01 => Some(CimFunct::Conv),
            0b10 => Some(CimFunct::Read),
            0b11 => Some(CimFunct::Write),
            _ => None,
        }
    }
}

/// A decoded CIM-type instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CimInstr {
    pub funct: CimFunct,
    /// Source base register (a0..a3).
    pub rs1: Reg,
    /// Destination base register (a0..a3).
    pub rs2: Reg,
    /// Source word offset (8 bits unsigned).
    pub imm_s: u16,
    /// Destination word offset (7 bits unsigned).
    pub imm_d: u16,
    /// Output latch word select (cim_conv only, 3 bits).
    pub wd: u8,
    /// Input-buffer shift enable (cim_conv only).
    pub sh: bool,
}

/// The a-register window addressable by the 2-bit selectors.
pub const CIM_REG_WINDOW: [Reg; 4] = [Reg::A0, Reg::A1, Reg::A2, Reg::A3];

impl CimInstr {
    pub const IMM_S_MAX: u16 = 0xFF;
    pub const IMM_D_MAX: u16 = 0x7F;

    /// Validate field ranges (used by the assembler and the prop tests).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            CIM_REG_WINDOW.contains(&self.rs1),
            "cim rs1 must be a0..a3, got {}",
            self.rs1
        );
        anyhow::ensure!(
            CIM_REG_WINDOW.contains(&self.rs2),
            "cim rs2 must be a0..a3, got {}",
            self.rs2
        );
        anyhow::ensure!(self.imm_s <= Self::IMM_S_MAX, "imm_s out of range");
        anyhow::ensure!(self.imm_d <= Self::IMM_D_MAX, "imm_d out of range");
        anyhow::ensure!(self.wd < 8, "wd out of range");
        if self.funct != CimFunct::Conv {
            anyhow::ensure!(self.wd == 0 && !self.sh, "wd/sh are cim_conv-only fields");
        }
        Ok(())
    }

    fn reg_sel(r: Reg) -> u32 {
        (r.0 - 10) as u32
    }

    fn sel_reg(bits: u32) -> Reg {
        Reg(10 + (bits & 0b11) as u8)
    }

    /// Encode to the 32-bit instruction word.
    pub fn encode(&self) -> u32 {
        ((self.imm_d as u32 & 0x7F) << 25)
            | ((self.imm_s as u32 & 0xFF) << 17)
            | (Self::reg_sel(self.rs2) << 15)
            | (Self::reg_sel(self.rs1) << 13)
            | (self.funct.bits() << 11)
            | ((self.wd as u32 & 0x7) << 8)
            | ((self.sh as u32) << 7)
            | CIM_OPCODE
    }

    /// Decode from a 32-bit instruction word (must have the CIM opcode).
    pub fn decode(word: u32) -> Option<Self> {
        if word & 0x7F != CIM_OPCODE {
            return None;
        }
        let funct = CimFunct::from_bits((word >> 11) & 0b11)?;
        Some(CimInstr {
            funct,
            rs1: Self::sel_reg(word >> 13),
            rs2: Self::sel_reg(word >> 15),
            imm_s: ((word >> 17) & 0xFF) as u16,
            imm_d: ((word >> 25) & 0x7F) as u16,
            wd: ((word >> 8) & 0x7) as u8,
            sh: (word >> 7) & 1 == 1,
        })
    }

    /// Convenience constructor for `cim_conv`.
    pub fn conv(rs1: Reg, imm_s: u16, rs2: Reg, imm_d: u16, wd: u8, sh: bool) -> Self {
        CimInstr { funct: CimFunct::Conv, rs1, rs2, imm_s, imm_d, wd, sh }
    }

    /// Convenience constructor for `cim_w`.
    pub fn write(rs1: Reg, imm_s: u16, rs2: Reg, imm_d: u16) -> Self {
        CimInstr { funct: CimFunct::Write, rs1, rs2, imm_s, imm_d, wd: 0, sh: false }
    }

    /// Convenience constructor for `cim_r`.
    pub fn read(rs1: Reg, imm_s: u16, rs2: Reg, imm_d: u16) -> Self {
        CimInstr { funct: CimFunct::Read, rs1, rs2, imm_s, imm_d, wd: 0, sh: false }
    }
}

impl fmt::Display for CimInstr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.funct {
            CimFunct::Conv => write!(
                f,
                "cim_conv {}+{}, {}+{}, wd={}{}",
                self.rs1,
                self.imm_s,
                self.rs2,
                self.imm_d,
                self.wd,
                if self.sh { ", sh" } else { "" }
            ),
            CimFunct::Read => {
                write!(f, "cim_r {}+{}, {}+{}", self.rs1, self.imm_s, self.rs2, self.imm_d)
            }
            CimFunct::Write => {
                write!(f, "cim_w {}+{}, {}+{}", self.rs1, self.imm_s, self.rs2, self.imm_d)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip_field_sweep() {
        for funct in [CimFunct::Conv, CimFunct::Read, CimFunct::Write] {
            let (wds, shs): (&[u8], &[bool]) = if funct == CimFunct::Conv {
                (&[0, 1, 3, 7], &[false, true])
            } else {
                (&[0], &[false])
            };
            for rs1 in CIM_REG_WINDOW {
                for rs2 in CIM_REG_WINDOW {
                    for &imm_s in &[0u16, 1, 31, 32, 255] {
                        for &imm_d in &[0u16, 17, 127] {
                            for &wd in wds {
                                for &sh in shs {
                                    let i = CimInstr { funct, rs1, rs2, imm_s, imm_d, wd, sh };
                                    i.validate().unwrap();
                                    let w = i.encode();
                                    assert_eq!(w & 0x7F, CIM_OPCODE);
                                    assert_eq!(CimInstr::decode(w), Some(i));
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn rejects_wrong_opcode() {
        assert!(CimInstr::decode(0x0000_0013).is_none()); // addi x0,x0,0
    }

    #[test]
    fn funct2_values_match_paper_reading() {
        // Fig. 4 lists 0x01 / 0x10 / 0x11 — read as binary 2-bit values.
        assert_eq!(CimFunct::Conv.bits(), 0b01);
        assert_eq!(CimFunct::Read.bits(), 0b10);
        assert_eq!(CimFunct::Write.bits(), 0b11);
    }

    #[test]
    fn validate_rejects_bad_fields() {
        let mut i = CimInstr::conv(Reg::A0, 0, Reg::A1, 0, 0, true);
        i.rs1 = Reg::T0;
        assert!(i.validate().is_err());
        let mut j = CimInstr::write(Reg::A0, 0, Reg::A1, 0);
        j.sh = true;
        assert!(j.validate().is_err());
        let mut k = CimInstr::conv(Reg::A0, 0, Reg::A1, 0, 0, false);
        k.imm_d = 0x80;
        assert!(k.validate().is_err());
    }
}
