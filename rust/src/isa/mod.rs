//! The CIMR-V instruction set: RV32IM plus the CIM-type extension.
//!
//! The paper runs a modified ibex (RV32IMC) core; we implement RV32I + M
//! (the compiler emits no compressed instructions) and the paper's three
//! CIM instructions (Fig. 4). [`decode`]/[`encode`] are exact inverses —
//! a property test in `rust/tests/proptests.rs` round-trips the whole space.

pub mod cim;
pub mod decode;
pub mod disasm;
pub mod encode;
pub mod rv32;

pub use cim::{CimFunct, CimInstr, CIM_OPCODE};
pub use decode::decode;
pub use disasm::disasm;
pub use encode::encode;
pub use rv32::{Instr, Reg};
