//! Disassembler — trace output, the `disasm` CLI subcommand, and the
//! isa_playground example.

use super::rv32::*;

/// Render one decoded instruction in assembler syntax.
pub fn disasm(i: &Instr) -> String {
    use Instr::*;
    match *i {
        Lui { rd, imm } => format!("lui {rd}, {:#x}", imm),
        Auipc { rd, imm } => format!("auipc {rd}, {:#x}", imm),
        Jal { rd, offset } => format!("jal {rd}, {offset}"),
        Jalr { rd, rs1, offset } => format!("jalr {rd}, {offset}({rs1})"),
        Branch { kind, rs1, rs2, offset } => {
            let n = match kind {
                BranchKind::Beq => "beq",
                BranchKind::Bne => "bne",
                BranchKind::Blt => "blt",
                BranchKind::Bge => "bge",
                BranchKind::Bltu => "bltu",
                BranchKind::Bgeu => "bgeu",
            };
            format!("{n} {rs1}, {rs2}, {offset}")
        }
        Load { kind, rd, rs1, offset } => {
            let n = match kind {
                LoadKind::Lb => "lb",
                LoadKind::Lh => "lh",
                LoadKind::Lw => "lw",
                LoadKind::Lbu => "lbu",
                LoadKind::Lhu => "lhu",
            };
            format!("{n} {rd}, {offset}({rs1})")
        }
        Store { kind, rs1, rs2, offset } => {
            let n = match kind {
                StoreKind::Sb => "sb",
                StoreKind::Sh => "sh",
                StoreKind::Sw => "sw",
            };
            format!("{n} {rs2}, {offset}({rs1})")
        }
        OpImm { op, rd, rs1, imm } => {
            let n = match op {
                AluOp::Add => "addi",
                AluOp::Sll => "slli",
                AluOp::Slt => "slti",
                AluOp::Sltu => "sltiu",
                AluOp::Xor => "xori",
                AluOp::Srl => "srli",
                AluOp::Sra => "srai",
                AluOp::Or => "ori",
                AluOp::And => "andi",
                AluOp::Sub => "sub?",
            };
            format!("{n} {rd}, {rs1}, {imm}")
        }
        Op { op, rd, rs1, rs2 } => {
            let n = match op {
                AluOp::Add => "add",
                AluOp::Sub => "sub",
                AluOp::Sll => "sll",
                AluOp::Slt => "slt",
                AluOp::Sltu => "sltu",
                AluOp::Xor => "xor",
                AluOp::Srl => "srl",
                AluOp::Sra => "sra",
                AluOp::Or => "or",
                AluOp::And => "and",
            };
            format!("{n} {rd}, {rs1}, {rs2}")
        }
        MulDiv { op, rd, rs1, rs2 } => {
            let n = match op {
                MulOp::Mul => "mul",
                MulOp::Mulh => "mulh",
                MulOp::Mulhsu => "mulhsu",
                MulOp::Mulhu => "mulhu",
                MulOp::Div => "div",
                MulOp::Divu => "divu",
                MulOp::Rem => "rem",
                MulOp::Remu => "remu",
            };
            format!("{n} {rd}, {rs1}, {rs2}")
        }
        Fence => "fence".to_string(),
        Ecall => "ecall".to_string(),
        Ebreak => "ebreak".to_string(),
        Csr { op, rd, rs1, csr } => {
            let n = match op {
                CsrOp::Rw => "csrrw",
                CsrOp::Rs => "csrrs",
                CsrOp::Rc => "csrrc",
                CsrOp::Rwi => "csrrwi",
                CsrOp::Rsi => "csrrsi",
                CsrOp::Rci => "csrrci",
            };
            format!("{n} {rd}, {csr:#x}, {rs1}")
        }
        Cim(c) => c.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::super::decode::decode;
    use super::*;

    #[test]
    fn renders_common_forms() {
        assert_eq!(disasm(&decode(0x02A0_0513).unwrap()), "addi a0, zero, 42");
        assert_eq!(disasm(&decode(0x0000_0073).unwrap()), "ecall");
    }

    #[test]
    fn renders_cim() {
        use crate::isa::cim::{CimFunct, CimInstr};
        let c = CimInstr {
            funct: CimFunct::Conv,
            rs1: Reg::A0,
            rs2: Reg::A1,
            imm_s: 3,
            imm_d: 7,
            wd: 1,
            sh: true,
        };
        assert_eq!(disasm(&Instr::Cim(c)), "cim_conv a0+3, a1+7, wd=1, sh");
    }
}
