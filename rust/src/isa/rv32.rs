//! RV32IM instruction forms (the base ISA of the modified ibex core).

use std::fmt;

use super::cim::CimInstr;

/// An architectural register x0..x31.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Reg(pub u8);

impl Reg {
    pub const ZERO: Reg = Reg(0);
    pub const RA: Reg = Reg(1);
    pub const SP: Reg = Reg(2);
    pub const GP: Reg = Reg(3);
    pub const TP: Reg = Reg(4);
    pub const T0: Reg = Reg(5);
    pub const T1: Reg = Reg(6);
    pub const T2: Reg = Reg(7);
    pub const S0: Reg = Reg(8);
    pub const S1: Reg = Reg(9);
    pub const A0: Reg = Reg(10);
    pub const A1: Reg = Reg(11);
    pub const A2: Reg = Reg(12);
    pub const A3: Reg = Reg(13);
    pub const A4: Reg = Reg(14);
    pub const A5: Reg = Reg(15);
    pub const A6: Reg = Reg(16);
    pub const A7: Reg = Reg(17);
    pub const S2: Reg = Reg(18);
    pub const S3: Reg = Reg(19);
    pub const S4: Reg = Reg(20);
    pub const S5: Reg = Reg(21);
    pub const S6: Reg = Reg(22);
    pub const S7: Reg = Reg(23);
    pub const S8: Reg = Reg(24);
    pub const S9: Reg = Reg(25);
    pub const S10: Reg = Reg(26);
    pub const S11: Reg = Reg(27);
    pub const T3: Reg = Reg(28);
    pub const T4: Reg = Reg(29);
    pub const T5: Reg = Reg(30);
    pub const T6: Reg = Reg(31);

    pub fn idx(self) -> usize {
        self.0 as usize
    }

    /// ABI name ("x5" registers print as "t0" etc.).
    pub fn abi(self) -> &'static str {
        const NAMES: [&str; 32] = [
            "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1", "a2",
            "a3", "a4", "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9",
            "s10", "s11", "t3", "t4", "t5", "t6",
        ];
        NAMES[self.idx()]
    }
}

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.abi())
    }
}

/// ALU operations shared by the register-register and register-immediate
/// forms (OP / OP-IMM).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
}

/// M-extension operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MulOp {
    Mul,
    Mulh,
    Mulhsu,
    Mulhu,
    Div,
    Divu,
    Rem,
    Remu,
}

/// Load widths (funct3 of LOAD).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoadKind {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

/// Store widths (funct3 of STORE).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StoreKind {
    Sb,
    Sh,
    Sw,
}

/// Branch conditions (funct3 of BRANCH).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BranchKind {
    Beq,
    Bne,
    Blt,
    Bge,
    Bltu,
    Bgeu,
}

/// CSR access forms.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CsrOp {
    Rw,
    Rs,
    Rc,
    Rwi,
    Rsi,
    Rci,
}

/// A decoded CIMR-V instruction (RV32IM + CIM extension).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instr {
    Lui { rd: Reg, imm: i32 },
    Auipc { rd: Reg, imm: i32 },
    Jal { rd: Reg, offset: i32 },
    Jalr { rd: Reg, rs1: Reg, offset: i32 },
    Branch { kind: BranchKind, rs1: Reg, rs2: Reg, offset: i32 },
    Load { kind: LoadKind, rd: Reg, rs1: Reg, offset: i32 },
    Store { kind: StoreKind, rs1: Reg, rs2: Reg, offset: i32 },
    OpImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    Op { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    MulDiv { op: MulOp, rd: Reg, rs1: Reg, rs2: Reg },
    Fence,
    Ecall,
    Ebreak,
    Csr { op: CsrOp, rd: Reg, rs1: Reg, csr: u16 },
    /// The paper's CIM-type instruction (opcode 0b1111110).
    Cim(CimInstr),
}

impl Instr {
    /// True for instructions that redirect the front-end (flush the
    /// 2-stage pipeline's prefetch buffer when taken).
    pub fn is_control_flow(&self) -> bool {
        matches!(
            self,
            Instr::Jal { .. } | Instr::Jalr { .. } | Instr::Branch { .. }
        )
    }
}
