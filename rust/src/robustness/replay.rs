//! Variation-aware tensor-level execution: the packed XNOR-popcount path
//! with the cycle engine's per-fire `VariationModel` disturbance replayed
//! exactly.
//!
//! ## The draw-order contract
//!
//! In the cycle engine ([`crate::cim::CimMacro::fire`]) every fire walks
//! all `Mode::X.sense_amps()` (= 256) SA columns in ascending order and
//! calls `VariationModel::disturb` on each column whose mask is armed —
//! and the boot sequence arms the *entire* mask plane, so **every column
//! of every fire consumes exactly one RNG draw**, including columns that
//! hold stale weights from earlier layers and columns that are never
//! drained. The disturbance on a column the program does read is applied
//! to the same ideal integer MAC sum the packed kernels compute, with the
//! same noise scale: `active = 32 * window_words = kernel * c_in` (mask
//! fully armed over the layer's window).
//!
//! The replay therefore walks fires in program order — layers ascending,
//! row positions ascending within a layer, `t_in` fires per layer per
//! owning macro (pooled layers fire on dropped odd tails too) — and for
//! each fire disturbs the owned channels' ideal sums (the shard's
//! channels sit at SA columns `0..len`) then [`VariationModel::burn`]s
//! the remaining `256 - len` draws. Under sharding each macro advances
//! its own stream: `Soc::with_variation` clones one identically seeded
//! model into every macro of the bank, and a macro only fires for layers
//! it owns channels of. `tests/variation_parity.rs` proves bit-identical
//! disturbed logits against the cycle engine across opt levels and shard
//! counts; the structural argument for stale/undrained columns reducing
//! to a draw burn is in the module text above (their sums never reach an
//! output, and `disturb` consumes one draw regardless of the sum).
//!
//! Semantics: one inference = one fresh stream per macro from
//! [`VariationParams::seed`]. That keeps the functional simulator
//! stateless (`&self`, shareable behind `Arc`) and makes every
//! Monte-Carlo trial reproducible from its config; the cycle backend
//! mirrors it by re-injecting fresh models before each run.

use anyhow::{anyhow, bail, ensure, Result};

use crate::cim::{Mode, VariationModel};
use crate::fsim::exec::{DecodedProgram, ShardedProgram};
use crate::model::reference::{self, BitMap, PackedLayer};

/// Variation/nonlinearity injection parameters — the plain-data config
/// behind [`VariationModel`] (which additionally carries live RNG state).
/// `Copy` so it can ride inside `ServeOptions` and sweep grids.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VariationParams {
    /// Per-cell conductance sigma (units of one cell's contribution).
    pub sigma: f64,
    /// Bitline nonlinearity coefficient (single-ended mapping only).
    pub nl_alpha: f64,
    /// Symmetric (differential) weight mapping enabled?
    pub symmetric: bool,
    /// Residual differential mismatch when symmetric (0..1).
    pub mismatch: f64,
    /// Per-inference RNG seed (each macro of a bank clones the stream).
    pub seed: u64,
}

impl Default for VariationParams {
    fn default() -> Self {
        VariationParams {
            sigma: 0.0,
            nl_alpha: 0.0,
            symmetric: true,
            mismatch: VariationModel::DEFAULT_MISMATCH,
            seed: 7,
        }
    }
}

impl VariationParams {
    /// Instantiate the stateful model this config describes (fresh
    /// stream from `seed`).
    pub fn model(&self) -> VariationModel {
        VariationModel::new(self.sigma, self.nl_alpha, self.symmetric, self.seed)
            .with_mismatch(self.mismatch)
    }

    /// True when the disturbance is an arithmetic identity (logits cannot
    /// change; RNG draws may still occur in the cycle engine).
    pub fn is_noop(&self) -> bool {
        self.sigma == 0.0 && (self.symmetric || self.nl_alpha == 0.0)
    }

    /// Parse the CLI spec shared by `serve --variation`, `sweep`,
    /// `table1` and `ablation`: comma-separated `key=value` pairs, e.g.
    /// `sigma=0.1,nl=0.3,mapping=single,mismatch=0.05,seed=7`. Keys:
    /// `sigma`, `nl` (alias `nl_alpha`), `mapping`
    /// (`symmetric`|`single`), `mismatch`, `seed`; all optional, unknown
    /// keys rejected.
    pub fn parse_spec(spec: &str) -> Result<Self> {
        let mut p = VariationParams::default();
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("variation spec entry {part:?} is not key=value"))?;
            let (k, v) = (k.trim(), v.trim());
            let f = || -> Result<f64> {
                v.parse().map_err(|_| anyhow!("variation {k}={v:?}: expected a number"))
            };
            match k {
                "sigma" => p.sigma = f()?,
                "nl" | "nl_alpha" => p.nl_alpha = f()?,
                "mismatch" => p.mismatch = f()?,
                "seed" => {
                    p.seed = v
                        .parse()
                        .map_err(|_| anyhow!("variation seed={v:?}: expected an integer"))?
                }
                "mapping" => {
                    p.symmetric = match v {
                        "symmetric" | "sym" | "differential" => true,
                        "single" | "single-ended" | "se" => false,
                        _ => bail!("variation mapping={v:?} (symmetric|single)"),
                    }
                }
                _ => bail!(
                    "unknown variation key {k:?} (sigma|nl|mapping|mismatch|seed)"
                ),
            }
        }
        ensure!(p.sigma >= 0.0, "variation sigma must be >= 0");
        ensure!((0.0..=1.0).contains(&p.mismatch), "variation mismatch must be in [0, 1]");
        Ok(p)
    }

    /// Render back to the canonical spec string (reports, JSON).
    pub fn spec(&self) -> String {
        format!(
            "sigma={},nl={},mapping={},mismatch={},seed={}",
            self.sigma,
            self.nl_alpha,
            if self.symmetric { "symmetric" } else { "single" },
            self.mismatch,
            self.seed
        )
    }
}

/// One macro's shard view of a layer: global channel offset + the packed
/// sub-layer (the full layer at offset 0 when unsharded).
type ShardView<'a> = Option<(usize, &'a PackedLayer)>;

/// Disturbed inference through the packed kernels: audio -> (logits,
/// argmax), bit-identical to `Soc::infer` with `with_variation` models
/// freshly seeded from `params.seed`. `sp` carries the per-macro slices
/// of a sharded program (`None` = the classic single-macro chip).
pub fn infer_disturbed(
    d: &DecodedProgram,
    sp: Option<&ShardedProgram>,
    params: &VariationParams,
    audio: &[f32],
) -> (Vec<f32>, usize) {
    let x = d.preprocess(audio);
    match sp {
        Some(sp) => {
            let per_macro: Vec<Vec<ShardView>> = sp
                .per_macro
                .iter()
                .map(|shards| {
                    shards.iter().map(|s| s.as_ref().map(|(off, pl)| (*off, pl))).collect()
                })
                .collect();
            replay(d, &per_macro, params, x)
        }
        None => {
            let per_macro: Vec<Vec<ShardView>> =
                vec![d.layers.iter().map(|l| Some((0usize, l))).collect()];
            replay(d, &per_macro, params, x)
        }
    }
}

/// The replay core. `per_macro[m][layer]` is macro `m`'s shard of each
/// layer (`None` = idle for that layer, no fires, no draws).
fn replay(
    d: &DecodedProgram,
    per_macro: &[Vec<ShardView>],
    params: &VariationParams,
    mut x: BitMap,
) -> (Vec<f32>, usize) {
    let sas = Mode::X.sense_amps();
    let n_layers = d.layers.len();
    // One identically seeded stream per macro (Soc::with_variation clones
    // the injected model into every macro of the bank).
    let mut vms: Vec<VariationModel> = (0..per_macro.len()).map(|_| params.model()).collect();

    for li in 0..n_layers - 1 {
        let full = &d.layers[li];
        let t_in = x.t;
        let t_out = if full.pooled { t_in / 2 } else { t_in };
        let mut out = BitMap::zero(t_out, full.c_out);
        for (vm, shards) in vms.iter_mut().zip(per_macro) {
            let Some((off, shard)) = shards[li] else { continue };
            // Mask fully armed over the window: every column's noise
            // scale is the layer's full wordline count.
            let active = shard.rows() as u32;
            let burns = sas.saturating_sub(shard.c_out);
            let mut window = vec![0u64; shard.plane_words];
            let mut sums = vec![0i32; shard.c_out];
            for t in 0..t_in {
                reference::conv_sums_packed_into(&x, shard, t, &mut window, &mut sums);
                let ot = if full.pooled { t / 2 } else { t };
                for (c, &s) in sums.iter().enumerate() {
                    // The draw happens for every fire — including the
                    // dropped odd pooling tail, which the macro still
                    // fires without draining.
                    let ds = vm.disturb(s, active);
                    if ot < t_out && ds > shard.thresholds[c] {
                        out.set(ot, off + c); // pooled max == OR of the pair
                    }
                }
                for _ in 0..burns {
                    vm.burn();
                }
            }
        }
        x = out;
    }

    // Final raw layer: disturbed sums accumulate through the GAP.
    let last = &d.layers[n_layers - 1];
    let t_in = x.t;
    let mut logits = vec![0.0f32; last.c_out];
    for (vm, shards) in vms.iter_mut().zip(per_macro) {
        let Some((off, shard)) = shards[n_layers - 1] else { continue };
        let active = shard.rows() as u32;
        let burns = sas.saturating_sub(shard.c_out);
        let mut window = vec![0u64; shard.plane_words];
        let mut sums = vec![0i32; shard.c_out];
        let mut acc = vec![0i64; shard.c_out];
        for t in 0..t_in {
            reference::conv_sums_packed_into(&x, shard, t, &mut window, &mut sums);
            for (a, &s) in acc.iter_mut().zip(sums.iter()) {
                *a += vm.disturb(s, active) as i64;
            }
            for _ in 0..burns {
                vm.burn();
            }
        }
        for (c, &a) in acc.iter().enumerate() {
            logits[off + c] = a as f32 / t_in as f32;
        }
    }
    let predicted = reference::argmax(&logits);
    (logits, predicted)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program_sharded;
    use crate::dataflow::shard::ShardPlan;
    use crate::model::{dataset, KwsModel};

    fn decoded(n_macros: usize) -> (DecodedProgram, Option<ShardedProgram>, Vec<f32>) {
        let m = KwsModel::synthetic(3);
        let prog = build_kws_program_sharded(&m, OptLevel::FULL, n_macros).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        let sp = (n_macros > 1).then(|| d.shard(&prog.shards).unwrap());
        let audio = dataset::synth_utterance(2, 5, m.audio_len, 0.3);
        (d, sp, audio)
    }

    #[test]
    fn noop_params_reproduce_undisturbed_inference() {
        for n in [1usize, 2, 3] {
            let (d, sp, audio) = decoded(n);
            let want = match &sp {
                Some(sp) => d.infer_sharded(&audio, sp),
                None => d.infer(&audio),
            };
            for p in [
                VariationParams::default(),
                VariationParams { sigma: 0.0, nl_alpha: 0.9, symmetric: true, ..Default::default() },
                VariationParams { mismatch: 0.0, sigma: 0.0, ..Default::default() },
            ] {
                assert!(p.is_noop());
                let got = infer_disturbed(&d, sp.as_ref(), &p, &audio);
                assert_eq!(got, want, "macros {n} params {p:?}");
            }
        }
    }

    #[test]
    fn deterministic_per_seed_and_seed_sensitive() {
        let (d, _, audio) = decoded(1);
        let p = VariationParams { sigma: 0.6, nl_alpha: 0.3, symmetric: false, ..Default::default() };
        let a = infer_disturbed(&d, None, &p, &audio);
        let b = infer_disturbed(&d, None, &p, &audio);
        assert_eq!(a, b, "same seed must replay the same disturbance");
        let other = VariationParams { seed: p.seed + 1, ..p };
        let c = infer_disturbed(&d, None, &other, &audio);
        assert_ne!(a.0, c.0, "different seeds must disturb differently");
    }

    #[test]
    fn symmetric_mapping_stays_closer_to_clean() {
        let (d, _, audio) = decoded(1);
        let (clean, _) = d.infer(&audio);
        let drift = |symmetric: bool| -> f32 {
            let p = VariationParams { sigma: 0.4, nl_alpha: 0.3, symmetric, ..Default::default() };
            let (logits, _) = infer_disturbed(&d, None, &p, &audio);
            logits.iter().zip(&clean).map(|(a, b)| (a - b).abs()).sum()
        };
        assert!(
            drift(true) < drift(false),
            "symmetric mapping must suppress the disturbance: {} vs {}",
            drift(true),
            drift(false)
        );
    }

    #[test]
    fn explicit_even_plans_replay_without_panicking() {
        // The cycle engine is limited to word-aligned plans; the replay
        // accepts any channel-granular slicing (its own semantics there).
        let m = KwsModel::synthetic(9);
        let prog = crate::compiler::build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        let audio = dataset::synth_utterance(1, 9, m.audio_len, 0.3);
        let p = VariationParams { sigma: 0.2, ..Default::default() };
        for n in 1..=3 {
            let plan = ShardPlan::even(&prog.plan, n).unwrap();
            let sp = d.shard(&plan).unwrap();
            let (logits, _) = infer_disturbed(&d, Some(&sp), &p, &audio);
            assert_eq!(logits.len(), m.n_classes);
        }
    }

    #[test]
    fn spec_parse_roundtrip_and_errors() {
        let p = VariationParams::parse_spec("sigma=0.1,nl=0.3,mapping=single,mismatch=0.02,seed=9")
            .unwrap();
        assert_eq!(p.sigma, 0.1);
        assert_eq!(p.nl_alpha, 0.3);
        assert!(!p.symmetric);
        assert_eq!(p.mismatch, 0.02);
        assert_eq!(p.seed, 9);
        assert_eq!(VariationParams::parse_spec(&p.spec()).unwrap(), p);
        // Defaults fill unspecified keys; empty spec is the default.
        let q = VariationParams::parse_spec("sigma=0.5").unwrap();
        assert!(q.symmetric);
        assert_eq!(q.mismatch, VariationModel::DEFAULT_MISMATCH);
        assert_eq!(VariationParams::parse_spec("").unwrap(), VariationParams::default());
        for bad in ["sigma", "sigma=x", "mapping=quantum", "bogus=1", "sigma=-1", "mismatch=2"] {
            assert!(VariationParams::parse_spec(bad).is_err(), "{bad:?} must be rejected");
        }
    }
}
