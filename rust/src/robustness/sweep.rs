//! Monte-Carlo robustness sweep: fan a (sigma × nl_alpha × mapping ×
//! seed) grid across threads over a labeled utterance set, through the
//! variation-aware fast path.
//!
//! Each grid point is one reproducible trial: fresh per-macro noise
//! streams from the point's seed, every utterance served through
//! [`FastSim::infer_batch_disturbed`] (the same `run_batch` kernels the
//! coordinator serves with, batch threads pinned to 1 — the point fleet
//! is the parallelism). Per point the sweep records accuracy, how often
//! the argmax flipped vs the clean run, and logit-divergence statistics;
//! the analytical chip latency rides along so a report stands on its own.
//! [`SweepReport::to_json`] is the `BENCH_robustness.json` payload
//! (emitted through `util::json`, like every other machine-readable
//! artifact in the tree).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::fsim::FastSim;
use crate::telemetry::{self, Histogram};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::replay::VariationParams;

/// Bootstrap resamples per cell for the seed-level confidence interval.
const BOOTSTRAP_RESAMPLES: usize = 1000;

/// One (sigma, nl, mapping) cell's seed-aggregated accuracy with a
/// bootstrap 95% confidence interval over its Monte-Carlo seeds.
#[derive(Debug, Clone)]
pub struct CellSummary {
    pub sigma: f64,
    pub nl_alpha: f64,
    pub symmetric: bool,
    pub mean_accuracy: f64,
    /// 2.5th percentile of the bootstrap distribution of the mean.
    pub ci95_lo: f64,
    /// 97.5th percentile of the bootstrap distribution of the mean.
    pub ci95_hi: f64,
    pub n_seeds: usize,
}

/// The sweep grid + execution knobs.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Cell-variation sigmas to sweep.
    pub sigmas: Vec<f64>,
    /// Bitline NL coefficients to sweep.
    pub nl_alphas: Vec<f64>,
    /// Weight mappings to sweep (`true` = symmetric, `false` =
    /// single-ended).
    pub mappings: Vec<bool>,
    /// Monte-Carlo seeds per (sigma, nl, mapping) cell.
    pub seeds: Vec<u64>,
    /// Residual differential mismatch for the symmetric mapping.
    pub mismatch: f64,
    /// Worker threads for the grid fan-out (0 = one per core).
    pub threads: usize,
}

impl SweepConfig {
    /// The standard grid: the §II-B sigma ladder up to the single-ended
    /// collapse point, both mappings, 4 seeds per cell.
    pub fn full() -> Self {
        SweepConfig {
            sigmas: vec![0.0, 0.05, 0.1, 0.2, 0.4, 0.6],
            nl_alphas: vec![0.3],
            mappings: vec![true, false],
            seeds: (0..4).map(|s| 1000 + s).collect(),
            mismatch: crate::cim::VariationModel::DEFAULT_MISMATCH,
            threads: 0,
        }
    }

    /// The CI smoke grid: clean + the collapse sigma, both mappings, 2
    /// seeds — small enough to run on every push, decisive enough for
    /// [`SweepReport::check_mapping_claim`].
    pub fn quick() -> Self {
        SweepConfig {
            sigmas: vec![0.0, 0.6],
            seeds: vec![1000, 1001],
            ..Self::full()
        }
    }

    /// All grid points, seeds innermost (so adjacent points share a
    /// config cell and per-cell aggregation is a contiguous scan).
    pub fn grid(&self) -> Vec<VariationParams> {
        let mut out = Vec::new();
        for &sigma in &self.sigmas {
            for &nl_alpha in &self.nl_alphas {
                for &symmetric in &self.mappings {
                    for &seed in &self.seeds {
                        out.push(VariationParams {
                            sigma,
                            nl_alpha,
                            symmetric,
                            mismatch: self.mismatch,
                            seed,
                        });
                    }
                }
            }
        }
        out
    }
}

/// One grid point's measurements.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub params: VariationParams,
    /// Top-1 accuracy over the utterance set under this disturbance.
    pub accuracy: f64,
    /// Fraction of utterances whose argmax flipped vs the clean run.
    pub flip_rate: f64,
    /// Mean |disturbed − clean| over every logit of every utterance.
    pub mean_abs_logit_delta: f64,
    /// Worst-case |disturbed − clean| logit deviation.
    pub max_abs_logit_delta: f64,
}

/// The whole sweep's results + provenance.
#[derive(Debug, Clone)]
pub struct SweepReport {
    pub points: Vec<SweepPoint>,
    /// Accuracy of the undisturbed fast path on the same set.
    pub clean_accuracy: f64,
    pub n_utterances: usize,
    /// Disturbed inferences executed (grid × utterances).
    pub inferences: usize,
    pub elapsed_s: f64,
    /// Host throughput of the disturbed fast path over the whole grid.
    pub inf_per_s: f64,
    /// Analytical chip latency per inference (data-independent).
    pub chip_cycles_per_inference: u64,
    pub mismatch: f64,
    pub threads: usize,
}

impl SweepReport {
    /// Mean accuracy across seeds of every (sigma, nl, mapping) cell, in
    /// grid order: `(sigma, nl_alpha, symmetric, mean accuracy)`.
    pub fn cells(&self) -> Vec<(f64, f64, bool, f64)> {
        let mut keys: Vec<(f64, f64, bool)> = Vec::new();
        let mut sums: Vec<f64> = Vec::new();
        let mut counts: Vec<usize> = Vec::new();
        for p in &self.points {
            let key = (p.params.sigma, p.params.nl_alpha, p.params.symmetric);
            match keys.iter().position(|k| *k == key) {
                Some(i) => {
                    sums[i] += p.accuracy;
                    counts[i] += 1;
                }
                None => {
                    keys.push(key);
                    sums.push(p.accuracy);
                    counts.push(1);
                }
            }
        }
        keys.iter()
            .zip(&sums)
            .zip(&counts)
            .map(|((k, sum), count)| (k.0, k.1, k.2, sum / *count as f64))
            .collect()
    }

    /// Per-cell seed statistics with bootstrap 95% confidence intervals,
    /// in grid order. Resampling is deterministic (cell-indexed seeds),
    /// so reports and JSON artifacts are reproducible run to run. A
    /// single-seed cell has no resampling spread: its interval collapses
    /// to the point estimate.
    pub fn cell_summaries(&self) -> Vec<CellSummary> {
        let mut keys: Vec<(f64, f64, bool)> = Vec::new();
        let mut samples: Vec<Vec<f64>> = Vec::new();
        for p in &self.points {
            let key = (p.params.sigma, p.params.nl_alpha, p.params.symmetric);
            match keys.iter().position(|k| *k == key) {
                Some(i) => samples[i].push(p.accuracy),
                None => {
                    keys.push(key);
                    samples.push(vec![p.accuracy]);
                }
            }
        }
        keys.iter()
            .zip(&samples)
            .enumerate()
            .map(|(ci, (k, xs))| {
                let n = xs.len();
                let mean = xs.iter().sum::<f64>() / n as f64;
                let (lo, hi) = if n < 2 {
                    (mean, mean)
                } else {
                    let mut rng = Rng::new(
                        0xB007_5742u64 ^ (ci as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut means: Vec<f64> = (0..BOOTSTRAP_RESAMPLES)
                        .map(|_| {
                            (0..n).map(|_| xs[rng.below(n as u64) as usize]).sum::<f64>()
                                / n as f64
                        })
                        .collect();
                    means.sort_by(|a, b| a.total_cmp(b));
                    // Nearest-rank percentiles of the bootstrap means.
                    let at = |p: f64| {
                        let rank =
                            ((p * means.len() as f64).ceil() as usize).clamp(1, means.len());
                        means[rank - 1]
                    };
                    (at(0.025), at(0.975))
                };
                CellSummary {
                    sigma: k.0,
                    nl_alpha: k.1,
                    symmetric: k.2,
                    mean_accuracy: mean,
                    ci95_lo: lo,
                    ci95_hi: hi,
                    n_seeds: n,
                }
            })
            .collect()
    }

    /// The paper's qualitative §II-B claim at this sweep's largest sigma:
    /// `(sigma, symmetric mean accuracy, single-ended mean accuracy)`.
    /// `None` unless both mappings were swept at a sigma > 0.
    pub fn mapping_gap_at_max_sigma(&self) -> Option<(f64, f64, f64)> {
        let cells = self.cells();
        let sigma = cells
            .iter()
            .filter(|c| c.0 > 0.0)
            .map(|c| c.0)
            .fold(f64::NEG_INFINITY, f64::max);
        if !sigma.is_finite() {
            return None;
        }
        let acc = |symmetric: bool| {
            let picked: Vec<f64> = cells
                .iter()
                .filter(|c| c.0 == sigma && c.2 == symmetric)
                .map(|c| c.3)
                .collect();
            if picked.is_empty() {
                None
            } else {
                Some(picked.iter().sum::<f64>() / picked.len() as f64)
            }
        };
        Some((sigma, acc(true)?, acc(false)?))
    }

    /// Assert the §II-B claim: symmetric mapping holds accuracy where
    /// single-ended collapses as sigma grows (strictly better at the
    /// largest swept sigma). The CI `sweep --quick --check` gate.
    pub fn check_mapping_claim(&self) -> Result<()> {
        let (sigma, sym, single) = self.mapping_gap_at_max_sigma().ok_or_else(|| {
            anyhow::anyhow!(
                "mapping claim needs both mappings swept at a sigma > 0 (grid too small)"
            )
        })?;
        ensure!(
            sym > single,
            "symmetric mapping must beat single-ended at sigma {sigma}: \
             {:.1}% vs {:.1}%",
            100.0 * sym,
            100.0 * single
        );
        Ok(())
    }

    /// `BENCH_robustness.json` payload.
    pub fn to_json(&self) -> Json {
        let points = self
            .points
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("sigma", Json::num(p.params.sigma)),
                    ("nl_alpha", Json::num(p.params.nl_alpha)),
                    ("mapping", Json::str(if p.params.symmetric { "symmetric" } else { "single" })),
                    ("seed", Json::num(p.params.seed as f64)),
                    ("accuracy", Json::num(p.accuracy)),
                    ("flip_rate", Json::num(p.flip_rate)),
                    ("mean_abs_logit_delta", Json::num(p.mean_abs_logit_delta)),
                    ("max_abs_logit_delta", Json::num(p.max_abs_logit_delta)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("clean_accuracy", Json::num(self.clean_accuracy)),
            ("n_utterances", Json::num(self.n_utterances as f64)),
            ("inferences", Json::num(self.inferences as f64)),
            ("elapsed_s", Json::num(self.elapsed_s)),
            ("inf_per_s", Json::num(self.inf_per_s)),
            (
                "chip_cycles_per_inference",
                Json::num(self.chip_cycles_per_inference as f64),
            ),
            ("mismatch", Json::num(self.mismatch)),
            ("threads", Json::num(self.threads as f64)),
            ("points", Json::Arr(points)),
            (
                "cells",
                Json::Arr(
                    self.cell_summaries()
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("sigma", Json::num(c.sigma)),
                                ("nl_alpha", Json::num(c.nl_alpha)),
                                (
                                    "mapping",
                                    Json::str(if c.symmetric { "symmetric" } else { "single" }),
                                ),
                                ("mean_accuracy", Json::num(c.mean_accuracy)),
                                ("ci95_lo", Json::num(c.ci95_lo)),
                                ("ci95_hi", Json::num(c.ci95_hi)),
                                ("n_seeds", Json::num(c.n_seeds as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ];
        if let Some((sigma, sym, single)) = self.mapping_gap_at_max_sigma() {
            fields.push((
                "mapping_claim",
                Json::obj(vec![
                    ("sigma", Json::num(sigma)),
                    ("symmetric_accuracy", Json::num(sym)),
                    ("single_ended_accuracy", Json::num(single)),
                ]),
            ));
        }
        Json::obj(fields)
    }
}

/// Run the sweep: every grid point over every utterance, points fanned
/// out across threads (the simulator is `&self`-stateless, so workers
/// share it without cloning). `labels[i]` is utterance `i`'s class.
pub fn run_sweep(
    sim: &FastSim,
    utterances: &[&[f32]],
    labels: &[usize],
    cfg: &SweepConfig,
) -> Result<SweepReport> {
    ensure!(!utterances.is_empty(), "sweep needs at least one utterance");
    ensure!(utterances.len() == labels.len(), "one label per utterance");
    // The same ranges VariationParams::parse_spec enforces — grid flags
    // (`--sigmas`, `--mismatch`) must not sneak in values the shared
    // spec parser would reject.
    ensure!(cfg.sigmas.iter().all(|&s| s >= 0.0), "sweep sigmas must be >= 0");
    ensure!(
        (0.0..=1.0).contains(&cfg.mismatch),
        "sweep mismatch must be in [0, 1] (got {})",
        cfg.mismatch
    );
    let grid = cfg.grid();
    ensure!(!grid.is_empty(), "sweep grid is empty (check the sigma/nl/mapping/seed lists)");
    ensure!(
        sim.variation().is_none(),
        "run_sweep needs an undisturbed simulator (the grid provides the variation)"
    );

    // Clean baseline once, through the same batched kernels.
    let clean = sim.infer_batch(utterances);
    let mut clean_hits = 0usize;
    for (r, &l) in clean.iter().zip(labels) {
        if r.predicted == l {
            clean_hits += 1;
        }
    }
    let chip_cycles = clean.first().map(|r| r.cycles).unwrap_or(0);

    let workers = if cfg.threads == 0 {
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
    } else {
        cfg.threads
    }
    .clamp(1, grid.len());

    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, SweepPoint)>> = Mutex::new(Vec::with_capacity(grid.len()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                let Some(params) = grid.get(i).copied() else { break };
                let tp0 = telemetry::enabled().then(Instant::now);
                let rs = sim.infer_batch_disturbed(utterances, &params);
                if let Some(tp0) = tp0 {
                    let telem = telemetry::global();
                    telem
                        .histogram("sweep.point_us", Histogram::us_bounds())
                        .observe(tp0.elapsed().as_micros() as u64);
                    telem.counter("sweep.points").inc();
                }
                let mut hits = 0usize;
                let mut flips = 0usize;
                let mut sum_delta = 0.0f64;
                let mut max_delta = 0.0f64;
                let mut n_logits = 0usize;
                for ((r, c), &label) in rs.iter().zip(&clean).zip(labels) {
                    if r.predicted == label {
                        hits += 1;
                    }
                    if r.predicted != c.predicted {
                        flips += 1;
                    }
                    for (a, b) in r.logits.iter().zip(&c.logits) {
                        let d = (a - b).abs() as f64;
                        sum_delta += d;
                        max_delta = max_delta.max(d);
                        n_logits += 1;
                    }
                }
                let n = utterances.len() as f64;
                let point = SweepPoint {
                    params,
                    accuracy: hits as f64 / n,
                    flip_rate: flips as f64 / n,
                    mean_abs_logit_delta: sum_delta / n_logits.max(1) as f64,
                    max_abs_logit_delta: max_delta,
                };
                results.lock().unwrap().push((i, point));
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    if telemetry::enabled() {
        let telem = telemetry::global();
        telem.gauge("sweep.points_per_s").set(grid.len() as f64 / elapsed.max(1e-9));
        telem.counter("sweep.inferences").add((grid.len() * utterances.len()) as u64);
    }

    let mut indexed = results.into_inner().unwrap();
    indexed.sort_by_key(|(i, _)| *i);
    let points: Vec<SweepPoint> = indexed.into_iter().map(|(_, p)| p).collect();
    let inferences = grid.len() * utterances.len();
    Ok(SweepReport {
        points,
        clean_accuracy: clean_hits as f64 / utterances.len() as f64,
        n_utterances: utterances.len(),
        inferences,
        elapsed_s: elapsed,
        inf_per_s: inferences as f64 / elapsed.max(1e-9),
        chip_cycles_per_inference: chip_cycles,
        mismatch: cfg.mismatch,
        threads: workers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program;
    use crate::mem::dram::DramConfig;
    use crate::model::{dataset, KwsModel};

    fn setup() -> (FastSim, Vec<Vec<f32>>, Vec<usize>) {
        let m = KwsModel::synthetic(3);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let sim = FastSim::new(prog, DramConfig::default()).unwrap().with_batch_threads(1);
        let labels: Vec<usize> = (0..4).map(|i| i % 12).collect();
        let audios: Vec<Vec<f32>> = labels
            .iter()
            .enumerate()
            .map(|(i, &l)| dataset::synth_utterance(l, 50 + i as u64, m.audio_len, 0.3))
            .collect();
        (sim, audios, labels)
    }

    #[test]
    fn sweep_runs_grid_in_order_and_aggregates() {
        let (sim, audios, labels) = setup();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        let cfg = SweepConfig {
            sigmas: vec![0.0, 0.3],
            nl_alphas: vec![0.3],
            mappings: vec![true, false],
            seeds: vec![1, 2],
            mismatch: 0.05,
            threads: 2,
        };
        let report = run_sweep(&sim, &refs, &labels, &cfg).unwrap();
        assert_eq!(report.points.len(), 8);
        assert_eq!(report.inferences, 8 * 4);
        assert_eq!(report.n_utterances, 4);
        // Points come back in grid order despite the thread fan-out.
        let grid = cfg.grid();
        for (p, g) in report.points.iter().zip(&grid) {
            assert_eq!(&p.params, g);
        }
        // sigma = 0 symmetric points are exactly the clean run.
        for p in report.points.iter().filter(|p| p.params.is_noop()) {
            assert_eq!(p.accuracy, report.clean_accuracy);
            assert_eq!(p.flip_rate, 0.0);
            assert_eq!(p.mean_abs_logit_delta, 0.0);
            assert_eq!(p.max_abs_logit_delta, 0.0);
        }
        // Cells average across the two seeds: 4 cells from 8 points.
        assert_eq!(report.cells().len(), 4);
        assert!(report.chip_cycles_per_inference > 0);
        assert!(report.inf_per_s > 0.0);
        // JSON payload parses back and carries the grid.
        let j = report.to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("points").unwrap().as_arr().unwrap().len(), 8);
        assert!(parsed.get("mapping_claim").is_ok());
        // Cell summaries ride along with their confidence intervals.
        let cells = parsed.get("cells").unwrap().as_arr().unwrap();
        assert_eq!(cells.len(), 4);
        for c in cells {
            let lo = c.get("ci95_lo").unwrap().as_f64().unwrap();
            let hi = c.get("ci95_hi").unwrap().as_f64().unwrap();
            assert!(lo <= hi);
        }
    }

    #[test]
    fn bootstrap_cis_bracket_seed_spread_and_are_deterministic() {
        let (sim, audios, labels) = setup();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        let cfg = SweepConfig {
            sigmas: vec![0.4],
            nl_alphas: vec![0.3],
            mappings: vec![false],
            seeds: (0..6).map(|s| 100 + s).collect(),
            mismatch: 0.05,
            threads: 2,
        };
        let report = run_sweep(&sim, &refs, &labels, &cfg).unwrap();
        let cells = report.cell_summaries();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.n_seeds, 6);
        // The interval is ordered, bounded by the observed seed spread,
        // and agrees with cells() on the point estimate.
        let accs: Vec<f64> = report.points.iter().map(|p| p.accuracy).collect();
        let min = accs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = accs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(c.ci95_lo <= c.ci95_hi);
        assert!(c.ci95_lo >= min - 1e-12 && c.ci95_hi <= max + 1e-12);
        assert!((c.mean_accuracy - report.cells()[0].3).abs() < 1e-12);
        // Deterministic: resampling is seeded per cell index.
        let again = report.cell_summaries();
        assert_eq!(c.ci95_lo, again[0].ci95_lo);
        assert_eq!(c.ci95_hi, again[0].ci95_hi);
        // A single-seed cell collapses to the point estimate.
        let single = SweepConfig { seeds: vec![100], ..cfg };
        let r1 = run_sweep(&sim, &refs, &labels, &single).unwrap();
        let c1 = &r1.cell_summaries()[0];
        assert_eq!(c1.ci95_lo, c1.mean_accuracy);
        assert_eq!(c1.ci95_hi, c1.mean_accuracy);
    }

    #[test]
    fn sweep_is_deterministic_across_runs_and_thread_counts() {
        let (sim, audios, labels) = setup();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        let mut cfg = SweepConfig {
            sigmas: vec![0.4],
            nl_alphas: vec![0.3],
            mappings: vec![false],
            seeds: vec![1, 2, 3],
            mismatch: 0.05,
            threads: 1,
        };
        let a = run_sweep(&sim, &refs, &labels, &cfg).unwrap();
        cfg.threads = 3;
        let b = run_sweep(&sim, &refs, &labels, &cfg).unwrap();
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.params, y.params);
            assert_eq!(x.accuracy, y.accuracy);
            assert_eq!(x.mean_abs_logit_delta, y.mean_abs_logit_delta);
        }
    }

    #[test]
    fn sweep_reports_per_point_telemetry_when_enabled() {
        crate::telemetry::with_telemetry(|| {
            let (sim, audios, labels) = setup();
            let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
            let cfg = SweepConfig {
                sigmas: vec![0.0, 0.3],
                nl_alphas: vec![0.3],
                mappings: vec![false],
                seeds: vec![1],
                mismatch: 0.05,
                threads: 2,
            };
            let telem = crate::telemetry::global();
            let before = telem.counter("sweep.points").get();
            run_sweep(&sim, &refs, &labels, &cfg).unwrap();
            // `>=`: the registry is process-global, and unguarded tests
            // running concurrently also record while telemetry is on.
            assert!(telem.counter("sweep.points").get() >= before + 2);
            assert!(telem.histogram("sweep.point_us", Histogram::us_bounds()).count() >= 2);
            assert!(telem.gauge("sweep.points_per_s").get() > 0.0);
            assert!(telem.counter("sweep.inferences").get() >= 2 * 4);
        });
    }

    #[test]
    fn mapping_claim_requires_a_decisive_grid() {
        let (sim, audios, labels) = setup();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        // Only sigma = 0: no claim derivable.
        let cfg = SweepConfig {
            sigmas: vec![0.0],
            nl_alphas: vec![0.3],
            mappings: vec![true, false],
            seeds: vec![1],
            mismatch: 0.05,
            threads: 1,
        };
        let report = run_sweep(&sim, &refs, &labels, &cfg).unwrap();
        assert!(report.mapping_gap_at_max_sigma().is_none());
        assert!(report.check_mapping_claim().is_err());
        // Input validation.
        assert!(run_sweep(&sim, &[], &[], &cfg).is_err());
        let empty = SweepConfig { sigmas: vec![], ..cfg };
        assert!(run_sweep(&sim, &refs, &labels, &empty).is_err());
    }
}
