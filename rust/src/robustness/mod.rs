//! Robustness analysis subsystem: variation-aware fast simulation and
//! Monte-Carlo sweeps at serving speed.
//!
//! The paper's accuracy claims rest on symmetric weight mapping
//! suppressing SRAM cell variation and bitline nonlinearity (§II-B).
//! Until this subsystem, only the cycle engine could inject that
//! disturbance ([`crate::cim::VariationModel`] inside `CimMacro::fire`) —
//! ~10^6 simulated steps per inference, far too slow for the
//! device-variation Monte-Carlo sweeps that are the standard deployability
//! evidence for in-memory compute. Three layers fix that:
//!
//! * [`replay`] — the variation-aware functional simulator: replays the
//!   macro bank's per-fire disturbance at tensor level, walking fires in
//!   the same per-macro sequence and RNG draw order the SoC uses
//!   (including sharded programs), so disturbed logits are bit-identical
//!   to the cycle engine for the same seed (`tests/variation_parity.rs`).
//! * [`sweep`] — the Monte-Carlo engine: fans a (sigma × nl_alpha ×
//!   mapping × seed) grid across threads over a labeled utterance set,
//!   producing per-point accuracy, logit-divergence stats and analytical
//!   latency; `BENCH_robustness.json` is its serialized form.
//! * the surface — the `cimrv sweep` subcommand (grid flags, `--quick`,
//!   `--check`), `serve --variation sigma=...` for fault-injection
//!   serving, and `--variation` on `table1`/`ablation`; all share one
//!   spec parser ([`VariationParams::parse_spec`]).

pub mod replay;
pub mod sweep;

pub use replay::{infer_disturbed, VariationParams};
pub use sweep::{run_sweep, CellSummary, SweepConfig, SweepPoint, SweepReport};

use anyhow::Result;

/// Parse the shared `--variation <spec>` CLI option (`run`-side surface
/// of the subsystem, used by `serve`, `table1` and `ablation`): `None`
/// when the flag is absent.
pub fn variation_from_args(args: &crate::util::cli::Args) -> Result<Option<VariationParams>> {
    args.opt("variation").map(VariationParams::parse_spec).transpose()
}
