//! The golden KWS model: HLO text -> PJRT executable -> logits.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::io::read_f32;
use crate::util::json::Json;

/// A compiled golden model plus its parameter payloads (fed as PJRT
/// inputs in manifest order on every call).
pub struct GoldenModel {
    exe: xla::PjRtLoadedExecutable,
    /// (shape, payload) per parameter after the audio input.
    params: Vec<(Vec<usize>, Vec<f32>)>,
    pub audio_len: usize,
    pub n_classes: usize,
}

impl GoldenModel {
    /// Load `model.hlo.txt` + weights from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest: Json = Json::parse(
            &std::fs::read_to_string(dir.join("kws_manifest.json"))
                .context("reading kws_manifest.json")?,
        )?;
        let audio_len = manifest.path(&["config", "audio_len"])?.as_usize()?;
        let n_classes = manifest.path(&["config", "n_classes"])?.as_usize()?;

        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let hlo_path = dir.join(manifest.path(&["hlo", "model"])?.as_str()?);
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().context("hlo path utf-8")?,
        )
        .with_context(|| format!("parsing {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;

        // Parameters in manifest order (the lowering's argument order).
        let mut params = Vec::new();
        for w in manifest.get("weights")?.as_arr()? {
            let file = w.get("file")?.as_str()?;
            let shape: Vec<usize> = w
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<_>>()?;
            let data = read_f32(&dir.join(file))?;
            ensure!(
                data.len() == shape.iter().product::<usize>().max(1),
                "{file}: payload/shape mismatch"
            );
            params.push((shape, data));
        }
        Ok(GoldenModel { exe, params, audio_len, n_classes })
    }

    pub fn load_default() -> Result<Self> {
        Self::load(&crate::util::io::artifacts_dir()?)
    }

    /// Whether this artifact set carries a loadable PJRT golden model:
    /// the manifest names an HLO file and that file exists on disk. The
    /// checked-in `rust/testdata` set intentionally ships golden *logits*
    /// instead of HLO (no Python/JAX in CI), so PJRT cross-checks gate on
    /// this instead of failing.
    pub fn available(dir: &Path) -> bool {
        let check = || -> Result<bool> {
            let manifest = Json::parse(
                &std::fs::read_to_string(dir.join("kws_manifest.json"))
                    .context("reading kws_manifest.json")?,
            )?;
            let hlo = manifest.path(&["hlo", "model"])?.as_str()?.to_string();
            Ok(dir.join(hlo).is_file())
        };
        check().unwrap_or(false)
    }

    /// Run one utterance through the golden model.
    pub fn infer(&self, audio: &[f32]) -> Result<Vec<f32>> {
        ensure!(audio.len() == self.audio_len, "audio length {}", audio.len());
        let mut literals = Vec::with_capacity(1 + self.params.len());
        literals.push(to_literal(audio, &[audio.len()])?);
        for (shape, data) in &self.params {
            literals.push(to_literal(data, shape)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // Lowered with return_tuple=True: a 1-tuple of the logits vector.
        let out = result.to_tuple1()?;
        let logits = out.to_vec::<f32>()?;
        ensure!(logits.len() == self.n_classes, "logits length {}", logits.len());
        Ok(logits)
    }
}

fn to_literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

// Exercised by rust/tests/golden_crosscheck.rs (needs artifacts on disk).
