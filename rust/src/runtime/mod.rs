//! PJRT golden-model runtime: loads the AOT-lowered JAX/Pallas HLO text
//! (`artifacts/*.hlo.txt`) and executes it on the CPU PJRT client — the
//! bit-exact oracle the cycle simulator is checked against.
//!
//! Python never runs here: `make artifacts` ran once at build time; the
//! interchange format is HLO *text* (the image's xla_extension 0.5.1
//! rejects jax>=0.5's 64-bit-id serialized protos — see DESIGN.md).

pub mod golden;

pub use golden::GoldenModel;
