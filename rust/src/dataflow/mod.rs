//! Dataflow planning: where feature maps, weight streams and macro
//! rectangles live, and which of the paper's optimizations (layer fusion,
//! conv/max-pool pipeline, weight fusion) the generated program applies.
//!
//! The policies themselves are *compiled into the program* by
//! `compiler::codegen`; this module owns the address/size arithmetic so
//! codegen, the SoC loader and the analytical models all agree.

pub mod plan;
pub mod shard;

pub use plan::{KwsPlan, LayerPlan};
pub use shard::{LayerShards, ShardAxis, ShardPlan};
