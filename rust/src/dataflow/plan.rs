//! Address & schedule planning for the KWS program (row-wise dataflow,
//! Fig. 5): FM SRAM buffers, weight-SRAM ping-pong halves, DRAM staging,
//! and per-layer shift/drain word counts.

use anyhow::{ensure, Result};

use crate::mem::layout;
use crate::model::KwsModel;

// --- FM SRAM layout (32 KiB) -------------------------------------------------
/// Ping-pong FM buffers (layer input/output) and the pre-pool staging
/// buffer used when the conv/max-pool pipeline is disabled.
pub const FM_BUF_A: u32 = 0x0000;
pub const FM_BUF_B: u32 = 0x1000;
pub const FM_PREPOOL: u32 = 0x2000;
/// Eight zero words for boundary-row shifts (never written).
pub const FM_ZERO: u32 = 0x7E00;
/// Scratch word for dummy stores (prefill shifts, even-position fires).
pub const FM_SCRATCH: u32 = 0x7F00;
/// One all-ones word (mask-plane boot initialisation source).
pub const FM_ONES: u32 = 0x7F80;

// --- Weight SRAM layout (64 KiB) ---------------------------------------------
// Static allocation: layer i's stream lives at the cumulative offset of
// the streams before it (the whole model's streams fit the 512 Kb SRAM —
// the "weight buffer" of Fig. 1; checked by KwsPlan::new). The uDMA
// descriptor chain fills the buffer once per inference, fully overlapped
// with preprocessing when weight fusion is on.

// --- DMEM layout --------------------------------------------------------------
/// Audio staged as i16; the halfword below the base stays zero (the
/// "previous sample" of sample 0 for the pre-emphasis filter).
pub const DMEM_AUDIO: u32 = 0x100;
/// Folded-BN per-channel thresholds (c i32 words)...
pub const DMEM_THR: u32 = 0x1_0000;
/// ...then c/32 flip words applied to each packed feature word.
pub const DMEM_FLIP: u32 = 0x1_0200;
/// GAP accumulators / result vector (n_classes i32 words).
pub const DMEM_RESULT: u32 = 0x1_0300;
/// Raw-sum dump area for the final layer (t_final * n_classes words).
pub const DMEM_RAWDUMP: u32 = 0x1_0400;
/// Per-macro raw partial-sum staging for input-axis-sharded programs:
/// `n_macros` rows of `c_out` i32 words for the current position (macro
/// `m`'s partials at word offset `m * c_out`; ≤ 4 macros × 256 channels
/// = 4 KiB). Merged by the RISC-V core before thresholding.
pub const DMEM_RAWPART: u32 = 0x1_2000;
/// Per-layer threshold table for input-axis-sharded programs (DMA'd
/// straight from the DRAM weight stream each weight phase; ≤ 256 words).
/// Input-axis macros hold only raw partial weights, so the SA threshold
/// registers are unused and the compare runs on the core.
pub const DMEM_SLICE_TH: u32 = 0x1_3000;

// --- DRAM staging --------------------------------------------------------------
pub const DRAM_AUDIO: u32 = 0x0000_0000;
pub const DRAM_WEIGHTS: u32 = 0x0001_0000;
/// Baseline (no layer fusion) FM spill region.
pub const DRAM_FM_SPILL: u32 = 0x0030_0000;

/// Per-layer schedule parameters.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub index: usize,
    /// Input feature words per row (c_in/32) — shifts per position.
    pub s_words: usize,
    /// Output latch words per row (ceil(c_out/32)) — drains per position.
    pub o_words: usize,
    /// Window length in words (kernel * c_in / 32).
    pub window_words: usize,
    /// Time length in/out (pools halve).
    pub t_in: usize,
    pub t_out: usize,
    pub pooled: bool,
    pub binarized: bool,
    pub c_out: usize,
    /// Sign-stream words (cols * active words) in the weight stream.
    pub sign_words: usize,
    /// Threshold words following the signs (0 for the raw final layer).
    pub th_words: usize,
    /// Byte offset of this layer's stream in the DRAM staging area.
    pub dram_offset: u32,
    /// Byte offset of this layer's stream in the weight SRAM (static).
    pub wt_offset: u32,
}

impl LayerPlan {
    /// Stream bytes (uDMA transfer length).
    pub fn stream_bytes(&self) -> u32 {
        ((self.sign_words + self.th_words) * 4) as u32
    }

    /// Words per output row in FM SRAM.
    pub fn out_row_words(&self) -> usize {
        self.o_words
    }

    /// Output FM bytes (pooled rows).
    pub fn out_bytes(&self) -> u32 {
        (self.t_out * self.o_words * 4) as u32
    }

    /// Input FM bytes.
    pub fn in_bytes(&self) -> u32 {
        (self.t_in * self.s_words * 4) as u32
    }
}

/// The whole-model plan.
#[derive(Debug, Clone)]
pub struct KwsPlan {
    pub layers: Vec<LayerPlan>,
    /// Audio bytes staged in DRAM (i16 samples).
    pub audio_bytes: u32,
}

impl KwsPlan {
    pub fn new(model: &KwsModel) -> Result<Self> {
        let mut layers = Vec::new();
        let mut dram_off = DRAM_WEIGHTS;
        let mut wt_off = 0u32;
        let mut t = model.t;
        for (i, l) in model.layers.iter().enumerate() {
            ensure!(l.c_in % 32 == 0, "layer {i}: c_in must be a word multiple");
            let s_words = l.c_in / 32;
            let o_words = l.c_out.div_ceil(32);
            let window_words = l.kernel * l.c_in / 32;
            ensure!(window_words <= 32, "layer {i}: window overflows the input buffer");
            ensure!(l.c_out <= 256, "layer {i}: X-mode SA overflow");
            let aw = window_words; // active words per column
            let sign_words = l.c_out * aw;
            let th_words = if l.binarized { l.c_out } else { 0 };
            let t_out = if l.pooled { t / 2 } else { t };
            let lp = LayerPlan {
                index: i,
                s_words,
                o_words,
                window_words,
                t_in: t,
                t_out,
                pooled: l.pooled,
                binarized: l.binarized,
                c_out: l.c_out,
                sign_words,
                th_words,
                dram_offset: dram_off,
                wt_offset: wt_off,
            };
            wt_off += lp.stream_bytes();
            ensure!(
                wt_off <= layout::WT_SIZE,
                "layer {i}: weight streams overflow the 512 Kb weight SRAM \
                 ({wt_off}B) — the Fig. 1 weight-buffer premise requires the \
                 model's streams to fit"
            );
            // FM buffers: unpooled staging must fit the pre-pool buffer.
            ensure!(lp.t_in * lp.o_words * 4 <= (FM_ZERO - FM_PREPOOL) as usize);
            dram_off += lp.stream_bytes();
            // 4-byte alignment is automatic (whole words).
            t = t_out;
            layers.push(lp);
        }
        Ok(KwsPlan { layers, audio_bytes: (model.audio_len * 2) as u32 })
    }

    /// Input FM buffer of layer `i` (ping-pong).
    pub fn in_buf(&self, i: usize) -> u32 {
        if i % 2 == 0 {
            FM_BUF_A
        } else {
            FM_BUF_B
        }
    }

    /// Output FM buffer of layer `i`.
    pub fn out_buf(&self, i: usize) -> u32 {
        if i % 2 == 0 {
            FM_BUF_B
        } else {
            FM_BUF_A
        }
    }

    /// Weight-SRAM byte offset of layer `i`'s stream.
    pub fn wt_offset(&self, i: usize) -> u32 {
        self.layers[i].wt_offset
    }

    /// Build the DRAM weight-stream image for all layers: sign words in
    /// column-major burst order, then threshold words.
    pub fn build_dram_weights(&self, model: &KwsModel) -> Vec<(u32, Vec<u8>)> {
        let mut chunks = Vec::new();
        for (lp, l) in self.layers.iter().zip(&model.layers) {
            let aw = lp.window_words;
            let mut bytes = Vec::with_capacity(lp.stream_bytes() as usize);
            for co in 0..l.c_out {
                for wj in 0..aw {
                    let mut sign = 0u32;
                    for b in 0..32 {
                        let r = wj * 32 + b;
                        if r < l.rows() && l.weight(r, co) > 0 {
                            sign |= 1 << b;
                        }
                    }
                    bytes.extend_from_slice(&sign.to_le_bytes());
                }
            }
            if l.binarized {
                for &th in &l.thresholds {
                    bytes.extend_from_slice(&(th as u32).to_le_bytes());
                }
            }
            debug_assert_eq!(bytes.len(), lp.stream_bytes() as usize);
            chunks.push((lp.dram_offset, bytes));
        }
        chunks
    }

    /// Audio staged as little-endian i16 (the ADC output the chip sees).
    pub fn build_dram_audio(&self, audio: &[f32]) -> Vec<u8> {
        let q = crate::model::reference::quantize_audio(audio);
        let mut bytes = Vec::with_capacity(q.len() * 2);
        for v in q {
            bytes.extend_from_slice(&(v as i16).to_le_bytes());
        }
        bytes
    }

    /// Total DRAM weight traffic per inference (all layer streams).
    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.stream_bytes() as u64).sum()
    }

    /// Total `cim_w` instructions for all macro loads.
    pub fn total_cim_w(&self) -> u64 {
        self.layers.iter().map(|l| (l.sign_words + l.th_words) as u64).sum()
    }
}

/// MMIO register absolute addresses used by codegen.
pub fn mmio(off: u32) -> u32 {
    layout::MMIO_BASE + off
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_model() -> KwsModel {
        // A tiny 3-layer model shaped like Table II for plan tests.
        use crate::model::kws::LayerSpec;
        let mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: vec![1; 3 * ci * co],
            thresholds: if binarized { vec![0; co] } else { vec![] },
        };
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 2,
            layers: vec![mk(64, 64, true, true), mk(64, 128, true, true), mk(128, 12, false, false)],
            bn_gamma: vec![1.0; 64],
            bn_beta: vec![0.0; 64],
            bn_mean: vec![0.0; 64],
            bn_var: vec![1.0; 64],
            pre_thr: vec![0; 64],
            pre_dir: vec![1; 64],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    #[test]
    fn plan_word_counts() {
        let m = fake_model();
        let p = KwsPlan::new(&m).unwrap();
        assert_eq!(p.layers[0].s_words, 2);
        assert_eq!(p.layers[0].o_words, 2);
        assert_eq!(p.layers[0].window_words, 6);
        assert_eq!(p.layers[0].t_out, 64);
        assert_eq!(p.layers[1].t_in, 64);
        assert_eq!(p.layers[2].o_words, 1); // 12 channels
        assert!(!p.layers[2].binarized);
    }

    #[test]
    fn ping_pong_alternates() {
        let m = fake_model();
        let p = KwsPlan::new(&m).unwrap();
        assert_eq!(p.in_buf(0), FM_BUF_A);
        assert_eq!(p.out_buf(0), FM_BUF_B);
        assert_eq!(p.in_buf(1), FM_BUF_B);
        assert_eq!(p.wt_offset(0), 0);
        assert_eq!(p.wt_offset(1), p.layers[0].stream_bytes());
    }

    #[test]
    fn dram_streams_sized_and_disjoint() {
        let m = fake_model();
        let p = KwsPlan::new(&m).unwrap();
        let chunks = p.build_dram_weights(&m);
        assert_eq!(chunks.len(), 3);
        for (i, (off, bytes)) in chunks.iter().enumerate() {
            assert_eq!(bytes.len() as u32, p.layers[i].stream_bytes());
            if i > 0 {
                let (poff, pbytes) = &chunks[i - 1];
                assert_eq!(poff + pbytes.len() as u32, *off, "contiguous streams");
            }
            assert!(*off >= DRAM_WEIGHTS);
        }
    }

    #[test]
    fn audio_staging_i16() {
        let m = fake_model();
        let p = KwsPlan::new(&m).unwrap();
        let bytes = p.build_dram_audio(&[0.0, 0.5, -1.0]);
        assert_eq!(bytes.len(), 6);
        assert_eq!(i16::from_le_bytes([bytes[2], bytes[3]]), 1024);
        assert_eq!(i16::from_le_bytes([bytes[4], bytes[5]]), -2048);
    }
}
