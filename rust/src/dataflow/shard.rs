//! Multi-macro sharding plan: split each layer's output channels across
//! N simulated CIM macros.
//!
//! One 16 KB macro bounds throughput; output channels are the natural
//! parallel axis (every macro sees the same input window and owns a
//! disjoint column range — cf. PSCNN's single large reconfigurable array
//! and CIMPool's weight partitioning). A [`ShardPlan`] assigns each layer
//! a per-macro channel range, reusing the per-layer rectangles the
//! compiler plan already carries ([`KwsPlan`]):
//!
//! * [`ShardPlan::even`] — channel-granular split (uneven `c_out % n`
//!   remainders go to the leading shards). Used by the functional
//!   simulator, which can merge at bit granularity.
//! * [`ShardPlan::word_aligned`] — 32-channel (output-latch word) granular
//!   split. Used by the cycle engine: each macro's latch words drain
//!   straight into the packed FM row at a word offset, so the row-wise
//!   drain loop needs no cross-word shifts.
//! * [`ShardPlan::input_word_aligned`] — input-channel-axis split
//!   ([`ShardAxis::Input`]): every macro holds all output channels of a
//!   disjoint input slice and emits partial raw sums, merged by addition
//!   before thresholding. The fallback for windows wider than one
//!   macro's wordlines (`compiler::build_kws_program_input_sharded` /
//!   `DecodedProgram::infer_input_sharded`).
//!
//! Both splits are value-preserving by construction: a channel's sums and
//! thresholds do not depend on which macro computes it, so sharded logits
//! are bit-identical to the single-macro run (property-tested in
//! `rust/tests/shard_parity.rs`).

use anyhow::{ensure, Result};

use super::plan::KwsPlan;

/// Which channel axis a plan splits layers along.
///
/// * `Output` — each macro owns a disjoint output-channel range (the
///   classic split: same input window everywhere, binarized partial rows
///   concatenate).
/// * `Input` — each macro owns a disjoint *input*-channel slice of every
///   layer and computes partial raw sums over **all** output channels;
///   partials add exactly (`sum = 2*pop(win & plane) - pop(win)` is
///   additive over disjoint input masks), then thresholding/pooling runs
///   on the merged sums. This is the fallback for layers/groups whose
///   window is wider than one macro's wordlines (`window_words > 32`
///   after fusion packs the array tighter — see `compiler::fusion`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAxis {
    Output,
    Input,
}

/// Per-layer channel ranges, one `[start, end)` per macro (empty
/// ranges allowed: a 12-channel classifier on 4 macros leaves 3 idle).
/// For [`ShardAxis::Input`] plans the ranges (and `c_out`, which then
/// holds the layer's **input**-channel total) are along the input axis.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerShards {
    pub index: usize,
    pub c_out: usize,
    pub ranges: Vec<(usize, usize)>,
}

impl LayerShards {
    /// Channels owned by macro `m`.
    pub fn len(&self, m: usize) -> usize {
        let (a, b) = self.ranges[m];
        b - a
    }

    pub fn is_empty(&self, m: usize) -> bool {
        self.len(m) == 0
    }

    /// `(macro, start, end)` for every macro that owns channels, in
    /// macro order — the interleave order of the cycle engine's fire
    /// sequences and the shard order of the functional simulator.
    pub fn non_empty(&self) -> Vec<(usize, usize, usize)> {
        self.ranges
            .iter()
            .enumerate()
            .filter(|(_, (a, b))| b > a)
            .map(|(m, &(a, b))| (m, a, b))
            .collect()
    }
}

/// The whole-model sharding: one [`LayerShards`] per layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    pub n_macros: usize,
    pub layers: Vec<LayerShards>,
    /// Axis the ranges split along ([`ShardAxis::Output`] for every
    /// classic constructor).
    pub axis: ShardAxis,
}

impl ShardPlan {
    /// The trivial single-macro plan (every layer in macro 0).
    pub fn single(plan: &KwsPlan) -> Self {
        ShardPlan {
            n_macros: 1,
            layers: plan
                .layers
                .iter()
                .map(|lp| LayerShards {
                    index: lp.index,
                    c_out: lp.c_out,
                    ranges: vec![(0, lp.c_out)],
                })
                .collect(),
            axis: ShardAxis::Output,
        }
    }

    /// Channel-granular even split: shard sizes differ by at most one,
    /// remainders assigned to the leading shards.
    pub fn even(plan: &KwsPlan, n: usize) -> Result<Self> {
        ensure!(n >= 1, "shard count must be >= 1");
        let layers = plan
            .layers
            .iter()
            .map(|lp| {
                let base = lp.c_out / n;
                let rem = lp.c_out % n;
                let mut ranges = Vec::with_capacity(n);
                let mut at = 0;
                for m in 0..n {
                    let len = base + usize::from(m < rem);
                    ranges.push((at, at + len));
                    at += len;
                }
                LayerShards { index: lp.index, c_out: lp.c_out, ranges }
            })
            .collect();
        let sp = ShardPlan { n_macros: n, layers, axis: ShardAxis::Output };
        sp.validate()?;
        Ok(sp)
    }

    /// Output-latch-word (32-channel) granular split for the cycle
    /// engine: every shard starts on a word boundary, words distributed
    /// as evenly as possible, the last owning word truncated to `c_out`.
    pub fn word_aligned(plan: &KwsPlan, n: usize) -> Result<Self> {
        ensure!(n >= 1, "shard count must be >= 1");
        let layers = plan
            .layers
            .iter()
            .map(|lp| {
                let words = lp.c_out.div_ceil(32);
                let base = words / n;
                let rem = words % n;
                let mut ranges = Vec::with_capacity(n);
                let mut at_word = 0;
                for m in 0..n {
                    let w = base + usize::from(m < rem);
                    let start = (at_word * 32).min(lp.c_out);
                    let end = ((at_word + w) * 32).min(lp.c_out);
                    ranges.push((start, end));
                    at_word += w;
                }
                LayerShards { index: lp.index, c_out: lp.c_out, ranges }
            })
            .collect();
        let sp = ShardPlan { n_macros: n, layers, axis: ShardAxis::Output };
        sp.validate()?;
        Ok(sp)
    }

    /// Input-channel-axis split, 32-channel (feature-word) granular:
    /// every macro owns the same `[start, end)` input slice of each
    /// layer (`c_in` is a word multiple by plan construction, so all
    /// slices are word-aligned). Each macro computes partial raw sums
    /// over **all** output channels of its slice; the engines merge by
    /// integer addition before thresholding. `LayerShards::c_out` holds
    /// the layer's input-channel total under this axis.
    pub fn input_word_aligned(plan: &KwsPlan, n: usize) -> Result<Self> {
        ensure!(n >= 1, "shard count must be >= 1");
        let layers = plan
            .layers
            .iter()
            .map(|lp| {
                let c_in = lp.s_words * 32;
                let words = lp.s_words;
                let base = words / n;
                let rem = words % n;
                let mut ranges = Vec::with_capacity(n);
                let mut at_word = 0;
                for m in 0..n {
                    let w = base + usize::from(m < rem);
                    ranges.push((at_word * 32, (at_word + w) * 32));
                    at_word += w;
                }
                LayerShards { index: lp.index, c_out: c_in, ranges }
            })
            .collect();
        let sp = ShardPlan { n_macros: n, layers, axis: ShardAxis::Input };
        sp.validate()?;
        Ok(sp)
    }

    /// Structural invariants: per layer, `n_macros` contiguous ranges
    /// covering exactly `[0, c_out)`.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.n_macros >= 1, "shard plan needs at least one macro");
        for ls in &self.layers {
            ensure!(
                ls.ranges.len() == self.n_macros,
                "layer {}: {} ranges for {} macros",
                ls.index,
                ls.ranges.len(),
                self.n_macros
            );
            let mut at = 0;
            for &(a, b) in &ls.ranges {
                ensure!(a == at && b >= a, "layer {}: ranges must tile [0, c_out)", ls.index);
                at = b;
            }
            ensure!(at == ls.c_out, "layer {}: ranges cover {at}, want {}", ls.index, ls.c_out);
        }
        Ok(())
    }

    /// True when every **non-empty** range starts on an output-latch word
    /// boundary (required by the cycle engine's drain addressing; empty
    /// ranges are never drained, and a trailing empty range necessarily
    /// starts at `c_out`, which need not be a word multiple).
    pub fn is_word_aligned(&self) -> bool {
        self.layers
            .iter()
            .all(|ls| ls.ranges.iter().all(|&(a, b)| b == a || a % 32 == 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::kws::LayerSpec;
    use crate::model::KwsModel;

    fn plan() -> KwsPlan {
        let mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: vec![1; 3 * ci * co],
            thresholds: if binarized { vec![0; co] } else { vec![] },
        };
        let m = KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 1,
            layers: vec![mk(64, 70, true, true), mk(64, 64, true, true), mk(64, 12, false, false)],
            bn_gamma: vec![1.0; 64],
            bn_beta: vec![0.0; 64],
            bn_mean: vec![0.0; 64],
            bn_var: vec![1.0; 64],
            pre_thr: vec![0; 64],
            pre_dir: vec![1; 64],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        };
        // c_out=70 is not macro-legal for c_in (s_words) purposes? It is:
        // only c_in must be a word multiple.
        KwsPlan::new(&m).unwrap()
    }

    #[test]
    fn even_split_covers_and_balances() {
        let p = plan();
        for n in 1..=4 {
            let sp = ShardPlan::even(&p, n).unwrap();
            sp.validate().unwrap();
            assert_eq!(sp.n_macros, n);
            for ls in &sp.layers {
                let lens: Vec<usize> = (0..n).map(|m| ls.len(m)).collect();
                let total: usize = lens.iter().sum();
                assert_eq!(total, ls.c_out);
                let max = *lens.iter().max().unwrap();
                let min = *lens.iter().min().unwrap();
                assert!(max - min <= 1, "uneven split must differ by <= 1: {lens:?}");
            }
        }
        // 70 % 4 != 0: the leading shards take the remainder.
        let sp = ShardPlan::even(&p, 4).unwrap();
        assert_eq!(sp.layers[0].ranges, vec![(0, 18), (18, 36), (36, 53), (53, 70)]);
    }

    #[test]
    fn word_aligned_split_is_word_aligned() {
        let p = plan();
        for n in 1..=4 {
            let sp = ShardPlan::word_aligned(&p, n).unwrap();
            sp.validate().unwrap();
            assert!(sp.is_word_aligned());
        }
        // 70 channels = 3 latch words over 2 macros: 2 + 1 words.
        let sp = ShardPlan::word_aligned(&p, 2).unwrap();
        assert_eq!(sp.layers[0].ranges, vec![(0, 64), (64, 70)]);
        // 12 channels on 4 macros: macro 0 owns all, 1..3 idle.
        assert_eq!(sp.layers[2].ranges, vec![(0, 12), (12, 12), (12, 12), (12, 12)]);
        assert_eq!(sp.layers[2].non_empty(), vec![(0, 0, 12)]);
    }

    #[test]
    fn input_split_tiles_input_channels() {
        let p = plan();
        for n in 1..=4 {
            let sp = ShardPlan::input_word_aligned(&p, n).unwrap();
            sp.validate().unwrap();
            assert_eq!(sp.axis, ShardAxis::Input);
            assert!(sp.is_word_aligned());
            for (ls, lp) in sp.layers.iter().zip(&p.layers) {
                assert_eq!(ls.c_out, lp.s_words * 32, "axis total is c_in");
                let covered: usize = (0..n).map(|m| ls.len(m)).sum();
                assert_eq!(covered, lp.s_words * 32);
            }
        }
        // 64 input channels = 2 words over 4 macros: 2 own a word each.
        let sp = ShardPlan::input_word_aligned(&p, 4).unwrap();
        assert_eq!(sp.layers[0].non_empty(), vec![(0, 0, 32), (1, 32, 64)]);
    }

    #[test]
    fn single_plan_matches_even_1() {
        let p = plan();
        assert_eq!(ShardPlan::single(&p), ShardPlan::even(&p, 1).unwrap());
        assert!(ShardPlan::even(&p, 0).is_err());
    }
}
