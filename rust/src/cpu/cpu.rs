//! The execute engine: fetch -> decode -> execute, one instruction per
//! step, with the paper's single-cycle CIM instructions.

use anyhow::{bail, Context, Result};

use crate::isa::{self, CimFunct, Instr};
use crate::mem::bus::{Bus, Width};
use crate::mem::layout::{self, Region};

use super::csr::CsrFile;
use super::regfile::RegFile;

/// Per-class retired-instruction counters (energy model + reports).
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecStats {
    pub instret: u64,
    pub cycles: u64,
    pub alu: u64,
    pub loads: u64,
    pub stores: u64,
    pub branches: u64,
    pub taken_branches: u64,
    pub jumps: u64,
    pub muldiv: u64,
    pub csr: u64,
    pub cim_conv: u64,
    pub cim_r: u64,
    pub cim_w: u64,
    /// Cycles lost to front-end flushes (taken control flow).
    pub flush_cycles: u64,
    /// Cycles lost to DRAM stalls (LSU misses into the DRAM window).
    pub dram_stall_cycles: u64,
}

/// What a single step did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepOutcome {
    /// Retired normally; `cycles` consumed.
    Retired { cycles: u64 },
    /// The program signalled completion (HOST_EXIT write or ebreak).
    Halted,
}

/// The 2-stage core.
#[derive(Debug, Clone)]
pub struct Cpu {
    pub pc: u32,
    pub regs: RegFile,
    pub csrs: CsrFile,
    pub stats: ExecStats,
    /// Halt latch (ebreak or HOST_EXIT observed).
    pub halted: bool,
}

impl Default for Cpu {
    fn default() -> Self {
        Self::new(0)
    }
}

/// Iterative divider latency (ibex-style).
const DIV_CYCLES: u64 = 37;

impl Cpu {
    pub fn new(boot_pc: u32) -> Self {
        Cpu { pc: boot_pc, regs: RegFile::new(), csrs: CsrFile::default(), stats: ExecStats::default(), halted: false }
    }

    /// Execute one instruction against the bus. The caller (SoC) owns the
    /// global clock: it calls `bus.tick(now)` first and advances `now` by
    /// the returned cycle count.
    pub fn step(&mut self, bus: &mut Bus) -> Result<StepOutcome> {
        if self.halted {
            return Ok(StepOutcome::Halted);
        }
        let pc = self.pc;
        let word = bus.fetch(pc)?;
        let instr = isa::decode(word).with_context(|| format!("at pc={pc:#010x}"))?;
        self.exec(instr, bus)
    }

    /// `step` with a predecoded program image (§Perf: decode once at load
    /// instead of on every retired instruction — the ISS's hottest path).
    /// Functionally identical to `step` for programs inside `prog`.
    pub fn step_predecoded(&mut self, bus: &mut Bus, prog: &[Instr]) -> Result<StepOutcome> {
        if self.halted {
            return Ok(StepOutcome::Halted);
        }
        let idx = (self.pc / 4) as usize;
        match prog.get(idx) {
            Some(&instr) => self.exec(instr, bus),
            None => self.step(bus), // outside the predecoded window
        }
    }

    fn exec(&mut self, instr: Instr, bus: &mut Bus) -> Result<StepOutcome> {
        let pc = self.pc;
        let mut cycles: u64 = 1;
        let mut next_pc = pc.wrapping_add(4);
        let s = &mut self.stats;

        match instr {
            Instr::Lui { rd, imm } => {
                s.alu += 1;
                self.regs.write(rd, (imm as u32) << 12);
            }
            Instr::Auipc { rd, imm } => {
                s.alu += 1;
                self.regs.write(rd, pc.wrapping_add((imm as u32) << 12));
            }
            Instr::Jal { rd, offset } => {
                s.jumps += 1;
                self.regs.write(rd, pc.wrapping_add(4));
                next_pc = pc.wrapping_add(offset as u32);
                cycles += 1;
                s.flush_cycles += 1;
            }
            Instr::Jalr { rd, rs1, offset } => {
                s.jumps += 1;
                let target = self.regs.read(rs1).wrapping_add(offset as u32) & !1;
                self.regs.write(rd, pc.wrapping_add(4));
                next_pc = target;
                cycles += 1;
                s.flush_cycles += 1;
            }
            Instr::Branch { kind, rs1, rs2, offset } => {
                s.branches += 1;
                let a = self.regs.read(rs1);
                let b = self.regs.read(rs2);
                use crate::isa::rv32::BranchKind::*;
                let taken = match kind {
                    Beq => a == b,
                    Bne => a != b,
                    Blt => (a as i32) < (b as i32),
                    Bge => (a as i32) >= (b as i32),
                    Bltu => a < b,
                    Bgeu => a >= b,
                };
                if taken {
                    s.taken_branches += 1;
                    next_pc = pc.wrapping_add(offset as u32);
                    cycles += 1;
                    s.flush_cycles += 1;
                }
            }
            Instr::Load { kind, rd, rs1, offset } => {
                s.loads += 1;
                let addr = self.regs.read(rs1).wrapping_add(offset as u32);
                use crate::isa::rv32::LoadKind::*;
                let (w, sext) = match kind {
                    Lb => (Width::Byte, true),
                    Lh => (Width::Half, true),
                    Lw => (Width::Word, false),
                    Lbu => (Width::Byte, false),
                    Lhu => (Width::Half, false),
                };
                let (raw, stall) = bus.read(addr, w)?;
                let v = if sext {
                    match w {
                        Width::Byte => raw as u8 as i8 as i32 as u32,
                        Width::Half => raw as u16 as i16 as i32 as u32,
                        Width::Word => raw,
                    }
                } else {
                    raw
                };
                self.regs.write(rd, v);
                cycles += 1 + stall; // 2-cycle load + DRAM stalls
                s.dram_stall_cycles += stall;
            }
            Instr::Store { kind, rs1, rs2, offset } => {
                s.stores += 1;
                let addr = self.regs.read(rs1).wrapping_add(offset as u32);
                use crate::isa::rv32::StoreKind::*;
                let w = match kind {
                    Sb => Width::Byte,
                    Sh => Width::Half,
                    Sw => Width::Word,
                };
                let stall = bus.write(addr, self.regs.read(rs2), w)?;
                cycles += stall;
                s.dram_stall_cycles += stall;
                if bus.exit_code.is_some() {
                    self.halted = true;
                    s.instret += 1;
                    s.cycles += cycles;
                    return Ok(StepOutcome::Halted);
                }
            }
            Instr::OpImm { op, rd, rs1, imm } => {
                s.alu += 1;
                let v = alu(op, self.regs.read(rs1), imm as u32);
                self.regs.write(rd, v);
            }
            Instr::Op { op, rd, rs1, rs2 } => {
                s.alu += 1;
                let v = alu(op, self.regs.read(rs1), self.regs.read(rs2));
                self.regs.write(rd, v);
            }
            Instr::MulDiv { op, rd, rs1, rs2 } => {
                s.muldiv += 1;
                let a = self.regs.read(rs1);
                let b = self.regs.read(rs2);
                use crate::isa::rv32::MulOp::*;
                let v = match op {
                    Mul => a.wrapping_mul(b),
                    Mulh => (((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32,
                    Mulhsu => (((a as i32 as i64) * (b as u64 as i64)) >> 32) as u32,
                    Mulhu => (((a as u64) * (b as u64)) >> 32) as u32,
                    Div => {
                        cycles += DIV_CYCLES;
                        if b == 0 { u32::MAX } else if a == 0x8000_0000 && b == u32::MAX { a } else { ((a as i32).wrapping_div(b as i32)) as u32 }
                    }
                    Divu => {
                        cycles += DIV_CYCLES;
                        if b == 0 { u32::MAX } else { a / b }
                    }
                    Rem => {
                        cycles += DIV_CYCLES;
                        if b == 0 { a } else if a == 0x8000_0000 && b == u32::MAX { 0 } else { ((a as i32).wrapping_rem(b as i32)) as u32 }
                    }
                    Remu => {
                        cycles += DIV_CYCLES;
                        if b == 0 { a } else { a % b }
                    }
                };
                self.regs.write(rd, v);
            }
            Instr::Fence => {
                s.alu += 1;
            }
            Instr::Ecall | Instr::Ebreak => {
                self.halted = true;
                s.instret += 1;
                s.cycles += cycles;
                return Ok(StepOutcome::Halted);
            }
            Instr::Csr { op, rd, rs1, csr } => {
                s.csr += 1;
                use crate::isa::rv32::CsrOp::*;
                let old = self.csrs.read(csr, s.cycles, s.instret)?;
                let operand = match op {
                    Rw | Rs | Rc => self.regs.read(rs1),
                    Rwi | Rsi | Rci => rs1.0 as u32,
                };
                let new = match op {
                    Rw | Rwi => Some(operand),
                    Rs | Rsi => (operand != 0).then_some(old | operand),
                    Rc | Rci => (operand != 0).then_some(old & !operand),
                };
                if let Some(v) = new {
                    self.csrs.write(csr, v)?;
                }
                self.regs.write(rd, old);
            }
            Instr::Cim(c) => {
                let _ = s;
                self.exec_cim(c, bus).with_context(|| format!("{c} at pc={pc:#010x}"))?;
            }
        }

        self.stats.instret += 1;
        self.stats.cycles += cycles;
        self.pc = next_pc;
        Ok(StepOutcome::Retired { cycles })
    }

    /// The CIM execute unit (paper Fig. 3/4): all three forms retire in
    /// one cycle; datapath touches FM/WT SRAM and the macro directly.
    fn exec_cim(&mut self, c: crate::isa::CimInstr, bus: &mut Bus) -> Result<()> {
        match c.funct {
            CimFunct::Conv => {
                self.stats.cim_conv += 1;
                if c.sh {
                    let src = self.regs.read(c.rs1).wrapping_add(4 * c.imm_s as u32);
                    let word = read_onchip_word(bus, src)?;
                    bus.cim_shift_in(word);
                }
                if c.wd == 0 {
                    bus.cim_fire();
                }
                let out = bus.cim_mut().store_word(c.wd);
                let dst = self.regs.read(c.rs2).wrapping_add(4 * c.imm_d as u32);
                write_onchip_word(bus, dst, out)?;
            }
            CimFunct::Write => {
                self.stats.cim_w += 1;
                let src = self.regs.read(c.rs1).wrapping_add(4 * c.imm_s as u32);
                let word = read_onchip_word(bus, src)?;
                let port = self.regs.read(c.rs2).wrapping_add(c.imm_d as u32);
                bus.cim_port_write(port, word)?;
            }
            CimFunct::Read => {
                self.stats.cim_r += 1;
                let port = self.regs.read(c.rs1).wrapping_add(c.imm_s as u32);
                let word = bus.cim_mut().port_read(port)?;
                let dst = self.regs.read(c.rs2).wrapping_add(4 * c.imm_d as u32);
                write_onchip_word(bus, dst, word)?;
            }
        }
        Ok(())
    }
}

/// CIM datapath SRAM read: FM or weight SRAM only (paper §II-C: "the CIM
/// instructions utilize data from the feature map SRAM or weight SRAM").
fn read_onchip_word(bus: &mut Bus, addr: u32) -> Result<u32> {
    match layout::decode(addr) {
        Some((Region::FmSram, off)) => bus.fm.read_u32(off),
        Some((Region::WtSram, off)) => bus.wt.read_u32(off),
        Some((Region::Dmem, off)) => bus.dmem.read_u32(off),
        _ => bail!("CIM access outside on-chip SRAM: {addr:#010x}"),
    }
}

fn write_onchip_word(bus: &mut Bus, addr: u32, v: u32) -> Result<()> {
    match layout::decode(addr) {
        Some((Region::FmSram, off)) => bus.fm.write_u32(off, v),
        Some((Region::WtSram, off)) => bus.wt.write_u32(off, v),
        Some((Region::Dmem, off)) => bus.dmem.write_u32(off, v),
        _ => bail!("CIM store outside on-chip SRAM: {addr:#010x}"),
    }
}

fn alu(op: crate::isa::rv32::AluOp, a: u32, b: u32) -> u32 {
    use crate::isa::rv32::AluOp::*;
    match op {
        Add => a.wrapping_add(b),
        Sub => a.wrapping_sub(b),
        Sll => a.wrapping_shl(b & 31),
        Slt => ((a as i32) < (b as i32)) as u32,
        Sltu => (a < b) as u32,
        Xor => a ^ b,
        Srl => a.wrapping_shr(b & 31),
        Sra => ((a as i32).wrapping_shr(b & 31)) as u32,
        Or => a | b,
        And => a & b,
    }
}

#[cfg(test)]
mod tests {
    use crate::isa::Reg;
    use super::*;
    use crate::isa::encode;
    use crate::mem::dram::DramConfig;

    fn run_program(words: &[u32]) -> (Cpu, Bus) {
        let mut bus = Bus::new(DramConfig::default());
        for (i, w) in words.iter().enumerate() {
            bus.imem.poke_u32((i * 4) as u32, *w).unwrap();
        }
        let mut cpu = Cpu::new(0);
        let mut now = 0u64;
        for _ in 0..10_000 {
            bus.tick(now).unwrap();
            match cpu.step(&mut bus).unwrap() {
                StepOutcome::Retired { cycles } => now += cycles,
                StepOutcome::Halted => break,
            }
        }
        (cpu, bus)
    }

    fn asm(instrs: &[Instr]) -> Vec<u32> {
        instrs.iter().map(|i| encode(i).unwrap()).collect()
    }

    #[test]
    fn arithmetic_and_halt() {
        use crate::isa::rv32::AluOp::*;
        let prog = asm(&[
            Instr::OpImm { op: Add, rd: Reg::A0, rs1: Reg::ZERO, imm: 21 },
            Instr::Op { op: Add, rd: Reg::A0, rs1: Reg::A0, rs2: Reg::A0 },
            Instr::Ebreak,
        ]);
        let (cpu, _) = run_program(&prog);
        assert_eq!(cpu.regs.read(Reg::A0), 42);
        assert!(cpu.halted);
        assert_eq!(cpu.stats.instret, 3);
    }

    #[test]
    fn loads_stores_dmem() {
        use crate::isa::rv32::{LoadKind, StoreKind};
        let base = layout::DMEM_BASE as i32;
        let prog = asm(&[
            Instr::Lui { rd: Reg::T0, imm: base >> 12 },
            Instr::OpImm { op: crate::isa::rv32::AluOp::Add, rd: Reg::T1, rs1: Reg::ZERO, imm: -7 },
            Instr::Store { kind: StoreKind::Sw, rs1: Reg::T0, rs2: Reg::T1, offset: 16 },
            Instr::Load { kind: LoadKind::Lw, rd: Reg::T2, rs1: Reg::T0, offset: 16 },
            Instr::Load { kind: LoadKind::Lh, rd: Reg::T3, rs1: Reg::T0, offset: 16 },
            Instr::Load { kind: LoadKind::Lbu, rd: Reg::T4, rs1: Reg::T0, offset: 16 },
            Instr::Ebreak,
        ]);
        let (cpu, _) = run_program(&prog);
        assert_eq!(cpu.regs.read(Reg::T2) as i32, -7);
        assert_eq!(cpu.regs.read(Reg::T3) as i32, -7); // sign-extended lh
        assert_eq!(cpu.regs.read(Reg::T4), 0xF9); // zero-extended lbu
    }

    #[test]
    fn branch_loop_counts_taken_flushes() {
        use crate::isa::rv32::AluOp::*;
        use crate::isa::rv32::BranchKind::*;
        // t0 = 5; loop: t0 -= 1; bne t0, zero, loop; ebreak
        let prog = asm(&[
            Instr::OpImm { op: Add, rd: Reg::T0, rs1: Reg::ZERO, imm: 5 },
            Instr::OpImm { op: Add, rd: Reg::T0, rs1: Reg::T0, imm: -1 },
            Instr::Branch { kind: Bne, rs1: Reg::T0, rs2: Reg::ZERO, offset: -4 },
            Instr::Ebreak,
        ]);
        let (cpu, _) = run_program(&prog);
        assert_eq!(cpu.regs.read(Reg::T0), 0);
        assert_eq!(cpu.stats.taken_branches, 4);
        assert_eq!(cpu.stats.flush_cycles, 4);
    }

    #[test]
    fn muldiv_semantics() {
        use crate::isa::rv32::AluOp::*;
        use crate::isa::rv32::MulOp::*;
        let prog = asm(&[
            Instr::OpImm { op: Add, rd: Reg::T0, rs1: Reg::ZERO, imm: -6 },
            Instr::OpImm { op: Add, rd: Reg::T1, rs1: Reg::ZERO, imm: 7 },
            Instr::MulDiv { op: Mul, rd: Reg::T2, rs1: Reg::T0, rs2: Reg::T1 },
            Instr::MulDiv { op: Div, rd: Reg::T3, rs1: Reg::T0, rs2: Reg::T1 },
            Instr::MulDiv { op: Rem, rd: Reg::T4, rs1: Reg::T0, rs2: Reg::T1 },
            Instr::MulDiv { op: Divu, rd: Reg::T5, rs1: Reg::T1, rs2: Reg::ZERO },
            Instr::Ebreak,
        ]);
        let (cpu, _) = run_program(&prog);
        assert_eq!(cpu.regs.read(Reg::T2) as i32, -42);
        assert_eq!(cpu.regs.read(Reg::T3) as i32, 0);
        assert_eq!(cpu.regs.read(Reg::T4) as i32, -6);
        assert_eq!(cpu.regs.read(Reg::T5), u32::MAX); // div by zero
    }

    #[test]
    fn single_cycle_cim_conv() {
        use crate::isa::CimInstr;
        // a0 = FM base (src), a1 = FM base + 0x100 (dst). One masked-off
        // macro (all masks zero) -> all sums 0, latch 0, but timing must
        // still be a single cycle.
        let fm = layout::FM_BASE as i32;
        let prog = asm(&[
            Instr::Lui { rd: Reg::A0, imm: fm >> 12 },
            Instr::Lui { rd: Reg::A1, imm: fm >> 12 },
            Instr::OpImm { op: crate::isa::rv32::AluOp::Add, rd: Reg::A1, rs1: Reg::A1, imm: 0x100 },
            Instr::Cim(CimInstr::conv(Reg::A0, 0, Reg::A1, 0, 0, true)),
            Instr::Ebreak,
        ]);
        let (cpu, bus) = run_program(&prog);
        assert_eq!(cpu.stats.cim_conv, 1);
        assert_eq!(bus.cim().stats.fires, 1);
        assert_eq!(bus.cim().stats.shifts, 1);
        // 3 ALU-ish (1 cycle each... lui=1) + cim 1 = instret 5 incl ebreak
        assert_eq!(cpu.stats.instret, 5);
    }

    #[test]
    fn cim_w_r_port_roundtrip_through_sram() {
        use crate::isa::CimInstr;
        let wt = layout::WT_BASE as i32;
        let prog = asm(&[
            Instr::Lui { rd: Reg::A0, imm: wt >> 12 },        // a0 = WT base
            Instr::Lui { rd: Reg::T0, imm: 0xABCDE },
            Instr::Store { kind: crate::isa::rv32::StoreKind::Sw, rs1: Reg::A0, rs2: Reg::T0, offset: 0 },
            Instr::OpImm { op: crate::isa::rv32::AluOp::Add, rd: Reg::A1, rs1: Reg::ZERO, imm: 0 }, // a1 = port 0
            Instr::Cim(CimInstr::write(Reg::A0, 0, Reg::A1, 5)), // WT[0] -> port word 5
            Instr::Cim(CimInstr::read(Reg::A1, 5, Reg::A0, 8)),  // port word 5 -> WT[8 words]
            Instr::Ebreak,
        ]);
        let (cpu, bus) = run_program(&prog);
        assert_eq!(cpu.stats.cim_w, 1);
        assert_eq!(cpu.stats.cim_r, 1);
        assert_eq!(bus.wt.peek_u32(32).unwrap(), 0xABCDE000);
    }

    #[test]
    fn dram_load_stalls_cpu() {
        use crate::isa::rv32::LoadKind;
        let dram = layout::DRAM_BASE as i32;
        let prog = asm(&[
            Instr::Lui { rd: Reg::T0, imm: dram >> 12 },
            Instr::Load { kind: LoadKind::Lw, rd: Reg::T1, rs1: Reg::T0, offset: 0 },
            Instr::Ebreak,
        ]);
        let (cpu, _) = run_program(&prog);
        assert!(cpu.stats.dram_stall_cycles > 0);
        assert!(cpu.stats.cycles > cpu.stats.instret);
    }
}
