//! The modified RISC-V core (paper Fig. 3): a 2-stage (ibex-class)
//! in-order pipeline — prefetch buffer feeding a decode/execute stage —
//! extended with the CIM read/write/convolution execute units.
//!
//! Timing model (cycles per retired instruction):
//!   * ALU / CSR / CIM-type: 1   (CIM instructions are atomic single-cycle,
//!     §II-C — the point of the ISA extension)
//!   * loads: 2 (+ DRAM stalls), stores: 1 (+ DRAM stalls)
//!   * taken branches / jumps: 2 (front-end flush of the 2-stage pipe)
//!   * mul: 1, div/rem: 37 (iterative divider, ibex-style)

pub mod cpu;
pub mod csr;
pub mod regfile;

pub use cpu::{Cpu, ExecStats, StepOutcome};
