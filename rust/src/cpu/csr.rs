//! Control and status registers. We implement the counters the paper's
//! flow actually uses (cycle/instret and their machine-mode aliases,
//! plus a scratch register) — enough for self-timing programs — and
//! fault on anything else.

use anyhow::{bail, Result};

/// Standard CSR addresses.
pub const CSR_CYCLE: u16 = 0xC00;
pub const CSR_CYCLEH: u16 = 0xC80;
pub const CSR_INSTRET: u16 = 0xC02;
pub const CSR_INSTRETH: u16 = 0xC82;
/// Machine-mode counter aliases (mcycle/minstret + high halves): firmware
/// written against M-mode reads these instead of the user-mode shadows,
/// and long-running self-timed loops need the high halves once the run
/// crosses 2^32 cycles.
pub const CSR_MCYCLE: u16 = 0xB00;
pub const CSR_MCYCLEH: u16 = 0xB80;
pub const CSR_MINSTRET: u16 = 0xB02;
pub const CSR_MINSTRETH: u16 = 0xB82;
/// mscratch: free scratch register.
pub const CSR_MSCRATCH: u16 = 0x340;

#[derive(Debug, Clone, Default)]
pub struct CsrFile {
    pub mscratch: u32,
}

impl CsrFile {
    pub fn read(&self, csr: u16, cycle: u64, instret: u64) -> Result<u32> {
        Ok(match csr {
            CSR_CYCLE | CSR_MCYCLE => cycle as u32,
            CSR_CYCLEH | CSR_MCYCLEH => (cycle >> 32) as u32,
            CSR_INSTRET | CSR_MINSTRET => instret as u32,
            CSR_INSTRETH | CSR_MINSTRETH => (instret >> 32) as u32,
            CSR_MSCRATCH => self.mscratch,
            _ => bail!("unimplemented CSR {csr:#x}"),
        })
    }

    pub fn write(&mut self, csr: u16, v: u32) -> Result<()> {
        match csr {
            CSR_MSCRATCH => self.mscratch = v,
            CSR_CYCLE | CSR_CYCLEH | CSR_INSTRET | CSR_INSTRETH => {
                bail!("CSR {csr:#x} is read-only")
            }
            // The hardware counters are writable in M-mode on real cores;
            // our programs never preset them, so accept and ignore the
            // write instead of faulting mid-run.
            CSR_MCYCLE | CSR_MCYCLEH | CSR_MINSTRET | CSR_MINSTRETH => {}
            _ => bail!("unimplemented CSR {csr:#x}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_scratch() {
        let mut c = CsrFile::default();
        assert_eq!(c.read(CSR_CYCLE, 0x1_0000_0005, 3).unwrap(), 5);
        assert_eq!(c.read(CSR_CYCLEH, 0x1_0000_0005, 3).unwrap(), 1);
        assert_eq!(c.read(CSR_INSTRET, 0, 3).unwrap(), 3);
        c.write(CSR_MSCRATCH, 99).unwrap();
        assert_eq!(c.read(CSR_MSCRATCH, 0, 0).unwrap(), 99);
        assert!(c.write(CSR_CYCLE, 0).is_err());
        assert!(c.read(0x300, 0, 0).is_err());
    }

    #[test]
    fn counter_carry_at_the_2_to_32_boundary() {
        // The low word wraps 0xFFFF_FFFF -> 0 exactly when the high word
        // carries 0 -> 1, and the user-mode shadows agree with the
        // machine-mode aliases at both sides of the boundary.
        let c = CsrFile::default();
        let before = u32::MAX as u64; // 2^32 - 1
        let after = before + 1; // 2^32
        for (lo, hi, mlo, mhi) in [
            (CSR_CYCLE, CSR_CYCLEH, CSR_MCYCLE, CSR_MCYCLEH),
            (CSR_INSTRET, CSR_INSTRETH, CSR_MINSTRET, CSR_MINSTRETH),
        ] {
            for csr_lo in [lo, mlo] {
                assert_eq!(c.read(csr_lo, before, before).unwrap(), u32::MAX);
                assert_eq!(c.read(csr_lo, after, after).unwrap(), 0);
            }
            for csr_hi in [hi, mhi] {
                assert_eq!(c.read(csr_hi, before, before).unwrap(), 0);
                assert_eq!(c.read(csr_hi, after, after).unwrap(), 1);
            }
        }
        // Reassembling (hi << 32) | lo recovers the exact 64-bit count.
        let big = 0x7_8000_0001u64;
        let lo = c.read(CSR_MCYCLE, big, 0).unwrap() as u64;
        let hi = c.read(CSR_MCYCLEH, big, 0).unwrap() as u64;
        assert_eq!((hi << 32) | lo, big);
    }

    #[test]
    fn machine_mode_counter_aliases() {
        let mut c = CsrFile::default();
        let cycle = 0x2_0000_0007u64;
        let instret = 0x3_0000_0009u64;
        assert_eq!(c.read(CSR_MCYCLE, cycle, instret).unwrap(), 7);
        assert_eq!(c.read(CSR_MCYCLEH, cycle, instret).unwrap(), 2);
        assert_eq!(c.read(CSR_MINSTRET, cycle, instret).unwrap(), 9);
        assert_eq!(c.read(CSR_MINSTRETH, cycle, instret).unwrap(), 3);
        // M-mode counter writes are accepted (and ignored), not faults.
        c.write(CSR_MCYCLE, 0).unwrap();
        c.write(CSR_MINSTRETH, 0).unwrap();
    }
}
