//! Control and status registers. We implement the counters the paper's
//! flow actually uses (cycle, instret, and a scratch register) — enough
//! for self-timing programs — and fault on anything else.

use anyhow::{bail, Result};

/// Standard CSR addresses.
pub const CSR_CYCLE: u16 = 0xC00;
pub const CSR_CYCLEH: u16 = 0xC80;
pub const CSR_INSTRET: u16 = 0xC02;
pub const CSR_INSTRETH: u16 = 0xC82;
/// mscratch: free scratch register.
pub const CSR_MSCRATCH: u16 = 0x340;

#[derive(Debug, Clone, Default)]
pub struct CsrFile {
    pub mscratch: u32,
}

impl CsrFile {
    pub fn read(&self, csr: u16, cycle: u64, instret: u64) -> Result<u32> {
        Ok(match csr {
            CSR_CYCLE => cycle as u32,
            CSR_CYCLEH => (cycle >> 32) as u32,
            CSR_INSTRET => instret as u32,
            CSR_INSTRETH => (instret >> 32) as u32,
            CSR_MSCRATCH => self.mscratch,
            _ => bail!("unimplemented CSR {csr:#x}"),
        })
    }

    pub fn write(&mut self, csr: u16, v: u32) -> Result<()> {
        match csr {
            CSR_MSCRATCH => self.mscratch = v,
            CSR_CYCLE | CSR_CYCLEH | CSR_INSTRET | CSR_INSTRETH => {
                bail!("CSR {csr:#x} is read-only")
            }
            _ => bail!("unimplemented CSR {csr:#x}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_scratch() {
        let mut c = CsrFile::default();
        assert_eq!(c.read(CSR_CYCLE, 0x1_0000_0005, 3).unwrap(), 5);
        assert_eq!(c.read(CSR_CYCLEH, 0x1_0000_0005, 3).unwrap(), 1);
        assert_eq!(c.read(CSR_INSTRET, 0, 3).unwrap(), 3);
        c.write(CSR_MSCRATCH, 99).unwrap();
        assert_eq!(c.read(CSR_MSCRATCH, 0, 0).unwrap(), 99);
        assert!(c.write(CSR_CYCLE, 0).is_err());
        assert!(c.read(0x300, 0, 0).is_err());
    }
}
