//! The 31x32-bit register file (x0 hardwired to zero).

use crate::isa::Reg;

#[derive(Debug, Clone)]
pub struct RegFile {
    regs: [u32; 32],
}

impl Default for RegFile {
    fn default() -> Self {
        Self::new()
    }
}

impl RegFile {
    pub fn new() -> Self {
        RegFile { regs: [0; 32] }
    }

    #[inline]
    pub fn read(&self, r: Reg) -> u32 {
        self.regs[r.idx()]
    }

    #[inline]
    pub fn write(&mut self, r: Reg, v: u32) {
        if r.0 != 0 {
            self.regs[r.idx()] = v;
        }
    }

    /// Debug dump (trace output).
    pub fn snapshot(&self) -> [u32; 32] {
        self.regs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn x0_is_hardwired_zero() {
        let mut rf = RegFile::new();
        rf.write(Reg::ZERO, 0xFFFF_FFFF);
        assert_eq!(rf.read(Reg::ZERO), 0);
    }

    #[test]
    fn rw() {
        let mut rf = RegFile::new();
        rf.write(Reg::A0, 42);
        rf.write(Reg::T6, 7);
        assert_eq!(rf.read(Reg::A0), 42);
        assert_eq!(rf.read(Reg::T6), 7);
    }
}
