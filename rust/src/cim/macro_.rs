//! The CIM macro: 512 Kb cell array, word port, single-cycle MAC fire,
//! output latch, pooling register and raw-sum readout.
//!
//! Semantics contract (shared with `python/compile/kernels/ref.py` and the
//! Pallas kernel): inputs in {0,1}, weights in {-1,0,+1} (sign+mask
//! planes), `out[c] = (sum_r in[r]*w[r][c]) > th[c]`. The integer MAC is
//! computed with bit-parallel popcounts:
//!
//! ```text
//!   sum = 2*popcount(x & sign & mask) - popcount(x & mask)
//! ```
//!
//! which is exactly `sum over active rows of (sign ? +1 : -1) * x_r`.

use anyhow::{bail, Result};

use super::input_buffer::InputBuffer;
use super::mode::{CimConfig, Mode};
use super::variation::VariationModel;
use super::weight_map::{self, PortWord};

/// Fire/shift/load statistics (energy model inputs).
#[derive(Debug, Clone, Copy, Default)]
pub struct CimStats {
    /// Full-array MAC fires.
    pub fires: u64,
    /// Input-buffer word shifts.
    pub shifts: u64,
    /// Output latch words stored to SRAM.
    pub out_words: u64,
    /// Weight port writes (`cim_w`).
    pub weight_writes: u64,
    /// Weight port reads (`cim_r`).
    pub weight_reads: u64,
    /// Total MAC operations performed (wordlines x SAs per fire).
    pub macs: u64,
}

/// The macro model.
#[derive(Debug, Clone)]
pub struct CimMacro {
    /// Sign plane: 8192 words (bit=1 -> +1).
    sign: Vec<u32>,
    /// Mask plane: 8192 words (bit=1 -> active cell).
    mask: Vec<u32>,
    /// Per-SA thresholds (512).
    th: Vec<i32>,
    /// Input shift buffer.
    pub input: InputBuffer,
    /// Binarized output latch of the last fire (512 bits max = 16 words).
    latch: [u32; 16],
    /// Max-pool rolling register (Fig. 7 pipeline block).
    pool_reg: [u32; 16],
    /// Raw integer sums of the last fire (high-precision readout port).
    raw: Vec<i32>,
    /// Live configuration (MMIO CIM_CFG).
    pub cfg: CimConfig,
    /// Optional variation/NL injection.
    pub variation: Option<VariationModel>,
    pub stats: CimStats,
}

impl Default for CimMacro {
    fn default() -> Self {
        Self::new()
    }
}

impl CimMacro {
    pub fn new() -> Self {
        CimMacro {
            sign: vec![0; weight_map::SIGN_WORDS as usize],
            mask: vec![0; weight_map::MASK_WORDS as usize],
            th: vec![0; weight_map::TH_WORDS as usize],
            input: InputBuffer::new(),
            latch: [0; 16],
            pool_reg: [0; 16],
            raw: vec![0; weight_map::RAW_WORDS as usize],
            cfg: CimConfig::default(),
            variation: None,
            stats: CimStats::default(),
        }
    }

    /// `cim_w`: write a 32-bit word into the port address space.
    pub fn port_write(&mut self, addr: u32, value: u32) -> Result<()> {
        self.stats.weight_writes += 1;
        match weight_map::decode_port(addr) {
            Some(PortWord::Sign(i)) => self.sign[i as usize] = value,
            Some(PortWord::Mask(i)) => self.mask[i as usize] = value,
            Some(PortWord::Threshold(i)) => self.th[i as usize] = value as i32,
            Some(PortWord::RawSum(_)) => bail!("raw-sum port is read-only"),
            None => bail!("cim_w to unmapped port word {addr:#x}"),
        }
        Ok(())
    }

    /// `cim_r`: read a 32-bit word from the port address space.
    pub fn port_read(&mut self, addr: u32) -> Result<u32> {
        self.stats.weight_reads += 1;
        Ok(match weight_map::decode_port(addr) {
            Some(PortWord::Sign(i)) => self.sign[i as usize],
            Some(PortWord::Mask(i)) => self.mask[i as usize],
            Some(PortWord::Threshold(i)) => self.th[i as usize] as u32,
            Some(PortWord::RawSum(i)) => self.raw[i as usize] as u32,
            None => bail!("cim_r from unmapped port word {addr:#x}"),
        })
    }

    /// Shift one word into the input buffer (the `sh` bit of `cim_conv`).
    #[inline]
    pub fn shift_in(&mut self, word: u32) {
        self.input.shift_in(word);
        self.stats.shifts += 1;
    }

    /// Fire the full-array MAC and latch all SA outputs (the `wd == 0`
    /// event of `cim_conv`). Single cycle in the timing model.
    ///
    /// The active layer's rectangle is `[row_base*32, +window_words*32) x
    /// [col_base*32, sense_amps)`: wordlines outside the window see zero
    /// input (they hold *other resident layers'* weights — the packing of
    /// DESIGN.md §4), so only the window rows contribute; every SA still
    /// physically fires (energy counts the full array).
    pub fn fire(&mut self) {
        let mode = self.cfg.mode;
        let cw = mode.col_words();
        let row_base = (self.cfg.row_base as usize).min(cw - 1);
        let n = (self.cfg.window_words as usize).min(cw - row_base);
        let sas = mode.sense_amps();
        self.stats.fires += 1;
        self.stats.macs += mode.macs_per_fire();

        // Gather the window once (hot path: reused across all columns).
        let mut x = [0u32; 32];
        for (j, xj) in x.iter_mut().enumerate().take(n) {
            *xj = self.input.window_word(j, n);
        }

        let mut latch = [0u32; 16];
        for c in 0..sas {
            let base = c * cw + row_base;
            let mut pos = 0u32;
            let mut act = 0u32;
            for j in 0..n {
                let m = self.mask[base + j];
                let xm = x[j] & m;
                act += xm.count_ones();
                pos += (xm & self.sign[base + j]).count_ones();
            }
            // sum over active rows of (+1 for sign=1, -1 for sign=0)
            let mut sum = (2 * pos) as i32 - act as i32;
            if let Some(v) = self.variation.as_mut() {
                // Noise scales with the column's active cell count.
                let col_active: u32 = (0..n).map(|j| self.mask[base + j].count_ones()).sum();
                sum = v.disturb(sum, col_active);
            }
            self.raw[c] = sum;
            if sum > self.th[c] {
                latch[c / 32] |= 1 << (c % 32);
            }
        }
        // Max-pool pipeline (Fig. 7): the previous fire's latch rolls into
        // the pool register, so stores issued after this fire read
        // `latch | pool_reg` = the binary max of the row pair.
        self.pool_reg = self.latch;
        self.latch = latch;
    }

    /// Read output latch word `wd` as stored by `cim_conv`, applying the
    /// max-pool pipeline (OR with the rolling pool register) when enabled.
    /// Returns the word to store to FM SRAM.
    pub fn store_word(&mut self, wd: u8) -> u32 {
        self.stats.out_words += 1;
        let idx = self.word_index(wd);
        let cur = self.latch[idx];
        if self.cfg.pool_or {
            cur | self.pool_reg[idx]
        } else {
            cur
        }
    }

    /// Clear the pool register (layer start).
    pub fn clear_pool(&mut self) {
        self.pool_reg = [0; 16];
    }

    fn word_index(&self, wd: u8) -> usize {
        // Layer rectangle: wd selects within the layer's column block.
        let max = match self.cfg.mode {
            Mode::X => 7,
            Mode::Y => 15,
        };
        ((self.cfg.col_base as usize) + (wd & 0x7) as usize).min(max)
    }

    /// Direct latch access (tests/debug).
    pub fn latch_word(&self, idx: usize) -> u32 {
        self.latch[idx]
    }

    /// Raw sum of SA `c` from the last fire (tests + final-layer readout).
    pub fn raw_sum(&self, c: usize) -> i32 {
        self.raw[c]
    }

    /// Host-side bulk load of a weight image (bypasses cycle accounting;
    /// the *timed* path is the `cim_w` burst the compiler emits).
    pub fn load_image(&mut self, img: &weight_map::WeightImage) -> Result<()> {
        for &(a, v) in &img.words {
            match weight_map::decode_port(a) {
                Some(PortWord::Sign(i)) => self.sign[i as usize] = v,
                Some(PortWord::Mask(i)) => self.mask[i as usize] = v,
                Some(PortWord::Threshold(i)) => self.th[i as usize] = v as i32,
                _ => bail!("bad image word {a:#x}"),
            }
        }
        Ok(())
    }

    /// Host-side bulk load of a packed binary layer at
    /// (`row_base`,`col_base`) x32-blocks: the sign planes are already in
    /// the port's column-major word layout, so the image is built by word
    /// copy (`WeightImage::from_packed_at`), not a per-bit walk. Bypasses
    /// cycle accounting like `load_image`; the *timed* path is the
    /// `cim_w` burst the compiler emits.
    pub fn load_packed(
        &mut self,
        layer: &crate::model::reference::PackedLayer,
        row_base: usize,
        col_base: usize,
    ) -> Result<()> {
        let mode = self.cfg.mode;
        if row_base * 32 + layer.rows() > mode.wordlines() {
            bail!("packed layer rows overflow {mode:?}");
        }
        if col_base * 32 + layer.c_out > mode.sense_amps() {
            bail!("packed layer cols overflow {mode:?}");
        }
        self.load_image(&weight_map::WeightImage::from_packed_at(mode, row_base, col_base, layer))
    }

    pub fn reset_stats(&mut self) {
        self.stats = CimStats::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Reference MAC in the obvious O(rows*cols) form.
    fn ref_mac(x: &[u8], w: &[Vec<i8>], th: &[i32]) -> (Vec<i32>, Vec<bool>) {
        let cols = w[0].len();
        let mut sums = vec![0i32; cols];
        for (r, &xr) in x.iter().enumerate() {
            if xr == 1 {
                for c in 0..cols {
                    sums[c] += w[r][c] as i32;
                }
            }
        }
        let bits = sums.iter().zip(th).map(|(s, t)| s > t).collect();
        (sums, bits)
    }

    fn setup_random(mode: Mode, rows: usize, cols: usize, seed: u64) -> (CimMacro, Vec<u8>, Vec<Vec<i8>>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let w: Vec<Vec<i8>> = (0..rows)
            .map(|_| (0..cols).map(|_| if rng.bool(0.1) { 0 } else { rng.pm1() }).collect())
            .collect();
        let th: Vec<i32> = (0..cols).map(|_| rng.range(0, 7) as i32 - 3).collect();
        let x: Vec<u8> = (0..rows).map(|_| rng.bool(0.5) as u8).collect();

        let mut m = CimMacro::new();
        m.cfg.mode = mode;
        m.cfg.window_words = rows.div_ceil(32) as u8;
        let img = weight_map::WeightImage::from_layer(mode, rows, cols, |r, c| w[r][c], &th);
        m.load_image(&img).unwrap();
        // Shift the input in, 32 bits at a time, LSB-first within words.
        let nwords = rows.div_ceil(32);
        for j in 0..nwords {
            let mut word = 0u32;
            for b in 0..32 {
                if j * 32 + b < rows && x[j * 32 + b] == 1 {
                    word |= 1 << b;
                }
            }
            m.shift_in(word);
        }
        (m, x, w, th)
    }

    #[test]
    fn mac_matches_reference_xmode() {
        for seed in 0..5 {
            let (mut m, x, w, th) = setup_random(Mode::X, 192, 64, seed);
            m.fire();
            let (sums, bits) = ref_mac(&x, &w, &th);
            for c in 0..64 {
                assert_eq!(m.raw_sum(c), sums[c], "sum col {c} seed {seed}");
                assert_eq!(
                    m.latch_word(c / 32) >> (c % 32) & 1 == 1,
                    bits[c],
                    "bit col {c} seed {seed}"
                );
            }
        }
    }

    #[test]
    fn mac_matches_reference_ymode_full() {
        let (mut m, x, w, th) = setup_random(Mode::Y, 512, 512, 9);
        m.fire();
        let (sums, _) = ref_mac(&x, &w, &th);
        for c in [0, 31, 255, 256, 511] {
            assert_eq!(m.raw_sum(c), sums[c], "col {c}");
        }
    }

    #[test]
    fn threshold_strictly_greater() {
        let mut m = CimMacro::new();
        m.cfg.window_words = 1;
        // One active row, weight +1, threshold 1: sum == 1, 1 > 1 false.
        let img = weight_map::WeightImage::from_layer(Mode::X, 1, 1, |_, _| 1, &[1]);
        m.load_image(&img).unwrap();
        m.shift_in(1);
        m.fire();
        assert_eq!(m.raw_sum(0), 1);
        assert_eq!(m.latch_word(0) & 1, 0);
        // Threshold 0: 1 > 0 true.
        m.port_write(weight_map::TH_BASE, 0).unwrap();
        m.fire();
        assert_eq!(m.latch_word(0) & 1, 1);
    }

    #[test]
    fn pool_or_is_pairwise_max() {
        let mut m = CimMacro::new();
        m.cfg.window_words = 1;
        m.cfg.pool_or = true;
        let img = weight_map::WeightImage::from_layer(Mode::X, 32, 32, |r, c| if r == c { 1 } else { -1 }, &vec![0; 32]);
        m.load_image(&img).unwrap();
        // Fire 1: input = bit0 only -> col 0 sum = +1 (>0), others -1.
        m.shift_in(1);
        m.fire();
        let w1 = m.latch_word(0);
        // Fire 2: input = bit1 only -> col 1 hot.
        m.shift_in(2);
        m.fire();
        let pooled = m.store_word(0);
        assert_eq!(w1, 0b01);
        assert_eq!(pooled, 0b11, "OR of the two fires");
    }

    #[test]
    fn port_rw_roundtrip_and_raw_readonly() {
        let mut m = CimMacro::new();
        m.port_write(0, 0xAAAA_5555).unwrap();
        assert_eq!(m.port_read(0).unwrap(), 0xAAAA_5555);
        m.port_write(weight_map::MASK_BASE + 5, 7).unwrap();
        assert_eq!(m.port_read(weight_map::MASK_BASE + 5).unwrap(), 7);
        m.port_write(weight_map::TH_BASE + 2, (-3i32) as u32).unwrap();
        assert_eq!(m.port_read(weight_map::TH_BASE + 2).unwrap() as i32, -3);
        assert!(m.port_write(weight_map::RAW_BASE, 0).is_err());
        assert!(m.port_write(0x10_0000, 0).is_err());
    }

    #[test]
    fn resident_rectangles_coexist() {
        // Two layers packed in disjoint rectangles: firing one layer's
        // window must not see the other's weights (the DESIGN.md §4
        // packing that makes Table II's weight-update flow possible).
        let mut m = CimMacro::new();
        // Layer A: rows [0,32), cols [0,32), all +1, th 0.
        let a = weight_map::WeightImage::from_layer_at(Mode::X, 0, 0, 32, 32, |_, _| 1, &vec![0; 32]);
        // Layer B: rows [32,64) (row_base 1), cols [0,32), all -1, th 0.
        let b = weight_map::WeightImage::from_layer_at(Mode::X, 1, 0, 32, 32, |_, _| -1, &vec![0; 32]);
        m.load_image(&a).unwrap();
        m.load_image(&b).unwrap();

        // Fire layer A: window 1 word at row_base 0, input all ones.
        m.cfg.window_words = 1;
        m.cfg.row_base = 0;
        m.shift_in(0xFFFF_FFFF);
        m.fire();
        assert_eq!(m.raw_sum(0), 32, "layer A sums +32");

        // Fire layer B: same input, row_base 1.
        m.cfg.row_base = 1;
        m.shift_in(0xFFFF_FFFF);
        m.fire();
        assert_eq!(m.raw_sum(0), -32, "layer B sums -32");
        // Layer A's weights are untouched.
        m.cfg.row_base = 0;
        m.shift_in(0xFFFF_FFFF);
        m.fire();
        assert_eq!(m.raw_sum(0), 32);
    }

    #[test]
    fn col_base_selects_latch_word() {
        // A layer at col block 1 (cols 32..64): wd=0 must store latch word 1.
        let mut m = CimMacro::new();
        let img =
            weight_map::WeightImage::from_layer_at(Mode::X, 0, 1, 32, 32, |_, _| 1, &vec![0; 32]);
        m.load_image(&img).unwrap();
        m.cfg.window_words = 1;
        m.cfg.col_base = 1;
        m.shift_in(0xFFFF_FFFF);
        m.fire();
        assert_eq!(m.store_word(0), 0xFFFF_FFFF, "cols 32..64 all hot");
        assert_eq!(m.latch_word(0), 0, "cols 0..32 dark (no weights)");
    }

    #[test]
    fn load_packed_fires_identically_to_image_load() {
        use crate::model::kws::LayerSpec;
        use crate::model::reference::PackedLayer;
        let mut rng = Rng::new(77);
        let (c_in, c_out) = (24, 40); // rows = 72: non-word-aligned tail
        let spec = LayerSpec {
            c_in,
            c_out,
            kernel: 3,
            pooled: false,
            binarized: true,
            weights: (0..3 * c_in * c_out).map(|_| rng.pm1()).collect(),
            thresholds: (0..c_out).map(|_| rng.range(0, 9) as i32 - 4).collect(),
        };
        let rows = spec.rows();
        let x: Vec<u8> = (0..rows).map(|_| rng.bool(0.5) as u8).collect();
        let shift = |m: &mut CimMacro| {
            for j in 0..rows.div_ceil(32) {
                let mut word = 0u32;
                for b in 0..32 {
                    if j * 32 + b < rows && x[j * 32 + b] == 1 {
                        word |= 1 << b;
                    }
                }
                m.shift_in(word);
            }
        };

        let mut via_image = CimMacro::new();
        via_image.cfg.window_words = rows.div_ceil(32) as u8;
        let img = weight_map::WeightImage::from_layer(
            Mode::X,
            rows,
            c_out,
            |r, c| spec.weight(r, c),
            &spec.thresholds,
        );
        via_image.load_image(&img).unwrap();
        shift(&mut via_image);
        via_image.fire();

        let mut via_packed = CimMacro::new();
        via_packed.cfg.window_words = rows.div_ceil(32) as u8;
        via_packed.load_packed(&PackedLayer::from_spec(&spec), 0, 0).unwrap();
        shift(&mut via_packed);
        via_packed.fire();

        for c in 0..c_out {
            assert_eq!(via_packed.raw_sum(c), via_image.raw_sum(c), "col {c}");
        }
        assert_eq!(via_packed.latch_word(0), via_image.latch_word(0));
        assert_eq!(via_packed.latch_word(1), via_image.latch_word(1));

        // Overflow guards reject out-of-array placements.
        assert!(via_packed.load_packed(&PackedLayer::from_spec(&spec), 31, 0).is_err());
        assert!(via_packed.load_packed(&PackedLayer::from_spec(&spec), 0, 8).is_err());
    }

    #[test]
    fn default_is_a_fresh_macro() {
        // `CimMacro::default()` (used by container types and the macro
        // bank) must equal `new()`: zeroed planes/stats, X-mode config.
        let mut d = CimMacro::default();
        assert_eq!(d.stats.fires, 0);
        assert_eq!(d.port_read(weight_map::SIGN_BASE).unwrap(), 0);
        assert_eq!(d.port_read(weight_map::MASK_BASE).unwrap(), 0);
        assert!(matches!(d.cfg.mode, Mode::X));
        assert_eq!(d.cfg.window_words, 32);
    }

    #[test]
    fn stats_accumulate() {
        let mut m = CimMacro::new();
        m.cfg.window_words = 1;
        m.shift_in(0);
        m.fire();
        m.store_word(0);
        assert_eq!(m.stats.shifts, 1);
        assert_eq!(m.stats.fires, 1);
        assert_eq!(m.stats.out_words, 1);
        assert_eq!(m.stats.macs, Mode::X.macs_per_fire());
    }
}
