//! Macro operating modes (paper §II-B) and per-layer configuration.

/// Array reconfiguration: the same 512 Kb cell array sensed two ways.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// High-input mode: 1024 wordlines × 512 bitlines, 256 sense amps
    /// (two bitlines per SA — the symmetric/differential pair).
    X,
    /// High-output mode: 512 wordlines × 1024 bitlines, 512 sense amps.
    Y,
}

impl Mode {
    /// Wordlines (MAC fan-in) in this mode.
    pub fn wordlines(self) -> usize {
        match self {
            Mode::X => 1024,
            Mode::Y => 512,
        }
    }

    /// Sense amplifiers (parallel outputs) in this mode.
    pub fn sense_amps(self) -> usize {
        match self {
            Mode::X => 256,
            Mode::Y => 512,
        }
    }

    /// 32-bit words per SA column in the weight port address space.
    pub fn col_words(self) -> usize {
        self.wordlines() / 32
    }

    /// MACs per fire (for TOPS accounting): every wordline × every SA.
    pub fn macs_per_fire(self) -> u64 {
        (self.wordlines() * self.sense_amps()) as u64
    }
}

/// Live configuration of the CIM unit (MMIO `CIM_CFG` register).
///
/// `row_base`/`col_base` (units of 32 wordlines / 32 SA columns) select
/// the rectangle of the array the current layer occupies: several layers'
/// weights stay resident simultaneously (DESIGN.md §4 packing), which is
/// what lets the KWS flow keep layers 0-4 in the macro across inferences
/// and only "weight update" layers 5-6 (paper Table II).
///
/// Register layout:
/// ```text
///   bit 0       mode (0 = X, 1 = Y)
///   bit 1       pool_or (conv/max-pool pipeline, Fig. 7)
///   bits 7:2    window_words (1..=32; 0 decodes as 32)
///   bits 12:8   row_base (x32 wordlines)
///   bits 16:13  col_base (x32 SA columns)
/// ```
#[derive(Debug, Clone, Copy)]
pub struct CimConfig {
    pub mode: Mode,
    /// Max-pool pipeline: stores emit `latch | pool_reg` (binary max).
    pub pool_or: bool,
    /// Input window length in 32-bit words (1..=32): how many of the most
    /// recently shifted words the layer's wordlines see.
    pub window_words: u8,
    /// First wordline block (x32) of the layer's rectangle.
    pub row_base: u8,
    /// First SA column block (x32) of the layer's rectangle.
    pub col_base: u8,
}

impl Default for CimConfig {
    fn default() -> Self {
        CimConfig { mode: Mode::X, pool_or: false, window_words: 32, row_base: 0, col_base: 0 }
    }
}

impl CimConfig {
    /// Decode from the MMIO register value (see `mem::layout`).
    pub fn from_bits(v: u32) -> Self {
        let w = ((v >> 2) & 0x3F) as u8;
        CimConfig {
            mode: if v & 1 != 0 { Mode::Y } else { Mode::X },
            pool_or: v & 2 != 0,
            window_words: if w == 0 { 32 } else { w.min(32) },
            row_base: ((v >> 8) & 0x1F) as u8,
            col_base: ((v >> 13) & 0x0F) as u8,
        }
    }

    /// Encode to the MMIO register value.
    pub fn to_bits(self) -> u32 {
        (matches!(self.mode, Mode::Y) as u32)
            | ((self.pool_or as u32) << 1)
            | (((self.window_words as u32) & 0x3F) << 2)
            | (((self.row_base as u32) & 0x1F) << 8)
            | (((self.col_base as u32) & 0x0F) << 13)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_matches_paper() {
        assert_eq!(Mode::X.wordlines(), 1024);
        assert_eq!(Mode::X.sense_amps(), 256);
        assert_eq!(Mode::Y.wordlines(), 512);
        assert_eq!(Mode::Y.sense_amps(), 512);
        // Total cells identical: the same 512 Kb array.
        assert_eq!(
            Mode::X.wordlines() * Mode::X.sense_amps(),
            Mode::Y.wordlines() * Mode::Y.sense_amps()
        );
    }

    #[test]
    fn tops_at_50mhz_matches_table1() {
        // X-mode, 2 ops per MAC, 50 MHz -> 26.21 TOPS (Table I).
        let tops = Mode::X.macs_per_fire() as f64 * 2.0 * crate::clock::CLOCK_HZ / 1e12;
        assert!((tops - 26.2144).abs() < 1e-3, "{tops}");
    }

    #[test]
    fn config_roundtrip() {
        for mode in [Mode::X, Mode::Y] {
            for pool_or in [false, true] {
                for window_words in [1u8, 6, 16, 32] {
                    for row_base in [0u8, 6, 18, 31] {
                        for col_base in [0u8, 2, 7, 15] {
                            let c = CimConfig { mode, pool_or, window_words, row_base, col_base };
                            let c2 = CimConfig::from_bits(c.to_bits());
                            assert_eq!(c2.mode, c.mode);
                            assert_eq!(c2.pool_or, c.pool_or);
                            assert_eq!(c2.window_words, c.window_words);
                            assert_eq!(c2.row_base, c.row_base);
                            assert_eq!(c2.col_base, c.col_base);
                        }
                    }
                }
            }
        }
    }
}
