//! The CIM input buffer: a 1024-bit register filled 32 bits at a time
//! ("the CIM input buffer is designed with a 32-bit shift" — paper §II-A).
//!
//! The row-wise dataflow leans on this: advancing the convolution window
//! by one row only shifts in `c_in/32` new words while the overlapping
//! `(k-1)*c_in` bits stay in place — that is the layer-fusion overlap
//! storage of Fig. 6.

/// 1024-bit shift register, 32 words, shifted one word at a time.
/// `word(j)` indexes the *window*: j = 0 is the oldest word of the last
/// `n` shifted, j = n-1 the newest (see `CimConfig::window_words`).
#[derive(Debug, Clone)]
pub struct InputBuffer {
    words: [u32; 32],
    /// Circular head: index of the slot holding the *newest* word.
    head: usize,
    /// Total shifts (energy accounting).
    pub shifts: u64,
}

impl Default for InputBuffer {
    fn default() -> Self {
        Self::new()
    }
}

impl InputBuffer {
    pub fn new() -> Self {
        InputBuffer { words: [0; 32], head: 31, shifts: 0 }
    }

    /// Shift one 32-bit word in (drops the word that entered 32 shifts ago).
    #[inline]
    pub fn shift_in(&mut self, word: u32) {
        self.head = (self.head + 1) & 31;
        self.words[self.head] = word;
        self.shifts += 1;
    }

    /// Word `j` of an `n`-word window ending at the newest word:
    /// j = 0 -> the word shifted `n-1` shifts ago, j = n-1 -> the newest.
    #[inline]
    pub fn window_word(&self, j: usize, n: usize) -> u32 {
        debug_assert!(j < n && n <= 32);
        self.words[(self.head + 33 - n + j) & 31]
    }

    /// The wordline bit `r` seen by the array for an `n`-word window.
    pub fn wordline(&self, r: usize, n: usize) -> bool {
        (self.window_word(r / 32, n) >> (r % 32)) & 1 == 1
    }

    /// Clear (layer transitions in the baseline path).
    pub fn clear(&mut self) {
        self.words = [0; 32];
        self.head = 31;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_ordering() {
        let mut b = InputBuffer::new();
        for i in 0..6u32 {
            b.shift_in(i);
        }
        // 3-word window: oldest of the window is word 3.
        assert_eq!(b.window_word(0, 3), 3);
        assert_eq!(b.window_word(1, 3), 4);
        assert_eq!(b.window_word(2, 3), 5);
        // 6-word window.
        assert_eq!(b.window_word(0, 6), 0);
        assert_eq!(b.window_word(5, 6), 5);
    }

    #[test]
    fn rolls_over_32() {
        let mut b = InputBuffer::new();
        for i in 0..40u32 {
            b.shift_in(i);
        }
        assert_eq!(b.window_word(31, 32), 39);
        assert_eq!(b.window_word(0, 32), 8); // words 0..7 dropped
        assert_eq!(b.shifts, 40);
    }

    #[test]
    fn wordline_bits() {
        let mut b = InputBuffer::new();
        b.shift_in(0b1010);
        b.shift_in(0x8000_0001);
        // window n=2: word0 = 0b1010, word1 = 0x80000001
        assert!(b.wordline(1, 2));
        assert!(!b.wordline(0, 2));
        assert!(b.wordline(3, 2));
        assert!(b.wordline(32, 2));
        assert!(b.wordline(63, 2));
        assert!(!b.wordline(62, 2));
    }

    #[test]
    fn overlap_survives_row_advance() {
        // Row-wise reuse: after shifting rows A,B,C then advancing by one
        // row (shift D), the window must read B,C,D — B and C reused.
        let mut b = InputBuffer::new();
        for w in [0xA, 0xB, 0xC] {
            b.shift_in(w);
        }
        b.shift_in(0xD);
        assert_eq!(
            (0..3).map(|j| b.window_word(j, 3)).collect::<Vec<_>>(),
            vec![0xB, 0xC, 0xD]
        );
    }
}
