//! Weight placement in the macro's word-addressed port, and the symmetry
//! (sign + mask) mapping.
//!
//! The `cim_w`/`cim_r` instructions move 32-bit words through a flat word
//! address space:
//!
//! ```text
//!   [0      .. 8192)   sign plane   (bit = 1 -> +1, bit = 0 -> -1)
//!   [8192   .. 16384)  mask plane   (bit = 1 -> cell active; 0 -> ternary 0)
//!   [16384  .. 16896)  SA thresholds (one i32 word per SA, 512 max)
//!   [16896  .. 17408)  raw MAC sums of the last fire (read-only)
//! ```
//!
//! The **symmetry weight mapping** of §II-B stores each logical weight as a
//! differential cell pair on the two bitlines of an SA; at this level of
//! abstraction that means: a weight is (sign, active) — exactly the two
//! planes — and first-order cell nonlinearity cancels in the differential
//! read (see `variation.rs` for what happens when it doesn't).
//!
//! Column-major layout: SA column `c` owns words `[c*col_words, (c+1)*col_words)`
//! of each plane, `col_words` = 32 (X-mode) or 16 (Y-mode) — so one column
//! is a contiguous run and a layer load is a linear `cim_w` burst.

use super::mode::Mode;
use crate::model::reference::PackedLayer;

/// Word counts of the port address space.
pub const SIGN_BASE: u32 = 0;
pub const SIGN_WORDS: u32 = 8192; // 256 Kb of logical weights
pub const MASK_BASE: u32 = 8192;
pub const MASK_WORDS: u32 = 8192;
pub const TH_BASE: u32 = 16384;
pub const TH_WORDS: u32 = 512;
pub const RAW_BASE: u32 = 16896;
pub const RAW_WORDS: u32 = 512;
pub const PORT_WORDS: u32 = RAW_BASE + RAW_WORDS;

/// What a port word address refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PortWord {
    Sign(u32),
    Mask(u32),
    Threshold(u32),
    RawSum(u32),
}

/// Decode a port word address.
pub fn decode_port(addr: u32) -> Option<PortWord> {
    match addr {
        _ if addr < MASK_BASE => Some(PortWord::Sign(addr)),
        _ if addr < TH_BASE => Some(PortWord::Mask(addr - MASK_BASE)),
        _ if addr < TH_BASE + TH_WORDS => Some(PortWord::Threshold(addr - TH_BASE)),
        _ if (RAW_BASE..RAW_BASE + RAW_WORDS).contains(&addr) => {
            Some(PortWord::RawSum(addr - RAW_BASE))
        }
        _ => None,
    }
}

/// Word index (within a plane) of wordline `r`, column `c`.
pub fn plane_word(mode: Mode, c: usize, r: usize) -> u32 {
    debug_assert!(c < mode.sense_amps() && r < mode.wordlines());
    (c * mode.col_words() + r / 32) as u32
}

/// A layer's weights laid out as port-write words: the "full stack flow"
/// compiler builds this image, stages it in DRAM, and emits the uDMA +
/// `cim_w` burst that loads it.
#[derive(Debug, Clone)]
pub struct WeightImage {
    pub mode: Mode,
    /// (port word address, value) pairs in burst order.
    pub words: Vec<(u32, u32)>,
}

impl WeightImage {
    /// Map a conv layer's weights (tap-major/channel-minor rows — the
    /// im2col order shared with `python/compile/kernels/ref.py`) onto a
    /// rectangle of the macro: `weights[r][c]` in {-1,0,+1} for rows
    /// `0..rows`, columns `0..cols`, placed at (`row_base`,`col_base`)
    /// x32-blocks. Only the rectangle's own words are emitted — other
    /// resident layers' rectangles are untouched (DESIGN.md §4 packing);
    /// rows inside the window beyond `rows` are masked off.
    /// `thresholds[c]` are the SA reference levels (absolute column =
    /// `col_base*32 + c`).
    pub fn from_layer_at(
        mode: Mode,
        row_base: usize,
        col_base: usize,
        rows: usize,
        cols: usize,
        weight: impl Fn(usize, usize) -> i8,
        thresholds: &[i32],
    ) -> Self {
        let cw = mode.col_words();
        let active_words = rows.div_ceil(32);
        assert!(row_base * 32 + rows <= mode.wordlines(), "rows overflow {mode:?}");
        assert!(col_base * 32 + cols <= mode.sense_amps(), "cols overflow {mode:?}");
        let mut words = Vec::new();
        for c in 0..cols {
            let c_abs = col_base * 32 + c;
            for wj in 0..active_words {
                let mut sign = 0u32;
                let mut mask = 0u32;
                for b in 0..32 {
                    let r = wj * 32 + b;
                    if r < rows {
                        match weight(r, c) {
                            0 => {} // ternary zero: cell masked off
                            x if x > 0 => {
                                mask |= 1 << b;
                                sign |= 1 << b;
                            }
                            _ => mask |= 1 << b,
                        }
                    }
                }
                words.push((SIGN_BASE + (c_abs * cw + row_base + wj) as u32, sign));
                words.push((MASK_BASE + (c_abs * cw + row_base + wj) as u32, mask));
            }
        }
        for (c, &th) in thresholds.iter().enumerate().take(cols) {
            words.push((TH_BASE + (col_base * 32 + c) as u32, th as u32));
        }
        WeightImage { mode, words }
    }

    /// Map a packed binary layer onto a rectangle of the macro. A
    /// [`PackedLayer`]'s sign planes are column-major u64 window words
    /// whose little-endian u32 halves ARE the port's word layout
    /// ([`PackedLayer::stream_word`]), so each stream word is emitted
    /// verbatim — no per-bit walk; the mask plane arms every in-window
    /// row (binary weights, no ternary zeros) with the tail beyond
    /// `rows()` off. Produces word-for-word the image `from_layer_at`
    /// builds from the same layer's scalar form.
    pub fn from_packed_at(mode: Mode, row_base: usize, col_base: usize, layer: &PackedLayer) -> Self {
        let cw = mode.col_words();
        let rows = layer.rows();
        let aw = layer.stream_words();
        assert!(row_base * 32 + rows <= mode.wordlines(), "rows overflow {mode:?}");
        assert!(col_base * 32 + layer.c_out <= mode.sense_amps(), "cols overflow {mode:?}");
        let mut words = Vec::with_capacity(layer.c_out * aw * 2 + layer.thresholds.len());
        for co in 0..layer.c_out {
            let c_abs = col_base * 32 + co;
            for wj in 0..aw {
                let sign = layer.stream_word(co, wj);
                let r0 = wj * 32;
                let mask =
                    if rows - r0 >= 32 { u32::MAX } else { (1u32 << (rows - r0)) - 1 };
                words.push((SIGN_BASE + (c_abs * cw + row_base + wj) as u32, sign & mask));
                words.push((MASK_BASE + (c_abs * cw + row_base + wj) as u32, mask));
            }
        }
        for (c, &th) in layer.thresholds.iter().enumerate() {
            words.push((TH_BASE + (col_base * 32 + c) as u32, th as u32));
        }
        WeightImage { mode, words }
    }

    /// `from_packed_at` anchored at the array origin.
    pub fn from_packed(mode: Mode, layer: &PackedLayer) -> Self {
        Self::from_packed_at(mode, 0, 0, layer)
    }

    /// `from_layer_at` anchored at the array origin.
    pub fn from_layer(
        mode: Mode,
        rows: usize,
        cols: usize,
        weight: impl Fn(usize, usize) -> i8,
        thresholds: &[i32],
    ) -> Self {
        Self::from_layer_at(mode, 0, 0, rows, cols, weight, thresholds)
    }

    /// Number of `cim_w` instructions (= cycles) to load this image.
    pub fn burst_len(&self) -> usize {
        self.words.len()
    }

    /// Serialize to a flat little-endian byte image: `[addr, value]` pairs
    /// are flattened into (addr-ordered) contiguous value words for DRAM
    /// staging; returns (base-sorted words, bytes).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.words.len() * 8);
        for &(a, v) in &self.words {
            out.extend_from_slice(&a.to_le_bytes());
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_decode_ranges() {
        assert_eq!(decode_port(0), Some(PortWord::Sign(0)));
        assert_eq!(decode_port(8191), Some(PortWord::Sign(8191)));
        assert_eq!(decode_port(8192), Some(PortWord::Mask(0)));
        assert_eq!(decode_port(16384), Some(PortWord::Threshold(0)));
        assert_eq!(decode_port(16896), Some(PortWord::RawSum(0)));
        assert_eq!(decode_port(17408), None);
        assert_eq!(decode_port(16895), Some(PortWord::Threshold(511)));
    }

    #[test]
    fn column_major_contiguous() {
        assert_eq!(plane_word(Mode::X, 0, 0), 0);
        assert_eq!(plane_word(Mode::X, 0, 1023), 31);
        assert_eq!(plane_word(Mode::X, 1, 0), 32);
        assert_eq!(plane_word(Mode::Y, 1, 0), 16);
    }

    #[test]
    fn image_masks_inactive_rows_within_window() {
        // 40 rows, 2 cols, all +1.
        let img = WeightImage::from_layer(Mode::X, 40, 2, |_, _| 1, &[0, 0]);
        // Column 0 sign word 0 = all ones; word 1 = low 8 bits only (mask).
        let get = |addr: u32| img.words.iter().find(|(a, _)| *a == addr).map(|(_, v)| *v);
        assert_eq!(get(SIGN_BASE), Some(0xFFFF_FFFF));
        assert_eq!(get(MASK_BASE), Some(0xFFFF_FFFF));
        assert_eq!(get(MASK_BASE + 1), Some(0x0000_00FF));
        // Words outside the rectangle are NOT touched (other layers own them).
        assert_eq!(get(MASK_BASE + 2), None);
        assert_eq!(get(MASK_BASE + 2 * 32), None);
    }

    #[test]
    fn placement_offsets_addresses() {
        // Rectangle at row block 6, col block 2: column 64, word 6.
        let img = WeightImage::from_layer_at(Mode::X, 6, 2, 32, 1, |_, _| 1, &[5]);
        let addrs: Vec<u32> = img.words.iter().map(|(a, _)| *a).collect();
        assert!(addrs.contains(&(SIGN_BASE + 64 * 32 + 6)));
        assert!(addrs.contains(&(MASK_BASE + 64 * 32 + 6)));
        assert!(addrs.contains(&(TH_BASE + 64)));
        assert_eq!(img.words.len(), 3);
    }

    #[test]
    fn packed_image_equals_scalar_image() {
        // A ±1 layer must produce word-for-word the same burst whether it
        // is mapped from the scalar weights or from the packed planes —
        // the layouts coincide, which is the whole point of PackedLayer.
        use crate::model::kws::LayerSpec;
        use crate::model::reference::PackedLayer;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(31);
        for (c_in, c_out, row_base, col_base) in [(32, 20, 0, 0), (24, 33, 2, 1)] {
            let spec = LayerSpec {
                c_in,
                c_out,
                kernel: 3,
                pooled: false,
                binarized: true,
                weights: (0..3 * c_in * c_out).map(|_| rng.pm1()).collect(),
                thresholds: (0..c_out).map(|_| rng.range(0, 9) as i32 - 4).collect(),
            };
            let packed = PackedLayer::from_spec(&spec);
            let scalar_img = WeightImage::from_layer_at(
                Mode::X,
                row_base,
                col_base,
                spec.rows(),
                c_out,
                |r, c| spec.weight(r, c),
                &spec.thresholds,
            );
            let packed_img = WeightImage::from_packed_at(Mode::X, row_base, col_base, &packed);
            assert_eq!(packed_img.words, scalar_img.words, "c_in {c_in} c_out {c_out}");
        }
    }

    #[test]
    fn negative_weights_clear_sign_bits() {
        let img =
            WeightImage::from_layer(Mode::X, 32, 1, |r, _| if r % 2 == 0 { 1 } else { -1 }, &[3]);
        let get = |addr: u32| img.words.iter().find(|(a, _)| *a == addr).map(|(_, v)| *v);
        assert_eq!(get(SIGN_BASE), Some(0x5555_5555));
        assert_eq!(get(TH_BASE), Some(3));
    }
}
