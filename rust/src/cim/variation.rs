//! Sense-amp nonlinearity and cell-variation injection (paper §II-B: "we
//! apply the symmetry weight mapping method to mitigate nonlinearity (NL)
//! and cell variation in binary or ternary weights").
//!
//! Model: an analog MAC sum accumulated on a long bitline suffers
//!   (a) per-cell conductance variation — zero-mean noise whose variance
//!       grows with the number of active cells: sigma_eff = sigma*sqrt(n);
//!   (b) bitline nonlinearity — a compressive term ~ alpha * s * |s| / n
//!       that biases large sums toward the rail.
//!
//! With **symmetric (differential) mapping**, both bitlines of an SA see
//! the same number of active cells, so the NL term cancels to first order
//! and only the residual mismatch (fraction `mismatch`) survives. With
//! single-ended mapping both terms apply in full. The ablation bench
//! (`table1_comparison --variation`) sweeps sigma and shows the accuracy
//! cliff the paper's mapping avoids.

use crate::util::rng::Rng;

/// Variation/nonlinearity injection parameters.
#[derive(Debug, Clone)]
pub struct VariationModel {
    /// Per-cell conductance sigma (in units of one cell's contribution).
    pub sigma: f64,
    /// Bitline nonlinearity coefficient.
    pub nl_alpha: f64,
    /// Symmetric (differential) weight mapping enabled?
    pub symmetric: bool,
    /// Residual differential mismatch when symmetric (0..1).
    pub mismatch: f64,
    /// RNG for the noise draws (seeded per run for reproducibility).
    pub rng: Rng,
}

impl VariationModel {
    pub fn new(sigma: f64, nl_alpha: f64, symmetric: bool, seed: u64) -> Self {
        VariationModel { sigma, nl_alpha, symmetric, mismatch: 0.05, rng: Rng::new(seed) }
    }

    /// Disturb one SA's ideal integer MAC sum. `active` is the number of
    /// unmasked cells on the column (noise scale), `sum` the ideal result.
    pub fn disturb(&mut self, sum: i32, active: u32) -> i32 {
        if active == 0 {
            return sum;
        }
        let n = active as f64;
        let noise_scale = if self.symmetric { self.mismatch } else { 1.0 };
        let noise = self.rng.normal() * self.sigma * n.sqrt() * noise_scale;
        let nl = if self.symmetric {
            // Differential read: compressive term cancels to first order.
            0.0
        } else {
            -self.nl_alpha * (sum as f64) * (sum as f64).abs() / n
        };
        let disturbed = sum as f64 + noise + nl;
        disturbed.round() as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sigma_is_identity_when_symmetric() {
        let mut v = VariationModel::new(0.0, 0.1, true, 1);
        for s in [-100, -1, 0, 1, 37, 500] {
            assert_eq!(v.disturb(s, 512), s);
        }
    }

    #[test]
    fn single_ended_nl_compresses_large_sums() {
        let mut v = VariationModel::new(0.0, 0.5, false, 1);
        let big = v.disturb(400, 512);
        assert!(big < 400, "compressive NL must pull large sums down, got {big}");
        let small = v.disturb(2, 512);
        assert!((small - 2).abs() <= 1);
    }

    #[test]
    fn symmetric_mapping_suppresses_noise() {
        // Same sigma, symmetric vs single-ended: symmetric spread is ~20x
        // smaller (mismatch = 0.05).
        let spread = |symmetric: bool| {
            let mut v = VariationModel::new(1.0, 0.0, symmetric, 7);
            let mut acc = 0.0;
            for _ in 0..2000 {
                let d = v.disturb(0, 1024) as f64;
                acc += d * d;
            }
            (acc / 2000.0).sqrt()
        };
        let sym = spread(true);
        let single = spread(false);
        assert!(
            sym * 10.0 < single,
            "symmetric {sym:.2} should be <<{single:.2}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = VariationModel::new(0.5, 0.1, false, 42);
        let mut b = VariationModel::new(0.5, 0.1, false, 42);
        for s in 0..50 {
            assert_eq!(a.disturb(s, 256), b.disturb(s, 256));
        }
    }
}
