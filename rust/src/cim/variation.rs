//! Sense-amp nonlinearity and cell-variation injection (paper §II-B: "we
//! apply the symmetry weight mapping method to mitigate nonlinearity (NL)
//! and cell variation in binary or ternary weights").
//!
//! Model: an analog MAC sum accumulated on a long bitline suffers
//!   (a) per-cell conductance variation — zero-mean noise whose variance
//!       grows with the number of active cells: sigma_eff = sigma*sqrt(n);
//!   (b) bitline nonlinearity — a compressive term ~ alpha * s * |s| / n
//!       that biases large sums toward the rail.
//!
//! With **symmetric (differential) mapping**, both bitlines of an SA see
//! the same number of active cells, so the NL term cancels to first order
//! and only the residual mismatch (fraction `mismatch`) survives. With
//! single-ended mapping both terms apply in full. The ablation bench
//! (`table1_comparison --variation`) sweeps sigma and shows the accuracy
//! cliff the paper's mapping avoids.

use crate::util::rng::Rng;

/// Variation/nonlinearity injection parameters.
#[derive(Debug, Clone)]
pub struct VariationModel {
    /// Per-cell conductance sigma (in units of one cell's contribution).
    pub sigma: f64,
    /// Bitline nonlinearity coefficient.
    pub nl_alpha: f64,
    /// Symmetric (differential) weight mapping enabled?
    pub symmetric: bool,
    /// Residual differential mismatch when symmetric (0..1).
    pub mismatch: f64,
    /// RNG for the noise draws (seeded per run for reproducibility).
    pub rng: Rng,
}

impl VariationModel {
    /// Default residual differential mismatch (override with
    /// [`Self::with_mismatch`]).
    pub const DEFAULT_MISMATCH: f64 = 0.05;

    pub fn new(sigma: f64, nl_alpha: f64, symmetric: bool, seed: u64) -> Self {
        VariationModel {
            sigma,
            nl_alpha,
            symmetric,
            mismatch: Self::DEFAULT_MISMATCH,
            rng: Rng::new(seed),
        }
    }

    /// Override the residual differential mismatch fraction (the noise
    /// that survives symmetric mapping; `0.0` = a perfectly matched
    /// differential pair, `1.0` = no suppression at all).
    pub fn with_mismatch(mut self, mismatch: f64) -> Self {
        self.mismatch = mismatch;
        self
    }

    /// Advance the RNG exactly as one [`Self::disturb`] call on an active
    /// column does, discarding the draw. The tensor-level replay
    /// (`robustness::replay`) uses this for SA columns outside the active
    /// layer's channel range: the boot sequence arms the whole mask
    /// plane, so *every* column of *every* fire consumes one draw in the
    /// cycle engine, whether or not its output is ever read.
    #[inline]
    pub fn burn(&mut self) {
        let _ = self.rng.normal();
    }

    /// Disturb one SA's ideal integer MAC sum. `active` is the number of
    /// unmasked cells on the column (noise scale), `sum` the ideal result.
    pub fn disturb(&mut self, sum: i32, active: u32) -> i32 {
        if active == 0 {
            return sum;
        }
        let n = active as f64;
        let noise_scale = if self.symmetric { self.mismatch } else { 1.0 };
        let noise = self.rng.normal() * self.sigma * n.sqrt() * noise_scale;
        let nl = if self.symmetric {
            // Differential read: compressive term cancels to first order.
            0.0
        } else {
            -self.nl_alpha * (sum as f64) * (sum as f64).abs() / n
        };
        let disturbed = sum as f64 + noise + nl;
        disturbed.round() as i32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sigma_is_identity_when_symmetric() {
        let mut v = VariationModel::new(0.0, 0.1, true, 1);
        for s in [-100, -1, 0, 1, 37, 500] {
            assert_eq!(v.disturb(s, 512), s);
        }
    }

    #[test]
    fn single_ended_nl_compresses_large_sums() {
        let mut v = VariationModel::new(0.0, 0.5, false, 1);
        let big = v.disturb(400, 512);
        assert!(big < 400, "compressive NL must pull large sums down, got {big}");
        let small = v.disturb(2, 512);
        assert!((small - 2).abs() <= 1);
    }

    #[test]
    fn symmetric_mapping_suppresses_noise() {
        // Same sigma, symmetric vs single-ended: symmetric spread is ~20x
        // smaller (mismatch = 0.05).
        let spread = |symmetric: bool| {
            let mut v = VariationModel::new(1.0, 0.0, symmetric, 7);
            let mut acc = 0.0;
            for _ in 0..2000 {
                let d = v.disturb(0, 1024) as f64;
                acc += d * d;
            }
            (acc / 2000.0).sqrt()
        };
        let sym = spread(true);
        let single = spread(false);
        assert!(
            sym * 10.0 < single,
            "symmetric {sym:.2} should be <<{single:.2}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = VariationModel::new(0.5, 0.1, false, 42);
        let mut b = VariationModel::new(0.5, 0.1, false, 42);
        for s in 0..50 {
            assert_eq!(a.disturb(s, 256), b.disturb(s, 256));
        }
    }

    #[test]
    fn disturb_stream_matches_manual_rng_replay() {
        // The disturbance stream is EXACTLY one normal() per active-column
        // disturb, applied as noise*sigma*sqrt(n)*scale (+ NL), rounded
        // half-away-from-zero. Variation parity between the cycle engine
        // and the tensor-level replay rests on this sequencing, so pin it
        // against a manual replay off the same seed.
        use crate::util::rng::Rng;
        let (sigma, nl_alpha, seed) = (0.8, 0.25, 9u64);
        let mut v = VariationModel::new(sigma, nl_alpha, false, seed);
        let mut rng = Rng::new(seed);
        for (s, active) in [(10i32, 96u32), (-40, 96), (3, 192), (100, 64), (0, 32)] {
            let got = v.disturb(s, active);
            let n = active as f64;
            let want = (s as f64
                + rng.normal() * sigma * n.sqrt()
                - nl_alpha * (s as f64) * (s as f64).abs() / n)
                .round() as i32;
            assert_eq!(got, want, "sum {s} active {active}");
        }
        // active == 0 consumes NO draw: both streams stay aligned after.
        assert_eq!(v.disturb(5, 0), 5);
        let got = v.disturb(7, 100);
        let want = (7.0 + rng.normal() * sigma * 100f64.sqrt() - nl_alpha * 7.0 * 7.0 / 100.0)
            .round() as i32;
        assert_eq!(got, want);
    }

    #[test]
    fn burn_advances_stream_exactly_like_disturb() {
        // burn() must consume exactly one draw, like disturb on an active
        // column — the replay's correctness for non-owned SA columns.
        let mut a = VariationModel::new(1.0, 0.2, false, 77);
        let mut b = VariationModel::new(1.0, 0.2, false, 77);
        let _ = a.disturb(12, 128);
        b.burn();
        for s in [3, -9, 40] {
            assert_eq!(a.disturb(s, 128), b.disturb(s, 128), "streams diverged at {s}");
        }
    }

    #[test]
    fn mismatch_parameter_scales_symmetric_noise() {
        // mismatch = 0.0: a perfect differential pair is an identity even
        // at huge sigma.
        let mut perfect = VariationModel::new(50.0, 0.3, true, 3).with_mismatch(0.0);
        for s in [-200, -1, 0, 17, 400] {
            assert_eq!(perfect.disturb(s, 1024), s);
        }
        // Larger mismatch => proportionally larger residual spread.
        let spread = |mismatch: f64| {
            let mut v = VariationModel::new(1.0, 0.0, true, 21).with_mismatch(mismatch);
            let mut acc = 0.0;
            for _ in 0..2000 {
                let d = v.disturb(0, 1024) as f64;
                acc += d * d;
            }
            (acc / 2000.0).sqrt()
        };
        let small = spread(0.05);
        let large = spread(0.5);
        assert!(
            large > 5.0 * small,
            "10x mismatch must widen the residual spread ~10x: {small:.2} vs {large:.2}"
        );
    }
}
