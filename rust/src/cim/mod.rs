//! The 512 Kb SRAM-based CIM macro (paper §II-B, integrating the ternary
//! macro of [7]) as a functional + timing + energy model.
//!
//! * [`mode`]         — X-mode (1024 WL × 256 SA) / Y-mode (512 WL × 512 SA)
//!   reconfiguration and the per-layer window configuration.
//! * [`input_buffer`] — the 1024-bit, 32-bit-shift input buffer (paper
//!   Fig. 2 designed it as a 32-bit shift "to reduce routing complexity").
//! * [`weight_map`]   — logical weight/threshold/mask placement in the
//!   macro's word-addressed port (symmetry mapping = sign + mask planes).
//! * [`variation`]    — sense-amp nonlinearity / cell-variation injection
//!   and the symmetric-mapping mitigation the paper references.
//! * [`macro_`]       — the array itself: `cim_w`/`cim_r` word port, the
//!   single-cycle full-array MAC ("fire"), output latch, pooling register,
//!   raw-sum readout port for the high-precision final layer.

pub mod input_buffer;
pub mod macro_;
pub mod mode;
pub mod variation;
pub mod weight_map;

pub use macro_::{CimMacro, CimStats};
pub use mode::{CimConfig, Mode};
pub use variation::VariationModel;
