//! Ablation execution modes: which of the paper's three optimizations the
//! compiler enables. The latency experiments (Figs. 6/7/9, the 85.14 %
//! headline) are differences between these modes on the same model.

use std::fmt;

/// Optimization toggles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptLevel {
    /// CIM layer fusion: inter-layer FMs stay in FM SRAM (Fig. 6).
    /// Off = every layer's input/output FM round-trips DRAM.
    pub layer_fusion: bool,
    /// Conv/max-pool pipeline: pooling fused into the drain path (Fig. 7).
    /// Off = a separate RISC-V pooling pass between conv layers.
    pub conv_pool_pipeline: bool,
    /// Weight fusion: uDMA prefetch of layer i+1 weights during layer i
    /// compute, double-buffered in weight SRAM (Figs. 8/9).
    /// Off = compute stalls on every layer's DRAM weight load.
    pub weight_fusion: bool,
}

impl OptLevel {
    /// The paper's baseline (conventional CIM accelerator).
    pub const BASELINE: OptLevel =
        OptLevel { layer_fusion: false, conv_pool_pipeline: false, weight_fusion: false };
    /// Everything on (the CIMR-V configuration).
    pub const FULL: OptLevel =
        OptLevel { layer_fusion: true, conv_pool_pipeline: true, weight_fusion: true };

    /// The cumulative ladder used for the 85.14 % waterfall:
    /// baseline -> +layer fusion -> +weight fusion -> +pipeline (the
    /// paper's §III-A ordering).
    pub fn ladder() -> [(&'static str, OptLevel); 4] {
        [
            ("baseline", OptLevel::BASELINE),
            (
                "+layer fusion",
                OptLevel { layer_fusion: true, ..OptLevel::BASELINE },
            ),
            (
                "+weight fusion",
                OptLevel { layer_fusion: true, weight_fusion: true, conv_pool_pipeline: false },
            ),
            ("+conv/pool pipeline (full)", OptLevel::FULL),
        ]
    }

    pub fn parse(s: &str) -> anyhow::Result<OptLevel> {
        Ok(match s {
            "baseline" | "none" => OptLevel::BASELINE,
            "full" | "all" => OptLevel::FULL,
            "layer-fusion" => OptLevel { layer_fusion: true, ..OptLevel::BASELINE },
            "weight-fusion" => OptLevel { weight_fusion: true, ..OptLevel::BASELINE },
            "pipeline" => OptLevel { conv_pool_pipeline: true, ..OptLevel::BASELINE },
            _ => anyhow::bail!(
                "unknown opt level {s:?} (baseline|layer-fusion|weight-fusion|pipeline|full)"
            ),
        })
    }
}

impl fmt::Display for OptLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "lf={} pipe={} wf={}",
            self.layer_fusion as u8, self.conv_pool_pipeline as u8, self.weight_fusion as u8
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_cumulative() {
        let l = OptLevel::ladder();
        assert_eq!(l[0].1, OptLevel::BASELINE);
        assert_eq!(l[3].1, OptLevel::FULL);
        assert!(l[1].1.layer_fusion && !l[1].1.weight_fusion);
        assert!(l[2].1.layer_fusion && l[2].1.weight_fusion && !l[2].1.conv_pool_pipeline);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(OptLevel::parse("full").unwrap(), OptLevel::FULL);
        assert_eq!(OptLevel::parse("baseline").unwrap(), OptLevel::BASELINE);
        assert!(OptLevel::parse("bogus").is_err());
    }
}
