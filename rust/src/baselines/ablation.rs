//! Ablation execution modes: which of the paper's three optimizations the
//! compiler enables. The latency experiments (Figs. 6/7/9, the 85.14 %
//! headline) are differences between these modes on the same model.

use std::fmt;

/// Optimization toggles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptLevel {
    /// CIM layer fusion: inter-layer FMs stay in FM SRAM (Fig. 6).
    /// Off = every layer's input/output FM round-trips DRAM.
    pub layer_fusion: bool,
    /// Conv/max-pool pipeline: pooling fused into the drain path (Fig. 7).
    /// Off = a separate RISC-V pooling pass between conv layers.
    pub conv_pool_pipeline: bool,
    /// Weight fusion: uDMA prefetch of layer i+1 weights during layer i
    /// compute, double-buffered in weight SRAM (Figs. 8/9).
    /// Off = compute stalls on every layer's DRAM weight load.
    pub weight_fusion: bool,
    /// Multi-layer-resident fused programs: the image is split into a
    /// one-time *setup* section (mask-plane init, every layer's weight
    /// DMA, resident layers' sign bursts packed at planner-assigned
    /// wordline rows — see `compiler::fusion`) and a steady-state
    /// *per-inference* section that re-fires the resident weights with
    /// zero per-inference weight DRAM traffic. Implies the other three
    /// (codegen rejects `fused` without them).
    pub fused: bool,
}

impl OptLevel {
    /// The paper's baseline (conventional CIM accelerator).
    pub const BASELINE: OptLevel = OptLevel {
        layer_fusion: false,
        conv_pool_pipeline: false,
        weight_fusion: false,
        fused: false,
    };
    /// The classic CIMR-V configuration (all three paper toggles, one
    /// self-contained boot-and-run image).
    pub const FULL: OptLevel = OptLevel {
        layer_fusion: true,
        conv_pool_pipeline: true,
        weight_fusion: true,
        fused: false,
    };
    /// FULL plus multi-layer-resident fusion (steady-state serving mode).
    pub const FUSED: OptLevel = OptLevel {
        layer_fusion: true,
        conv_pool_pipeline: true,
        weight_fusion: true,
        fused: true,
    };

    /// The cumulative ladder used for the 85.14 % waterfall:
    /// baseline -> +layer fusion -> +weight fusion -> +pipeline (the
    /// paper's §III-A ordering) -> +multi-layer residency.
    pub fn ladder() -> [(&'static str, OptLevel); 5] {
        [
            ("baseline", OptLevel::BASELINE),
            (
                "+layer fusion",
                OptLevel { layer_fusion: true, ..OptLevel::BASELINE },
            ),
            (
                "+weight fusion",
                OptLevel { layer_fusion: true, weight_fusion: true, ..OptLevel::BASELINE },
            ),
            ("+conv/pool pipeline (full)", OptLevel::FULL),
            ("+resident fusion (fused)", OptLevel::FUSED),
        ]
    }

    pub fn parse(s: &str) -> anyhow::Result<OptLevel> {
        Ok(match s {
            "baseline" | "none" => OptLevel::BASELINE,
            "full" | "all" => OptLevel::FULL,
            "fused" | "resident" => OptLevel::FUSED,
            "layer-fusion" => OptLevel { layer_fusion: true, ..OptLevel::BASELINE },
            "weight-fusion" => OptLevel { weight_fusion: true, ..OptLevel::BASELINE },
            "pipeline" => OptLevel { conv_pool_pipeline: true, ..OptLevel::BASELINE },
            _ => anyhow::bail!(
                "unknown opt level {s:?} (baseline|layer-fusion|weight-fusion|pipeline|full|fused)"
            ),
        })
    }
}

impl fmt::Display for OptLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "lf={} pipe={} wf={} fused={}",
            self.layer_fusion as u8,
            self.conv_pool_pipeline as u8,
            self.weight_fusion as u8,
            self.fused as u8
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_is_cumulative() {
        let l = OptLevel::ladder();
        assert_eq!(l[0].1, OptLevel::BASELINE);
        assert_eq!(l[3].1, OptLevel::FULL);
        assert_eq!(l[4].1, OptLevel::FUSED);
        assert!(l[1].1.layer_fusion && !l[1].1.weight_fusion);
        assert!(l[2].1.layer_fusion && l[2].1.weight_fusion && !l[2].1.conv_pool_pipeline);
        assert!(!l[3].1.fused && l[4].1.fused);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(OptLevel::parse("full").unwrap(), OptLevel::FULL);
        assert_eq!(OptLevel::parse("baseline").unwrap(), OptLevel::BASELINE);
        assert_eq!(OptLevel::parse("fused").unwrap(), OptLevel::FUSED);
        assert!(OptLevel::parse("bogus").is_err());
    }
}
