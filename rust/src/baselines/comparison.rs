//! Table I comparator rows: published numbers for JSSC'21 [4],
//! TCAS-I'22 [5], ISSCC'22 [9] (DIANA) and this work. The normalized
//! values are *computed* by `energy::normalize`, not transcribed — the
//! test in `normalize.rs` checks they reproduce the table's parentheses.

use crate::energy::normalize::DesignPoint;

/// All four Table I columns.
pub fn table1_rows() -> Vec<DesignPoint> {
    vec![
        DesignPoint {
            name: "JSSC'21 [4]",
            process_nm: 65.0,
            voltage_v: 1.0,
            // RNN processor, 4b/8b IA and W; Table I normalizes at 8b x 8b.
            ia_bits: 8.0,
            w_bits: 8.0,
            tops: Some(0.0055),
            tops_per_w: 0.91,
            accuracy_pct: Some(92.75),
            end_to_end: false,
            weight_fusion: false,
        },
        DesignPoint {
            name: "TCAS-I'22 [5]",
            process_nm: 28.0,
            voltage_v: 0.8,
            // BR-CIM: binary representation, normalized at 1b x 1b.
            ia_bits: 1.0,
            w_bits: 1.0,
            tops: None, // not reported
            tops_per_w: 1280.0,
            accuracy_pct: Some(76.40),
            end_to_end: false,
            weight_fusion: false,
        },
        DesignPoint {
            name: "ISSCC'22 [9]",
            process_nm: 22.0,
            voltage_v: 0.55,
            // DIANA analog path: 7b IA x 1.5b W.
            ia_bits: 7.0,
            w_bits: 1.5,
            tops: Some(29.5),
            tops_per_w: 600.0,
            accuracy_pct: Some(89.3),
            end_to_end: true,
            weight_fusion: false,
        },
        DesignPoint {
            name: "This work",
            process_nm: 28.0,
            voltage_v: 0.9,
            ia_bits: 1.0,
            w_bits: 1.0,
            tops: Some(26.2144),
            tops_per_w: 3707.84,
            accuracy_pct: Some(94.02), // paper; our synthetic-GSCD number is
            // reported next to it by the bench
            end_to_end: true,
            weight_fusion: true,
        },
    ]
}

/// Render Table I (the bench and the `table1` CLI subcommand print this).
pub fn render_table1(our_measured_tops_per_w: Option<f64>, our_accuracy: Option<f64>) -> String {
    let rows = table1_rows();
    let mut s = String::new();
    s.push_str(&format!(
        "{:<22}{:>14}{:>12}{:>14}{:>16}{:>18}{:>12}{:>8}{:>8}\n",
        "design", "process", "voltage", "TOPS", "norm TOPS", "TOPS/W", "norm EE", "e2e", "wfuse"
    ));
    for r in &rows {
        s.push_str(&format!(
            "{:<22}{:>12}nm{:>11}V{:>14}{:>16}{:>18}{:>12.2}{:>8}{:>8}\n",
            r.name,
            r.process_nm,
            r.voltage_v,
            r.tops.map_or("-".into(), |t| format!("{t}")),
            r.normalized_tops().map_or("-".into(), |t| format!("{t:.3}")),
            format!("{}", r.tops_per_w),
            r.normalized_tops_per_w(),
            if r.end_to_end { "yes" } else { "-" },
            if r.weight_fusion { "yes" } else { "-" },
        ));
    }
    if let Some(m) = our_measured_tops_per_w {
        s.push_str(&format!("this repro (measured, cycle+energy model): {m:.2} TOPS/W\n"));
    }
    if let Some(a) = our_accuracy {
        s.push_str(&format!("this repro (synthetic GSCD accuracy): {a:.2}%\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_rows_ours_last() {
        let rows = table1_rows();
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[3].name, "This work");
        assert!(rows[3].end_to_end && rows[3].weight_fusion);
        assert!(!rows.iter().take(3).any(|r| r.weight_fusion));
    }

    #[test]
    fn render_contains_all_designs() {
        let t = render_table1(Some(3500.0), Some(96.1));
        for n in ["JSSC", "TCAS", "ISSCC", "This work", "3500.00", "96.10%"] {
            assert!(t.contains(n), "missing {n} in:\n{t}");
        }
    }
}
