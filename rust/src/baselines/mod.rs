//! Baselines: the Table I comparator designs (published datasheet numbers
//! + the normalization model) and the ablation execution modes (no layer
//! fusion / no pipeline / no weight fusion) that the latency experiments
//! compare against.

pub mod ablation;
pub mod comparison;

pub use ablation::OptLevel;
