//! Mini-assembler: builds encoded RV32IM+CIM instruction streams with
//! labels, forward references and the usual pseudo-instructions. The
//! codegen (`codegen.rs`) drives this to produce the boot image.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::isa::rv32::{AluOp, BranchKind, Instr, LoadKind, StoreKind};
use crate::isa::{encode, CimInstr, Reg};

/// Number of instructions [`Asm::li`] expands to for a value — the
/// single source of truth shared with the analytical latency model
/// (`fsim::latency`), asserted against the real expansion in `li`.
pub fn li_len(v: i64) -> usize {
    let v = v as i32;
    if (-2048..=2047).contains(&v) {
        return 1;
    }
    let lo = (v << 20) >> 20;
    if lo != 0 {
        2
    } else {
        1
    }
}

/// A label handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Label(usize);

#[derive(Debug, Clone)]
enum Slot {
    /// Fully encoded instruction word.
    Done(u32),
    /// Branch to a label (patched at assembly).
    Branch { kind: BranchKind, rs1: Reg, rs2: Reg, target: Label },
    /// Jump-and-link to a label.
    Jal { rd: Reg, target: Label },
}

/// The builder.
#[derive(Debug, Default)]
pub struct Asm {
    slots: Vec<Slot>,
    labels: BTreeMap<Label, usize>, // label -> instruction index
    next_label: usize,
}

impl Asm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current position (instruction index).
    pub fn here(&self) -> usize {
        self.slots.len()
    }

    /// Create an unbound label.
    pub fn label(&mut self) -> Label {
        let l = Label(self.next_label);
        self.next_label += 1;
        l
    }

    /// Bind a label to the current position.
    pub fn bind(&mut self, l: Label) {
        self.labels.insert(l, self.slots.len());
    }

    /// Create a label bound here.
    pub fn here_label(&mut self) -> Label {
        let l = self.label();
        self.bind(l);
        l
    }

    pub fn raw(&mut self, i: Instr) -> &mut Self {
        self.slots.push(Slot::Done(encode(&i).expect("encodable instruction")));
        self
    }

    // --- pseudo-instructions -------------------------------------------

    /// Load a 32-bit immediate (lui+addi or single addi).
    pub fn li(&mut self, rd: Reg, v: i64) -> &mut Self {
        let before = self.here();
        let v = v as i32;
        if (-2048..=2047).contains(&v) {
            self.addi(rd, Reg::ZERO, v);
        } else {
            // lui loads the upper 20 bits; addi sign-extends, so round up.
            let lo = ((v << 20) >> 20) as i32; // low 12, sign-extended
            let hi = (v.wrapping_sub(lo) as u32) >> 12;
            self.raw(Instr::Lui { rd, imm: hi as i32 });
            if lo != 0 {
                self.addi(rd, rd, lo);
            }
        }
        debug_assert_eq!(self.here() - before, li_len(v as i64));
        self
    }

    pub fn addi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.raw(Instr::OpImm { op: AluOp::Add, rd, rs1, imm })
    }

    pub fn mv(&mut self, rd: Reg, rs: Reg) -> &mut Self {
        self.addi(rd, rs, 0)
    }

    pub fn add(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.raw(Instr::Op { op: AluOp::Add, rd, rs1, rs2 })
    }

    pub fn sub(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.raw(Instr::Op { op: AluOp::Sub, rd, rs1, rs2 })
    }

    pub fn slli(&mut self, rd: Reg, rs1: Reg, sh: i32) -> &mut Self {
        self.raw(Instr::OpImm { op: AluOp::Sll, rd, rs1, imm: sh })
    }

    pub fn srai(&mut self, rd: Reg, rs1: Reg, sh: i32) -> &mut Self {
        self.raw(Instr::OpImm { op: AluOp::Sra, rd, rs1, imm: sh })
    }

    pub fn xor(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.raw(Instr::Op { op: AluOp::Xor, rd, rs1, rs2 })
    }

    pub fn or(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.raw(Instr::Op { op: AluOp::Or, rd, rs1, rs2 })
    }

    pub fn and(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.raw(Instr::Op { op: AluOp::And, rd, rs1, rs2 })
    }

    pub fn slt(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.raw(Instr::Op { op: AluOp::Slt, rd, rs1, rs2 })
    }

    pub fn sll(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.raw(Instr::Op { op: AluOp::Sll, rd, rs1, rs2 })
    }

    pub fn lw(&mut self, rd: Reg, rs1: Reg, off: i32) -> &mut Self {
        self.raw(Instr::Load { kind: LoadKind::Lw, rd, rs1, offset: off })
    }

    pub fn lh(&mut self, rd: Reg, rs1: Reg, off: i32) -> &mut Self {
        self.raw(Instr::Load { kind: LoadKind::Lh, rd, rs1, offset: off })
    }

    pub fn sw(&mut self, rs1: Reg, rs2: Reg, off: i32) -> &mut Self {
        self.raw(Instr::Store { kind: StoreKind::Sw, rs1, rs2, offset: off })
    }

    pub fn cim(&mut self, c: CimInstr) -> &mut Self {
        c.validate().expect("valid cim instruction");
        self.raw(Instr::Cim(c))
    }

    pub fn ebreak(&mut self) -> &mut Self {
        self.raw(Instr::Ebreak)
    }

    // --- control flow ---------------------------------------------------

    pub fn branch(&mut self, kind: BranchKind, rs1: Reg, rs2: Reg, target: Label) -> &mut Self {
        self.slots.push(Slot::Branch { kind, rs1, rs2, target });
        self
    }

    pub fn beq(&mut self, rs1: Reg, rs2: Reg, t: Label) -> &mut Self {
        self.branch(BranchKind::Beq, rs1, rs2, t)
    }

    pub fn bne(&mut self, rs1: Reg, rs2: Reg, t: Label) -> &mut Self {
        self.branch(BranchKind::Bne, rs1, rs2, t)
    }

    pub fn blt(&mut self, rs1: Reg, rs2: Reg, t: Label) -> &mut Self {
        self.branch(BranchKind::Blt, rs1, rs2, t)
    }

    pub fn jal(&mut self, rd: Reg, target: Label) -> &mut Self {
        self.slots.push(Slot::Jal { rd, target });
        self
    }

    /// Assemble to instruction words (base address 0).
    pub fn assemble(&self) -> Result<Vec<u32>> {
        let resolve = |l: Label| -> Result<usize> {
            self.labels.get(&l).copied().ok_or_else(|| anyhow!("unbound label {l:?}"))
        };
        let mut out = Vec::with_capacity(self.slots.len());
        for (i, slot) in self.slots.iter().enumerate() {
            let word = match slot {
                Slot::Done(w) => *w,
                Slot::Branch { kind, rs1, rs2, target } => {
                    let t = resolve(*target)?;
                    let off = (t as i64 - i as i64) * 4;
                    if !(-4096..=4094).contains(&off) {
                        bail!("branch at {i} to {t}: offset {off} out of range");
                    }
                    encode(&Instr::Branch { kind: *kind, rs1: *rs1, rs2: *rs2, offset: off as i32 })?
                }
                Slot::Jal { rd, target } => {
                    let t = resolve(*target)?;
                    let off = (t as i64 - i as i64) * 4;
                    encode(&Instr::Jal { rd: *rd, offset: off as i32 })?
                }
            };
            out.push(word);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{decode, disasm};

    #[test]
    fn li_all_ranges() {
        for v in [0i64, 1, -1, 2047, -2048, 2048, -2049, 0x2000_0000, -0x8000_0000, 0x7FFF_FFFF, 0x0000_8FFF] {
            let mut a = Asm::new();
            a.li(Reg::T0, v);
            a.ebreak();
            let words = a.assemble().unwrap();
            // Execute by hand: lui/addi semantics.
            let mut reg = 0i64;
            for w in &words[..words.len() - 1] {
                match decode(*w).unwrap() {
                    Instr::Lui { imm, .. } => reg = ((imm as u32) << 12) as i32 as i64,
                    Instr::OpImm { imm, rs1, .. } => {
                        let base = if rs1 == Reg::ZERO { 0 } else { reg };
                        reg = (base as i32).wrapping_add(imm) as i64;
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            assert_eq!(reg as i32, v as i32, "li {v:#x}");
        }
    }

    #[test]
    fn forward_and_backward_branches() {
        let mut a = Asm::new();
        let end = a.label();
        a.li(Reg::T0, 3);
        let loop_top = a.here_label();
        a.addi(Reg::T0, Reg::T0, -1);
        a.beq(Reg::T0, Reg::ZERO, end);
        a.bne(Reg::T0, Reg::ZERO, loop_top);
        a.bind(end);
        a.ebreak();
        let words = a.assemble().unwrap();
        // beq at index 2 forward to 4: offset +8; bne at 3 back to 1: -8.
        assert!(disasm(&decode(words[2]).unwrap()).contains("beq"));
        match decode(words[2]).unwrap() {
            Instr::Branch { offset, .. } => assert_eq!(offset, 8),
            _ => panic!(),
        }
        match decode(words[3]).unwrap() {
            Instr::Branch { offset, .. } => assert_eq!(offset, -8),
            _ => panic!(),
        }
    }

    #[test]
    fn unbound_label_errors() {
        let mut a = Asm::new();
        let l = a.label();
        a.beq(Reg::ZERO, Reg::ZERO, l);
        assert!(a.assemble().is_err());
    }

    #[test]
    fn jal_offsets() {
        let mut a = Asm::new();
        let f = a.label();
        a.jal(Reg::RA, f);
        a.ebreak();
        a.bind(f);
        a.ebreak();
        let words = a.assemble().unwrap();
        match decode(words[0]).unwrap() {
            Instr::Jal { offset, .. } => assert_eq!(offset, 8),
            _ => panic!(),
        }
    }
}
