//! Program generator: trained KWS model + `OptLevel` -> bootable image.
//!
//! Register conventions in the emitted code:
//!   a0..a3  CIM-addressable window (bases for cim_conv/cim_w/cim_r)
//!   t0..t6  scalar temps
//!   s0..s5  loop counters / running pointers
//!   t6      MMIO base (held across the whole program)
//!
//! The conv inner code is fully unrolled straight-line `cim_conv`
//! sequences (the paper's single-cycle-per-instruction throughput story);
//! preprocessing and weight bursts are loops.

use anyhow::Result;

use crate::baselines::OptLevel;
use crate::cim::mode::{CimConfig, Mode};
use crate::cim::weight_map;
use crate::dataflow::plan::{self, KwsPlan};
use crate::dataflow::shard::ShardPlan;
use crate::isa::{CimInstr, Reg};
use crate::mem::layout;
use crate::model::KwsModel;

use super::asm::Asm;
use super::program::{Phase, Program};

const FM: i64 = layout::FM_BASE as i64;
const DMEM: i64 = layout::DMEM_BASE as i64;

fn mmio_sw(a: &mut Asm, reg: Reg, off: u32) {
    // t6 holds MMIO_BASE.
    a.sw(Reg::T6, reg, off as i32);
}

/// Busy-wait until the uDMA is idle (poll MMIO_UDMA_CTRL).
fn emit_udma_wait(a: &mut Asm) {
    let top = a.here_label();
    a.lw(Reg::T0, Reg::T6, layout::MMIO_UDMA_CTRL as i32);
    a.bne(Reg::T0, Reg::ZERO, top);
}

/// Program a uDMA transfer and start it (does not wait).
fn emit_udma_start(a: &mut Asm, src: i64, dst: i64, len: i64) {
    a.li(Reg::T0, src);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_SRC);
    a.li(Reg::T0, dst);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_DST);
    a.li(Reg::T0, len);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_LEN);
    a.li(Reg::T0, 1);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_CTRL);
}

fn emit_phase(a: &mut Asm, id: u32) {
    a.li(Reg::T0, id as i64);
    mmio_sw(a, Reg::T0, layout::MMIO_HOST_PHASE);
}

/// Select a macro of the bank (`m`), or broadcast with
/// `layout::CIM_SEL_BROADCAST as i64`. Only emitted by sharded programs
/// (`n_macros > 1`) so single-macro images stay byte-identical.
fn emit_sel(a: &mut Asm, m: i64) {
    a.li(Reg::T0, m);
    mmio_sw(a, Reg::T0, layout::MMIO_CIM_SEL);
}

const SEL_BROADCAST: i64 = layout::CIM_SEL_BROADCAST as i64;

/// Boot: stage audio into DMEM (uDMA), initialise the macro mask plane to
/// all-ones (binary weights: every cell active), set MMIO base register.
fn emit_boot(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, opt: OptLevel) {
    a.li(Reg::T6, layout::MMIO_BASE as i64);
    if shards.n_macros > 1 {
        // One broadcast burst arms every macro's mask plane below.
        emit_sel(a, SEL_BROADCAST);
    }
    // Audio: DRAM -> DMEM (background; mask init runs meanwhile).
    emit_udma_start(
        a,
        layout::DRAM_BASE as i64 + plan::DRAM_AUDIO as i64,
        DMEM + plan::DMEM_AUDIO as i64,
        p.audio_bytes as i64,
    );
    // Mask plane: 8192 words of 0xFFFFFFFF via cim_w from the FM ones
    // word. a1 = ones source, a2 = running port address.
    a.li(Reg::A1, FM + plan::FM_ONES as i64);
    a.li(Reg::A2, weight_map::MASK_BASE as i64);
    a.li(Reg::T1, (weight_map::MASK_BASE + weight_map::MASK_WORDS) as i64);
    // Store the ones word first (FM_ONES starts zeroed).
    a.li(Reg::T0, 0xFFFF_FFFFu32 as i64);
    a.sw(Reg::A1, Reg::T0, 0);
    let top = a.here_label();
    a.cim(CimInstr::write(Reg::A1, 0, Reg::A2, 0));
    a.addi(Reg::A2, Reg::A2, 1);
    a.bne(Reg::A2, Reg::T1, top);
    emit_udma_wait(a); // audio must have landed
    if opt.weight_fusion {
        // Weight fusion (Fig. 8): enqueue EVERY layer's stream on the uDMA
        // descriptor chain now — the engine drains DRAM into the weight
        // SRAM while the core runs preprocessing and early conv layers.
        for lp in &p.layers {
            emit_udma_start(
                a,
                layout::DRAM_BASE as i64 + lp.dram_offset as i64,
                layout::WT_BASE as i64 + lp.wt_offset as i64,
                lp.stream_bytes() as i64,
            );
        }
    }
    emit_phase(a, Phase::BootDone as u32);
}

/// Integer preprocessing (paper Fig. 10 RISC-V mode): pre-emphasis,
/// per-sample magnitude features, folded-BN threshold compare, packed
/// binary FM written to `FM_BUF_A`.
///
/// Loop structure: outer over t (frames), inner fully unrolled over the
/// two 32-channel words of each row.
fn emit_preprocess(a: &mut Asm, model: &KwsModel) {
    let frame = model.audio_len / model.t; // samples per frame
    let wpr = model.c / 32; // words per row
    a.li(Reg::S0, DMEM + plan::DMEM_AUDIO as i64); // audio ptr (by frame)
    a.li(Reg::S1, FM + plan::FM_BUF_A as i64); // FM out ptr
    a.li(Reg::S2, model.t as i64); // frame counter
    let t_top = a.here_label();
    a.li(Reg::S4, DMEM + plan::DMEM_THR as i64); // threshold table ptr
    for w in 0..wpr {
        a.li(Reg::T3, 0); // word accumulator
        for cbit in 0..32 {
            let ch = w * 32 + cbit;
            // x = audio[t*frame + ch]; xp = previous sample. The halfword
            // below DMEM_AUDIO is zero, so ch==0/t==0 reads a true zero.
            a.lh(Reg::T0, Reg::S0, (2 * ch) as i32);
            a.lh(Reg::T1, Reg::S0, (2 * ch) as i32 - 2);
            // y = 32x - 31xp = (x<<5) - ((xp<<5) - xp)
            a.slli(Reg::T0, Reg::T0, 5);
            a.slli(Reg::T2, Reg::T1, 5);
            a.sub(Reg::T2, Reg::T2, Reg::T1);
            a.sub(Reg::T0, Reg::T0, Reg::T2);
            // |y|
            a.srai(Reg::T1, Reg::T0, 31);
            a.xor(Reg::T0, Reg::T0, Reg::T1);
            a.sub(Reg::T0, Reg::T0, Reg::T1);
            // bit = thr < f  (flip applied per-word below)
            a.lw(Reg::T1, Reg::S4, (4 * ch) as i32);
            a.slt(Reg::T1, Reg::T1, Reg::T0);
            if cbit > 0 {
                a.slli(Reg::T1, Reg::T1, cbit as i32);
            }
            a.or(Reg::T3, Reg::T3, Reg::T1);
        }
        // Apply the per-word flip mask (folded BN gamma<0 / gamma==0).
        a.li(Reg::T4, DMEM + plan::DMEM_FLIP as i64 + (w * 4) as i64);
        a.lw(Reg::T4, Reg::T4, 0);
        a.xor(Reg::T3, Reg::T3, Reg::T4);
        a.sw(Reg::S1, Reg::T3, (w * 4) as i32);
    }
    a.addi(Reg::S1, Reg::S1, (wpr * 4) as i32);
    a.addi(Reg::S0, Reg::S0, (frame * 2) as i32);
    a.addi(Reg::S2, Reg::S2, -1);
    a.bne(Reg::S2, Reg::ZERO, t_top);
    emit_phase(a, Phase::PreprocessDone as u32);
}

/// Weight phase of layer `i`: make the stream resident in the weight-SRAM
/// half, then burst it into the macro(s) with `cim_w`. Under sharding each
/// macro receives its own contiguous column range of the stream (the sign
/// words are column-major, so a channel range is a contiguous slice) and
/// its shard's thresholds at SA 0..len.
fn emit_weight_phase(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, i: usize, opt: OptLevel) {
    let lp = &p.layers[i];
    let multi = shards.n_macros > 1;
    if opt.weight_fusion {
        // The descriptor chain was enqueued at boot (audio first, then one
        // descriptor per layer); wait until this layer's stream (done
        // count >= i + 2) has landed. With preprocessing in front, this
        // poll almost never spins — that is the Fig. 9 saving.
        a.li(Reg::T1, (i as i64) + 2);
        let top = a.here_label();
        a.lw(Reg::T0, Reg::T6, layout::MMIO_UDMA_DONE as i32);
        a.blt(Reg::T0, Reg::T1, top);
    } else {
        // Serial: fetch now, stall on DRAM (Fig. 9 baseline).
        emit_udma_start(
            a,
            layout::DRAM_BASE as i64 + lp.dram_offset as i64,
            layout::WT_BASE as i64 + lp.wt_offset as i64,
            lp.stream_bytes() as i64,
        );
        emit_udma_wait(a);
    }

    let aw = lp.window_words;
    for (m, c0, c1) in shards.layers[i].non_empty() {
        if multi {
            emit_sel(a, m as i64);
        }
        // cim_w burst: signs, column-major. a1 = stream ptr (this shard's
        // column range), a2 = port addr.
        a.li(Reg::A1, layout::WT_BASE as i64 + lp.wt_offset as i64 + (4 * c0 * aw) as i64);
        a.li(Reg::A2, weight_map::SIGN_BASE as i64);
        a.li(Reg::S5, (c1 - c0) as i64);
        let col_top = a.here_label();
        for j in 0..aw {
            a.cim(CimInstr::write(Reg::A1, j as u16, Reg::A2, j as u16));
        }
        a.addi(Reg::A1, Reg::A1, (4 * aw) as i32);
        a.addi(Reg::A2, Reg::A2, Mode::X.col_words() as i32);
        a.addi(Reg::S5, Reg::S5, -1);
        a.bne(Reg::S5, Reg::ZERO, col_top);

        // Thresholds (binarized layers): one word per owned channel. For
        // the single-macro plan a1 already points at the threshold words
        // (they follow the signs); a shard's range needs a reload.
        if lp.th_words > 0 {
            if multi {
                a.li(
                    Reg::A1,
                    layout::WT_BASE as i64 + lp.wt_offset as i64 + (4 * (lp.sign_words + c0)) as i64,
                );
            }
            a.li(Reg::A2, weight_map::TH_BASE as i64);
            a.li(Reg::S5, (c1 - c0) as i64);
            let th_top = a.here_label();
            a.cim(CimInstr::write(Reg::A1, 0, Reg::A2, 0));
            a.addi(Reg::A1, Reg::A1, 4);
            a.addi(Reg::A2, Reg::A2, 1);
            a.addi(Reg::S5, Reg::S5, -1);
            a.bne(Reg::S5, Reg::ZERO, th_top);
        }
    }

    emit_phase(a, Phase::weight_done(i));
}

/// Convolution phase of a binarized layer (row-wise dataflow, Fig. 5).
///
/// Under sharding, shifts broadcast to every macro (the shared input bus)
/// while fires and drains interleave per macro: each owner is selected,
/// fired, and drains its latch words at its word-aligned channel offset of
/// the packed output row — bit-identical rows, per-macro `CimStats`.
fn emit_conv_layer(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, i: usize, opt: OptLevel) {
    let lp = &p.layers[i];
    let s = lp.s_words;
    let o = lp.o_words;
    let t_len = lp.t_in;
    let fused_pool = opt.conv_pool_pipeline && lp.pooled;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();

    // Configure the CIM unit for this layer (broadcast: every macro runs
    // the same window geometry, each over its own column range).
    if multi {
        emit_sel(a, SEL_BROADCAST);
    }
    let cfg = CimConfig {
        mode: Mode::X,
        pool_or: fused_pool,
        window_words: lp.window_words as u8,
        row_base: 0,
        col_base: 0,
    };
    a.li(Reg::T0, cfg.to_bits() as i64);
    mmio_sw(a, Reg::T0, layout::MMIO_CIM_CFG);

    let in_buf = FM + p.in_buf(i) as i64;
    // Without the pipeline, pooled layers stage unpooled rows in PREPOOL.
    let conv_dst = if fused_pool || !lp.pooled {
        FM + p.out_buf(i) as i64
    } else {
        FM + plan::FM_PREPOOL as i64
    };
    a.li(Reg::A0, in_buf); // src row pointer
    a.li(Reg::A2, FM + plan::FM_SCRATCH as i64); // dummy store target
    a.li(Reg::A3, conv_dst); // real drain pointer

    // Prefill: zero row (pad), then rows 0 and 1 (broadcast shifts).
    a.li(Reg::A1, FM + plan::FM_ZERO as i64);
    for j in 0..s {
        a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
    }
    for j in 0..2 * s {
        a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
    }
    // a0 now conceptually points at row 2 (next row to shift).
    a.addi(Reg::A0, Reg::A0, (8 * s) as i32);

    for t in 0..t_len {
        // Does this position drain to the real output?
        let drains = if fused_pool { t % 2 == 1 } else { true };
        if drains {
            // Fire each owner (wd = 0 fires and stores its word 0 at the
            // shard's word offset), then drain its remaining latch words.
            for &(m, c0, c1) in &groups {
                if multi {
                    emit_sel(a, m as i64);
                }
                let base = c0 / 32; // word-aligned shard start
                let words = (c1 - c0).div_ceil(32);
                a.cim(CimInstr::conv(Reg::A0, 0, Reg::A3, base as u16, 0, false));
                for wd in 1..words {
                    a.cim(CimInstr::conv(Reg::A0, 0, Reg::A3, (base + wd) as u16, wd as u8, false));
                }
            }
            a.addi(Reg::A3, Reg::A3, (4 * o) as i32);
        } else {
            // Non-draining (even pooled position): every owner still
            // fires so its pool register rolls; stores are dummies.
            for &(m, ..) in &groups {
                if multi {
                    emit_sel(a, m as i64);
                }
                a.cim(CimInstr::conv(Reg::A0, 0, Reg::A2, 0, 0, false));
            }
        }
        // Shift in row t+2 for the next position (broadcast).
        if t + 2 <= t_len {
            if multi {
                emit_sel(a, SEL_BROADCAST);
            }
        }
        if t + 2 < t_len {
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
            }
            a.addi(Reg::A0, Reg::A0, (4 * s) as i32);
        } else if t + 2 == t_len {
            // Boundary: shift the zero row.
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
            }
        }
    }

    // Unfused pooling: RISC-V OR pass PREPOOL -> out buffer (Fig. 7
    // baseline: the CIM macro idles during this).
    if lp.pooled && !fused_pool {
        let out = FM + p.out_buf(i) as i64;
        a.li(Reg::S0, FM + plan::FM_PREPOOL as i64);
        a.li(Reg::S1, out);
        a.li(Reg::S2, lp.t_out as i64);
        let top = a.here_label();
        for w in 0..o {
            a.lw(Reg::T0, Reg::S0, (4 * w) as i32);
            a.lw(Reg::T1, Reg::S0, (4 * (o + w)) as i32);
            a.or(Reg::T0, Reg::T0, Reg::T1);
            a.sw(Reg::S1, Reg::T0, (4 * w) as i32);
        }
        a.addi(Reg::S0, Reg::S0, (8 * o) as i32);
        a.addi(Reg::S1, Reg::S1, (4 * o) as i32);
        a.addi(Reg::S2, Reg::S2, -1);
        a.bne(Reg::S2, Reg::ZERO, top);
    }

    // Baseline FM round trip (no layer fusion): spill the output FM to
    // DRAM and reload it (Fig. 6 baseline), except after the last layer.
    if !opt.layer_fusion && i + 1 < p.layers.len() {
        let out = p.out_buf(i) as i64;
        let bytes = lp.out_bytes() as i64;
        emit_udma_start(
            a,
            FM + out,
            layout::DRAM_BASE as i64 + plan::DRAM_FM_SPILL as i64,
            bytes,
        );
        emit_udma_wait(a);
        emit_udma_start(
            a,
            layout::DRAM_BASE as i64 + plan::DRAM_FM_SPILL as i64,
            FM + out,
            bytes,
        );
        emit_udma_wait(a);
    }
    emit_phase(a, Phase::conv_done(i));
}

/// Final layer: raw sums via the `cim_r` high-precision port, accumulated
/// into the GAP result vector on the RISC-V side (Fig. 10 post-processing).
/// Under sharding each owner macro is fired and its raw shard columns
/// drain to their global class offsets of the DMEM dump row.
fn emit_final_layer(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, model: &KwsModel, opt: OptLevel) {
    let i = p.layers.len() - 1;
    let lp = &p.layers[i];
    let s = lp.s_words;
    let t_len = lp.t_in;
    let n = model.n_classes;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();

    if multi {
        emit_sel(a, SEL_BROADCAST);
    }
    let cfg = CimConfig {
        mode: Mode::X,
        pool_or: false,
        window_words: lp.window_words as u8,
        row_base: 0,
        col_base: 0,
    };
    a.li(Reg::T0, cfg.to_bits() as i64);
    mmio_sw(a, Reg::T0, layout::MMIO_CIM_CFG);

    a.li(Reg::A0, FM + p.in_buf(i) as i64);
    a.li(Reg::A1, FM + plan::FM_ZERO as i64);
    a.li(Reg::A2, FM + plan::FM_SCRATCH as i64);
    a.li(Reg::A3, DMEM + plan::DMEM_RAWDUMP as i64);

    // Prefill rows -1, 0, 1 (broadcast shifts).
    for j in 0..s {
        a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
    }
    for j in 0..2 * s {
        a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
    }
    a.addi(Reg::A0, Reg::A0, (8 * s) as i32);

    // s3 = raw port base (register operand for cim_r).
    a.li(Reg::S3, weight_map::RAW_BASE as i64);
    for t in 0..t_len {
        for &(m, c0, c1) in &groups {
            if multi {
                emit_sel(a, m as i64);
            }
            // Fire; the binarized store goes to scratch (we read raw sums).
            a.cim(CimInstr::conv(Reg::A0, 0, Reg::A2, 0, 0, false));
            // Raw sums of this shard's columns -> their class offsets in
            // the DMEM dump row (a1 temporarily = port base).
            a.mv(Reg::A1, Reg::S3);
            for c in 0..c1 - c0 {
                a.cim(CimInstr::read(Reg::A1, c as u16, Reg::A3, (c0 + c) as u16));
            }
            a.li(Reg::A1, FM + plan::FM_ZERO as i64);
        }
        a.addi(Reg::A3, Reg::A3, (4 * n) as i32);
        if t + 2 <= t_len && multi {
            emit_sel(a, SEL_BROADCAST);
        }
        if t + 2 < t_len {
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
            }
            a.addi(Reg::A0, Reg::A0, (4 * s) as i32);
        } else if t + 2 == t_len {
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
            }
        }
    }

    // GAP accumulate: result[c] = sum over t of rawdump[t][c]. Pointer
    // walks the dump row by row so immediates stay within I-type range.
    a.li(Reg::S0, DMEM + plan::DMEM_RAWDUMP as i64);
    a.li(Reg::S1, DMEM + plan::DMEM_RESULT as i64);
    for c in 0..n {
        a.sw(Reg::S1, Reg::ZERO, (c * 4) as i32);
    }
    a.li(Reg::S2, t_len as i64);
    let gap_top = a.here_label();
    for c in 0..n {
        a.lw(Reg::T0, Reg::S1, (c * 4) as i32);
        a.lw(Reg::T1, Reg::S0, (c * 4) as i32);
        a.add(Reg::T0, Reg::T0, Reg::T1);
        a.sw(Reg::S1, Reg::T0, (c * 4) as i32);
    }
    a.addi(Reg::S0, Reg::S0, (n * 4) as i32);
    a.addi(Reg::S2, Reg::S2, -1);
    a.bne(Reg::S2, Reg::ZERO, gap_top);
    emit_phase(a, Phase::conv_done(i));
    let _ = opt;
}

/// Build the complete program for one inference (single macro).
pub fn build_kws_program(model: &KwsModel, opt: OptLevel) -> Result<Program> {
    build_kws_program_sharded(model, opt, 1)
}

/// Build a program whose layers are sharded across `n_macros` CIM macros
/// (`--macros N`): output channels split word-aligned per layer, weight
/// bursts routed per macro, fire sequences interleaved, drains at shard
/// offsets. `n_macros == 1` produces exactly the classic image.
pub fn build_kws_program_sharded(
    model: &KwsModel,
    opt: OptLevel,
    n_macros: usize,
) -> Result<Program> {
    let p = KwsPlan::new(model)?;
    let shards = ShardPlan::word_aligned(&p, n_macros.max(1))?;
    anyhow::ensure!(shards.is_word_aligned(), "cycle-engine shard plan must be word-aligned");
    let mut a = Asm::new();

    emit_boot(&mut a, &p, &shards, opt);
    emit_preprocess(&mut a, model);
    for i in 0..p.layers.len() {
        emit_weight_phase(&mut a, &p, &shards, i, opt);
        if p.layers[i].binarized {
            emit_conv_layer(&mut a, &p, &shards, i, opt);
        } else {
            emit_final_layer(&mut a, &p, &shards, model, opt);
        }
    }
    // Publish the result and halt.
    a.li(Reg::T0, DMEM + plan::DMEM_RESULT as i64);
    mmio_sw(&mut a, Reg::T0, layout::MMIO_HOST_RESULT);
    a.li(Reg::T0, 0);
    mmio_sw(&mut a, Reg::T0, layout::MMIO_HOST_EXIT);
    a.ebreak(); // unreachable (HOST_EXIT halts), defensive

    // DMEM constant tables: folded-BN thresholds + flip words.
    let thr_words: Vec<u32> = model
        .pre_thr
        .iter()
        .zip(&model.pre_dir)
        .zip(&model.bn_beta)
        .map(|((&thr, &dir), &beta)| match dir {
            // dir > 0: bit = f > thr (raw slt result, flip 0)
            1 => (thr.clamp(i32::MIN as i64, i32::MAX as i64)) as i32 as u32,
            // dir < 0: bit = !(f > thr) -> same thr, flip 1
            -1 => (thr.clamp(i32::MIN as i64, i32::MAX as i64)) as i32 as u32,
            // dir == 0: constant beta>0: thr = MAX (never >) with flip set
            // for true; or flip clear for false.
            _ => {
                let _ = beta;
                i32::MAX as u32
            }
        })
        .collect();
    let flip_words: Vec<u32> = (0..model.c / 32)
        .map(|w| {
            let mut word = 0u32;
            for b in 0..32 {
                let ch = w * 32 + b;
                let flip = match model.pre_dir[ch] {
                    -1 => true,
                    0 => model.bn_beta[ch] > 0.0,
                    _ => false,
                };
                if flip {
                    word |= 1 << b;
                }
            }
            word
        })
        .collect();

    let final_t = p.layers.last().unwrap().t_in;
    Ok(Program {
        imem: a.assemble()?,
        dram: p.build_dram_weights(model),
        dmem: vec![(plan::DMEM_THR, thr_words), (plan::DMEM_FLIP, flip_words)],
        result_addr: plan::DMEM_RESULT,
        final_t,
        opt,
        n_classes: model.n_classes,
        plan: p,
        shards,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::decode;

    fn fake_model() -> KwsModel {
        use crate::model::kws::LayerSpec;
        let mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: (0..3 * ci * co).map(|x| if x % 3 == 0 { 1 } else { -1 }).collect(),
            thresholds: if binarized { vec![0; co] } else { vec![] },
        };
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 1,
            layers: vec![mk(64, 64, true, true), mk(64, 12, false, false)],
            bn_gamma: vec![1.0; 64],
            bn_beta: vec![0.0; 64],
            bn_mean: vec![10.0; 64],
            bn_var: vec![100.0; 64],
            pre_thr: vec![10; 64],
            pre_dir: vec![1; 64],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    #[test]
    fn builds_and_decodes_for_all_opt_levels() {
        let m = fake_model();
        for (_, opt) in crate::baselines::OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            assert!(!prog.imem.is_empty());
            assert!(prog.imem.len() * 4 <= layout::IMEM_SIZE as usize, "IMEM overflow");
            // Every emitted word must decode.
            for (i, w) in prog.imem.iter().enumerate() {
                decode(*w).unwrap_or_else(|e| panic!("word {i}: {e}"));
            }
        }
    }

    #[test]
    fn baseline_has_more_instructions() {
        let m = fake_model();
        let base = build_kws_program(&m, OptLevel::BASELINE).unwrap();
        let full = build_kws_program(&m, OptLevel::FULL).unwrap();
        assert!(
            base.imem.len() > full.imem.len(),
            "baseline adds pooling passes + FM spills: {} vs {}",
            base.imem.len(),
            full.imem.len()
        );
    }

    #[test]
    fn sharded_build_encodes_and_single_matches_classic() {
        let m = fake_model();
        let classic = build_kws_program(&m, OptLevel::FULL).unwrap();
        let one = build_kws_program_sharded(&m, OptLevel::FULL, 1).unwrap();
        // n_macros = 1 must be byte-identical to the classic image.
        assert_eq!(one.imem, classic.imem);
        assert_eq!(one.shards.n_macros, 1);
        for n in 2..=4 {
            let prog = build_kws_program_sharded(&m, OptLevel::FULL, n).unwrap();
            assert_eq!(prog.shards.n_macros, n);
            assert!(prog.shards.is_word_aligned());
            // Sharded programs interleave selects: strictly more instrs.
            assert!(prog.imem.len() > classic.imem.len());
            for (i, w) in prog.imem.iter().enumerate() {
                decode(*w).unwrap_or_else(|e| panic!("n={n} word {i}: {e}"));
            }
        }
    }

    #[test]
    fn dram_image_covers_all_layers() {
        let m = fake_model();
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        assert_eq!(prog.dram.len(), 2);
        let total: usize = prog.dram.iter().map(|(_, b)| b.len()).sum();
        // L0: 64 cols * 6 words + 64 th; L1: 12 cols * 6 words.
        assert_eq!(total, (64 * 6 + 64 + 12 * 6) * 4);
    }
}
