//! Program generator: trained KWS model + `OptLevel` -> bootable image.
//!
//! Register conventions in the emitted code:
//!   a0..a3  CIM-addressable window (bases for cim_conv/cim_w/cim_r)
//!   t0..t6  scalar temps
//!   s0..s5  loop counters / running pointers
//!   t6      MMIO base (held across the whole program)
//!
//! The conv inner code is fully unrolled straight-line `cim_conv`
//! sequences (the paper's single-cycle-per-instruction throughput story);
//! preprocessing and weight bursts are loops.
//!
//! # Fused fire/drain ordering contract (`OptLevel::FUSED`)
//!
//! Fused images split in two sections (see [`Program::entry`]):
//!
//! 1. **Setup** (PC 0, run once by the SoC loader): mask-plane init, every
//!    layer's weight stream DMA'd DRAM -> weight SRAM, and each *resident*
//!    layer's sign planes burst to its `FusionPlan::row_base` rectangle.
//! 2. **Per-inference** (PC `entry`): audio DMA only — no weight DRAM
//!    traffic. Each layer's weight phase re-bursts just its thresholds
//!    (the per-SA-column threshold registers are shared by co-resident
//!    layers) plus the sign planes of *streamed* layers at `stream_base`.
//!
//! Within a fused pooled conv layer the ordering is the conv/pool
//! pipeline's: position `t` fires with `pool_or` latching, odd positions
//! drain the pooled word while the macro's shift register is already
//! taking row `t+2` of the *same* layer — and because layer `i+1`'s
//! planes are co-resident, its weight phase needs no sign burst, so its
//! fires start immediately after layer `i`'s last drain. The first pooled
//! drain is announced with the `Phase::pool_drain(i)` marker (id `40+i`)
//! so trace viewers can render the drain region `[40+i, 30+i)` as a slice
//! concurrent with the next fires; cycle attribution folds it into conv.
//!
//! Streamed layers (wordline budget exceeded) fall back per-layer; when a
//! whole group exceeds one macro's wordlines, input-channel-axis sharding
//! ([`build_kws_program_input_sharded`]) splits every window across the
//! bank, shrinking per-macro windows (`FusionPlan::for_slices`).

use anyhow::Result;

use crate::baselines::OptLevel;
use crate::cim::mode::{CimConfig, Mode};
use crate::cim::weight_map;
use crate::dataflow::plan::{self, KwsPlan};
use crate::dataflow::shard::ShardPlan;
use crate::isa::{CimInstr, Reg};
use crate::mem::layout;
use crate::model::KwsModel;

use super::asm::Asm;
use super::fusion::FusionPlan;
use super::program::{Phase, Program};

const FM: i64 = layout::FM_BASE as i64;
const DMEM: i64 = layout::DMEM_BASE as i64;

fn mmio_sw(a: &mut Asm, reg: Reg, off: u32) {
    // t6 holds MMIO_BASE.
    a.sw(Reg::T6, reg, off as i32);
}

/// Busy-wait until the uDMA is idle (poll MMIO_UDMA_CTRL).
fn emit_udma_wait(a: &mut Asm) {
    let top = a.here_label();
    a.lw(Reg::T0, Reg::T6, layout::MMIO_UDMA_CTRL as i32);
    a.bne(Reg::T0, Reg::ZERO, top);
}

/// Program a uDMA transfer and start it (does not wait).
fn emit_udma_start(a: &mut Asm, src: i64, dst: i64, len: i64) {
    a.li(Reg::T0, src);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_SRC);
    a.li(Reg::T0, dst);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_DST);
    a.li(Reg::T0, len);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_LEN);
    a.li(Reg::T0, 1);
    mmio_sw(a, Reg::T0, layout::MMIO_UDMA_CTRL);
}

fn emit_phase(a: &mut Asm, id: u32) {
    a.li(Reg::T0, id as i64);
    mmio_sw(a, Reg::T0, layout::MMIO_HOST_PHASE);
}

/// Select a macro of the bank (`m`), or broadcast with
/// `layout::CIM_SEL_BROADCAST as i64`. Only emitted by sharded programs
/// (`n_macros > 1`) so single-macro images stay byte-identical.
fn emit_sel(a: &mut Asm, m: i64) {
    a.li(Reg::T0, m);
    mmio_sw(a, Reg::T0, layout::MMIO_CIM_SEL);
}

const SEL_BROADCAST: i64 = layout::CIM_SEL_BROADCAST as i64;

/// Boot: stage audio into DMEM (uDMA), initialise the macro mask plane to
/// all-ones (binary weights: every cell active), set MMIO base register.
fn emit_boot(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, opt: OptLevel) {
    a.li(Reg::T6, layout::MMIO_BASE as i64);
    if shards.n_macros > 1 {
        // One broadcast burst arms every macro's mask plane below.
        emit_sel(a, SEL_BROADCAST);
    }
    // Audio: DRAM -> DMEM (background; mask init runs meanwhile).
    emit_udma_start(
        a,
        layout::DRAM_BASE as i64 + plan::DRAM_AUDIO as i64,
        DMEM + plan::DMEM_AUDIO as i64,
        p.audio_bytes as i64,
    );
    // Mask plane: 8192 words of 0xFFFFFFFF via cim_w from the FM ones
    // word. a1 = ones source, a2 = running port address.
    a.li(Reg::A1, FM + plan::FM_ONES as i64);
    a.li(Reg::A2, weight_map::MASK_BASE as i64);
    a.li(Reg::T1, (weight_map::MASK_BASE + weight_map::MASK_WORDS) as i64);
    // Store the ones word first (FM_ONES starts zeroed).
    a.li(Reg::T0, 0xFFFF_FFFFu32 as i64);
    a.sw(Reg::A1, Reg::T0, 0);
    let top = a.here_label();
    a.cim(CimInstr::write(Reg::A1, 0, Reg::A2, 0));
    a.addi(Reg::A2, Reg::A2, 1);
    a.bne(Reg::A2, Reg::T1, top);
    emit_udma_wait(a); // audio must have landed
    if opt.weight_fusion {
        // Weight fusion (Fig. 8): enqueue EVERY layer's stream on the uDMA
        // descriptor chain now — the engine drains DRAM into the weight
        // SRAM while the core runs preprocessing and early conv layers.
        for lp in &p.layers {
            emit_udma_start(
                a,
                layout::DRAM_BASE as i64 + lp.dram_offset as i64,
                layout::WT_BASE as i64 + lp.wt_offset as i64,
                lp.stream_bytes() as i64,
            );
        }
    }
    emit_phase(a, Phase::BootDone as u32);
}

/// Integer preprocessing (paper Fig. 10 RISC-V mode): pre-emphasis,
/// per-sample magnitude features, folded-BN threshold compare, packed
/// binary FM written to `FM_BUF_A`.
///
/// Loop structure: outer over t (frames), inner fully unrolled over the
/// two 32-channel words of each row.
fn emit_preprocess(a: &mut Asm, model: &KwsModel) {
    let frame = model.audio_len / model.t; // samples per frame
    let wpr = model.c / 32; // words per row
    a.li(Reg::S0, DMEM + plan::DMEM_AUDIO as i64); // audio ptr (by frame)
    a.li(Reg::S1, FM + plan::FM_BUF_A as i64); // FM out ptr
    a.li(Reg::S2, model.t as i64); // frame counter
    let t_top = a.here_label();
    a.li(Reg::S4, DMEM + plan::DMEM_THR as i64); // threshold table ptr
    for w in 0..wpr {
        a.li(Reg::T3, 0); // word accumulator
        for cbit in 0..32 {
            let ch = w * 32 + cbit;
            // x = audio[t*frame + ch]; xp = previous sample. The halfword
            // below DMEM_AUDIO is zero, so ch==0/t==0 reads a true zero.
            a.lh(Reg::T0, Reg::S0, (2 * ch) as i32);
            a.lh(Reg::T1, Reg::S0, (2 * ch) as i32 - 2);
            // y = 32x - 31xp = (x<<5) - ((xp<<5) - xp)
            a.slli(Reg::T0, Reg::T0, 5);
            a.slli(Reg::T2, Reg::T1, 5);
            a.sub(Reg::T2, Reg::T2, Reg::T1);
            a.sub(Reg::T0, Reg::T0, Reg::T2);
            // |y|
            a.srai(Reg::T1, Reg::T0, 31);
            a.xor(Reg::T0, Reg::T0, Reg::T1);
            a.sub(Reg::T0, Reg::T0, Reg::T1);
            // bit = thr < f  (flip applied per-word below)
            a.lw(Reg::T1, Reg::S4, (4 * ch) as i32);
            a.slt(Reg::T1, Reg::T1, Reg::T0);
            if cbit > 0 {
                a.slli(Reg::T1, Reg::T1, cbit as i32);
            }
            a.or(Reg::T3, Reg::T3, Reg::T1);
        }
        // Apply the per-word flip mask (folded BN gamma<0 / gamma==0).
        a.li(Reg::T4, DMEM + plan::DMEM_FLIP as i64 + (w * 4) as i64);
        a.lw(Reg::T4, Reg::T4, 0);
        a.xor(Reg::T3, Reg::T3, Reg::T4);
        a.sw(Reg::S1, Reg::T3, (w * 4) as i32);
    }
    a.addi(Reg::S1, Reg::S1, (wpr * 4) as i32);
    a.addi(Reg::S0, Reg::S0, (frame * 2) as i32);
    a.addi(Reg::S2, Reg::S2, -1);
    a.bne(Reg::S2, Reg::ZERO, t_top);
    emit_phase(a, Phase::PreprocessDone as u32);
}

/// Weight phase of layer `i`: make the stream resident in the weight-SRAM
/// half, then burst it into the macro(s) with `cim_w`. Under sharding each
/// macro receives its own contiguous column range of the stream (the sign
/// words are column-major, so a channel range is a contiguous slice) and
/// its shard's thresholds at SA 0..len.
fn emit_weight_phase(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, i: usize, opt: OptLevel) {
    let lp = &p.layers[i];
    let multi = shards.n_macros > 1;
    if opt.weight_fusion {
        // The descriptor chain was enqueued at boot (audio first, then one
        // descriptor per layer); wait until this layer's stream (done
        // count >= i + 2) has landed. With preprocessing in front, this
        // poll almost never spins — that is the Fig. 9 saving.
        a.li(Reg::T1, (i as i64) + 2);
        let top = a.here_label();
        a.lw(Reg::T0, Reg::T6, layout::MMIO_UDMA_DONE as i32);
        a.blt(Reg::T0, Reg::T1, top);
    } else {
        // Serial: fetch now, stall on DRAM (Fig. 9 baseline).
        emit_udma_start(
            a,
            layout::DRAM_BASE as i64 + lp.dram_offset as i64,
            layout::WT_BASE as i64 + lp.wt_offset as i64,
            lp.stream_bytes() as i64,
        );
        emit_udma_wait(a);
    }

    let aw = lp.window_words;
    for (m, c0, c1) in shards.layers[i].non_empty() {
        if multi {
            emit_sel(a, m as i64);
        }
        // cim_w burst: signs, column-major. a1 = stream ptr (this shard's
        // column range), a2 = port addr.
        a.li(Reg::A1, layout::WT_BASE as i64 + lp.wt_offset as i64 + (4 * c0 * aw) as i64);
        a.li(Reg::A2, weight_map::SIGN_BASE as i64);
        a.li(Reg::S5, (c1 - c0) as i64);
        let col_top = a.here_label();
        for j in 0..aw {
            a.cim(CimInstr::write(Reg::A1, j as u16, Reg::A2, j as u16));
        }
        a.addi(Reg::A1, Reg::A1, (4 * aw) as i32);
        a.addi(Reg::A2, Reg::A2, Mode::X.col_words() as i32);
        a.addi(Reg::S5, Reg::S5, -1);
        a.bne(Reg::S5, Reg::ZERO, col_top);

        // Thresholds (binarized layers): one word per owned channel. For
        // the single-macro plan a1 already points at the threshold words
        // (they follow the signs); a shard's range needs a reload.
        if lp.th_words > 0 {
            if multi {
                a.li(
                    Reg::A1,
                    layout::WT_BASE as i64 + lp.wt_offset as i64 + (4 * (lp.sign_words + c0)) as i64,
                );
            }
            a.li(Reg::A2, weight_map::TH_BASE as i64);
            a.li(Reg::S5, (c1 - c0) as i64);
            let th_top = a.here_label();
            a.cim(CimInstr::write(Reg::A1, 0, Reg::A2, 0));
            a.addi(Reg::A1, Reg::A1, 4);
            a.addi(Reg::A2, Reg::A2, 1);
            a.addi(Reg::S5, Reg::S5, -1);
            a.bne(Reg::S5, Reg::ZERO, th_top);
        }
    }

    emit_phase(a, Phase::weight_done(i));
}

/// Sign-plane `cim_w` burst of layer `i` into the wordline rectangle at
/// `row_base` (row blocks of 32). The port address of window word `j` of
/// column `c` is `c * 32 + row_base + j` — exactly the words a fire with
/// `CimConfig::row_base == row_base` reads back, so a layer bursts and
/// fires through the same rectangle regardless of where it sits.
fn emit_sign_burst(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, i: usize, row_base: usize) {
    let lp = &p.layers[i];
    let aw = lp.window_words;
    let multi = shards.n_macros > 1;
    for (m, c0, c1) in shards.layers[i].non_empty() {
        if multi {
            emit_sel(a, m as i64);
        }
        a.li(Reg::A1, layout::WT_BASE as i64 + lp.wt_offset as i64 + (4 * c0 * aw) as i64);
        a.li(Reg::A2, (weight_map::SIGN_BASE + row_base) as i64);
        a.li(Reg::S5, (c1 - c0) as i64);
        let col_top = a.here_label();
        for j in 0..aw {
            a.cim(CimInstr::write(Reg::A1, j as u16, Reg::A2, j as u16));
        }
        a.addi(Reg::A1, Reg::A1, (4 * aw) as i32);
        a.addi(Reg::A2, Reg::A2, Mode::X.col_words() as i32);
        a.addi(Reg::S5, Reg::S5, -1);
        a.bne(Reg::S5, Reg::ZERO, col_top);
    }
}

/// Fused-program weight phase of layer `i`: no DRAM traffic (streams went
/// resident in the weight SRAM at setup). Resident layers' sign planes
/// are already in their rectangles; streamed layers re-burst theirs at
/// `stream_base`. Thresholds are re-burst for every binarized layer —
/// the per-column threshold registers are shared across co-residents.
fn emit_fused_weight_phase(
    a: &mut Asm,
    p: &KwsPlan,
    shards: &ShardPlan,
    i: usize,
    fp: &FusionPlan,
) {
    let lp = &p.layers[i];
    let multi = shards.n_macros > 1;
    if !fp.resident[i] {
        emit_sign_burst(a, p, shards, i, fp.stream_base);
    }
    if lp.th_words > 0 {
        for (m, c0, c1) in shards.layers[i].non_empty() {
            if multi {
                emit_sel(a, m as i64);
            }
            a.li(
                Reg::A1,
                layout::WT_BASE as i64 + lp.wt_offset as i64 + (4 * (lp.sign_words + c0)) as i64,
            );
            a.li(Reg::A2, weight_map::TH_BASE as i64);
            a.li(Reg::S5, (c1 - c0) as i64);
            let th_top = a.here_label();
            a.cim(CimInstr::write(Reg::A1, 0, Reg::A2, 0));
            a.addi(Reg::A1, Reg::A1, 4);
            a.addi(Reg::A2, Reg::A2, 1);
            a.addi(Reg::S5, Reg::S5, -1);
            a.bne(Reg::S5, Reg::ZERO, th_top);
        }
    }
    emit_phase(a, Phase::weight_done(i));
}

/// Convolution phase of a binarized layer (row-wise dataflow, Fig. 5).
///
/// Under sharding, shifts broadcast to every macro (the shared input bus)
/// while fires and drains interleave per macro: each owner is selected,
/// fired, and drains its latch words at its word-aligned channel offset of
/// the packed output row — bit-identical rows, per-macro `CimStats`.
fn emit_conv_layer(
    a: &mut Asm,
    p: &KwsPlan,
    shards: &ShardPlan,
    i: usize,
    opt: OptLevel,
    fusion: Option<&FusionPlan>,
) {
    let lp = &p.layers[i];
    let s = lp.s_words;
    let o = lp.o_words;
    let t_len = lp.t_in;
    let fused_pool = opt.conv_pool_pipeline && lp.pooled;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();

    // Configure the CIM unit for this layer (broadcast: every macro runs
    // the same window geometry, each over its own column range). Fused
    // programs aim the window at the layer's resident (or streaming)
    // wordline rectangle.
    if multi {
        emit_sel(a, SEL_BROADCAST);
    }
    let cfg = CimConfig {
        mode: Mode::X,
        pool_or: fused_pool,
        window_words: lp.window_words as u8,
        row_base: fusion.map_or(0, |f| f.row_base[i] as u8),
        col_base: 0,
    };
    a.li(Reg::T0, cfg.to_bits() as i64);
    mmio_sw(a, Reg::T0, layout::MMIO_CIM_CFG);

    let in_buf = FM + p.in_buf(i) as i64;
    // Without the pipeline, pooled layers stage unpooled rows in PREPOOL.
    let conv_dst = if fused_pool || !lp.pooled {
        FM + p.out_buf(i) as i64
    } else {
        FM + plan::FM_PREPOOL as i64
    };
    a.li(Reg::A0, in_buf); // src row pointer
    a.li(Reg::A2, FM + plan::FM_SCRATCH as i64); // dummy store target
    a.li(Reg::A3, conv_dst); // real drain pointer

    // Prefill: zero row (pad), then rows 0 and 1 (broadcast shifts).
    a.li(Reg::A1, FM + plan::FM_ZERO as i64);
    for j in 0..s {
        a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
    }
    for j in 0..2 * s {
        a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
    }
    // a0 now conceptually points at row 2 (next row to shift).
    a.addi(Reg::A0, Reg::A0, (8 * s) as i32);

    for t in 0..t_len {
        // Does this position drain to the real output?
        let drains = if fused_pool { t % 2 == 1 } else { true };
        if drains {
            if t == 1 && fused_pool && fusion.is_some() {
                // First pooled drain of the fused schedule: from here on,
                // drains overlap the next position's shift-in/fire.
                emit_phase(a, Phase::pool_drain(i));
            }
            // Fire each owner (wd = 0 fires and stores its word 0 at the
            // shard's word offset), then drain its remaining latch words.
            for &(m, c0, c1) in &groups {
                if multi {
                    emit_sel(a, m as i64);
                }
                let base = c0 / 32; // word-aligned shard start
                let words = (c1 - c0).div_ceil(32);
                a.cim(CimInstr::conv(Reg::A0, 0, Reg::A3, base as u16, 0, false));
                for wd in 1..words {
                    a.cim(CimInstr::conv(Reg::A0, 0, Reg::A3, (base + wd) as u16, wd as u8, false));
                }
            }
            a.addi(Reg::A3, Reg::A3, (4 * o) as i32);
        } else {
            // Non-draining (even pooled position): every owner still
            // fires so its pool register rolls; stores are dummies.
            for &(m, ..) in &groups {
                if multi {
                    emit_sel(a, m as i64);
                }
                a.cim(CimInstr::conv(Reg::A0, 0, Reg::A2, 0, 0, false));
            }
        }
        // Shift in row t+2 for the next position (broadcast).
        if t + 2 <= t_len {
            if multi {
                emit_sel(a, SEL_BROADCAST);
            }
        }
        if t + 2 < t_len {
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
            }
            a.addi(Reg::A0, Reg::A0, (4 * s) as i32);
        } else if t + 2 == t_len {
            // Boundary: shift the zero row.
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
            }
        }
    }

    // Unfused pooling: RISC-V OR pass PREPOOL -> out buffer (Fig. 7
    // baseline: the CIM macro idles during this).
    if lp.pooled && !fused_pool {
        let out = FM + p.out_buf(i) as i64;
        a.li(Reg::S0, FM + plan::FM_PREPOOL as i64);
        a.li(Reg::S1, out);
        a.li(Reg::S2, lp.t_out as i64);
        let top = a.here_label();
        for w in 0..o {
            a.lw(Reg::T0, Reg::S0, (4 * w) as i32);
            a.lw(Reg::T1, Reg::S0, (4 * (o + w)) as i32);
            a.or(Reg::T0, Reg::T0, Reg::T1);
            a.sw(Reg::S1, Reg::T0, (4 * w) as i32);
        }
        a.addi(Reg::S0, Reg::S0, (8 * o) as i32);
        a.addi(Reg::S1, Reg::S1, (4 * o) as i32);
        a.addi(Reg::S2, Reg::S2, -1);
        a.bne(Reg::S2, Reg::ZERO, top);
    }

    // Baseline FM round trip (no layer fusion): spill the output FM to
    // DRAM and reload it (Fig. 6 baseline), except after the last layer.
    if !opt.layer_fusion && i + 1 < p.layers.len() {
        let out = p.out_buf(i) as i64;
        let bytes = lp.out_bytes() as i64;
        emit_udma_start(
            a,
            FM + out,
            layout::DRAM_BASE as i64 + plan::DRAM_FM_SPILL as i64,
            bytes,
        );
        emit_udma_wait(a);
        emit_udma_start(
            a,
            layout::DRAM_BASE as i64 + plan::DRAM_FM_SPILL as i64,
            FM + out,
            bytes,
        );
        emit_udma_wait(a);
    }
    emit_phase(a, Phase::conv_done(i));
}

/// Final layer: raw sums via the `cim_r` high-precision port, accumulated
/// into the GAP result vector on the RISC-V side (Fig. 10 post-processing).
/// Under sharding each owner macro is fired and its raw shard columns
/// drain to their global class offsets of the DMEM dump row.
fn emit_final_layer(
    a: &mut Asm,
    p: &KwsPlan,
    shards: &ShardPlan,
    model: &KwsModel,
    opt: OptLevel,
    fusion: Option<&FusionPlan>,
) {
    let i = p.layers.len() - 1;
    let lp = &p.layers[i];
    let s = lp.s_words;
    let t_len = lp.t_in;
    let n = model.n_classes;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();

    if multi {
        emit_sel(a, SEL_BROADCAST);
    }
    let cfg = CimConfig {
        mode: Mode::X,
        pool_or: false,
        window_words: lp.window_words as u8,
        row_base: fusion.map_or(0, |f| f.row_base[i] as u8),
        col_base: 0,
    };
    a.li(Reg::T0, cfg.to_bits() as i64);
    mmio_sw(a, Reg::T0, layout::MMIO_CIM_CFG);

    a.li(Reg::A0, FM + p.in_buf(i) as i64);
    a.li(Reg::A1, FM + plan::FM_ZERO as i64);
    a.li(Reg::A2, FM + plan::FM_SCRATCH as i64);
    a.li(Reg::A3, DMEM + plan::DMEM_RAWDUMP as i64);

    // Prefill rows -1, 0, 1 (broadcast shifts).
    for j in 0..s {
        a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
    }
    for j in 0..2 * s {
        a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
    }
    a.addi(Reg::A0, Reg::A0, (8 * s) as i32);

    // s3 = raw port base (register operand for cim_r).
    a.li(Reg::S3, weight_map::RAW_BASE as i64);
    for t in 0..t_len {
        for &(m, c0, c1) in &groups {
            if multi {
                emit_sel(a, m as i64);
            }
            // Fire; the binarized store goes to scratch (we read raw sums).
            a.cim(CimInstr::conv(Reg::A0, 0, Reg::A2, 0, 0, false));
            // Raw sums of this shard's columns -> their class offsets in
            // the DMEM dump row (a1 temporarily = port base).
            a.mv(Reg::A1, Reg::S3);
            for c in 0..c1 - c0 {
                a.cim(CimInstr::read(Reg::A1, c as u16, Reg::A3, (c0 + c) as u16));
            }
            a.li(Reg::A1, FM + plan::FM_ZERO as i64);
        }
        a.addi(Reg::A3, Reg::A3, (4 * n) as i32);
        if t + 2 <= t_len && multi {
            emit_sel(a, SEL_BROADCAST);
        }
        if t + 2 < t_len {
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A0, j as u16, Reg::A2, 0, 7, true));
            }
            a.addi(Reg::A0, Reg::A0, (4 * s) as i32);
        } else if t + 2 == t_len {
            for j in 0..s {
                a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
            }
        }
    }

    // GAP accumulate: result[c] = sum over t of rawdump[t][c]. Pointer
    // walks the dump row by row so immediates stay within I-type range.
    a.li(Reg::S0, DMEM + plan::DMEM_RAWDUMP as i64);
    a.li(Reg::S1, DMEM + plan::DMEM_RESULT as i64);
    for c in 0..n {
        a.sw(Reg::S1, Reg::ZERO, (c * 4) as i32);
    }
    a.li(Reg::S2, t_len as i64);
    let gap_top = a.here_label();
    for c in 0..n {
        a.lw(Reg::T0, Reg::S1, (c * 4) as i32);
        a.lw(Reg::T1, Reg::S0, (c * 4) as i32);
        a.add(Reg::T0, Reg::T0, Reg::T1);
        a.sw(Reg::S1, Reg::T0, (c * 4) as i32);
    }
    a.addi(Reg::S0, Reg::S0, (n * 4) as i32);
    a.addi(Reg::S2, Reg::S2, -1);
    a.bne(Reg::S2, Reg::ZERO, gap_top);
    emit_phase(a, Phase::conv_done(i));
    let _ = opt;
}

/// Build the complete program for one inference (single macro).
pub fn build_kws_program(model: &KwsModel, opt: OptLevel) -> Result<Program> {
    build_kws_program_sharded(model, opt, 1)
}

/// Build a program whose layers are sharded across `n_macros` CIM macros
/// (`--macros N`): output channels split word-aligned per layer, weight
/// bursts routed per macro, fire sequences interleaved, drains at shard
/// offsets. `n_macros == 1` produces exactly the classic image.
pub fn build_kws_program_sharded(
    model: &KwsModel,
    opt: OptLevel,
    n_macros: usize,
) -> Result<Program> {
    if opt.fused {
        return build_fused_program(model, opt, n_macros);
    }
    let p = KwsPlan::new(model)?;
    let shards = ShardPlan::word_aligned(&p, n_macros.max(1))?;
    anyhow::ensure!(shards.is_word_aligned(), "cycle-engine shard plan must be word-aligned");
    let mut a = Asm::new();

    emit_boot(&mut a, &p, &shards, opt);
    emit_preprocess(&mut a, model);
    for i in 0..p.layers.len() {
        emit_weight_phase(&mut a, &p, &shards, i, opt);
        if p.layers[i].binarized {
            emit_conv_layer(&mut a, &p, &shards, i, opt, None);
        } else {
            emit_final_layer(&mut a, &p, &shards, model, opt, None);
        }
    }
    emit_epilogue(&mut a);

    let (thr_words, flip_words) = dmem_tables(model);
    let final_t = p.layers.last().unwrap().t_in;
    Ok(Program {
        imem: a.assemble()?,
        entry: 0,
        dram: p.build_dram_weights(model),
        dmem: vec![(plan::DMEM_THR, thr_words), (plan::DMEM_FLIP, flip_words)],
        result_addr: plan::DMEM_RESULT,
        final_t,
        opt,
        n_classes: model.n_classes,
        plan: p,
        shards,
    })
}

/// Result publication + halt, shared by every builder.
fn emit_epilogue(a: &mut Asm) {
    a.li(Reg::T0, DMEM + plan::DMEM_RESULT as i64);
    mmio_sw(a, Reg::T0, layout::MMIO_HOST_RESULT);
    a.li(Reg::T0, 0);
    mmio_sw(a, Reg::T0, layout::MMIO_HOST_EXIT);
    a.ebreak(); // unreachable (HOST_EXIT halts), defensive
}

/// DMEM constant tables: folded-BN thresholds + flip words.
fn dmem_tables(model: &KwsModel) -> (Vec<u32>, Vec<u32>) {
    let thr_words: Vec<u32> = model
        .pre_thr
        .iter()
        .zip(&model.pre_dir)
        .zip(&model.bn_beta)
        .map(|((&thr, &dir), &beta)| match dir {
            // dir > 0: bit = f > thr (raw slt result, flip 0)
            1 => (thr.clamp(i32::MIN as i64, i32::MAX as i64)) as i32 as u32,
            // dir < 0: bit = !(f > thr) -> same thr, flip 1
            -1 => (thr.clamp(i32::MIN as i64, i32::MAX as i64)) as i32 as u32,
            // dir == 0: constant beta>0: thr = MAX (never >) with flip set
            // for true; or flip clear for false.
            _ => {
                let _ = beta;
                i32::MAX as u32
            }
        })
        .collect();
    let flip_words: Vec<u32> = (0..model.c / 32)
        .map(|w| {
            let mut word = 0u32;
            for b in 0..32 {
                let ch = w * 32 + b;
                let flip = match model.pre_dir[ch] {
                    -1 => true,
                    0 => model.bn_beta[ch] > 0.0,
                    _ => false,
                };
                if flip {
                    word |= 1 << b;
                }
            }
            word
        })
        .collect();
    (thr_words, flip_words)
}

/// Fused image (`OptLevel::FUSED`): a one-time *setup* section at PC 0 and
/// the steady-state per-inference section at [`Program::entry`]. See the
/// module docs for the ordering contract. Per-inference DRAM traffic is
/// the audio buffer only.
fn build_fused_program(model: &KwsModel, opt: OptLevel, n_macros: usize) -> Result<Program> {
    anyhow::ensure!(
        opt.layer_fusion && opt.conv_pool_pipeline && opt.weight_fusion,
        "opt.fused implies layer_fusion + conv_pool_pipeline + weight_fusion (use OptLevel::FUSED)"
    );
    let p = KwsPlan::new(model)?;
    let shards = ShardPlan::word_aligned(&p, n_macros.max(1))?;
    anyhow::ensure!(shards.is_word_aligned(), "cycle-engine shard plan must be word-aligned");
    let fp = FusionPlan::new(&p);
    let multi = shards.n_macros > 1;

    // --- Setup section (PC 0, run once by the SoC loader) ----------------
    let mut s = Asm::new();
    s.li(Reg::T6, layout::MMIO_BASE as i64);
    if multi {
        emit_sel(&mut s, SEL_BROADCAST);
    }
    // Mask plane: all-ones (binary weights — every cell of every resident
    // rectangle active; fires gate by window, not by mask).
    s.li(Reg::A1, FM + plan::FM_ONES as i64);
    s.li(Reg::A2, weight_map::MASK_BASE as i64);
    s.li(Reg::T1, (weight_map::MASK_BASE + weight_map::MASK_WORDS) as i64);
    s.li(Reg::T0, 0xFFFF_FFFFu32 as i64);
    s.sw(Reg::A1, Reg::T0, 0);
    let top = s.here_label();
    s.cim(CimInstr::write(Reg::A1, 0, Reg::A2, 0));
    s.addi(Reg::A2, Reg::A2, 1);
    s.bne(Reg::A2, Reg::T1, top);
    // Every layer's weight stream goes resident in the weight SRAM, once.
    for lp in &p.layers {
        emit_udma_start(
            &mut s,
            layout::DRAM_BASE as i64 + lp.dram_offset as i64,
            layout::WT_BASE as i64 + lp.wt_offset as i64,
            lp.stream_bytes() as i64,
        );
        emit_udma_wait(&mut s);
    }
    // Resident layers' sign planes: burst once into their rectangles.
    for i in 0..p.layers.len() {
        if fp.resident[i] {
            emit_sign_burst(&mut s, &p, &shards, i, fp.row_base[i]);
        }
    }
    s.li(Reg::T0, 0);
    mmio_sw(&mut s, Reg::T0, layout::MMIO_HOST_EXIT);
    s.ebreak();
    let setup = s.assemble()?;

    // --- Per-inference section (PC `entry`) ------------------------------
    // Branch targets are PC-relative within each section, so the two
    // assemblies concatenate safely.
    let mut a = Asm::new();
    a.li(Reg::T6, layout::MMIO_BASE as i64);
    if multi {
        emit_sel(&mut a, SEL_BROADCAST);
    }
    emit_udma_start(
        &mut a,
        layout::DRAM_BASE as i64 + plan::DRAM_AUDIO as i64,
        DMEM + plan::DMEM_AUDIO as i64,
        p.audio_bytes as i64,
    );
    emit_udma_wait(&mut a);
    emit_phase(&mut a, Phase::BootDone as u32);
    emit_preprocess(&mut a, model);
    for i in 0..p.layers.len() {
        emit_fused_weight_phase(&mut a, &p, &shards, i, &fp);
        if p.layers[i].binarized {
            emit_conv_layer(&mut a, &p, &shards, i, opt, Some(&fp));
        } else {
            emit_final_layer(&mut a, &p, &shards, model, opt, Some(&fp));
        }
    }
    emit_epilogue(&mut a);

    let mut imem = setup;
    let entry = imem.len();
    imem.extend_from_slice(&a.assemble()?);
    anyhow::ensure!(imem.len() * 4 <= layout::IMEM_SIZE as usize, "fused image overflows IMEM");

    let (thr_words, flip_words) = dmem_tables(model);
    let final_t = p.layers.last().unwrap().t_in;
    Ok(Program {
        imem,
        entry,
        dram: p.build_dram_weights(model),
        dmem: vec![(plan::DMEM_THR, thr_words), (plan::DMEM_FLIP, flip_words)],
        result_addr: plan::DMEM_RESULT,
        final_t,
        opt,
        n_classes: model.n_classes,
        plan: p,
        shards,
    })
}

/// Weight phase of layer `i` under input-axis sharding: every macro gets
/// *all* output columns of its input-word slice of the stream; thresholds
/// go to DMEM (`plan::DMEM_SLICE_TH`) for the host-side compare — the
/// macros produce raw partial sums, not latched bits.
fn emit_input_weight_phase(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, i: usize) {
    let lp = &p.layers[i];
    let multi = shards.n_macros > 1;
    // Serial stream fetch (input-axis programs always load serially: the
    // th DMA below would contend with a boot-enqueued descriptor chain).
    emit_udma_start(
        a,
        layout::DRAM_BASE as i64 + lp.dram_offset as i64,
        layout::WT_BASE as i64 + lp.wt_offset as i64,
        lp.stream_bytes() as i64,
    );
    emit_udma_wait(a);

    let aw = lp.window_words;
    let s = lp.s_words;
    let k = aw / s; // kernel taps
    for (m, c0, c1) in shards.layers[i].non_empty() {
        let wa = c0 / 32; // first input word of this macro's slice
        let sl = (c1 - c0) / 32; // slice words per tap
        if multi {
            emit_sel(a, m as i64);
        }
        // Column-major burst of the slice's words of every output column:
        // stream word (tap, j) of column c is at c*aw + tap*s + wa + j;
        // its port word within the macro's shrunk window is tap*sl + j.
        a.li(Reg::A1, layout::WT_BASE as i64 + lp.wt_offset as i64);
        a.li(Reg::A2, weight_map::SIGN_BASE as i64);
        a.li(Reg::S5, lp.c_out as i64);
        let col_top = a.here_label();
        for tap in 0..k {
            for j in 0..sl {
                a.cim(CimInstr::write(
                    Reg::A1,
                    (tap * s + wa + j) as u16,
                    Reg::A2,
                    (tap * sl + j) as u16,
                ));
            }
        }
        a.addi(Reg::A1, Reg::A1, (4 * aw) as i32);
        a.addi(Reg::A2, Reg::A2, Mode::X.col_words() as i32);
        a.addi(Reg::S5, Reg::S5, -1);
        a.bne(Reg::S5, Reg::ZERO, col_top);
    }
    if lp.th_words > 0 {
        emit_udma_start(
            a,
            layout::DRAM_BASE as i64 + lp.dram_offset as i64 + (4 * lp.sign_words) as i64,
            DMEM + plan::DMEM_SLICE_TH as i64,
            (4 * lp.th_words) as i64,
        );
        emit_udma_wait(a);
    }
    emit_phase(a, Phase::weight_done(i));
}

/// Binarized conv layer under input-axis sharding: each macro fires over
/// its input slice and drains *raw partial sums* (`cim_r`) of all output
/// channels into a per-macro DMEM row; the core adds the partials
/// (integer addition — exact, so the merge is bit-identical to the
/// unsharded layer), applies thresholds (strict `>`) and packs the output
/// row. Pooling is always the host OR pass here (`conv_pool_pipeline` is
/// a no-op: macro latch bits never carry this layer's output).
fn emit_input_conv_layer(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, i: usize, opt: OptLevel) {
    let lp = &p.layers[i];
    let s = lp.s_words;
    let o = lp.o_words;
    let t_len = lp.t_in;
    let c_out = lp.c_out;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();
    let k = lp.window_words / s;

    // Per-macro window config (windows differ per slice width).
    for &(m, c0, c1) in &groups {
        let sl = (c1 - c0) / 32;
        if multi {
            emit_sel(a, m as i64);
        }
        let cfg = CimConfig {
            mode: Mode::X,
            pool_or: false,
            window_words: (k * sl) as u8,
            row_base: 0,
            col_base: 0,
        };
        a.li(Reg::T0, cfg.to_bits() as i64);
        mmio_sw(a, Reg::T0, layout::MMIO_CIM_CFG);
    }

    a.li(Reg::A0, FM + p.in_buf(i) as i64); // src row pointer
    a.li(Reg::A1, FM + plan::FM_ZERO as i64);
    a.li(Reg::A2, FM + plan::FM_SCRATCH as i64);
    a.li(Reg::S3, weight_map::RAW_BASE as i64);
    a.li(Reg::S4, DMEM + plan::DMEM_SLICE_TH as i64);
    // Packed output rows: straight to the out buffer, or staged in
    // PREPOOL for the host OR pass.
    let dst = if lp.pooled { FM + plan::FM_PREPOOL as i64 } else { FM + p.out_buf(i) as i64 };
    a.li(Reg::S1, dst);

    // Prefill: per macro, its slice words of the zero row and rows 0, 1.
    for &(m, c0, c1) in &groups {
        let wa = c0 / 32;
        let sl = (c1 - c0) / 32;
        if multi {
            emit_sel(a, m as i64);
        }
        for j in 0..sl {
            a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
        }
        for r in 0..2 {
            for j in 0..sl {
                a.cim(CimInstr::conv(Reg::A0, (r * s + wa + j) as u16, Reg::A2, 0, 7, true));
            }
        }
    }
    a.addi(Reg::A0, Reg::A0, (8 * s) as i32);

    for t in 0..t_len {
        // Fire each macro and drain its raw partials to its RAWPART row.
        for (gi, &(m, ..)) in groups.iter().enumerate() {
            if multi {
                emit_sel(a, m as i64);
            }
            a.cim(CimInstr::conv(Reg::A0, 0, Reg::A2, 0, 0, false));
            a.li(Reg::A3, DMEM + plan::DMEM_RAWPART as i64 + (4 * gi * c_out) as i64);
            a.mv(Reg::A1, Reg::S3);
            for c in 0..c_out {
                if c > 0 && c % 128 == 0 {
                    a.addi(Reg::A3, Reg::A3, 4 * 128); // imm_d is 7 bits
                }
                a.cim(CimInstr::read(Reg::A1, c as u16, Reg::A3, (c % 128) as u16));
            }
            a.li(Reg::A1, FM + plan::FM_ZERO as i64);
        }
        // Merge partials into row 0 (exact integer adds).
        for gi in 1..groups.len() {
            a.li(Reg::S0, DMEM + plan::DMEM_RAWPART as i64);
            a.li(Reg::S5, DMEM + plan::DMEM_RAWPART as i64 + (4 * gi * c_out) as i64);
            a.li(Reg::S2, c_out as i64);
            let top = a.here_label();
            a.lw(Reg::T0, Reg::S0, 0);
            a.lw(Reg::T1, Reg::S5, 0);
            a.add(Reg::T0, Reg::T0, Reg::T1);
            a.sw(Reg::S0, Reg::T0, 0);
            a.addi(Reg::S0, Reg::S0, 4);
            a.addi(Reg::S5, Reg::S5, 4);
            a.addi(Reg::S2, Reg::S2, -1);
            a.bne(Reg::S2, Reg::ZERO, top);
        }
        // Threshold (strict >, same compare as the macro latch) and pack.
        a.li(Reg::S0, DMEM + plan::DMEM_RAWPART as i64);
        for wd in 0..o {
            a.li(Reg::T3, 0);
            for bit in 0..32.min(c_out - wd * 32) {
                let c = wd * 32 + bit;
                a.lw(Reg::T0, Reg::S0, (4 * c) as i32);
                a.lw(Reg::T1, Reg::S4, (4 * c) as i32);
                a.slt(Reg::T1, Reg::T1, Reg::T0);
                if bit > 0 {
                    a.slli(Reg::T1, Reg::T1, bit as i32);
                }
                a.or(Reg::T3, Reg::T3, Reg::T1);
            }
            a.sw(Reg::S1, Reg::T3, (4 * wd) as i32);
        }
        a.addi(Reg::S1, Reg::S1, (4 * o) as i32);
        // Shift in row t+2 (per macro, its slice).
        if t + 2 < t_len {
            for &(m, c0, c1) in &groups {
                let wa = c0 / 32;
                let sl = (c1 - c0) / 32;
                if multi {
                    emit_sel(a, m as i64);
                }
                for j in 0..sl {
                    a.cim(CimInstr::conv(Reg::A0, (wa + j) as u16, Reg::A2, 0, 7, true));
                }
            }
            a.addi(Reg::A0, Reg::A0, (4 * s) as i32);
        } else if t + 2 == t_len {
            for &(m, c0, c1) in &groups {
                let sl = (c1 - c0) / 32;
                if multi {
                    emit_sel(a, m as i64);
                }
                for j in 0..sl {
                    a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
                }
            }
        }
    }

    // Host OR pooling PREPOOL -> out buffer.
    if lp.pooled {
        a.li(Reg::S0, FM + plan::FM_PREPOOL as i64);
        a.li(Reg::S1, FM + p.out_buf(i) as i64);
        a.li(Reg::S2, lp.t_out as i64);
        let top = a.here_label();
        for w in 0..o {
            a.lw(Reg::T0, Reg::S0, (4 * w) as i32);
            a.lw(Reg::T1, Reg::S0, (4 * (o + w)) as i32);
            a.or(Reg::T0, Reg::T0, Reg::T1);
            a.sw(Reg::S1, Reg::T0, (4 * w) as i32);
        }
        a.addi(Reg::S0, Reg::S0, (8 * o) as i32);
        a.addi(Reg::S1, Reg::S1, (4 * o) as i32);
        a.addi(Reg::S2, Reg::S2, -1);
        a.bne(Reg::S2, Reg::ZERO, top);
    }

    // Baseline FM round trip (no layer fusion), as in the classic image.
    if !opt.layer_fusion && i + 1 < p.layers.len() {
        let out = p.out_buf(i) as i64;
        let bytes = lp.out_bytes() as i64;
        emit_udma_start(a, FM + out, layout::DRAM_BASE as i64 + plan::DRAM_FM_SPILL as i64, bytes);
        emit_udma_wait(a);
        emit_udma_start(a, layout::DRAM_BASE as i64 + plan::DRAM_FM_SPILL as i64, FM + out, bytes);
        emit_udma_wait(a);
    }
    emit_phase(a, Phase::conv_done(i));
}

/// Final layer under input-axis sharding: per-macro raw partials of the
/// `n_classes` columns merge into the GAP dump row.
fn emit_input_final_layer(a: &mut Asm, p: &KwsPlan, shards: &ShardPlan, model: &KwsModel) {
    let i = p.layers.len() - 1;
    let lp = &p.layers[i];
    let s = lp.s_words;
    let t_len = lp.t_in;
    let n = model.n_classes;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();
    let k = lp.window_words / s;

    for &(m, c0, c1) in &groups {
        let sl = (c1 - c0) / 32;
        if multi {
            emit_sel(a, m as i64);
        }
        let cfg = CimConfig {
            mode: Mode::X,
            pool_or: false,
            window_words: (k * sl) as u8,
            row_base: 0,
            col_base: 0,
        };
        a.li(Reg::T0, cfg.to_bits() as i64);
        mmio_sw(a, Reg::T0, layout::MMIO_CIM_CFG);
    }

    a.li(Reg::A0, FM + p.in_buf(i) as i64);
    a.li(Reg::A1, FM + plan::FM_ZERO as i64);
    a.li(Reg::A2, FM + plan::FM_SCRATCH as i64);
    a.li(Reg::S3, weight_map::RAW_BASE as i64);
    a.li(Reg::S1, DMEM + plan::DMEM_RAWDUMP as i64); // walking dump row ptr

    for &(m, c0, c1) in &groups {
        let wa = c0 / 32;
        let sl = (c1 - c0) / 32;
        if multi {
            emit_sel(a, m as i64);
        }
        for j in 0..sl {
            a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
        }
        for r in 0..2 {
            for j in 0..sl {
                a.cim(CimInstr::conv(Reg::A0, (r * s + wa + j) as u16, Reg::A2, 0, 7, true));
            }
        }
    }
    a.addi(Reg::A0, Reg::A0, (8 * s) as i32);

    for t in 0..t_len {
        for (gi, &(m, ..)) in groups.iter().enumerate() {
            if multi {
                emit_sel(a, m as i64);
            }
            a.cim(CimInstr::conv(Reg::A0, 0, Reg::A2, 0, 0, false));
            a.li(Reg::A3, DMEM + plan::DMEM_RAWPART as i64);
            a.mv(Reg::A1, Reg::S3);
            for c in 0..n {
                a.cim(CimInstr::read(Reg::A1, c as u16, Reg::A3, (gi * n + c) as u16));
            }
            a.li(Reg::A1, FM + plan::FM_ZERO as i64);
        }
        // Merge the per-macro class partials into the dump row.
        a.li(Reg::A3, DMEM + plan::DMEM_RAWPART as i64);
        for c in 0..n {
            a.lw(Reg::T0, Reg::A3, (4 * c) as i32);
            for gi in 1..groups.len() {
                a.lw(Reg::T1, Reg::A3, (4 * (gi * n + c)) as i32);
                a.add(Reg::T0, Reg::T0, Reg::T1);
            }
            a.sw(Reg::S1, Reg::T0, (4 * c) as i32);
        }
        a.addi(Reg::S1, Reg::S1, (4 * n) as i32);
        if t + 2 < t_len {
            for &(m, c0, c1) in &groups {
                let wa = c0 / 32;
                let sl = (c1 - c0) / 32;
                if multi {
                    emit_sel(a, m as i64);
                }
                for j in 0..sl {
                    a.cim(CimInstr::conv(Reg::A0, (wa + j) as u16, Reg::A2, 0, 7, true));
                }
            }
            a.addi(Reg::A0, Reg::A0, (4 * s) as i32);
        } else if t + 2 == t_len {
            for &(m, c0, c1) in &groups {
                let sl = (c1 - c0) / 32;
                if multi {
                    emit_sel(a, m as i64);
                }
                for j in 0..sl {
                    a.cim(CimInstr::conv(Reg::A1, j as u16, Reg::A2, 0, 7, true));
                }
            }
        }
    }

    // GAP accumulate (identical to the classic epilogue).
    a.li(Reg::S0, DMEM + plan::DMEM_RAWDUMP as i64);
    a.li(Reg::S1, DMEM + plan::DMEM_RESULT as i64);
    for c in 0..n {
        a.sw(Reg::S1, Reg::ZERO, (c * 4) as i32);
    }
    a.li(Reg::S2, t_len as i64);
    let gap_top = a.here_label();
    for c in 0..n {
        a.lw(Reg::T0, Reg::S1, (c * 4) as i32);
        a.lw(Reg::T1, Reg::S0, (c * 4) as i32);
        a.add(Reg::T0, Reg::T0, Reg::T1);
        a.sw(Reg::S1, Reg::T0, (c * 4) as i32);
    }
    a.addi(Reg::S0, Reg::S0, (n * 4) as i32);
    a.addi(Reg::S2, Reg::S2, -1);
    a.bne(Reg::S2, Reg::ZERO, gap_top);
    emit_phase(a, Phase::conv_done(i));
}

/// Build a program sharded on the *input-channel* axis
/// (`ShardPlan::input_word_aligned`): every macro holds all output
/// columns of a disjoint input-word slice of each layer and fires over a
/// proportionally shrunk window; the core merges raw partial sums
/// exactly. This is the fallback when a fused group's full window exceeds
/// one macro's wordlines. Thresholding/pooling move to the core, so
/// `conv_pool_pipeline` and `weight_fusion` are no-ops here; `fused` is
/// rejected (tensor-level residency for sliced windows lives in `fsim`).
pub fn build_kws_program_input_sharded(
    model: &KwsModel,
    opt: OptLevel,
    n_macros: usize,
) -> Result<Program> {
    anyhow::ensure!(!opt.fused, "input-axis sharding: resident fusion not supported on the cycle engine");
    let p = KwsPlan::new(model)?;
    let shards = ShardPlan::input_word_aligned(&p, n_macros.max(1))?;
    // Boot without the weight-fusion descriptor chain: the per-layer
    // threshold DMA below would contend with boot-enqueued descriptors.
    let serial = OptLevel { weight_fusion: false, ..opt };
    let mut a = Asm::new();
    emit_boot(&mut a, &p, &shards, serial);
    emit_preprocess(&mut a, model);
    for i in 0..p.layers.len() {
        emit_input_weight_phase(&mut a, &p, &shards, i);
        if p.layers[i].binarized {
            emit_input_conv_layer(&mut a, &p, &shards, i, opt);
        } else {
            emit_input_final_layer(&mut a, &p, &shards, model);
        }
    }
    emit_epilogue(&mut a);

    let (thr_words, flip_words) = dmem_tables(model);
    let final_t = p.layers.last().unwrap().t_in;
    Ok(Program {
        imem: a.assemble()?,
        entry: 0,
        dram: p.build_dram_weights(model),
        dmem: vec![(plan::DMEM_THR, thr_words), (plan::DMEM_FLIP, flip_words)],
        result_addr: plan::DMEM_RESULT,
        final_t,
        opt,
        n_classes: model.n_classes,
        plan: p,
        shards,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::decode;

    fn fake_model() -> KwsModel {
        use crate::model::kws::LayerSpec;
        let mk = |ci: usize, co: usize, pooled: bool, binarized: bool| LayerSpec {
            c_in: ci,
            c_out: co,
            kernel: 3,
            pooled,
            binarized,
            weights: (0..3 * ci * co).map(|x| if x % 3 == 0 { 1 } else { -1 }).collect(),
            thresholds: if binarized { vec![0; co] } else { vec![] },
        };
        KwsModel {
            audio_len: 16000,
            t: 128,
            c: 64,
            n_classes: 12,
            fusion_split: 1,
            layers: vec![mk(64, 64, true, true), mk(64, 12, false, false)],
            bn_gamma: vec![1.0; 64],
            bn_beta: vec![0.0; 64],
            bn_mean: vec![10.0; 64],
            bn_var: vec![100.0; 64],
            pre_thr: vec![10; 64],
            pre_dir: vec![1; 64],
            trained: false,
            artifacts_dir: std::path::PathBuf::new(),
        }
    }

    #[test]
    fn builds_and_decodes_for_all_opt_levels() {
        let m = fake_model();
        for (_, opt) in crate::baselines::OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            assert!(!prog.imem.is_empty());
            assert!(prog.imem.len() * 4 <= layout::IMEM_SIZE as usize, "IMEM overflow");
            // Every emitted word must decode.
            for (i, w) in prog.imem.iter().enumerate() {
                decode(*w).unwrap_or_else(|e| panic!("word {i}: {e}"));
            }
        }
    }

    #[test]
    fn baseline_has_more_instructions() {
        let m = fake_model();
        let base = build_kws_program(&m, OptLevel::BASELINE).unwrap();
        let full = build_kws_program(&m, OptLevel::FULL).unwrap();
        assert!(
            base.imem.len() > full.imem.len(),
            "baseline adds pooling passes + FM spills: {} vs {}",
            base.imem.len(),
            full.imem.len()
        );
    }

    #[test]
    fn sharded_build_encodes_and_single_matches_classic() {
        let m = fake_model();
        let classic = build_kws_program(&m, OptLevel::FULL).unwrap();
        let one = build_kws_program_sharded(&m, OptLevel::FULL, 1).unwrap();
        // n_macros = 1 must be byte-identical to the classic image.
        assert_eq!(one.imem, classic.imem);
        assert_eq!(one.shards.n_macros, 1);
        for n in 2..=4 {
            let prog = build_kws_program_sharded(&m, OptLevel::FULL, n).unwrap();
            assert_eq!(prog.shards.n_macros, n);
            assert!(prog.shards.is_word_aligned());
            // Sharded programs interleave selects: strictly more instrs.
            assert!(prog.imem.len() > classic.imem.len());
            for (i, w) in prog.imem.iter().enumerate() {
                decode(*w).unwrap_or_else(|e| panic!("n={n} word {i}: {e}"));
            }
        }
    }

    #[test]
    fn fused_build_has_setup_and_steady_sections() {
        let m = fake_model();
        for n in 1..=4 {
            let prog = build_kws_program_sharded(&m, OptLevel::FUSED, n).unwrap();
            // Setup section at PC 0, per-inference section at entry.
            assert!(prog.entry > 0 && prog.entry < prog.imem.len(), "n={n}");
            for (i, w) in prog.imem.iter().enumerate() {
                decode(*w).unwrap_or_else(|e| panic!("n={n} word {i}: {e}"));
            }
            // Steady state carries no weight-stream DMA: the per-inference
            // section is much smaller than a classic FULL image.
            let full = build_kws_program_sharded(&m, OptLevel::FULL, n).unwrap();
            assert!(prog.imem.len() - prog.entry < full.imem.len(), "n={n}");
        }
    }

    #[test]
    fn fused_requires_the_full_ladder() {
        let m = fake_model();
        let bad = OptLevel { fused: true, ..OptLevel::BASELINE };
        assert!(build_kws_program(&m, bad).is_err());
    }

    #[test]
    fn input_sharded_builds_and_decodes() {
        let m = fake_model();
        for n in 1..=4 {
            let prog = build_kws_program_input_sharded(&m, OptLevel::FULL, n).unwrap();
            assert_eq!(prog.shards.axis, crate::dataflow::ShardAxis::Input);
            assert_eq!(prog.entry, 0);
            assert!(prog.imem.len() * 4 <= layout::IMEM_SIZE as usize, "n={n}");
            for (i, w) in prog.imem.iter().enumerate() {
                decode(*w).unwrap_or_else(|e| panic!("n={n} word {i}: {e}"));
            }
        }
        assert!(build_kws_program_input_sharded(&m, OptLevel::FUSED, 2).is_err());
    }

    #[test]
    fn dram_image_covers_all_layers() {
        let m = fake_model();
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        assert_eq!(prog.dram.len(), 2);
        let total: usize = prog.dram.iter().map(|(_, b)| b.len()).sum();
        // L0: 64 cols * 6 words + 64 th; L1: 12 cols * 6 words.
        assert_eq!(total, (64 * 6 + 64 + 12 * 6) * 4);
    }
}
