//! The "full stack flow" compiler (paper §II-G, Fig. 10): takes the
//! trained/quantized KWS model and emits a complete, runnable RV32IM+CIM
//! program image — boot, integer preprocessing, per-layer weight loading
//! (uDMA + `cim_w` bursts), row-wise CIM convolution with the configured
//! optimizations, and RISC-V post-processing.
//!
//! * [`asm`]     — label-based mini-assembler over the `isa` encoder.
//! * [`codegen`] — the program generator, parameterized by
//!   `baselines::OptLevel` (layer fusion / conv-pool pipeline / weight
//!   fusion toggles — the ablation axes of Figs. 6/7/9).
//! * [`program`] — the linked image: IMEM words + DRAM staging + DMEM
//!   constant tables + metadata.

pub mod asm;
pub mod codegen;
pub mod fusion;
pub mod program;

pub use codegen::{build_kws_program, build_kws_program_input_sharded, build_kws_program_sharded};
pub use fusion::FusionPlan;
pub use program::{Phase, Program};
