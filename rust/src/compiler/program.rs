//! The linked program image produced by codegen and consumed by both
//! execution engines: the cycle-level SoC loader (`sim::soc`) and the
//! fast functional simulator (`fsim`).

use crate::baselines::OptLevel;
use crate::dataflow::plan::KwsPlan;
use crate::dataflow::shard::ShardPlan;

/// Phase marker ids written to `MMIO_HOST_PHASE` (cycle attribution).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    BootDone = 1,
    PreprocessDone = 2,
    /// Weight phase of layer i done: 10 + i.
    WeightBase = 10,
    /// Conv phase of layer i done: 30 + i.
    ConvBase = 30,
    /// Fused conv/pool pipeline of layer i entered its first pooled
    /// drain: 40 + i. Emitted only by fused programs; marks the start of
    /// the region where pooling drains overlap the next fires (the
    /// Perfetto exporter renders `[40+i, 30+i)` as a concurrent
    /// pool-drain slice). Cycle attribution folds it into the conv
    /// bucket (`PhaseBreakdown::from_markers` buckets 30..=49 as conv).
    PoolDrainBase = 40,
}

impl Phase {
    pub fn weight_done(layer: usize) -> u32 {
        Phase::WeightBase as u32 + layer as u32
    }

    pub fn conv_done(layer: usize) -> u32 {
        Phase::ConvBase as u32 + layer as u32
    }

    pub fn pool_drain(layer: usize) -> u32 {
        Phase::PoolDrainBase as u32 + layer as u32
    }
}

/// A complete bootable image.
#[derive(Debug, Clone)]
pub struct Program {
    /// Encoded instructions, loaded at IMEM 0 (boot vector).
    pub imem: Vec<u32>,
    /// Per-inference entry point (instruction index). Classic programs
    /// are one self-contained boot-and-run image (`entry == 0`). Fused
    /// programs (`opt.fused`) put a one-time *setup* section at PC 0 —
    /// mask-plane init, all weight DMA, resident layers' sign bursts —
    /// which the SoC loader executes once at construction; every
    /// [`crate::sim::Soc::run`] then starts here, in the steady-state
    /// per-inference section.
    pub entry: usize,
    /// DRAM staging: (byte offset, payload) chunks (weights; audio is
    /// staged per-inference by the SoC loader).
    pub dram: Vec<(u32, Vec<u8>)>,
    /// DMEM constant tables: (byte offset, words).
    pub dmem: Vec<(u32, Vec<u32>)>,
    /// DMEM byte address of the n_classes i32 result sums (divide by the
    /// final-layer T on the host for GAP logits).
    pub result_addr: u32,
    /// Final-layer time length (GAP divisor).
    pub final_t: usize,
    /// The optimization level this program was compiled with.
    pub opt: OptLevel,
    pub n_classes: usize,
    /// The address/schedule plan the image was generated from. Carried in
    /// the image so tensor-level backends (`fsim`) can reconstruct layer
    /// geometry and decode the DRAM weight streams without the source
    /// model — the program is the single deployable artifact.
    pub plan: KwsPlan,
    /// Multi-macro sharding metadata: which macro owns which output
    /// channels of each layer (`ShardPlan::single` for classic one-macro
    /// programs). Both engines consume it — the SoC sizes its macro bank
    /// from it, `fsim` pre-slices its packed layers from it.
    pub shards: ShardPlan,
}

impl Program {
    /// Rough static footprint for reports.
    pub fn imem_bytes(&self) -> usize {
        self.imem.len() * 4
    }
}
