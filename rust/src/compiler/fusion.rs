//! Multi-layer residency planning for fused programs (`OptLevel::FUSED`).
//!
//! The X-mode macro has 32 wordline blocks (32 × 32 = 1024 wordlines).
//! The classic program gives every layer the whole array at `row_base 0`
//! and re-bursts that layer's sign planes each inference. Fusion instead
//! packs as many consecutive layers' sign planes as fit *co-resident* at
//! disjoint wordline rows, bursts them once at program setup, and only
//! streams the layers that did not fit. Streamed layers share the row
//! region above the resident shelf ([`FusionPlan::stream_base`]), so a
//! streamed burst can never clobber a resident layer.
//!
//! Placement is purely row-axis: every layer (resident or streamed)
//! occupies sense-amp columns `0..c_out` of its own row rectangle, so the
//! per-column threshold registers are shared — binarized layers re-burst
//! thresholds per inference either way (cheap: `c_out` words vs the
//! `c_out * window_words` sign words the residency saves).
//!
//! The packing objective is DRAM-traffic/burst-cycle savings: residents
//! are chosen greedily by descending `sign_words` (ties to the earlier
//! layer), subject to `resident_rows + max(streamed window_words) <= 32`
//! — a fixpoint, since which layers stream determines the shelf budget.

use crate::cim::Mode;
use crate::dataflow::plan::KwsPlan;

/// Row-axis placement of every layer of a fused program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FusionPlan {
    /// Layer i's sign planes stay in the macro across inferences.
    pub resident: Vec<bool>,
    /// First wordline block (x32) of layer i's rectangle. Residents get
    /// disjoint rows packed from 0 in layer order; all streamed layers
    /// share [`Self::stream_base`].
    pub row_base: Vec<usize>,
    /// First row block above the resident shelf (= total resident rows).
    pub stream_base: usize,
}

impl FusionPlan {
    /// Plan residency for a single whole-width macro.
    pub fn new(p: &KwsPlan) -> FusionPlan {
        let ww: Vec<usize> = p.layers.iter().map(|l| l.window_words).collect();
        let sw: Vec<usize> = p.layers.iter().map(|l| l.sign_words).collect();
        Self::for_window_words(&ww, &sw)
    }

    /// Plan residency when each macro holds a `1/n` input-channel slice
    /// of every layer (`ShardPlan::input_word_aligned`): the per-macro
    /// window shrinks to `kernel * ceil(s_words/n)` row blocks, so more
    /// layers fit resident as the bank grows — the fallback path for
    /// fused groups wider than one macro's wordlines.
    pub fn for_slices(p: &KwsPlan, n: usize) -> FusionPlan {
        let ww: Vec<usize> = p
            .layers
            .iter()
            .map(|l| {
                let k = l.window_words / l.s_words.max(1);
                k * l.s_words.div_ceil(n.max(1))
            })
            .collect();
        let sw: Vec<usize> =
            p.layers.iter().map(|l| l.c_out * l.window_words.div_ceil(n.max(1))).collect();
        Self::for_window_words(&ww, &sw)
    }

    fn for_window_words(ww: &[usize], sign_words: &[usize]) -> FusionPlan {
        let n = ww.len();
        let cap = Mode::X.col_words(); // 32 row blocks
        let mut resident = vec![true; n];
        // Fixpoint: streamed layers set the shelf budget, the budget sets
        // who streams. Monotone in practice; capped at 2n rounds.
        for _ in 0..2 * n.max(1) {
            let streamed_ww =
                (0..n).filter(|&i| !resident[i]).map(|i| ww[i]).max().unwrap_or(0);
            let budget = cap.saturating_sub(streamed_ww);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&i| (std::cmp::Reverse(sign_words[i]), i));
            let mut next = vec![false; n];
            let mut used = 0usize;
            for &i in &order {
                if used + ww[i] <= budget {
                    next[i] = true;
                    used += ww[i];
                }
            }
            if next == resident {
                break;
            }
            resident = next;
        }
        let mut row_base = vec![0usize; n];
        let mut acc = 0usize;
        for i in 0..n {
            if resident[i] {
                row_base[i] = acc;
                acc += ww[i];
            }
        }
        for i in 0..n {
            if !resident[i] {
                row_base[i] = acc;
            }
        }
        FusionPlan { resident, row_base, stream_base: acc }
    }

    pub fn n_resident(&self) -> usize {
        self.resident.iter().filter(|&&r| r).count()
    }

    /// Sign words re-burst per inference under this plan (streamed layers
    /// only) — the quantity residency minimizes.
    pub fn streamed_sign_words(&self, p: &KwsPlan) -> usize {
        p.layers
            .iter()
            .zip(&self.resident)
            .filter(|(_, &r)| !r)
            .map(|(l, _)| l.sign_words)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::KwsModel;

    fn plan_of(m: &KwsModel) -> (KwsPlan, FusionPlan) {
        let p = KwsPlan::new(m).unwrap();
        let f = FusionPlan::new(&p);
        (p, f)
    }

    #[test]
    fn placement_is_disjoint_and_within_budget() {
        for m in [KwsModel::synthetic(3), KwsModel::synthetic_wide(1)] {
            let (p, f) = plan_of(&m);
            assert_eq!(f.resident.len(), p.layers.len());
            let mut shelf = 0usize;
            for (i, l) in p.layers.iter().enumerate() {
                if f.resident[i] {
                    assert_eq!(f.row_base[i], shelf, "residents pack in layer order");
                    shelf += l.window_words;
                } else {
                    assert_eq!(f.row_base[i], f.stream_base);
                }
            }
            assert_eq!(f.stream_base, shelf);
            let max_streamed = p
                .layers
                .iter()
                .enumerate()
                .filter(|(i, _)| !f.resident[*i])
                .map(|(_, l)| l.window_words)
                .max()
                .unwrap_or(0);
            assert!(shelf + max_streamed <= Mode::X.col_words());
        }
    }

    #[test]
    fn small_models_go_fully_resident() {
        // synthetic(3): window_words sum well under 32 -> everything
        // resident, zero per-inference sign traffic.
        let (p, f) = plan_of(&KwsModel::synthetic(3));
        assert!(f.resident.iter().all(|&r| r));
        assert_eq!(f.streamed_sign_words(&p), 0);
    }

    #[test]
    fn wide_models_stream_under_pressure() {
        // synthetic_wide: window_words [6, 24, 24, 18] cannot co-reside;
        // the fixpoint settles on a partial shelf that still leaves room
        // for the widest streamed window.
        let (p, f) = plan_of(&KwsModel::synthetic_wide(1));
        assert!(f.n_resident() >= 1, "some residency must survive");
        assert!(f.n_resident() < p.layers.len(), "not everything fits");
        assert!(f.streamed_sign_words(&p) < p.layers.iter().map(|l| l.sign_words).sum::<usize>());
    }

    #[test]
    fn slicing_grows_residency() {
        let m = KwsModel::synthetic_wide(2);
        let p = KwsPlan::new(&m).unwrap();
        let f1 = FusionPlan::for_slices(&p, 1);
        let f4 = FusionPlan::for_slices(&p, 4);
        assert_eq!(f1, FusionPlan::new(&p));
        assert!(f4.n_resident() > f1.n_resident(), "slicing frees wordline budget");
    }
}
